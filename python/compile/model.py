"""Layer 2: the checkpointed application — a byte-level transformer LM.

This is the "parallel scientific application" whose execution the paper's
coordinated-checkpointing model protects (DESIGN.md §6). The rust
coordinator trains it through PJRT: one AOT-lowered ``train_step`` call
per step, with the parameter/optimizer state living in rust-owned buffers
that the checkpoint manager serializes on the paper's period.

Design constraints from the three-layer architecture:

* Every dense contraction routes through the Layer-1 Pallas ``matmul``
  kernel so the training step's hot-spot is an explicitly tiled program.
* All model/optimizer state is carried as ONE flat f32 vector (``theta``
  plus Adam's ``m``/``v``): the HLO signature stays six buffers wide,
  which keeps the rust runtime simple and the checkpoint format trivial
  (three contiguous f32 blobs + a step counter).
* Shapes are static: batch and sequence length are baked at AOT time.

The model is a standard pre-LN causal transformer, sized so that a few
hundred CPU training steps complete in minutes (~470k parameters by
default — the end-to-end example's loss curve is the deliverable, not
the parameter count).
"""

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    seq: int = 64
    batch: int = 8
    d_mlp: int = 512
    lr: float = 3e-3
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# --------------------------------------------------------------------------
# Parameter layout: a flat f32 vector with a static (name, shape) manifest.
# --------------------------------------------------------------------------


def param_manifest(cfg: TransformerConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat layout."""
    d, v, s, h = cfg.d_model, cfg.vocab, cfg.seq, cfg.d_mlp
    manifest = [("embed", (v, d)), ("pos_embed", (s, d))]
    for i in range(cfg.n_layers):
        manifest += [
            (f"l{i}.ln1_g", (d,)),
            (f"l{i}.ln1_b", (d,)),
            (f"l{i}.wqkv", (d, 3 * d)),
            (f"l{i}.bqkv", (3 * d,)),
            (f"l{i}.wo", (d, d)),
            (f"l{i}.bo", (d,)),
            (f"l{i}.ln2_g", (d,)),
            (f"l{i}.ln2_b", (d,)),
            (f"l{i}.wmlp1", (d, h)),
            (f"l{i}.bmlp1", (h,)),
            (f"l{i}.wmlp2", (h, d)),
            (f"l{i}.bmlp2", (d,)),
        ]
    manifest += [
        ("ln_f_g", (d,)),
        ("ln_f_b", (d,)),
        ("w_logits", (d, v)),
        ("b_logits", (v,)),
    ]
    return manifest


def param_count(cfg: TransformerConfig) -> int:
    total = 0
    for _, shape in param_manifest(cfg):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def unflatten(cfg: TransformerConfig, theta) -> Dict[str, jnp.ndarray]:
    """Static slicing of the flat vector into named arrays (fused away by
    XLA — zero runtime cost)."""
    params = {}
    off = 0
    for name, shape in param_manifest(cfg):
        n = 1
        for s in shape:
            n *= s
        params[name] = theta[off : off + n].reshape(shape)
        off += n
    assert off == theta.shape[0], (off, theta.shape)
    return params


def init_theta(cfg: TransformerConfig, key) -> jnp.ndarray:
    """Initialise the flat parameter vector.

    Scaled-normal for projections, zeros for biases, ones for LN gains —
    the standard GPT-ish recipe.
    """
    chunks = []
    for name, shape in param_manifest(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            arr = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_b", "bqkv", "bo", "bmlp1", "bmlp2", "b_logits")):
            arr = jnp.zeros(shape, jnp.float32)
        elif name in ("embed", "pos_embed"):
            arr = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[0]
            arr = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(
                jnp.float32(fan_in)
            )
        chunks.append(arr.reshape(-1))
    return jnp.concatenate(chunks)


# --------------------------------------------------------------------------
# Forward pass.
# --------------------------------------------------------------------------


def _layer_norm(x, g, b, eps=1e-5):
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * g + b


def _dense(x2d, w, b):
    """[N, in] @ [in, out] + b through the Pallas kernel."""
    return matmul(x2d, w) + b


def forward(cfg: TransformerConfig, params: Dict[str, jnp.ndarray], tokens):
    """tokens i32[B, S] -> logits f32[B, S, V]."""
    b, s = tokens.shape
    d = cfg.d_model
    x = params["embed"][tokens] + params["pos_embed"][None, :s, :]

    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))
    for i in range(cfg.n_layers):
        p = lambda k: params[f"l{i}.{k}"]
        # Attention block.
        h = _layer_norm(x, p("ln1_g"), p("ln1_b"))
        qkv = _dense(h.reshape(b * s, d), p("wqkv"), p("bqkv"))
        qkv = qkv.reshape(b, s, 3, cfg.n_heads, cfg.d_head)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(
            jnp.float32(cfg.d_head)
        )
        scores = jnp.where(causal[None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b * s, d)
        x = x + _dense(ctx, p("wo"), p("bo")).reshape(b, s, d)
        # MLP block.
        h = _layer_norm(x, p("ln2_g"), p("ln2_b"))
        h = _dense(h.reshape(b * s, d), p("wmlp1"), p("bmlp1"))
        h = jax.nn.gelu(h)
        x = x + _dense(h, p("wmlp2"), p("bmlp2")).reshape(b, s, d)

    x = _layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    logits = _dense(x.reshape(b * s, d), params["w_logits"], params["b_logits"])
    return logits.reshape(b, s, cfg.vocab)


def loss_fn(cfg: TransformerConfig, theta, x_tokens, y_tokens):
    """Mean next-token cross-entropy."""
    params = unflatten(cfg, theta)
    logits = forward(cfg, params, x_tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y_tokens[..., None], axis=-1)
    return nll.mean()


# --------------------------------------------------------------------------
# Training step (Adam) and eval — the two AOT entry points.
# --------------------------------------------------------------------------


def train_step(cfg: TransformerConfig, theta, m, v, step, x_tokens, y_tokens):
    """One Adam step. All state flat; returns the updated state + loss.

    Signature (the artifact's parameter order the rust runtime relies on):
      theta f32[P], m f32[P], v f32[P], step f32[], x i32[B,S], y i32[B,S]
      -> (theta' f32[P], m' f32[P], v' f32[P], step' f32[], loss f32[])
    """
    loss, grad = jax.value_and_grad(lambda t: loss_fn(cfg, t, x_tokens, y_tokens))(
        theta
    )
    step = step + 1.0
    m = cfg.adam_b1 * m + (1.0 - cfg.adam_b1) * grad
    v = cfg.adam_b2 * v + (1.0 - cfg.adam_b2) * grad * grad
    mhat = m / (1.0 - cfg.adam_b1**step)
    vhat = v / (1.0 - cfg.adam_b2**step)
    theta = theta - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.adam_eps)
    return theta, m, v, step, loss


def eval_loss(cfg: TransformerConfig, theta, x_tokens, y_tokens):
    """Forward-only loss (used by the coordinator to verify restored
    checkpoints and to log validation loss)."""
    return (loss_fn(cfg, theta, x_tokens, y_tokens),)


def jitted_entry_points(cfg: TransformerConfig):
    """The two functions ``aot.py`` lowers, with shapes baked in."""
    p = param_count(cfg)
    f32 = jnp.float32
    i32 = jnp.int32
    theta_s = jax.ShapeDtypeStruct((p,), f32)
    scalar_s = jax.ShapeDtypeStruct((), f32)
    tok_s = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), i32)

    def train_fn(theta, m, v, step, x, y):
        return train_step(cfg, theta, m, v, step, x, y)

    def eval_fn(theta, x, y):
        return eval_loss(cfg, theta, x, y)

    return {
        "train_step": (train_fn, (theta_s, theta_s, theta_s, scalar_s, tok_s, tok_s)),
        "eval_loss": (eval_fn, (theta_s, tok_s, tok_s)),
    }
