"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal at build time: pytest compares
every kernel against its oracle over hypothesis-generated shapes and
parameter draws before ``aot.py`` is allowed to emit artifacts
(``make artifacts`` runs the tests first).
"""

import jax.numpy as jnp

from .sweep import N_PARAMS


def ref_matmul(x, y):
    """Oracle for kernels.matmul."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def ref_period_sweep(t_grid, params):
    """Oracle for kernels.period_sweep — straight transcription of
    §3.1/§3.2 with numpy-style broadcasting (no Pallas, no blocking)."""
    assert params.shape == (N_PARAMS,)
    c, r, d, omega, mu, t_base, p_static, p_cal, p_io, p_down = [
        params[i] for i in range(N_PARAMS)
    ]
    t = t_grid.astype(jnp.float32)

    a = (1.0 - omega) * c
    b = 1.0 - (d + r + omega * c) / mu
    hi = 2.0 * mu * b
    in_domain = (t > a) & (t < hi)
    t_safe = jnp.where(in_domain, t, a + 1.0)

    t_final = t_base * t_safe / ((t_safe - a) * (b - t_safe / (2.0 * mu)))
    failures = t_final / mu
    re_exec = (
        omega * c
        + (t_safe**2 - c**2) / (2.0 * t_safe)
        + omega * c**2 / (2.0 * t_safe)
    )
    t_cal = t_base + failures * re_exec
    t_io = t_base * c / (t_safe - a) + failures * (r + c**2 / (2.0 * t_safe))
    t_down = failures * d
    e_final = t_cal * p_cal + t_io * p_io + t_down * p_down + t_final * p_static

    inf = jnp.float32(jnp.inf)
    return (
        jnp.where(in_domain, t_final, inf),
        jnp.where(in_domain, e_final, inf),
    )
