"""Pallas period-sweep kernel (Layer 1).

Evaluates the paper's closed forms ``T_final(T)`` and ``E_final(T)``
(§3.1–§3.2) for a dense grid of candidate periods in one shot. This is
the figure harness's inner loop, expressed as an elementwise Pallas
program: the grid of periods is tiled into VMEM-sized blocks and the ten
scenario scalars are broadcast to every block.

The rust coordinator loads the lowered artifact
(``artifacts/sweep_eval.hlo.txt``) and cross-checks its own
``model::{time,energy}`` implementation against it through PJRT — a
three-layer consistency test (rust float math vs XLA vs the pure-jnp
oracle in ``ref.py``).

Out-of-domain periods (``T ≤ (1−ω)C`` or ``T ≥ 2μb``) evaluate to +inf,
mirroring ``model::time::t_final``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Scenario parameter vector layout (keep in sync with
# rust/src/runtime/artifacts.rs and ref.py):
PARAM_NAMES = (
    "c",
    "r",
    "d",
    "omega",
    "mu",
    "t_base",
    "p_static",
    "p_cal",
    "p_io",
    "p_down",
)
N_PARAMS = len(PARAM_NAMES)

# Periods per Pallas block: 128 f64-ish f32 lanes is one VPU-friendly
# vector register row; the whole block is a few KB of VMEM.
BLOCK = 128


def _sweep_math(t, p):
    """Shared elementwise math (used by the kernel body on refs)."""
    c, r, d, omega, mu = p[0], p[1], p[2], p[3], p[4]
    t_base, p_static, p_cal, p_io, p_down = p[5], p[6], p[7], p[8], p[9]

    a = (1.0 - omega) * c
    b = 1.0 - (d + r + omega * c) / mu
    hi = 2.0 * mu * b

    in_domain = (t > a) & (t < hi)
    # Guard the arithmetic so out-of-domain lanes do not produce NaNs
    # that would poison `where`.
    t_safe = jnp.where(in_domain, t, a + 1.0)

    denom = (t_safe - a) * (b - t_safe / (2.0 * mu))
    t_final = t_base * t_safe / denom

    failures = t_final / mu
    re_exec = (
        omega * c
        + (t_safe * t_safe - c * c) / (2.0 * t_safe)
        + omega * c * c / (2.0 * t_safe)
    )
    t_cal = t_base + failures * re_exec
    t_io = t_base * c / (t_safe - a) + failures * (r + c * c / (2.0 * t_safe))
    t_down = failures * d
    e_final = (
        t_cal * p_cal + t_io * p_io + t_down * p_down + t_final * p_static
    )

    inf = jnp.float32(jnp.inf)
    return (
        jnp.where(in_domain, t_final, inf),
        jnp.where(in_domain, e_final, inf),
    )


def _sweep_kernel(t_ref, p_ref, tf_ref, ef_ref):
    tf, ef = _sweep_math(t_ref[...], p_ref[...])
    tf_ref[...] = tf
    ef_ref[...] = ef


@functools.partial(jax.jit, static_argnames=("interpret",))
def period_sweep(t_grid, params, *, interpret=True):
    """Evaluate (T_final, E_final) for every period in ``t_grid``.

    Args:
      t_grid: f32[n] candidate periods; n must be a multiple of BLOCK.
      params: f32[N_PARAMS] scenario vector (see PARAM_NAMES).

    Returns:
      (t_final f32[n], e_final f32[n]).
    """
    (n,) = t_grid.shape
    assert n % BLOCK == 0, f"grid size {n} not a multiple of {BLOCK}"
    assert params.shape == (N_PARAMS,)
    return pl.pallas_call(
        _sweep_kernel,
        grid=(n // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((N_PARAMS,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(t_grid, params)
