"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

- ``matmul``: MXU-tiled matrix multiply with a custom VJP so the L2
  training step differentiates through it.
- ``sweep``: the paper's T_final/E_final formulas evaluated over a dense
  grid of candidate periods (the figure harness's hot loop).
- ``ref``: pure-jnp oracles for both, used by pytest.
"""

from .matmul import matmul, pallas_matmul
from .sweep import period_sweep

__all__ = ["matmul", "pallas_matmul", "period_sweep"]
