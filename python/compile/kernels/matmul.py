"""Pallas tiled matmul (Layer 1).

The transformer's dense layers (QKV/output projections, MLP, logits) all
route through this kernel, so the training step's compute hot-spot lowers
to an explicitly tiled program.

TPU mapping (DESIGN.md §7): each grid cell loads an ``(bm, K)`` × ``(K,
bn)`` pair of VMEM-resident tiles and issues one MXU contraction with
``preferred_element_type=float32`` accumulation. The BlockSpec index maps
express the HBM→VMEM schedule; K is kept un-tiled because every K we use
(≤ 512) fits VMEM comfortably (see the VMEM budget check below).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel runs through the Pallas interpreter and lowers
to plain HLO — numerically identical, structurally the same program.

``matmul`` wraps the kernel in a ``jax.custom_vjp`` whose backward pass
reuses the same kernel (dX = dO·Wᵀ, dW = Xᵀ·dO), making the L2 training
step differentiable through Pallas.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget per core in bytes (TPU v4-class scratchpad); the tile
# chooser refuses configurations whose working set exceeds a safe half of
# it. This is the structural knob the §Perf pass tunes.
VMEM_BYTES = 16 * 1024 * 1024
VMEM_SAFETY = 0.5

# MXU systolic array is 128x128: column tiles stick to the 128-lane
# width; row tiles go as large as VMEM allows — a bigger bm amortises the
# weight-tile (K, bn) load across more rows and, on the CPU-interpret
# path our AOT artifact actually executes, cuts the per-grid-cell
# dispatch overhead ~3x (see EXPERIMENTS.md §Perf L1-1 for the sweep).
ROW_TILES = (512, 256, 128, 64, 32, 16, 8)
COL_TILES = (128, 64, 32, 16, 8)
# Kept for backward compatibility with older callers/tests.
PREFERRED_TILES = COL_TILES


def _pick_tile(dim: int, preferred) -> int:
    """Largest preferred tile that divides ``dim`` (falls back to dim)."""
    for t in preferred:
        if dim % t == 0 and t <= dim:
            return t
    return dim


def tile_config(m: int, k: int, n: int):
    """Choose (bm, bn) tiles and check the VMEM working set.

    Returns ``(bm, bn, vmem_bytes)``. Raises if even the smallest tiling
    exceeds the VMEM budget (callers should then tile K too — our shapes
    never need it).
    """
    bm, bn = _pick_tile(m, ROW_TILES), _pick_tile(n, COL_TILES)
    while True:
        vmem = 4 * (bm * k + k * bn + bm * bn)  # f32 operand+output tiles
        if vmem <= VMEM_BYTES * VMEM_SAFETY:
            return bm, bn, vmem
        if bm >= bn and bm > 8:
            bm //= 2
        elif bn > 8:
            bn //= 2
        else:
            raise ValueError(
                f"matmul tile ({bm}x{k})x({k}x{bn}) exceeds VMEM budget"
            )


def _mm_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def pallas_matmul(x, y, *, interpret=True):
    """Raw Pallas matmul: ``x @ y`` with grid tiling, no VJP."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm, bn, _ = tile_config(m, k, n)
    return pl.pallas_call(
        _mm_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, y)


@jax.custom_vjp
def matmul(x, y):
    """Differentiable Pallas matmul used by the L2 model."""
    return pallas_matmul(x, y)


def _matmul_fwd(x, y):
    return pallas_matmul(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # Both cotangents go through the same Pallas kernel.
    dx = pallas_matmul(g, y.T)
    dy = pallas_matmul(x.T, g)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)
