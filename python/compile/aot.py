"""AOT compiler: lower the L2/L1 program once to HLO text artifacts.

Run via ``make artifacts`` (or ``python -m compile.aot --out-dir
../artifacts`` from ``python/``). Python never runs again after this —
the rust binary loads the artifacts through PJRT.

Interchange format is **HLO text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts written:
  train_step.hlo.txt  — one Adam step of the transformer LM
  eval_loss.hlo.txt   — forward-only loss
  sweep_eval.hlo.txt  — Pallas period-sweep kernel over a 1024-point grid
  params.bin          — initial flat f32 parameter vector (little-endian)
  meta.json           — shapes, dtypes, layout manifest, config, seeds
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import sweep as sweep_mod


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(cfg: model_mod.TransformerConfig, out_dir: str) -> dict:
    entries = model_mod.jitted_entry_points(cfg)
    meta_fns = {}
    for name, (fn, specs) in entries.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta_fns[name] = {
            "path": os.path.basename(path),
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"wrote {path} ({len(text)} chars)")
    return meta_fns


def lower_sweep(grid_n: int, out_dir: str) -> dict:
    f32 = jnp.float32
    t_spec = jax.ShapeDtypeStruct((grid_n,), f32)
    p_spec = jax.ShapeDtypeStruct((sweep_mod.N_PARAMS,), f32)
    lowered = jax.jit(
        lambda t, p: sweep_mod.period_sweep(t, p)
    ).lower(t_spec, p_spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "sweep_eval.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")
    return {
        "path": os.path.basename(path),
        "grid_n": grid_n,
        "param_names": list(sweep_mod.PARAM_NAMES),
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }


def dump_params(cfg: model_mod.TransformerConfig, seed: int, out_dir: str) -> dict:
    theta = model_mod.init_theta(cfg, jax.random.PRNGKey(seed))
    raw = bytes(memoryview(jnp.asarray(theta, jnp.float32)).cast("B"))
    path = os.path.join(out_dir, "params.bin")
    with open(path, "wb") as f:
        f.write(raw)
    print(f"wrote {path} ({len(raw)} bytes, {theta.shape[0]} params)")
    manifest = []
    off = 0
    for name, shape in model_mod.param_manifest(cfg):
        n = 1
        for s in shape:
            n *= s
        manifest.append({"name": name, "shape": list(shape), "offset": off})
        off += n
    return {
        "path": os.path.basename(path),
        "n_params": int(theta.shape[0]),
        "seed": seed,
        "manifest": manifest,
        "sha256": hashlib.sha256(raw).hexdigest(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--grid-n", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=2013)
    # Model size knobs (defaults match DESIGN.md).
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = model_mod.TransformerConfig(
        d_model=args.d_model,
        n_layers=args.n_layers,
        n_heads=args.n_heads,
        seq=args.seq,
        batch=args.batch,
        d_mlp=4 * args.d_model,
        lr=args.lr,
    )
    os.makedirs(args.out_dir, exist_ok=True)

    meta = {
        "paper": "Aupy et al., Optimal Checkpointing Period: Time vs. Energy (2013)",
        "jax_version": jax.__version__,
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "seq": cfg.seq,
            "batch": cfg.batch,
            "d_mlp": cfg.d_mlp,
            "lr": cfg.lr,
        },
        "functions": lower_model(cfg, args.out_dir),
        "sweep": lower_sweep(args.grid_n, args.out_dir),
        "params": dump_params(cfg, args.seed, args.out_dir),
    }
    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
