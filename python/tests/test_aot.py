"""AOT pipeline: lowering produces loadable, well-formed artifacts."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

PY_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # Tiny model so the test lowers in seconds.
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--d-model",
            "32",
            "--n-layers",
            "1",
            "--n-heads",
            "2",
            "--seq",
            "16",
            "--batch",
            "4",
            "--grid-n",
            "256",
        ],
        cwd=PY_DIR,
        check=True,
    )
    return out


def test_all_artifacts_exist(artifacts):
    for name in (
        "train_step.hlo.txt",
        "eval_loss.hlo.txt",
        "sweep_eval.hlo.txt",
        "params.bin",
        "meta.json",
    ):
        assert (artifacts / name).exists(), name


def test_hlo_text_is_parseable_shape(artifacts):
    for name in ("train_step", "eval_loss", "sweep_eval"):
        text = (artifacts / f"{name}.hlo.txt").read_text()
        assert "ENTRY" in text, name
        assert "HloModule" in text, name


def test_meta_consistency(artifacts):
    meta = json.loads((artifacts / "meta.json").read_text())
    n = meta["params"]["n_params"]
    raw = (artifacts / "params.bin").read_bytes()
    assert len(raw) == 4 * n
    theta = np.frombuffer(raw, np.float32)
    assert np.isfinite(theta).all()
    # Manifest offsets are contiguous and complete.
    off = 0
    for entry in meta["params"]["manifest"]:
        assert entry["offset"] == off
        off += int(np.prod(entry["shape"]))
    assert off == n
    # train_step inputs: theta, m, v, step, x, y.
    ins = meta["functions"]["train_step"]["inputs"]
    assert len(ins) == 6
    assert ins[0]["shape"] == [n]
    assert ins[3]["shape"] == []
    assert ins[4]["dtype"] == "int32"
    assert meta["sweep"]["param_names"][0] == "c"


def test_train_step_hlo_has_flat_signature(artifacts):
    text = (artifacts / "train_step.hlo.txt").read_text()
    meta = json.loads((artifacts / "meta.json").read_text())
    n = meta["params"]["n_params"]
    # Entry computation takes three f32[n] state vectors.
    assert text.count(f"f32[{n}]") >= 3
