"""L2 correctness: transformer shapes, flat-layout invariants, and a
short end-to-end training sanity run (loss must drop on learnable data).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def tiny_cfg(**kw):
    base = dict(
        vocab=64, d_model=32, n_heads=2, n_layers=1, seq=16, batch=4, d_mlp=64,
        lr=1e-2,
    )
    base.update(kw)
    return M.TransformerConfig(**base)


def test_param_count_matches_manifest():
    cfg = tiny_cfg()
    total = sum(int(np.prod(s)) for _, s in M.param_manifest(cfg))
    assert M.param_count(cfg) == total
    theta = M.init_theta(cfg, jax.random.PRNGKey(0))
    assert theta.shape == (total,)


def test_default_config_size():
    cfg = M.TransformerConfig()
    n = M.param_count(cfg)
    assert 300_000 < n < 800_000, n  # ~470k by design


def test_unflatten_roundtrip_offsets():
    cfg = tiny_cfg()
    theta = jnp.arange(M.param_count(cfg), dtype=jnp.float32)
    params = M.unflatten(cfg, theta)
    off = 0
    for name, shape in M.param_manifest(cfg):
        n = int(np.prod(shape))
        np.testing.assert_array_equal(
            np.asarray(params[name]).reshape(-1),
            np.arange(off, off + n, dtype=np.float32),
        )
        off += n


def test_init_scheme():
    cfg = tiny_cfg()
    params = M.unflatten(cfg, M.init_theta(cfg, jax.random.PRNGKey(1)))
    assert np.allclose(params["l0.ln1_g"], 1.0)
    assert np.allclose(params["l0.bqkv"], 0.0)
    assert 0.0 < np.std(np.asarray(params["l0.wqkv"])) < 1.0
    assert np.std(np.asarray(params["embed"])) < 0.05


def test_forward_shapes_and_finiteness():
    cfg = tiny_cfg()
    theta = M.init_theta(cfg, jax.random.PRNGKey(2))
    params = M.unflatten(cfg, theta)
    toks = jnp.zeros((cfg.batch, cfg.seq), jnp.int32)
    logits = M.forward(cfg, params, toks)
    assert logits.shape == (cfg.batch, cfg.seq, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_causality():
    # Changing a future token must not affect past logits.
    cfg = tiny_cfg()
    theta = M.init_theta(cfg, jax.random.PRNGKey(3))
    params = M.unflatten(cfg, theta)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)).astype(np.int32)
    toks2 = toks.copy()
    toks2[:, -1] = (toks2[:, -1] + 1) % cfg.vocab
    l1 = M.forward(cfg, params, jnp.asarray(toks))
    l2 = M.forward(cfg, params, jnp.asarray(toks2))
    np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], atol=1e-5)
    assert not np.allclose(l1[:, -1], l2[:, -1], atol=1e-5)


def test_initial_loss_near_log_vocab():
    cfg = tiny_cfg()
    theta = M.init_theta(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(1)
    x = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)).astype(np.int32)
    y = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)).astype(np.int32)
    loss = M.loss_fn(cfg, theta, jnp.asarray(x), jnp.asarray(y))
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


def synthetic_batch(cfg, rng):
    """Learnable data: y = (3x + 7) mod vocab — a lookup table a 1-layer
    transformer memorises quickly."""
    x = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)).astype(np.int32)
    y = ((3 * x + 7) % cfg.vocab).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


def test_train_step_decreases_loss():
    cfg = tiny_cfg()
    theta = M.init_theta(cfg, jax.random.PRNGKey(5))
    p = M.param_count(cfg)
    m = jnp.zeros(p, jnp.float32)
    v = jnp.zeros(p, jnp.float32)
    step = jnp.float32(0.0)
    rng = np.random.default_rng(2)

    step_fn = jax.jit(lambda *a: M.train_step(cfg, *a))
    first = None
    loss = None
    for _ in range(40):
        x, y = synthetic_batch(cfg, rng)
        theta, m, v, step, loss = step_fn(theta, m, v, step, x, y)
        if first is None:
            first = float(loss)
    assert float(step) == 40.0
    assert float(loss) < first * 0.7, (first, float(loss))


def test_train_step_deterministic():
    cfg = tiny_cfg()
    theta0 = M.init_theta(cfg, jax.random.PRNGKey(6))
    p = M.param_count(cfg)
    z = jnp.zeros(p, jnp.float32)
    rng = np.random.default_rng(3)
    x, y = synthetic_batch(cfg, rng)
    out1 = M.train_step(cfg, theta0, z, z, jnp.float32(0.0), x, y)
    out2 = M.train_step(cfg, theta0, z, z, jnp.float32(0.0), x, y)
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_eval_loss_matches_loss_fn():
    cfg = tiny_cfg()
    theta = M.init_theta(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(4)
    x, y = synthetic_batch(cfg, rng)
    (e,) = M.eval_loss(cfg, theta, x, y)
    l = M.loss_fn(cfg, theta, x, y)
    np.testing.assert_allclose(np.asarray(e), np.asarray(l))


def test_entry_points_shapes():
    cfg = tiny_cfg()
    eps = M.jitted_entry_points(cfg)
    assert set(eps) == {"train_step", "eval_loss"}
    fn, specs = eps["train_step"]
    assert len(specs) == 6
    assert specs[0].shape == (M.param_count(cfg),)
    assert specs[4].shape == (cfg.batch, cfg.seq)
