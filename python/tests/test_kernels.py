"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps the matmul shapes and the sweep kernel's scenario
parameters; numerics are compared with assert_allclose. This is the gate
``make artifacts`` runs before emitting HLO.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, pallas_matmul, period_sweep
from compile.kernels.matmul import tile_config, VMEM_BYTES, VMEM_SAFETY
from compile.kernels.ref import ref_matmul, ref_period_sweep
from compile.kernels.sweep import BLOCK, N_PARAMS


# ---------------------------------------------------------------- matmul

dims = st.sampled_from([8, 16, 24, 32, 64, 128, 256])


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    y = jax.random.normal(ky, (k, n), jnp.float32)
    out = pallas_matmul(x, y)
    np.testing.assert_allclose(out, ref_matmul(x, y), rtol=1e-5, atol=1e-5)


def test_matmul_nonsquare_large():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512, 128), jnp.float32)
    y = jax.random.normal(key, (128, 384), jnp.float32)
    np.testing.assert_allclose(
        pallas_matmul(x, y), ref_matmul(x, y), rtol=1e-5, atol=1e-5
    )


def test_matmul_rejects_contraction_mismatch():
    x = jnp.zeros((8, 16), jnp.float32)
    y = jnp.zeros((8, 16), jnp.float32)
    with pytest.raises(AssertionError):
        pallas_matmul(x, y)


@settings(max_examples=15, deadline=None)
@given(m=st.sampled_from([8, 64, 128]), seed=st.integers(0, 2**31 - 1))
def test_matmul_custom_vjp_matches_autodiff(m, seed):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (m, 32), jnp.float32)
    y = jax.random.normal(ky, (32, 16), jnp.float32)

    def f_pallas(x, y):
        return (matmul(x, y) ** 2).sum()

    def f_ref(x, y):
        return (ref_matmul(x, y) ** 2).sum()

    gx, gy = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    rx, ry = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gy, ry, rtol=1e-4, atol=1e-4)


def test_tile_config_divides_and_fits_vmem():
    for m, k, n in [(512, 128, 384), (512, 512, 128), (8, 8, 8), (128, 128, 256)]:
        bm, bn, vmem = tile_config(m, k, n)
        assert m % bm == 0 and n % bn == 0
        assert vmem <= VMEM_BYTES * VMEM_SAFETY


def test_tile_config_prefers_mxu_sized_tiles():
    # Rows: as large as VMEM allows (amortises weight-tile loads and
    # grid dispatch); columns: the 128-lane MXU width.
    bm, bn, _ = tile_config(512, 128, 384)
    assert bm == 512
    assert bn == 128


# ----------------------------------------------------------------- sweep


def paper_params(mu=300.0, rho=5.5, omega=0.5, c=10.0, r=10.0, d=1.0):
    alpha = 1.0
    beta = rho * (1.0 + alpha) - 1.0
    return np.array(
        [c, r, d, omega, mu, 10_000.0, 1.0, alpha, beta, 0.0], np.float32
    )


def test_sweep_matches_ref_paper_point():
    t = np.linspace(11.0, 500.0, 1024, dtype=np.float32)
    p = paper_params()
    tf, ef = period_sweep(jnp.asarray(t), jnp.asarray(p))
    rtf, ref_ = ref_period_sweep(jnp.asarray(t), jnp.asarray(p))
    np.testing.assert_allclose(tf, rtf, rtol=1e-5)
    np.testing.assert_allclose(ef, ref_, rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    mu=st.floats(200.0, 5000.0),
    rho=st.floats(1.0, 20.0),
    omega=st.floats(0.0, 1.0),
    c=st.floats(1.0, 15.0),
    blocks=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_sweep_matches_ref_hypothesis(mu, rho, omega, c, blocks, seed):
    n = BLOCK * blocks
    rng = np.random.default_rng(seed)
    t = rng.uniform(0.1, 3.0 * mu, n).astype(np.float32)
    p = paper_params(mu=mu, rho=rho, omega=omega, c=c, r=c, d=0.1 * c)
    tf, ef = period_sweep(jnp.asarray(t), jnp.asarray(p))
    rtf, ref_ = ref_period_sweep(jnp.asarray(t), jnp.asarray(p))
    np.testing.assert_allclose(tf, rtf, rtol=2e-5, atol=1e-3)
    np.testing.assert_allclose(ef, ref_, rtol=2e-5, atol=1e-3)


def test_sweep_out_of_domain_is_inf():
    p = paper_params()
    a = (1.0 - p[3]) * p[0]
    hi = 2.0 * p[4] * (1.0 - (p[2] + p[1] + p[3] * p[0]) / p[4])
    t = np.full(BLOCK, a * 0.5, np.float32)
    t[1] = hi * 1.5
    t[2] = 100.0  # in domain
    tf, ef = period_sweep(jnp.asarray(t), jnp.asarray(p))
    assert np.isinf(tf[0]) and np.isinf(ef[0])
    assert np.isinf(tf[1]) and np.isinf(ef[1])
    assert np.isfinite(tf[2]) and np.isfinite(ef[2])


def test_sweep_grid_argmin_near_eq1():
    # The grid argmin of T_final should sit near Eq. 1's
    # sqrt(2(1-w)C(mu-(D+R+wC))) = sqrt(2840) for the paper's Fig 1 point.
    p = paper_params()
    t = np.linspace(10.5, 300.0, 1024, dtype=np.float32)
    tf, _ = period_sweep(jnp.asarray(t), jnp.asarray(p))
    t_opt = float(t[int(np.argmin(np.asarray(tf)))])
    assert abs(t_opt - np.sqrt(2840.0)) < 2.0, t_opt


def test_sweep_requires_block_multiple():
    p = paper_params()
    with pytest.raises(AssertionError):
        period_sweep(jnp.zeros(100, jnp.float32), jnp.asarray(p))


def test_sweep_param_vector_arity():
    assert N_PARAMS == 10
    with pytest.raises(AssertionError):
        period_sweep(
            jnp.zeros(BLOCK, jnp.float32), jnp.zeros(N_PARAMS + 1, jnp.float32)
        )
