//! The paper's full §4 experimental study: regenerates Figures 1, 2, 3a,
//! 3b as CSV files, prints the headline numbers, and runs the ablations
//! (ω sweep, first-order accuracy, γ sweep, MSK comparison).
//!
//! ```bash
//! cargo run --release --example exascale_study [-- --out-dir target/figures]
//! ```

use std::path::PathBuf;

use ckpt_period::figures::{self, ablations, fig1, fig2, fig3, headline};
use ckpt_period::util::table::{fnum, Table};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let out_dir = args
        .iter()
        .position(|a| a == "--out-dir")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("target/figures"));

    println!("=== Figure 1: ratios vs rho (mu in {{30, 60, 120, 300}} min) ===");
    let f1 = fig1::series(&fig1::rho_grid(60));
    figures::persist(&fig1::table(&f1), &out_dir, "fig1")?;
    // Print the arrow points the paper emphasises.
    let mut t = Table::new(&["mu_min", "rho", "energy_gain_pct", "time_overhead_pct"]);
    for &mu in &fig1::MUS {
        for &rho in &fig1::RHO_ARROWS {
            let p = f1
                .iter()
                .filter(|p| p.mu == mu)
                .min_by(|a, b| {
                    (a.rho - rho).abs().partial_cmp(&(b.rho - rho).abs()).unwrap()
                })
                .unwrap();
            t.row(&[
                fnum(mu, 0),
                fnum(rho, 1),
                fnum((1.0 - 1.0 / p.energy_ratio) * 100.0, 2),
                fnum((p.time_ratio - 1.0) * 100.0, 2),
            ]);
        }
    }
    println!("{}", t.render());

    println!("=== Figure 2: ratio surfaces over (mu, rho) ===");
    let f2 = fig2::grid(&fig2::mu_grid(40), &fig2::rho_grid(40));
    figures::persist(&fig2::table(&f2), &out_dir, "fig2")?;
    println!(
        "max energy gain over the surface: {:.1}%\n",
        fig2::max_energy_gain_pct(&f2)
    );

    println!("=== Figure 3: ratios vs node count (C=R=1 min, mu=120min@1e6) ===");
    for (rho, name) in [(5.5, "fig3a"), (7.0, "fig3b")] {
        let pts = fig3::series(rho, &fig3::node_grid(80));
        figures::persist(&fig3::table(&pts), &out_dir, name)?;
        let (gain, at) = fig3::peak_energy_gain(&pts);
        let peak = pts
            .iter()
            .max_by(|a, b| a.energy_ratio.partial_cmp(&b.energy_ratio).unwrap())
            .unwrap();
        println!(
            "{name} (rho={rho}): peak energy gain {gain:.1}% at N={at:.2e} \
             (time overhead there: {:.1}%); domain limit N={:.2e}",
            (peak.time_ratio - 1.0) * 100.0,
            headline::fig3_domain_limit(rho)
        );
    }
    println!();

    println!("=== Headline numbers (paper §5) ===");
    let h = headline::compute();
    println!(
        "mu=300, rho=5.5: {:.1}% energy gain / {:.1}% time overhead \
         (paper: '>20% / ~10%')",
        h.energy_gain_mu300_rho55_pct, h.time_overhead_mu300_rho55_pct
    );
    println!(
        "mu=300, rho=7.0: {:.1}% energy gain / {:.1}% time overhead",
        h.energy_gain_mu300_rho7_pct, h.time_overhead_mu300_rho7_pct
    );
    println!(
        "Fig 3 peak: {:.1}% energy gain at N={:.2e} with {:.1}% time overhead \
         (paper: 'up to 30% for only 12%')\n",
        h.fig3_peak_energy_gain_pct, h.fig3_peak_at_nodes, h.fig3_time_overhead_at_peak_pct
    );

    println!("=== Pareto frontier: the trade-off presets' knees ===");
    let frontiers = figures::frontier::series(48);
    println!("{}", figures::frontier::knee_table(&frontiers).render());
    for (label, gain, overhead) in figures::frontier::knee_headlines(&frontiers) {
        println!(
            "  {label}: knee buys {gain:.1}% energy for {overhead:.1}% more time"
        );
    }
    figures::persist(&figures::frontier::table(&frontiers), &out_dir, "frontier")?;
    figures::persist(
        &figures::frontier::knee_table(&frontiers),
        &out_dir,
        "frontier_knees",
    )?;
    println!();

    println!("=== Ablation: omega sweep (blocking -> fully overlapped) ===");
    let omega_rows = ablations::omega_sweep(11);
    println!("{}", ablations::omega_table(&omega_rows).render());
    figures::persist(&ablations::omega_table(&omega_rows), &out_dir, "ablation_omega")?;

    println!("=== Ablation: first-order accuracy (closed form vs numeric) ===");
    let acc = ablations::first_order_accuracy(8);
    println!("{}", ablations::accuracy_table(&acc).render());
    figures::persist(&ablations::accuracy_table(&acc), &out_dir, "ablation_accuracy")?;

    println!("=== Ablation: first-order periods priced by the exact renewal model ===");
    let ex = ablations::first_order_vs_exact(&[40.0, 60.0, 120.0, 300.0, 1000.0]);
    println!("{}", ablations::exact_table(&ex).render());
    figures::persist(&ablations::exact_table(&ex), &out_dir, "ablation_exact")?;

    println!("=== Ablation: gamma (P_Down) sweep ===");
    let mut t = Table::new(&["gamma", "energy_gain_pct", "time_overhead_pct"]);
    for (gamma, gain, overhead) in ablations::gamma_sweep(5) {
        t.row(&[fnum(gamma, 2), fnum(gain, 2), fnum(overhead, 2)]);
    }
    println!("{}", t.render());

    println!("=== Ablation: per-node Weibull platforms (matched MTBF) ===");
    let wb = ablations::weibull_robustness(&[1.0, 0.7], &[1e5, 1e6, 5e6], 5.5, 120);
    let wb_table = ablations::weibull_table(&wb);
    println!("{}", wb_table.render());
    figures::persist(&wb_table, &out_dir, "ablation_weibull")?;

    println!("=== MSK baseline comparison (omega = 0, paper §3.2 side note) ===");
    let mut t = Table::new(&[
        "mu_min",
        "T_AlgoE_min",
        "T_MSK_min",
        "energy_penalty_at_MSK_period_pct",
    ]);
    for mu in [60.0, 120.0, 300.0] {
        let m = ablations::msk_comparison(mu, 5.5);
        t.row(&[
            fnum(mu, 0),
            fnum(m.t_algo_e, 2),
            fnum(m.t_msk, 2),
            fnum(m.penalty_pct, 3),
        ]);
    }
    println!("{}", t.render());

    println!("CSV series written to {}", out_dir.display());
    Ok(())
}
