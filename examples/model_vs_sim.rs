//! V-sim: validate the analytical model against the discrete-event
//! simulator — expected makespan, expected energy, and the location of
//! both optimal periods.
//!
//! ```bash
//! cargo run --release --example model_vs_sim [-- --quick]
//! ```

use ckpt_period::config::presets::fig1_scenario;
use ckpt_period::model::energy::e_final;
use ckpt_period::model::ratios::compare;
use ckpt_period::model::time::t_final;
use ckpt_period::sim::runner::empirical_optimal_period;
use ckpt_period::sim::{monte_carlo, SimConfig};
use ckpt_period::util::stats::rel_err;
use ckpt_period::util::table::{fnum, Table};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 150 } else { 600 };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    println!("=== expected makespan & energy: model vs Monte-Carlo ({reps} reps) ===");
    let mut t = Table::new(&[
        "mu_min",
        "rho",
        "period",
        "makespan_model",
        "makespan_sim",
        "err_pct",
        "energy_model",
        "energy_sim",
        "err_pct",
    ]);
    for mu in [120.0, 300.0] {
        for rho in [2.0, 5.5, 7.0] {
            let s = fig1_scenario(mu, rho);
            let cmp = compare(&s).unwrap();
            for (label, period) in [("AlgoT", cmp.t_time), ("AlgoE", cmp.t_energy)] {
                let mc = monte_carlo(&SimConfig::paper(s, period), reps, 11, threads);
                let tm = t_final(&s, period);
                let em = e_final(&s, period);
                t.row(&[
                    fnum(mu, 0),
                    fnum(rho, 1),
                    format!("{label}={:.1}", period),
                    fnum(tm, 0),
                    fnum(mc.makespan.mean(), 0),
                    fnum(rel_err(tm, mc.makespan.mean()) * 100.0, 2),
                    fnum(em, 0),
                    fnum(mc.energy.mean(), 0),
                    fnum(rel_err(em, mc.energy.mean()) * 100.0, 2),
                ]);
            }
        }
    }
    println!("{}", t.render());

    println!("=== empirical optimal periods vs closed forms (mu=300, rho=5.5) ===");
    let s = fig1_scenario(300.0, 5.5);
    let cmp = compare(&s).unwrap();
    let grid: Vec<f64> = (1..=30).map(|i| 10.0 * i as f64).collect();
    let sweep_reps = if quick { 60 } else { 200 };
    let (t_emp, _) = empirical_optimal_period(
        |t| SimConfig::paper(s, t),
        &grid,
        sweep_reps,
        23,
        threads,
        false,
    );
    let (e_emp, _) = empirical_optimal_period(
        |t| SimConfig::paper(s, t),
        &grid,
        sweep_reps,
        23,
        threads,
        true,
    );
    println!("  time-optimal period:   closed form {:.1} min, empirical grid argmin {t_emp:.1} min", cmp.t_time);
    println!("  energy-optimal period: closed form {:.1} min, empirical grid argmin {e_emp:.1} min", cmp.t_energy);

    println!("\n=== simulated strategy ratios vs model (mu=300, rho=5.5) ===");
    let mc_t = monte_carlo(&SimConfig::paper(s, cmp.t_time), reps, 31, threads);
    let mc_e = monte_carlo(&SimConfig::paper(s, cmp.t_energy), reps, 31, threads);
    println!(
        "  energy ratio AlgoT/AlgoE: model {:.4}, simulated {:.4}",
        cmp.energy_ratio(),
        mc_t.energy.mean() / mc_e.energy.mean()
    );
    println!(
        "  time ratio   AlgoE/AlgoT: model {:.4}, simulated {:.4}",
        cmp.time_ratio(),
        mc_e.makespan.mean() / mc_t.makespan.mean()
    );
}
