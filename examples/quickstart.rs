//! Quickstart: compute the paper's optimal checkpoint periods for an
//! Exascale-like platform and print the time/energy trade-off.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ckpt_period::model::energy::{e_final, t_energy_opt};
use ckpt_period::model::params::{CheckpointParams, PowerParams, Scenario};
use ckpt_period::model::ratios::compare;
use ckpt_period::model::time::{daly, t_final, t_time_opt, young};
use ckpt_period::util::table::{fnum, Table};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // The paper's §4 reference platform: C = R = 10 min, D = 1 min,
    // half-overlapped checkpoints, P_Static = P_Cal = 10 mW/node,
    // P_IO = 100 mW/node (rho = 5.5), MTBF 300 min (~220k nodes of
    // Jaguar-class hardware), and a one-week application.
    let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5)?;
    let power = PowerParams::new(10.0, 10.0, 100.0, 0.0)?;
    let scenario = Scenario::new(ckpt, power, 300.0, 7.0 * 24.0 * 60.0)?;

    println!("platform: mu = {} min, rho = {}", scenario.mu, power.rho());
    println!("application: T_base = {} min\n", scenario.t_base);

    let mut table = Table::new(&["strategy", "period_min", "makespan_min", "energy_mW_min"]);
    for (name, period) in [
        ("AlgoT (Eq. 1)", t_time_opt(&scenario)?),
        ("AlgoE (quadratic root)", t_energy_opt(&scenario)?),
        ("Young", scenario.clamp_period(young(&scenario))?),
        ("Daly", scenario.clamp_period(daly(&scenario))?),
    ] {
        table.row(&[
            name.to_string(),
            fnum(period, 2),
            fnum(t_final(&scenario, period), 0),
            fnum(e_final(&scenario, period), 0),
        ]);
    }
    println!("{}", table.render());

    let cmp = compare(&scenario)?;
    println!(
        "checkpointing at the energy-optimal period saves {:.1}% energy\n\
         at the cost of {:.1}% longer execution — the paper's core trade-off.",
        cmp.energy_gain_pct(),
        cmp.time_overhead_pct()
    );
    Ok(())
}
