//! End-to-end driver (deliverable (b)/DESIGN.md V-e2e): train the
//! transformer LM through PJRT under injected failures, with coordinated
//! checkpointing at AlgoT's and AlgoE's periods, and report measured
//! time/energy plus the loss curve.
//!
//! All three layers compose here: the Pallas matmul kernel (L1) inside
//! the JAX train step (L2), AOT-compiled and driven by the rust
//! coordinator (L3) with real checkpoint I/O, real rollbacks, and the
//! paper's power model applied to measured phase times.
//!
//! ```bash
//! cargo run --release --example fault_tolerant_training -- --steps 300
//! ```

use ckpt_period::coordinator::{Coordinator, CoordinatorConfig, PeriodPolicy, RunReport};
use ckpt_period::runtime::Runtime;
use ckpt_period::util::table::{fnum, Table};

fn flag(args: &[String], name: &str, default: u64) -> u64 {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps = flag(&args, "--steps", 300);
    let mu_s = flag(&args, "--mu", 15) as f64;

    let rt = Runtime::cpu()?;
    println!(
        "PJRT platform: {} ({} device(s)); workload: {} train steps, MTBF {mu_s}s\n",
        rt.platform_name(),
        rt.device_count(),
        steps
    );

    let run = |policy: PeriodPolicy, tag: &str| -> Result<RunReport, Box<dyn std::error::Error>> {
        let ckpt_dir = std::env::temp_dir().join(format!("ckpt_e2e_example_{tag}"));
        let _ = std::fs::remove_dir_all(&ckpt_dir);
        let mut cfg = CoordinatorConfig::new("artifacts", ckpt_dir);
        cfg.policy = policy;
        cfg.steps = steps;
        cfg.mu_s = mu_s;
        cfg.downtime_s = 0.1;
        cfg.data_seed = 7;
        cfg.failure_seed = 4242; // identical failure schedule for both runs
        let report = Coordinator::new(&rt, cfg)?.run()?;
        Ok(report)
    };

    println!("--- run 1/2: AlgoT (time-optimal period) ---");
    let rep_t = run(PeriodPolicy::AlgoT, "algot")?;
    print_report(&rep_t);

    println!("--- run 2/2: AlgoE (energy-optimal period) ---");
    let rep_e = run(PeriodPolicy::AlgoE, "algoe")?;
    print_report(&rep_e);

    println!("=== AlgoT vs AlgoE (measured) ===");
    let time_ratio = rep_e.makespan_s / rep_t.makespan_s;
    let energy_ratio = rep_t.energy.total / rep_e.energy.total;
    let mut t = Table::new(&["quantity", "AlgoT", "AlgoE", "ratio"]);
    t.row(&[
        "period_s".into(),
        fnum(rep_t.period_s, 2),
        fnum(rep_e.period_s, 2),
        fnum(rep_e.period_s / rep_t.period_s, 3),
    ]);
    t.row(&[
        "makespan_s".into(),
        fnum(rep_t.makespan_s, 1),
        fnum(rep_e.makespan_s, 1),
        fnum(time_ratio, 4),
    ]);
    t.row(&[
        "energy".into(),
        fnum(rep_t.energy.total, 0),
        fnum(rep_e.energy.total, 0),
        fnum(energy_ratio, 4),
    ]);
    t.row(&[
        "checkpoints".into(),
        format!("{}", rep_t.n_checkpoints),
        format!("{}", rep_e.n_checkpoints),
        String::new(),
    ]);
    t.row(&[
        "failures".into(),
        format!("{}", rep_t.n_failures),
        format!("{}", rep_e.n_failures),
        String::new(),
    ]);
    println!("{}", t.render());
    println!(
        "measured: AlgoE saves {:.1}% energy for {:.1}% extra time \
         (model predicted {:.1}% / {:.1}%)",
        (1.0 - 1.0 / energy_ratio) * 100.0,
        (time_ratio - 1.0) * 100.0,
        (1.0 - rep_e.predicted_energy / rep_t.predicted_energy) * 100.0,
        (rep_e.predicted_makespan_s / rep_t.predicted_makespan_s - 1.0) * 100.0,
    );

    // Persist both loss curves + reports for EXPERIMENTS.md.
    let out = std::path::Path::new("target/e2e");
    std::fs::create_dir_all(out)?;
    std::fs::write(out.join("algot.json"), rep_t.to_json().to_string_pretty())?;
    std::fs::write(out.join("algoe.json"), rep_e.to_json().to_string_pretty())?;
    println!("reports written to {}", out.display());
    Ok(())
}

fn print_report(r: &RunReport) {
    println!(
        "  period {:.2}s (C={:.3}s R={:.3}s step={:.3}s omega_measured={:.2})",
        r.period_s, r.measured_c_s, r.measured_r_s, r.step_s, r.omega_measured
    );
    println!(
        "  makespan {:.1}s (model {:.1}s) | energy {:.0} (model {:.0}) | \
         {} failures, {} checkpoints, re-exec {:.1}%",
        r.makespan_s,
        r.predicted_makespan_s,
        r.energy.total,
        r.predicted_energy,
        r.n_failures,
        r.n_checkpoints,
        r.re_exec_fraction() * 100.0
    );
    // Compact loss curve: first, every ~20%, last.
    let n = r.losses.len();
    if n > 0 {
        let mut samples = Vec::new();
        for i in [0, n / 5, 2 * n / 5, 3 * n / 5, 4 * n / 5, n - 1] {
            let (s, l) = r.losses[i.min(n - 1)];
            samples.push(format!("step {:>4.0}: {l:.3}", s));
        }
        samples.dedup();
        println!("  loss curve: {}", samples.join("  ->  "));
    }
    println!();
}
