//! Golden-value regression tests for the figure pipeline.
//!
//! The fixtures below are the headline rows of Fig. 1/2/3, computed from
//! the paper's closed forms (independently mirrored and cross-checked
//! outside this crate). Tolerances are 1e-9 **relative** — loose enough
//! for last-ulp evaluation-order drift, tight enough that any real
//! change to the model, the optimal-period solvers, or the grid-engine
//! rewiring fails loudly here.

use ckpt_period::config::presets::{tier_preset, tradeoff_presets};
use ckpt_period::figures::{drift, fig1, fig2, fig3, headline, knee_drift};
use ckpt_period::model::{Backend, RecoveryModel, Scenario};
use ckpt_period::pareto::{Frontier, KneeMethod};

const REL_TOL: f64 = 1e-9;

/// Tolerance for goldens that pass through the exact backend's numeric
/// optimisers: `grid_then_golden` pins the argmin only to ~1e-10·hi
/// absolute (~3e-9 relative on these scenarios), so a 1e-9 gate would
/// flake on last-ulp libm drift. 1e-6 still fails loudly on any real
/// change to the exact model, the optimiser, or the frontier geometry.
const EXACT_REL_TOL: f64 = 1e-6;

fn assert_close(what: &str, got: f64, want: f64) {
    assert_close_tol(what, got, want, REL_TOL);
}

fn assert_close_tol(what: &str, got: f64, want: f64, tol: f64) {
    let denom = want.abs().max(1e-300);
    assert!(
        ((got - want) / denom).abs() < tol,
        "{what}: got {got:.15e}, golden {want:.15e}"
    );
}

#[test]
fn fig1_golden_rows_at_paper_arrows() {
    // One series call covers all four μ curves at the two arrow ρ's.
    let pts = fig1::series(&fig1::RHO_ARROWS);
    let at = |mu: f64, rho: f64| {
        *pts.iter().find(|p| p.mu == mu && p.rho == rho).expect("point exists")
    };

    // (μ=300, ρ=5.5): the paper's reference point.
    let p = at(300.0, 5.5);
    assert_close("t_time(300,5.5)", p.t_time, 53.291650377896914);
    assert_close("t_energy(300,5.5)", p.t_energy, 128.06733820931626);
    assert_close("time_ratio(300,5.5)", p.time_ratio, 1.1032741952337373);
    assert_close("energy_ratio(300,5.5)", p.energy_ratio, 1.2249508155528048);

    // (μ=300, ρ=7): the second arrow.
    let p = at(300.0, 7.0);
    assert_close("t_time(300,7)", p.t_time, 53.291650377896914);
    assert_close("t_energy(300,7)", p.t_energy, 138.3595040792064);
    assert_close("time_ratio(300,7)", p.time_ratio, 1.12629954034473);
    assert_close("energy_ratio(300,7)", p.energy_ratio, 1.2911371925698878);

    // (μ=120, ρ=5.5): the mid-MTBF curve.
    let p = at(120.0, 5.5);
    assert_close("t_time(120,5.5)", p.t_time, 32.2490309931942);
    assert_close("t_energy(120,5.5)", p.t_energy, 64.35029533730273);
    assert_close("time_ratio(120,5.5)", p.time_ratio, 1.1208694800730306);
    assert_close("energy_ratio(120,5.5)", p.energy_ratio, 1.2151768198887833);
}

#[test]
fn fig1_golden_unity_corner() {
    // (μ=30, ρ=1): both strategies nearly coincide — the ratios' floor.
    let pts = fig1::series(&[1.0]);
    let p = *pts.iter().find(|p| p.mu == 30.0).unwrap();
    assert_close("t_time(30,1)", p.t_time, 11.832159566199232);
    assert_close("t_energy(30,1)", p.t_energy, 12.400980358030257);
    assert_close("time_ratio(30,1)", p.time_ratio, 1.0028026209790593);
    assert_close("energy_ratio(30,1)", p.energy_ratio, 1.0029291452638538);
}

#[test]
fn fig2_golden_corner_cell() {
    // The ρ=20 edge of the surface at μ=300: the largest gain plotted.
    let cells = fig2::grid(&[300.0], &[20.0]);
    assert_eq!(cells.len(), 1);
    assert_close("fig2 time_ratio(300,20)", cells[0].time_ratio, 1.239118295415918);
    assert_close("fig2 energy_ratio(300,20)", cells[0].energy_ratio, 1.6550201311848949);
    assert_close(
        "fig2 max gain pct",
        fig2::max_energy_gain_pct(&cells),
        39.57777424229516,
    );
}

#[test]
fn fig3_golden_points() {
    // N = 10⁶ (μ = 120) on the ρ = 5.5 panel.
    let pts = fig3::series(5.5, &[1e6]);
    assert_eq!(pts.len(), 1);
    let p = pts[0];
    assert!(!p.clamped);
    assert_close("fig3 mu(1e6)", p.mu, 120.0);
    assert_close("fig3 time_ratio(1e6,5.5)", p.time_ratio, 1.062437391812873);
    assert_close("fig3 energy_ratio(1e6,5.5)", p.energy_ratio, 1.1650187374996614);

    // N = 10⁷ (μ = 12) on the ρ = 7 panel.
    let pts = fig3::series(7.0, &[1e7]);
    let p = pts[0];
    assert!(!p.clamped);
    assert_close("fig3 time_ratio(1e7,7)", p.time_ratio, 1.143544531726686);
    assert_close("fig3 energy_ratio(1e7,7)", p.energy_ratio, 1.263902759237994);
}

#[test]
fn frontier_golden_hypervolume_and_knee_rows() {
    // One golden row per trade-off preset at the 65-point sampling the
    // frontier figure uses: normalised hypervolume plus the chord knee's
    // (period, makespan, energy). Computed from the paper's closed forms
    // (independently mirrored and cross-checked outside this crate,
    // like the Fig. 1/2/3 fixtures above). This is the regression gate
    // for the Pareto subsystem: any change to the optimal-period
    // solvers, the frontier sampling, the dominance filter, the
    // normalisation, or the knee geometry fails loudly here.
    const N: usize = 65;
    // (label, hypervolume, knee_period, knee_makespan, knee_energy)
    let golden = [
        (
            "fig1-rho5.5",
            0.8468027928654311,
            83.66927355941102,
            13175.590452351636,
            42585.14151061798,
        ),
        (
            "fig1-rho7",
            0.8502537757827617,
            86.52128072997093,
            13225.92632743352,
            47350.02147479943,
        ),
        (
            "alpha-heavy",
            0.8381306720787302,
            73.5608078084129,
            13019.938295432235,
            67636.03672145416,
        ),
        (
            "beta-heavy",
            0.8561030239219451,
            93.3043959320106,
            13355.36685344219,
            43521.35042490259,
        ),
        (
            "gamma-heavy",
            0.846761578077717,
            83.61911034875286,
            13174.728295224146,
            42678.83124771653,
        ),
        (
            "exascale-io-heavy",
            0.8586450677879421,
            28.67042581392691,
            12122.753205453675,
            42306.16662215283,
        ),
    ];
    let presets = tradeoff_presets();
    assert_eq!(presets.len(), golden.len(), "preset set changed; regenerate the goldens");
    for (label, hv, knee_period, knee_time, knee_energy) in golden {
        let (_, s) = presets
            .iter()
            .find(|(l, _)| *l == label)
            .unwrap_or_else(|| panic!("preset {label} disappeared"));
        let f = Frontier::compute(s, N, Backend::FirstOrder).expect(label);
        assert_close(&format!("{label} hypervolume"), f.hypervolume(), hv);
        let k = f.knee(KneeMethod::MaxDistanceToChord).expect(label);
        assert_close(&format!("{label} knee period"), k.point.period, knee_period);
        assert_close(&format!("{label} knee makespan"), k.point.time, knee_time);
        assert_close(&format!("{label} knee energy"), k.point.energy, knee_energy);
    }
}

#[test]
fn tiers_golden_knee_rows() {
    // Golden rows for the multi-level storage figure: the two headline
    // base presets under each tier stack (the flattened PFS baseline
    // and the 2-/3-level drained hierarchies), at the same 65-point
    // sampling as the frontier rows above. Values from the same
    // independently mirrored forms as every other fixture here. The
    // tiered optimal periods pass through `grid_then_golden`, so the
    // whole block sits at the numeric-optimiser tolerance.
    //
    // This is also the acceptance gate for the hierarchy story: after
    // the golden check, the >=2-level knees must strictly dominate the
    // flattened tiers-1 knee of the same base preset — on EVERY base
    // preset, both axes at once.
    const N: usize = 65;
    // (base, tiers, hypervolume, knee_period, knee_makespan, knee_energy)
    let golden = [
        (
            "fig1-rho5.5",
            "tiers-1",
            0.8468027928654311,
            83.66927355941102,
            13175.590452351636,
            42585.14151061798,
        ),
        (
            "fig1-rho5.5",
            "tiers-2",
            0.8002461721041462,
            39.34791050627604,
            11739.97817059445,
            41022.165764539,
        ),
        (
            "fig1-rho5.5",
            "tiers-3",
            0.7789424795972112,
            29.157009556547827,
            10943.377498167474,
            27145.67156039877,
        ),
        (
            "exascale-io-heavy",
            "tiers-1",
            0.8434805974814471,
            46.06166960443084,
            16442.352541244945,
            69751.6816764987,
        ),
        (
            "exascale-io-heavy",
            "tiers-2",
            0.7568119254792574,
            27.56417742098122,
            14274.694490285654,
            66177.35862220114,
        ),
        (
            "exascale-io-heavy",
            "tiers-3",
            0.7755899783911637,
            17.386761664609384,
            11688.301080532268,
            33019.17379428112,
        ),
    ];
    let presets = tradeoff_presets();
    let tiered = |base: &str, tiers: &str| {
        let (_, s) = presets
            .iter()
            .find(|(l, _)| *l == base)
            .unwrap_or_else(|| panic!("preset {base} disappeared"));
        let specs = tier_preset(tiers).unwrap_or_else(|| panic!("tier preset {tiers}"));
        Scenario::with_tier_specs(s.ckpt, s.power, s.mu, s.t_base, &specs)
            .unwrap_or_else(|e| panic!("{base}+{tiers}: {e}"))
    };
    for (base, tiers, hv, knee_period, knee_time, knee_energy) in golden {
        let label = format!("{base}+{tiers}");
        let s = tiered(base, tiers);
        let f = Frontier::compute(&s, N, Backend::FirstOrder).expect(&label);
        assert_close_tol(&format!("{label} hypervolume"), f.hypervolume(), hv, EXACT_REL_TOL);
        let k = f.knee(KneeMethod::MaxDistanceToChord).expect(&label);
        assert_close_tol(&format!("{label} knee period"), k.point.period, knee_period, EXACT_REL_TOL);
        assert_close_tol(&format!("{label} knee makespan"), k.point.time, knee_time, EXACT_REL_TOL);
        assert_close_tol(&format!("{label} knee energy"), k.point.energy, knee_energy, EXACT_REL_TOL);
    }
    // Strict knee dominance of the drained hierarchies over the flat
    // baseline, across every base preset (not just the golden pair).
    for (base, _) in &presets {
        let flat = Frontier::compute(&tiered(base, "tiers-1"), N, Backend::FirstOrder)
            .expect(base)
            .knee(KneeMethod::MaxDistanceToChord)
            .expect(base)
            .point;
        for tiers in ["tiers-2", "tiers-3"] {
            let k = Frontier::compute(&tiered(base, tiers), N, Backend::FirstOrder)
                .expect(base)
                .knee(KneeMethod::MaxDistanceToChord)
                .expect(base)
                .point;
            assert!(
                k.time < flat.time && k.energy < flat.energy,
                "{base}+{tiers} knee ({}, {}) does not dominate tiers-1 ({}, {})",
                k.time,
                k.energy,
                flat.time,
                flat.energy
            );
        }
    }
}

#[test]
fn exact_frontier_golden_hypervolume_and_knee_rows() {
    // The exact-backend counterparts of the rows above: one golden row
    // per trade-off preset under Backend::Exact(Ideal) at the same
    // 65-point sampling — the regression gate for the exact renewal
    // objectives, the memoised numeric optima, and the backend-generic
    // frontier plumbing. Values from the same independently mirrored
    // closed/renewal forms as every other fixture here. Note the exact
    // knees run 6-11% longer than the first-order ones even at the
    // paper's mu = 300 reference point.
    const N: usize = 65;
    // (label, hypervolume, knee_period, knee_makespan, knee_energy)
    let golden = [
        (
            "fig1-rho5.5",
            0.8469065887275516,
            92.10684702052407,
            13028.462894955712,
            41046.16129881349,
        ),
        (
            "fig1-rho7",
            0.8503965943599592,
            95.67146115457088,
            13080.612777286706,
            45399.03206022526,
        ),
        (
            "alpha-heavy",
            0.838165259387657,
            80.915287849,
            12883.676880847172,
            65875.76172017591,
        ),
        (
            "beta-heavy",
            0.8563332522220954,
            104.27802424666791,
            13216.362985644537,
            41299.68467926749,
        ),
        (
            "gamma-heavy",
            0.8468664280302014,
            92.04775997699443,
            13027.620592377396,
            41135.680911641655,
        ),
        (
            "exascale-io-heavy",
            0.8586865790320234,
            30.60256359158587,
            12073.448755249814,
            41281.24041631975,
        ),
    ];
    let backend = Backend::Exact(RecoveryModel::Ideal);
    let presets = tradeoff_presets();
    assert_eq!(presets.len(), golden.len(), "preset set changed; regenerate the goldens");
    for (label, hv, knee_period, knee_time, knee_energy) in golden {
        let (_, s) = presets
            .iter()
            .find(|(l, _)| *l == label)
            .unwrap_or_else(|| panic!("preset {label} disappeared"));
        let f = Frontier::compute(s, N, backend).expect(label);
        let what = |q: &str| format!("{label} exact {q}");
        assert_close_tol(&what("hypervolume"), f.hypervolume(), hv, EXACT_REL_TOL);
        let k = f.knee(KneeMethod::MaxDistanceToChord).expect(label);
        assert_close_tol(&what("knee period"), k.point.period, knee_period, EXACT_REL_TOL);
        assert_close_tol(&what("knee makespan"), k.point.time, knee_time, EXACT_REL_TOL);
        assert_close_tol(&what("knee energy"), k.point.energy, knee_energy, EXACT_REL_TOL);
    }
}

#[test]
fn knee_drift_golden_rows() {
    // The knee-drift figure's golden rows (KNEE_DRIFT_POINTS = 129
    // sampling): first-order knee, exact knee, and the drift between
    // them, per trade-off preset plus the two small-mu stress rows.
    // This pins the acceptance headline: >5% drift everywhere, >20% at
    // mu = 120 and >40% at mu = 60.
    // (label, knee_first_order, knee_exact, drift_pct)
    let golden = [
        ("fig1-rho5.5", 83.66927355941102, 92.10684702052407, 10.084434945071896),
        ("fig1-rho7", 87.18587333701242, 96.46946602590738, 10.648046906647046),
        ("alpha-heavy", 73.93616257564467, 80.47921265421145, 8.849593826123359),
        ("beta-heavy", 93.3043959320106, 103.30071597757939, 10.71366460895684),
        ("gamma-heavy", 83.61911034875286, 92.04775997699443, 10.079812608730165),
        ("exascale-io-heavy", 28.391677774862558, 30.28419348972647, 6.665741031125361),
        ("fig1-rho5.5-mu120", 46.04254301605333, 55.98356156163236, 21.59094153881327),
        ("fig1-rho5.5-mu60", 26.894138670118732, 38.64212304509, 43.682322453494685),
    ];
    let rows = knee_drift::series();
    assert_eq!(rows.len(), golden.len(), "drift preset set changed; regenerate the goldens");
    for (label, knee_first, knee_exact, drift_pct) in golden {
        let r = rows
            .iter()
            .find(|r| r.label == label)
            .unwrap_or_else(|| panic!("drift row {label} disappeared"));
        let what = |q: &str| format!("{label} {q}");
        // The first-order knee is closed-form all the way down; the
        // exact one (and the drift) go through the numeric optimiser.
        assert_close(&what("first-order knee"), r.knee_first_order, knee_first);
        assert_close_tol(&what("exact knee"), r.knee_exact, knee_exact, EXACT_REL_TOL);
        assert_close_tol(&what("drift"), r.drift_pct, drift_pct, EXACT_REL_TOL);
        assert!(r.drift_pct > 5.0, "{label}: drift {} below the 5% headline", r.drift_pct);
    }
}

#[test]
fn drift_golden_rows_and_alpha_monotonicity() {
    // The drift.csv gate. Unlike the closed-form fixtures above these
    // are Monte-Carlo means, so the rows are *banded*, not bit-golden:
    // the bands come from the Python mirror of the drift DES (the same
    // mirror that produced the other fixtures' closed forms), widened
    // for seed variation. Two gates:
    //
    // 1. per-family reference rows (α = 0.2, band = 0.05, unit speed)
    //    land inside the mirror's bands for tracking lag, drift lag,
    //    and waste/energy regret;
    // 2. the μ-noise-cancelled drift lag decreases monotonically as α
    //    grows at fixed band, for every family that drifts C/R (the
    //    EWMA's domain — μ-decay is α-flat by construction and gated
    //    to *zero* drift lag at band 0).
    let rows = drift::series(24);

    // (family, lag band, drift-lag band, waste-regret band,
    //  energy-regret band) — mirror values in comments.
    let golden: [(&str, (f64, f64), (f64, f64), (f64, f64), (f64, f64)); 4] = [
        // lag ~12.6–14.5, dlag ~2.0–2.2, regret −0.9…+0.4, e-regret
        // +10…+26 across mirror seed sets (energy regret carries the
        // largest seed variance: it prices the μ-noise period wobble
        // against the doubled I/O draw).
        ("io-ramp", (8.0, 22.0), (0.7, 5.0), (-2.5, 2.5), (3.0, 40.0)),
        // lag ~23.4–24.9, dlag ~1.8 (band floor), regret +4.4…+5.2,
        // e-regret ~−8.7
        ("mu-decay", (15.0, 34.0), (0.2, 4.8), (1.0, 10.0), (-20.0, -1.0)),
        // lag ~12.1–12.8, dlag ~2.5–2.7, regret ~−0.1, e-regret ~+0.6
        ("step-reconfig", (7.0, 19.0), (0.8, 5.8), (-2.5, 2.5), (-5.0, 6.0)),
        // lag ~17.5, dlag ~9.2, regret +1.3…+2.3, e-regret +12…+20
        ("contention-burst", (11.0, 26.0), (3.5, 15.0), (-1.0, 5.0), (3.0, 36.0)),
    ];
    let (ref_alpha, ref_band) = drift::REFERENCE_KNOBS;
    for (family, lag_b, dlag_b, regret_b, e_regret_b) in golden {
        let r = rows
            .iter()
            .find(|r| {
                r.family == family
                    && r.model == "first-order"
                    && r.speed == 1.0
                    && r.alpha == ref_alpha
                    && r.hysteresis == ref_band
            })
            .unwrap_or_else(|| panic!("drift reference row {family} disappeared"));
        let in_band = |what: &str, v: f64, (lo, hi): (f64, f64)| {
            assert!(
                (lo..=hi).contains(&v),
                "{family} {what}: {v} outside the mirror band [{lo}, {hi}]"
            );
        };
        in_band("tracking lag", r.tracking_lag_pct, lag_b);
        in_band("drift lag", r.drift_lag_pct, dlag_b);
        in_band("waste regret", r.waste_regret_pct, regret_b);
        in_band("energy regret", r.energy_regret_pct, e_regret_b);
    }

    // Monotonicity: at fixed band the drift lag decreases in α for the
    // C/R-drifting families, at both drift speeds. Band 0 is strict
    // (the mirror's adjacent gaps are 1.7–4x); the hysteresis bands
    // floor the tail, so adjacency there allows 5% + 0.02pp of slack
    // with a strict overall decrease.
    for family in ["io-ramp", "step-reconfig", "contention-burst"] {
        for speed in drift::SPEEDS {
            for band in [0.0, 0.05] {
                let prof = drift::lag_by_alpha(&rows, family, speed, band, false);
                assert_eq!(prof.len(), drift::ALPHAS.len(), "{family} x{speed} band={band}");
                for w in prof.windows(2) {
                    let (a0, l0) = w[0];
                    let (a1, l1) = w[1];
                    let slack = if band == 0.0 { 0.0 } else { l0 * 0.05 + 0.02 };
                    assert!(
                        l1 < l0 + slack,
                        "{family} x{speed} band={band}: drift lag rose \
                         {l0} (α={a0}) -> {l1} (α={a1})"
                    );
                }
                let (first, last) = (prof[0].1, prof[prof.len() - 1].1);
                assert!(
                    first > last * 1.25,
                    "{family} x{speed} band={band}: α barely matters ({first} vs {last})"
                );
            }
        }
    }

    // μ-decay is the EWMA's blind spot: zero drift lag at band 0 for
    // every α (the exposure estimator, not the EWMA, tracks μ).
    for (alpha, dlag) in drift::lag_by_alpha(&rows, "mu-decay", 1.0, 0.0, false) {
        assert!(dlag < 1e-9, "mu-decay α={alpha}: drift lag {dlag} != 0 at band 0");
    }
}

#[test]
fn headline_golden_numbers() {
    let h = headline::compute();
    assert_close(
        "energy gain (300, 5.5) %",
        h.energy_gain_mu300_rho55_pct,
        18.3640692096921,
    );
    assert_close(
        "time overhead (300, 5.5) %",
        h.time_overhead_mu300_rho55_pct,
        10.327419523373727,
    );
    assert_close(
        "energy gain (300, 7) %",
        h.energy_gain_mu300_rho7_pct,
        22.548896759019588,
    );
    assert_close(
        "time overhead (300, 7) %",
        h.time_overhead_mu300_rho7_pct,
        12.629954034473002,
    );
}
