//! Telemetry zero-perturbation suite (ISSUE 7): instrumentation is
//! observational only. Counters, span histograms and the decision-trace
//! sink must leave every deterministic surface **bit-identical** —
//! metrics never enter cache keys, memo keys, or seed derivations, and
//! a run with the JSONL trace installed produces the same numbers as
//! one without. Also pins the histogram's merge algebra (associative
//! and commutative, so per-worker recording totals the same at any
//! thread count) and the trace's replay property (the period events
//! account for every update the summary counts).

use ckpt_period::config::presets::{fig1_scenario, tier_presets, tradeoff_presets};
use ckpt_period::coordinator::PeriodPolicy;
use ckpt_period::drift::DriftProcess;
use ckpt_period::model::Backend;
use ckpt_period::pareto::online::knee_period;
use ckpt_period::pareto::KneeMethod;
use ckpt_period::serve::{solve, BatchEngine, Query};
use ckpt_period::sim::adaptive::{adaptive_monte_carlo, AdaptiveSimConfig, AdaptiveSimulator};
use ckpt_period::telemetry::registry::metrics;
use ckpt_period::telemetry::{trace, Histogram};
use ckpt_period::util::json::parse;
use ckpt_period::util::pool::ThreadPool;

/// The drift configuration the zero-perturbation checks run on: a
/// moving C/R/io environment, the knee policy, the realistic failure
/// process.
fn drift_cfg() -> AdaptiveSimConfig {
    let s = fig1_scenario(120.0, 5.5);
    let drift = DriftProcess::parse("ramp:0:5000:c=2,r=2,io=2").expect("spec parses");
    let policy = PeriodPolicy::Knee {
        method: KneeMethod::MaxDistanceToChord,
        backend: Backend::FirstOrder,
    };
    AdaptiveSimConfig::paper_drifting(s, policy, drift).expect("drift stays in domain")
}

/// Hammering every metric surface — counters, gauges, histograms —
/// must not move any computed result: solve keys, memoised policy
/// periods and simulated sample paths are all pure functions of their
/// inputs, never of the registry.
#[test]
fn counters_never_leak_into_keys_or_results() {
    let s = fig1_scenario(300.0, 5.5);
    let q = Query::new(s, PeriodPolicy::AlgoT, Backend::FirstOrder);
    let key_before = q.solve_key();
    let knee_before = knee_period(&s, KneeMethod::MaxDistanceToChord, Backend::FirstOrder)
        .unwrap()
        .to_bits();
    let run_before = AdaptiveSimulator::new(AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT))
        .run(41)
        .makespan
        .to_bits();

    for _ in 0..10_000 {
        metrics::SERVE_QUERIES_TOTAL.inc();
        metrics::POOL_STEALS_TOTAL.inc();
        metrics::TIER_ENVELOPE_EVALUATED_TOTAL.inc();
        metrics::POOL_QUEUE_DEPTH.set(17);
        metrics::SERVE_SOLVE_NS.observe(12_345);
        metrics::GRID_CELL_NS.observe(777);
    }

    assert_eq!(q.solve_key(), key_before, "solve key moved under counter traffic");
    assert_eq!(
        knee_period(&s, KneeMethod::MaxDistanceToChord, Backend::FirstOrder)
            .unwrap()
            .to_bits(),
        knee_before,
        "memoised knee period moved under counter traffic"
    );
    assert_eq!(
        AdaptiveSimulator::new(AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT))
            .run(41)
            .makespan
            .to_bits(),
        run_before,
        "sample path moved under counter traffic"
    );
}

/// The serve-equivalence vector: a shuffled 1k-query batch answers
/// bit-identically to sequential [`solve`] calls at 1 and 8 local pool
/// threads, with the stage instrumentation live the whole time — and
/// the stage histograms actually record.
#[test]
fn instrumented_batches_stay_bit_identical_across_thread_counts() {
    // 250 distinct scenarios (each a fresh online-memo quantum), each
    // queried 4x, deterministically scrambled.
    let unique: Vec<Query> = (0..250)
        .map(|i| {
            let s = fig1_scenario(120.0 * 1.01f64.powi(i), 5.5);
            let policy = if i % 2 == 0 {
                PeriodPolicy::Knee {
                    method: KneeMethod::MaxDistanceToChord,
                    backend: Backend::FirstOrder,
                }
            } else {
                PeriodPolicy::AlgoT
            };
            Query::new(s, policy, Backend::FirstOrder)
        })
        .collect();
    let n = unique.len() * 4;
    let batch: Vec<Query> =
        (0..n).map(|i| unique[(i * 7919) % unique.len()].clone()).collect();

    let solve_before = metrics::SERVE_SOLVE_NS.snapshot();
    let sequential: Vec<_> = batch.iter().map(|q| solve(q).unwrap()).collect();
    let engine = BatchEngine::without_cache();
    for workers in [0usize, 7] {
        let pool = ThreadPool::new(workers);
        let answers = engine.answer_all_on(&pool, &batch);
        assert_eq!(answers.len(), batch.len());
        for (i, (got, want)) in answers.iter().zip(&sequential).enumerate() {
            let got = got.as_ref().unwrap();
            assert_eq!(got.period.to_bits(), want.period.to_bits(), "slot {i}/{workers}w");
            assert_eq!(got.t_final.to_bits(), want.t_final.to_bits(), "slot {i}/{workers}w");
            assert_eq!(got.e_final.to_bits(), want.e_final.to_bits(), "slot {i}/{workers}w");
        }
    }
    // The dedup/solve/scatter spans recorded both batches (span timing
    // can be disabled via CKPT_TELEMETRY, in which case counts stand
    // still — the determinism half above is what must always hold).
    if ckpt_period::telemetry::timing_enabled() {
        let solve_after = metrics::SERVE_SOLVE_NS.snapshot();
        assert!(
            solve_after.count() >= solve_before.count() + 2,
            "solve stage histogram did not record"
        );
    }
}

/// Merging per-worker histograms is associative and commutative: any
/// grouping of the same observations snapshots to the same buckets and
/// sum, so per-worker recording is thread-count-invariant by algebra.
#[test]
fn histogram_merge_is_order_and_grouping_invariant() {
    let observations: Vec<u64> = (0..4096).map(|i| (i * i * 31) % 1_000_000 + 1).collect();

    // One histogram, recorded from 8 OS threads concurrently.
    let concurrent = Histogram::new();
    std::thread::scope(|scope| {
        for chunk in observations.chunks(512) {
            scope.spawn(|| {
                for &v in chunk {
                    concurrent.observe(v);
                }
            });
        }
    });

    // Eight per-thread histograms, merged serially.
    let mut merged = Histogram::new().snapshot();
    for chunk in observations.chunks(512) {
        let h = Histogram::new();
        for &v in chunk {
            h.observe(v);
        }
        merged = merged.merge(&h.snapshot());
    }

    // And the same merged pairwise in reverse order.
    let mut reversed = Histogram::new().snapshot();
    for chunk in observations.chunks(512).rev() {
        let h = Histogram::new();
        for &v in chunk {
            h.observe(v);
        }
        reversed = reversed.merge(&h.snapshot());
    }

    let direct = concurrent.snapshot();
    assert_eq!(direct.buckets, merged.buckets);
    assert_eq!(direct.sum, merged.sum);
    assert_eq!(merged.buckets, reversed.buckets);
    assert_eq!(merged.sum, reversed.sum);
    assert_eq!(direct.count(), observations.len() as u64);
}

/// The tentpole contract, end to end: an adaptive drift Monte-Carlo
/// with the JSONL trace installed is bit-identical to one without —
/// and the trace replays every period change the summary counted,
/// for both the controller and its oracle twin.
#[test]
fn trace_is_zero_perturbation_and_replays_period_updates() {
    let cfg = drift_cfg();
    let mut oracle_cfg = cfg.clone();
    oracle_cfg.oracle = true;
    // A seed range no other test uses, so concurrent tests in this
    // binary can't bleed events into the filter below.
    const BASE_SEED: u64 = 990_001;
    const REPS: usize = 12;

    let untraced = adaptive_monte_carlo(&cfg, REPS, BASE_SEED, 1);
    let untraced_oracle = adaptive_monte_carlo(&oracle_cfg, REPS, BASE_SEED, 1);
    // Per-path update counts for the replay check — gathered BEFORE the
    // sink goes live, so these runs don't emit duplicate events.
    let sim = AdaptiveSimulator::new(cfg.clone());
    let expected_updates: u64 =
        (0..REPS).map(|i| sim.run(BASE_SEED + i as u64).n_period_updates).sum();

    let dir = std::env::temp_dir().join(format!("ckpt_telemetry_{}", std::process::id()));
    let path = dir.join("trace.jsonl");
    trace::install(&path).expect("trace sink installs");
    let traced = adaptive_monte_carlo(&cfg, REPS, BASE_SEED, 1);
    let traced_oracle = adaptive_monte_carlo(&oracle_cfg, REPS, BASE_SEED, 1);
    assert!(trace::finish(), "sink was installed");

    for (name, a, b) in [
        ("adaptive", &untraced, &traced),
        ("oracle", &untraced_oracle, &traced_oracle),
    ] {
        assert_eq!(a.makespan.mean().to_bits(), b.makespan.mean().to_bits(), "{name}");
        assert_eq!(a.energy.mean().to_bits(), b.energy.mean().to_bits(), "{name}");
        assert_eq!(
            a.final_period.mean().to_bits(),
            b.final_period.mean().to_bits(),
            "{name}"
        );
        assert_eq!(
            a.period_updates.mean().to_bits(),
            b.period_updates.mean().to_bits(),
            "{name}"
        );
    }

    // Replay: every counted update appears as a changed period event
    // with this run's seeds (other tests may interleave events from
    // different seed ranges; the envelope's seed field filters them).
    let text = std::fs::read_to_string(&path).expect("trace written");
    let in_range = |seed: f64| {
        (BASE_SEED..BASE_SEED + REPS as u64).contains(&(seed as u64))
    };
    let mut changed = 0u64;
    let mut kinds_seen = std::collections::BTreeSet::new();
    let mut oracle_seen = false;
    for line in text.lines() {
        let doc = parse(line).unwrap_or_else(|e| panic!("bad trace line {line}: {e}"));
        let kind = doc.req_str("kind").expect("kind").to_string();
        let seed = doc.req_f64("seed").expect("seed");
        doc.req_f64("t").expect("t");
        assert!(
            ["observe", "period", "failure", "recovery"].contains(&kind.as_str()),
            "unknown kind {kind}"
        );
        if !in_range(seed) {
            continue;
        }
        kinds_seen.insert(kind.clone());
        let oracle = doc.get("oracle").and_then(|j| j.as_bool()) == Some(true);
        oracle_seen |= oracle;
        if kind == "period"
            && !oracle
            && doc.get("changed").and_then(|j| j.as_bool()) == Some(true)
        {
            changed += 1;
        }
    }
    assert_eq!(
        changed, expected_updates,
        "trace must replay every counted period update"
    );
    assert!(kinds_seen.contains("observe"), "kinds: {kinds_seen:?}");
    assert!(kinds_seen.contains("period"), "kinds: {kinds_seen:?}");
    assert!(oracle_seen, "oracle twin decisions must be traced");
    let _ = std::fs::remove_dir_all(dir);
}

/// Every preset stays bit-identical between a registry at process-start
/// state and one full of traffic — the golden-figure guard, cheap form:
/// the figure stack's inputs are policy periods and sim cells, both
/// pinned above, so here we pin the frontier path the figures draw.
/// ISSUE 9 extension of the zero-perturbation contract to the hot-path
/// overhaul: pool-parallel frontier sampling (1 vs 8 worker pools) must
/// be byte-identical to the serial reference loop, and the bound-pruned
/// tier-envelope scans must match the exhaustive scans — minimum *and*
/// argmin — across every trade-off preset × objective backend × storage
/// hierarchy crossing.
#[test]
fn parallel_frontier_and_pruned_tier_scans_match_their_references() {
    use ckpt_period::model::tiers::{
        e_final_tiered_reference, min_energy_cadence, min_time_cadence, t_final_tiered_reference,
    };
    use ckpt_period::model::{RecoveryModel, Scenario};
    use ckpt_period::pareto::Frontier;

    let backends = [Backend::FirstOrder, Backend::Exact(RecoveryModel::Ideal)];
    for (pname, base) in tradeoff_presets() {
        for (tname, specs) in tier_presets() {
            // Re-dress the preset's parameters in each storage hierarchy
            // (tiers-1 canonicalises back to the scalar model); skip
            // crossings that leave the model's constructor domain.
            let Ok(s) =
                Scenario::with_tier_specs(base.ckpt, base.power, base.mu, base.t_base, &specs)
            else {
                continue;
            };
            for backend in backends {
                // No feasible period under this crossing: nothing to
                // sample (both paths fail the same way).
                let Ok(reference) = Frontier::compute_reference(&s, 17, backend) else {
                    assert!(
                        Frontier::compute(&s, 17, backend).is_err(),
                        "{pname}/{tname}: pooled path disagrees on feasibility"
                    );
                    continue;
                };
                for workers in [0usize, 7] {
                    let pool = ThreadPool::new(workers);
                    let pooled = Frontier::compute_on(&pool, &s, 17, backend).unwrap();
                    assert_eq!(
                        pooled,
                        reference,
                        "{pname}/{tname}: {workers} workers under {}",
                        backend.name()
                    );
                }
            }
            // Pruned envelope scans vs the exhaustive references, at
            // periods inside, near, and outside the analytic domain.
            if let Some(&h) = s.hierarchy() {
                for t in [s.a() * 0.5, 20.0, 45.0, 90.0] {
                    let (tv, tk, _) = min_time_cadence(&s, &h, t);
                    let (rtv, rtk) = t_final_tiered_reference(&s, &h, t);
                    assert_eq!(tv.to_bits(), rtv.to_bits(), "{pname}/{tname} time min, t={t}");
                    assert_eq!(tk, rtk, "{pname}/{tname} time argmin, t={t}");
                    let (ev, ek, _) = min_energy_cadence(&s, &h, t);
                    let (rev, rek) = e_final_tiered_reference(&s, &h, t);
                    assert_eq!(ev.to_bits(), rev.to_bits(), "{pname}/{tname} energy min, t={t}");
                    assert_eq!(ek, rek, "{pname}/{tname} energy argmin, t={t}");
                }
            }
        }
    }
}

#[test]
fn frontier_solves_are_unmoved_by_span_instrumentation() {
    use ckpt_period::pareto::Frontier;
    let mut before = Vec::new();
    for (_, s) in tradeoff_presets() {
        let f = Frontier::compute(&s, 65, Backend::FirstOrder).unwrap();
        before.push((f.t_time_opt.to_bits(), f.t_energy_opt.to_bits()));
    }
    // Saturate the frontier histogram between passes.
    for _ in 0..50_000 {
        metrics::FRONTIER_SOLVE_NS.observe(1_000_000);
    }
    for (i, (_, s)) in tradeoff_presets().into_iter().enumerate() {
        let f = Frontier::compute(&s, 65, Backend::FirstOrder).unwrap();
        assert_eq!(f.t_time_opt.to_bits(), before[i].0, "preset {i}");
        assert_eq!(f.t_energy_opt.to_bits(), before[i].1, "preset {i}");
    }
}
