//! ISSUE 8 acceptance gate: the tiered storage hierarchy is a strict
//! superset of the scalar model. A degenerate single-tier hierarchy
//! must reproduce the scalar code path **bit for bit** — direct model
//! calls, grid cells, frontier geometry, serve answers at 1 and 8 pool
//! participants, and simulated sample paths — on every trade-off
//! preset. Multi-level hierarchies must keep the frontier invariants
//! (pinned endpoints, strict dominance ordering, interior knee,
//! `T_Energy_opt >= T_Time_opt`) and stay byte-identical across
//! thread counts when the drain-queue simulator fans out.

use ckpt_period::config::presets::{tier_preset, tradeoff_presets};
use ckpt_period::coordinator::PeriodPolicy;
use ckpt_period::model::{e_final, t_energy_opt, t_final, t_time_opt, Backend, Scenario};
use ckpt_period::pareto::{Frontier, KneeMethod};
use ckpt_period::serve::{solve, BatchEngine, Query};
use ckpt_period::sim::{monte_carlo, SimConfig, Simulator};
use ckpt_period::storage::TierSpec;
use ckpt_period::sweep::{CellOutput, GridSpec};
use ckpt_period::util::pool::ThreadPool;

/// The scenario re-expressed as a one-level hierarchy: same `(C, R,
/// P_IO)` triple, but routed through the tier-construction path.
fn single_tier_twin(s: &Scenario) -> Scenario {
    let one = [TierSpec::new(s.ckpt.c, s.ckpt.r, s.power.p_io)];
    Scenario::with_tier_specs(s.ckpt, s.power, s.mu, s.t_base, &one)
        .expect("single tier stays in domain")
}

/// A tiered variant of a base preset under a named tier stack.
fn tiered(s: &Scenario, stack: &str) -> Scenario {
    let specs = tier_preset(stack).expect("tier preset exists");
    Scenario::with_tier_specs(s.ckpt, s.power, s.mu, s.t_base, &specs)
        .expect("tier preset stays in domain")
}

/// Interior sample periods of a scenario's analytic domain.
fn sample_periods(s: &Scenario) -> Vec<f64> {
    let (lo, hi) = s.domain();
    [0.1, 0.3, 0.5, 0.7, 0.9]
        .iter()
        .map(|f| (lo + (hi - lo) * f).max(s.min_period()))
        .collect()
}

#[test]
fn single_tier_is_bit_identical_to_the_scalar_model() {
    for (label, s) in tradeoff_presets() {
        let twin = single_tier_twin(&s);
        assert!(twin.hierarchy().is_none(), "{label}: 1 level must canonicalise to Scalar");
        assert_eq!(twin.key_words(), s.key_words(), "{label}: solve keys diverged");

        // Optimal periods and both objectives, bit for bit.
        let (tt, tt2) = (t_time_opt(&s).unwrap(), t_time_opt(&twin).unwrap());
        let (te, te2) = (t_energy_opt(&s).unwrap(), t_energy_opt(&twin).unwrap());
        assert_eq!(tt.to_bits(), tt2.to_bits(), "{label}: t_time_opt");
        assert_eq!(te.to_bits(), te2.to_bits(), "{label}: t_energy_opt");
        for t in sample_periods(&s) {
            assert_eq!(
                t_final(&s, t).to_bits(),
                t_final(&twin, t).to_bits(),
                "{label}: t_final({t})"
            );
            assert_eq!(
                e_final(&s, t).to_bits(),
                e_final(&twin, t).to_bits(),
                "{label}: e_final({t})"
            );
        }

        // Frontier samples, point for point.
        let fa = Frontier::compute(&s, 33, Backend::FirstOrder).unwrap();
        let fb = Frontier::compute(&twin, 33, Backend::FirstOrder).unwrap();
        assert_eq!(fa.len(), fb.len(), "{label}: frontier length");
        for (p, q) in fa.points().iter().zip(fb.points()) {
            assert_eq!(p.period.to_bits(), q.period.to_bits(), "{label}: frontier period");
            assert_eq!(p.time.to_bits(), q.time.to_bits(), "{label}: frontier time");
            assert_eq!(p.energy.to_bits(), q.energy.to_bits(), "{label}: frontier energy");
        }

        // Simulated sample paths share every field of every replicate.
        let t = t_time_opt(&s).unwrap();
        let run_a = Simulator::new(SimConfig::paper(s, t)).run(7);
        let run_b = Simulator::new(SimConfig::paper(twin, t)).run(7);
        assert_eq!(run_a.makespan.to_bits(), run_b.makespan.to_bits(), "{label}: makespan");
        assert_eq!(run_a.energy.to_bits(), run_b.energy.to_bits(), "{label}: energy");
        assert_eq!(run_a.n_failures, run_b.n_failures, "{label}: failures");
        assert_eq!(run_a.n_checkpoints, run_b.n_checkpoints, "{label}: checkpoints");
        assert_eq!(run_a.work_lost.to_bits(), run_b.work_lost.to_bits(), "{label}: work lost");
    }
}

#[test]
fn single_tier_grid_cells_match_the_scalar_cells() {
    // The same equivalence through the grid engine: model cells over a
    // period sweep plus a frontier cell, scalar vs single-tier twin,
    // with the memo cache both off and on (the shared key means the
    // twin's cached cells must serve the scalar spec and vice versa).
    for (label, s) in tradeoff_presets() {
        let twin = single_tier_twin(&s);
        let periods = sample_periods(&s);
        for use_cache in [false, true] {
            let mut build = |sc: Scenario| {
                let mut spec = GridSpec::model_sweep(sc, &periods, 42);
                spec.push_frontier(sc, 17);
                if use_cache {
                    spec
                } else {
                    spec.without_cache()
                }
            };
            let ra = build(s).evaluate();
            let rb = build(twin).evaluate();
            assert_eq!(ra.len(), rb.len(), "{label}: cell count");
            for (a, b) in ra.iter().zip(&rb) {
                match (&a.output, &b.output) {
                    (
                        CellOutput::Model { t_final: t1, e_final: e1 },
                        CellOutput::Model { t_final: t2, e_final: e2 },
                    ) => {
                        assert_eq!(t1.to_bits(), t2.to_bits(), "{label}: cell t_final");
                        assert_eq!(e1.to_bits(), e2.to_bits(), "{label}: cell e_final");
                    }
                    (CellOutput::Frontier(Ok(f1)), CellOutput::Frontier(Ok(f2))) => {
                        assert_eq!(f1.hypervolume.to_bits(), f2.hypervolume.to_bits(), "{label}");
                        assert_eq!(f1.points.len(), f2.points.len(), "{label}");
                        for (p, q) in f1.points.iter().zip(&f2.points) {
                            assert_eq!(p.time.to_bits(), q.time.to_bits(), "{label}");
                            assert_eq!(p.energy.to_bits(), q.energy.to_bits(), "{label}");
                        }
                    }
                    (a, b) => panic!("{label}: cell outputs diverged: {a:?} vs {b:?}"),
                }
            }
        }
    }
}

#[test]
fn tiered_serve_answers_are_thread_count_invariant() {
    // Tiered scenarios through the batch engine: 1 vs 8 pool
    // participants, cache off and on, must reproduce the sequential
    // solve bits — the tier-plan memo must not introduce any
    // scheduling-order sensitivity.
    let policies = ["algo-t", "algo-e", "knee", "eps-energy:5"];
    let mut queries = Vec::new();
    for (_, s) in tradeoff_presets() {
        for stack in ["tiers-2", "tiers-3"] {
            let ts = tiered(&s, stack);
            for raw in policies {
                queries.push(Query::new(ts, PeriodPolicy::parse(raw).unwrap(), Backend::FirstOrder));
            }
        }
    }
    let reference: Vec<_> = queries.iter().map(|q| solve(q).expect("in domain")).collect();
    let serial = ThreadPool::new(0);
    let wide = ThreadPool::new(7);
    for (what, answers) in [
        ("1-thread uncached", BatchEngine::without_cache().answer_all_on(&serial, &queries)),
        ("8-thread uncached", BatchEngine::without_cache().answer_all_on(&wide, &queries)),
        ("1-thread cached", BatchEngine::new().answer_all_on(&serial, &queries)),
        ("8-thread cached", BatchEngine::new().answer_all_on(&wide, &queries)),
    ] {
        for (i, (got, want)) in answers.iter().zip(&reference).enumerate() {
            let got = got.as_ref().expect("tiered queries are solvable");
            assert_eq!(got.period.to_bits(), want.period.to_bits(), "{what} slot {i}: period");
            assert_eq!(got.t_final.to_bits(), want.t_final.to_bits(), "{what} slot {i}: t_final");
            assert_eq!(got.e_final.to_bits(), want.e_final.to_bits(), "{what} slot {i}: e_final");
        }
    }
}

#[test]
fn multi_level_frontier_keeps_the_pareto_invariants() {
    for (label, s) in tradeoff_presets() {
        for stack in ["tiers-2", "tiers-3"] {
            let ts = tiered(&s, stack);
            let what = format!("{label}+{stack}");
            let tt = t_time_opt(&ts).unwrap();
            let te = t_energy_opt(&ts).unwrap();
            assert!(te >= tt * (1.0 - 1e-9), "{what}: T_E={te} < T_T={tt}");

            let f = Frontier::compute(&ts, 33, Backend::FirstOrder).expect(&what);
            let pts = f.points();
            assert!(pts.len() >= 3, "{what}: frontier collapsed to {} points", pts.len());
            // Endpoints pinned to the per-objective optima.
            let (lo, hi) = if tt <= te { (tt, te) } else { (te, tt) };
            assert_eq!(pts.first().unwrap().period.to_bits(), lo.to_bits(), "{what}: left end");
            assert_eq!(pts.last().unwrap().period.to_bits(), hi.to_bits(), "{what}: right end");
            // Strict dominance ordering: time ascending, energy descending.
            for w in pts.windows(2) {
                assert!(w[0].time < w[1].time, "{what}: time not strictly ascending");
                assert!(w[0].energy > w[1].energy, "{what}: energy not strictly descending");
            }
            let k = f.knee(KneeMethod::MaxDistanceToChord).expect(&what);
            assert!(k.index > 0 && k.index < pts.len() - 1, "{what}: knee not interior");
        }
    }
}

#[test]
fn drain_queue_simulation_is_thread_count_deterministic() {
    // The drain-queue DES fans replicates out on the pool; estimates
    // must be byte-identical at every thread count, and a re-run of the
    // same seed must reproduce the sample path exactly.
    for stack in ["tiers-2", "tiers-3"] {
        let (_, base) = &tradeoff_presets()[0];
        let ts = tiered(base, stack);
        assert!(ts.hierarchy().is_some(), "{stack} must stay tiered");
        let period = t_time_opt(&ts).unwrap();
        let cfg = SimConfig::paper(ts, period);

        let sim = Simulator::new(cfg.clone());
        let (a, b) = (sim.run(11), sim.run(11));
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{stack}: replay makespan");
        assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "{stack}: replay energy");
        assert_eq!(a.n_failures, b.n_failures, "{stack}: replay failures");

        let m1 = monte_carlo(&cfg, 48, 2024, 1);
        let m8 = monte_carlo(&cfg, 48, 2024, 8);
        assert_eq!(
            m1.makespan.mean().to_bits(),
            m8.makespan.mean().to_bits(),
            "{stack}: makespan mean differs across thread counts"
        );
        assert_eq!(
            m1.energy.mean().to_bits(),
            m8.energy.mean().to_bits(),
            "{stack}: energy mean differs across thread counts"
        );
        assert_eq!(
            m1.work_lost.mean().to_bits(),
            m8.work_lost.mean().to_bits(),
            "{stack}: work-lost mean differs across thread counts"
        );
        assert!(m1.failures.mean() > 0.0, "{stack}: no failures simulated — test is vacuous");
        assert!(m1.checkpoints.mean() > 1.0, "{stack}: no checkpoints simulated");
    }
}
