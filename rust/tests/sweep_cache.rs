//! `sweep::cache` eviction and counter behaviour under concurrent hits
//! from the pool — the paths the unit tests only exercise
//! single-threaded.
//!
//! The cache is process-global, so these tests serialise on a local
//! mutex and restore the default capacity before returning. They live
//! in their own integration binary so the capacity games cannot perturb
//! the unit tests' hit-count assertions.

use std::sync::Mutex;

use ckpt_period::config::presets::fig1_scenario;
use ckpt_period::model::{e_final, t_final};
use ckpt_period::sweep::{cache, CellOutput, GridSpec};

static SERIAL: Mutex<()> = Mutex::new(());

/// Run `f` with the cache cleared and capacity `cap`, restoring the
/// default capacity afterwards (even on panic the next test's guard
/// re-clears).
fn with_capacity<T>(cap: usize, f: impl FnOnce() -> T) -> T {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    cache::clear();
    cache::set_capacity(cap);
    let out = f();
    cache::set_capacity(cache::default_capacity());
    cache::clear();
    out
}

fn periods(offset: f64, n: usize) -> Vec<f64> {
    // Distinct period bit patterns per caller => distinct cache keys.
    (0..n).map(|i| 30.0 + i as f64 * 0.5 + offset).collect()
}

#[test]
fn concurrent_fills_respect_capacity_and_stay_correct() {
    let s = fig1_scenario(300.0, 5.5);
    with_capacity(64, || {
        std::thread::scope(|scope| {
            let mut joins = Vec::new();
            for t in 0..4u32 {
                joins.push(scope.spawn(move || {
                    // 4 × 100 distinct model cells against capacity 64:
                    // eviction churns while the pool evaluates.
                    let ps = periods(t as f64 * 1e-3, 100);
                    let results = GridSpec::model_sweep(s, &ps, 1).evaluate();
                    for (&p, r) in ps.iter().zip(&results) {
                        match r.output {
                            CellOutput::Model { t_final: tf, e_final: ef } => {
                                assert_eq!(tf.to_bits(), t_final(&s, p).to_bits());
                                assert_eq!(ef.to_bits(), e_final(&s, p).to_bits());
                            }
                            ref other => panic!("unexpected output {other:?}"),
                        }
                    }
                }));
            }
            for j in joins {
                j.join().unwrap();
            }
        });
        assert!(
            cache::len() <= 64,
            "eviction failed to bound the cache: {} entries",
            cache::len()
        );
        assert!(cache::len() > 0, "everything was evicted");
    });
}

#[test]
fn counters_account_for_every_concurrent_lookup() {
    let s = fig1_scenario(120.0, 7.0);
    with_capacity(4096, || {
        let ps = periods(0.0, 50);
        let spec = GridSpec::model_sweep(s, &ps, 1);

        cache::reset_stats();
        let cold = spec.evaluate();
        let (h_cold, m_cold) = cache::stats();
        // A cold fill of 50 distinct cells: one miss each, no hit.
        assert_eq!(m_cold, 50, "cold misses {m_cold}");
        assert_eq!(h_cold, 0, "cold hits {h_cold}");

        cache::reset_stats();
        std::thread::scope(|scope| {
            let spec = &spec;
            let cold = &cold;
            let mut joins = Vec::new();
            for _ in 0..4 {
                joins.push(scope.spawn(move || {
                    let warm = spec.evaluate();
                    assert_eq!(&warm, cold, "cache hit changed a result");
                }));
            }
            for j in joins {
                j.join().unwrap();
            }
        });
        let (h_warm, m_warm) = cache::stats();
        // 4 concurrent warm evaluations of the same 50 cells: every
        // lookup hits; nothing recomputes.
        assert_eq!(h_warm, 200, "warm hits {h_warm}");
        assert_eq!(m_warm, 0, "warm misses {m_warm}");
    });
}

#[test]
fn evicted_cells_recompute_to_identical_outputs() {
    let s = fig1_scenario(300.0, 2.0);
    with_capacity(32, || {
        let early = periods(0.0, 20);
        let spec = GridSpec::model_sweep(s, &early, 1);
        let first = spec.evaluate();

        // Push enough younger cells through to evict the early ones
        // (capacity 32, FIFO). Disjoint period range: all inserts fresh.
        let filler = periods(100.0, 200);
        let _ = GridSpec::model_sweep(s, &filler, 1).evaluate();
        assert!(cache::len() <= 32);

        cache::reset_stats();
        let second = spec.evaluate();
        let (_, m) = cache::stats();
        assert!(m >= 1, "expected at least one recomputation after eviction");
        // Evaluation is pure: recomputed outputs are bit-identical.
        assert_eq!(first, second);
    });
}

#[test]
fn shrinking_capacity_evicts_immediately() {
    let s = fig1_scenario(300.0, 5.5);
    with_capacity(4096, || {
        let ps = periods(3.0, 100);
        let _ = GridSpec::model_sweep(s, &ps, 1).evaluate();
        assert!(cache::len() >= 100);
        cache::set_capacity(10);
        assert!(cache::len() <= 10, "shrink left {} entries", cache::len());
    });
}
