//! Acceptance tests of the Pareto-frontier subsystem (ISSUE 2):
//!
//! (a) frontier endpoints coincide with `T_Time_opt` / `T_Energy_opt`
//!     to 1e-6 relative;
//! (b) no returned point is dominated;
//! (c) ε-constraint solutions lie on the frontier;
//! (d) the simulated frontier agrees with the analytic one within the
//!     (truncation-widened) 95% CIs for every trade-off preset;
//! (e) frontier results are byte-identical across thread counts.

use ckpt_period::config::presets::tradeoff_presets;
use ckpt_period::model::energy::{e_final, t_energy_opt};
use ckpt_period::model::time::{t_final, t_time_opt};
use ckpt_period::model::{Backend, RecoveryModel};
use ckpt_period::pareto::{
    family_frontiers, min_energy_with_time_overhead, min_time_with_energy_overhead, validate,
    Frontier, FrontierSummary, KneeMethod,
};
use ckpt_period::sim::{monte_carlo, SimConfig};
use ckpt_period::util::stats::rel_err;

const POINTS: usize = 33;
const FO: Backend = Backend::FirstOrder;
const EXACT: Backend = Backend::Exact(RecoveryModel::Ideal);

#[test]
fn a_endpoints_coincide_with_the_optimal_periods() {
    for (label, s) in tradeoff_presets() {
        let f = Frontier::compute(&s, POINTS, FO).expect(label);
        let tt = t_time_opt(&s).unwrap();
        let te = t_energy_opt(&s).unwrap();
        let lo = f.time_opt_point();
        let hi = f.energy_opt_point();
        assert!(
            rel_err(lo.period, tt) < 1e-6,
            "{label}: time endpoint {} vs T_Time_opt {tt}",
            lo.period
        );
        assert!(
            rel_err(hi.period, te) < 1e-6,
            "{label}: energy endpoint {} vs T_Energy_opt {te}",
            hi.period
        );
        // And the objective values at the endpoints are the optima's.
        assert!(rel_err(lo.time, t_final(&s, tt)) < 1e-6, "{label}");
        assert!(rel_err(hi.energy, e_final(&s, te)) < 1e-6, "{label}");
    }
}

#[test]
fn b_no_returned_point_is_dominated() {
    for (label, s) in tradeoff_presets() {
        let f = Frontier::compute(&s, 65, FO).expect(label);
        let pts = f.points();
        for (i, p) in pts.iter().enumerate() {
            for (j, q) in pts.iter().enumerate() {
                assert!(
                    i == j || !p.dominates(q),
                    "{label}: point {i} {p:?} dominates point {j} {q:?}"
                );
            }
        }
    }
}

#[test]
fn c_eps_constraint_solutions_lie_on_the_frontier() {
    for (label, s) in tradeoff_presets() {
        let f = Frontier::compute(&s, 129, FO).expect(label);
        let (lo_p, hi_p) = (f.t_time_opt.min(f.t_energy_opt), f.t_time_opt.max(f.t_energy_opt));
        for eps in [0.5, 2.0, 5.0, 20.0] {
            let sols = [
                min_energy_with_time_overhead(&s, eps, FO).unwrap(),
                min_time_with_energy_overhead(&s, eps, FO).unwrap(),
            ];
            for sol in sols {
                // On the frontier's period segment...
                assert!(
                    (lo_p - 1e-9..=hi_p + 1e-9).contains(&sol.period),
                    "{label} eps={eps}%: period {} outside [{lo_p}, {hi_p}]",
                    sol.period
                );
                // ...consistent with the closed forms...
                assert!(rel_err(sol.time, t_final(&s, sol.period)) < 1e-12, "{label}");
                assert!(rel_err(sol.energy, e_final(&s, sol.period)) < 1e-12, "{label}");
                // ...and not dominated by any sampled frontier point.
                for q in f.points() {
                    assert!(
                        !(q.time < sol.time * (1.0 - 1e-9)
                            && q.energy < sol.energy * (1.0 - 1e-9)),
                        "{label} eps={eps}%: {q:?} dominates eps-solution {sol:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn d_simulated_frontier_agrees_for_every_tradeoff_preset() {
    for (label, s) in tradeoff_presets() {
        let f = Frontier::compute(&s, POINTS, FO).expect(label);
        let v = validate(&f, 5, 160, 2013);
        for p in &v.points {
            assert!(
                p.time_agrees,
                "{label}: makespan disagrees at T={:.2} (model {:.1} vs sim {:.1} ± {:.1})",
                p.point.period, p.point.time, p.sim.makespan_mean, p.sim.makespan_ci95_half
            );
            assert!(
                p.energy_agrees,
                "{label}: energy disagrees at T={:.2} (model {:.1} vs sim {:.1} ± {:.1})",
                p.point.period, p.point.energy, p.sim.energy_mean, p.sim.energy_ci95_half
            );
        }
        assert!(v.all_agree(), "{label}");
    }
}

#[test]
fn e_frontier_results_identical_across_thread_counts() {
    // The analytic frontier is pure model evaluation fanned out on the
    // pool; the validated frontier seeds every sim cell from the cell's
    // own parameter bits. Both are therefore reproducible bit-for-bit
    // by a fully serial computation — which is exactly what a
    // one-thread pool would run, so agreement here is thread-count
    // invariance (`util::pool` writes results by index; see also
    // `sim_vs_model::monte_carlo_and_grid_engine_identical_across_
    // thread_counts`).
    let presets: Vec<(String, _)> =
        tradeoff_presets().into_iter().map(|(l, s)| (l.to_string(), s)).collect();

    // Pool-evaluated family vs direct inline computation per scenario.
    let family = family_frontiers(presets.clone(), POINTS, 7, FO);
    for (f, (label, s)) in family.iter().zip(&presets) {
        let direct = FrontierSummary::compute(s, POINTS, FO).expect("in domain");
        let sum = f.summary.as_ref().expect("in domain");
        assert_eq!(sum, &direct, "{label}");
        for (a, b) in sum.points.iter().zip(&direct.points) {
            assert_eq!(a.time.to_bits(), b.time.to_bits(), "{label}");
            assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "{label}");
        }
    }
    // Re-evaluating the family is bit-stable (memoised or not).
    assert_eq!(family, family_frontiers(presets.clone(), POINTS, 7, FO));

    // Simulated frontier: every pool-scheduled estimate equals serial
    // (threads = 1) Monte Carlo at the derived seed.
    let (label, s) = &presets[0];
    let f = Frontier::compute(s, POINTS, FO).unwrap();
    let v = validate(&f, 3, 64, 99);
    for p in &v.points {
        let mut cfg = SimConfig::paper(*s, p.point.period);
        cfg.failures_during_recovery = false;
        let serial = monte_carlo(&cfg, 64, p.seed, 1);
        assert_eq!(
            p.sim.makespan_mean.to_bits(),
            serial.makespan.mean().to_bits(),
            "{label}"
        );
        assert_eq!(p.sim.energy_mean.to_bits(), serial.energy.mean().to_bits(), "{label}");
    }
    assert_eq!(v, validate(&f, 3, 64, 99));
}

#[test]
fn knees_exist_and_sit_strictly_inside_every_preset_frontier() {
    for (label, s) in tradeoff_presets() {
        let f = Frontier::compute(&s, 65, FO).expect(label);
        for method in [KneeMethod::MaxDistanceToChord, KneeMethod::MaxCurvature] {
            let k = f.knee(method).unwrap_or_else(|| panic!("{label}: no {method:?} knee"));
            assert!(k.index > 0 && k.index < f.len() - 1, "{label} {method:?}");
            assert!(k.score > 0.0, "{label} {method:?}");
            let p = k.point;
            assert!(p.period > f.t_time_opt.min(f.t_energy_opt), "{label}");
            assert!(p.period < f.t_time_opt.max(f.t_energy_opt), "{label}");
        }
        // Hypervolume sane for every preset.
        let hv = f.hypervolume();
        assert!(hv > 0.0 && hv < 1.0, "{label}: hv={hv}");
    }
}

// ---- exact-backend acceptance (ISSUE 4) ----

#[test]
fn exact_endpoints_are_the_exact_optima_on_every_preset() {
    for (label, s) in tradeoff_presets() {
        let f = Frontier::compute(&s, POINTS, EXACT).expect(label);
        let tt = EXACT.t_time_opt(&s).unwrap();
        let te = EXACT.t_energy_opt(&s).unwrap();
        assert!(rel_err(f.time_opt_point().period, tt) < 1e-6, "{label}");
        assert!(rel_err(f.energy_opt_point().period, te) < 1e-6, "{label}");
        // The exact trade-off is real on every preset (rho > 1) and its
        // window sits strictly above the first-order one.
        assert!(te > tt, "{label}");
        assert!(tt > t_time_opt(&s).unwrap(), "{label}");
        assert!(te > t_energy_opt(&s).unwrap(), "{label}");
    }
}

#[test]
fn exact_frontier_has_no_dominated_points_and_interior_knees() {
    for (label, s) in tradeoff_presets() {
        let f = Frontier::compute(&s, 65, EXACT).expect(label);
        let pts = f.points();
        assert!(pts.len() >= 60, "{label}: kept {} of 65", pts.len());
        for (i, p) in pts.iter().enumerate() {
            for (j, q) in pts.iter().enumerate() {
                assert!(i == j || !p.dominates(q), "{label}: {p:?} dominates {q:?}");
            }
        }
        let k = f.knee(KneeMethod::MaxDistanceToChord).expect(label);
        assert!(k.index > 0 && k.index < f.len() - 1, "{label}");
        let hv = f.hypervolume();
        assert!(hv > 0.5 && hv < 1.0, "{label}: hv={hv}");
    }
}

#[test]
fn exact_eps_solutions_obey_their_bounds_under_the_exact_objectives() {
    for (label, s) in tradeoff_presets() {
        for eps in [0.5, 2.0, 5.0] {
            let sol = min_energy_with_time_overhead(&s, eps, EXACT).expect(label);
            assert!(
                sol.time <= sol.bound * (1.0 + 1e-9),
                "{label} eps={eps}%: {} > bound {}",
                sol.time,
                sol.bound
            );
            assert!(rel_err(sol.time, EXACT.t_final(&s, sol.period)) < 1e-12, "{label}");
            let sol = min_time_with_energy_overhead(&s, eps, EXACT).expect(label);
            assert!(
                sol.energy <= sol.bound * (1.0 + 1e-9),
                "{label} eps={eps}%: {} > bound {}",
                sol.energy,
                sol.bound
            );
        }
    }
}

#[test]
fn exact_frontier_identical_across_thread_counts_and_to_direct_computation() {
    // `pareto --model exact` acceptance: pool-evaluated exact frontier
    // cells equal the direct inline computation bit-for-bit (the memo
    // caches pure values), and re-evaluation is bit-stable.
    let presets: Vec<(String, _)> =
        tradeoff_presets().into_iter().map(|(l, s)| (l.to_string(), s)).collect();
    let family = family_frontiers(presets.clone(), POINTS, 7, EXACT);
    for (f, (label, s)) in family.iter().zip(&presets) {
        let direct = FrontierSummary::compute(s, POINTS, EXACT).expect("in domain");
        let sum = f.summary.as_ref().expect("in domain");
        assert_eq!(sum, &direct, "{label}");
        for (a, b) in sum.points.iter().zip(&direct.points) {
            assert_eq!(a.time.to_bits(), b.time.to_bits(), "{label}");
            assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "{label}");
        }
    }
    assert_eq!(family, family_frontiers(presets, POINTS, 7, EXACT));
}

#[test]
fn exact_frontier_simulates_within_the_flat_band_at_small_mu() {
    // The small-mu acceptance: at mu=120 the exact frontier must track
    // Monte Carlo inside the flat 2% allowance (no truncation widening),
    // including the long-period AlgoE end where the first-order forms
    // are 5-10% off.
    let s = ckpt_period::config::presets::fig1_scenario(120.0, 5.5);
    let f = Frontier::compute(&s, POINTS, EXACT).unwrap();
    let v = validate(&f, 4, 200, 2013);
    for p in &v.points {
        assert!(
            p.time_agrees && p.energy_agrees,
            "T={:.2}: model ({:.1}, {:.1}) vs sim ({:.1}±{:.1}, {:.1}±{:.1})",
            p.point.period,
            p.point.time,
            p.point.energy,
            p.sim.makespan_mean,
            p.sim.makespan_ci95_half,
            p.sim.energy_mean,
            p.sim.energy_ci95_half
        );
    }
}
