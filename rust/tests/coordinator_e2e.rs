//! End-to-end coordinator runs: checkpointing + failure injection +
//! rollback over the real PJRT workload. These are the system's acceptance
//! tests; the quantitative experiment lives in
//! `examples/fault_tolerant_training`.

use ckpt_period::coordinator::{Coordinator, CoordinatorConfig, OverlapMode, PeriodPolicy};
use ckpt_period::runtime::Runtime;

fn base_cfg(tag: &str) -> CoordinatorConfig {
    let ckpt_dir = std::env::temp_dir().join(format!("ckpt_e2e_{tag}"));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let mut cfg = CoordinatorConfig::new("artifacts", ckpt_dir);
    cfg.steps = 30;
    cfg.mu_s = 6.0; // aggressive failures so short runs still see them
    cfg.downtime_s = 0.02;
    cfg.calibration_steps = 2;
    cfg
}

#[test]
fn failure_free_run_completes_and_checkpoints() {
    let rt = Runtime::cpu().unwrap();
    let mut cfg = base_cfg("ff");
    cfg.inject_failures = false;
    cfg.policy = PeriodPolicy::Fixed(0.5); // checkpoint every ~0.5 s
    let report = Coordinator::new(&rt, cfg).unwrap().run().unwrap();

    assert_eq!(report.n_failures, 0);
    assert_eq!(report.steps_executed, 30);
    assert_eq!(report.steps_target, 30);
    assert_eq!(report.re_exec_fraction(), 0.0);
    assert!(report.n_checkpoints >= 1, "report: {report:?}");
    assert!(report.makespan_s > 0.0);
    assert!(report.energy.total > 0.0);
    // Loss curve recorded and decreasing overall.
    assert_eq!(report.losses.len(), 30);
    let first = report.losses[0].1;
    let last = report.final_loss().unwrap();
    assert!(last < first, "loss {first} -> {last}");
    // Phase accounting covers the makespan (loop bookkeeping overhead is
    // outside the tracked phases, so allow slack).
    let tracked =
        report.compute_s + report.checkpoint_s + report.recovery_s + report.down_s;
    assert!(tracked <= report.makespan_s * 1.01);
    assert!(tracked >= report.makespan_s * 0.5, "tracked {tracked} of {}", report.makespan_s);
}

#[test]
fn failures_trigger_rollback_and_reexecution() {
    let rt = Runtime::cpu().unwrap();
    let mut cfg = base_cfg("fail");
    cfg.policy = PeriodPolicy::Fixed(0.4);
    cfg.mu_s = 2.0; // MTBF ~ a couple of seconds: several failures
    let report = Coordinator::new(&rt, cfg).unwrap().run().unwrap();

    assert!(report.n_failures >= 1, "no failures injected: {report:?}");
    // Re-execution: more steps executed than the target.
    assert!(
        report.steps_executed >= report.steps_target,
        "{} < {}",
        report.steps_executed,
        report.steps_target
    );
    assert!(report.down_s > 0.0);
    assert!(report.recovery_s > 0.0);
    // Downtime accounting: each failure sleeps ~downtime_s.
    assert!(report.down_s >= 0.9 * cfg_downtime(&report) * report.n_failures as f64);
    // The run still finished the full workload.
    assert_eq!(report.steps_target, 30);
    assert!(report.final_loss().unwrap().is_finite());
}

fn cfg_downtime(_r: &ckpt_period::coordinator::RunReport) -> f64 {
    0.02
}

#[test]
fn blocking_and_overlapped_modes_both_work() {
    let rt = Runtime::cpu().unwrap();

    let mut cfg = base_cfg("block");
    cfg.inject_failures = false;
    cfg.policy = PeriodPolicy::Fixed(0.3);
    cfg.overlap = OverlapMode::Blocking;
    let blocking = Coordinator::new(&rt, cfg).unwrap().run().unwrap();
    assert_eq!(blocking.omega_assumed, 0.0);
    assert!(blocking.n_checkpoints >= 1);

    let mut cfg = base_cfg("olap");
    cfg.inject_failures = false;
    cfg.policy = PeriodPolicy::Fixed(0.3);
    cfg.overlap = OverlapMode::Overlapped { assumed_omega: 0.9 };
    let overlapped = Coordinator::new(&rt, cfg).unwrap().run().unwrap();
    assert!(overlapped.n_checkpoints >= 1);
    // Overlapped mode must actually overlap: work completed during
    // checkpoint windows.
    assert!(
        overlapped.omega_measured > 0.3,
        "omega_measured = {}",
        overlapped.omega_measured
    );
}

#[test]
fn algo_t_and_algo_e_periods_ordered() {
    // With rho = 5.5 power ratios, AlgoE must choose a longer period.
    let rt = Runtime::cpu().unwrap();

    let mut cfg = base_cfg("pt");
    cfg.inject_failures = false;
    cfg.steps = 12;
    cfg.mu_s = 20.0;
    cfg.policy = PeriodPolicy::AlgoT;
    let rt_t = Coordinator::new(&rt, cfg).unwrap().run().unwrap();

    let mut cfg = base_cfg("pe");
    cfg.inject_failures = false;
    cfg.steps = 12;
    cfg.mu_s = 20.0;
    cfg.policy = PeriodPolicy::AlgoE;
    let rt_e = Coordinator::new(&rt, cfg).unwrap().run().unwrap();

    assert!(
        rt_e.period_s >= rt_t.period_s,
        "AlgoE period {} < AlgoT period {}",
        rt_e.period_s,
        rt_t.period_s
    );
}

#[test]
fn adaptive_mode_completes_and_reacts() {
    let rt = Runtime::cpu().unwrap();
    let mut cfg = base_cfg("adaptive");
    cfg.adaptive = true;
    cfg.policy = PeriodPolicy::AlgoT;
    cfg.mu_s = 3.0; // failures arrive, MTBF estimate moves
    cfg.steps = 25;
    let report = Coordinator::new(&rt, cfg).unwrap().run().unwrap();
    assert_eq!(report.steps_target, 25);
    assert!(report.final_loss().unwrap().is_finite());
    // The adaptive run still produced checkpoints and survived failures.
    assert!(report.n_checkpoints >= 1);
}

#[test]
fn report_json_is_parseable() {
    let rt = Runtime::cpu().unwrap();
    let mut cfg = base_cfg("json");
    cfg.inject_failures = false;
    cfg.steps = 6;
    cfg.policy = PeriodPolicy::Fixed(0.5);
    let report = Coordinator::new(&rt, cfg).unwrap().run().unwrap();
    let parsed = ckpt_period::util::json::parse(&report.to_json().to_string_pretty()).unwrap();
    assert_eq!(parsed.req_f64("steps_target").unwrap(), 6.0);
    assert!(parsed.get("losses").unwrap().as_arr().unwrap().len() == 6);
}
