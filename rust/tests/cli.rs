//! CLI smoke tests: run the `ckpt-period` binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_ckpt-period"))
}

fn run_ok(args: &[&str]) -> String {
    let out = bin().args(args).output().expect("binary runs");
    assert!(
        out.status.success(),
        "args {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn help_lists_subcommands() {
    let out = run_ok(&["--help"]);
    for cmd in
        ["optimize", "sweep", "pareto", "simulate", "figures", "train", "batch", "bench", "info"]
    {
        assert!(out.contains(cmd), "missing {cmd} in: {out}");
    }
}

#[test]
fn unknown_subcommand_fails() {
    let out = bin().arg("bogus").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn optimize_prints_strategies() {
    let out = run_ok(&["optimize", "--mu", "300", "--rho", "5.5"]);
    for s in ["AlgoT", "AlgoE", "Young", "Daly", "energy gain"] {
        assert!(out.contains(s), "missing {s} in: {out}");
    }
}

#[test]
fn optimize_msk_requires_blocking() {
    let out = run_ok(&["optimize", "--omega", "0", "--msk"]);
    assert!(out.contains("MSK baseline"), "{out}");
}

#[test]
fn optimize_rejects_bad_omega() {
    let out = bin().args(["optimize", "--omega", "2.0"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn sweep_writes_csv() {
    let path = std::env::temp_dir().join("ckpt_cli_sweep.csv");
    let _ = std::fs::remove_file(&path);
    run_ok(&["sweep", "--points", "50", "--out", path.to_str().unwrap()]);
    let csv = std::fs::read_to_string(&path).unwrap();
    assert_eq!(csv.lines().count(), 51); // header + 50 rows
    assert!(csv.starts_with("period_min,"));
    let _ = std::fs::remove_file(path);
}

#[test]
fn sweep_breakdown_adds_columns() {
    let out = run_ok(&["sweep", "--points", "10", "--breakdown"]);
    assert!(out.contains("energy_ckpt"), "{out}");
    assert!(out.contains("time_fail_min"), "{out}");
}

#[test]
fn simulate_reports_model_and_ci() {
    let out = run_ok(&["simulate", "--replicates", "50", "--seed", "3"]);
    assert!(out.contains("makespan_min"), "{out}");
    assert!(out.contains("simulated (95% CI)"), "{out}");
}

#[test]
fn figures_generates_csvs() {
    let dir = std::env::temp_dir().join("ckpt_cli_figures");
    let _ = std::fs::remove_dir_all(&dir);
    let out = run_ok(&["figures", "--points", "12", "--out-dir", dir.to_str().unwrap()]);
    assert!(out.contains("peak energy gain"));
    assert!(out.contains("frontier knee"), "{out}");
    assert!(out.contains("knee drift"), "{out}");
    assert!(out.contains("adaptive knee"), "{out}");
    assert!(out.contains("drift tracking"), "{out}");
    assert!(out.contains("tiers knee"), "{out}");
    for f in [
        "fig1.csv",
        "fig2.csv",
        "fig3a.csv",
        "fig3b.csv",
        "frontier.csv",
        "frontier_knees.csv",
        "knee_drift.csv",
        "adaptive.csv",
        "drift.csv",
        "tiers.csv",
    ] {
        assert!(dir.join(f).exists(), "missing {f}");
    }
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn optimize_accepts_tier_presets_and_raw_grammar() {
    // Preset: the hierarchy's projection overrides C/R, so the optimal
    // periods differ from the flat default scenario.
    let flat = run_ok(&["optimize", "--mu", "300", "--rho", "5.5"]);
    let tiered = run_ok(&["optimize", "--mu", "300", "--rho", "5.5", "--tiers", "tiers-2"]);
    assert!(tiered.contains("AlgoT"), "{tiered}");
    assert_ne!(flat, tiered, "--tiers tiers-2 changed nothing");
    // Raw grammar round-trips through the same path.
    let raw = run_ok(&[
        "optimize",
        "--mu",
        "300",
        "--rho",
        "5.5",
        "--tiers",
        "c=1,r=1,io=3/c=10,r=10,io=10",
    ]);
    assert!(raw.contains("AlgoE"), "{raw}");
    // A single-level stack is the scalar model: identical output to
    // spelling C/R directly.
    let one = run_ok(&["optimize", "--mu", "300", "--rho", "5.5", "--tiers", "c=10,r=10,io=10"]);
    assert_eq!(one, flat, "1-level --tiers must degenerate to the scalar path");
}

#[test]
fn tiers_flag_rejects_bad_values_with_the_full_grammar() {
    for bad in ["nope", "c=1,r=1", "c=1,r=1,io=3/c=0,r=1,io=1", "x=2"] {
        let out = bin().args(["optimize", "--tiers", bad]).output().unwrap();
        assert!(!out.status.success(), "--tiers {bad} accepted");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("invalid value"), "{bad}: {err}");
        assert!(err.contains("tiers-1|tiers-2|tiers-3"), "{bad}: presets missing from {err}");
        assert!(err.contains("joined by '/'"), "{bad}: grammar missing from {err}");
    }
    // Tiered scenarios reject drift schedules at the flag layer, not
    // with a panic inside the simulator.
    let out = bin()
        .args([
            "simulate",
            "--adaptive",
            "--tiers",
            "tiers-2",
            "--drift",
            "io-ramp",
            "--replicates",
            "4",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("stationary"), "{err}");
}

#[test]
fn bench_gate_compares_the_trajectory() {
    let dir = std::env::temp_dir().join("ckpt_cli_bench_gate");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // No trajectory yet: benign skip, exit 0.
    let out = run_ok(&["bench", "--gate", "--out-dir", dir.to_str().unwrap()]);
    assert!(out.contains("skipping"), "{out}");
    let doc = |warm: f64| {
        format!(
            r#"{{"schema": "ckpt-period/bench/v2", "quick": true, "warm_memo_ns": 90.0,
                "cell_throughput_per_sec": 2000000.0,
                "queries_per_sec": {{"4": {{"cold": 1.0, "warm": {warm}}}}}}}"#
        )
    };
    std::fs::write(dir.join("BENCH_0.json"), doc(5.0e6)).unwrap();
    std::fs::write(dir.join("BENCH_1.json"), doc(4.9e6)).unwrap();
    let out = run_ok(&["bench", "--gate", "--out-dir", dir.to_str().unwrap()]);
    assert!(out.contains("bench gate passed"), "{out}");
    // A 30% warm-q/s drop on the newest pair fails with a full report.
    std::fs::write(dir.join("BENCH_2.json"), doc(3.4e6)).unwrap();
    let out =
        bin().args(["bench", "--gate", "--out-dir", dir.to_str().unwrap()]).output().unwrap();
    assert!(!out.status.success(), "regressed trajectory must fail the gate");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("REGRESSION") && err.contains("FAILED"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pareto_prints_frontier_and_knees() {
    let out = run_ok(&["pareto", "--points", "32"]);
    assert!(out.contains("hypervolume"), "{out}");
    assert!(out.contains("model first-order"), "{out}");
    assert!(out.contains("knee (max dist to chord)"), "{out}");
    assert!(out.contains("energy_gain_pct"), "{out}");
}

#[test]
fn pareto_exact_model_shifts_the_frontier() {
    // Small mu: the exact window sits visibly above the first-order one
    // (the knee-drift regime), and the artifact records the backend.
    let first = run_ok(&["pareto", "--points", "16", "--mu", "60"]);
    let exact = run_ok(&["pareto", "--points", "16", "--mu", "60", "--model", "exact"]);
    assert!(exact.contains("model exact"), "{exact}");
    let t_lo = |out: &str| {
        let tail = out.split("T in [").nth(1).expect("frontier line").to_string();
        tail.split(',').next().unwrap().trim().parse::<f64>().unwrap()
    };
    let (fo_lo, ex_lo) = (t_lo(&first), t_lo(&exact));
    assert!(ex_lo > fo_lo * 1.2, "exact T_Time_opt {ex_lo} !>> first-order {fo_lo}");
    // exact:ideal is accepted too.
    let out = run_ok(&["pareto", "--points", "16", "--model", "exact:ideal"]);
    assert!(out.contains("model exact:ideal"), "{out}");
}

#[test]
fn bad_model_values_are_rejected_with_the_grammar() {
    for cmd in [
        vec!["pareto", "--model", "bogus"],
        vec!["simulate", "--model", "exact:lazy", "--replicates", "4"],
        vec!["train", "--model", "second-order"],
    ] {
        let out = bin().args(&cmd).output().unwrap();
        assert!(!out.status.success(), "{cmd:?} accepted");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("model"), "{cmd:?}: {err}");
        assert!(err.contains("first-order|exact"), "{cmd:?}: grammar missing from {err}");
    }
}

#[test]
fn pareto_eps_constraints_report_solutions() {
    let out = run_ok(&["pareto", "--points", "24", "--eps-time", "5", "--eps-energy", "5"]);
    assert!(out.contains("eps-time 5%"), "{out}");
    assert!(out.contains("eps-energy 5%"), "{out}");
    assert!(out.contains("binding") || out.contains("slack"), "{out}");
    // Negative budgets are rejected.
    let bad = bin().args(["pareto", "--eps-time", "-1"]).output().unwrap();
    assert!(!bad.status.success());
}

#[test]
fn pareto_writes_json_artifact() {
    let path = std::env::temp_dir().join("ckpt_cli_pareto.json");
    let _ = std::fs::remove_file(&path);
    run_ok(&[
        "pareto",
        "--points",
        "16",
        "--eps-time",
        "10",
        "--out",
        path.to_str().unwrap(),
    ]);
    let raw = std::fs::read_to_string(&path).unwrap();
    assert!(raw.contains("\"schema\": \"ckpt-period/pareto-frontier/v1\""), "{raw}");
    assert!(raw.contains("\"t_time_opt_min\""), "{raw}");
    assert!(raw.contains("\"min_energy_given_time\""), "{raw}");
    let _ = std::fs::remove_file(path);
}

#[test]
fn pareto_simulate_reports_agreement() {
    let out = run_ok(&[
        "pareto",
        "--points",
        "16",
        "--simulate",
        "--replicates",
        "40",
        "--sim-points",
        "3",
    ]);
    assert!(out.contains("simulated frontier"), "{out}");
    assert!(out.contains("confidence bands"), "{out}");
}

#[test]
fn pareto_family_presets_streams_one_artifact_per_scenario() {
    let dir = std::env::temp_dir().join("ckpt_cli_pareto_family");
    let _ = std::fs::remove_dir_all(&dir);
    let out = run_ok(&[
        "pareto",
        "--family",
        "presets",
        "--points",
        "9",
        "--out-dir",
        dir.to_str().unwrap(),
    ]);
    assert!(out.contains("fig1-rho5.5"), "{out}");
    assert!(out.contains("frontier artifacts written"), "{out}");
    for label in [
        "fig1-rho5.5",
        "fig1-rho7",
        "alpha-heavy",
        "beta-heavy",
        "gamma-heavy",
        "exascale-io-heavy",
    ] {
        let path = dir.join(format!("{label}.json"));
        assert!(path.exists(), "missing {label}.json");
        let raw = std::fs::read_to_string(&path).unwrap();
        assert!(raw.contains("\"schema\": \"ckpt-period/pareto-frontier/v1\""), "{label}");
        assert!(raw.contains("\"hypervolume\""), "{label}");
        assert!(raw.contains("\"knee_chord\""), "{label}");
    }
    // Unknown families are rejected with a clear message.
    let bad = bin().args(["pareto", "--family", "bogus"]).output().unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("unknown family"));
    // Single-scenario extras are rejected rather than silently dropped.
    let bad = bin()
        .args(["pareto", "--family", "presets", "--eps-time", "5"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("not supported with --family"));
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn simulate_adaptive_knee_runs_end_to_end() {
    let out = run_ok(&[
        "simulate",
        "--adaptive",
        "--policy",
        "knee",
        "--replicates",
        "24",
        "--seed",
        "3",
    ]);
    assert!(out.contains("adaptive simulation: policy knee"), "{out}");
    assert!(out.contains("makespan_min"), "{out}");
    assert!(out.contains("period_updates"), "{out}");
    // The knee policy re-targets at the exact backend through --model.
    let out = run_ok(&[
        "simulate",
        "--adaptive",
        "--policy",
        "knee",
        "--model",
        "exact",
        "--replicates",
        "16",
        "--seed",
        "3",
    ]);
    assert!(out.contains("policy knee, model exact"), "{out}");
    // The budget policies parse and run through the same path.
    let out = run_ok(&[
        "simulate",
        "--adaptive",
        "--policy",
        "eps-time:5",
        "--replicates",
        "16",
    ]);
    assert!(out.contains("policy eps-time"), "{out}");
}

#[test]
fn simulate_drift_runs_end_to_end() {
    // A preset name and the raw grammar both drive the drift path, and
    // the table carries the tracking/regret rows.
    let out = run_ok(&[
        "simulate",
        "--adaptive",
        "--policy",
        "knee",
        "--drift",
        "ramp:0:5000:c=2,r=2,io=2",
        "--replicates",
        "16",
        "--seed",
        "3",
    ]);
    assert!(out.contains("adaptive drift simulation: policy knee"), "{out}");
    assert!(out.contains("drift ramp:0:5000"), "{out}");
    assert!(out.contains("tracking_lag_pct"), "{out}");
    assert!(out.contains("waste_regret_pct"), "{out}");
    let out = run_ok(&[
        "simulate",
        "--adaptive",
        "--policy",
        "knee",
        "--drift",
        "io-ramp",
        "--alpha",
        "0.5",
        "--hysteresis",
        "0.02",
        "--replicates",
        "12",
    ]);
    assert!(out.contains("alpha 0.5, band 0.02"), "{out}");
}

#[test]
fn drift_and_knob_flags_are_validated() {
    // Bad drift specs surface the full grammar, like --policy/--model.
    for bad in ["bogus-preset", "ramp:5000:0:c=2", "step:100:c=0", "contention:0:0.5:c=2"] {
        let out = bin()
            .args(["simulate", "--adaptive", "--drift", bad])
            .output()
            .unwrap();
        assert!(!out.status.success(), "{bad} accepted");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("drift"), "{bad}: {err}");
        assert!(err.contains("stationary|step:"), "{bad}: grammar missing from {err}");
        assert!(err.contains("io-ramp"), "{bad}: presets missing from {err}");
    }
    // The knobs obey the Ewma / hysteresis contracts.
    for (flag, bad) in [("--alpha", "0"), ("--alpha", "1.5"), ("--hysteresis", "-0.1")] {
        let out = bin()
            .args(["simulate", "--adaptive", flag, bad, "--replicates", "4"])
            .output()
            .unwrap();
        assert!(!out.status.success(), "{flag} {bad} accepted");
        assert!(
            String::from_utf8_lossy(&out.stderr).contains("invalid value"),
            "{flag} {bad}"
        );
    }
    // Controller knobs without --adaptive are a clear error, not a
    // silent no-op.
    let out = bin()
        .args(["simulate", "--drift", "io-ramp", "--replicates", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--adaptive"));
    // train validates the same knobs before touching any runtime.
    let out = bin().args(["train", "--drift", "nope"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("drift"));
    // train runs in wall-clock seconds: the minute-authored presets
    // are rejected with a units hint, not silently run ~60x too fast.
    let out = bin().args(["train", "--drift", "mu-decay"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("seconds"), "{err}");
}

#[test]
fn replicate_and_batch_knobs_are_validated() {
    // Zero sample paths is a CliError up front, not an assert deep in
    // the Monte-Carlo runner.
    for args in [vec!["simulate", "--replicates", "0"], vec!["train", "--steps", "0"]] {
        let out = bin().args(&args).output().unwrap();
        assert!(!out.status.success(), "{args:?} accepted");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("invalid value"), "{args:?}: {err}");
        assert!(err.contains(">= 1"), "{args:?}: {err}");
    }
    // --batch takes 'auto' or a positive integer, full grammar in the
    // message like --policy/--model.
    for bad in ["0", "many", "2.5"] {
        let out = bin().args(["simulate", "--batch", bad, "--replicates", "4"]).output().unwrap();
        assert!(!out.status.success(), "--batch {bad} accepted");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("batch"), "{bad}: {err}");
        assert!(err.contains("auto"), "{bad}: grammar missing from {err}");
    }
    // The batch size is an execution-shape knob: stdout is
    // byte-identical for every value.
    let base = run_ok(&["simulate", "--replicates", "24", "--seed", "5"]);
    for b in ["1", "5", "64"] {
        let out = run_ok(&["simulate", "--replicates", "24", "--seed", "5", "--batch", b]);
        assert_eq!(base, out, "--batch {b} changed the output");
    }
}

#[test]
fn info_reports_memo_counters() {
    let out = run_ok(&["info"]);
    assert!(out.contains("memo caches"), "{out}");
    // One registry-driven table, every cached surface a row (zero
    // counters in a fresh process, but every row is always there).
    for row in [
        "grid cell cache",
        "online policy memo",
        "exact optima memo",
        "tier plan memo",
        "serve answer cache",
    ] {
        assert!(out.contains(row), "missing cache row {row}: {out}");
    }
    for col in ["entries", "hits", "misses", "clears", "hit rate"] {
        assert!(out.contains(col), "missing column {col}: {out}");
    }
}

#[test]
fn info_metrics_prints_the_prometheus_exposition() {
    let out = run_ok(&["info", "--metrics"]);
    assert!(out.contains("# TYPE ckpt_cache_hits_total counter"), "{out}");
    assert!(out.contains("# TYPE ckpt_serve_stage_ns histogram"), "{out}");
    assert!(out.contains("ckpt_cache_entries{cache=\"grid-cell-cache\"}"), "{out}");
    assert!(out.contains("ckpt_cache_entries{cache=\"tier-plan-memo\"}"), "{out}");
    assert!(out.contains("ckpt_serve_stage_ns_bucket{stage=\"solve\",le=\"+Inf\"}"), "{out}");
    // Exposition-only mode: no summary tables mixed into the scrape.
    assert!(!out.contains("memo caches"), "{out}");
}

#[test]
fn simulate_trace_writes_a_replayable_jsonl_decision_log() {
    let dir = std::env::temp_dir().join(format!("ckpt_cli_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.jsonl");
    let out = bin()
        .args([
            "simulate",
            "--adaptive",
            "--policy",
            "knee",
            "--drift",
            "ramp:0:5000:c=2,r=2,io=2",
            "--replicates",
            "4",
            "--seed",
            "3",
            "--trace",
            path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("decision trace written"), "{err}");

    let text = std::fs::read_to_string(&path).unwrap();
    let mut kinds = std::collections::BTreeSet::new();
    let mut oracle_seen = false;
    for line in text.lines() {
        // Every line is one standalone JSON event with the envelope.
        let doc = ckpt_period::util::json::parse(line)
            .unwrap_or_else(|e| panic!("bad trace line {line}: {e}"));
        let kind = doc.req_str("kind").unwrap().to_string();
        assert!(
            ["observe", "period", "failure", "recovery"].contains(&kind.as_str()),
            "unknown kind {kind}"
        );
        doc.req_f64("seed").unwrap();
        doc.req_f64("t").unwrap();
        if doc.get("oracle").and_then(|j| j.as_bool()) == Some(true) {
            oracle_seen = true;
        }
        kinds.insert(kind);
    }
    assert!(kinds.contains("observe"), "kinds: {kinds:?}");
    assert!(kinds.contains("period"), "kinds: {kinds:?}");
    assert!(oracle_seen, "the oracle twin's decisions must be traced too");
    std::fs::remove_dir_all(&dir).unwrap();

    // --trace is an adaptive-run concept; anything else is an error.
    let out =
        bin().args(["simulate", "--trace", "x.jsonl", "--replicates", "2"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--adaptive"));
}

#[test]
fn bad_policies_are_rejected_with_the_grammar() {
    for bad in ["fixed:-5", "fixed:NaN", "fixed:inf", "eps-time:-1", "bogus"] {
        let out = bin().args(["simulate", "--policy", bad]).output().unwrap();
        assert!(!out.status.success(), "{bad} accepted");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("policy"), "{bad}: {err}");
        assert!(err.contains("knee"), "{bad}: grammar missing from {err}");
    }
    // train surfaces the same CliError path before touching any runtime.
    let out = bin().args(["train", "--policy", "fixed:-5"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("invalid value"), "train policy error");
}

#[test]
fn duplicate_value_flag_is_a_clear_error() {
    let out = bin().args(["optimize", "--mu", "300", "--mu", "120"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("more than once"), "{err}");
    assert!(err.contains("--mu"), "{err}");
}

#[test]
fn info_reads_artifacts() {
    let out = run_ok(&["info"]);
    assert!(out.contains("470784 params") || out.contains("params"), "{out}");
    assert!(out.contains("sweep grid"), "{out}");
}
