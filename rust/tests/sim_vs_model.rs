//! V-sim (DESIGN.md §4): Monte-Carlo simulation vs the analytical model
//! over the paper's scenario families — the validation the paper itself
//! could not run.

use ckpt_period::config::presets::{
    fig1_scenario, fig3_scenario, io_contention_scenario, jaguar_platform, two_level_scenario,
    weibull_platform_scenario,
};
use ckpt_period::model::energy::e_final;
use ckpt_period::model::params::Scenario;
use ckpt_period::model::ratios::compare;
use ckpt_period::model::time::t_final;
use ckpt_period::model::{t_energy_opt, t_time_opt};
use ckpt_period::sim::{monte_carlo, FailureProcess, SimConfig};
use ckpt_period::sweep::GridSpec;
use ckpt_period::util::stats::{ConfidenceLevel, rel_err};

const REPS: usize = 300;
const THREADS: usize = 8;

#[test]
fn model_matches_simulation_across_fig1_grid() {
    // The model is first-order in C/mu and assumes failures never strike
    // during downtime/recovery; match that assumption here (the realistic
    // mode is exercised by `realistic_recovery_failures_add_second_order_
    // overhead` below). Expect ~2% at mu=300 (C/mu = 1/30), ~5% at
    // mu=120 (C/mu = 1/12).
    for mu in [120.0, 300.0] {
        for rho in [2.0, 5.5, 7.0] {
            let s = fig1_scenario(mu, rho);
            for period in [t_time_opt(&s).unwrap(), t_energy_opt(&s).unwrap()] {
                // Truncation error scales like (T/mu)^2 (the neglected
                // multi-failure-per-period terms); AlgoE at mu=120
                // stretches T to ~0.4*mu where that's ~7%.
                let tol = 0.02 + 0.5 * (period / mu).powi(2);
                let mut cfg = SimConfig::paper(s, period);
                cfg.failures_during_recovery = false;
                let mc = monte_carlo(&cfg, REPS, 17, THREADS);
                let t_err = rel_err(mc.makespan.mean(), t_final(&s, period));
                let e_err = rel_err(mc.energy.mean(), e_final(&s, period));
                assert!(
                    t_err < tol,
                    "makespan err {t_err} at mu={mu} rho={rho} T={period}"
                );
                assert!(
                    e_err < tol,
                    "energy err {e_err} at mu={mu} rho={rho} T={period}"
                );
            }
        }
    }
}

#[test]
fn exact_model_matches_simulation_at_small_mu() {
    // Where the first-order forms drift by 5-10% (AlgoE periods at
    // mu=120), the exact renewal model should track Monte Carlo within
    // sampling error (~1-2%) in BOTH recovery modes.
    use ckpt_period::model::exact::{e_final_exact, t_final_exact, RecoveryModel};
    for rho in [2.0, 5.5, 7.0] {
        let s = fig1_scenario(120.0, rho);
        let period = t_energy_opt(&s).unwrap(); // the stressed regime
        for (model, flag) in
            [(RecoveryModel::Ideal, false), (RecoveryModel::Restarting, true)]
        {
            let mut cfg = SimConfig::paper(s, period);
            cfg.failures_during_recovery = flag;
            let mc = monte_carlo(&cfg, REPS, 73, THREADS);
            let tm = t_final_exact(&s, period, model);
            let em = e_final_exact(&s, period, model);
            let t_err = rel_err(mc.makespan.mean(), tm);
            let e_err = rel_err(mc.energy.mean(), em);
            assert!(
                t_err < 0.02,
                "exact makespan err {t_err} (rho={rho}, {model:?}): sim {} vs {tm}",
                mc.makespan.mean()
            );
            assert!(
                e_err < 0.02,
                "exact energy err {e_err} (rho={rho}, {model:?}): sim {} vs {em}",
                mc.energy.mean()
            );
        }
    }
}

#[test]
fn realistic_recovery_failures_add_second_order_overhead() {
    // With failures allowed during downtime/recovery (reality), the
    // simulated makespan exceeds the model's, by an amount on the order
    // of (D+R)/mu per failure — a few percent here, not more.
    let s = fig1_scenario(120.0, 5.5);
    let t = t_time_opt(&s).unwrap();
    let ideal = {
        let mut cfg = SimConfig::paper(s, t);
        cfg.failures_during_recovery = false;
        monte_carlo(&cfg, REPS, 41, THREADS)
    };
    let real = monte_carlo(&SimConfig::paper(s, t), REPS, 41, THREADS);
    assert!(real.makespan.mean() >= ideal.makespan.mean());
    let extra = real.makespan.mean() / ideal.makespan.mean() - 1.0;
    assert!(extra < 0.10, "second-order overhead {extra}");
}

#[test]
fn simulated_ratios_track_model_ratios() {
    // The figures' headline quantities, by simulation.
    let s = fig1_scenario(300.0, 5.5);
    let cmp = compare(&s).unwrap();
    let mc_t = monte_carlo(&SimConfig::paper(s, cmp.t_time), REPS, 3, THREADS);
    let mc_e = monte_carlo(&SimConfig::paper(s, cmp.t_energy), REPS, 3, THREADS);

    let sim_time_ratio = mc_e.makespan.mean() / mc_t.makespan.mean();
    let sim_energy_ratio = mc_t.energy.mean() / mc_e.energy.mean();
    assert!(
        (sim_time_ratio - cmp.time_ratio()).abs() < 0.05,
        "time ratio sim {sim_time_ratio} vs model {}",
        cmp.time_ratio()
    );
    assert!(
        (sim_energy_ratio - cmp.energy_ratio()).abs() < 0.05,
        "energy ratio sim {sim_energy_ratio} vs model {}",
        cmp.energy_ratio()
    );
    // And the gain direction is as the paper claims.
    assert!(sim_energy_ratio > 1.1);
}

#[test]
fn per_node_superposition_equivalent_to_aggregate() {
    // mu = mu_ind / N (§2.1): a per-node process with the same platform
    // MTBF yields the same expected makespan.
    let s = fig1_scenario(300.0, 5.5);
    let t = t_time_opt(&s).unwrap();
    let agg = SimConfig::paper(s, t);
    let mut per_node = agg.clone();
    per_node.failure = FailureProcess::PerNodeExponential { n: 1000, mtbf_ind: 300_000.0 };
    let a = monte_carlo(&agg, REPS, 5, THREADS);
    let b = monte_carlo(&per_node, REPS, 6, THREADS);
    assert!(
        rel_err(a.makespan.mean(), b.makespan.mean()) < 0.03,
        "agg {} vs per-node {}",
        a.makespan.mean(),
        b.makespan.mean()
    );
}

#[test]
fn weibull_failures_shift_results_but_model_stays_sane() {
    // Robustness extension: with Weibull shape 0.7 (bursty failures) the
    // first-order exponential model keeps the right order of magnitude.
    let s = fig1_scenario(300.0, 5.5);
    let t = t_time_opt(&s).unwrap();
    let mut cfg = SimConfig::paper(s, t);
    // Per-node Weibull with the same long-run platform MTBF: scale so
    // that scale*Gamma(1+1/shape)/n = 300.
    let n = 200;
    let shape = 0.7;
    let scale = 300.0 * n as f64 / ckpt_period::sim::failure::gamma(1.0 + 1.0 / shape);
    cfg.failure = FailureProcess::PerNodeWeibull { n, shape, scale_ind: scale };
    let mc = monte_carlo(&cfg, REPS, 9, THREADS);
    let model = t_final(&s, t);
    let err = rel_err(mc.makespan.mean(), model);
    assert!(
        err < 0.15,
        "Weibull sim {} vs exp model {model}: err {err}",
        mc.makespan.mean()
    );
}

#[test]
fn fig3_scenarios_validate_where_in_domain() {
    for n_nodes in [1e5, 1e6, 5e6] {
        let s = fig3_scenario(n_nodes, 5.5).expect("in domain");
        let t = t_time_opt(&s).unwrap();
        let mc = monte_carlo(&SimConfig::paper(s, t), REPS, 21, THREADS);
        let err = rel_err(mc.makespan.mean(), t_final(&s, t));
        // Smaller mu => bigger first-order error; stay within 10%.
        assert!(err < 0.10, "N={n_nodes}: err {err}");
    }
}

/// CI-based agreement check for one scenario at AlgoT's period: the
/// analytical `T_final`/`E_final` must fall within the Monte-Carlo 95%
/// confidence band, widened by the first-order model's own truncation
/// error (which scales like `(T/μ)²` — the neglected
/// multi-failure-per-period terms).
fn assert_within_ci(tag: &str, s: &Scenario, seed: u64) {
    let period = t_time_opt(s).unwrap();
    let mut cfg = SimConfig::paper(*s, period);
    // The first-order model assumes failure-free recovery; match it.
    cfg.failures_during_recovery = false;
    let mc = monte_carlo(&cfg, REPS, seed, THREADS);
    let tol = 0.02 + 0.5 * (period / s.mu).powi(2);
    for (what, model, stats) in [
        ("makespan", t_final(s, period), &mc.makespan),
        ("energy", e_final(s, period), &mc.energy),
    ] {
        let half = stats.ci_half_width(ConfidenceLevel::P95);
        let slack = 3.0 * half + tol * model;
        assert!(
            (model - stats.mean()).abs() <= slack,
            "{tag}: {what} model {model} vs sim {} ± {half} (slack {slack})",
            stats.mean()
        );
    }
}

#[test]
fn all_preset_families_within_ci_of_model() {
    // Satellite coverage: every scenario family `config::presets` can
    // produce is validated sim-vs-model, seeded and deterministic.
    let mut seed = 1000;
    let mut check = |tag: String, s: Scenario| {
        seed += 1;
        assert_within_ci(&tag, &s, seed);
    };
    for mu in [120.0, 300.0] {
        for rho in [2.0, 5.5, 7.0] {
            check(format!("fig1 mu={mu} rho={rho}"), fig1_scenario(mu, rho));
        }
    }
    for n_nodes in [1e5, 1e6] {
        check(
            format!("fig3 N={n_nodes}"),
            fig3_scenario(n_nodes, 5.5).expect("in domain"),
        );
    }
    // Jaguar-derived platform MTBF on the Fig. 1 family.
    check("jaguar".into(), fig1_scenario(jaguar_platform(219_150.0).mu(), 5.5));
    for contention in [0.5, 1.0] {
        check(
            format!("io-contention x={contention}"),
            io_contention_scenario(300.0, 5.5, contention).expect("in domain"),
        );
    }
    check(
        "two-level 9f/1s".into(),
        two_level_scenario(300.0, 5.5, 1.0, 10.0, 10).expect("in domain"),
    );
    check(
        "two-level 4f/1s".into(),
        two_level_scenario(300.0, 7.0, 2.0, 10.0, 5).expect("in domain"),
    );
}

#[test]
fn weibull_preset_platform_mtbf_is_calibrated() {
    // The Weibull preset promises the same long-run platform MTBF as the
    // exponential preset; under shape = 1 it IS exponential in law, so
    // the model must agree within CI-level slack.
    let (s, process) = weibull_platform_scenario(1e6, 5.5, 1.0).expect("in domain");
    let period = t_time_opt(&s).unwrap();
    let mut cfg = SimConfig::paper(s, period);
    cfg.failure = process;
    cfg.failures_during_recovery = false;
    let mc = monte_carlo(&cfg, REPS, 77, THREADS);
    let err = rel_err(mc.makespan.mean(), t_final(&s, period));
    assert!(err < 0.05, "shape=1 Weibull err {err}");

    // Bursty shape keeps the right order of magnitude (robustness band).
    let (s, process) = weibull_platform_scenario(1e6, 5.5, 0.7).expect("in domain");
    let mut cfg = SimConfig::paper(s, period);
    cfg.failure = process;
    let mc = monte_carlo(&cfg, REPS, 78, THREADS);
    let err = rel_err(mc.makespan.mean(), t_final(&s, period));
    assert!(err < 0.20, "shape=0.7 Weibull err {err}");
}

#[test]
fn monte_carlo_and_grid_engine_identical_across_thread_counts() {
    // Satellite determinism: same base seed => bit-identical estimates
    // for threads ∈ {1, 2, 8}, and the grid engine returns exactly the
    // serial reference for its derived cell seed.
    let s = fig1_scenario(300.0, 5.5);
    let t = t_time_opt(&s).unwrap();
    let cfg = SimConfig::paper(s, t);
    let reference = monte_carlo(&cfg, 96, 1234, 1);
    for threads in [2usize, 8] {
        let mc = monte_carlo(&cfg, 96, 1234, threads);
        for (a, b) in [
            (reference.makespan.mean(), mc.makespan.mean()),
            (reference.energy.mean(), mc.energy.mean()),
            (reference.failures.mean(), mc.failures.mean()),
            (reference.work_lost.mean(), mc.work_lost.mean()),
        ] {
            assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
        }
    }

    let mut spec = GridSpec::new(1234);
    spec.push_sim(s, t, 96);
    let spec = spec.without_cache();
    let cell_seed = spec.cell_seed(&spec.cells()[0]);
    let engine = spec.evaluate();
    let engine_sim = engine[0].output.sim().expect("sim output");
    // Engine (pool-scheduled) == serial monte_carlo at the derived seed.
    let serial = monte_carlo(&cfg, 96, cell_seed, 1);
    assert_eq!(engine_sim.makespan_mean.to_bits(), serial.makespan.mean().to_bits());
    assert_eq!(engine_sim.energy_mean.to_bits(), serial.energy.mean().to_bits());
    // And evaluating the same spec twice is bit-stable.
    let again = spec.evaluate();
    assert_eq!(engine, again);
}
