//! V-sim (DESIGN.md §4): Monte-Carlo simulation vs the analytical model
//! over the paper's scenario families — the validation the paper itself
//! could not run.

use ckpt_period::config::presets::{fig1_scenario, fig3_scenario};
use ckpt_period::model::energy::e_final;
use ckpt_period::model::ratios::compare;
use ckpt_period::model::time::t_final;
use ckpt_period::model::{t_energy_opt, t_time_opt};
use ckpt_period::sim::{monte_carlo, FailureProcess, SimConfig};
use ckpt_period::util::stats::rel_err;

const REPS: usize = 300;
const THREADS: usize = 8;

#[test]
fn model_matches_simulation_across_fig1_grid() {
    // The model is first-order in C/mu and assumes failures never strike
    // during downtime/recovery; match that assumption here (the realistic
    // mode is exercised by `realistic_recovery_failures_add_second_order_
    // overhead` below). Expect ~2% at mu=300 (C/mu = 1/30), ~5% at
    // mu=120 (C/mu = 1/12).
    for mu in [120.0, 300.0] {
        for rho in [2.0, 5.5, 7.0] {
            let s = fig1_scenario(mu, rho);
            for period in [t_time_opt(&s).unwrap(), t_energy_opt(&s).unwrap()] {
                // Truncation error scales like (T/mu)^2 (the neglected
                // multi-failure-per-period terms); AlgoE at mu=120
                // stretches T to ~0.4*mu where that's ~7%.
                let tol = 0.02 + 0.5 * (period / mu).powi(2);
                let mut cfg = SimConfig::paper(s, period);
                cfg.failures_during_recovery = false;
                let mc = monte_carlo(&cfg, REPS, 17, THREADS);
                let t_err = rel_err(mc.makespan.mean(), t_final(&s, period));
                let e_err = rel_err(mc.energy.mean(), e_final(&s, period));
                assert!(
                    t_err < tol,
                    "makespan err {t_err} at mu={mu} rho={rho} T={period}"
                );
                assert!(
                    e_err < tol,
                    "energy err {e_err} at mu={mu} rho={rho} T={period}"
                );
            }
        }
    }
}

#[test]
fn exact_model_matches_simulation_at_small_mu() {
    // Where the first-order forms drift by 5-10% (AlgoE periods at
    // mu=120), the exact renewal model should track Monte Carlo within
    // sampling error (~1-2%) in BOTH recovery modes.
    use ckpt_period::model::exact::{e_final_exact, t_final_exact, RecoveryModel};
    for rho in [2.0, 5.5, 7.0] {
        let s = fig1_scenario(120.0, rho);
        let period = t_energy_opt(&s).unwrap(); // the stressed regime
        for (model, flag) in
            [(RecoveryModel::Ideal, false), (RecoveryModel::Restarting, true)]
        {
            let mut cfg = SimConfig::paper(s, period);
            cfg.failures_during_recovery = flag;
            let mc = monte_carlo(&cfg, REPS, 73, THREADS);
            let tm = t_final_exact(&s, period, model);
            let em = e_final_exact(&s, period, model);
            let t_err = rel_err(mc.makespan.mean(), tm);
            let e_err = rel_err(mc.energy.mean(), em);
            assert!(
                t_err < 0.02,
                "exact makespan err {t_err} (rho={rho}, {model:?}): sim {} vs {tm}",
                mc.makespan.mean()
            );
            assert!(
                e_err < 0.02,
                "exact energy err {e_err} (rho={rho}, {model:?}): sim {} vs {em}",
                mc.energy.mean()
            );
        }
    }
}

#[test]
fn realistic_recovery_failures_add_second_order_overhead() {
    // With failures allowed during downtime/recovery (reality), the
    // simulated makespan exceeds the model's, by an amount on the order
    // of (D+R)/mu per failure — a few percent here, not more.
    let s = fig1_scenario(120.0, 5.5);
    let t = t_time_opt(&s).unwrap();
    let ideal = {
        let mut cfg = SimConfig::paper(s, t);
        cfg.failures_during_recovery = false;
        monte_carlo(&cfg, REPS, 41, THREADS)
    };
    let real = monte_carlo(&SimConfig::paper(s, t), REPS, 41, THREADS);
    assert!(real.makespan.mean() >= ideal.makespan.mean());
    let extra = real.makespan.mean() / ideal.makespan.mean() - 1.0;
    assert!(extra < 0.10, "second-order overhead {extra}");
}

#[test]
fn simulated_ratios_track_model_ratios() {
    // The figures' headline quantities, by simulation.
    let s = fig1_scenario(300.0, 5.5);
    let cmp = compare(&s).unwrap();
    let mc_t = monte_carlo(&SimConfig::paper(s, cmp.t_time), REPS, 3, THREADS);
    let mc_e = monte_carlo(&SimConfig::paper(s, cmp.t_energy), REPS, 3, THREADS);

    let sim_time_ratio = mc_e.makespan.mean() / mc_t.makespan.mean();
    let sim_energy_ratio = mc_t.energy.mean() / mc_e.energy.mean();
    assert!(
        (sim_time_ratio - cmp.time_ratio()).abs() < 0.05,
        "time ratio sim {sim_time_ratio} vs model {}",
        cmp.time_ratio()
    );
    assert!(
        (sim_energy_ratio - cmp.energy_ratio()).abs() < 0.05,
        "energy ratio sim {sim_energy_ratio} vs model {}",
        cmp.energy_ratio()
    );
    // And the gain direction is as the paper claims.
    assert!(sim_energy_ratio > 1.1);
}

#[test]
fn per_node_superposition_equivalent_to_aggregate() {
    // mu = mu_ind / N (§2.1): a per-node process with the same platform
    // MTBF yields the same expected makespan.
    let s = fig1_scenario(300.0, 5.5);
    let t = t_time_opt(&s).unwrap();
    let agg = SimConfig::paper(s, t);
    let mut per_node = agg.clone();
    per_node.failure = FailureProcess::PerNodeExponential { n: 1000, mtbf_ind: 300_000.0 };
    let a = monte_carlo(&agg, REPS, 5, THREADS);
    let b = monte_carlo(&per_node, REPS, 6, THREADS);
    assert!(
        rel_err(a.makespan.mean(), b.makespan.mean()) < 0.03,
        "agg {} vs per-node {}",
        a.makespan.mean(),
        b.makespan.mean()
    );
}

#[test]
fn weibull_failures_shift_results_but_model_stays_sane() {
    // Robustness extension: with Weibull shape 0.7 (bursty failures) the
    // first-order exponential model keeps the right order of magnitude.
    let s = fig1_scenario(300.0, 5.5);
    let t = t_time_opt(&s).unwrap();
    let mut cfg = SimConfig::paper(s, t);
    // Per-node Weibull with the same long-run platform MTBF: scale so
    // that scale*Gamma(1+1/shape)/n = 300.
    let n = 200;
    let shape = 0.7;
    let scale = 300.0 * n as f64 / ckpt_period::sim::failure::gamma(1.0 + 1.0 / shape);
    cfg.failure = FailureProcess::PerNodeWeibull { n, shape, scale_ind: scale };
    let mc = monte_carlo(&cfg, REPS, 9, THREADS);
    let model = t_final(&s, t);
    let err = rel_err(mc.makespan.mean(), model);
    assert!(
        err < 0.15,
        "Weibull sim {} vs exp model {model}: err {err}",
        mc.makespan.mean()
    );
}

#[test]
fn fig3_scenarios_validate_where_in_domain() {
    for n_nodes in [1e5, 1e6, 5e6] {
        let s = fig3_scenario(n_nodes, 5.5).expect("in domain");
        let t = t_time_opt(&s).unwrap();
        let mc = monte_carlo(&SimConfig::paper(s, t), REPS, 21, THREADS);
        let err = rel_err(mc.makespan.mean(), t_final(&s, t));
        // Smaller mu => bigger first-order error; stay within 10%.
        assert!(err < 0.10, "N={n_nodes}: err {err}");
    }
}
