//! Acceptance tests of the objective-model backend abstraction
//! (ISSUE 4):
//!
//! (a) the exact backend converges to the paper's first-order forms as
//!     failures become rare — optima and knee agree within tolerance as
//!     μ grows (property test over random scenarios);
//! (b) the documented knee drift appears in the frequent-failure
//!     regime: >5% at the paper's reference point, >40% at μ = 60;
//! (c) backend dispatch is consistent end to end: the online policy
//!     memo returns the same knees the frontier computes directly.

use ckpt_period::config::presets::fig1_scenario;
use ckpt_period::model::params::{CheckpointParams, PowerParams, Scenario};
use ckpt_period::model::{Backend, RecoveryModel};
use ckpt_period::pareto::online::knee_period;
use ckpt_period::pareto::{Frontier, KneeMethod};
use ckpt_period::prop_assert;
use ckpt_period::util::proptest::{check, Gen};
use ckpt_period::util::stats::rel_err;

const FO: Backend = Backend::FirstOrder;
const EXACT: Backend = Backend::Exact(RecoveryModel::Ideal);

#[test]
fn prop_exact_backend_converges_to_first_order_as_failures_become_rare() {
    // mu >= 2000 * (C + R + D): the truncation error of the first-order
    // forms scales like overheads/mu, so the backends' optimal periods
    // must agree to a few percent. Calibration: across the default
    // seed's 60 cases the worst drifts are 0.5% (T_Time_opt), 1.2%
    // (T_Energy_opt) and 1.9% (knee); the worst *corner* of the sampled
    // space (all overheads maxed, mu at its floor) reaches ~2.4% on the
    // energy optimum, so the bounds below hold over the whole space
    // (for replayed CKPT_PROPTEST_SEED overrides too), not just the
    // default draw.
    check("exact backend converges to first-order", 60, |g: &mut Gen| {
        let c = g.f64_in(0.5, 20.0);
        let r = g.f64_in(0.5, 20.0);
        let d = g.f64_in(0.0, 5.0);
        let omega = g.f64_in(0.0, 1.0);
        let mu = g.f64_log_in(2000.0 * (c + r + d), 1e7);
        let alpha = g.f64_in(0.1, 4.0);
        let rho = g.f64_in(1.5, 20.0);
        let ckpt = CheckpointParams::new(c, r, d, omega).unwrap();
        let power = PowerParams::from_rho(rho, alpha, 0.0).unwrap();
        let s = Scenario::new(ckpt, power, mu, 10_000.0).unwrap();

        let tt_f = FO.t_time_opt(&s).unwrap();
        let tt_e = EXACT.t_time_opt(&s).unwrap();
        prop_assert!(
            g,
            rel_err(tt_e, tt_f) < 0.03,
            "T_Time_opt: exact {tt_e} vs first-order {tt_f} (mu={mu})"
        );
        let te_f = FO.t_energy_opt(&s).unwrap();
        let te_e = EXACT.t_energy_opt(&s).unwrap();
        prop_assert!(
            g,
            rel_err(te_e, te_f) < 0.04,
            "T_Energy_opt: exact {te_e} vs first-order {te_f} (mu={mu})"
        );

        // Knees agree too wherever both frontiers have one.
        let kf = Frontier::compute(&s, 65, FO)
            .unwrap()
            .knee(KneeMethod::MaxDistanceToChord);
        let ke = Frontier::compute(&s, 65, EXACT)
            .unwrap()
            .knee(KneeMethod::MaxDistanceToChord);
        if let (Some(kf), Some(ke)) = (kf, ke) {
            prop_assert!(
                g,
                rel_err(ke.point.period, kf.point.period) < 0.04,
                "knee: exact {} vs first-order {} (mu={mu})",
                ke.point.period,
                kf.point.period
            );
        }
        Ok(())
    });
}

#[test]
fn knee_drift_exceeds_five_percent_in_the_frequent_failure_regime() {
    // The acceptance headline, through the same online-policy path the
    // adaptive controller uses. Drift grows monotonically as mu shrinks
    // along the Fig. 1 family.
    let mut last = 0.0;
    for (mu, min_drift) in [(300.0, 0.05), (120.0, 0.20), (60.0, 0.40)] {
        let s = fig1_scenario(mu, 5.5);
        let fo = knee_period(&s, KneeMethod::MaxDistanceToChord, FO).unwrap();
        let ex = knee_period(&s, KneeMethod::MaxDistanceToChord, EXACT).unwrap();
        let drift = ex / fo - 1.0;
        assert!(drift > min_drift, "mu={mu}: drift {drift} below {min_drift}");
        assert!(drift > last, "mu={mu}: drift {drift} not above {last}");
        last = drift;
    }
    // And at large mu the same path agrees within 2%.
    let s = fig1_scenario(1e5, 5.5);
    let fo = knee_period(&s, KneeMethod::MaxDistanceToChord, FO).unwrap();
    let ex = knee_period(&s, KneeMethod::MaxDistanceToChord, EXACT).unwrap();
    assert!(rel_err(ex, fo) < 0.02, "mu=1e5: {ex} vs {fo}");
}

#[test]
fn online_memo_agrees_with_direct_frontier_knees_under_the_exact_backend() {
    // fig1 parameters are quantisation fixed points, so the memoised
    // online read must equal the direct frontier computation bit for
    // bit — the determinism contract adaptive grid cells rely on.
    for mu in [300.0, 120.0, 60.0] {
        let s = fig1_scenario(mu, 5.5);
        let direct = Frontier::compute(&s, 129, EXACT)
            .unwrap()
            .knee(KneeMethod::MaxDistanceToChord)
            .unwrap()
            .point
            .period;
        let online = knee_period(&s, KneeMethod::MaxDistanceToChord, EXACT).unwrap();
        assert_eq!(online.to_bits(), direct.to_bits(), "mu={mu}");
    }
}
