//! Acceptance tests of the frontier-aware adaptive policy (ISSUE 3):
//!
//! (a) on every trade-off preset the knee policy's Monte-Carlo
//!     (time, energy) lands between the AlgoT and AlgoE endpoints under
//!     injected failures — time overhead ≤ AlgoE's, energy ≤ AlgoT's,
//!     and strictly between wherever the frontier is non-degenerate;
//! (b) the budget policies respect their ε-constraints end to end;
//! (c) adaptive results are byte-identical across thread counts, both
//!     through `adaptive_monte_carlo` directly and through
//!     `CellJob::AdaptiveRun` grid cells;
//! (d) the policy-level periods sit inside the optimal-period interval.

use ckpt_period::config::presets::tradeoff_presets;
use ckpt_period::coordinator::PeriodPolicy;
use ckpt_period::model::energy::t_energy_opt;
use ckpt_period::model::time::t_time_opt;
use ckpt_period::model::{Backend, RecoveryModel};
use ckpt_period::pareto::KneeMethod;
use ckpt_period::sim::adaptive::{
    adaptive_monte_carlo, AdaptiveMonteCarloResult, AdaptiveSimConfig,
};
use ckpt_period::sweep::GridSpec;

const REPLICATES: usize = 200;
const SEED: u64 = 2013;

const KNEE: PeriodPolicy = PeriodPolicy::Knee {
    method: KneeMethod::MaxDistanceToChord,
    backend: Backend::FirstOrder,
};
const KNEE_EXACT: PeriodPolicy = PeriodPolicy::Knee {
    method: KneeMethod::MaxDistanceToChord,
    backend: Backend::Exact(RecoveryModel::Ideal),
};

/// Same base seed for every policy: common random numbers correlate the
/// failure processes across the compared runs, so mean differences
/// reflect the policies, not sampling noise.
fn mc(s: ckpt_period::model::Scenario, policy: PeriodPolicy) -> AdaptiveMonteCarloResult {
    adaptive_monte_carlo(&AdaptiveSimConfig::paper(s, policy), REPLICATES, SEED, 8)
}

#[test]
fn a_knee_policy_lands_between_the_endpoints_on_every_preset() {
    for (label, s) in tradeoff_presets() {
        let algo_t = mc(s, PeriodPolicy::AlgoT);
        let algo_e = mc(s, PeriodPolicy::AlgoE);
        let knee = mc(s, KNEE);

        // The acceptance bound: no worse than the wrong endpoint on
        // either axis.
        assert!(
            knee.makespan.mean() <= algo_e.makespan.mean(),
            "{label}: knee makespan {} > AlgoE {}",
            knee.makespan.mean(),
            algo_e.makespan.mean()
        );
        assert!(
            knee.energy.mean() <= algo_t.energy.mean(),
            "{label}: knee energy {} > AlgoT {}",
            knee.energy.mean(),
            algo_t.energy.mean()
        );

        // Strictly between the endpoints wherever the frontier is
        // non-degenerate (it is, on every preset: the model-level knee
        // sits ≥1.2% above AlgoT in time and ≥2.2% above AlgoE in
        // energy, far beyond the Monte-Carlo standard error here).
        let tt = t_time_opt(&s).unwrap();
        let te = t_energy_opt(&s).unwrap();
        if te > tt {
            assert!(
                knee.makespan.mean() > algo_t.makespan.mean(),
                "{label}: knee makespan {} not above AlgoT {}",
                knee.makespan.mean(),
                algo_t.makespan.mean()
            );
            assert!(
                knee.energy.mean() > algo_e.energy.mean(),
                "{label}: knee energy {} not above AlgoE {}",
                knee.energy.mean(),
                algo_e.energy.mean()
            );
            let kp = knee.final_period.mean();
            assert!(kp > tt && kp < te, "{label}: knee period {kp} outside ({tt}, {te})");
        }
    }
}

#[test]
fn b_budget_policies_respect_their_constraints() {
    let (_, s) = tradeoff_presets().into_iter().next().unwrap();
    let algo_t = mc(s, PeriodPolicy::AlgoT);
    let algo_e = mc(s, PeriodPolicy::AlgoE);

    // A 5% time budget: cheaper than AlgoT in energy, and the measured
    // time overhead over AlgoT stays in the budget's neighbourhood
    // (the budget constrains the *model* makespan; Monte-Carlo noise
    // and online estimation add a little slack either way).
    let eps_t = mc(
        s,
        PeriodPolicy::EnergyBudget { max_time_overhead: 5.0, backend: Backend::FirstOrder },
    );
    assert!(eps_t.energy.mean() < algo_t.energy.mean());
    let overhead = eps_t.makespan.mean() / algo_t.makespan.mean() - 1.0;
    assert!(overhead < 0.07, "measured time overhead {overhead} far above the 5% budget");

    // The transpose: a 5% energy budget beats AlgoE on time and stays
    // near its energy bound.
    let eps_e = mc(
        s,
        PeriodPolicy::TimeBudget { max_energy_overhead: 5.0, backend: Backend::FirstOrder },
    );
    assert!(eps_e.makespan.mean() < algo_e.makespan.mean());
    let overhead = eps_e.energy.mean() / algo_e.energy.mean() - 1.0;
    assert!(overhead < 0.07, "measured energy overhead {overhead} far above the 5% budget");
}

#[test]
fn c_adaptive_results_identical_across_thread_counts() {
    let (_, s) = tradeoff_presets().into_iter().next().unwrap();
    let cfg = AdaptiveSimConfig::paper(s, KNEE);

    // Direct Monte-Carlo: serial vs pooled.
    let serial = adaptive_monte_carlo(&cfg, 64, 7, 1);
    let pooled = adaptive_monte_carlo(&cfg, 64, 7, 8);
    assert_eq!(serial.makespan.mean().to_bits(), pooled.makespan.mean().to_bits());
    assert_eq!(serial.energy.mean().to_bits(), pooled.energy.mean().to_bits());
    assert_eq!(serial.final_period.mean().to_bits(), pooled.final_period.mean().to_bits());

    // Grid cells: the pooled cell equals serial Monte-Carlo at the
    // cell's derived seed, and re-evaluation is bit-stable.
    let mut spec = GridSpec::new(42);
    spec.push_adaptive(s, KNEE, 64);
    let seed = spec.cell_seed(&spec.cells()[0]);
    let results = spec.evaluate();
    let summary = results[0].output.adaptive().expect("in domain");
    let direct = adaptive_monte_carlo(&cfg, 64, seed, 1);
    assert_eq!(summary.makespan_mean.to_bits(), direct.makespan.mean().to_bits());
    assert_eq!(summary.energy_mean.to_bits(), direct.energy.mean().to_bits());
    assert_eq!(results, spec.evaluate());
}

#[test]
fn d_policy_periods_sit_inside_the_optimal_interval() {
    for (label, s) in tradeoff_presets() {
        let tt = t_time_opt(&s).unwrap();
        let te = t_energy_opt(&s).unwrap();
        let knee = KNEE.period(&s).expect(label);
        assert!(knee > tt && knee < te, "{label}: knee {knee} outside ({tt}, {te})");
        for eps in [0.5, 2.0, 10.0] {
            let p = PeriodPolicy::EnergyBudget {
                max_time_overhead: eps,
                backend: Backend::FirstOrder,
            }
            .period(&s)
            .expect(label);
            assert!(
                (tt - 1e-9..=te + 1e-9).contains(&p),
                "{label} eps-time:{eps}: period {p} outside [{tt}, {te}]"
            );
            let p = PeriodPolicy::TimeBudget {
                max_energy_overhead: eps,
                backend: Backend::FirstOrder,
            }
            .period(&s)
            .expect(label);
            assert!(
                (tt - 1e-9..=te + 1e-9).contains(&p),
                "{label} eps-energy:{eps}: period {p} outside [{tt}, {te}]"
            );
        }
    }
}

#[test]
fn e_exact_knee_policy_runs_longer_periods_and_stays_deterministic() {
    // `simulate --policy knee --model exact` acceptance: the exact-knee
    // controller adopts a visibly longer period than the first-order
    // knee (>5% at mu=300, the knee-drift headline), lands between the
    // exact optima, and is byte-identical across thread counts.
    let (_, s) = tradeoff_presets().into_iter().next().unwrap();
    let fo = mc(s, KNEE);
    let ex = mc(s, KNEE_EXACT);
    let (fo_p, ex_p) = (fo.final_period.mean(), ex.final_period.mean());
    assert!(ex_p > fo_p * 1.05, "exact knee period {ex_p} !> first-order {fo_p}");
    let exact = Backend::Exact(RecoveryModel::Ideal);
    let tt = exact.t_time_opt(&s).unwrap();
    let te = exact.t_energy_opt(&s).unwrap();
    assert!(ex_p > tt && ex_p < te, "exact knee period {ex_p} outside ({tt}, {te})");

    // Thread-count invariance, directly and through a grid cell.
    let cfg = AdaptiveSimConfig::paper(s, KNEE_EXACT);
    let serial = adaptive_monte_carlo(&cfg, 64, 7, 1);
    let pooled = adaptive_monte_carlo(&cfg, 64, 7, 8);
    assert_eq!(serial.makespan.mean().to_bits(), pooled.makespan.mean().to_bits());
    assert_eq!(serial.energy.mean().to_bits(), pooled.energy.mean().to_bits());
    assert_eq!(serial.final_period.mean().to_bits(), pooled.final_period.mean().to_bits());
    let mut spec = GridSpec::new(42);
    spec.push_adaptive(s, KNEE_EXACT, 64);
    let seed = spec.cell_seed(&spec.cells()[0]);
    let results = spec.evaluate();
    let summary = results[0].output.adaptive().expect("in domain");
    let direct = adaptive_monte_carlo(&cfg, 64, seed, 1);
    assert_eq!(summary.makespan_mean.to_bits(), direct.makespan.mean().to_bits());
    assert_eq!(summary.energy_mean.to_bits(), direct.energy.mean().to_bits());
    // The exact and first-order knee cells must not share seeds (the
    // backend is part of the key derivation).
    let mut fo_spec = GridSpec::new(42);
    fo_spec.push_adaptive(s, KNEE, 64);
    assert_ne!(seed, fo_spec.cell_seed(&fo_spec.cells()[0]));
}
