//! Acceptance tests of the batched lockstep Monte-Carlo executor and
//! the warm-started frontier re-solves (ISSUE 10):
//!
//! (a) the batched executor is bit-identical to the retained
//!     per-replica reference loops — fixed-period (scalar and tiered)
//!     and adaptive (stationary, drifting, tiered) — at 1 and 8
//!     threads, for every batch size (property tests over random
//!     scenarios × presets × drift schedules × tier stacks);
//! (b) the per-seed decision-trace event sequences are unchanged by
//!     batching (replicates may interleave; each path's own sequence
//!     may not);
//! (c) warm-started exact-backend solves along a drift-style family
//!     sequence return exactly the hint-free exact optima on every
//!     trade-off preset.

use ckpt_period::config::presets::{drift_preset, fig1_scenario, tradeoff_presets};
use ckpt_period::coordinator::policy::PeriodPolicy;
use ckpt_period::drift::DriftProcess;
use ckpt_period::model::exact::{t_energy_opt_exact, t_time_opt_exact};
use ckpt_period::model::params::{CheckpointParams, PowerParams, Scenario};
use ckpt_period::model::{Backend, RecoveryModel};
use ckpt_period::prop_assert;
use ckpt_period::sim::adaptive::adaptive_monte_carlo_reference;
use ckpt_period::sim::batch::set_batch_size;
use ckpt_period::sim::runner::monte_carlo_reference;
use ckpt_period::sim::{adaptive_monte_carlo, monte_carlo, FailureProcess, SimConfig};
use ckpt_period::storage::TierSpec;
use ckpt_period::telemetry::trace;
use ckpt_period::util::json::parse;
use ckpt_period::util::proptest::{check, Gen};
use ckpt_period::util::stats::OnlineStats;

/// Both aggregates carry order-sensitive `OnlineStats` folds, so bit
/// equality of mean and variance per channel pins the full per-replicate
/// result stream (any reordering or value drift perturbs the fold).
fn assert_stats_eq(name: &str, a: &OnlineStats, b: &OnlineStats, ctx: &str) {
    assert_eq!(a.count(), b.count(), "{name} count ({ctx})");
    assert_eq!(a.mean().to_bits(), b.mean().to_bits(), "{name} mean ({ctx})");
    assert_eq!(
        a.variance().to_bits(),
        b.variance().to_bits(),
        "{name} variance ({ctx})"
    );
}

#[test]
fn prop_batched_fixed_executor_is_bit_identical_to_the_reference() {
    check("batched fixed-period executor matches reference", 24, |g: &mut Gen| {
        let c = g.f64_in(2.0, 15.0);
        let r = g.f64_in(2.0, 15.0);
        let d = g.f64_in(0.0, 3.0);
        let omega = g.f64_in(0.0, 1.0);
        let mu = g.f64_log_in(80.0, 2000.0);
        let ckpt = CheckpointParams::new(c, r, d, omega).unwrap();
        let power = PowerParams::from_rho(g.f64_in(1.5, 10.0), 1.0, 0.0).unwrap();
        let scenario = if g.bool() {
            // A 2-level tier stack: fast node-local front, durable back.
            let specs = [
                TierSpec::new(c * 0.2, r * 0.2, 30.0),
                TierSpec::new(c, r, 100.0),
            ];
            Scenario::with_tier_specs(ckpt, power, mu, 8_000.0, &specs).unwrap()
        } else {
            Scenario::new(ckpt, power, mu, 8_000.0).unwrap()
        };
        let period = g.f64_in(scenario.min_period() * 1.5, scenario.min_period() * 6.0);
        let failure = match g.usize_in(0, 2) {
            0 => FailureProcess::Exponential { mtbf: mu },
            1 => FailureProcess::PerNodeExponential { n: 8, mtbf_ind: mu * 8.0 },
            _ => FailureProcess::PerNodeWeibull { n: 8, shape: 0.7, scale_ind: mu * 8.0 },
        };
        let cfg = SimConfig {
            scenario,
            period,
            failure,
            failures_during_recovery: g.bool(),
        };
        let reps = g.usize_in(1, 20);
        let seed = g.usize_in(0, 1_000_000) as u64;
        set_batch_size(Some(g.usize_in(1, reps + 4)));
        let reference = monte_carlo_reference(&cfg, reps, seed, 1);
        for threads in [1, 8] {
            let batched = monte_carlo(&cfg, reps, seed, threads);
            let ctx = format!("threads={threads} reps={reps} seed={seed}");
            for (name, a, b) in [
                ("makespan", &reference.makespan, &batched.makespan),
                ("energy", &reference.energy, &batched.energy),
                ("failures", &reference.failures, &batched.failures),
                ("checkpoints", &reference.checkpoints, &batched.checkpoints),
                ("work_lost", &reference.work_lost, &batched.work_lost),
            ] {
                assert_stats_eq(name, a, b, &ctx);
            }
            prop_assert!(g, batched.replicates == reps, "replicate count ({ctx})");
        }
        set_batch_size(None);
        Ok(())
    });
}

#[test]
fn prop_batched_adaptive_executor_is_bit_identical_to_the_reference() {
    let policies = [
        PeriodPolicy::AlgoT,
        PeriodPolicy::AlgoE,
        PeriodPolicy::Daly,
        PeriodPolicy::Young,
    ];
    let drifts = ["stationary", "io-ramp", "mu-decay", "step-reconfig", "contention-burst"];
    check("batched adaptive executor matches reference", 16, |g: &mut Gen| {
        let mu = g.f64_log_in(120.0, 1200.0);
        let base = fig1_scenario(mu, g.f64_in(2.0, 9.0));
        let tiered = g.bool();
        let scenario = if tiered {
            let specs = [TierSpec::new(1.0, 1.0, 30.0), TierSpec::new(10.0, 10.0, 100.0)];
            Scenario::with_tier_specs(base.ckpt, base.power, base.mu, base.t_base, &specs)
                .unwrap()
        } else {
            base
        };
        let policy = *g.choose(&policies);
        // The drain queue has no trajectory semantics: tier stacks run
        // stationary, scalar scenarios draw any drift preset.
        let drift = if tiered { "stationary" } else { *g.choose(&drifts) };
        let process = if drift == "stationary" {
            DriftProcess::Stationary
        } else {
            drift_preset(drift).unwrap()
        };
        let cfg = ckpt_period::sim::AdaptiveSimConfig::paper_drifting(scenario, policy, process)
            .unwrap();
        let reps = g.usize_in(1, 10);
        let seed = g.usize_in(0, 1_000_000) as u64;
        set_batch_size(Some(g.usize_in(1, reps + 2)));
        let reference = adaptive_monte_carlo_reference(&cfg, reps, seed, 1);
        for threads in [1, 8] {
            let batched = adaptive_monte_carlo(&cfg, reps, seed, threads);
            let ctx = format!(
                "threads={threads} reps={reps} seed={seed} drift={drift} tiered={tiered}"
            );
            for (name, a, b) in [
                ("makespan", &reference.makespan, &batched.makespan),
                ("energy", &reference.energy, &batched.energy),
                ("failures", &reference.failures, &batched.failures),
                ("checkpoints", &reference.checkpoints, &batched.checkpoints),
                ("work_lost", &reference.work_lost, &batched.work_lost),
                ("period_updates", &reference.period_updates, &batched.period_updates),
                ("final_period", &reference.final_period, &batched.final_period),
                ("tracking_lag", &reference.tracking_lag, &batched.tracking_lag),
                ("drift_lag", &reference.drift_lag, &batched.drift_lag),
            ] {
                assert_stats_eq(name, a, b, &ctx);
            }
        }
        set_batch_size(None);
        Ok(())
    });
}

/// Lockstep batching may interleave *different* replicates' trace
/// events (each line carries its seed), but every single path's own
/// event sequence must be byte-identical to the reference loop's.
#[test]
fn batched_decision_traces_match_the_reference_per_seed() {
    // A seed range no other test uses, so concurrent tests in this
    // binary can't bleed events into the filter below.
    const BASE_SEED: u64 = 870_001;
    const REPS: usize = 6;
    let cfg = ckpt_period::sim::AdaptiveSimConfig::paper_drifting(
        fig1_scenario(300.0, 5.5),
        PeriodPolicy::AlgoT,
        drift_preset("io-ramp").unwrap(),
    )
    .unwrap();

    let dir = std::env::temp_dir().join(format!("ckpt_batch_trace_{}", std::process::id()));
    let per_seed = |path: &std::path::Path| {
        let mut by_seed: std::collections::BTreeMap<u64, Vec<String>> =
            std::collections::BTreeMap::new();
        for line in std::fs::read_to_string(path).expect("trace written").lines() {
            let doc = parse(line).unwrap_or_else(|e| panic!("bad trace line {line}: {e}"));
            let seed = doc.req_f64("seed").expect("seed") as u64;
            if (BASE_SEED..BASE_SEED + REPS as u64).contains(&seed) {
                by_seed.entry(seed).or_default().push(line.to_string());
            }
        }
        by_seed
    };

    let ref_path = dir.join("reference.jsonl");
    trace::install(&ref_path).expect("trace sink installs");
    let reference = adaptive_monte_carlo_reference(&cfg, REPS, BASE_SEED, 1);
    assert!(trace::finish());

    let batched_path = dir.join("batched.jsonl");
    set_batch_size(Some(2));
    trace::install(&batched_path).expect("trace sink installs");
    let batched = adaptive_monte_carlo(&cfg, REPS, BASE_SEED, 8);
    assert!(trace::finish());
    set_batch_size(None);

    assert_eq!(
        reference.makespan.mean().to_bits(),
        batched.makespan.mean().to_bits()
    );
    let (ref_events, batch_events) = (per_seed(&ref_path), per_seed(&batched_path));
    assert_eq!(ref_events.len(), REPS, "every path traced");
    for (seed, lines) in &ref_events {
        assert!(!lines.is_empty(), "seed {seed} traced no events");
        assert_eq!(
            Some(lines),
            batch_events.get(seed),
            "seed {seed}: per-path event sequence changed under batching"
        );
    }
    let _ = std::fs::remove_dir_all(dir);
}

/// Drift-style re-solves through the backend (which seed each other's
/// warm brackets family-by-family) return exactly the hint-free exact
/// optima — on every trade-off preset, both recovery models, walking μ
/// downward the way a drift schedule would.
#[test]
fn warm_started_exact_solves_match_cold_solves_on_every_preset() {
    for m in [RecoveryModel::Ideal, RecoveryModel::Restarting] {
        let b = Backend::Exact(m);
        for (label, s) in tradeoff_presets() {
            for factor in [1.0, 0.95, 0.9, 0.86] {
                let sf = Scenario::new(s.ckpt, s.power, s.mu * factor, s.t_base).unwrap();
                assert_eq!(
                    b.t_time_opt(&sf).expect(label).to_bits(),
                    t_time_opt_exact(&sf, m).to_bits(),
                    "{label} x{factor} time ({})",
                    b.name()
                );
                assert_eq!(
                    b.t_energy_opt(&sf).expect(label).to_bits(),
                    t_energy_opt_exact(&sf, m).to_bits(),
                    "{label} x{factor} energy ({})",
                    b.name()
                );
            }
        }
    }
}
