//! Acceptance tests of the non-stationary drift layer (ISSUE 5):
//!
//! (a) **zero regression** — with a `Stationary` drift process the
//!     adaptive simulator and `CellJob::DriftRun` grid cells are
//!     **bit-identical** to the static path, across thread counts, for
//!     every trade-off preset;
//! (b) drift runs are deterministic and byte-identical across thread
//!     counts, directly and through grid cells;
//! (c) the α × band sweep is seed-paired (common random numbers): the
//!     drift-cell seed ignores the controller knobs but not the
//!     schedule;
//! (d) the oracle twin pins the tracking metrics: zero lag for the
//!     clairvoyant period, bounded regret for the estimating
//!     controller.

use ckpt_period::config::presets::{drift_preset, tradeoff_presets};
use ckpt_period::coordinator::PeriodPolicy;
use ckpt_period::drift::{DriftProcess, DriftTargets, EnvTrajectory};
use ckpt_period::model::Backend;
use ckpt_period::pareto::KneeMethod;
use ckpt_period::sim::adaptive::{adaptive_monte_carlo, AdaptiveSimConfig, AdaptiveSimulator};
use ckpt_period::sweep::GridSpec;

const KNEE: PeriodPolicy = PeriodPolicy::Knee {
    method: KneeMethod::MaxDistanceToChord,
    backend: Backend::FirstOrder,
};

fn io_ramp() -> DriftProcess {
    drift_preset("io-ramp").expect("preset exists")
}

#[test]
fn a_stationary_trajectory_is_bit_identical_on_every_preset() {
    // The tentpole's zero-regression guarantee, per trade-off preset:
    // the drift machinery with a Stationary schedule produces the same
    // bits as the static path — for single runs, Monte-Carlo
    // aggregates at both thread counts, and grid cells.
    for (label, s) in tradeoff_presets() {
        let static_cfg = AdaptiveSimConfig::paper(s, KNEE);
        let drift_cfg =
            AdaptiveSimConfig::paper_drifting(s, KNEE, DriftProcess::Stationary).unwrap();

        // Single sample paths.
        let a = AdaptiveSimulator::new(static_cfg.clone());
        let b = AdaptiveSimulator::new(drift_cfg.clone());
        for seed in [1u64, 2013] {
            assert_eq!(a.run(seed), b.run(seed), "{label} seed={seed}");
        }

        // Monte-Carlo, serial vs pooled, static vs drifting config.
        let mc_static = adaptive_monte_carlo(&static_cfg, 24, 7, 1);
        for (what, mc) in [
            ("drift serial", adaptive_monte_carlo(&drift_cfg, 24, 7, 1)),
            ("drift pooled", adaptive_monte_carlo(&drift_cfg, 24, 7, 8)),
            ("static pooled", adaptive_monte_carlo(&static_cfg, 24, 7, 8)),
        ] {
            assert_eq!(
                mc.makespan.mean().to_bits(),
                mc_static.makespan.mean().to_bits(),
                "{label}: {what} makespan"
            );
            assert_eq!(
                mc.energy.mean().to_bits(),
                mc_static.energy.mean().to_bits(),
                "{label}: {what} energy"
            );
            assert_eq!(
                mc.final_period.mean().to_bits(),
                mc_static.final_period.mean().to_bits(),
                "{label}: {what} final period"
            );
        }

        // Grid cells: a Stationary DriftRun's adaptive half equals the
        // plain adaptive Monte-Carlo at the drift cell's own seed.
        let mut spec = GridSpec::new(42);
        spec.push_drift(s, KNEE, 24, DriftProcess::Stationary, 0.3, 0.05);
        let seed = spec.cell_seed(&spec.cells()[0]);
        let results = spec.evaluate();
        let sum = results[0].output.drift().unwrap_or_else(|| panic!("{label}: out of domain"));
        let direct = adaptive_monte_carlo(&static_cfg, 24, seed, 1);
        assert_eq!(
            sum.adaptive.makespan_mean.to_bits(),
            direct.makespan.mean().to_bits(),
            "{label}: grid cell makespan"
        );
        assert_eq!(
            sum.adaptive.energy_mean.to_bits(),
            direct.energy.mean().to_bits(),
            "{label}: grid cell energy"
        );
        assert_eq!(
            sum.adaptive.final_period_mean.to_bits(),
            direct.final_period.mean().to_bits(),
            "{label}: grid cell final period"
        );
        // Bit-stable on re-evaluation (memo) too.
        assert_eq!(results, spec.evaluate(), "{label}");
    }
}

#[test]
fn b_drift_runs_are_thread_count_invariant() {
    let (_, s) = tradeoff_presets().into_iter().next().unwrap();
    for (name, drift) in [
        ("io-ramp", io_ramp()),
        ("mu-decay", drift_preset("mu-decay").unwrap()),
        ("contention-burst", drift_preset("contention-burst").unwrap()),
    ] {
        let cfg = AdaptiveSimConfig::paper_drifting(s, KNEE, drift).unwrap();
        let serial = adaptive_monte_carlo(&cfg, 32, 7, 1);
        let pooled = adaptive_monte_carlo(&cfg, 32, 7, 8);
        assert_eq!(
            serial.makespan.mean().to_bits(),
            pooled.makespan.mean().to_bits(),
            "{name}"
        );
        assert_eq!(serial.energy.mean().to_bits(), pooled.energy.mean().to_bits(), "{name}");
        assert_eq!(
            serial.tracking_lag.mean().to_bits(),
            pooled.tracking_lag.mean().to_bits(),
            "{name}"
        );
        assert_eq!(
            serial.drift_lag.mean().to_bits(),
            pooled.drift_lag.mean().to_bits(),
            "{name}"
        );

        // And through a grid cell at its derived seed.
        let mut spec = GridSpec::new(2013);
        spec.push_drift(s, KNEE, 32, drift, 0.3, 0.05);
        let seed = spec.cell_seed(&spec.cells()[0]);
        let sum = *spec.evaluate()[0].output.drift().expect("in domain");
        let direct = adaptive_monte_carlo(&cfg, 32, seed, 1);
        assert_eq!(
            sum.adaptive.makespan_mean.to_bits(),
            direct.makespan.mean().to_bits(),
            "{name}: cell vs direct"
        );
        assert_eq!(
            sum.adaptive.tracking_lag_pct_mean.to_bits(),
            direct.tracking_lag.mean().to_bits(),
            "{name}: cell vs direct lag"
        );
    }
}

#[test]
fn c_alpha_band_sweep_is_seed_paired_but_schedules_are_not() {
    let (_, s) = tradeoff_presets().into_iter().next().unwrap();
    let seed_of = |drift, alpha, hysteresis| {
        let mut spec = GridSpec::new(5);
        spec.push_drift(s, KNEE, 16, drift, alpha, hysteresis);
        spec.cell_seed(&spec.cells()[0])
    };
    // The knob axes reuse the seed (paired comparisons)…
    let s1 = seed_of(io_ramp(), 0.05, 0.0);
    assert_eq!(s1, seed_of(io_ramp(), 0.9, 0.0));
    assert_eq!(s1, seed_of(io_ramp(), 0.05, 0.1));
    // …while the schedule is environment: a fresh seed.
    assert_ne!(s1, seed_of(io_ramp().time_scaled(4.0), 0.05, 0.0));
    // (Cache-key distinctness across the knob axes is covered by the
    // grid module's unit tests — the key is crate-private.)
    // Seed-pairing is what makes the α axis a CRN comparison: the two
    // cells below share failure randomness, so their drift-lag gap is
    // the EWMA effect, not noise.
    let run = |alpha: f64| {
        let mut cfg = AdaptiveSimConfig::paper_drifting(s, KNEE, io_ramp()).unwrap();
        cfg.alpha = alpha;
        cfg.hysteresis = 0.0;
        adaptive_monte_carlo(&cfg, 24, s1, 1)
    };
    let slow = run(0.05);
    let fast = run(0.9);
    assert!(
        slow.drift_lag.mean() > fast.drift_lag.mean(),
        "paired drift lag not ordered: {} vs {}",
        slow.drift_lag.mean(),
        fast.drift_lag.mean()
    );
    // Same environment, same seeds: the failure counts stay close (the
    // paths diverge only through the period feedback).
    let (a, b) = (slow.failures.mean(), fast.failures.mean());
    assert!((a - b).abs() / a < 0.25, "CRN failure counts far apart: {a} vs {b}");
}

#[test]
fn d_oracle_pins_the_tracking_metrics_per_family() {
    let (_, s) = tradeoff_presets().into_iter().next().unwrap();
    for (name, drift) in [
        ("io-ramp", io_ramp()),
        ("mu-decay", drift_preset("mu-decay").unwrap()),
        ("step-reconfig", drift_preset("step-reconfig").unwrap()),
    ] {
        let cfg = AdaptiveSimConfig::paper_drifting(s, KNEE, drift).unwrap();
        let mut oracle_cfg = cfg.clone();
        oracle_cfg.oracle = true;
        let adaptive = adaptive_monte_carlo(&cfg, 32, 11, 8);
        let oracle = adaptive_monte_carlo(&oracle_cfg, 32, 11, 8);
        assert!(
            oracle.tracking_lag.mean() < 1e-9,
            "{name}: oracle lag {}",
            oracle.tracking_lag.mean()
        );
        assert!(
            adaptive.tracking_lag.mean() > 1.0,
            "{name}: controller lag {} suspiciously small",
            adaptive.tracking_lag.mean()
        );
        // The paired waste gap stays in a tight band (the knee is a
        // forgiving operating point; μ-decay pays the most because the
        // estimator trails the rising failure rate).
        let regret =
            (adaptive.makespan.mean() - oracle.makespan.mean()) / s.t_base * 100.0;
        assert!((-2.0..10.0).contains(&regret), "{name}: waste regret {regret}%");
    }
}

#[test]
fn e_drift_trajectory_views_are_quantisable_like_static_scenarios() {
    // The scenario-at-time views feed the same quantised online memo
    // as static scenarios: sub-0.1% neighbours on the trajectory map
    // to the same knee period, bitwise.
    use ckpt_period::pareto::online::knee_period;
    let (_, s) = tradeoff_presets().into_iter().next().unwrap();
    let traj = EnvTrajectory::new(
        s,
        DriftProcess::Ramp {
            from_t: 0.0,
            to_t: 10_000.0,
            to: DriftTargets { c: 2.0, r: 2.0, mu: 1.0, p_io: 1.0 },
        },
    )
    .unwrap();
    let a = traj.scenario_at(5000.0);
    let b = traj.scenario_at(5001.0); // C moves by 0.01% — same quantum
    let ka = knee_period(&a, KneeMethod::MaxDistanceToChord, Backend::FirstOrder).unwrap();
    let kb = knee_period(&b, KneeMethod::MaxDistanceToChord, Backend::FirstOrder).unwrap();
    assert_eq!(ka.to_bits(), kb.to_bits());
    // A full-quantum step lands on a different knee.
    let c = traj.scenario_at(7500.0);
    let kc = knee_period(&c, KneeMethod::MaxDistanceToChord, Backend::FirstOrder).unwrap();
    assert!(kc > ka, "knee must grow with C: {kc} vs {ka}");
}
