//! Three-layer consistency: the rust `model` implementation, the Pallas
//! period-sweep kernel (compiled through XLA), and — transitively via
//! pytest — the pure-jnp oracle must all agree on `T_final`/`E_final`.

use ckpt_period::model::energy::e_final;
use ckpt_period::model::params::{CheckpointParams, PowerParams, Scenario};
use ckpt_period::model::time::t_final;
use ckpt_period::runtime::{ArtifactDir, Runtime, SweepEvaluator};
use ckpt_period::util::stats::rel_err;

fn setup() -> (Runtime, ArtifactDir) {
    let rt = Runtime::cpu().unwrap();
    let dir = ArtifactDir::open("artifacts").expect("run `make artifacts` first");
    (rt, dir)
}

fn check_scenario(evaluator: &SweepEvaluator, s: &Scenario) {
    let grid = evaluator.uniform_grid(s);
    let (tf, ef) = evaluator.eval(s, &grid).unwrap();
    let mut compared = 0;
    for (i, &t) in grid.iter().enumerate() {
        let rust_tf = t_final(s, t as f64);
        let rust_ef = e_final(s, t as f64);
        if !rust_tf.is_finite() {
            // The artifact computes in f32; domain-edge disagreement at
            // the very last grid point is acceptable.
            continue;
        }
        compared += 1;
        // f32 kernel vs f64 rust: allow 1e-3 relative.
        assert!(
            rel_err(tf[i] as f64, rust_tf) < 1e-3,
            "T_final mismatch at T={t}: xla={} rust={rust_tf}",
            tf[i]
        );
        assert!(
            rel_err(ef[i] as f64, rust_ef) < 1e-3,
            "E_final mismatch at T={t}: xla={} rust={rust_ef}",
            ef[i]
        );
    }
    assert!(compared > grid.len() / 2, "compared only {compared} points");
}

#[test]
fn sweep_kernel_matches_rust_model_fig1_point() {
    let (rt, dir) = setup();
    let evaluator = SweepEvaluator::load(&rt, &dir).unwrap();
    let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
    let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
    let s = Scenario::new(ckpt, power, 300.0, 10_000.0).unwrap();
    check_scenario(&evaluator, &s);
}

#[test]
fn sweep_kernel_matches_rust_model_across_parameters() {
    let (rt, dir) = setup();
    let evaluator = SweepEvaluator::load(&rt, &dir).unwrap();
    for (mu, rho, omega) in [
        (120.0, 1.5, 0.0),
        (300.0, 7.0, 1.0),
        (1000.0, 12.0, 0.25),
        (60.0, 3.0, 0.75),
    ] {
        let ckpt = CheckpointParams::new(5.0, 4.0, 0.5, omega).unwrap();
        let power = PowerParams::from_rho(rho, 1.0, 0.0).unwrap();
        let s = Scenario::new(ckpt, power, mu, 5000.0).unwrap();
        check_scenario(&evaluator, &s);
    }
}

#[test]
fn sweep_argmin_matches_closed_forms() {
    // The XLA-evaluated grid's argmins should bracket the closed-form
    // optima (grid resolution tolerance).
    let (rt, dir) = setup();
    let evaluator = SweepEvaluator::load(&rt, &dir).unwrap();
    let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
    let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
    let s = Scenario::new(ckpt, power, 300.0, 10_000.0).unwrap();

    let grid = evaluator.uniform_grid(&s);
    let (tf, ef) = evaluator.eval(&s, &grid).unwrap();
    let argmin = |xs: &[f32]| {
        let mut best = 0;
        for (i, &x) in xs.iter().enumerate() {
            if x < xs[best] {
                best = i;
            }
        }
        grid[best] as f64
    };
    let spacing = (grid[1] - grid[0]) as f64;
    let t_t = ckpt_period::model::t_time_opt(&s).unwrap();
    let t_e = ckpt_period::model::t_energy_opt(&s).unwrap();
    assert!(
        (argmin(&tf) - t_t).abs() <= 2.0 * spacing,
        "xla argmin {} vs Eq.1 {t_t}",
        argmin(&tf)
    );
    assert!(
        (argmin(&ef) - t_e).abs() <= 2.0 * spacing,
        "xla argmin {} vs quadratic {t_e}",
        argmin(&ef)
    );
}

#[test]
fn sweep_rejects_wrong_grid_size() {
    let (rt, dir) = setup();
    let evaluator = SweepEvaluator::load(&rt, &dir).unwrap();
    let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
    let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
    let s = Scenario::new(ckpt, power, 300.0, 10_000.0).unwrap();
    assert!(evaluator.eval(&s, &[50.0; 3]).is_err());
}
