//! Acceptance tests of the serve query protocol (ISSUE 6 satellites):
//!
//! (a) fuzz-style round-trip — arbitrary valid queries survive
//!     parse → solve → serialize → parse → solve with bit-identical
//!     answers on every field;
//! (b) malformed lines become structured per-line error records and
//!     never kill the stream or shift later line numbers — in the
//!     library and through the CLI (stdin end to end, exit 0);
//! (c) the binary wire artifact decodes bit-exactly to the answers the
//!     JSON stream reported;
//! (d) the Unix-socket mode serves a batch per connection from one
//!     long-lived process.

use std::io::Write;
use std::process::{Command, Stdio};

use ckpt_period::config::ScenarioSpec;
use ckpt_period::model::params::{CheckpointParams, PowerParams, Scenario};
use ckpt_period::model::Backend;
use ckpt_period::prop_assert;
use ckpt_period::serve::{parse_lines, solve, wire, Query};
use ckpt_period::util::json::{self, Json};
use ckpt_period::util::proptest::{check, Gen};

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_ckpt-period"))
}

/// Draw a random feasible scenario, or `None` when the draw lands
/// outside the first-order domain (the property skips those).
fn gen_scenario(g: &mut Gen) -> Option<Scenario> {
    let c = g.f64_in(1.0, 20.0);
    let r = g.f64_in(1.0, 20.0);
    let d = g.f64_in(0.1, 2.0);
    let omega = g.f64_in(0.0, 1.0);
    let mu = g.f64_log_in(60.0, 1e5);
    let rho = g.f64_in(1.5, 10.0);
    let ckpt = CheckpointParams::new(c, r, d, omega).ok()?;
    let power = PowerParams::from_rho(rho, 1.0, 0.0).ok()?;
    let s = Scenario::new(ckpt, power, mu, 10_000.0).ok()?;
    Backend::FirstOrder.t_time_opt(&s).ok()?;
    Some(s)
}

#[test]
fn a_arbitrary_valid_queries_roundtrip_bit_exactly() {
    // Exact-backend draws are rare by construction (each distinct
    // scenario pays a numeric bracketing solve before the memo kicks
    // in), first-order draws dominate.
    let models = ["first-order", "first-order", "first-order", "exact", "exact:ideal"];
    let policies = [
        "algo-t", "algo-e", "young", "daly", "knee", "knee:curvature", "eps-time:5",
        "eps-energy:7.5",
    ];
    let drifts = ["", "io-ramp", "mu-decay", "ramp:0:5000:c=1.5,io=1.2"];
    check("serve query roundtrip", 48, |g: &mut Gen| {
        let Some(s) = gen_scenario(g) else { return Ok(()) };
        let mut fields = vec![(
            "scenario",
            ScenarioSpec { scenario: s, n_nodes: None }.to_json(),
        )];
        let policy = *g.choose(&policies);
        let model = *g.choose(&models);
        fields.push(("policy", Json::Str(policy.into())));
        fields.push(("model", Json::Str(model.into())));
        let drift = *g.choose(&drifts);
        if !drift.is_empty() {
            fields.push(("drift", Json::Str(drift.into())));
            fields.push(("at", Json::Num(g.f64_in(0.0, 5000.0))));
        }
        let line = Json::obj(fields).to_string_compact();
        g.note("line", &line);
        let q = match Query::parse_line(&line) {
            Ok(q) => q,
            // A drift schedule may push the worst corner out of domain;
            // rejecting at parse time IS the contract — skip the case.
            Err(e) if e.contains("scenario/drift") => return Ok(()),
            Err(e) => {
                prop_assert!(g, false, "valid line rejected: {e}");
                unreachable!()
            }
        };
        let first = match solve(&q) {
            Ok(a) => a,
            // Budget policies can be infeasible on a random frontier;
            // an error answer is valid protocol output, not a failure.
            Err(_) => return Ok(()),
        };
        // serialize -> parse -> solve: everything must round-trip to
        // the same bits (Json prints f64 in shortest-roundtrip form).
        let reserialized = q.to_json().to_string_compact();
        g.note("reserialized", &reserialized);
        let q2 = Query::parse_line(&reserialized).expect("serialized query reparses");
        prop_assert!(g, q2.solve_key() == q.solve_key(), "solve keys diverged");
        let second = solve(&q2).expect("reparsed query solves");
        for (name, x, y) in [
            ("period", first.period, second.period),
            ("t_final", first.t_final, second.t_final),
            ("e_final", first.e_final, second.e_final),
            ("t_time_opt", first.t_time_opt, second.t_time_opt),
            ("t_energy_opt", first.t_energy_opt, second.t_energy_opt),
            ("time_overhead_pct", first.time_overhead_pct, second.time_overhead_pct),
            ("energy_gain_pct", first.energy_gain_pct, second.energy_gain_pct),
        ] {
            prop_assert!(g, x.to_bits() == y.to_bits(), "{name}: {x} != {y}");
        }
        Ok(())
    });
}

#[test]
fn b_malformed_lines_never_kill_the_stream() {
    check("malformed lines are per-line records", 64, |g: &mut Gen| {
        // Interleave good lines with random garbage; positions must be
        // preserved exactly and every good line must still parse.
        let garbage = [
            "{",
            "]",
            "null",
            "42",
            "\"scenario\"",
            r#"{"scenario": "no-such-preset"}"#,
            r#"{"scenario": "fig1-rho5.5", "polcy": "knee"}"#,
            r#"{"scenario": "fig1-rho5.5", "at": "soon"}"#,
            "\u{7f}binary\u{0}junk",
        ];
        let n = g.usize_in(2, 12);
        let mut input = String::new();
        let mut want_good = Vec::new();
        let mut want_bad = Vec::new();
        for i in 1..=n {
            if g.bool() {
                input.push_str(r#"{"scenario": "fig1-rho5.5"}"#);
                want_good.push(i);
            } else {
                input.push_str(g.choose(&garbage));
                want_bad.push(i);
            }
            input.push('\n');
        }
        let (queries, errors) = parse_lines(&input);
        let got_good: Vec<usize> = queries.iter().map(|(l, _)| *l).collect();
        let got_bad: Vec<usize> = errors.iter().map(|e| e.line).collect();
        prop_assert!(g, got_good == want_good, "good lines {got_good:?} != {want_good:?}");
        prop_assert!(g, got_bad == want_bad, "error lines {got_bad:?} != {want_bad:?}");
        for e in &errors {
            prop_assert!(g, !e.error.is_empty(), "empty error message at line {}", e.line);
        }
        Ok(())
    });
}

#[test]
fn c_cli_stdin_stream_answers_good_lines_and_records_bad_ones() {
    let input = concat!(
        "{\"id\": \"a\", \"scenario\": \"fig1-rho5.5\"}\n",
        "this is not json\n",
        "{\"id\": \"b\", \"scenario\": \"fig1-rho7\", \"policy\": \"algo-t\"}\n",
        "\n",
        "{\"id\": \"c\", \"scenario\": \"fig1-rho5.5\", \"drift\": \"io-ramp\", \"at\": 2500}\n",
    );
    let mut child = bin()
        .args(["batch"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("binary runs");
    child.stdin.take().unwrap().write_all(input.as_bytes()).unwrap();
    let out = child.wait_with_output().unwrap();
    // Malformed lines must NOT fail the process.
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8(out.stdout).unwrap();
    let stderr = String::from_utf8(out.stderr).unwrap();

    // stdout: exactly the three answers, in input order, parseable JSON.
    let answers: Vec<Json> =
        stdout.lines().map(|l| json::parse(l).expect("answer line is JSON")).collect();
    assert_eq!(answers.len(), 3, "{stdout}");
    let field = |j: &Json, k: &str| j.get(k).and_then(|v| v.as_str().map(String::from));
    assert_eq!(field(&answers[0], "id").as_deref(), Some("a"));
    assert_eq!(field(&answers[1], "id").as_deref(), Some("b"));
    assert_eq!(field(&answers[2], "id").as_deref(), Some("c"));
    assert_eq!(answers[0].req_f64("line").unwrap(), 1.0);
    assert_eq!(answers[1].req_f64("line").unwrap(), 3.0);
    assert_eq!(answers[2].req_f64("line").unwrap(), 5.0);
    assert_eq!(field(&answers[1], "policy").as_deref(), Some("algo-t"));
    assert_eq!(field(&answers[2], "drift").as_deref(), Some("io-ramp"));
    for a in &answers {
        assert!(a.req_f64("period_min").unwrap() > 0.0, "{a:?}");
        assert!(a.req_f64("makespan_min").unwrap() > 0.0, "{a:?}");
        assert!(a.req_f64("energy_mW_min").unwrap() > 0.0, "{a:?}");
    }

    // stderr: the line-2 error record plus the summary.
    let rec = stderr
        .lines()
        .find(|l| l.starts_with('{'))
        .expect("error record on stderr");
    let rec = json::parse(rec).unwrap();
    assert_eq!(rec.req_f64("line").unwrap(), 2.0);
    assert!(!rec.req_str("error").unwrap().is_empty());
    assert!(
        stderr.contains("answered 3 queries (3 unique solves), 1 errors"),
        "{stderr}"
    );
}

#[test]
fn d_binary_artifact_decodes_to_the_same_bits() {
    let dir = std::env::temp_dir().join("ckpt_serve_protocol");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let in_path = dir.join("queries.jsonl");
    let bin_path = dir.join("answers.bin");
    let lines = [
        r#"{"scenario": "fig1-rho5.5"}"#,
        r#"{"scenario": "beta-heavy", "policy": "eps-time:5"}"#,
        "not json at all",
        r#"{"scenario": "fig1-rho5.5"}"#,
    ];
    std::fs::write(&in_path, lines.join("\n")).unwrap();
    let out = bin()
        .args(["batch", "--in", in_path.to_str().unwrap(), "--bin-out", bin_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // The wire artifact holds the *parsed* queries' answers (3 records:
    // the malformed line never reaches the solver).
    let buf = std::fs::read(&bin_path).unwrap();
    let decoded = wire::decode(&buf).expect("valid CKPTSRV1 buffer");
    assert_eq!(decoded.len(), 3);
    let solved: Vec<_> = [lines[0], lines[1], lines[3]]
        .iter()
        .map(|l| solve(&Query::parse_line(l).unwrap()).unwrap())
        .collect();
    for (i, (got, want)) in decoded.iter().zip(&solved).enumerate() {
        let got = got.expect("ok record");
        assert_eq!(got.period.to_bits(), want.period.to_bits(), "record {i}");
        assert_eq!(got.t_final.to_bits(), want.t_final.to_bits(), "record {i}");
        assert_eq!(got.e_final.to_bits(), want.e_final.to_bits(), "record {i}");
    }
    // Duplicates answer identically through the dedup path.
    assert_eq!(decoded[0], decoded[2]);
    let _ = std::fs::remove_dir_all(dir);
}

#[cfg(unix)]
#[test]
fn e_unix_socket_serves_a_batch_per_connection() {
    use std::io::Read;
    use std::os::unix::net::UnixStream;

    let sock = std::env::temp_dir().join(format!("ckpt_serve_{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&sock);
    let mut server = bin()
        .args(["batch", "--socket", sock.to_str().unwrap()])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("server starts");

    // Wait for the listener to come up.
    let mut stream = None;
    for _ in 0..100 {
        match UnixStream::connect(&sock) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    }
    let mut stream = stream.expect("socket came up");

    let batch = "{\"scenario\": \"fig1-rho5.5\"}\nbroken line\n{\"scenario\": \"fig1-rho7\"}\n";
    stream.write_all(batch.as_bytes()).unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut reply = String::new();
    stream.read_to_string(&mut reply).unwrap();
    server.kill().ok();
    server.wait().ok();
    let _ = std::fs::remove_file(&sock);

    // Answers and the error record share the stream, ordered by line;
    // error records are the objects carrying an `error` key.
    let docs: Vec<Json> = reply.lines().map(|l| json::parse(l).expect("json line")).collect();
    assert_eq!(docs.len(), 3, "{reply}");
    assert_eq!(docs[0].req_f64("line").unwrap(), 1.0);
    assert_eq!(docs[1].req_f64("line").unwrap(), 2.0);
    assert_eq!(docs[2].req_f64("line").unwrap(), 3.0);
    assert!(docs[0].get("error").is_none() && docs[0].req_f64("period_min").unwrap() > 0.0);
    assert!(docs[1].get("error").is_some(), "{reply}");
    assert!(docs[2].get("error").is_none() && docs[2].req_f64("period_min").unwrap() > 0.0);
}
