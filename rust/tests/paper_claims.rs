//! T-headline (DESIGN.md §4): the paper's §4–§5 claims, asserted
//! against the full figure series.

use ckpt_period::figures::{fig1, fig2, fig3, headline};

#[test]
fn fig1_curves_have_paper_shape() {
    let pts = fig1::series(&fig1::rho_grid(60));
    // Four curves x 60 points.
    assert_eq!(pts.len(), 240);
    // At rho = 1 both strategies nearly coincide.
    for &mu in &fig1::MUS {
        let p0 = pts.iter().find(|p| p.mu == mu && p.rho == 1.0).unwrap();
        assert!(p0.energy_ratio < 1.02, "mu={mu}: {}", p0.energy_ratio);
    }
    // Energy ratio grows along rho; time ratio stays comparatively flat.
    let p_hi = pts.iter().find(|p| p.mu == 300.0 && p.rho > 19.5).unwrap();
    assert!(p_hi.energy_ratio > 1.3, "{}", p_hi.energy_ratio);
    assert!(p_hi.time_ratio < p_hi.energy_ratio);
}

#[test]
fn fig1_arrow_points_match_conclusion() {
    // §5: "with current values, we can save more than 20% of energy with
    // an MTBF of 300 min, at the price of an increase of 10% in the
    // execution time". Our exact optima give 19-26% across the rho
    // arrows at ~8-11% time cost (see EXPERIMENTS.md).
    let pts = fig1::series(&fig1::RHO_ARROWS);
    let at = |mu: f64, rho: f64| {
        pts.iter().find(|p| p.mu == mu && p.rho == rho).copied().unwrap()
    };
    let p55 = at(300.0, 5.5);
    let gain55 = (1.0 - 1.0 / p55.energy_ratio) * 100.0;
    assert!(gain55 > 15.0, "rho=5.5 gain {gain55}%");
    assert!((p55.time_ratio - 1.0) * 100.0 < 15.0);

    let p7 = at(300.0, 7.0);
    let gain7 = (1.0 - 1.0 / p7.energy_ratio) * 100.0;
    assert!(gain7 > 20.0, "rho=7 gain {gain7}%");
    assert!(gain7 > gain55);
}

#[test]
fn fig2_surface_consistent_with_fig1_slices() {
    let rhos = fig1::rho_grid(20);
    let cells = fig2::grid(&[300.0], &rhos);
    let line = fig1::series(&rhos);
    for (c, p) in cells.iter().zip(line.iter().filter(|p| p.mu == 300.0)) {
        assert!((c.energy_ratio - p.energy_ratio).abs() < 1e-12);
        assert!((c.time_ratio - p.time_ratio).abs() < 1e-12);
    }
}

#[test]
fn fig3_both_panels_peak_then_converge() {
    for (rho, min_peak_gain) in [(5.5, 15.0), (7.0, 20.0)] {
        let pts = fig3::series(rho, &fig3::node_grid(80));
        let (gain, at) = fig3::peak_energy_gain(&pts);
        assert!(gain > min_peak_gain, "rho={rho}: peak {gain}%");
        assert!((1e5..1e8).contains(&at), "rho={rho}: peak at {at}");
        // Tail converges to 1 (clamped regime).
        let last = pts.last().unwrap();
        assert!(last.energy_ratio < 1.01 && last.time_ratio < 1.01);
        // Head (small N, huge mu) has positive but sub-peak gain.
        let first = pts.first().unwrap();
        let first_gain = (1.0 - 1.0 / first.energy_ratio) * 100.0;
        assert!(first_gain > 0.0 && first_gain < gain);
    }
}

#[test]
fn headline_numbers_summary() {
    let h = headline::compute();
    // Energy gain exceeds time cost everywhere the paper quotes numbers.
    assert!(h.energy_gain_mu300_rho55_pct > h.time_overhead_mu300_rho55_pct);
    assert!(h.energy_gain_mu300_rho7_pct > h.time_overhead_mu300_rho7_pct);
    assert!(h.fig3_peak_energy_gain_pct > h.fig3_time_overhead_at_peak_pct);
    assert!(h.fig3_peak_in_expected_band);
}
