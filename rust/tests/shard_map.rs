//! Concurrency semantics of the sharded map behind every process-wide
//! cache (ISSUE 9 satellite). The unit tests in `util::shard` cover the
//! single-threaded policy mechanics; these tests race real thread
//! counts (1 vs 8) over overlapping keys and assert the contract the
//! caches depend on:
//!
//! * reads stay bit-identical to the pure function of the key being
//!   cached, at any thread count and interleaving;
//! * the memo counting protocol (`get` / `count_miss` /
//!   `insert_if_absent`) resolves every operation to exactly one
//!   hit-or-miss event, and the per-shard counters sum exactly to the
//!   aggregates;
//! * `insert_if_absent` is first-writer-wins: racing writers all
//!   observe the one stored value;
//! * capacity bounds hold under concurrent inserts in both overflow
//!   modes (the semantics `tests/sweep_cache.rs` exercises through the
//!   grid cache).

use std::sync::Barrier;

use ckpt_period::util::shard::{ShardedMap, N_SHARDS};

/// The pure function of the key these tests cache — any deterministic
/// f64-valued function works; the assertions are on exact bits.
fn value_of(k: u64) -> f64 {
    (k as f64).sqrt() * 3.0 + k as f64 / 7.0
}

/// Run the memo protocol over `keys` overlapping keys from `threads`
/// threads (each thread visits every key once, in a thread-specific
/// rotation so the interleavings differ), asserting every read is
/// bit-identical to [`value_of`]. Returns the map for counter checks.
fn run_memo(threads: u64, keys: u64) -> ShardedMap<u64, f64> {
    let map: ShardedMap<u64, f64> = ShardedMap::clearing(1 << 14);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let map = &map;
            scope.spawn(move || {
                for i in 0..keys {
                    let k = (i + t * 17) % keys;
                    let v = match map.get(&k) {
                        Some(v) => v,
                        None => {
                            let computed = value_of(k);
                            map.count_miss(&k);
                            map.insert_if_absent(k, computed)
                        }
                    };
                    assert_eq!(v.to_bits(), value_of(k).to_bits(), "key {k} perturbed");
                }
            });
        }
    });
    map
}

#[test]
fn memo_protocol_counts_exactly_one_event_per_lookup_at_any_thread_count() {
    const KEYS: u64 = 512;
    for threads in [1u64, 8] {
        let map = run_memo(threads, KEYS);
        let (hits, misses) = map.stats();
        // Every operation is either a counted hit or a compute that
        // counted one miss — no lookup is dropped or double-counted,
        // however the 8 threads interleave.
        assert_eq!(
            hits + misses,
            threads * KEYS,
            "{threads} thread(s): {hits} hits + {misses} misses"
        );
        // Every key was computed at least once, and duplicated computes
        // can only come from racing threads.
        assert!(misses >= KEYS, "{threads} thread(s): only {misses} misses");
        if threads == 1 {
            assert_eq!((hits, misses), (0, KEYS), "single thread never races");
        }
        // First-writer-wins keeps one entry per key regardless of races.
        assert_eq!(map.len(), KEYS as usize);
        // Per-shard counters sum exactly to the aggregates.
        let stats = map.shard_stats();
        assert_eq!(stats.len(), N_SHARDS);
        let shard_hits: u64 = stats.iter().map(|(h, _)| h).sum();
        let shard_misses: u64 = stats.iter().map(|(_, m)| m).sum();
        assert_eq!((shard_hits, shard_misses), (hits, misses));
        assert_eq!(map.shard_entries().iter().sum::<usize>(), map.len());
    }
}

#[test]
fn shard_assignment_is_independent_of_thread_count() {
    const KEYS: u64 = 512;
    // The key→shard hash is fixed-key, so the occupancy profile of the
    // same key set must be identical however many threads filled it.
    let serial = run_memo(1, KEYS);
    let racing = run_memo(8, KEYS);
    assert_eq!(serial.shard_entries(), racing.shard_entries());
}

#[test]
fn racing_inserts_are_first_writer_wins() {
    const RACERS: usize = 8;
    let map: ShardedMap<u64, f64> = ShardedMap::clearing(64);
    let barrier = Barrier::new(RACERS);
    // Deliberately distinct values per racer (the caches only ever
    // store pure functions of the key; this isolates the mechanism):
    // whoever lands first, everyone must observe the same stored value.
    let observed: Vec<f64> = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for t in 0..RACERS {
            let (map, barrier) = (&map, &barrier);
            joins.push(scope.spawn(move || {
                barrier.wait();
                map.insert_if_absent(7, 1000.0 + t as f64)
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    let stored = map.get(&7).expect("key present");
    for v in &observed {
        assert_eq!(v.to_bits(), stored.to_bits(), "a racer saw a losing value");
    }
    assert_eq!(map.len(), 1);
}

#[test]
fn capacity_bounds_hold_under_concurrent_inserts() {
    // FIFO mode: 8 threads push 800 distinct keys through capacity 64;
    // quarter-eviction must keep the bound the whole way.
    let fifo: ShardedMap<u64, f64> = ShardedMap::fifo(64);
    std::thread::scope(|scope| {
        for t in 0..8u64 {
            let fifo = &fifo;
            scope.spawn(move || {
                for i in 0..100u64 {
                    let k = t * 1000 + i;
                    fifo.insert_if_absent(k, value_of(k));
                    assert!(fifo.len() <= 64, "fifo bound broken at {} entries", fifo.len());
                }
            });
        }
    });
    assert!(fifo.evictions() >= 1, "800 inserts through capacity 64 never evicted");
    assert!(fifo.len() <= 64 && !fifo.is_empty());
    // Shrinking evicts immediately; survivors still read back pure.
    fifo.set_capacity(8);
    assert!(fifo.len() <= 8, "shrink left {} entries", fifo.len());
    fifo.set_capacity(fifo.default_capacity());

    // Clearing mode: the wholesale clear keeps the same bound.
    let clearing: ShardedMap<u64, f64> = ShardedMap::clearing(64);
    std::thread::scope(|scope| {
        for t in 0..8u64 {
            let clearing = &clearing;
            scope.spawn(move || {
                for i in 0..100u64 {
                    let k = t * 1000 + i;
                    let v = clearing.insert_if_absent(k, value_of(k));
                    assert_eq!(v.to_bits(), value_of(k).to_bits());
                }
            });
        }
    });
    assert!(clearing.clears() >= 1, "800 inserts through capacity 64 never cleared");
    assert!(clearing.len() <= 64 + 8, "clear failed to bound the map: {}", clearing.len());
}
