//! Integration: artifacts → PJRT → training steps. Requires
//! `make artifacts` to have populated `artifacts/` (the Makefile `test`
//! target guarantees the ordering).

use ckpt_period::runtime::{ArtifactDir, Runtime};
use ckpt_period::workload::{TrainSession, TrainState};

fn artifacts() -> ArtifactDir {
    ArtifactDir::open("artifacts").expect("run `make artifacts` before `cargo test`")
}

#[test]
fn artifact_meta_matches_design() {
    let dir = artifacts();
    assert_eq!(dir.batch, 8);
    assert_eq!(dir.seq, 64);
    assert_eq!(dir.vocab, 256);
    assert_eq!(dir.n_params, 470_784);
    assert_eq!(dir.sweep_grid_n, 1024);
    // Manifest spot checks.
    let embed = dir.entry("embed").unwrap();
    assert_eq!(embed.shape, vec![256, 128]);
    assert_eq!(embed.offset, 0);
    assert!(dir.entry("l1.wmlp2").is_some());
    assert!(dir.entry("w_logits").is_some());
}

#[test]
fn initial_params_are_finite_and_structured() {
    let dir = artifacts();
    let theta = dir.initial_params().unwrap();
    assert_eq!(theta.len(), dir.n_params);
    assert!(theta.iter().all(|x| x.is_finite()));
    // LN gains initialised to 1.
    let ln = dir.entry("l0.ln1_g").unwrap();
    assert!(theta[ln.offset..ln.offset + ln.len()].iter().all(|&x| x == 1.0));
    // Biases to 0.
    let b = dir.entry("l0.bqkv").unwrap();
    assert!(theta[b.offset..b.offset + b.len()].iter().all(|&x| x == 0.0));
}

#[test]
fn train_step_executes_and_learns() {
    let rt = Runtime::cpu().unwrap();
    let dir = artifacts();
    let session = TrainSession::new(&rt, &dir, 42).unwrap();
    let mut state = TrainState::initial(&dir).unwrap();

    let first = session.step(&mut state).unwrap();
    // Initial loss ~ ln(256) = 5.55 for byte-level uniform.
    assert!((first - (256f32).ln()).abs() < 0.7, "first loss {first}");

    let mut last = first;
    for _ in 0..14 {
        last = session.step(&mut state).unwrap();
    }
    assert_eq!(state.step, 15.0);
    assert_eq!(state.next_batch, 15);
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    // Adam moments became non-zero.
    assert!(state.m.iter().any(|&x| x != 0.0));
    assert!(state.v.iter().any(|&x| x != 0.0));
}

#[test]
fn train_step_is_deterministic() {
    let rt = Runtime::cpu().unwrap();
    let dir = artifacts();
    let session = TrainSession::new(&rt, &dir, 7).unwrap();
    let mut a = TrainState::initial(&dir).unwrap();
    let mut b = TrainState::initial(&dir).unwrap();
    let la = session.step(&mut a).unwrap();
    let lb = session.step(&mut b).unwrap();
    assert_eq!(la, lb);
    assert_eq!(a.theta, b.theta);
    assert_eq!(a.m, b.m);
}

#[test]
fn eval_loss_consistent_with_training_signal() {
    let rt = Runtime::cpu().unwrap();
    let dir = artifacts();
    let session = TrainSession::new(&rt, &dir, 3).unwrap();
    let state = TrainState::initial(&dir).unwrap();
    let e0 = session.eval(&state, 0).unwrap();
    assert!(e0.is_finite() && e0 > 0.0);
    // Same batch, same params => same loss.
    assert_eq!(e0, session.eval(&state, 0).unwrap());
    // Different batch => (almost surely) different loss.
    assert_ne!(e0, session.eval(&state, 1).unwrap());
}

#[test]
fn resume_from_cloned_state_matches_continuous_run() {
    // The checkpoint/restore correctness core: cloning the full state
    // and continuing must reproduce the continuous trajectory exactly.
    let rt = Runtime::cpu().unwrap();
    let dir = artifacts();
    let session = TrainSession::new(&rt, &dir, 11).unwrap();

    let mut continuous = TrainState::initial(&dir).unwrap();
    for _ in 0..4 {
        session.step(&mut continuous).unwrap();
    }
    let snapshot = continuous.clone();
    let mut more = Vec::new();
    let mut cont = continuous;
    for _ in 0..3 {
        more.push(session.step(&mut cont).unwrap());
    }

    let mut resumed = snapshot;
    let mut replay = Vec::new();
    for _ in 0..3 {
        replay.push(session.step(&mut resumed).unwrap());
    }
    assert_eq!(more, replay);
    assert_eq!(cont.theta, resumed.theta);
    assert_eq!(cont.step, resumed.step);
}
