//! Integration tests of the batched scenario-grid engine: determinism,
//! memoisation, figure-path equivalence, and the new scenario families
//! end to end.

use ckpt_period::config::presets::{
    fig1_scenario, io_contention_scenario, two_level_scenario, weibull_platform_scenario,
};
use ckpt_period::figures::{ablations, fig1, fig2};
use ckpt_period::model::ratios::compare;
use ckpt_period::model::{t_time_opt, time::t_final};
use ckpt_period::sweep::{cache, Cell, CellJob, GridSpec};
use ckpt_period::util::pool::ThreadPool;

#[test]
fn figure_series_equal_direct_model_evaluation() {
    // The rewiring must be observationally identical to calling
    // `compare` per point.
    let rhos = fig1::rho_grid(12);
    let pts = fig1::series(&rhos);
    for p in &pts {
        let direct = compare(&fig1_scenario(p.mu, p.rho)).unwrap();
        assert_eq!(p.time_ratio.to_bits(), direct.time_ratio().to_bits());
        assert_eq!(p.energy_ratio.to_bits(), direct.energy_ratio().to_bits());
        assert_eq!(p.t_time.to_bits(), direct.t_time.to_bits());
    }
    // fig2's mu=300 row equals the fig1 slice (also checked by
    // paper_claims; repeated here against the engine's cache path).
    let cells = fig2::grid(&[300.0], &rhos);
    for (c, p) in cells.iter().zip(pts.iter().filter(|p| p.mu == 300.0)) {
        assert_eq!(c.energy_ratio.to_bits(), p.energy_ratio.to_bits());
    }
}

#[test]
fn evaluate_is_deterministic_and_cache_transparent() {
    let scenarios: Vec<_> = [60.0, 120.0, 300.0]
        .into_iter()
        .flat_map(|mu| [2.0, 5.5, 7.0].into_iter().map(move |rho| fig1_scenario(mu, rho)))
        .collect();
    let mut spec = GridSpec::new(42);
    for s in &scenarios {
        spec.push_compare(*s);
        let t = t_time_opt(s).unwrap();
        spec.push_sim(*s, t, 40);
    }
    // Cached and uncached evaluation agree exactly.
    let uncached = spec.clone().without_cache().evaluate();
    let cached_cold = spec.evaluate();
    let cached_warm = spec.evaluate();
    assert_eq!(uncached, cached_cold);
    assert_eq!(cached_cold, cached_warm);
}

#[test]
fn cache_survives_grid_reordering() {
    cache::clear();
    let s = fig1_scenario(300.0, 5.5);
    let t = t_time_opt(&s).unwrap();
    let mut a = GridSpec::new(7);
    a.push_sim(s, t, 32).push_compare(s);
    let ra = a.evaluate();

    let (h_before, _) = cache::stats();
    let mut b = GridSpec::new(7);
    b.push_compare(s).push_sim(s, t, 32);
    let rb = b.evaluate();
    let (h_after, _) = cache::stats();
    // Hit counters are global; other concurrent tests may add hits, but
    // our two re-ordered cells must account for at least two of them.
    assert!(h_after - h_before >= 2, "expected cache hits for reordered cells");
    // Same cells, same outputs, independent of position.
    assert_eq!(ra[0].output, rb[1].output);
    assert_eq!(ra[1].output, rb[0].output);
}

#[test]
fn new_scenario_families_flow_through_the_engine() {
    // One declarative batch mixing all three new preset families.
    let mut spec = GridSpec::new(11);
    let io = io_contention_scenario(300.0, 5.5, 0.75).unwrap();
    let two = two_level_scenario(300.0, 5.5, 1.0, 10.0, 10).unwrap();
    let (wb_s, wb_proc) = weibull_platform_scenario(1e6, 5.5, 0.7).unwrap();
    spec.push_compare(io);
    spec.push_compare(two);
    let wb_t = t_time_opt(&wb_s).unwrap();
    spec.push(Cell {
        scenario: wb_s,
        failure: Some(wb_proc),
        job: CellJob::Sim { period: wb_t, replicates: 60, failures_during_recovery: true },
    });
    let results = spec.without_cache().evaluate();

    let io_cmp = results[0].output.comparison().expect("io-contention in domain");
    let two_cmp = results[1].output.comparison().expect("two-level in domain");
    // Costlier I/O (contention) widens AlgoE's gain vs the cheap-average
    // two-level store.
    assert!(io_cmp.energy_ratio() > two_cmp.energy_ratio());
    let wb = results[2].output.sim().expect("weibull sim");
    assert!(wb.makespan_mean > 0.0 && wb.failures_mean > 0.0);
    let model = t_final(&wb_s, wb_t);
    assert!((wb.makespan_mean - model).abs() / model < 0.25);
}

#[test]
fn weibull_ablation_exercises_preset_and_is_deterministic() {
    let rows = ablations::weibull_robustness(&[0.7], &[1e5, 1e6], 5.5, 60);
    assert_eq!(rows.len(), 2);
    let again = ablations::weibull_robustness(&[0.7], &[1e5, 1e6], 5.5, 60);
    for (a, b) in rows.iter().zip(&again) {
        assert_eq!(a.sim_makespan.to_bits(), b.sim_makespan.to_bits());
        assert!(a.rel_err < 0.25, "{a:?}");
    }
}

#[test]
fn engine_usable_from_many_threads_at_once() {
    // Figure/CLI callers may overlap (e.g. tests run concurrently); the
    // global pool serialises batches without deadlock and results stay
    // correct per caller.
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for k in 0..4u64 {
            joins.push(scope.spawn(move || {
                let s = fig1_scenario(300.0, 2.0 + k as f64);
                let spec = GridSpec::compare_all([s], k).without_cache();
                let out = spec.evaluate();
                let cmp = out[0].output.comparison().unwrap();
                let direct = compare(&s).unwrap();
                assert_eq!(cmp.t_energy.to_bits(), direct.t_energy.to_bits());
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    });
    // Sanity: the global pool is constructible and reports a size (zero
    // workers is legal — the submitter computes inline).
    let _ = ThreadPool::global().n_workers();
}
