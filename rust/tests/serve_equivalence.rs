//! ISSUE 6 acceptance gate: the batch engine is a pure reordering of
//! sequential solves. A shuffled 1k-query vector full of duplicates,
//! answered through dedup + the work-stealing pool, must be
//! bit-identical to one-at-a-time `PeriodPolicy::period` calls — at 1
//! and 8 pool participants, with and without the answer cache. This
//! extends the CRN/determinism contract of `tests/drift_tracking.rs`
//! from grid cells to the serve path.

use ckpt_period::config::presets::{drift_preset, tradeoff_presets};
use ckpt_period::coordinator::PeriodPolicy;
use ckpt_period::drift::DriftProcess;
use ckpt_period::model::Backend;
use ckpt_period::serve::{solve, Answer, BatchEngine, Query};
use ckpt_period::util::pool::ThreadPool;
use ckpt_period::util::rng::Pcg64;

/// The distinct (scenario × policy × backend × drift × at) combos the
/// 1k vector is drawn from. Exact-backend combos are kept to the knee
/// (one numeric bracketing per preset) so the test stays fast.
fn combos() -> Vec<Query> {
    let policies = [
        "algo-t",
        "algo-e",
        "young",
        "daly",
        "fixed:37.5",
        "knee",
        "knee:curvature",
        "eps-time:5",
        "eps-energy:5",
    ];
    let drifts: [(DriftProcess, &[f64]); 3] = [
        (DriftProcess::Stationary, &[0.0]),
        (drift_preset("io-ramp").unwrap(), &[0.0, 2500.0, 5000.0]),
        (drift_preset("mu-decay").unwrap(), &[1000.0]),
    ];
    let mut out = Vec::new();
    for (_, s) in tradeoff_presets() {
        for raw in policies {
            let policy = PeriodPolicy::parse(raw).unwrap();
            for (drift, ats) in &drifts {
                for &at in *ats {
                    let mut q = Query::new(s, policy, Backend::FirstOrder);
                    q.drift = *drift;
                    q.at = at;
                    out.push(q);
                }
            }
        }
        // One exact-backend combo per preset, stationary.
        out.push(Query::new(
            s,
            PeriodPolicy::parse("knee").unwrap(),
            Backend::parse("exact").unwrap(),
        ));
    }
    // Drop the rare drift × preset corner that leaves the feasible
    // domain: the equivalence gate wants a fully solvable vector (error
    // scatter has its own test in the engine's unit suite).
    out.retain(|q| solve(q).is_ok());
    out
}

/// Deterministic Fisher–Yates expansion: 1000 draws with duplicates.
fn shuffled_vector(combos: &[Query], n: usize, seed: u64) -> Vec<Query> {
    let mut rng = Pcg64::new(seed, 0);
    let mut v: Vec<Query> =
        (0..n).map(|_| combos[rng.below(combos.len() as u64) as usize].clone()).collect();
    for i in (1..v.len()).rev() {
        let j = rng.below((i + 1) as u64) as usize;
        v.swap(i, j);
    }
    v
}

fn assert_bits_eq(a: &Answer, b: &Answer, what: &str) {
    for (name, x, y) in [
        ("period", a.period, b.period),
        ("t_final", a.t_final, b.t_final),
        ("e_final", a.e_final, b.e_final),
        ("t_time_opt", a.t_time_opt, b.t_time_opt),
        ("t_energy_opt", a.t_energy_opt, b.t_energy_opt),
        ("time_overhead_pct", a.time_overhead_pct, b.time_overhead_pct),
        ("energy_gain_pct", a.energy_gain_pct, b.energy_gain_pct),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: {name} {x} != {y}");
    }
}

#[test]
fn batch_answers_are_bit_identical_to_sequential_policy_calls() {
    let combos = combos();
    assert!(combos.len() >= 40, "combo pool too small: {}", combos.len());
    let queries = shuffled_vector(&combos, 1000, 2013);

    // Sequential reference: one PeriodPolicy::period call per query on
    // its effective (drift-advanced) scenario, no batch machinery.
    let reference: Vec<Result<Answer, _>> = queries.iter().map(solve).collect();
    for (q, r) in queries.iter().zip(&reference) {
        let s = q.effective_scenario().expect("combos stay in domain");
        let direct = q.policy.period(&s).expect("combos are solvable");
        let a = r.as_ref().expect("combos are solvable");
        assert_eq!(a.period.to_bits(), direct.to_bits(), "solve vs direct policy call");
    }

    // Batch at 1 and 8 participants, cache off then on: every variant
    // must reproduce the sequential bits slot for slot.
    let serial_pool = ThreadPool::new(0);
    let wide_pool = ThreadPool::new(7);
    for (what, answers) in [
        ("1-thread uncached", BatchEngine::without_cache().answer_all_on(&serial_pool, &queries)),
        ("8-thread uncached", BatchEngine::without_cache().answer_all_on(&wide_pool, &queries)),
        ("1-thread cached", BatchEngine::new().answer_all_on(&serial_pool, &queries)),
        ("8-thread cached", BatchEngine::new().answer_all_on(&wide_pool, &queries)),
    ] {
        assert_eq!(answers.len(), queries.len(), "{what}");
        for (i, (got, want)) in answers.iter().zip(&reference).enumerate() {
            let got = got.as_ref().expect("batch answer ok");
            let want = want.as_ref().unwrap();
            assert_bits_eq(got, want, &format!("{what} slot {i}"));
        }
    }

    // Sanity on the dedup premise: far fewer unique solves than slots.
    let unique = BatchEngine::unique_count(&queries);
    assert!(unique <= combos.len(), "{unique} unique > {} combos", combos.len());
    assert!(unique >= combos.len() / 2, "shuffle under-covered the combos: {unique}");
}
