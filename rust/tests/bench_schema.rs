//! Bench-harness self-test (ISSUE 6 satellite, extended by ISSUEs 7,
//! 9 and 10): `bench --quick` must emit a `BENCH_<n>.json` that
//! validates against the current schema (`ckpt-period/bench/v4` — v3's
//! solver legs plus the batched Monte-Carlo replicas/sec legs and the
//! warm-started endpoint re-solve leg), and the committed repo-root
//! trajectory must stay readable: every historical point validates
//! under its own declared version, v1/v2/v3/v4, with the shared key
//! set intact. Every future PR's perf trajectory depends on these keys
//! staying put.

use std::path::Path;
use std::process::Command;

use ckpt_period::util::json::{parse, Json};

fn req_num(doc: &Json, key: &str) -> f64 {
    doc.req_f64(key).unwrap_or_else(|e| panic!("{key}: {e}"))
}

/// The v1 key set — shared by every schema version since.
fn validate_common(doc: &Json, origin: &str) {
    assert_eq!(doc.req_str("suite").unwrap(), "serve", "{origin}");
    assert!(doc.get("quick").and_then(|q| q.as_bool()).is_some(), "{origin}: quick flag");
    assert!(!doc.req_str("git_describe").unwrap().is_empty(), "{origin}: git describe label");
    assert!(req_num(doc, "pool_threads") >= 1.0, "{origin}");
    assert!(req_num(doc, "memo_scenarios") >= 1.0, "{origin}");
    assert!(req_num(doc, "batch") >= 1.0, "{origin}");
    assert!(req_num(doc, "cells") >= 1.0, "{origin}");
    assert!(req_num(doc, "cell_throughput_per_sec") > 0.0, "{origin}");

    // Cold/warm memo latency: both positive, warm strictly below cold
    // (the memo hit path must never regress to a recompute).
    let cold = req_num(doc, "cold_memo_ns");
    let warm = req_num(doc, "warm_memo_ns");
    assert!(cold > 0.0 && warm > 0.0, "{origin}: latencies cold {cold} warm {warm}");
    assert!(warm < cold, "{origin}: warm memo {warm}ns not strictly below cold {cold}ns");

    // Queries/sec at each standard thread count, cold and warm.
    let qps = doc.get("queries_per_sec").expect("queries_per_sec object");
    for threads in ["1", "4", "8"] {
        let t = qps
            .get(threads)
            .unwrap_or_else(|| panic!("{origin}: missing thread count {threads}"));
        assert!(req_num(t, "cold") > 0.0, "{origin}: {threads} threads cold qps");
        assert!(req_num(t, "warm") > 0.0, "{origin}: {threads} threads warm qps");
    }
}

/// The percentile block `render::hist_stats_json` emits, as embedded
/// per stage in each v2 queries/sec leg.
fn validate_stage_stats(stats: &Json, origin: &str) {
    assert!(req_num(stats, "count") >= 1.0, "{origin}: stage never recorded");
    let p50 = req_num(stats, "p50_ns");
    let p95 = req_num(stats, "p95_ns");
    let p99 = req_num(stats, "p99_ns");
    assert!(p50 > 0.0, "{origin}: p50");
    assert!(p50 <= p95 && p95 <= p99, "{origin}: percentiles out of order {p50}/{p95}/{p99}");
}

/// v2 additions: cold-memo tail, per-leg pool_threads + stage
/// percentiles, and the whole-registry telemetry snapshot.
fn validate_v2(doc: &Json, origin: &str) {
    let p50 = req_num(doc, "cold_memo_p50_ns");
    let p95 = req_num(doc, "cold_memo_p95_ns");
    let p99 = req_num(doc, "cold_memo_p99_ns");
    assert!(p50 > 0.0, "{origin}: cold p50");
    assert!(p50 <= p95 && p95 <= p99, "{origin}: cold tail out of order {p50}/{p95}/{p99}");

    let qps = doc.get("queries_per_sec").expect("queries_per_sec object");
    for threads in ["1", "4", "8"] {
        let t = qps.get(threads).unwrap();
        let origin = format!("{origin} @{threads}t");
        assert!(req_num(t, "pool_threads") >= 1.0, "{origin}: pool_threads");
        let stages = t.get("stages").unwrap_or_else(|| panic!("{origin}: stages block"));
        for stage in ["dedup", "solve", "scatter"] {
            let s = stages.get(stage).unwrap_or_else(|| panic!("{origin}: stage {stage}"));
            validate_stage_stats(s, &format!("{origin}/{stage}"));
        }
    }

    let telemetry = doc.get("telemetry").unwrap_or_else(|| panic!("{origin}: telemetry block"));
    for section in ["counters", "caches", "histograms"] {
        assert!(telemetry.get(section).is_some(), "{origin}: telemetry.{section}");
    }
}

/// v3 additions: pooled frontier points/sec per thread count, and the
/// tier-plan solver leg with its envelope-pruning counter deltas.
fn validate_v3(doc: &Json, origin: &str) {
    assert!(req_num(doc, "frontier_points") >= 2.0, "{origin}: frontier_points");
    let fps = doc.get("frontier_per_sec").expect("frontier_per_sec object");
    for threads in ["1", "4", "8"] {
        let t = fps
            .get(threads)
            .unwrap_or_else(|| panic!("{origin}: missing frontier thread count {threads}"));
        let origin = format!("{origin} frontier @{threads}t");
        assert!(req_num(t, "cold") > 0.0, "{origin}: cold pts/s");
        assert!(req_num(t, "warm") > 0.0, "{origin}: warm pts/s");
        assert!(req_num(t, "pool_threads") >= 1.0, "{origin}: pool_threads");
    }

    assert!(req_num(doc, "tier_plan_scenarios") >= 1.0, "{origin}: tier_plan_scenarios");
    let tp = doc.get("tier_plan_per_sec").expect("tier_plan_per_sec object");
    assert!(req_num(tp, "cold") > 0.0, "{origin}: tier cold solves/s");
    assert!(req_num(tp, "warm") > 0.0, "{origin}: tier warm solves/s");
    // The bound-pruned envelope must be doing real work on the
    // three-tier bench scenarios: more vectors pruned than evaluated.
    let evaluated = req_num(tp, "envelope_evaluated");
    let skipped = req_num(tp, "envelope_skipped");
    assert!(evaluated >= 1.0, "{origin}: envelope never evaluated");
    assert!(
        skipped > evaluated,
        "{origin}: pruning below 50% (evaluated {evaluated}, skipped {skipped})"
    );
}

/// v4 additions: scalar-vs-batched Monte-Carlo replicas/sec per thread
/// count (with the lockstep batch size in force), and the warm-started
/// endpoint re-solve leg with its hit/fallback counter deltas.
fn validate_v4(doc: &Json, origin: &str) {
    assert!(req_num(doc, "sim_replicates") >= 1.0, "{origin}: sim_replicates");
    let sim = doc.get("sim_replicas_per_sec").expect("sim_replicas_per_sec object");
    for threads in ["1", "4", "8"] {
        let t = sim
            .get(threads)
            .unwrap_or_else(|| panic!("{origin}: missing sim thread count {threads}"));
        let origin = format!("{origin} sim @{threads}t");
        assert!(req_num(t, "scalar") > 0.0, "{origin}: scalar replicas/s");
        assert!(req_num(t, "batched") > 0.0, "{origin}: batched replicas/s");
        assert!(req_num(t, "batch_size") >= 1.0, "{origin}: batch_size");
        assert!(req_num(t, "pool_threads") >= 1.0, "{origin}: pool_threads");
    }

    assert!(req_num(doc, "warm_resolve_scenarios") >= 2.0, "{origin}: warm_resolve_scenarios");
    let wr = doc.get("warm_resolve_per_sec").expect("warm_resolve_per_sec object");
    let cold = req_num(wr, "cold");
    let warm = req_num(wr, "warm");
    assert!(cold > 0.0 && warm > 0.0, "{origin}: re-solve rates cold {cold} warm {warm}");
    // A validated 3-probe bracket replaces the ~400-point endpoint
    // scan, so the drifting pass must out-run the family-cold one.
    assert!(warm > cold, "{origin}: warm re-solves {warm}/s not above cold {cold}/s");
    // The μ walk moves the optimum well under a grid cell per step:
    // the seeded brackets must actually validate, not fall back.
    assert!(req_num(wr, "warm_hits") >= 1.0, "{origin}: warm pass never hit");
    assert!(req_num(wr, "warm_fallbacks") >= 0.0, "{origin}: warm_fallbacks");
}

/// Dispatch on the declared schema version. Every version validates
/// the common key set; v2 adds the observability payload, v3 the
/// solver legs, v4 the batched-executor and warm-re-solve legs.
fn validate(doc: &Json, origin: &str) {
    let schema = doc.req_str("schema").unwrap_or_else(|e| panic!("{origin}: {e}")).to_string();
    validate_common(doc, origin);
    match schema.as_str() {
        "ckpt-period/bench/v1" => {}
        "ckpt-period/bench/v2" => validate_v2(doc, origin),
        "ckpt-period/bench/v3" => {
            validate_v2(doc, origin);
            validate_v3(doc, origin);
        }
        "ckpt-period/bench/v4" => {
            validate_v2(doc, origin);
            validate_v3(doc, origin);
            validate_v4(doc, origin);
        }
        other => panic!("{origin}: unknown bench schema {other}"),
    }
}

#[test]
fn bench_quick_emits_a_schema_valid_trajectory_point() {
    let dir = std::env::temp_dir().join(format!("ckpt_bench_schema_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let out = Command::new(env!("CARGO_BIN_EXE_ckpt-period"))
        .args(["bench", "--quick", "--out-dir", dir.to_str().unwrap()])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "bench failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    // An empty --out-dir starts the trajectory at index 0.
    let path = dir.join("BENCH_0.json");
    let raw = std::fs::read_to_string(&path).expect("BENCH_0.json exists");
    let doc = parse(&raw).expect("valid JSON");

    // A fresh run must declare the current schema and fully validate.
    assert_eq!(doc.req_str("schema").unwrap(), "ckpt-period/bench/v4");
    assert_eq!(doc.get("quick").and_then(|q| q.as_bool()), Some(true));
    validate(&doc, "fresh quick run");

    // A second run appends the next index instead of overwriting.
    let out = Command::new(env!("CARGO_BIN_EXE_ckpt-period"))
        .args(["bench", "--quick", "--out-dir", dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(dir.join("BENCH_1.json").exists(), "trajectory must append");
    assert_eq!(std::fs::read_to_string(dir.join("BENCH_0.json")).unwrap(), raw);

    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn committed_trajectory_validates_under_each_declared_version() {
    // Tests run with CWD = rust/; the trajectory lives at the repo root.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("repo root").to_path_buf();
    let mut found = 0usize;
    for i in 0.. {
        let path = root.join(format!("BENCH_{i}.json"));
        if !path.exists() {
            break;
        }
        let raw = std::fs::read_to_string(&path).unwrap();
        let doc = parse(&raw).unwrap_or_else(|e| panic!("BENCH_{i}.json: {e}"));
        validate(&doc, &format!("BENCH_{i}.json"));
        found += 1;
    }
    assert!(found >= 1, "no committed BENCH_<n>.json trajectory at the repo root");
}
