//! Bench-harness self-test (ISSUE 6 satellite): `bench --quick` must
//! emit a `BENCH_<n>.json` that validates against the fixed schema —
//! every future PR's perf trajectory depends on these keys staying
//! put — and the warm memo path must be strictly faster than cold.

use std::process::Command;

use ckpt_period::util::json::{parse, Json};

fn req_num(doc: &Json, key: &str) -> f64 {
    doc.req_f64(key).unwrap_or_else(|e| panic!("{key}: {e}"))
}

#[test]
fn bench_quick_emits_a_schema_valid_trajectory_point() {
    let dir = std::env::temp_dir().join(format!("ckpt_bench_schema_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let out = Command::new(env!("CARGO_BIN_EXE_ckpt-period"))
        .args(["bench", "--quick", "--out-dir", dir.to_str().unwrap()])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "bench failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    // An empty --out-dir starts the trajectory at index 0.
    let path = dir.join("BENCH_0.json");
    let raw = std::fs::read_to_string(&path).expect("BENCH_0.json exists");
    let doc = parse(&raw).expect("valid JSON");

    // Required keys, exactly as EXPERIMENTS.md and CI consume them.
    assert_eq!(doc.req_str("schema").unwrap(), "ckpt-period/bench/v1");
    assert_eq!(doc.req_str("suite").unwrap(), "serve");
    assert_eq!(doc.get("quick").and_then(|q| q.as_bool()), Some(true));
    assert!(!doc.req_str("git_describe").unwrap().is_empty(), "git describe label");
    assert!(req_num(&doc, "pool_threads") >= 1.0);
    assert!(req_num(&doc, "memo_scenarios") >= 1.0);
    assert!(req_num(&doc, "batch") >= 1.0);
    assert!(req_num(&doc, "cells") >= 1.0);
    assert!(req_num(&doc, "cell_throughput_per_sec") > 0.0);

    // Cold/warm memo latency: both positive, warm strictly below cold
    // (the memo hit path must never regress to a recompute).
    let cold = req_num(&doc, "cold_memo_ns");
    let warm = req_num(&doc, "warm_memo_ns");
    assert!(cold > 0.0 && warm > 0.0, "latencies: cold {cold} warm {warm}");
    assert!(warm < cold, "warm memo {warm}ns not strictly below cold {cold}ns");

    // Queries/sec at each standard thread count, cold and warm.
    let qps = doc.get("queries_per_sec").expect("queries_per_sec object");
    for threads in ["1", "4", "8"] {
        let t = qps.get(threads).unwrap_or_else(|| panic!("missing thread count {threads}"));
        let cold_qps = req_num(t, "cold");
        let warm_qps = req_num(t, "warm");
        assert!(cold_qps > 0.0, "{threads} threads cold qps");
        assert!(warm_qps > 0.0, "{threads} threads warm qps");
    }

    // A second run appends the next index instead of overwriting.
    let out = Command::new(env!("CARGO_BIN_EXE_ckpt-period"))
        .args(["bench", "--quick", "--out-dir", dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(dir.join("BENCH_1.json").exists(), "trajectory must append");
    assert_eq!(std::fs::read_to_string(dir.join("BENCH_0.json")).unwrap(), raw);

    let _ = std::fs::remove_dir_all(dir);
}
