//! Bench for Figures 3a/3b: ratios vs node count at ρ = 5.5 and ρ = 7.

use ckpt_period::figures::fig3;
use ckpt_period::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("fig3_node_scaling");

    for (rho, name) in [(5.5, "fig3a_rho5.5"), (7.0, "fig3b_rho7")] {
        let nodes = fig3::node_grid(80);
        b.run_units(name, nodes.len() as f64, || black_box(fig3::series(rho, &nodes)));
        let pts = fig3::series(rho, &nodes);
        let (gain, at) = fig3::peak_energy_gain(&pts);
        println!(
            "{name}: peak energy gain {gain:.1}% at N={at:.2e} \
             (paper: up to 30% between 1e6 and 1e7); tail ratio {:.3}",
            pts.last().unwrap().energy_ratio
        );
        let csv = format!("target/bench-results/{}.csv", &name[..5]);
        let _ = fig3::table(&pts).write_csv(std::path::Path::new(&csv));
    }
    b.finish();
}
