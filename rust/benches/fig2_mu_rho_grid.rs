//! Bench for Figure 2: the (μ, ρ) ratio surfaces, engine edition.
//!
//! Three measurements:
//!
//! * the closed-form surface, **cold** (memo cache cleared each
//!   iteration — pure pool-parallel compute) and **warm** (second
//!   invocation of an identical grid — the repeated-figure/CLI path the
//!   cache exists for);
//! * a Monte-Carlo (μ, ρ) grid through the engine vs the seed's
//!   *per-call spawn/join* `monte_carlo` pattern (scoped threads forked
//!   and joined per cell, with its serial-fallback calibration hack),
//!   reproduced verbatim below as the baseline. The printed `speedup`
//!   line is the acceptance number for pool reuse.

use ckpt_period::config::presets::fig2_scenario;
use ckpt_period::figures::fig2;
use ckpt_period::model::t_time_opt;
use ckpt_period::sim::engine::{RunResult, SimConfig, Simulator};
use ckpt_period::sweep::{cache, GridSpec};
use ckpt_period::util::bench::{black_box, Bench};
use ckpt_period::util::stats::OnlineStats;

/// The seed's `monte_carlo`: spawn + join scoped threads on every call,
/// with the timing-based serial fallback. Kept here (only) as the bench
/// baseline; the library now fans out on the persistent pool.
fn spawn_join_monte_carlo(cfg: &SimConfig, replicates: usize, base_seed: u64, threads: usize) -> f64 {
    let mut threads = threads.clamp(1, replicates);
    let sim = Simulator::new(cfg.clone());
    let mut first: Option<RunResult> = None;
    if threads > 1 {
        let t0 = std::time::Instant::now();
        first = Some(sim.run(base_seed));
        let est_total = t0.elapsed().as_secs_f64() * (replicates - 1) as f64;
        if est_total < 1e-3 {
            threads = 1;
        }
    }
    let results: Vec<RunResult> = if threads == 1 {
        let skip = usize::from(first.is_some());
        let mut out: Vec<RunResult> = Vec::with_capacity(replicates);
        out.extend(first);
        out.extend((skip..replicates).map(|i| sim.run(base_seed + i as u64)));
        out
    } else {
        let mut out: Vec<Option<RunResult>> = vec![None; replicates];
        let chunks: Vec<Vec<usize>> =
            (0..threads).map(|t| (t..replicates).step_by(threads).collect()).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for idxs in &chunks {
                let sim = &sim;
                handles.push(scope.spawn(move || {
                    idxs.iter().map(|&i| (i, sim.run(base_seed + i as u64))).collect::<Vec<_>>()
                }));
            }
            for h in handles {
                for (i, r) in h.join().expect("sim thread panicked") {
                    out[i] = Some(r);
                }
            }
        });
        out.into_iter().map(|r| r.unwrap()).collect()
    };
    let mut stats = OnlineStats::new();
    for r in &results {
        stats.push(r.makespan);
    }
    stats.mean()
}

fn main() {
    let mut b = Bench::new("fig2_mu_rho_grid");

    // Closed-form surfaces: cold (pool-parallel compute) vs warm (memo).
    for n in [20usize, 40, 80] {
        let mus = fig2::mu_grid(n);
        let rhos = fig2::rho_grid(n);
        b.run_units(&format!("surface_{n}x{n}_cold"), (n * n) as f64, || {
            cache::clear();
            black_box(fig2::grid(&mus, &rhos))
        });
        cache::clear();
        let _ = fig2::grid(&mus, &rhos); // populate
        b.run_units(&format!("surface_{n}x{n}_warm_cached"), (n * n) as f64, || {
            black_box(fig2::grid(&mus, &rhos))
        });
    }

    // Monte-Carlo grid: engine (persistent pool, cells parallel) vs the
    // seed's per-cell spawn/join calls. Small replicate counts are the
    // regime the seed's calibration hack forced serial.
    const GRID_N: usize = 8;
    const REPS: usize = 16;
    let mus: Vec<f64> = (0..GRID_N).map(|i| 120.0 + 180.0 * i as f64 / (GRID_N - 1) as f64).collect();
    let rhos: Vec<f64> = (0..GRID_N).map(|i| 2.0 + 10.0 * i as f64 / (GRID_N - 1) as f64).collect();
    let cells: Vec<(SimConfig, f64)> = mus
        .iter()
        .flat_map(|&mu| rhos.iter().map(move |&rho| (mu, rho)))
        .map(|(mu, rho)| {
            let s = fig2_scenario(mu, rho);
            let t = t_time_opt(&s).unwrap();
            (SimConfig::paper(s, t), t)
        })
        .collect();
    let n_cells = cells.len();

    let engine = b
        .run_units(&format!("mc_grid_{GRID_N}x{GRID_N}x{REPS}_engine_pool"), n_cells as f64, || {
            let mut spec = GridSpec::new(99);
            for (cfg, period) in &cells {
                spec.push_sim(cfg.scenario, *period, REPS);
            }
            black_box(spec.without_cache().evaluate())
        })
        .median();

    let baseline = b
        .run_units(
            &format!("mc_grid_{GRID_N}x{GRID_N}x{REPS}_seed_spawn_join"),
            n_cells as f64,
            || {
                let mut acc = 0.0;
                for (cfg, _) in &cells {
                    acc += spawn_join_monte_carlo(cfg, REPS, 99, 8);
                }
                black_box(acc)
            },
        )
        .median();

    println!(
        "fig2 mc-grid speedup: engine+pool is {:.2}x the seed spawn/join path \
         (engine {:.3} ms vs baseline {:.3} ms)",
        baseline / engine,
        engine * 1e3,
        baseline * 1e3
    );

    let cells = fig2::grid(&fig2::mu_grid(40), &fig2::rho_grid(40));
    println!(
        "fig2: max energy gain over surface {:.1}% (paper: >20% at mu=300)",
        fig2::max_energy_gain_pct(&cells)
    );
    let (hits, misses) = cache::stats();
    println!("fig2: memo cache {hits} hits / {misses} misses this process");
    let _ = fig2::table(&cells).write_csv(std::path::Path::new("target/bench-results/fig2.csv"));
    b.finish();
}
