//! Bench for Figure 2: the (μ, ρ) ratio surfaces.

use ckpt_period::figures::fig2;
use ckpt_period::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("fig2_mu_rho_grid");

    for n in [20usize, 40, 80] {
        let mus = fig2::mu_grid(n);
        let rhos = fig2::rho_grid(n);
        b.run_units(&format!("surface_{n}x{n}"), (n * n) as f64, || {
            black_box(fig2::grid(&mus, &rhos))
        });
    }

    let cells = fig2::grid(&fig2::mu_grid(40), &fig2::rho_grid(40));
    println!(
        "fig2: max energy gain over surface {:.1}% (paper: >20% at mu=300)",
        fig2::max_energy_gain_pct(&cells)
    );
    let _ = fig2::table(&cells).write_csv(std::path::Path::new("target/bench-results/fig2.csv"));
    b.finish();
}
