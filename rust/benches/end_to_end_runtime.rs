//! End-to-end runtime bench: PJRT train-step latency, checkpoint
//! save/restore cost (the measured C and R), and a short coordinated run
//! — the L3 hot path the §Perf pass optimises.
//!
//! Requires `make artifacts`.

use ckpt_period::coordinator::checkpoint::CheckpointStore;
use ckpt_period::coordinator::{Coordinator, CoordinatorConfig, PeriodPolicy};
use ckpt_period::runtime::{ArtifactDir, Runtime, SweepEvaluator};
use ckpt_period::util::bench::{black_box, Bench};
use ckpt_period::workload::{TrainSession, TrainState};

fn main() {
    let mut b = Bench::new("end_to_end_runtime");
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let dir = ArtifactDir::open("artifacts").expect("run `make artifacts` first");

    // Artifact compile time (cold-start cost, once per process).
    b.run("compile_train_step_artifact", || {
        black_box(rt.load_hlo_text(&dir.hlo_path("train_step")).unwrap())
    });

    let session = TrainSession::new(&rt, &dir, 1).unwrap();
    let mut state = TrainState::initial(&dir).unwrap();

    // The request-path hot loop: one PJRT train step (470k params),
    // host-vector path vs the literal-resident §Perf path (L3-2).
    b.run_units("train_step_pjrt", 1.0, || black_box(session.step(&mut state).unwrap()));
    let mut lit_state = ckpt_period::workload::LitTrainState::from_state(&state);
    b.run_units("train_step_pjrt_lit", 1.0, || {
        black_box(session.step_lit(&mut lit_state).unwrap())
    });
    b.run_units("eval_loss_pjrt", 1.0, || black_box(session.eval(&state, 0).unwrap()));

    // Checkpoint C and R on this machine (5.6 MB state).
    let store =
        CheckpointStore::new(std::env::temp_dir().join("ckpt_bench_store")).unwrap();
    b.run_units("checkpoint_save_c", 1.0, || black_box(store.save(&state).unwrap()));
    b.run_units("checkpoint_load_r", 1.0, || black_box(store.load().unwrap().1));

    // Sweep artifact (1024-period grid through XLA).
    let evaluator = SweepEvaluator::load(&rt, &dir).unwrap();
    let s = ckpt_period::config::presets::fig1_scenario(300.0, 5.5);
    let grid = evaluator.uniform_grid(&s);
    b.run_units("sweep_eval_1024_via_xla", 1024.0, || {
        black_box(evaluator.eval(&s, &grid).unwrap())
    });

    // A short coordinated run (failure-free, fixed period) to time the
    // full control loop. Artifact compilation happens once in
    // Coordinator::new, outside the timed closure — the loop is what we
    // are measuring.
    let ckpt_dir = std::env::temp_dir().join("ckpt_bench_e2e");
    let mut cfg = CoordinatorConfig::new("artifacts", &ckpt_dir);
    cfg.steps = 20;
    cfg.inject_failures = false;
    cfg.policy = PeriodPolicy::Fixed(0.5);
    cfg.calibration_steps = 1;
    let coord = Coordinator::new(&rt, cfg).unwrap();
    b.run_units("coordinator_20steps_failure_free", 20.0, || {
        let _ = std::fs::remove_dir_all(&ckpt_dir);
        black_box(coord.run().unwrap())
    });

    b.finish();
}
