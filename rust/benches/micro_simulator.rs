//! Micro-bench: discrete-event simulator throughput (single runs and
//! multi-threaded Monte-Carlo), plus the failure-stream generators.

use ckpt_period::config::presets::fig1_scenario;
use ckpt_period::model::t_time_opt;
use ckpt_period::sim::{monte_carlo, FailureProcess, SimConfig, Simulator};
use ckpt_period::util::bench::{black_box, Bench};
use ckpt_period::util::rng::Pcg64;

fn main() {
    let mut b = Bench::new("micro_simulator");
    let s = fig1_scenario(300.0, 5.5);
    let t = t_time_opt(&s).unwrap();

    // Single-run cost (~190 periods + ~35 failures per run at these
    // parameters).
    let sim = Simulator::new(SimConfig::paper(s, t));
    let mut seed = 0u64;
    b.run_units("single_run_10k_min_app", 1.0, || {
        seed += 1;
        black_box(sim.run(seed))
    });

    // Monte-Carlo: inline serial loop vs persistent-pool fan-out.
    // (`threads` is effectively a switch now: 1 => serial, >1 => the
    // process-wide pool, whose size is fixed at CKPT_POOL_THREADS/cores.)
    for (threads, label) in [(1usize, "serial"), (8, "pool")] {
        let cfg = SimConfig::paper(s, t);
        b.run_units(&format!("monte_carlo_128reps_{label}"), 128.0, || {
            black_box(monte_carlo(&cfg, 128, 99, threads))
        });
    }

    // Failure streams.
    for (name, proc_) in [
        ("stream_exponential", FailureProcess::Exponential { mtbf: 10.0 }),
        (
            "stream_per_node_weibull_100",
            FailureProcess::PerNodeWeibull { n: 100, shape: 0.7, scale_ind: 1000.0 },
        ),
    ] {
        b.run_units(&format!("{name}_10k_events"), 10_000.0, || {
            let mut rng = Pcg64::seeded(5);
            let mut stream = proc_.stream(&mut rng);
            let mut now = 0.0;
            for _ in 0..10_000 {
                now = stream.next_after(now).at;
            }
            black_box(now)
        });
    }

    b.finish();
}
