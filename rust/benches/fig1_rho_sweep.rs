//! Bench for Figure 1: regenerates the ρ-sweep series (4 μ-curves) and
//! times the generation. Prints the series' summary so the bench output
//! itself documents the reproduced figure.

use ckpt_period::figures::fig1;
use ckpt_period::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("fig1_rho_sweep");

    for n in [60usize, 240, 960] {
        let rhos = fig1::rho_grid(n);
        b.run_units(&format!("series_{}pts", n * fig1::MUS.len()), (n * 4) as f64, || {
            black_box(fig1::series(&rhos))
        });
    }

    // Reproduce + report the figure itself (fixed resolution).
    let pts = fig1::series(&fig1::rho_grid(60));
    let p = pts
        .iter()
        .filter(|p| p.mu == 300.0)
        .min_by(|a, b| (a.rho - 5.5).abs().partial_cmp(&(b.rho - 5.5).abs()).unwrap())
        .unwrap();
    println!(
        "fig1 @ (mu=300, rho=5.5): energy ratio {:.4}, time ratio {:.4} \
         (paper: ~1.25 / ~1.1)",
        p.energy_ratio, p.time_ratio
    );
    let table = fig1::table(&pts);
    let _ = table.write_csv(std::path::Path::new("target/bench-results/fig1.csv"));
    b.finish();
}
