//! Micro-bench: the analytical model's hot paths (the figure harness
//! evaluates these ~10⁶ times per surface).

use ckpt_period::config::presets::fig1_scenario;
use ckpt_period::model::energy::{de_quadratic, e_final, t_energy_opt_numeric, t_energy_opt_raw};
use ckpt_period::model::time::{t_final, t_time_opt_raw};
use ckpt_period::model::{compare, t_energy_opt};
use ckpt_period::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("micro_model_eval");
    let s = fig1_scenario(300.0, 5.5);

    b.run_units("t_final_1k_evals", 1000.0, || {
        let mut acc = 0.0;
        for i in 0..1000 {
            acc += t_final(&s, 11.0 + i as f64 * 0.5);
        }
        black_box(acc)
    });

    b.run_units("e_final_1k_evals", 1000.0, || {
        let mut acc = 0.0;
        for i in 0..1000 {
            acc += e_final(&s, 11.0 + i as f64 * 0.5);
        }
        black_box(acc)
    });

    b.run("t_time_opt_closed_form", || black_box(t_time_opt_raw(&s)));
    b.run("de_quadratic_coeffs", || black_box(de_quadratic(&s)));
    b.run("t_energy_opt_closed_form", || black_box(t_energy_opt_raw(&s)));
    b.run("t_energy_opt_clamped", || black_box(t_energy_opt(&s).unwrap()));
    b.run("t_energy_opt_numeric_golden", || black_box(t_energy_opt_numeric(&s)));
    b.run("compare_full", || black_box(compare(&s).unwrap()));

    b.finish();
}
