//! Expected execution time (§3.1).
//!
//! With `a = (1−ω)C` and `b = 1 − (D+R+ωC)/μ`:
//!
//! ```text
//! T_ff(T)    = T_base · T / (T − a)
//! T_final(T) = T_base · T / ((T − a)(b − T/(2μ)))
//! ```
//!
//! `T_final` is exactly minimised (within the closed form) at
//! `T_Time_opt = sqrt(2(1−ω)C(μ − (D+R+ωC)))` — the paper's Eq. (1):
//! setting `dT_final/dT = 0` gives `T²/(2μ) = ab`, i.e.
//! `T² = 2μ·(1−ω)C·b = 2(1−ω)C(μ − (D+R+ωC))`.

use super::params::{ModelError, Scenario};

/// Fault-free execution time `T_ff(T)` (checkpoint overhead only).
pub fn t_ff(s: &Scenario, t: f64) -> f64 {
    s.t_base * t / (t - s.a())
}

/// Expected number of failures over the whole (expected) execution.
pub fn expected_failures(s: &Scenario, t: f64) -> f64 {
    t_final(s, t) / s.mu
}

/// Expected time lost per failure: `D + R + ωC + T/2` (§3.1).
pub fn time_lost_per_failure(s: &Scenario, t: f64) -> f64 {
    s.ckpt.d + s.ckpt.r + s.ckpt.omega * s.ckpt.c + t / 2.0
}

/// Expected total execution time `T_final(T)`.
///
/// Panics in debug if `t` is outside the open domain `(a, 2μb)`; returns
/// `+inf` in release (callers that sweep grids filter on finiteness).
///
/// Tiered scenarios dispatch to the κ-minimised envelope in
/// [`super::tiers`]; the scalar path below is untouched by the
/// hierarchy refactor.
pub fn t_final(s: &Scenario, t: f64) -> f64 {
    if let Some(h) = s.hierarchy() {
        return super::tiers::t_final_tiered(s, h, t);
    }
    let (lo, hi) = s.domain();
    if t <= lo || t >= hi {
        return f64::INFINITY;
    }
    s.t_base * t / ((t - s.a()) * (s.b() - t / (2.0 * s.mu)))
}

/// The waste ratio `T_final/T_base − 1` (overhead fraction).
pub fn waste(s: &Scenario, t: f64) -> f64 {
    t_final(s, t) / s.t_base - 1.0
}

/// Time-optimal checkpointing period (Eq. 1), **unclamped**:
/// `sqrt(2(1−ω)C(μ − (D+R+ωC)))`.
pub fn t_time_opt_raw(s: &Scenario) -> f64 {
    (2.0 * s.a() * (s.mu - (s.ckpt.d + s.ckpt.r + s.ckpt.omega * s.ckpt.c))).sqrt()
}

/// Time-optimal period, clamped into the physical domain `[C, 2μb)`.
/// This is the period **AlgoT** checkpoints with.
///
/// For `ω = 1` the checkpoint is fully overlapped and the failure-free
/// overhead vanishes; the raw formula returns 0 and the clamp (to `C`)
/// is what makes AlgoT well defined — checkpoint back-to-back.
pub fn t_time_opt(s: &Scenario) -> Result<f64, ModelError> {
    if let Some(h) = s.hierarchy() {
        return super::tiers::t_time_opt_tiered(s, h);
    }
    s.clamp_period(t_time_opt_raw(s))
}

/// Young's classical period `sqrt(2Cμ) + C` (blocking checkpoints).
pub fn young(s: &Scenario) -> f64 {
    (2.0 * s.ckpt.c * s.mu).sqrt() + s.ckpt.c
}

/// Daly's higher-order period `sqrt(2C(μ + D + R)) + C` (blocking).
///
/// Note: Daly's own refinement subtracts the overheads from μ in some
/// variants; we implement the form quoted by this paper (§2.1).
pub fn daly(s: &Scenario) -> f64 {
    (2.0 * s.ckpt.c * (s.mu + s.ckpt.d + s.ckpt.r)).sqrt() + s.ckpt.c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{CheckpointParams, PowerParams};
    use crate::prop_assert;
    use crate::util::proptest::{check, Gen};

    fn scenario(mu: f64, omega: f64) -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, omega).unwrap();
        let power = PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        Scenario::new(ckpt, power, mu, 10_000.0).unwrap()
    }

    fn random_scenario(g: &mut Gen) -> Scenario {
        // Draw parameters in the paper's realistic ranges with mu large
        // enough that the domain is non-degenerate.
        let c = g.f64_in(0.5, 20.0);
        let r = g.f64_in(0.5, 20.0);
        let d = g.f64_in(0.0, 5.0);
        let omega = g.f64_in(0.0, 1.0);
        let mu = g.f64_log_in(10.0 * (c + r + d), 1e6);
        let alpha = g.f64_in(0.1, 4.0);
        let rho = g.f64_in(1.0, 20.0);
        let gamma = g.f64_in(0.0, 1.0);
        let ckpt = CheckpointParams::new(c, r, d, omega).unwrap();
        let power = PowerParams::from_rho(rho, alpha, gamma).unwrap();
        Scenario::new(ckpt, power, mu, 10_000.0).unwrap()
    }

    #[test]
    fn t_ff_at_large_period_approaches_t_base() {
        let s = scenario(300.0, 0.5);
        assert!((t_ff(&s, 1e9) - s.t_base) / s.t_base < 1e-6);
    }

    #[test]
    fn t_ff_overhead_formula() {
        let s = scenario(300.0, 0.5);
        // T=100, a=5 => T_ff = T_base * 100/95.
        assert!((t_ff(&s, 100.0) - s.t_base * 100.0 / 95.0).abs() < 1e-9);
    }

    #[test]
    fn t_final_outside_domain_is_infinite() {
        let s = scenario(300.0, 0.5);
        let (lo, hi) = s.domain();
        assert!(t_final(&s, lo).is_infinite());
        assert!(t_final(&s, hi).is_infinite());
        assert!(t_final(&s, lo / 2.0).is_infinite());
        assert!(t_final(&s, hi * 2.0).is_infinite());
        assert!(t_final(&s, (lo + hi) / 2.0).is_finite());
    }

    #[test]
    fn t_final_exceeds_t_ff() {
        let s = scenario(300.0, 0.5);
        for t in [20.0, 50.0, 100.0, 200.0] {
            assert!(t_final(&s, t) > t_ff(&s, t), "t={t}");
        }
    }

    #[test]
    fn eq1_value_paper_fig1() {
        // mu=300, C=10, R=10, D=1, omega=1/2:
        // T_opt = sqrt(2*5*(300-16)) = sqrt(2840).
        let s = scenario(300.0, 0.5);
        assert!((t_time_opt_raw(&s) - (2840.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn eq1_is_stationary_point() {
        // Central finite difference of T_final at T_opt is ~0.
        let s = scenario(300.0, 0.5);
        let t = t_time_opt_raw(&s);
        let h = 1e-4;
        let d = (t_final(&s, t + h) - t_final(&s, t - h)) / (2.0 * h);
        let scale = t_final(&s, t) / t;
        assert!(d.abs() / scale < 1e-6, "d={d}");
    }

    #[test]
    fn prop_t_opt_is_global_min_on_grid() {
        check("T_Time_opt minimises T_final", 200, |g| {
            let s = random_scenario(g);
            let topt = t_time_opt(&s).unwrap();
            let best = t_final(&s, topt);
            let (lo, hi) = s.domain();
            for i in 1..200 {
                let t = lo + (hi - lo) * i as f64 / 200.0;
                let t = t.max(s.min_period());
                if t >= hi {
                    break;
                }
                let v = t_final(&s, t);
                prop_assert!(
                    g,
                    best <= v * (1.0 + 1e-9),
                    "T_final({t})={v} < T_final({topt})={best}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_young_daly_order_and_closeness() {
        check("Daly >= Young and both near Eq.1 for omega=0, large mu", 100, |g| {
            let c = g.f64_in(1.0, 15.0);
            let mu = g.f64_log_in(1e4, 1e6);
            let ckpt = CheckpointParams::new(c, c, 1.0, 0.0).unwrap();
            let power = PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
            let s = Scenario::new(ckpt, power, mu, 1e4).unwrap();
            prop_assert!(g, daly(&s) >= young(&s), "daly < young");
            let rel = (t_time_opt_raw(&s) - young(&s)).abs() / young(&s);
            prop_assert!(g, rel < 0.05, "Eq.1 vs Young rel diff {rel}");
            Ok(())
        });
    }

    #[test]
    fn omega_one_clamps_to_c() {
        let s = scenario(300.0, 1.0);
        assert_eq!(t_time_opt_raw(&s), 0.0);
        assert_eq!(t_time_opt(&s).unwrap(), s.ckpt.c);
    }

    #[test]
    fn waste_positive_and_small_for_large_mu() {
        let s = scenario(300.0, 0.5);
        let t = t_time_opt(&s).unwrap();
        let w = waste(&s, t);
        assert!(w > 0.0 && w < 0.5, "w={w}");
    }

    #[test]
    fn expected_failures_scales_with_final_time() {
        let s = scenario(300.0, 0.5);
        let t = t_time_opt(&s).unwrap();
        let f = expected_failures(&s, t);
        assert!((f - t_final(&s, t) / 300.0).abs() < 1e-9);
    }

    #[test]
    fn time_lost_per_failure_terms() {
        let s = scenario(300.0, 0.5);
        // D + R + omega*C + T/2 = 1 + 10 + 5 + 50 at T=100.
        assert!((time_lost_per_failure(&s, 100.0) - 66.0).abs() < 1e-12);
    }
}
