//! AlgoT vs AlgoE comparisons — the quantities every figure plots.
//!
//! * **time ratio** = `T_final(T_Energy_opt) / T_final(T_Time_opt)` ≥ 1:
//!   the slowdown paid for running at the energy-optimal period
//!   (Fig. 2b, Fig. 3 "execution time ratio of AlgoE over AlgoT").
//! * **energy ratio** = `E_final(T_Time_opt) / E_final(T_Energy_opt)` ≥ 1:
//!   the energy saved by AlgoE
//!   (Fig. 2a, Fig. 3 "energy ratio of AlgoT over AlgoE").

use super::energy::{e_final, t_energy_opt};
use super::params::{ModelError, Scenario};
use super::time::{t_final, t_time_opt};

/// Everything the figures need for one scenario.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Comparison {
    /// AlgoT's period (clamped Eq. 1).
    pub t_time: f64,
    /// AlgoE's period (clamped quadratic root).
    pub t_energy: f64,
    /// Makespans at each period.
    pub makespan_at_t: f64,
    pub makespan_at_e: f64,
    /// Energies at each period.
    pub energy_at_t: f64,
    pub energy_at_e: f64,
}

impl Comparison {
    /// `T_final(AlgoE) / T_final(AlgoT)` — "execution time ratio of
    /// AlgoE over AlgoT" (≥ 1).
    pub fn time_ratio(&self) -> f64 {
        self.makespan_at_e / self.makespan_at_t
    }

    /// `E_final(AlgoT) / E_final(AlgoE)` — "energy ratio of AlgoT over
    /// AlgoE" (≥ 1).
    pub fn energy_ratio(&self) -> f64 {
        self.energy_at_t / self.energy_at_e
    }

    /// Energy saved by AlgoE, in percent of AlgoT's energy.
    pub fn energy_gain_pct(&self) -> f64 {
        (1.0 - self.energy_at_e / self.energy_at_t) * 100.0
    }

    /// Extra time paid by AlgoE, in percent of AlgoT's makespan.
    pub fn time_overhead_pct(&self) -> f64 {
        (self.time_ratio() - 1.0) * 100.0
    }
}

/// Evaluate both strategies on a scenario.
pub fn compare(s: &Scenario) -> Result<Comparison, ModelError> {
    let t_time = t_time_opt(s)?;
    let t_energy = t_energy_opt(s)?;
    Ok(Comparison {
        t_time,
        t_energy,
        makespan_at_t: t_final(s, t_time),
        makespan_at_e: t_final(s, t_energy),
        energy_at_t: e_final(s, t_time),
        energy_at_e: e_final(s, t_energy),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{CheckpointParams, PowerParams};
    use crate::prop_assert;
    use crate::util::proptest::{check, Gen};

    fn paper_scenario(mu: f64, rho: f64) -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::from_rho(rho, 1.0, 0.0).unwrap();
        Scenario::new(ckpt, power, mu, 10_000.0).unwrap()
    }

    #[test]
    fn ratios_at_least_one() {
        for mu in [30.0, 60.0, 120.0, 300.0] {
            for rho in [1.0, 2.0, 5.5, 7.0, 15.0] {
                let cmp = compare(&paper_scenario(mu, rho)).unwrap();
                assert!(cmp.time_ratio() >= 1.0 - 1e-12, "mu={mu} rho={rho}");
                assert!(cmp.energy_ratio() >= 1.0 - 1e-12, "mu={mu} rho={rho}");
            }
        }
    }

    #[test]
    fn rho_one_with_matching_gamma_gives_identical_strategies() {
        // rho=1 means P_IO == P_Cal; with gamma chosen so downtime power
        // matches too, energy is a monotone transform of a
        // time-like objective only at alpha==beta; in practice the
        // periods are close. Assert near-unity ratios.
        let cmp = compare(&paper_scenario(300.0, 1.0)).unwrap();
        assert!(cmp.time_ratio() < 1.02);
        assert!(cmp.energy_ratio() < 1.02);
    }

    #[test]
    fn paper_headline_mu300() {
        // §5: "with current values (rho=5.5..7, mu=300 min) we can save
        // more than 20% of energy at the price of ~10% more time".
        let cmp = compare(&paper_scenario(300.0, 5.5)).unwrap();
        assert!(
            cmp.energy_gain_pct() > 15.0,
            "energy gain {}%",
            cmp.energy_gain_pct()
        );
        assert!(
            cmp.time_overhead_pct() < 20.0,
            "time overhead {}%",
            cmp.time_overhead_pct()
        );
        // Energy gain strictly exceeds the time price (the paper's point).
        assert!(cmp.energy_gain_pct() > cmp.time_overhead_pct());
    }

    #[test]
    fn prop_energy_ratio_monotone_in_rho() {
        // Bigger I/O power premium => bigger gain from AlgoE.
        check("energy ratio nondecreasing in rho", 60, |g: &mut Gen| {
            let mu = g.f64_in(100.0, 500.0);
            let rho_lo = g.f64_in(1.0, 10.0);
            let rho_hi = rho_lo + g.f64_in(0.5, 8.0);
            let lo = compare(&paper_scenario(mu, rho_lo)).unwrap();
            let hi = compare(&paper_scenario(mu, rho_hi)).unwrap();
            prop_assert!(
                g,
                hi.energy_ratio() >= lo.energy_ratio() - 1e-9,
                "mu={mu} rho {rho_lo}->{rho_hi}: {} -> {}",
                lo.energy_ratio(),
                hi.energy_ratio()
            );
            Ok(())
        });
    }

    #[test]
    fn ratios_converge_to_one_when_c_approaches_mu() {
        // Fig 3 regime: enormous N => mu ~ C => both periods clamp to C.
        let ckpt = CheckpointParams::new(1.0, 1.0, 0.1, 0.5).unwrap();
        let power = PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        let ratios_at = |mu: f64| {
            let s = Scenario::new(ckpt, power, mu, 1e4).unwrap();
            let cmp = compare(&s).unwrap();
            (cmp.time_ratio(), cmp.energy_ratio())
        };
        let (t_mid, e_mid) = ratios_at(4.0);
        let (t_tiny, e_tiny) = ratios_at(2.5); // mu only 2.5x the checkpoint
        // Toward the breakdown regime (mu -> C) both ratios head back to 1
        // — the tail of the paper's Fig 3 hump at 10^8 nodes.
        assert!(t_tiny < t_mid, "time {t_tiny} !< {t_mid}");
        assert!(e_tiny < e_mid, "energy {e_tiny} !< {e_mid}");
        assert!(t_tiny < 1.05, "time ratio {t_tiny}");
        assert!(e_tiny < 1.10, "energy ratio {e_tiny}");
    }

    #[test]
    fn gain_and_overhead_consistent_with_ratios() {
        let cmp = compare(&paper_scenario(120.0, 7.0)).unwrap();
        assert!((cmp.time_overhead_pct() - (cmp.time_ratio() - 1.0) * 100.0).abs() < 1e-12);
        let gain = cmp.energy_gain_pct() / 100.0;
        assert!(((1.0 / (1.0 - gain)) - cmp.energy_ratio()).abs() < 1e-9);
    }
}
