//! Exact expected makespan/energy for exponential failures — no
//! first-order truncation (an extension beyond the paper).
//!
//! The paper's §3 formulas are first-order approximations in `T/μ`; our
//! Monte-Carlo validation shows they drift by ~5–10 % once `T` reaches
//! `0.3–0.5 μ` (exactly AlgoE's regime at small μ). For exponential
//! failures the expectation can be computed *exactly* with
//! renewal-reward arguments, thanks to memorylessness:
//!
//! The process renews at every **completed checkpoint**: between two
//! completions the system must survive a span of wall length `T`
//! (compute `T−C`, then checkpoint `C`); any failure inside the span
//! rolls the work back to the previous completion, costs an expected
//! recovery `E_rec`, and restarts the span. Each completed span banks
//! `T − (1−ω)C` work units.
//!
//! With failure rate `λ = 1/μ` and `p = e^{−λT}` the success probability
//! per attempt:
//!
//! ```text
//! E[span]            = (e^{λT} − 1)(1/λ + E_rec)
//! E[failures/span]   = e^{λT} − 1
//! E[compute wall]    = (1/λ)(e^{λT} − e^{λC})      (per span, all attempts)
//! E[checkpoint wall] = (1/λ)(e^{λC} − 1)
//! E_rec              = D + R                        (no failures in recovery)
//!                    = (e^{λ(D+R)} − 1)/λ           (failures restart D+R)
//! E[work/span]       = (T − C) + ωC·e^{−λT}
//!   (the ωC overlap survives only if the span saw no failure — a
//!    rollback discards it, the paper's per-failure ωC term)
//! spans              = T_base / E[work/span]        (renewal–reward)
//! ```
//!
//! Energy applies the same per-phase powers as the simulator:
//! `P_Static` everywhere, `P_Cal` on compute + `ω`·checkpoint wall,
//! `P_IO` on checkpoint + recovery wall, `P_Down` on downtime.
//!
//! `rust/tests/sim_vs_model.rs::exact_model_matches_simulation_at_small_mu`
//! checks these against Monte Carlo at `μ = 120` where the first-order
//! forms are visibly off; `examples/exascale_study` prints the
//! first-order-vs-exact ablation.

use super::optimize::{grid_then_golden, grid_then_golden_warm};
use super::params::Scenario;

/// How recovery interacts with further failures (must match the
/// simulator's `failures_during_recovery` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryModel {
    /// Failures never strike during D+R (the paper's implicit model).
    Ideal,
    /// Failures during D+R restart the downtime+recovery (reality; the
    /// simulator's default).
    Restarting,
}

/// Exact expected phase breakdown for one run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExactBreakdown {
    pub makespan: f64,
    pub energy: f64,
    /// Expected *primary* (up-time) failures — episode starts. Under
    /// [`RecoveryModel::Restarting`] the simulator additionally counts
    /// the geometric restarts during D + R
    /// ([`Backend::expected_failures`](super::backend::Backend) folds
    /// them in).
    pub failures: f64,
    pub compute_wall: f64,
    pub checkpoint_wall: f64,
    pub recovery_wall: f64,
    pub down_wall: f64,
}

/// Expected duration of one downtime+recovery episode.
pub fn expected_recovery(s: &Scenario, model: RecoveryModel) -> f64 {
    let dr = s.ckpt.d + s.ckpt.r;
    match model {
        RecoveryModel::Ideal => dr,
        RecoveryModel::Restarting => s.mu * ((dr / s.mu).exp() - 1.0),
    }
}

/// Per-scenario invariants of the exact renewal model, hoisted out of
/// the per-period loop. The numeric optimiser evaluates the breakdown
/// at ~400 grid points plus the golden-section refinement per solve;
/// `λ`, `E_rec`, `e^{λC}` and the whole (t-independent) checkpoint wall
/// per span only depend on the scenario, so they are computed once
/// here. Every hoisted value is the *verbatim* subexpression the
/// one-shot path computed (same operations on the same inputs), so
/// [`ExactEvaluator::breakdown`] is bit-identical to the historical
/// per-call [`exact_breakdown`] — which now just delegates.
#[derive(Debug, Clone, Copy)]
pub struct ExactEvaluator {
    s: Scenario,
    lam: f64,
    c: f64,
    /// `ωC` — the overlap term's numerator in the banked-work formula.
    omega_c: f64,
    e_rec: f64,
    /// `e^{λC}`.
    exp_lam_c: f64,
    /// `(e^{λC} − 1)/λ` — checkpoint wall per span, t-independent.
    ckpt_per_span: f64,
    /// `D + R`, for the episode down/recovery split.
    dr: f64,
}

impl ExactEvaluator {
    pub fn new(s: &Scenario, model: RecoveryModel) -> ExactEvaluator {
        let lam = 1.0 / s.mu;
        let c = s.ckpt.c;
        let exp_lam_c = (lam * c).exp();
        ExactEvaluator {
            s: *s,
            lam,
            c,
            omega_c: s.ckpt.omega * c,
            e_rec: expected_recovery(s, model),
            exp_lam_c,
            ckpt_per_span: (exp_lam_c - 1.0) / lam,
            dr: s.ckpt.d + s.ckpt.r,
        }
    }

    /// Exact expectation at period `t` (must satisfy `t > (1−ω)C`;
    /// unlike the first-order forms there is **no upper domain limit**
    /// — the exact model stays finite for every `t`).
    pub fn breakdown(&self, t: f64) -> ExactBreakdown {
        let s = &self.s;
        assert!(t > s.a(), "period {t} does not exceed lost work {}", s.a());

        // Work banked per span: the successful attempt checkpoints
        // (T−C) + overlap, where overlap = ωC only if the span saw no
        // failure (a rollback resets the overlap — the ωC done during
        // the previous checkpoint is lost, exactly the paper's
        // per-failure ωC term). P(no failure in span) = e^{−λT}.
        let growth = (self.lam * t).exp();
        let work_per_span = (t - self.c) + self.omega_c / growth;
        let spans = s.t_base / work_per_span;
        let fails_per_span = growth - 1.0;

        let compute_per_span = (growth - self.exp_lam_c) / self.lam;

        let failures = spans * fails_per_span;
        let compute_wall = spans * compute_per_span;
        let checkpoint_wall = spans * self.ckpt_per_span;
        // Down/recovery split: the D and R parts scale proportionally
        // inside each episode (for Restarting this is the expected share
        // — failures land uniformly-exponentially across the episode).
        let episode_wall = failures * self.e_rec;
        let (down_wall, recovery_wall) = if self.dr > 0.0 {
            (episode_wall * s.ckpt.d / self.dr, episode_wall * s.ckpt.r / self.dr)
        } else {
            (0.0, 0.0)
        };

        let makespan = compute_wall + checkpoint_wall + episode_wall;
        let p = &s.power;
        let energy = p.p_static * makespan
            + p.p_cal * (compute_wall + s.ckpt.omega * checkpoint_wall)
            + p.p_io * (checkpoint_wall + recovery_wall)
            + p.p_down * down_wall;

        ExactBreakdown {
            makespan,
            energy,
            failures,
            compute_wall,
            checkpoint_wall,
            recovery_wall,
            down_wall,
        }
    }
}

/// One-shot exact expectation at period `t` — builds the per-scenario
/// [`ExactEvaluator`] and evaluates once. Loops over `t` should build
/// the evaluator themselves.
pub fn exact_breakdown(s: &Scenario, t: f64, model: RecoveryModel) -> ExactBreakdown {
    ExactEvaluator::new(s, model).breakdown(t)
}

/// Exact expected makespan.
pub fn t_final_exact(s: &Scenario, t: f64, model: RecoveryModel) -> f64 {
    exact_breakdown(s, t, model).makespan
}

/// Exact expected energy.
pub fn e_final_exact(s: &Scenario, t: f64, model: RecoveryModel) -> f64 {
    exact_breakdown(s, t, model).energy
}

/// Exact time-optimal period (numeric: the exact objective has no
/// algebraic closed form). The scenario invariants are hoisted out of
/// the ~400-point optimiser loop via [`ExactEvaluator`].
pub fn t_time_opt_exact(s: &Scenario, model: RecoveryModel) -> f64 {
    let ev = ExactEvaluator::new(s, model);
    optimise(s, |t| ev.breakdown(t).makespan)
}

/// Exact energy-optimal period.
pub fn t_energy_opt_exact(s: &Scenario, model: RecoveryModel) -> f64 {
    let ev = ExactEvaluator::new(s, model);
    optimise(s, |t| ev.breakdown(t).energy)
}

/// [`t_time_opt_exact`] seeded with the argmin of a previous, nearby
/// solve (the warm-start re-solve path under drift). Returns `None`
/// when the hint's grid bracket fails to validate — the caller falls
/// back to the cold scan. A validated hint refines the exact bracket
/// the cold scan would pick, so `Some(t)` is bit-identical to
/// [`t_time_opt_exact`] (see
/// [`grid_then_golden_warm`](super::optimize::grid_then_golden_warm)).
pub fn t_time_opt_exact_warm(s: &Scenario, model: RecoveryModel, hint: f64) -> Option<f64> {
    let ev = ExactEvaluator::new(s, model);
    optimise_warm(s, |t| ev.breakdown(t).makespan, hint)
}

/// Warm-started [`t_energy_opt_exact`]; same contract as
/// [`t_time_opt_exact_warm`].
pub fn t_energy_opt_exact_warm(s: &Scenario, model: RecoveryModel, hint: f64) -> Option<f64> {
    let ev = ExactEvaluator::new(s, model);
    optimise_warm(s, |t| ev.breakdown(t).energy, hint)
}

fn optimise(s: &Scenario, f: impl FnMut(f64) -> f64) -> f64 {
    // The exact objective is unimodal in t on (a, ∞): waste explodes both
    // as t -> a (checkpoint overhead) and t -> ∞ (e^{λt} re-execution).
    // 10 μ comfortably brackets the minimum.
    let lo = s.min_period().max(s.a() * 1.000001);
    let hi = (10.0 * s.mu).max(lo * 4.0);
    let (t, _) = grid_then_golden(f, lo, hi, 400, 1e-10 * hi);
    t.max(s.min_period())
}

/// [`optimise`] seeded from `hint`: identical bracket expressions and
/// post-processing, so a validated hint yields the cold argmin
/// bit-for-bit.
fn optimise_warm(s: &Scenario, f: impl FnMut(f64) -> f64, hint: f64) -> Option<f64> {
    let lo = s.min_period().max(s.a() * 1.000001);
    let hi = (10.0 * s.mu).max(lo * 4.0);
    let (t, _) = grid_then_golden_warm(f, lo, hi, 400, 1e-10 * hi, hint)?;
    Some(t.max(s.min_period()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{CheckpointParams, PowerParams};
    use crate::model::time::{t_final, t_time_opt_raw};
    use crate::model::energy::{e_final, t_energy_opt_raw};
    use crate::prop_assert;
    use crate::util::proptest::{check, Gen};
    use crate::util::stats::rel_err;

    fn scenario(mu: f64, omega: f64) -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, omega).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        Scenario::new(ckpt, power, mu, 10_000.0).unwrap()
    }

    #[test]
    fn warm_optima_are_bit_identical_to_cold() {
        // Seed with the cold argmin itself. When the hint rounds into
        // the cold scan's grid cell the bracket validates and must
        // refine bit-identically; when it rounds into a neighbouring
        // cell the strict-dip check falls back (also correct).
        let mut validated = 0;
        for model in [RecoveryModel::Ideal, RecoveryModel::Restarting] {
            for mu in [150.0, 600.0, 2_400.0] {
                let s = scenario(mu, 0.5);
                let cold_t = t_time_opt_exact(&s, model);
                let cold_e = t_energy_opt_exact(&s, model);
                if let Some(warm_t) = t_time_opt_exact_warm(&s, model, cold_t) {
                    assert_eq!(cold_t.to_bits(), warm_t.to_bits(), "time mu={mu}");
                    validated += 1;
                }
                if let Some(warm_e) = t_energy_opt_exact_warm(&s, model, cold_e) {
                    assert_eq!(cold_e.to_bits(), warm_e.to_bits(), "energy mu={mu}");
                    validated += 1;
                }
            }
        }
        assert!(validated > 0, "no warm bracket validated across 12 seeds");
        // A hopeless hint falls back.
        let s = scenario(600.0, 0.5);
        assert!(t_time_opt_exact_warm(&s, RecoveryModel::Ideal, f64::NAN).is_none());
    }

    #[test]
    fn agrees_with_first_order_when_mu_huge() {
        // lambda*T -> 0: exact == first-order to high precision.
        let s = scenario(1e6, 0.5);
        for t in [50.0, 200.0, 1000.0] {
            let exact = t_final_exact(&s, t, RecoveryModel::Ideal);
            let approx = t_final(&s, t);
            assert!(rel_err(exact, approx) < 1e-3, "t={t}: {exact} vs {approx}");
            let ee = e_final_exact(&s, t, RecoveryModel::Ideal);
            let ea = e_final(&s, t);
            assert!(rel_err(ee, ea) < 1e-3, "t={t}: {ee} vs {ea}");
        }
    }

    #[test]
    fn exceeds_first_order_at_small_mu() {
        // The neglected multi-failure terms make reality slower than the
        // first-order prediction at T comparable to mu... for makespan the
        // first-order form diverges as T -> 2 mu b while the exact stays
        // finite, so compare in the moderate regime.
        let s = scenario(120.0, 0.5);
        let t = 48.0;
        let exact = t_final_exact(&s, t, RecoveryModel::Ideal);
        let approx = t_final(&s, t);
        // First-order UNDER-estimates by a few percent here (matches the
        // simulator, which sided against the approximation).
        assert!(
            exact < approx,
            "expected first-order to over-correct: exact={exact} approx={approx}"
        );
        assert!(rel_err(exact, approx) > 0.01);
    }

    #[test]
    fn finite_beyond_first_order_domain() {
        let s = scenario(120.0, 0.5);
        let (_, hi) = s.domain();
        // Beyond 2*mu*b the first-order form is infinite; exact is not.
        assert!(t_final(&s, hi * 1.5).is_infinite());
        assert!(t_final_exact(&s, hi * 1.5, RecoveryModel::Ideal).is_finite());
    }

    #[test]
    fn restarting_recovery_costs_more() {
        let s = scenario(60.0, 0.5);
        let t = 40.0;
        let ideal = t_final_exact(&s, t, RecoveryModel::Ideal);
        let restarting = t_final_exact(&s, t, RecoveryModel::Restarting);
        assert!(restarting > ideal);
        // And the difference is second-order small: (D+R)/mu ~ 18%.
        assert!(rel_err(restarting, ideal) < 0.1);
    }

    #[test]
    fn exact_optima_near_first_order_at_large_mu() {
        let s = scenario(3000.0, 0.5);
        let tt = t_time_opt_exact(&s, RecoveryModel::Ideal);
        assert!(rel_err(tt, t_time_opt_raw(&s)) < 0.02, "{tt}");
        let te = t_energy_opt_exact(&s, RecoveryModel::Ideal);
        assert!(rel_err(te, t_energy_opt_raw(&s)) < 0.05, "{te}");
    }

    #[test]
    fn exact_optimum_diverges_from_eq1_at_small_mu() {
        // At mu = 6C the first-order optimum is visibly off: Eq. 1's
        // (mu - (D+R+wC)) factor over-shrinks the period, while the true
        // e^{lambda T} waste is better balanced by a longer one. Running
        // at the exact optimum beats running at Eq. 1's period under the
        // exact objective.
        let s = scenario(60.0, 0.5);
        let exact = t_time_opt_exact(&s, RecoveryModel::Ideal);
        let first = t_time_opt_raw(&s);
        assert!(rel_err(exact, first) > 0.1, "exact={exact} first={first}");
        let at_exact = t_final_exact(&s, exact, RecoveryModel::Ideal);
        let at_first = t_final_exact(&s, first, RecoveryModel::Ideal);
        assert!(at_exact < at_first, "{at_exact} !< {at_first}");
    }

    #[test]
    fn prop_exact_is_minimum_on_grid() {
        check("exact optimal period is argmin", 50, |g: &mut Gen| {
            let mu = g.f64_log_in(50.0, 1e5);
            let omega = g.f64_in(0.0, 1.0);
            let s = scenario(mu, omega);
            let topt = t_time_opt_exact(&s, RecoveryModel::Ideal);
            let best = t_final_exact(&s, topt, RecoveryModel::Ideal);
            for i in 1..50 {
                let t = s.min_period() + i as f64 * mu / 10.0;
                let v = t_final_exact(&s, t, RecoveryModel::Ideal);
                prop_assert!(g, best <= v * (1.0 + 1e-7), "T={t}: {v} < {best} (mu={mu})");
            }
            Ok(())
        });
    }

    #[test]
    fn hoisted_evaluator_matches_the_one_shot_path_bit_for_bit() {
        for (mu, omega) in [(120.0, 0.5), (60.0, 0.0), (3000.0, 1.0)] {
            let s = scenario(mu, omega);
            for model in [RecoveryModel::Ideal, RecoveryModel::Restarting] {
                let ev = ExactEvaluator::new(&s, model);
                for t in [12.0, 50.0, 200.0, 1000.0] {
                    // A reused evaluator and a fresh one-shot build must
                    // agree exactly at every period.
                    let a = ev.breakdown(t);
                    let b = exact_breakdown(&s, t, model);
                    assert_eq!(a, b, "mu={mu} omega={omega} t={t}");
                    assert_eq!(a.makespan.to_bits(), t_final_exact(&s, t, model).to_bits());
                    assert_eq!(a.energy.to_bits(), e_final_exact(&s, t, model).to_bits());
                }
            }
        }
    }

    #[test]
    fn phase_walls_sum_to_makespan() {
        let s = scenario(120.0, 0.5);
        let b = exact_breakdown(&s, 50.0, RecoveryModel::Restarting);
        let sum = b.compute_wall + b.checkpoint_wall + b.recovery_wall + b.down_wall;
        assert!(rel_err(sum, b.makespan) < 1e-12);
        assert!(b.failures > 0.0);
    }
}
