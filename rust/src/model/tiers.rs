//! Analytical time/energy model over a multi-level storage hierarchy.
//!
//! # The drain model
//!
//! Every checkpoint writes **synchronously** to tier 0 (node-local,
//! cost `C_0`, power `P_IO_0`) exactly as in the scalar model. Every
//! `κ_i`-th checkpoint additionally **drains asynchronously** to tier
//! `i` (cost `C_i`, power `P_IO_i`), overlapping compute: the drain
//! costs energy and *exposure* — a failure strikes the node, destroying
//! the local copies, and recovery restarts from the freshest copy on
//! the nearest surviving tier (tier 1 in expectation, read cost `R_1`,
//! already projected onto the scenario's scalar `R`).
//!
//! # First-order objectives
//!
//! Relative to the scalar first-order model the drain cadence `κ₁` adds
//! one term: the recovery copy is, on average, older than the latest
//! local checkpoint by the cadence aging plus the in-flight drain
//! latency,
//!
//! ```text
//! X(T, κ₁) = (κ₁ − 1)·T/2 + C_1 ,
//! ```
//!
//! so each failure loses an extra `X` of re-execution. Folding `X` into
//! the scalar `b = 1 − (D+R+ωC)/μ`:
//!
//! ```text
//! T_final(T, κ₁) = T_base·T / ((T−a)(b − X/μ − T/(2μ)))
//! ```
//!
//! The energy adds the drain work (`#checkpoints/κ_i` drains of
//! `C_i` minutes at `P_IO_i` each) and reprices recovery reads at the
//! recovery tier's power:
//!
//! ```text
//! E(T, κ) = P_Static·T_final
//!         + P_Cal·(T_base + F·(re_exec + X))
//!         + P_IO_0·(N·C_0 + F·C_0²/(2T))        N = T_base/(T−a)
//!         + P_IO_1·F·R_1  +  P_Down·F·D           F = T_final/μ
//!         + Σ_{i≥1} P_IO_i·C_i·N/κ_i
//! ```
//!
//! Both objectives are **κ-minimised envelopes**: cadences range over
//! `1..=`[`KAPPA_MAX`] with nested divisibility (`κ_{i-1} | κ_i` — a
//! drain to tier `i` sources a copy that reached tier `i−1`) and the
//! feasibility constraint `C_i ≤ κ_i·T` (the drain device must keep
//! up). Time is always minimised at the smallest feasible `κ₁` (X is
//! increasing in κ); energy can prefer `κ₁ > 1` when deep-tier I/O
//! power dominates — that asymmetry is the tiered analogue of the
//! paper's `T_Energy_opt ≥ T_Time_opt` headline.
//!
//! The envelope scans are **bound-pruned**, not exhaustive: the time
//! objective ignores every cadence but `κ₁` (one evaluation per
//! subtree), and the energy scan collapses each innermost cadence run
//! to a drain-cost lower bound at its far end, skipping runs that
//! cannot beat the running best — bit-identical to the exhaustive scan
//! by construction (see [`min_energy_cadence`]), with the
//! evaluated/skipped split exported on the
//! `ckpt_tier_envelope_*_total` counters.
//!
//! # The optimal period vector
//!
//! [`time_plan`]/[`energy_plan`] minimise the envelopes numerically
//! (same `grid_then_golden` machinery as the exact backend) and return
//! a [`TierPlan`] — the period *and* the per-tier cadence vector —
//! memoised process-wide by the scenario's exact key bits
//! ([`tier_plan_memo_stats`] feeds the telemetry cache table).
//!
//! Scalar scenarios never reach this module: [`super::time`] /
//! [`super::energy`] intercept on [`Scenario::hierarchy`] being `Some`,
//! and 1-level hierarchies canonicalise to `Scalar` at construction, so
//! the degenerate case is the scalar code path itself, bit for bit.

use crate::storage::{TierHierarchy, MAX_TIERS};
use crate::telemetry::registry::metrics;
use crate::util::memo::{MemoStats, PureMemo};

use super::energy::re_exec_per_failure;
use super::optimize::grid_then_golden;
use super::params::{ModelError, Scenario};

/// Largest drain cadence considered by the envelopes. Beyond ~64 the
/// aging term `(κ−1)T/2` dwarfs any drain-energy saving on every
/// realistic preset.
pub const KAPPA_MAX: u32 = 64;

/// The solved operating point of a tiered scenario: the checkpoint
/// period plus the drain cadence of every tier (`kappa[0] == 1` by
/// definition — every checkpoint lands on tier 0; entries past the
/// hierarchy depth stay 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierPlan {
    pub period: f64,
    pub kappa: [u32; MAX_TIERS],
}

const PLAN_TIME_TAG: u64 = 1;
const PLAN_ENERGY_TAG: u64 = 2;

/// Tier-plan memo: variable-length exact-bits keys (tag + scenario key
/// words), [`TierPlan`] values.
static TIER_PLAN_MEMO: PureMemo<Vec<u64>, TierPlan> = PureMemo::new(16_384);

/// Hit/miss/clear counters and live size of the tier-plan memo (the
/// telemetry registry's "tier plan memo" cache row).
pub fn tier_plan_memo_stats() -> (MemoStats, usize) {
    (TIER_PLAN_MEMO.stats(), TIER_PLAN_MEMO.len())
}

/// Live entries per backing shard (`ckpt_cache_shard_entries`).
pub fn tier_plan_memo_shard_entries() -> Vec<usize> {
    TIER_PLAN_MEMO.shard_entries()
}

fn plan_key(tag: u64, s: &Scenario) -> Vec<u64> {
    let mut k = Vec::with_capacity(32);
    k.push(tag);
    k.extend(s.key_words());
    k
}

/// Extra expected loss per failure from draining every `κ₁`-th
/// checkpoint: cadence aging plus in-flight drain latency.
pub fn extra_loss_per_failure(h: &TierHierarchy, t: f64, kappa1: u32) -> f64 {
    (kappa1 - 1) as f64 * t / 2.0 + h.tier(1).c
}

/// Enumerate feasible cadence vectors (nested divisibility, drain
/// keeps up) in deterministic order.
fn for_each_cadence(h: &TierHierarchy, t: f64, mut f: impl FnMut(&[u32; MAX_TIERS])) {
    let n = h.len();
    let feasible = |i: usize, k: u32| h.tier(i).c <= k as f64 * t;
    let mut kappa = [1u32; MAX_TIERS];
    for k1 in 1..=KAPPA_MAX {
        if !feasible(1, k1) {
            continue;
        }
        kappa[1] = k1;
        if n == 2 {
            f(&kappa);
            continue;
        }
        let mut k2 = k1;
        while k2 <= KAPPA_MAX {
            if feasible(2, k2) {
                kappa[2] = k2;
                if n == 3 {
                    f(&kappa);
                } else {
                    let mut k3 = k2;
                    while k3 <= KAPPA_MAX {
                        if feasible(3, k3) {
                            kappa[3] = k3;
                            f(&kappa);
                        }
                        k3 += k2;
                    }
                    kappa[3] = 1;
                }
            }
            k2 += k1;
        }
        kappa[2] = 1;
    }
}

/// `T_final` at a fixed cadence vector (only `κ₁` matters for time).
/// `+inf` outside the (cadence-dependent) domain.
pub fn t_final_at(s: &Scenario, h: &TierHierarchy, t: f64, kappa: &[u32; MAX_TIERS]) -> f64 {
    let a = s.a();
    let x = extra_loss_per_failure(h, t, kappa[1]);
    let b_eff = s.b() - x / s.mu;
    if t <= a || b_eff - t / (2.0 * s.mu) <= 0.0 {
        return f64::INFINITY;
    }
    s.t_base * t / ((t - a) * (b_eff - t / (2.0 * s.mu)))
}

/// `E_final` at a fixed cadence vector. `+inf` outside the domain or
/// when the cadence is infeasible.
pub fn e_final_at(s: &Scenario, h: &TierHierarchy, t: f64, kappa: &[u32; MAX_TIERS]) -> f64 {
    let tf = t_final_at(s, h, t, kappa);
    if !tf.is_finite() {
        return f64::INFINITY;
    }
    let f = tf / s.mu;
    let c0 = s.ckpt.c;
    let x = extra_loss_per_failure(h, t, kappa[1]);
    let n_ckpt = s.t_base / (t - s.a());
    let t_cal = s.t_base + f * (re_exec_per_failure(s, t) + x);
    // Synchronous tier-0 writes (plus the interrupted partial write).
    let e_write = s.power.p_io * (n_ckpt * c0 + f * c0 * c0 / (2.0 * t));
    // Recovery reads the nearest drained tier at that tier's power.
    let e_recover = h.tier(1).p_io * f * s.ckpt.r;
    // Asynchronous drains: every κ_i-th checkpoint, C_i minutes at P_IO_i.
    let mut e_drain = 0.0;
    for i in 1..h.len() {
        e_drain += h.tier(i).p_io * h.tier(i).c * n_ckpt / kappa[i] as f64;
    }
    t_cal * s.power.p_cal
        + e_write
        + e_recover
        + e_drain
        + f * s.ckpt.d * s.power.p_down
        + tf * s.power.p_static
}

/// Evaluation/skip counts from one envelope scan. `evaluated +
/// skipped` equals the size of the full divisibility-constrained
/// feasible cadence set, so `skipped / (evaluated + skipped)` is the
/// pruning rate. Summed process-wide into the
/// `ckpt_tier_envelope_{evaluated,skipped}_total` counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScanStats {
    /// Cadence vectors whose objective was actually computed.
    pub evaluated: u64,
    /// Cadence vectors pruned before evaluation — by the drain-cost
    /// lower bound (energy) or by collapsing a `κ₁` subtree of equal
    /// values to its representative (time).
    pub skipped: u64,
}

impl ScanStats {
    fn publish(self) -> Self {
        metrics::TIER_ENVELOPE_EVALUATED_TOTAL.add(self.evaluated);
        metrics::TIER_ENVELOPE_SKIPPED_TOTAL.add(self.skipped);
        self
    }
}

/// Number of feasible cadence vectors in the `κ₁` subtree — what an
/// exhaustive scan would have evaluated there.
fn subtree_len(h: &TierHierarchy, t: f64, k1: u32) -> u64 {
    let n = h.len();
    let feasible = |i: usize, k: u32| h.tier(i).c <= k as f64 * t;
    if n == 2 {
        return 1;
    }
    let mut count = 0u64;
    let mut k2 = k1;
    while k2 <= KAPPA_MAX {
        if feasible(2, k2) {
            if n == 3 {
                count += 1;
            } else {
                let mut k3 = k2;
                while k3 <= KAPPA_MAX {
                    if feasible(3, k3) {
                        count += 1;
                    }
                    k3 += k2;
                }
            }
        }
        k2 += k1;
    }
    count
}

/// First feasible cadence vector of the `κ₁` subtree in enumeration
/// order, if any — the vector an exhaustive first-found scan records
/// for a subtree whose objective values are all equal.
fn first_completion(h: &TierHierarchy, t: f64, k1: u32) -> Option<[u32; MAX_TIERS]> {
    let n = h.len();
    let feasible = |i: usize, k: u32| h.tier(i).c <= k as f64 * t;
    let mut kappa = [1u32; MAX_TIERS];
    kappa[1] = k1;
    if n == 2 {
        return Some(kappa);
    }
    let mut k2 = k1;
    while k2 <= KAPPA_MAX {
        if feasible(2, k2) {
            kappa[2] = k2;
            if n == 3 {
                return Some(kappa);
            }
            let mut k3 = k2;
            while k3 <= KAPPA_MAX {
                if feasible(3, k3) {
                    kappa[3] = k3;
                    return Some(kappa);
                }
                k3 += k2;
            }
        }
        k2 += k1;
    }
    None
}

/// Time envelope scan: [`t_final_at`] ignores every cadence but `κ₁`,
/// so each subtree collapses to one evaluation at its first feasible
/// completion — the exact vector the exhaustive first-found scan would
/// record, since all of a subtree's values share `κ₁` bit for bit and
/// the strict `<` update keeps the first occurrence. Returns
/// `(min, argmin, stats)`; the argmin is `[0; MAX_TIERS]` when every
/// feasible vector is out of domain (`+inf`), matching the exhaustive
/// scan's never-updated state.
pub fn min_time_cadence(
    s: &Scenario,
    h: &TierHierarchy,
    t: f64,
) -> (f64, [u32; MAX_TIERS], ScanStats) {
    let feasible = |i: usize, k: u32| h.tier(i).c <= k as f64 * t;
    let mut best_v = f64::INFINITY;
    let mut best_k = [0u32; MAX_TIERS];
    let mut stats = ScanStats::default();
    for k1 in 1..=KAPPA_MAX {
        if !feasible(1, k1) {
            continue;
        }
        let Some(first) = first_completion(h, t, k1) else {
            continue;
        };
        let v = t_final_at(s, h, t, &first);
        stats.evaluated += 1;
        stats.skipped += subtree_len(h, t, k1) - 1;
        if v < best_v {
            best_v = v;
            best_k = first;
        }
    }
    (best_v, best_k, stats.publish())
}

/// Shared state of one bound-pruned energy scan (see
/// [`min_energy_cadence`]).
struct EnergyScan<'a> {
    s: &'a Scenario,
    h: &'a TierHierarchy,
    t: f64,
    best_v: f64,
    best_k: [u32; MAX_TIERS],
    stats: ScanStats,
}

impl EnergyScan<'_> {
    fn feasible(&self, i: usize, k: u32) -> bool {
        self.h.tier(i).c <= k as f64 * self.t
    }

    /// Scan one innermost run — the multiples of `step` written into
    /// `kappa[slot]` — without walking it. Feasibility (`C ≤ κ·t`) is
    /// monotone in κ, so the feasible multiples form a suffix
    /// `m_lo..=m_hi`; the objective varies along the run only through
    /// that tier's drain term `P_IO·C·N/κ`, monotone decreasing in κ
    /// (and round-to-nearest `+`/`/` are monotone, so the *computed*
    /// values are non-increasing bit-wise). One evaluation at the
    /// run's end therefore yields the run minimum — a drain-cost lower
    /// bound for the whole run. Runs that cannot beat the running best
    /// are skipped wholesale; a winning run's argmin — the first
    /// vector attaining the minimum, exactly what the exhaustive
    /// scan's strict `<` update records — is recovered by bisection.
    fn run(&mut self, kappa: &mut [u32; MAX_TIERS], slot: usize, step: u32) {
        let m_hi = KAPPA_MAX / step;
        let mut m_lo = 1u32;
        while m_lo <= m_hi && !self.feasible(slot, m_lo * step) {
            m_lo += 1;
        }
        if m_lo > m_hi {
            return;
        }
        let len = (m_hi - m_lo + 1) as u64;
        kappa[slot] = m_hi * step;
        let v_end = e_final_at(self.s, self.h, self.t, kappa);
        self.stats.evaluated += 1;
        if v_end >= self.best_v {
            // Nothing here can beat the best: the run is non-increasing
            // toward `v_end ≥ best`, and the strict `<` update would
            // have ignored every vector in it.
            self.stats.skipped += len - 1;
        } else {
            // Bisect for the first multiple attaining `v_end` —
            // attainment (bit-equality with the run minimum) is a
            // monotone predicate along a non-increasing run.
            let (mut lo, mut hi) = (m_lo, m_hi);
            let mut evals = 0u64;
            while lo < hi {
                let mid = lo + (hi - lo) / 2;
                kappa[slot] = mid * step;
                evals += 1;
                if e_final_at(self.s, self.h, self.t, kappa).to_bits() == v_end.to_bits() {
                    hi = mid;
                } else {
                    lo = mid + 1;
                }
            }
            self.stats.evaluated += evals;
            self.stats.skipped += len - 1 - evals;
            self.best_v = v_end;
            kappa[slot] = lo * step;
            self.best_k = *kappa;
        }
        kappa[slot] = 1;
    }
}

/// Bound-pruned energy envelope scan: the minimum of [`e_final_at`]
/// over the feasible cadence set, its argmin, and the scan counts —
/// bit-identical (value *and* argmin) to the exhaustive first-found
/// scan ([`e_final_tiered_reference`]); see [`EnergyScan::run`] for
/// why the pruning cannot perturb either.
pub fn min_energy_cadence(
    s: &Scenario,
    h: &TierHierarchy,
    t: f64,
) -> (f64, [u32; MAX_TIERS], ScanStats) {
    let n = h.len();
    let mut scan = EnergyScan {
        s,
        h,
        t,
        best_v: f64::INFINITY,
        best_k: [0u32; MAX_TIERS],
        stats: ScanStats::default(),
    };
    let mut kappa = [1u32; MAX_TIERS];
    for k1 in 1..=KAPPA_MAX {
        if !scan.feasible(1, k1) {
            continue;
        }
        kappa[1] = k1;
        if n == 2 {
            // One vector per `κ₁`: evaluate it directly.
            let v = e_final_at(s, h, t, &kappa);
            scan.stats.evaluated += 1;
            if v < scan.best_v {
                scan.best_v = v;
                scan.best_k = kappa;
            }
        } else if n == 3 {
            scan.run(&mut kappa, 2, k1);
        } else {
            let mut k2 = k1;
            while k2 <= KAPPA_MAX {
                if scan.feasible(2, k2) {
                    kappa[2] = k2;
                    scan.run(&mut kappa, 3, k2);
                }
                k2 += k1;
            }
            kappa[2] = 1;
        }
    }
    (scan.best_v, scan.best_k, scan.stats.publish())
}

/// Exhaustive time envelope scan — the pre-pruning reference the tests
/// hold [`min_time_cadence`] against, bit for bit. Not public API.
#[doc(hidden)]
pub fn t_final_tiered_reference(
    s: &Scenario,
    h: &TierHierarchy,
    t: f64,
) -> (f64, [u32; MAX_TIERS]) {
    let mut best_v = f64::INFINITY;
    let mut best_k = [0u32; MAX_TIERS];
    for_each_cadence(h, t, |kappa| {
        let v = t_final_at(s, h, t, kappa);
        if v < best_v {
            best_v = v;
            best_k = *kappa;
        }
    });
    (best_v, best_k)
}

/// Exhaustive energy envelope scan — reference for
/// [`min_energy_cadence`]. Not public API.
#[doc(hidden)]
pub fn e_final_tiered_reference(
    s: &Scenario,
    h: &TierHierarchy,
    t: f64,
) -> (f64, [u32; MAX_TIERS]) {
    let mut best_v = f64::INFINITY;
    let mut best_k = [0u32; MAX_TIERS];
    for_each_cadence(h, t, |kappa| {
        let v = e_final_at(s, h, t, kappa);
        if v < best_v {
            best_v = v;
            best_k = *kappa;
        }
    });
    (best_v, best_k)
}

/// κ-minimised expected-time envelope (the tiered `T_final`).
pub fn t_final_tiered(s: &Scenario, h: &TierHierarchy, t: f64) -> f64 {
    min_time_cadence(s, h, t).0
}

/// κ-minimised expected-energy envelope (the tiered `E_final`).
pub fn e_final_tiered(s: &Scenario, h: &TierHierarchy, t: f64) -> f64 {
    min_energy_cadence(s, h, t).0
}

/// The energy-minimising cadence vector at a fixed period — what the
/// DES drains with. Pure function of `(scenario, hierarchy, period)`;
/// deterministic first-found tie-break. Falls back to the smallest
/// feasible cadence when the period is outside the analytic domain (a
/// simulation can still run there).
pub fn cadence_for(s: &Scenario, h: &TierHierarchy, t: f64) -> [u32; MAX_TIERS] {
    let (_, mut best, _) = min_energy_cadence(s, h, t);
    if best[0] == 0 {
        // Outside the analytic domain: first feasible cadence, or the
        // slowest one if even KAPPA_MAX cannot keep up.
        let mut fallback: Option<[u32; MAX_TIERS]> = None;
        for_each_cadence(h, t, |kappa| {
            if fallback.is_none() {
                fallback = Some(*kappa);
            }
        });
        best = fallback.unwrap_or_else(|| {
            let mut k = [KAPPA_MAX; MAX_TIERS];
            k[0] = 1;
            k
        });
    }
    best
}

enum Objective {
    Time,
    Energy,
}

fn solve_plan(s: &Scenario, h: &TierHierarchy, obj: Objective) -> TierPlan {
    let (lo, hi) = s.domain();
    let lo = lo.max(s.min_period() * 0.5).max(lo + 1e-9 * (hi - lo));
    let hi = hi * (1.0 - 1e-9);
    let period = if lo >= hi {
        s.min_period()
    } else {
        let f = |t: f64| match obj {
            Objective::Time => t_final_tiered(s, h, t),
            Objective::Energy => e_final_tiered(s, h, t),
        };
        let (t, _) = grid_then_golden(f, lo, hi, 400, 1e-9 * (hi - lo));
        t
    };
    let period = s.clamp_period(period).unwrap_or(s.min_period());
    let kappa = match obj {
        Objective::Energy => cadence_for(s, h, period),
        Objective::Time => {
            // Time is minimised at the smallest feasible cadence.
            let (_, best, _) = min_time_cadence(s, h, period);
            if best[0] == 0 {
                cadence_for(s, h, period)
            } else {
                best
            }
        }
    };
    TierPlan { period, kappa }
}

/// Time-optimal operating point (period + cadences), memoised by exact
/// scenario bits. Errors when no feasible period exists at all (same
/// gate as the scalar `clamp_period`).
pub fn time_plan(s: &Scenario, h: &TierHierarchy) -> Result<TierPlan, ModelError> {
    s.clamp_period(s.min_period())?;
    Ok(TIER_PLAN_MEMO
        .get_or_compute(plan_key(PLAN_TIME_TAG, s), || solve_plan(s, h, Objective::Time)))
}

/// Energy-optimal operating point (period + cadences), memoised.
pub fn energy_plan(s: &Scenario, h: &TierHierarchy) -> Result<TierPlan, ModelError> {
    s.clamp_period(s.min_period())?;
    Ok(TIER_PLAN_MEMO
        .get_or_compute(plan_key(PLAN_ENERGY_TAG, s), || solve_plan(s, h, Objective::Energy)))
}

/// Tiered time-optimal period (the `AlgoT` period for a tiered
/// scenario); [`time_plan`] carries the cadences.
pub fn t_time_opt_tiered(s: &Scenario, h: &TierHierarchy) -> Result<f64, ModelError> {
    Ok(time_plan(s, h)?.period)
}

/// Tiered energy-optimal period (the `AlgoE` period).
pub fn t_energy_opt_tiered(s: &Scenario, h: &TierHierarchy) -> Result<f64, ModelError> {
    Ok(energy_plan(s, h)?.period)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{CheckpointParams, PowerParams};
    use crate::model::{e_final, t_final};
    use crate::storage::TierSpec;
    use crate::util::stats::rel_err;

    fn tiered_scenario() -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(1.0, 1.0, 10.0, 0.0).unwrap();
        Scenario::with_tier_specs(
            ckpt,
            power,
            300.0,
            10_000.0,
            &[TierSpec::new(1.0, 1.0, 30.0), TierSpec::new(10.0, 10.0, 100.0)],
        )
        .unwrap()
    }

    fn flattened_equivalent() -> Scenario {
        // Same effective projection, no hierarchy: C=1 (tier-0 write),
        // R=10 (tier-1 restart), P_IO=30 (tier-0 power).
        let t = tiered_scenario();
        t.scalar_effective()
    }

    #[test]
    fn tiered_time_reduces_to_scalar_plus_drain_loss() {
        let s = tiered_scenario();
        let h = *s.hierarchy().unwrap();
        let flat = flattened_equivalent();
        let t = 60.0;
        // κ₁=1: the only difference from the flat projection is the
        // in-flight drain latency C_1 folded into b.
        let kappa = [1u32; MAX_TIERS];
        let direct = t_final_at(&s, &h, t, &kappa);
        let b_eff = flat.b() - h.tier(1).c / flat.mu;
        let expect = flat.t_base * t / ((t - flat.a()) * (b_eff - t / (2.0 * flat.mu)));
        assert!(rel_err(direct, expect) < 1e-12);
        // And the envelope picks κ₁=1 for time.
        assert_eq!(t_final_tiered(&s, &h, t).to_bits(), direct.to_bits());
        // Tiered time is worse than the flat projection (drain exposure)
        // at equal parameters...
        assert!(t_final_tiered(&s, &h, t) > t_final(&flat, t));
    }

    #[test]
    fn tiered_energy_envelope_beats_every_fixed_cadence() {
        let s = tiered_scenario();
        let h = *s.hierarchy().unwrap();
        let t = 60.0;
        let env = e_final_tiered(&s, &h, t);
        assert!(env.is_finite());
        for k1 in [1u32, 2, 4, 8, 16, 64] {
            let mut kappa = [1u32; MAX_TIERS];
            kappa[1] = k1;
            assert!(env <= e_final_at(&s, &h, t, &kappa) + 1e-12, "k1={k1}");
        }
    }

    #[test]
    fn expensive_deep_tier_prefers_sparse_drains() {
        // PFS I/O power dominates: the energy-minimising cadence drains
        // less often than every checkpoint.
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(1.0, 1.0, 10.0, 0.0).unwrap();
        let s = Scenario::with_tier_specs(
            ckpt,
            power,
            300.0,
            10_000.0,
            &[TierSpec::new(1.0, 1.0, 5.0), TierSpec::new(10.0, 10.0, 500.0)],
        )
        .unwrap();
        let h = *s.hierarchy().unwrap();
        let kappa = cadence_for(&s, &h, 40.0);
        assert!(kappa[1] > 1, "kappa={kappa:?}");
    }

    #[test]
    fn plans_are_memoised_bit_stably() {
        let s = tiered_scenario();
        let h = *s.hierarchy().unwrap();
        let p1 = energy_plan(&s, &h).unwrap();
        let p2 = energy_plan(&s, &h).unwrap();
        assert_eq!(p1.period.to_bits(), p2.period.to_bits());
        assert_eq!(p1.kappa, p2.kappa);
        let (stats, len) = tier_plan_memo_stats();
        assert!(stats.hits >= 1, "second call should hit");
        assert!(len >= 1);
    }

    #[test]
    fn plan_periods_minimise_their_envelopes() {
        let s = tiered_scenario();
        let h = *s.hierarchy().unwrap();
        let tp = time_plan(&s, &h).unwrap();
        let ep = energy_plan(&s, &h).unwrap();
        let (lo, hi) = s.domain();
        for i in 1..100 {
            let t = (lo + (hi - lo) * i as f64 / 100.0).max(s.min_period());
            if t >= hi {
                break;
            }
            assert!(
                t_final_tiered(&s, &h, tp.period) <= t_final_tiered(&s, &h, t) * (1.0 + 1e-6),
                "time plan beaten at t={t}"
            );
            assert!(
                e_final_tiered(&s, &h, ep.period) <= e_final_tiered(&s, &h, t) * (1.0 + 1e-6),
                "energy plan beaten at t={t}"
            );
        }
        assert_eq!(tp.kappa[0], 1);
        assert_eq!(ep.kappa[0], 1);
    }

    #[test]
    fn energy_period_at_least_time_period_with_expensive_io() {
        let s = tiered_scenario();
        let h = *s.hierarchy().unwrap();
        let tt = t_time_opt_tiered(&s, &h).unwrap();
        let te = t_energy_opt_tiered(&s, &h).unwrap();
        assert!(te >= tt * (1.0 - 1e-9), "te={te} tt={tt}");
    }

    #[test]
    fn two_tier_beats_flattened_single_tier_on_both_objectives() {
        // The headline claim: splitting a PFS-only configuration into
        // SSD + PFS strictly improves both optima — cheap local writes
        // shrink the failure-free overhead, sparse drains shrink the
        // I/O energy.
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(1.0, 1.0, 100.0, 0.0).unwrap();
        // Flat: everything on the PFS.
        let flat = Scenario::with_tier_specs(
            ckpt,
            power,
            300.0,
            10_000.0,
            &[TierSpec::new(10.0, 10.0, 100.0)],
        )
        .unwrap();
        assert!(flat.tiers.is_scalar());
        // Tiered: local SSD in front of the same PFS.
        let tiered = Scenario::with_tier_specs(
            ckpt,
            power,
            300.0,
            10_000.0,
            &[TierSpec::new(1.0, 1.0, 30.0), TierSpec::new(10.0, 10.0, 100.0)],
        )
        .unwrap();
        let h = *tiered.hierarchy().unwrap();
        let flat_tt = crate::model::t_time_opt(&flat).unwrap();
        let flat_te = crate::model::t_energy_opt(&flat).unwrap();
        let tier_tp = time_plan(&tiered, &h).unwrap();
        let tier_ep = energy_plan(&tiered, &h).unwrap();
        assert!(
            t_final_tiered(&tiered, &h, tier_tp.period) < t_final(&flat, flat_tt),
            "tiered time not better"
        );
        assert!(
            e_final_tiered(&tiered, &h, tier_ep.period) < e_final(&flat, flat_te),
            "tiered energy not better"
        );
    }

    #[test]
    fn infeasible_small_period_is_infinite() {
        let s = tiered_scenario();
        let h = *s.hierarchy().unwrap();
        // Below a = (1-ω)C_0 the envelope is infinite.
        assert!(t_final_tiered(&s, &h, s.a() * 0.5).is_infinite());
        assert!(e_final_tiered(&s, &h, s.a() * 0.5).is_infinite());
    }

    fn three_tier_scenario() -> Scenario {
        // SSD + burst buffer + PFS — the shape of the tiers-3 preset.
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(1.0, 1.0, 10.0, 0.0).unwrap();
        Scenario::with_tier_specs(
            ckpt,
            power,
            300.0,
            10_000.0,
            &[
                TierSpec::new(1.0, 1.0, 3.0),
                TierSpec::new(2.0, 3.0, 6.0),
                TierSpec::new(10.0, 10.0, 10.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn pruned_scans_match_the_exhaustive_reference_bit_for_bit() {
        for s in [tiered_scenario(), three_tier_scenario()] {
            let h = *s.hierarchy().unwrap();
            for t in [s.a() * 0.5, 20.0, 40.0, 60.0, 90.0, 150.0] {
                let (tv, tk, _) = min_time_cadence(&s, &h, t);
                let (rtv, rtk) = t_final_tiered_reference(&s, &h, t);
                assert_eq!(tv.to_bits(), rtv.to_bits(), "time min at t={t}");
                assert_eq!(tk, rtk, "time argmin at t={t}");
                let (ev, ek, _) = min_energy_cadence(&s, &h, t);
                let (rev, rek) = e_final_tiered_reference(&s, &h, t);
                assert_eq!(ev.to_bits(), rev.to_bits(), "energy min at t={t}");
                assert_eq!(ek, rek, "energy argmin at t={t}");
            }
        }
    }

    #[test]
    fn scan_counts_partition_the_full_envelope() {
        // evaluated + skipped must equal the exhaustive scan's
        // evaluation count, for both objectives.
        let s = three_tier_scenario();
        let h = *s.hierarchy().unwrap();
        let t = 60.0;
        let mut full = 0u64;
        for_each_cadence(&h, t, |_| full += 1);
        let (_, _, ts) = min_time_cadence(&s, &h, t);
        let (_, _, es) = min_energy_cadence(&s, &h, t);
        assert_eq!(ts.evaluated + ts.skipped, full);
        assert_eq!(es.evaluated + es.skipped, full);
    }

    #[test]
    fn pruning_skips_more_than_half_the_envelope_on_three_tiers() {
        let s = three_tier_scenario();
        let h = *s.hierarchy().unwrap();
        let mut total = ScanStats::default();
        for t in [30.0, 45.0, 60.0, 90.0] {
            let (_, _, ts) = min_time_cadence(&s, &h, t);
            let (_, _, es) = min_energy_cadence(&s, &h, t);
            total.evaluated += ts.evaluated + es.evaluated;
            total.skipped += ts.skipped + es.skipped;
        }
        assert!(
            total.skipped > total.evaluated,
            "pruning too weak: {total:?}"
        );
        // And the pruning never perturbs the solved plans: the plans
        // still minimise the *reference* envelopes (checked bit-wise
        // against the pruned scan in the test above).
        let tp = time_plan(&s, &h).unwrap();
        let ep = energy_plan(&s, &h).unwrap();
        assert_eq!(tp.kappa[0], 1);
        assert_eq!(ep.kappa[0], 1);
    }
}
