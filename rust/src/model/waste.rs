//! Waste decomposition: *where* the overhead goes at a given period.
//!
//! The paper's trade-off is easiest to see as a budget: every minute
//! beyond `T_base` is either checkpoint overhead (grows as `1/T`) or
//! failure-induced loss (grows as `T`), and every Joule beyond the
//! baseline splits the same way but weighted by different powers —
//! checkpoints cost `P_IO`-heavy time while re-execution costs
//! `P_Cal`-heavy time. AlgoE moves the period to rebalance the *energy*
//! budget, which is exactly why it stretches `T` when `ρ > 1`.
//!
//! Used by the `sweep` CLI (`--breakdown`) and the `exascale_study`
//! discussion; tested against the closed forms it decomposes.

use super::energy::{io_per_failure, phase_times, re_exec_per_failure};
use super::params::Scenario;
use super::time::{t_ff, t_final};

/// Additive decomposition of time and energy overheads at period `t`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WasteBreakdown {
    /// Expected makespan and the failure-free baseline `T_base`.
    pub makespan: f64,
    /// Time lost to periodic checkpointing alone (`T_ff − T_base`).
    pub time_checkpointing: f64,
    /// Additional time lost to failures (`T_final − T_ff`).
    pub time_failures: f64,
    /// Energy above `T_base · (P_Static + P_Cal)` split by cause.
    pub energy_baseline: f64,
    pub energy_checkpointing: f64,
    pub energy_failures: f64,
    /// Fractions of makespan (diagnostics; sum with `t_base/makespan` to 1).
    pub frac_checkpointing: f64,
    pub frac_failures: f64,
}

/// Decompose time and energy waste at period `t`.
///
/// Energy attribution: the checkpointing share is what a failure-free
/// run at period `t` would consume above baseline (ckpt I/O time at
/// `P_IO` plus the stretched static time, minus the `ωC` work credit);
/// the failure share is the remainder of `E_final`.
pub fn waste_breakdown(s: &Scenario, t: f64) -> WasteBreakdown {
    let makespan = t_final(s, t);
    let ff = t_ff(s, t);
    let p = &s.power;

    let energy_baseline = s.t_base * (p.p_static + p.p_cal);

    // Failure-free run at period t: T_ff wall time; CPU busy exactly
    // T_base work-units; checkpoints active C per period.
    let n_periods = s.t_base / (t - s.a());
    let ckpt_wall = n_periods * s.ckpt.c;
    let e_ff = p.p_static * ff + p.p_cal * s.t_base + p.p_io * ckpt_wall;
    let energy_checkpointing = e_ff - energy_baseline;

    let ph = phase_times(s, t);
    let e_total = ph.t_cal * p.p_cal
        + ph.t_io * p.p_io
        + ph.t_down * p.p_down
        + ph.t_final * p.p_static;
    let energy_failures = e_total - e_ff;

    WasteBreakdown {
        makespan,
        time_checkpointing: ff - s.t_base,
        time_failures: makespan - ff,
        energy_baseline,
        energy_checkpointing,
        energy_failures,
        frac_checkpointing: (ff - s.t_base) / makespan,
        frac_failures: (makespan - ff) / makespan,
    }
}

/// The two marginal energy prices the optimum balances (per failure):
/// CPU re-execution energy and I/O loss energy. Diagnostic used by the
/// study example.
pub fn per_failure_energy(s: &Scenario, t: f64) -> (f64, f64) {
    let cpu = re_exec_per_failure(s, t) * s.power.p_cal;
    let io = io_per_failure(s, t) * s.power.p_io;
    (cpu, io)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::energy::e_final;
    use crate::model::params::{CheckpointParams, PowerParams};
    use crate::model::{t_energy_opt, t_time_opt};
    use crate::util::stats::rel_err;

    fn scenario(mu: f64) -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        Scenario::new(ckpt, power, mu, 10_000.0).unwrap()
    }

    #[test]
    fn time_parts_sum_to_makespan() {
        let s = scenario(300.0);
        for t in [40.0, 80.0, 160.0] {
            let w = waste_breakdown(&s, t);
            let sum = s.t_base + w.time_checkpointing + w.time_failures;
            assert!(rel_err(sum, w.makespan) < 1e-12, "t={t}");
            assert!(w.time_checkpointing > 0.0 && w.time_failures > 0.0);
        }
    }

    #[test]
    fn energy_parts_sum_to_e_final() {
        let s = scenario(300.0);
        for t in [40.0, 80.0, 160.0] {
            let w = waste_breakdown(&s, t);
            let sum = w.energy_baseline + w.energy_checkpointing + w.energy_failures;
            assert!(rel_err(sum, e_final(&s, t)) < 1e-9, "t={t}");
        }
    }

    #[test]
    fn checkpoint_share_falls_with_t_failure_share_rises() {
        let s = scenario(300.0);
        let a = waste_breakdown(&s, 40.0);
        let b = waste_breakdown(&s, 160.0);
        assert!(b.time_checkpointing < a.time_checkpointing);
        assert!(b.time_failures > a.time_failures);
        assert!(b.energy_checkpointing < a.energy_checkpointing);
        assert!(b.energy_failures > a.energy_failures);
    }

    #[test]
    fn algo_e_spends_less_on_checkpointing_than_algo_t() {
        // The whole point of AlgoE at rho > 1: buy fewer expensive
        // checkpoints with cheaper re-execution.
        let s = scenario(300.0);
        let wt = waste_breakdown(&s, t_time_opt(&s).unwrap());
        let we = waste_breakdown(&s, t_energy_opt(&s).unwrap());
        assert!(we.energy_checkpointing < wt.energy_checkpointing);
        assert!(we.energy_failures > wt.energy_failures);
        // And in total AlgoE wins on energy.
        let et = wt.energy_baseline + wt.energy_checkpointing + wt.energy_failures;
        let ee = we.energy_baseline + we.energy_checkpointing + we.energy_failures;
        assert!(ee < et);
    }

    #[test]
    fn per_failure_prices_cross_with_t() {
        let s = scenario(300.0);
        // Small T: IO loss per failure dominates CPU re-exec; large T:
        // re-exec dominates.
        let (cpu_small, io_small) = per_failure_energy(&s, 15.0);
        let (cpu_large, io_large) = per_failure_energy(&s, 250.0);
        assert!(io_small > cpu_small, "{io_small} vs {cpu_small}");
        assert!(cpu_large > io_large, "{cpu_large} vs {io_large}");
    }
}
