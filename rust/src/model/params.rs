//! Model parameters (§2 of the paper) and their validity checks.

use crate::storage::{TierConfig, TierHierarchy};

/// Resilience parameters (§2.1). All times in minutes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointParams {
    /// Checkpoint duration `C`.
    pub c: f64,
    /// Recovery duration `R` (time to read the last checkpoint).
    pub r: f64,
    /// Downtime `D` (reboot / spare setup).
    pub d: f64,
    /// Slow-down factor `ω ∈ [0, 1]`: during a checkpoint of length `C`,
    /// `ωC` work units still complete. `ω = 0` is fully blocking,
    /// `ω = 1` fully overlapped.
    pub omega: f64,
}

impl CheckpointParams {
    pub fn new(c: f64, r: f64, d: f64, omega: f64) -> Result<Self, ModelError> {
        let p = CheckpointParams { c, r, d, omega };
        p.validate()?;
        Ok(p)
    }

    pub fn validate(&self) -> Result<(), ModelError> {
        if !(self.c > 0.0 && self.c.is_finite()) {
            return Err(ModelError::Invalid(format!("C must be > 0, got {}", self.c)));
        }
        if self.r < 0.0 || self.d < 0.0 {
            return Err(ModelError::Invalid(format!(
                "R and D must be >= 0, got R={} D={}",
                self.r, self.d
            )));
        }
        if !(0.0..=1.0).contains(&self.omega) {
            return Err(ModelError::Invalid(format!(
                "omega must be in [0,1], got {}",
                self.omega
            )));
        }
        Ok(())
    }

    /// The paper's `a = (1-ω)C`: work units lost to each checkpoint.
    #[inline]
    pub fn a(&self) -> f64 {
        (1.0 - self.omega) * self.c
    }
}

/// Power parameters (§2.2), in mW per node. `P_Cal`, `P_IO`, `P_Down`
/// are *overheads on top of* `P_Static`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerParams {
    pub p_static: f64,
    pub p_cal: f64,
    pub p_io: f64,
    pub p_down: f64,
}

impl PowerParams {
    pub fn new(p_static: f64, p_cal: f64, p_io: f64, p_down: f64) -> Result<Self, ModelError> {
        let p = PowerParams { p_static, p_cal, p_io, p_down };
        p.validate()?;
        Ok(p)
    }

    pub fn validate(&self) -> Result<(), ModelError> {
        if !(self.p_static > 0.0) {
            return Err(ModelError::Invalid(format!(
                "P_Static must be > 0, got {}",
                self.p_static
            )));
        }
        for (name, v) in
            [("P_Cal", self.p_cal), ("P_IO", self.p_io), ("P_Down", self.p_down)]
        {
            if v < 0.0 || !v.is_finite() {
                return Err(ModelError::Invalid(format!("{name} must be >= 0, got {v}")));
            }
        }
        Ok(())
    }

    /// `α = P_Cal / P_Static`.
    #[inline]
    pub fn alpha(&self) -> f64 {
        self.p_cal / self.p_static
    }

    /// `β = P_IO / P_Static`.
    #[inline]
    pub fn beta(&self) -> f64 {
        self.p_io / self.p_static
    }

    /// `γ = P_Down / P_Static`.
    #[inline]
    pub fn gamma(&self) -> f64 {
        self.p_down / self.p_static
    }

    /// The paper's headline knob `ρ = (1+β)/(1+α)` (Eq. 2).
    #[inline]
    pub fn rho(&self) -> f64 {
        (1.0 + self.beta()) / (1.0 + self.alpha())
    }

    /// Build powers from `(α, β, γ)` ratios with `P_Static = 1`.
    /// Keeps figures parameterised exactly as in the paper.
    pub fn from_ratios(alpha: f64, beta: f64, gamma: f64) -> Result<Self, ModelError> {
        PowerParams::new(1.0, alpha, beta, gamma)
    }

    /// Build powers achieving a target `ρ` for a fixed `α` and `γ`:
    /// `β = ρ(1+α) − 1`. This is how Fig. 1 and Fig. 2 scan ρ.
    pub fn from_rho(rho: f64, alpha: f64, gamma: f64) -> Result<Self, ModelError> {
        let beta = rho * (1.0 + alpha) - 1.0;
        if beta < 0.0 {
            return Err(ModelError::Invalid(format!(
                "rho={rho} with alpha={alpha} gives negative beta={beta}"
            )));
        }
        PowerParams::from_ratios(alpha, beta, gamma)
    }
}

/// Platform description: `μ = μ_ind / N` (§2.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Platform {
    /// Number of nodes `N`.
    pub n_nodes: f64,
    /// Individual node MTBF `μ_ind`, in minutes.
    pub mu_ind: f64,
}

impl Platform {
    pub fn new(n_nodes: f64, mu_ind: f64) -> Result<Self, ModelError> {
        if !(n_nodes >= 1.0) || !(mu_ind > 0.0) {
            return Err(ModelError::Invalid(format!(
                "need N >= 1 and mu_ind > 0, got N={n_nodes} mu_ind={mu_ind}"
            )));
        }
        Ok(Platform { n_nodes, mu_ind })
    }

    /// Platform MTBF `μ = μ_ind / N`.
    #[inline]
    pub fn mu(&self) -> f64 {
        self.mu_ind / self.n_nodes
    }

    /// Jaguar-derived individual MTBF (§4): 45 208 processors, one fault
    /// per day ⇒ `μ_ind = 45 208 days ≈ 125 years`, in minutes.
    pub fn jaguar_mu_ind_minutes() -> f64 {
        45_208.0 * 24.0 * 60.0
    }
}

/// A complete model instantiation: what every formula in [`super::time`]
/// and [`super::energy`] takes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scenario {
    pub ckpt: CheckpointParams,
    pub power: PowerParams,
    /// Platform MTBF `μ` in minutes.
    pub mu: f64,
    /// Failure-free application duration `T_base` in minutes.
    pub t_base: f64,
    /// Storage model. [`TierConfig::Scalar`] (the default, and what
    /// every pre-existing constructor produces) means `ckpt`/`power`
    /// are the whole story. `Tiered` carries the multi-level hierarchy
    /// while `ckpt.c`/`ckpt.r`/`power.p_io` hold its *effective
    /// projection* — tier-0 write cost, tier-1 restart cost, tier-0 I/O
    /// power — so scalar-only consumers still see sensible numbers.
    pub tiers: TierConfig,
}

impl Scenario {
    pub fn new(
        ckpt: CheckpointParams,
        power: PowerParams,
        mu: f64,
        t_base: f64,
    ) -> Result<Self, ModelError> {
        let s = Scenario { ckpt, power, mu, t_base, tiers: TierConfig::Scalar };
        s.validate()?;
        Ok(s)
    }

    /// Scenario over a storage hierarchy. `ckpt` supplies `D` and `ω`
    /// only; its `c`/`r` (and `power.p_io`) are overwritten with the
    /// hierarchy's effective projection: synchronous writes land on
    /// tier 0 (`c = C_0`, `p_io = P_IO_0`) and recovery reads the
    /// nearest drained tier (`r = R_1`). A 1-level hierarchy
    /// canonicalises to the scalar model — bit-for-bit, because the
    /// projection of a single tier *is* that tier.
    pub fn with_tiers(
        ckpt: CheckpointParams,
        power: PowerParams,
        mu: f64,
        t_base: f64,
        tiers: TierConfig,
    ) -> Result<Self, ModelError> {
        let (ckpt, power, tiers) = match tiers.hierarchy() {
            None => {
                (ckpt, power, TierConfig::Scalar)
            }
            Some(h) => {
                let mut ckpt = ckpt;
                let mut power = power;
                ckpt.c = h.tier(0).c;
                ckpt.r = h.tier(1).r;
                power.p_io = h.tier(0).p_io;
                (ckpt, power, tiers)
            }
        };
        let s = Scenario { ckpt, power, mu, t_base, tiers };
        s.validate()?;
        Ok(s)
    }

    /// Scenario over a raw tier slice. A 1-level slice canonicalises to
    /// the scalar model with that tier's `(c, r, p_io)` projected onto
    /// `ckpt`/`power`; ≥ 2 levels go through [`Scenario::with_tiers`].
    pub fn with_tier_specs(
        ckpt: CheckpointParams,
        power: PowerParams,
        mu: f64,
        t_base: f64,
        tiers: &[crate::storage::TierSpec],
    ) -> Result<Self, ModelError> {
        if let [only] = tiers {
            // Validate through the hierarchy path, then project: the
            // single tier *is* the scalar (C, R, P_IO) triple.
            TierHierarchy::new(tiers).map_err(ModelError::Invalid)?;
            let mut ckpt = ckpt;
            let mut power = power;
            ckpt.c = only.c;
            ckpt.r = only.r;
            power.p_io = only.p_io;
            return Scenario::new(ckpt, power, mu, t_base);
        }
        let cfg = TierConfig::from_tiers(tiers).map_err(ModelError::Invalid)?;
        Scenario::with_tiers(ckpt, power, mu, t_base, cfg)
    }

    /// The scalar projection of this scenario: identical for `Scalar`,
    /// and for `Tiered` the same parameters with the hierarchy dropped
    /// (what a consumer that flattens the hierarchy would see).
    pub fn scalar_effective(&self) -> Scenario {
        Scenario { tiers: TierConfig::Scalar, ..*self }
    }

    /// The storage hierarchy, when this scenario is tiered.
    #[inline]
    pub fn hierarchy(&self) -> Option<&TierHierarchy> {
        self.tiers.hierarchy()
    }

    pub fn validate(&self) -> Result<(), ModelError> {
        self.ckpt.validate()?;
        self.power.validate()?;
        if !(self.mu > 0.0 && self.mu.is_finite()) {
            return Err(ModelError::Invalid(format!("mu must be > 0, got {}", self.mu)));
        }
        if !(self.t_base > 0.0) {
            return Err(ModelError::Invalid(format!(
                "t_base must be > 0, got {}",
                self.t_base
            )));
        }
        // First-order validity: failures must not be able to absorb the
        // whole period budget, i.e. b > 0.
        if self.b() <= 0.0 {
            return Err(ModelError::OutOfDomain(format!(
                "D + R + omega*C = {} >= mu = {}: first-order model breaks down",
                self.ckpt.d + self.ckpt.r + self.ckpt.omega * self.ckpt.c,
                self.mu
            )));
        }
        Ok(())
    }

    /// `a = (1-ω)C`.
    #[inline]
    pub fn a(&self) -> f64 {
        self.ckpt.a()
    }

    /// `b = 1 − (D + R + ωC)/μ`.
    #[inline]
    pub fn b(&self) -> f64 {
        1.0 - (self.ckpt.d + self.ckpt.r + self.ckpt.omega * self.ckpt.c) / self.mu
    }

    /// The open interval of periods on which `T_final` is positive and
    /// finite: `T ∈ (a, 2μb)`.
    pub fn domain(&self) -> (f64, f64) {
        (self.a(), 2.0 * self.mu * self.b())
    }

    /// Practical lower bound on the period: the checkpoint itself must
    /// fit, so `T ≥ C` (also `> a` automatically since `a ≤ C`).
    pub fn min_period(&self) -> f64 {
        self.ckpt.c
    }

    /// Clamp a period into the physically meaningful part of the domain.
    /// Matches the paper's observed behaviour near the breakdown regime
    /// ("both periods become close to C"). Returns an error when even
    /// `T = C` is outside the model's domain (μ too small: the machine
    /// fails faster than it checkpoints).
    pub fn clamp_period(&self, t: f64) -> Result<f64, ModelError> {
        let (_, hi) = self.domain();
        let lo = self.min_period();
        if lo >= hi {
            return Err(ModelError::OutOfDomain(format!(
                "no feasible period: C={} >= 2*mu*b={hi}",
                self.ckpt.c
            )));
        }
        // Keep strictly inside the upper bound.
        Ok(t.clamp(lo, hi * (1.0 - 1e-9)))
    }

    /// Whether the first-order approximation is trustworthy:
    /// `C, D, R ≪ μ` (we use a factor-10 rule of thumb).
    pub fn first_order_ok(&self) -> bool {
        let worst = self.ckpt.c.max(self.ckpt.d).max(self.ckpt.r);
        worst * 10.0 <= self.mu
    }

    /// Exact-bits encoding of the *scalar* scenario parameters — the
    /// historical fixed-width key prefix. Tier structure is **not**
    /// included; key sites must use [`Scenario::key_words`]. Kept
    /// `[u64; 10]` so scalar keys (and every seed derived from them)
    /// stay bit-identical across the tiered-storage refactor.
    pub fn key_bits(&self) -> [u64; 10] {
        let Scenario { ckpt, power, mu, t_base, tiers: _ } = *self;
        let CheckpointParams { c, r, d, omega } = ckpt;
        let PowerParams { p_static, p_cal, p_io, p_down } = power;
        [
            c.to_bits(),
            r.to_bits(),
            d.to_bits(),
            omega.to_bits(),
            p_static.to_bits(),
            p_cal.to_bits(),
            p_io.to_bits(),
            p_down.to_bits(),
            mu.to_bits(),
            t_base.to_bits(),
        ]
    }

    /// Exact-bits encoding of **every** scenario parameter, for
    /// memo/cache keys (the grid engine's cell keys, the online-policy
    /// memo, the optima memos, serve solve keys): the 10-word scalar
    /// prefix from [`Scenario::key_bits`] plus the tier extension from
    /// [`TierConfig::key_words`]. The extension is *empty* for scalar
    /// scenarios, so pre-refactor keys — and the seeds split from them
    /// — are reproduced bit-for-bit; tiered scenarios can never alias a
    /// scalar one because their extension starts with a non-zero level
    /// count. One canonical listing: the exhaustive destructuring in
    /// the two halves makes adding a field a compile error here rather
    /// than a silent memo alias at whichever key site forgot it.
    pub fn key_words(&self) -> Vec<u64> {
        let mut k = Vec::with_capacity(10 + 1 + 5 * 4);
        k.extend_from_slice(&self.key_bits());
        k.extend(self.tiers.key_words());
        k
    }
}

/// Errors from parameter validation or out-of-domain evaluation.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelError {
    Invalid(String),
    OutOfDomain(String),
}

impl std::fmt::Display for ModelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelError::Invalid(m) => write!(f, "invalid parameter: {m}"),
            ModelError::OutOfDomain(m) => write!(f, "out of model domain: {m}"),
        }
    }
}

impl std::error::Error for ModelError {}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn paper_fig1_scenario(mu: f64, rho: f64) -> Scenario {
        // Fig 1: C=R=10 min, D=1 min, gamma=0, omega=1/2; alpha = 1.
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::from_rho(rho, 1.0, 0.0).unwrap();
        Scenario::new(ckpt, power, mu, 10_000.0).unwrap()
    }

    #[test]
    fn ratios_match_paper_values() {
        // P_Static=10, P_Cal=10, P_IO=100 => rho = (1+10)/(1+1) = 5.5.
        let p = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        assert!((p.alpha() - 1.0).abs() < 1e-12);
        assert!((p.beta() - 10.0).abs() < 1e-12);
        assert!((p.rho() - 5.5).abs() < 1e-12);
        // P_Static=5 with same overheads => rho = (1+20)/(1+2) = 7.
        let p = PowerParams::new(5.0, 10.0, 100.0, 0.0).unwrap();
        assert!((p.rho() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn from_rho_roundtrips() {
        for rho in [1.0, 2.0, 5.5, 7.0, 20.0] {
            let p = PowerParams::from_rho(rho, 1.0, 0.0).unwrap();
            assert!((p.rho() - rho).abs() < 1e-12, "rho={rho}");
        }
        assert!(PowerParams::from_rho(0.1, 1.0, 0.0).is_err());
    }

    #[test]
    fn jaguar_mu_ind_is_about_125_years() {
        let years = Platform::jaguar_mu_ind_minutes() / (365.0 * 24.0 * 60.0);
        assert!((years - 123.8).abs() < 1.0, "years={years}");
    }

    #[test]
    fn platform_mtbf_scales_inverse_n() {
        let p = Platform::new(1e6, Platform::jaguar_mu_ind_minutes()).unwrap();
        let p10 = Platform::new(1e7, Platform::jaguar_mu_ind_minutes()).unwrap();
        assert!((p.mu() / p10.mu() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn paper_node_counts_give_paper_mtbf() {
        // §4: N = 219,150 => mu = 300 min; N = 2,191,500 => mu = 30 min.
        let mu_ind = Platform::jaguar_mu_ind_minutes();
        let mu_300 = Platform::new(219_150.0, mu_ind).unwrap().mu();
        let mu_30 = Platform::new(2_191_500.0, mu_ind).unwrap().mu();
        assert!((mu_300 - 297.0).abs() < 3.0, "mu_300={mu_300}");
        assert!((mu_30 - 29.7).abs() < 0.3, "mu_30={mu_30}");
    }

    #[test]
    fn validation_rejects_bad_params() {
        assert!(CheckpointParams::new(0.0, 1.0, 1.0, 0.5).is_err());
        assert!(CheckpointParams::new(1.0, -1.0, 1.0, 0.5).is_err());
        assert!(CheckpointParams::new(1.0, 1.0, 1.0, 1.5).is_err());
        assert!(PowerParams::new(0.0, 1.0, 1.0, 0.0).is_err());
        assert!(PowerParams::new(1.0, -1.0, 1.0, 0.0).is_err());
        assert!(Platform::new(0.5, 100.0).is_err());
    }

    #[test]
    fn scenario_domain_and_b() {
        let s = paper_fig1_scenario(300.0, 5.5);
        // b = 1 - (1 + 10 + 5)/300 = 1 - 16/300
        assert!((s.b() - (1.0 - 16.0 / 300.0)).abs() < 1e-12);
        assert!((s.a() - 5.0).abs() < 1e-12);
        let (lo, hi) = s.domain();
        assert!(lo < s.min_period() && s.min_period() < hi);
    }

    #[test]
    fn scenario_rejects_mu_smaller_than_overheads() {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        // D + R + omega C = 16 > mu = 10 => b < 0.
        assert!(matches!(
            Scenario::new(ckpt, power, 10.0, 1000.0),
            Err(ModelError::OutOfDomain(_))
        ));
    }

    #[test]
    fn clamp_period_behaviour() {
        let s = paper_fig1_scenario(300.0, 5.5);
        assert_eq!(s.clamp_period(1.0).unwrap(), s.min_period());
        let (_, hi) = s.domain();
        assert!(s.clamp_period(1e9).unwrap() < hi);
        let t = s.clamp_period(100.0).unwrap();
        assert_eq!(t, 100.0);
    }

    #[test]
    fn first_order_flag() {
        assert!(paper_fig1_scenario(300.0, 5.5).first_order_ok());
        assert!(!paper_fig1_scenario(50.0, 5.5).first_order_ok());
    }

    #[test]
    fn key_bits_cover_every_field() {
        let base = paper_fig1_scenario(300.0, 5.5);
        let bits = base.key_bits();
        assert_eq!(bits, base.key_bits(), "deterministic");
        // Changing any single parameter changes the key.
        let mut variants = [base; 10];
        variants[0].ckpt.c += 1.0;
        variants[1].ckpt.r += 1.0;
        variants[2].ckpt.d += 1.0;
        variants[3].ckpt.omega += 0.1;
        variants[4].power.p_static += 1.0;
        variants[5].power.p_cal += 1.0;
        variants[6].power.p_io += 1.0;
        variants[7].power.p_down += 1.0;
        variants[8].mu += 1.0;
        variants[9].t_base += 1.0;
        for (i, v) in variants.iter().enumerate() {
            assert_ne!(v.key_bits(), bits, "field {i} not covered by key_bits");
        }
    }

    #[test]
    fn key_words_equal_key_bits_for_scalar() {
        let s = paper_fig1_scenario(300.0, 5.5);
        assert_eq!(s.key_words(), s.key_bits().to_vec());
    }

    #[test]
    fn key_words_cover_tier_structure() {
        use crate::storage::TierSpec;
        let base = paper_fig1_scenario(300.0, 5.5);
        let tiered = Scenario::with_tier_specs(
            base.ckpt,
            base.power,
            base.mu,
            base.t_base,
            &[TierSpec::new(1.0, 1.0, 30.0), TierSpec::new(10.0, 10.0, 100.0)],
        )
        .unwrap();
        assert_ne!(tiered.key_words(), tiered.key_bits().to_vec());
        assert!(tiered.key_words().len() > 10);
        // Scalar-projected copy drops the extension again.
        assert_eq!(
            tiered.scalar_effective().key_words(),
            tiered.key_bits().to_vec()
        );
    }

    #[test]
    fn single_tier_scenario_is_bit_identical_to_scalar() {
        use crate::storage::TierSpec;
        let base = paper_fig1_scenario(300.0, 5.5);
        let one = Scenario::with_tier_specs(
            base.ckpt,
            base.power,
            base.mu,
            base.t_base,
            &[TierSpec::new(base.ckpt.c, base.ckpt.r, base.power.p_io)],
        )
        .unwrap();
        assert_eq!(one, base);
        assert_eq!(one.key_words(), base.key_words());
        assert!(one.tiers.is_scalar());
    }

    #[test]
    fn tiered_scenario_projects_effective_scalars() {
        use crate::storage::TierSpec;
        let base = paper_fig1_scenario(300.0, 5.5);
        let tiered = Scenario::with_tier_specs(
            base.ckpt,
            base.power,
            base.mu,
            base.t_base,
            &[TierSpec::new(1.0, 1.5, 30.0), TierSpec::new(10.0, 12.0, 100.0)],
        )
        .unwrap();
        // c = C_0, r = R_1 (restart reads the nearest drained tier),
        // p_io = P_IO_0 (synchronous writes land on tier 0).
        assert_eq!(tiered.ckpt.c, 1.0);
        assert_eq!(tiered.ckpt.r, 12.0);
        assert_eq!(tiered.power.p_io, 30.0);
        // D and omega pass through from the caller's ckpt.
        assert_eq!(tiered.ckpt.d, base.ckpt.d);
        assert_eq!(tiered.ckpt.omega, base.ckpt.omega);
        assert!(tiered.hierarchy().is_some());
    }
}
