//! Scalar minimisation utilities.
//!
//! Used to (a) cross-validate the closed-form optimal periods against the
//! exact closed-form objectives, (b) optimise objectives with no closed
//! form (the MSK baseline, DES-calibrated objectives), and (c) quantify
//! how far the paper's first-order formulas drift from the numeric optima
//! as `C/μ` grows (an ablation in `examples/exascale_study`).

/// Golden-section search for the minimum of a unimodal `f` on `[lo, hi]`.
///
/// Returns `(argmin, min)`. Tolerance is on the argument. If `f` is not
/// unimodal the result is a local minimum bracketed by the initial
/// interval — combine with [`grid_then_golden`] for robustness.
pub fn golden_section(mut f: impl FnMut(f64) -> f64, lo: f64, hi: f64, tol: f64) -> (f64, f64) {
    assert!(hi > lo, "invalid bracket [{lo}, {hi}]");
    const INVPHI: f64 = 0.618_033_988_749_894_8; // 1/φ
    const INVPHI2: f64 = 0.381_966_011_250_105_2; // 1/φ²
    let (mut a, mut b) = (lo, hi);
    let mut h = b - a;
    let mut c = a + INVPHI2 * h;
    let mut d = a + INVPHI * h;
    let mut fc = f(c);
    let mut fd = f(d);
    // Enough iterations to shrink below tol.
    let n = ((tol / h).ln() / INVPHI.ln()).ceil().max(1.0) as usize;
    for _ in 0..n {
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            h = b - a;
            c = a + INVPHI2 * h;
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            h = b - a;
            d = a + INVPHI * h;
            fd = f(d);
        }
    }
    let x = if fc < fd { (a + d) / 2.0 } else { (c + b) / 2.0 };
    let fx = f(x);
    (x, fx)
}

/// Coarse grid scan followed by golden-section refinement around the best
/// grid cell. Robust to mild non-unimodality (e.g. objectives flattened
/// by clamping at the domain edge).
pub fn grid_then_golden(
    mut f: impl FnMut(f64) -> f64,
    lo: f64,
    hi: f64,
    grid: usize,
    tol: f64,
) -> (f64, f64) {
    assert!(grid >= 2 && hi > lo);
    let mut best_i = 0;
    let mut best_v = f64::INFINITY;
    let step = (hi - lo) / grid as f64;
    for i in 0..=grid {
        let x = lo + step * i as f64;
        let v = f(x);
        if v < best_v {
            best_v = v;
            best_i = i;
        }
    }
    let a = lo + step * best_i.saturating_sub(1) as f64;
    let b = (lo + step * (best_i + 1) as f64).min(hi);
    golden_section(f, a, b, tol)
}

/// Warm-started variant of [`grid_then_golden`]: seed the scan with an
/// `hint` argmin carried over from a previous, nearby solve.
///
/// Probes the three grid points bracketing the hint; when they form a
/// strict, finite, interior local minimum, the full grid scan is
/// skipped and golden-section refines exactly the bracket the cold
/// scan would have selected — for a unimodal objective the grid argmin
/// is the grid point nearest the true minimum, so a validated hint
/// bracket *is* the cold bracket (same `lo + step * i` endpoint
/// expressions, same refinement calls) and the result is bit-identical
/// to [`grid_then_golden`]. Cost: 3 probes + refinement instead of
/// `grid + 1` probes + refinement.
///
/// Returns `None` when the bracket check fails — non-finite or
/// out-of-domain hint, probe values not a strict interior dip (e.g.
/// the optimum moved to the domain edge, or drifted more than a grid
/// cell past the hint's neighbours) — and the caller falls back to the
/// cold path.
pub fn grid_then_golden_warm(
    mut f: impl FnMut(f64) -> f64,
    lo: f64,
    hi: f64,
    grid: usize,
    tol: f64,
    hint: f64,
) -> Option<(f64, f64)> {
    assert!(grid >= 2 && hi > lo);
    if !hint.is_finite() {
        return None;
    }
    let step = (hi - lo) / grid as f64;
    let j_raw = ((hint - lo) / step).round();
    if !(0.0..=grid as f64).contains(&j_raw) {
        return None;
    }
    // Edge hints clamp to the innermost interior point; a true edge
    // optimum then fails the strict-dip check below and falls back.
    let j = (j_raw as usize).clamp(1, grid - 1);
    let vm = f(lo + step * (j - 1) as f64);
    let vj = f(lo + step * j as f64);
    let vp = f(lo + step * (j + 1) as f64);
    if !(vm.is_finite() && vj.is_finite() && vp.is_finite()) || !(vm > vj && vj < vp) {
        return None;
    }
    // Identical endpoint expressions to the cold path with `best_i = j`.
    let a = lo + step * (j - 1) as f64;
    let b = (lo + step * (j + 1) as f64).min(hi);
    Some(golden_section(f, a, b, tol))
}

/// Solve `a2·x² + a1·x + a0 = 0` for real roots, returned ascending.
pub fn quadratic_roots(a2: f64, a1: f64, a0: f64) -> Vec<f64> {
    if a2 == 0.0 {
        if a1 == 0.0 {
            return vec![];
        }
        return vec![-a0 / a1];
    }
    let disc = a1 * a1 - 4.0 * a2 * a0;
    if disc < 0.0 {
        return vec![];
    }
    let sq = disc.sqrt();
    // Numerically stable: avoid cancellation by computing the large-|.|
    // root first, then the other via Vieta.
    let q = -0.5 * (a1 + a1.signum() * sq);
    let (r1, r2) = if q == 0.0 { (0.0, 0.0) } else { (q / a2, a0 / q) };
    let mut roots = vec![r1, r2];
    roots.sort_by(|a, b| a.partial_cmp(b).unwrap());
    roots.dedup();
    roots
}

/// The unique positive root of a quadratic, if any.
pub fn positive_root(a2: f64, a1: f64, a0: f64) -> Option<f64> {
    quadratic_roots(a2, a1, a0).into_iter().find(|&r| r > 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::{check, Gen};

    #[test]
    fn golden_finds_parabola_min() {
        let (x, fx) = golden_section(|x| (x - 3.2) * (x - 3.2) + 1.0, 0.0, 10.0, 1e-9);
        assert!((x - 3.2).abs() < 1e-7, "x={x}");
        assert!((fx - 1.0).abs() < 1e-12);
    }

    #[test]
    fn golden_handles_min_at_edge() {
        let (x, _) = golden_section(|x| x, 2.0, 5.0, 1e-9);
        assert!((x - 2.0).abs() < 1e-6, "x={x}");
    }

    #[test]
    fn grid_then_golden_escapes_local_flat() {
        // Piecewise: flat high plateau then a dip near 8.
        let f = |x: f64| if x < 6.0 { 10.0 - 1e-6 * x } else { (x - 8.0) * (x - 8.0) };
        let (x, _) = grid_then_golden(f, 0.0, 10.0, 50, 1e-9);
        assert!((x - 8.0).abs() < 1e-6, "x={x}");
    }

    #[test]
    fn warm_start_matches_cold_when_bracket_validates() {
        let f = |x: f64| (x - 3.7) * (x - 3.7);
        let cold = grid_then_golden(f, 0.0, 10.0, 100, 1e-9);
        let warm = grid_then_golden_warm(f, 0.0, 10.0, 100, 1e-9, 3.64).unwrap();
        assert_eq!(cold.0.to_bits(), warm.0.to_bits());
        assert_eq!(cold.1.to_bits(), warm.1.to_bits());
    }

    #[test]
    fn warm_start_rejects_bad_hints() {
        let f = |x: f64| (x - 8.0) * (x - 8.0);
        // Hint far from the minimum: the probed triple is monotone.
        assert!(grid_then_golden_warm(f, 0.0, 10.0, 50, 1e-9, 1.0).is_none());
        // Non-finite and out-of-domain hints.
        assert!(grid_then_golden_warm(f, 0.0, 10.0, 50, 1e-9, f64::NAN).is_none());
        assert!(grid_then_golden_warm(f, 0.0, 10.0, 50, 1e-9, 42.0).is_none());
        // Minimum at the domain edge: never a strict interior dip.
        assert!(grid_then_golden_warm(|x| x, 2.0, 5.0, 50, 1e-9, 2.0).is_none());
    }

    #[test]
    fn prop_warm_start_is_bit_identical_to_cold() {
        check("warm start matches cold grid_then_golden", 300, |g: &mut Gen| {
            let m = g.f64_in(1.0, 9.0);
            let scale = g.f64_in(0.1, 10.0);
            let hint = m + g.f64_in(-0.5, 0.5);
            let f = |x: f64| scale * (x - m) * (x - m);
            if let Some(warm) = grid_then_golden_warm(f, 0.0, 10.0, 64, 1e-9, hint) {
                let cold = grid_then_golden(f, 0.0, 10.0, 64, 1e-9);
                prop_assert!(
                    g,
                    warm.0.to_bits() == cold.0.to_bits() && warm.1.to_bits() == cold.1.to_bits(),
                    "warm {warm:?} cold {cold:?}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn quadratic_root_cases() {
        assert_eq!(quadratic_roots(0.0, 0.0, 1.0), vec![]);
        assert_eq!(quadratic_roots(0.0, 2.0, -4.0), vec![2.0]);
        let r = quadratic_roots(1.0, -3.0, 2.0);
        assert!((r[0] - 1.0).abs() < 1e-12 && (r[1] - 2.0).abs() < 1e-12);
        assert_eq!(quadratic_roots(1.0, 0.0, 1.0), vec![]);
        // Double root dedups.
        let r = quadratic_roots(1.0, -2.0, 1.0);
        assert_eq!(r.len(), 1);
        assert!((r[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn positive_root_picks_positive() {
        // roots -5 and +2
        let r = positive_root(1.0, 3.0, -10.0).unwrap();
        assert!((r - 2.0).abs() < 1e-12);
        assert!(positive_root(1.0, 3.0, 2.0).is_none()); // roots -1, -2
    }

    #[test]
    fn prop_golden_matches_true_quadratic_min() {
        check("golden-section finds quadratic minima", 300, |g: &mut Gen| {
            let m = g.f64_in(-50.0, 50.0);
            let scale = g.f64_in(0.1, 10.0);
            let (x, _) =
                golden_section(|x| scale * (x - m) * (x - m), m - 100.0, m + 100.0, 1e-10);
            prop_assert!(g, (x - m).abs() < 1e-6, "x={x} m={m}");
            Ok(())
        });
    }

    #[test]
    fn prop_quadratic_roots_satisfy_equation() {
        check("roots satisfy polynomial", 300, |g: &mut Gen| {
            let a2 = g.f64_in(-10.0, 10.0);
            let a1 = g.f64_in(-10.0, 10.0);
            let a0 = g.f64_in(-10.0, 10.0);
            for r in quadratic_roots(a2, a1, a0) {
                let v = a2 * r * r + a1 * r + a0;
                let scale = a2.abs() * r * r + a1.abs() * r.abs() + a0.abs() + 1e-12;
                prop_assert!(g, v.abs() / scale < 1e-9, "residual {v} at root {r}");
            }
            Ok(())
        });
    }
}
