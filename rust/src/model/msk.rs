//! The Meneses–Sarood–Kalé baseline ([6] in the paper).
//!
//! MSK's model is blocking-only (`ω = 0`) with two powers: a base power
//! `L` (our `P_Static`) and a max power `H` (our `P_Static + P_Cal`),
//! and `P_IO = P_Down = 0` for the optimum derivation. The paper's §3.2
//! side note pins down exactly where the two analyses differ, per
//! failure:
//!
//! * MSK re-execution energy: `(T−2C)/2 · P_Cal`
//!   — ours: `(T²−C²)/(2T) · P_Cal`;
//! * MSK checkpoint-loss I/O energy: `C · P_IO`
//!   — ours: `C²/(2T) · P_IO`.
//!
//! We implement the MSK variant of `E_final` by substituting those two
//! per-failure terms into the same energy skeleton (same `T_final`,
//! same failure-free terms), which is precisely the comparison the paper
//! makes. `T-msk` in DESIGN.md regenerates it. The MSK optimum has no
//! closed form in this skeleton, so it is found numerically.

use super::energy::{e_final, t_energy_opt};
use super::optimize::grid_then_golden;
use super::params::{ModelError, Scenario};
use super::time::t_final;

/// MSK per-failure CPU re-execution time: `(T − 2C)/2` (clamped at 0 for
/// tiny periods, where the expression would go negative — MSK's form is a
/// coarser average that ignores where in the period the failure lands).
pub fn msk_re_exec_per_failure(s: &Scenario, t: f64) -> f64 {
    ((t - 2.0 * s.ckpt.c) / 2.0).max(0.0)
}

/// MSK per-failure I/O loss: a full checkpoint `C` (ours: the expected
/// interrupted fraction `C²/2T`).
pub fn msk_io_loss_per_failure(s: &Scenario) -> f64 {
    s.ckpt.c
}

/// MSK-style expected energy at period `t` (requires `ω = 0` scenarios to
/// be meaningful; callers assert).
pub fn e_final_msk(s: &Scenario, t: f64) -> f64 {
    debug_assert!(
        s.ckpt.omega == 0.0,
        "MSK is a blocking-checkpoint model; build the scenario with omega = 0"
    );
    let tf = t_final(s, t);
    if !tf.is_finite() {
        return f64::INFINITY;
    }
    let failures = tf / s.mu;
    let t_cal = s.t_base + failures * msk_re_exec_per_failure(s, t);
    let t_io = s.t_base * s.ckpt.c / (t - s.a())
        + failures * (s.ckpt.r + msk_io_loss_per_failure(s));
    let t_down = failures * s.ckpt.d;
    t_cal * s.power.p_cal
        + t_io * s.power.p_io
        + t_down * s.power.p_down
        + tf * s.power.p_static
}

/// Numeric argmin of [`e_final_msk`] over the physical domain.
pub fn t_energy_opt_msk(s: &Scenario) -> Result<f64, ModelError> {
    let (lo, hi) = s.domain();
    let lo = lo.max(s.min_period());
    let hi = hi * (1.0 - 1e-9);
    if lo >= hi {
        return Err(ModelError::OutOfDomain("no feasible period for MSK optimum".into()));
    }
    let (t, _) = grid_then_golden(|t| e_final_msk(s, t), lo, hi, 400, 1e-9 * (hi - lo));
    s.clamp_period(t)
}

/// Side-by-side numbers for the paper's §3.2 MSK comparison: energy (in
/// *our* refined model) achieved when checkpointing with the MSK-optimal
/// period vs with AlgoE's period. Positive `penalty_pct` means MSK's
/// period wastes that much energy under the refined accounting.
#[derive(Debug, Clone, Copy)]
pub struct MskComparison {
    pub t_algo_e: f64,
    pub t_msk: f64,
    pub energy_algo_e: f64,
    pub energy_at_msk_period: f64,
    pub penalty_pct: f64,
}

pub fn compare_with_msk(s: &Scenario) -> Result<MskComparison, ModelError> {
    let t_algo_e = t_energy_opt(s)?;
    let t_msk = t_energy_opt_msk(s)?;
    let energy_algo_e = e_final(s, t_algo_e);
    let energy_at_msk_period = e_final(s, t_msk);
    Ok(MskComparison {
        t_algo_e,
        t_msk,
        energy_algo_e,
        energy_at_msk_period,
        penalty_pct: (energy_at_msk_period / energy_algo_e - 1.0) * 100.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{CheckpointParams, PowerParams, Scenario};

    fn blocking_scenario(mu: f64, rho: f64) -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.0).unwrap();
        let power = PowerParams::from_rho(rho, 1.0, 0.0).unwrap();
        Scenario::new(ckpt, power, mu, 10_000.0).unwrap()
    }

    #[test]
    fn msk_terms_match_side_note() {
        let s = blocking_scenario(300.0, 5.5);
        assert_eq!(msk_re_exec_per_failure(&s, 100.0), 40.0); // (100-20)/2
        assert_eq!(msk_io_loss_per_failure(&s), 10.0); // C
        assert_eq!(msk_re_exec_per_failure(&s, 10.0), 0.0); // clamped
    }

    #[test]
    fn msk_underestimates_re_exec_and_overestimates_io() {
        use crate::model::energy::{io_per_failure, re_exec_per_failure};
        let s = blocking_scenario(300.0, 5.5);
        let t = 100.0;
        // (T-2C)/2 = 40 < (T^2-C^2)/2T = 49.5
        assert!(msk_re_exec_per_failure(&s, t) < re_exec_per_failure(&s, t));
        // C = 10 > C^2/2T = 0.5 (io_per_failure also includes R)
        let ours_loss = io_per_failure(&s, t) - s.ckpt.r;
        assert!(msk_io_loss_per_failure(&s) > ours_loss);
    }

    #[test]
    fn msk_optimum_in_domain_and_penalized_under_refined_model() {
        for mu in [60.0, 120.0, 300.0] {
            let s = blocking_scenario(mu, 5.5);
            let cmp = compare_with_msk(&s).unwrap();
            assert!(cmp.t_msk >= s.min_period());
            // AlgoE is optimal under the refined model, so any other
            // period (including MSK's) can only cost more.
            assert!(cmp.penalty_pct >= -1e-9, "mu={mu} cmp={cmp:?}");
        }
    }

    #[test]
    fn msk_period_differs_from_ours() {
        let s = blocking_scenario(300.0, 5.5);
        let cmp = compare_with_msk(&s).unwrap();
        let rel = (cmp.t_msk - cmp.t_algo_e).abs() / cmp.t_algo_e;
        assert!(rel > 0.005, "periods unexpectedly identical: {cmp:?}");
    }

    #[test]
    fn msk_energy_finite_in_domain() {
        let s = blocking_scenario(300.0, 5.5);
        assert!(e_final_msk(&s, 60.0).is_finite());
        assert!(e_final_msk(&s, 1e9).is_infinite());
    }
}
