//! Expected energy consumption (§3.2) and the energy-optimal period.
//!
//! # Phase times
//!
//! ```text
//! T_Cal(T)  = T_base + (T_final/μ)(ωC + (T²−C²)/(2T) + ωC²/(2T))
//! T_IO(T)   = T_base·C/(T−a)  + (T_final/μ)(R + C²/(2T))
//! T_Down(T) = (T_final/μ)·D
//! E_final   = T_Cal·P_Cal + T_IO·P_IO + T_Down·P_Down + T_final·P_Static
//! ```
//!
//! Note `T_final ≠ T_Cal + T_IO + T_Down` unless `ω = 0`: CPU and I/O
//! overlap during non-blocking checkpoints and both powers are drawn.
//!
//! # The stationarity quadratic
//!
//! Dividing by `P_Static·T_base` and writing `α, β, γ` for the power
//! ratios, `u = 1/(2μ)`, `a = (1−ω)C`, `b = 1 − (D+R+ωC)/μ`,
//! `m = αωC + βR + γD + μ`, `q = (β − α(1−ω))C²/2`:
//!
//! ```text
//! E/(P_s·T_base) = α + N(T)/(μ·f(T)) + βC/(T−a),
//!   N(T) = αT²/2 + mT + q,      f(T) = (T−a)(b−uT)
//! ```
//!
//! Setting `dE/dT = 0` and multiplying by `μ·f²` yields the quadratic
//! `A2·T² + A1·T + A0 = 0` with
//!
//! ```text
//! A2 = α(b+au)/2 + mu − βCu/2
//! A1 = 2qu − αab + βCb
//! A0 = −mab − q(b+au) − μβCb²
//! ```
//!
//! This is our own derivation: it is the **exact** stationarity condition
//! of the closed-form `E_final` above (the published derivation reaches
//! the same quadratic up to transcription noise in the preprint; our unit
//! tests verify the root coincides with a golden-section argmin of
//! `E_final` to 1e-6 relative, which the transcribed coefficients do not).
//! `T_Energy_opt` is the unique positive root — the period **AlgoE**
//! checkpoints with.

use super::optimize::{grid_then_golden, positive_root};
use super::params::{ModelError, Scenario};
use super::time::t_final;

/// Breakdown of expected durations per power state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseTimes {
    /// Wall-clock expectation `T_final`.
    pub t_final: f64,
    /// Time the CPU draws `P_Cal` (base work + re-execution).
    pub t_cal: f64,
    /// Time the I/O system draws `P_IO` (checkpoints + recoveries).
    pub t_io: f64,
    /// Downtime drawing `P_Down`.
    pub t_down: f64,
}

/// Expected CPU re-execution work per failure (§3.2):
/// `ωC + (T²−C²)/(2T) + ωC²/(2T)`.
pub fn re_exec_per_failure(s: &Scenario, t: f64) -> f64 {
    let c = s.ckpt.c;
    let om = s.ckpt.omega;
    om * c + (t * t - c * c) / (2.0 * t) + om * c * c / (2.0 * t)
}

/// Expected I/O time per failure: `R + C²/(2T)` (recovery plus the
/// partially-written checkpoint the failure interrupted).
pub fn io_per_failure(s: &Scenario, t: f64) -> f64 {
    s.ckpt.r + s.ckpt.c * s.ckpt.c / (2.0 * t)
}

/// Compute all phase durations at period `t`.
///
/// For tiered scenarios `t_final` is the κ-minimised envelope while the
/// phase *split* uses the effective scalar projection (tier-0 writes,
/// tier-1 recovery) — a diagnostic view; the tiered energy accounting
/// itself lives in [`super::tiers::e_final_at`].
pub fn phase_times(s: &Scenario, t: f64) -> PhaseTimes {
    let tf = t_final(s, t);
    if !tf.is_finite() {
        return PhaseTimes {
            t_final: f64::INFINITY,
            t_cal: f64::INFINITY,
            t_io: f64::INFINITY,
            t_down: f64::INFINITY,
        };
    }
    let failures = tf / s.mu;
    let t_cal = s.t_base + failures * re_exec_per_failure(s, t);
    let t_io = s.t_base * s.ckpt.c / (t - s.a()) + failures * io_per_failure(s, t);
    let t_down = failures * s.ckpt.d;
    PhaseTimes { t_final: tf, t_cal, t_io, t_down }
}

/// Expected total energy `E_final(T)` (mW·min with the paper's units).
///
/// Tiered scenarios dispatch to the κ-minimised envelope in
/// [`super::tiers`]; the scalar path below is untouched.
pub fn e_final(s: &Scenario, t: f64) -> f64 {
    if let Some(h) = s.hierarchy() {
        return super::tiers::e_final_tiered(s, h, t);
    }
    let ph = phase_times(s, t);
    if !ph.t_final.is_finite() {
        return f64::INFINITY;
    }
    ph.t_cal * s.power.p_cal
        + ph.t_io * s.power.p_io
        + ph.t_down * s.power.p_down
        + ph.t_final * s.power.p_static
}

/// Coefficients `(A2, A1, A0)` of the stationarity quadratic of
/// `E_final` (see module docs).
pub fn de_quadratic(s: &Scenario) -> (f64, f64, f64) {
    let c = s.ckpt.c;
    let (alpha, beta, gamma) = (s.power.alpha(), s.power.beta(), s.power.gamma());
    let a = s.a();
    let b = s.b();
    let mu = s.mu;
    let u = 1.0 / (2.0 * mu);
    let m = alpha * s.ckpt.omega * c + beta * s.ckpt.r + gamma * s.ckpt.d + mu;
    let q = (beta - alpha * (1.0 - s.ckpt.omega)) * c * c / 2.0;
    let a2 = alpha * (b + a * u) / 2.0 + m * u - beta * c * u / 2.0;
    let a1 = 2.0 * q * u - alpha * a * b + beta * c * b;
    let a0 = -m * a * b - q * (b + a * u) - mu * beta * c * b * b;
    (a2, a1, a0)
}

/// Energy-optimal period, **unclamped**: the positive root of
/// [`de_quadratic`]. Falls back to a numeric argmin of `E_final` when the
/// quadratic has no positive root in the domain (can happen at extreme
/// parameter corners, e.g. `β ≈ 0` with `ω = 1` where the raw stationary
/// point collapses to 0).
pub fn t_energy_opt_raw(s: &Scenario) -> f64 {
    let (a2, a1, a0) = de_quadratic(s);
    let (_, hi) = s.domain();
    match positive_root(a2, a1, a0) {
        Some(r) if r < hi => r,
        _ => t_energy_opt_numeric(s),
    }
}

/// Energy-optimal period clamped into `[C, 2μb)`: the period **AlgoE**
/// checkpoints with.
pub fn t_energy_opt(s: &Scenario) -> Result<f64, ModelError> {
    if let Some(h) = s.hierarchy() {
        return super::tiers::t_energy_opt_tiered(s, h);
    }
    s.clamp_period(t_energy_opt_raw(s))
}

/// Numeric argmin of the exact `E_final` over the physical domain.
/// Used as a fallback and to validate the closed form in tests/ablations.
pub fn t_energy_opt_numeric(s: &Scenario) -> f64 {
    let (lo, hi) = s.domain();
    let lo = lo.max(s.min_period() * 0.5).max(lo + 1e-9 * (hi - lo));
    let hi = hi * (1.0 - 1e-9);
    if lo >= hi {
        return s.min_period();
    }
    let (t, _) = grid_then_golden(|t| e_final(s, t), lo, hi, 400, 1e-9 * (hi - lo));
    t
}

/// Numeric argmin of the exact `T_final` (same machinery, used by the
/// first-order-accuracy ablation).
pub fn t_time_opt_numeric(s: &Scenario) -> f64 {
    let (lo, hi) = s.domain();
    let lo = lo + 1e-9 * (hi - lo);
    let hi = hi * (1.0 - 1e-9);
    let (t, _) = grid_then_golden(|t| t_final(s, t), lo, hi, 400, 1e-9 * (hi - lo));
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{CheckpointParams, PowerParams};
    use crate::model::time::{t_time_opt, t_time_opt_raw};
    use crate::prop_assert;
    use crate::util::proptest::{check, Gen};
    use crate::util::stats::rel_err;

    fn paper_scenario(mu: f64, rho: f64, omega: f64) -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, omega).unwrap();
        let power = PowerParams::from_rho(rho, 1.0, 0.0).unwrap();
        Scenario::new(ckpt, power, mu, 10_000.0).unwrap()
    }

    fn random_scenario(g: &mut Gen) -> Scenario {
        let c = g.f64_in(0.5, 20.0);
        let r = g.f64_in(0.5, 20.0);
        let d = g.f64_in(0.0, 5.0);
        let omega = g.f64_in(0.0, 1.0);
        let mu = g.f64_log_in(20.0 * (c + r + d), 1e6);
        let alpha = g.f64_in(0.1, 4.0);
        let rho = g.f64_in(1.0, 20.0);
        let gamma = g.f64_in(0.0, 1.0);
        let ckpt = CheckpointParams::new(c, r, d, omega).unwrap();
        let power = PowerParams::from_rho(rho, alpha, gamma).unwrap();
        Scenario::new(ckpt, power, mu, 10_000.0).unwrap()
    }

    #[test]
    fn phase_times_identity_when_blocking() {
        // omega = 0: no overlap, so T_final = T_Cal + T_IO + T_Down
        // (±first-order wobble; equality holds exactly here because the
        // same expectation terms partition the period).
        let s = paper_scenario(300.0, 5.5, 0.0);
        let t = 80.0;
        let ph = phase_times(&s, t);
        let sum = ph.t_cal + ph.t_io + ph.t_down;
        assert!(
            rel_err(sum, ph.t_final) < 0.02,
            "sum={sum} t_final={}",
            ph.t_final
        );
    }

    #[test]
    fn overlap_makes_sum_exceed_t_final() {
        // omega = 1: CPU keeps working during checkpoints, so the CPU and
        // IO phase times double-count the overlap.
        let s = paper_scenario(300.0, 5.5, 1.0);
        let ph = phase_times(&s, 60.0);
        assert!(ph.t_cal + ph.t_io + ph.t_down > ph.t_final * 1.05);
    }

    #[test]
    fn e_final_infinite_outside_domain() {
        let s = paper_scenario(300.0, 5.5, 0.5);
        assert!(e_final(&s, 1.0).is_infinite());
        assert!(e_final(&s, 1e9).is_infinite());
        assert!(e_final(&s, 60.0).is_finite());
    }

    #[test]
    fn quadratic_root_matches_numeric_argmin_paper_point() {
        let s = paper_scenario(300.0, 5.5, 0.5);
        let root = t_energy_opt_raw(&s);
        let numeric = t_energy_opt_numeric(&s);
        assert!(
            rel_err(root, numeric) < 1e-5,
            "root={root} numeric={numeric}"
        );
    }

    #[test]
    fn prop_quadratic_root_is_argmin_of_e_final() {
        check("T_Energy_opt == argmin E_final", 150, |g| {
            let s = random_scenario(g);
            let root = t_energy_opt_raw(&s);
            let numeric = t_energy_opt_numeric(&s);
            let (lo, hi) = s.domain();
            // Compare only when the stationary point is interior (not
            // squeezed against the domain edge by clamping effects).
            if root > lo * 1.01 && root < hi * 0.99 {
                let e_root = e_final(&s, root);
                let e_num = e_final(&s, numeric);
                prop_assert!(
                    g,
                    rel_err(e_root, e_num) < 1e-6,
                    "E(root={root})={e_root} vs E(num={numeric})={e_num} \
                     [mu={} rho={} omega={}]",
                    s.mu,
                    s.power.rho(),
                    s.ckpt.omega
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_energy_period_exceeds_time_period_when_io_expensive() {
        // For rho > 1 (I/O power > CPU power), checkpointing costs extra
        // energy, so AlgoE stretches the period: T_E >= T_T.
        check("rho>1 => T_Energy_opt >= T_Time_opt", 150, |g| {
            let c = g.f64_in(0.5, 15.0);
            let mu = g.f64_log_in(50.0 * c, 1e6);
            let omega = g.f64_in(0.0, 0.9);
            let alpha = g.f64_in(0.2, 3.0);
            let rho = g.f64_in(1.5, 20.0);
            let ckpt = CheckpointParams::new(c, c, 0.1 * c, omega).unwrap();
            let power = PowerParams::from_rho(rho, alpha, 0.0).unwrap();
            let s = Scenario::new(ckpt, power, mu, 1e4).unwrap();
            let tt = t_time_opt(&s).unwrap();
            let te = t_energy_opt(&s).unwrap();
            prop_assert!(
                g,
                te >= tt * (1.0 - 1e-9),
                "T_E={te} < T_T={tt} (rho={rho} omega={omega} alpha={alpha} mu={mu})"
            );
            Ok(())
        });
    }

    #[test]
    fn beta_zero_shrinks_energy_period() {
        // With free I/O power and expensive CPU, AlgoE checkpoints MORE
        // often than AlgoT: T_E ~ sqrt(2Cmu/(1+alpha)) < sqrt(2Cmu b).
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.0).unwrap();
        let power = PowerParams::from_ratios(1.0, 0.0, 0.0).unwrap();
        let s = Scenario::new(ckpt, power, 10_000.0, 1e4).unwrap();
        let te = t_energy_opt_raw(&s);
        let tt = t_time_opt_raw(&s);
        assert!(te < tt, "te={te} tt={tt}");
        let predict = (2.0f64 * 10.0 * 10_000.0 / 2.0).sqrt();
        assert!(rel_err(te, predict) < 0.05, "te={te} predict={predict}");
    }

    #[test]
    fn energy_at_algo_e_below_energy_at_algo_t() {
        for rho in [1.5, 3.0, 5.5, 7.0, 12.0] {
            for mu in [30.0, 60.0, 120.0, 300.0] {
                let s = paper_scenario(mu, rho, 0.5);
                let tt = t_time_opt(&s).unwrap();
                let te = t_energy_opt(&s).unwrap();
                assert!(
                    e_final(&s, te) <= e_final(&s, tt) * (1.0 + 1e-12),
                    "mu={mu} rho={rho}"
                );
                assert!(
                    t_final(&s, tt) <= t_final(&s, te) * (1.0 + 1e-12),
                    "mu={mu} rho={rho}"
                );
            }
        }
    }

    #[test]
    fn re_exec_terms_match_paper_forms() {
        let s = paper_scenario(300.0, 5.5, 0.0);
        // omega=0: re-exec per failure reduces to (T^2 - C^2)/2T.
        let t = 100.0;
        let expect = (t * t - 100.0) / (2.0 * t);
        assert!((re_exec_per_failure(&s, t) - expect).abs() < 1e-12);
        // io per failure: R + C^2/2T.
        assert!((io_per_failure(&s, t) - (10.0 + 100.0 / (2.0 * t))).abs() < 1e-12);
    }

    #[test]
    fn e_final_scales_linearly_with_p_static_at_fixed_ratios() {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let p1 = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        let p2 = PowerParams::new(20.0, 20.0, 200.0, 0.0).unwrap();
        let s1 = Scenario::new(ckpt, p1, 300.0, 1e4).unwrap();
        let s2 = Scenario::new(ckpt, p2, 300.0, 1e4).unwrap();
        assert!(rel_err(2.0 * e_final(&s1, 60.0), e_final(&s2, 60.0)) < 1e-12);
        // And the optimal period only depends on the ratios.
        assert!(rel_err(t_energy_opt_raw(&s1), t_energy_opt_raw(&s2)) < 1e-12);
    }

    #[test]
    fn numeric_time_argmin_matches_eq1() {
        let s = paper_scenario(300.0, 5.5, 0.5);
        assert!(rel_err(t_time_opt_numeric(&s), t_time_opt_raw(&s)) < 1e-5);
    }
}
