//! The objective-model backend: first-order closed forms vs the exact
//! renewal model, behind one dispatch point.
//!
//! Everything downstream of the model — the Pareto frontier
//! ([`crate::pareto`]), the ε-constraint solves, the online knee/budget
//! policies ([`crate::coordinator::policy`]), grid cells
//! ([`crate::sweep`]), figures and the CLI — evaluates the two
//! objectives through a [`Backend`] instead of calling
//! [`time::t_final`]/[`energy::e_final`] directly:
//!
//! * [`Backend::FirstOrder`] — the paper's §3 closed forms and their
//!   algebraic optima (Eq. 1 and the stationarity quadratic). The
//!   default everywhere; exactly the pre-backend behaviour.
//! * [`Backend::Exact`] — the exact renewal expectations of
//!   [`super::exact`] (no `T/μ` truncation), parameterised by how
//!   recovery interacts with further failures
//!   ([`RecoveryModel::Ideal`] or [`RecoveryModel::Restarting`]).
//!   The optima have no closed form; they are computed by
//!   [`grid_then_golden`](super::optimize::grid_then_golden) and
//!   **memoised process-wide** keyed on the scenario's exact parameter
//!   bits — the cached value is a pure function of its key, so grid
//!   sweeps stay fast and results are byte-identical across thread
//!   counts, exactly like the [`crate::sweep`] memo cache. On a memo
//!   miss the scan is **warm-started** from the last argmin solved for
//!   the same drift-invariant scenario family (the `WARM_HINTS` store):
//!   successive re-solves under drift validate a 3-probe bracket
//!   around the previous optimum instead of scanning the full grid,
//!   falling back to the cold scan bit-identically when the bracket
//!   check fails. Hints are advisory — they can change how fast a
//!   solve runs, never what it returns.
//!
//! At large `μ` the two backends agree (the truncation error scales
//! like `1/μ`; see `rust/tests/model_backend.rs` for the property
//! test); at small `μ` — the Exascale regime where the time–energy
//! trade-off is widest — they drift 5–40% apart, which is why the knee
//! policy and the frontier accept a backend at all
//! (`figures::knee_drift` quantifies the drift per preset).
//!
//! # Domain
//!
//! The exact objectives are finite for every period `T > a`, but the
//! backend deliberately inherits the first-order feasibility gate
//! (`C < 2μb`, i.e. [`Scenario::clamp_period`] succeeds at `T = C`):
//! a scenario is either usable under *both* backends or under neither,
//! so swapping backends can never change which grid cells clamp to
//! `None`.

use super::exact::{
    e_final_exact, exact_breakdown, t_energy_opt_exact, t_energy_opt_exact_warm, t_final_exact,
    t_time_opt_exact, t_time_opt_exact_warm, ExactEvaluator, RecoveryModel,
};
use super::optimize::{grid_then_golden, grid_then_golden_warm};
use super::params::{ModelError, Scenario};
use super::{energy, time};
use crate::telemetry::registry::metrics;
use crate::util::memo::PureMemo;
use crate::util::shard::ShardedMap;

/// Which objective model evaluates `T_final`/`E_final` and their
/// optimal periods.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// The paper's first-order closed forms (§3). The default.
    #[default]
    FirstOrder,
    /// The exact renewal model of [`super::exact`].
    Exact(RecoveryModel),
}

impl Backend {
    /// The accepted `--model` spellings, for CLI help and error
    /// messages. Plain `exact` is `exact:restarting` — the simulator's
    /// realistic default, where failures can strike during D + R;
    /// `exact:ideal` matches the paper's implicit failure-free-recovery
    /// assumption (and the first-order forms' own).
    pub const PARSE_HELP: &'static str = "first-order|exact|exact:ideal|exact:restarting";

    /// Parse a CLI-style backend name (see [`Self::PARSE_HELP`]).
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "first-order" | "first_order" => Some(Backend::FirstOrder),
            "exact" | "exact:restarting" => Some(Backend::Exact(RecoveryModel::Restarting)),
            "exact:ideal" => Some(Backend::Exact(RecoveryModel::Ideal)),
            _ => None,
        }
    }

    /// Stable display name; round-trips through [`Self::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            Backend::FirstOrder => "first-order",
            Backend::Exact(RecoveryModel::Restarting) => "exact",
            Backend::Exact(RecoveryModel::Ideal) => "exact:ideal",
        }
    }

    /// Stable encoding for cache keys and seed derivation (grid cells,
    /// the online-policy memo). Distinct per variant, never reused.
    pub fn key_word(&self) -> u64 {
        match self {
            Backend::FirstOrder => 0,
            Backend::Exact(RecoveryModel::Ideal) => 1,
            Backend::Exact(RecoveryModel::Restarting) => 2,
        }
    }

    /// Expected makespan at period `t`. `+inf` outside the backend's
    /// domain (first-order: `t ∉ (a, 2μb)`; exact: `t ≤ a`).
    ///
    /// Tiered scenarios: the first-order arm dispatches through
    /// [`time::t_final`] to the κ-minimised envelope; the exact arm
    /// applies the tier structure as an **additive first-order
    /// correction** on top of the exact renewal value of the flattened
    /// projection — `exact(flat) + (FO_tiered − FO_flat)` — since the
    /// renewal recursion has no closed tiered analogue. For scalar
    /// scenarios both corrections vanish identically.
    pub fn t_final(&self, s: &Scenario, t: f64) -> f64 {
        match self {
            Backend::FirstOrder => time::t_final(s, t),
            Backend::Exact(m) => {
                if t <= s.a() {
                    f64::INFINITY
                } else if s.hierarchy().is_some() {
                    let flat = s.scalar_effective();
                    let fo_tiered = time::t_final(s, t);
                    let fo_flat = time::t_final(&flat, t);
                    if !fo_tiered.is_finite() || !fo_flat.is_finite() {
                        f64::INFINITY
                    } else {
                        t_final_exact(s, t, *m) + (fo_tiered - fo_flat)
                    }
                } else {
                    t_final_exact(s, t, *m)
                }
            }
        }
    }

    /// Expected energy at period `t` (same domain convention and tier
    /// handling as [`Self::t_final`]).
    pub fn e_final(&self, s: &Scenario, t: f64) -> f64 {
        match self {
            Backend::FirstOrder => energy::e_final(s, t),
            Backend::Exact(m) => {
                if t <= s.a() {
                    f64::INFINITY
                } else if s.hierarchy().is_some() {
                    let flat = s.scalar_effective();
                    let fo_tiered = energy::e_final(s, t);
                    let fo_flat = energy::e_final(&flat, t);
                    if !fo_tiered.is_finite() || !fo_flat.is_finite() {
                        f64::INFINITY
                    } else {
                        e_final_exact(s, t, *m) + (fo_tiered - fo_flat)
                    }
                } else {
                    e_final_exact(s, t, *m)
                }
            }
        }
    }

    /// Both objectives at period `t` in one evaluation, `(time,
    /// energy)`. Bit-identical to calling [`Self::t_final`] and
    /// [`Self::e_final`] — but for the exact backend it computes the
    /// renewal breakdown once instead of twice, halving the cost of
    /// frontier sampling (the hot path of the online-policy memo).
    pub fn objectives(&self, s: &Scenario, t: f64) -> (f64, f64) {
        match self {
            Backend::FirstOrder => (time::t_final(s, t), energy::e_final(s, t)),
            Backend::Exact(m) => {
                if t <= s.a() {
                    (f64::INFINITY, f64::INFINITY)
                } else if s.hierarchy().is_some() {
                    // The tier corrections differ per objective; route
                    // through the single-objective arms (the breakdown
                    // sharing below only pays off for scalar scenarios).
                    (self.t_final(s, t), self.e_final(s, t))
                } else {
                    let b = exact_breakdown(s, t, *m);
                    (b.makespan, b.energy)
                }
            }
        }
    }

    /// Expected number of failures over the whole execution at `t`, as
    /// the simulator counts them.
    pub fn expected_failures(&self, s: &Scenario, t: f64) -> f64 {
        match self {
            Backend::FirstOrder => time::expected_failures(s, t),
            Backend::Exact(m) => {
                if t <= s.a() {
                    return f64::INFINITY;
                }
                // `exact_breakdown.failures` counts *primary* (up-time)
                // failures — the episode starts. Under Restarting,
                // failures also strike during D + R and the simulator
                // counts each restart too: restarts per episode are
                // geometric, e^{(D+R)/μ} − 1 in expectation, so the
                // observed total is primary · e^{(D+R)/μ}.
                let primary = exact_breakdown(s, t, *m).failures;
                match m {
                    RecoveryModel::Ideal => primary,
                    RecoveryModel::Restarting => {
                        primary * ((s.ckpt.d + s.ckpt.r) / s.mu).exp()
                    }
                }
            }
        }
    }

    /// The backend's time-optimal period, clamped to `T ≥ C`. Errors
    /// exactly when the first-order model has no feasible period (see
    /// the module docs on the shared domain gate). Tiered scenarios
    /// minimise the tier-corrected objective numerically, memoised
    /// like the scalar exact optima (the key carries the tier words).
    pub fn t_time_opt(&self, s: &Scenario) -> Result<f64, ModelError> {
        match self {
            Backend::FirstOrder => time::t_time_opt(s),
            Backend::Exact(m) => {
                s.clamp_period(s.min_period())?;
                if s.hierarchy().is_some() {
                    Ok(cached_opt(OPT_TIME_TAG, *m, s, |hint| {
                        // Hoist the per-scenario invariants out of the
                        // ~400-point optimiser loop: the flattened
                        // projection and the exact evaluator depend only
                        // on the scenario. The closure body repeats
                        // [`Self::t_final`]'s tiered arm verbatim (same
                        // expressions, same inputs), so the argmin is
                        // bit-identical to minimising `b.t_final` per-t.
                        let flat = s.scalar_effective();
                        let ev = ExactEvaluator::new(s, *m);
                        let obj = |t: f64| {
                            if t <= s.a() {
                                return f64::INFINITY;
                            }
                            let fo_tiered = time::t_final(s, t);
                            let fo_flat = time::t_final(&flat, t);
                            if !fo_tiered.is_finite() || !fo_flat.is_finite() {
                                f64::INFINITY
                            } else {
                                ev.breakdown(t).makespan + (fo_tiered - fo_flat)
                            }
                        };
                        if let Some(h) = hint {
                            if let Some(t) = numeric_opt_warm(s, &obj, h) {
                                return (t, true);
                            }
                        }
                        (numeric_opt(s, &obj), false)
                    }))
                } else {
                    Ok(cached_opt(OPT_TIME_TAG, *m, s, |hint| {
                        if let Some(h) = hint {
                            if let Some(t) = t_time_opt_exact_warm(s, *m, h) {
                                return (t, true);
                            }
                        }
                        (t_time_opt_exact(s, *m), false)
                    }))
                }
            }
        }
    }

    /// The backend's energy-optimal period (same contract as
    /// [`Self::t_time_opt`]).
    pub fn t_energy_opt(&self, s: &Scenario) -> Result<f64, ModelError> {
        match self {
            Backend::FirstOrder => energy::t_energy_opt(s),
            Backend::Exact(m) => {
                s.clamp_period(s.min_period())?;
                if s.hierarchy().is_some() {
                    Ok(cached_opt(OPT_ENERGY_TAG, *m, s, |hint| {
                        // Same hoist as `t_time_opt`: the closure body is
                        // [`Self::e_final`]'s tiered arm verbatim.
                        let flat = s.scalar_effective();
                        let ev = ExactEvaluator::new(s, *m);
                        let obj = |t: f64| {
                            if t <= s.a() {
                                return f64::INFINITY;
                            }
                            let fo_tiered = energy::e_final(s, t);
                            let fo_flat = energy::e_final(&flat, t);
                            if !fo_tiered.is_finite() || !fo_flat.is_finite() {
                                f64::INFINITY
                            } else {
                                ev.breakdown(t).energy + (fo_tiered - fo_flat)
                            }
                        };
                        if let Some(h) = hint {
                            if let Some(t) = numeric_opt_warm(s, &obj, h) {
                                return (t, true);
                            }
                        }
                        (numeric_opt(s, &obj), false)
                    }))
                } else {
                    Ok(cached_opt(OPT_ENERGY_TAG, *m, s, |hint| {
                        if let Some(h) = hint {
                            if let Some(t) = t_energy_opt_exact_warm(s, *m, h) {
                                return (t, true);
                            }
                        }
                        (t_energy_opt_exact(s, *m), false)
                    }))
                }
            }
        }
    }
}

const OPT_TIME_TAG: u64 = 1;
const OPT_ENERGY_TAG: u64 = 2;

type OptKey = Vec<u64>;

/// One entry per (optimum, recovery model, scenario) triple; see
/// [`PureMemo`] for the clearing/concurrency contract. Sized for drift
/// sweeps, which visit one scenario per distinct quantised trajectory
/// view ([`opt_memo_stats`] reports the churn). Keys are the
/// variable-length [`Scenario::key_words`] (scalar scenarios produce
/// the historical 12-word shape, tiered ones append their extension).
static OPT_MEMO: PureMemo<OptKey> = PureMemo::new(32_768);

fn opt_key(tag: u64, model: RecoveryModel, s: &Scenario) -> OptKey {
    let mut k = Vec::with_capacity(12);
    k.push(tag);
    k.push(match model {
        RecoveryModel::Ideal => 1,
        RecoveryModel::Restarting => 2,
    });
    k.extend(s.key_words());
    k
}

/// Last solved argmin per **drift-invariant scenario family** — the
/// warm-start hint store behind [`Backend::t_time_opt`] /
/// [`Backend::t_energy_opt`] memo misses. Drift targets rescale `C`,
/// `R`, `μ` and `P_IO` only, so the family key keeps everything drift
/// leaves fixed (`D`, `ω`, the other power rails, `t_base`, the tier
/// words): successive quantised views of one drifting scenario land on
/// the same family and seed each other's brackets. Entries are
/// advisory — a stale or cross-scenario hint either fails the bracket
/// check (cold fallback) or validates to the cold-identical bracket —
/// so last-writer-wins overwrite ([`ShardedMap::put`]) is sound.
static WARM_HINTS: ShardedMap<OptKey, f64> = ShardedMap::clearing(32_768);

fn warm_key(tag: u64, model: RecoveryModel, s: &Scenario) -> OptKey {
    let mut k = Vec::with_capacity(12);
    k.push(tag);
    k.push(match model {
        RecoveryModel::Ideal => 1,
        RecoveryModel::Restarting => 2,
    });
    k.push(s.ckpt.d.to_bits());
    k.push(s.ckpt.omega.to_bits());
    k.push(s.power.p_static.to_bits());
    k.push(s.power.p_cal.to_bits());
    k.push(s.power.p_down.to_bits());
    k.push(s.t_base.to_bits());
    if let Some(h) = s.hierarchy() {
        for i in 0..h.len() {
            let tier = h.tier(i);
            k.push(tier.c.to_bits());
            k.push(tier.r.to_bits());
            k.push(tier.p_io.to_bits());
        }
    }
    k
}

/// Numeric argmin over the first-order feasibility domain — the same
/// bracketing as `energy::t_energy_opt_numeric`, but over an arbitrary
/// (tier-corrected) objective.
fn numeric_opt(s: &Scenario, f: impl FnMut(f64) -> f64) -> f64 {
    let (lo, hi) = s.domain();
    let lo = lo.max(s.min_period() * 0.5).max(lo + 1e-9 * (hi - lo));
    let hi = hi * (1.0 - 1e-9);
    if lo >= hi {
        return s.min_period();
    }
    let (t, _) = grid_then_golden(f, lo, hi, 400, 1e-9 * (hi - lo));
    t
}

/// [`numeric_opt`] seeded from `hint`: identical bracket expressions,
/// so a validated hint yields the cold argmin bit-for-bit. `None` on a
/// failed bracket check — and on the degenerate `lo >= hi` domain,
/// where the cold path's `min_period` early-out must win.
fn numeric_opt_warm(s: &Scenario, f: impl FnMut(f64) -> f64, hint: f64) -> Option<f64> {
    let (lo, hi) = s.domain();
    let lo = lo.max(s.min_period() * 0.5).max(lo + 1e-9 * (hi - lo));
    let hi = hi * (1.0 - 1e-9);
    if lo >= hi {
        return None;
    }
    let (t, _) = grid_then_golden_warm(f, lo, hi, 400, 1e-9 * (hi - lo), hint)?;
    Some(t)
}

/// Memoised numeric optimum: pure function of the key, so which thread
/// (or concurrently running grid cell) fills the entry first cannot
/// change the value anyone reads.
///
/// On a memo miss, `solve` receives the family's previous argmin from
/// [`WARM_HINTS`] (if any) and reports `(argmin, used_warm_path)`; the
/// fresh argmin is stored back as the family's next hint. Warm hits
/// and fallbacks are counted on `ckpt_opt_warm_{hits,fallbacks}_total`.
fn cached_opt(
    tag: u64,
    model: RecoveryModel,
    s: &Scenario,
    solve: impl FnOnce(Option<f64>) -> (f64, bool),
) -> f64 {
    let fam = warm_key(tag, model, s);
    OPT_MEMO.get_or_compute(opt_key(tag, model, s), || {
        let hint = WARM_HINTS.get(&fam);
        let (t, warm) = solve(hint);
        if warm {
            metrics::OPT_WARM_HITS_TOTAL.inc();
        } else {
            metrics::OPT_WARM_FALLBACKS_TOTAL.inc();
        }
        WARM_HINTS.put(fam, t);
        t
    })
}

/// Counter snapshot of the exact-optima memo (hits/misses/wholesale
/// clears since process start) plus its live entry count — the `info`
/// subcommand's churn report (drift trajectories re-key this memo once
/// per distinct scenario view).
pub fn opt_memo_stats() -> (crate::util::memo::MemoStats, usize) {
    (OPT_MEMO.stats(), OPT_MEMO.len())
}

/// Live entries per backing shard (`ckpt_cache_shard_entries`).
pub fn opt_memo_shard_entries() -> Vec<usize> {
    OPT_MEMO.shard_entries()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{fig1_scenario, tradeoff_presets};
    use crate::model::params::{CheckpointParams, PowerParams};
    use crate::util::stats::rel_err;

    #[test]
    fn parse_roundtrips_through_name() {
        for b in [
            Backend::FirstOrder,
            Backend::Exact(RecoveryModel::Ideal),
            Backend::Exact(RecoveryModel::Restarting),
        ] {
            assert_eq!(Backend::parse(b.name()), Some(b), "{}", b.name());
        }
        assert_eq!(
            Backend::parse("exact:restarting"),
            Some(Backend::Exact(RecoveryModel::Restarting))
        );
        for bad in ["", "exact:", "exact:lazy", "firstorder", "EXACT", "second-order"] {
            assert_eq!(Backend::parse(bad), None, "{bad}");
        }
        assert_eq!(Backend::default(), Backend::FirstOrder);
    }

    #[test]
    fn key_words_are_distinct() {
        let words = [
            Backend::FirstOrder.key_word(),
            Backend::Exact(RecoveryModel::Ideal).key_word(),
            Backend::Exact(RecoveryModel::Restarting).key_word(),
        ];
        for i in 0..words.len() {
            for j in i + 1..words.len() {
                assert_ne!(words[i], words[j]);
            }
        }
    }

    #[test]
    fn first_order_backend_is_bit_identical_to_the_closed_forms() {
        let s = fig1_scenario(300.0, 5.5);
        let b = Backend::FirstOrder;
        for t in [20.0, 53.0, 100.0, 200.0] {
            assert_eq!(b.t_final(&s, t).to_bits(), time::t_final(&s, t).to_bits());
            assert_eq!(b.e_final(&s, t).to_bits(), energy::e_final(&s, t).to_bits());
        }
        assert_eq!(
            b.t_time_opt(&s).unwrap().to_bits(),
            time::t_time_opt(&s).unwrap().to_bits()
        );
        assert_eq!(
            b.t_energy_opt(&s).unwrap().to_bits(),
            energy::t_energy_opt(&s).unwrap().to_bits()
        );
    }

    #[test]
    fn exact_backend_matches_the_exact_module() {
        let s = fig1_scenario(120.0, 5.5);
        for m in [RecoveryModel::Ideal, RecoveryModel::Restarting] {
            let b = Backend::Exact(m);
            for t in [30.0, 60.0, 120.0] {
                assert_eq!(b.t_final(&s, t).to_bits(), t_final_exact(&s, t, m).to_bits());
                assert_eq!(b.e_final(&s, t).to_bits(), e_final_exact(&s, t, m).to_bits());
            }
            assert_eq!(b.t_time_opt(&s).unwrap(), t_time_opt_exact(&s, m));
            assert_eq!(b.t_energy_opt(&s).unwrap(), t_energy_opt_exact(&s, m));
        }
    }

    #[test]
    fn exact_optima_are_memoised_bit_stably() {
        let s = fig1_scenario(60.0, 5.5);
        let b = Backend::Exact(RecoveryModel::Ideal);
        let a1 = b.t_time_opt(&s).unwrap();
        let a2 = b.t_time_opt(&s).unwrap();
        assert_eq!(a1.to_bits(), a2.to_bits());
        let e1 = b.t_energy_opt(&s).unwrap();
        let e2 = b.t_energy_opt(&s).unwrap();
        assert_eq!(e1.to_bits(), e2.to_bits());
        // Time and energy optima do not alias in the memo.
        assert_ne!(a1.to_bits(), e1.to_bits());
    }

    #[test]
    fn out_of_first_order_domain_errors_under_every_backend() {
        // C >= 2*mu*b: the shared feasibility gate rejects the scenario
        // for first-order AND exact, keeping clamp regimes aligned.
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        let s = Scenario::new(ckpt, power, 17.0, 1000.0).unwrap();
        for b in [
            Backend::FirstOrder,
            Backend::Exact(RecoveryModel::Ideal),
            Backend::Exact(RecoveryModel::Restarting),
        ] {
            assert!(b.t_time_opt(&s).is_err(), "{}", b.name());
            assert!(b.t_energy_opt(&s).is_err(), "{}", b.name());
        }
    }

    #[test]
    fn sub_domain_periods_are_infinite_not_panics() {
        let s = fig1_scenario(300.0, 5.5);
        let b = Backend::Exact(RecoveryModel::Ideal);
        // t <= a = 5: the exact forms would assert; the backend returns
        // +inf like the first-order forms do outside their domain.
        assert!(b.t_final(&s, 5.0).is_infinite());
        assert!(b.e_final(&s, 2.0).is_infinite());
        assert!(b.expected_failures(&s, 5.0).is_infinite());
    }

    #[test]
    fn backends_converge_at_large_mu_and_drift_at_small_mu() {
        let quiet = fig1_scenario(1e5, 5.5);
        let b = Backend::Exact(RecoveryModel::Ideal);
        assert!(
            rel_err(
                b.t_time_opt(&quiet).unwrap(),
                Backend::FirstOrder.t_time_opt(&quiet).unwrap()
            ) < 0.01
        );
        let stressed = fig1_scenario(60.0, 5.5);
        assert!(
            rel_err(
                b.t_time_opt(&stressed).unwrap(),
                Backend::FirstOrder.t_time_opt(&stressed).unwrap()
            ) > 0.1
        );
    }

    #[test]
    fn objectives_are_bit_identical_to_the_separate_evaluations() {
        let s = fig1_scenario(120.0, 5.5);
        for b in [
            Backend::FirstOrder,
            Backend::Exact(RecoveryModel::Ideal),
            Backend::Exact(RecoveryModel::Restarting),
        ] {
            for t in [2.0, 30.0, 60.0, 120.0] {
                let (time, energy) = b.objectives(&s, t);
                assert_eq!(time.to_bits(), b.t_final(&s, t).to_bits(), "{} t={t}", b.name());
                assert_eq!(energy.to_bits(), b.e_final(&s, t).to_bits(), "{} t={t}", b.name());
            }
        }
    }

    #[test]
    fn exact_backend_applies_additive_tier_correction() {
        use crate::storage::TierSpec;
        let flat = fig1_scenario(120.0, 5.5);
        let tiered = Scenario::with_tier_specs(
            flat.ckpt,
            flat.power,
            flat.mu,
            flat.t_base,
            &[TierSpec::new(1.0, 1.0, 30.0), TierSpec::new(10.0, 10.0, 100.0)],
        )
        .unwrap();
        let proj = tiered.scalar_effective();
        for m in [RecoveryModel::Ideal, RecoveryModel::Restarting] {
            let b = Backend::Exact(m);
            for t in [30.0, 60.0, 120.0] {
                let expect = t_final_exact(&proj, t, m)
                    + (time::t_final(&tiered, t) - time::t_final(&proj, t));
                assert_eq!(b.t_final(&tiered, t).to_bits(), expect.to_bits());
                let expect_e = e_final_exact(&proj, t, m)
                    + (energy::e_final(&tiered, t) - energy::e_final(&proj, t));
                assert_eq!(b.e_final(&tiered, t).to_bits(), expect_e.to_bits());
            }
            // Optima are finite, in-domain, memo-stable, and distinct
            // from the flattened projection's (the memo key carries the
            // tier words).
            let tt = b.t_time_opt(&tiered).unwrap();
            assert_eq!(tt.to_bits(), b.t_time_opt(&tiered).unwrap().to_bits());
            assert!(tt >= tiered.min_period());
            assert!(b.t_final(&tiered, tt).is_finite());
            let flat_tt = b.t_time_opt(&proj).unwrap();
            assert_ne!(tt.to_bits(), flat_tt.to_bits(), "{}", b.name());
        }
    }

    #[test]
    fn warm_hints_never_change_results() {
        // Scenarios in one drift-invariant family (only μ differs):
        // every solve after the first sees the previous argmin as its
        // warm hint, and must still equal the hint-free exact solve
        // bit-for-bit — hints steer the scan, never the answer.
        for m in [RecoveryModel::Ideal, RecoveryModel::Restarting] {
            let b = Backend::Exact(m);
            for mu in [90.0, 96.0, 103.0, 111.0, 240.0, 57.0] {
                let s = fig1_scenario(mu, 5.5);
                assert_eq!(
                    b.t_time_opt(&s).unwrap().to_bits(),
                    t_time_opt_exact(&s, m).to_bits(),
                    "time {} mu={mu}",
                    b.name()
                );
                assert_eq!(
                    b.t_energy_opt(&s).unwrap().to_bits(),
                    t_energy_opt_exact(&s, m).to_bits(),
                    "energy {} mu={mu}",
                    b.name()
                );
            }
        }
    }

    #[test]
    fn tiered_warm_resolves_match_cold_numeric_opt() {
        use crate::storage::TierSpec;
        let specs = [TierSpec::new(1.0, 1.0, 30.0), TierSpec::new(10.0, 10.0, 100.0)];
        let m = RecoveryModel::Restarting;
        let b = Backend::Exact(m);
        // One drift-invariant tiered family solved in sequence: the
        // second and third solves see the previous argmin as a hint.
        for mu in [140.0, 133.0, 127.0] {
            let base = fig1_scenario(mu, 5.5);
            let s = Scenario::with_tier_specs(base.ckpt, base.power, base.mu, base.t_base, &specs)
                .unwrap();
            let got = b.t_time_opt(&s).unwrap();
            // Cold reference: the tiered objective minimised hint-free.
            let flat = s.scalar_effective();
            let ev = ExactEvaluator::new(&s, m);
            let cold = numeric_opt(&s, |t| {
                if t <= s.a() {
                    return f64::INFINITY;
                }
                let fo_tiered = time::t_final(&s, t);
                let fo_flat = time::t_final(&flat, t);
                if !fo_tiered.is_finite() || !fo_flat.is_finite() {
                    f64::INFINITY
                } else {
                    ev.breakdown(t).makespan + (fo_tiered - fo_flat)
                }
            });
            assert_eq!(got.to_bits(), cold.to_bits(), "mu={mu}");
        }
    }

    #[test]
    fn expected_failures_match_the_underlying_models() {
        for (label, s) in tradeoff_presets() {
            let t = time::t_time_opt(&s).expect(label);
            assert_eq!(
                Backend::FirstOrder.expected_failures(&s, t).to_bits(),
                time::expected_failures(&s, t).to_bits(),
                "{label}"
            );
            // Ideal: exactly the primary (up-time) failure count.
            let primary = exact_breakdown(&s, t, RecoveryModel::Ideal).failures;
            assert_eq!(
                Backend::Exact(RecoveryModel::Ideal).expected_failures(&s, t).to_bits(),
                primary.to_bits(),
                "{label}"
            );
            // Restarting: the simulator also counts the geometric
            // restarts during D + R, so the observed total exceeds the
            // primary count by exactly e^{(D+R)/mu}.
            let total = Backend::Exact(RecoveryModel::Restarting).expected_failures(&s, t);
            let scale = ((s.ckpt.d + s.ckpt.r) / s.mu).exp();
            assert!(total > primary, "{label}");
            assert!(
                rel_err(total, primary * scale) < 1e-12,
                "{label}: {total} vs {} * {scale}",
                primary
            );
        }
    }
}
