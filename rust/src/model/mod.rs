//! The paper's analytical model (§2–§3).
//!
//! * [`params`] — checkpoint, power and platform parameters ([`Scenario`]).
//! * [`time`] — expected makespan `T_final(T)` and the time-optimal period
//!   `T_Time_opt` (Eq. 1), plus Young's and Daly's classical formulas.
//! * [`energy`] — expected energy `E_final(T)` phase by phase, and the
//!   energy-optimal period `T_Energy_opt` (positive root of the
//!   stationarity quadratic of `E_final`).
//! * [`optimize`] — golden-section minimiser used to cross-validate the
//!   closed forms and to optimise models with no closed form (MSK).
//! * [`exact`] — the exact renewal expectations for exponential failures
//!   (no first-order truncation), with numeric optima.
//! * [`backend`] — the [`Backend`] dispatch point every downstream
//!   consumer (frontier, policies, grid cells, figures, CLI) evaluates
//!   the objectives through: `Backend::FirstOrder` is the paper's
//!   closed forms, `Backend::Exact(RecoveryModel)` the exact renewal
//!   model with memoised numeric optima. Select it on the CLI with
//!   `--model first-order|exact|exact:ideal|exact:restarting`.
//! * [`msk`] — the Meneses–Sarood–Kalé baseline of [6], with the
//!   per-failure loss terms the paper's §3.2 side note attributes to it.
//! * [`ratios`] — the AlgoT-vs-AlgoE comparisons all figures are built on.
//! * [`tiers`] — the multi-level storage analytics: κ-minimised
//!   time/energy envelopes over a [`crate::storage::TierHierarchy`] and
//!   the memoised optimal period-plus-cadence vector ([`tiers::TierPlan`]).
//!   [`time`]/[`energy`] dispatch to it when a scenario carries a
//!   hierarchy; scalar scenarios never touch it.
//!
//! # When the exact backend matters
//!
//! The first-order forms neglect multi-failure-per-period terms that
//! scale like `(T/μ)²`; at small `μ` — frequent failures, exactly where
//! the time/energy trade-off is widest — their optimal periods drift
//! 5–40% from the exact ones (`figures::knee_drift` tabulates the
//! drift; EXPERIMENTS.md records the headline numbers). At `μ ≫ C+R+D`
//! the backends agree to well under a percent.
//!
//! # Conventions
//!
//! All times are **minutes** (the paper's unit) and powers are **mW per
//! node** (the paper's 20 MW / 10⁶ nodes budget); energies are mW·min.
//! The model is agnostic to units as long as they are consistent.

pub mod backend;
pub mod energy;
pub mod exact;
pub mod msk;
pub mod optimize;
pub mod params;
pub mod ratios;
pub mod tiers;
pub mod time;
pub mod waste;

pub use backend::Backend;
pub use energy::{e_final, t_energy_opt};
pub use exact::RecoveryModel;
pub use params::{CheckpointParams, ModelError, Platform, PowerParams, Scenario};
pub use ratios::{compare, Comparison};
pub use time::{t_final, t_time_opt};
