//! The checkpointed application: a byte-level transformer-LM training
//! workload executed through PJRT.
//!
//! * [`data`] — deterministic synthetic corpus (an affine byte map plus a
//!   Markov background), batched to the shapes baked into the artifacts.
//! * [`trainer`] — [`trainer::TrainState`] (flat `theta`/`m`/`v`/`step`,
//!   exactly the artifact's calling convention) and
//!   [`trainer::TrainSession`] which owns the compiled `train_step` /
//!   `eval_loss` executables and advances the state one step per call.

pub mod data;
pub mod trainer;

pub use data::DataGen;
pub use trainer::{LitTrainState, TrainSession, TrainState};
