//! Training session: rust-owned state advanced by the compiled
//! `train_step` artifact, one PJRT call per step.

use super::data::DataGen;
#[cfg(not(feature = "pjrt"))]
use crate::runtime::xla_stub as xla;

use crate::runtime::artifacts::ArtifactDir;
use crate::runtime::client::{
    literal_f32, literal_i32_2d, literal_scalar_f32, to_scalar_f32, to_vec_f32, Executable,
    Runtime, RuntimeError,
};

/// Full training state — exactly what a checkpoint must capture.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
    /// Index of the next data batch (so restores replay the schedule).
    pub next_batch: u64,
}

impl TrainState {
    /// Fresh state from the artifact's initial parameters.
    pub fn initial(dir: &ArtifactDir) -> Result<Self, RuntimeError> {
        let theta = dir.initial_params()?;
        let n = theta.len();
        Ok(TrainState { theta, m: vec![0.0; n], v: vec![0.0; n], step: 0.0, next_batch: 0 })
    }

    pub fn n_params(&self) -> usize {
        self.theta.len()
    }

    /// Total bytes a checkpoint of this state occupies (3 f32 vectors +
    /// step + batch counter).
    pub fn checkpoint_bytes(&self) -> usize {
        3 * 4 * self.theta.len() + 4 + 8
    }
}

/// Literal-resident training state — the §Perf representation of the
/// hot loop (EXPERIMENTS.md §Perf L3-2).
///
/// Keeping `theta`/`m`/`v` as `xla::Literal` between steps skips the
/// `Literal -> Vec<f32> -> Literal` round trip (~7 ms/step at 470k
/// params); the host vectors are materialised only when a checkpoint
/// snapshot is taken.
pub struct LitTrainState {
    theta: xla::Literal,
    m: xla::Literal,
    v: xla::Literal,
    pub step: f32,
    pub next_batch: u64,
}

impl LitTrainState {
    pub fn from_state(s: &TrainState) -> Self {
        LitTrainState {
            theta: literal_f32(&s.theta),
            m: literal_f32(&s.m),
            v: literal_f32(&s.v),
            step: s.step,
            next_batch: s.next_batch,
        }
    }

    /// Materialise the host-vector form (checkpoint snapshots).
    pub fn to_state(&self) -> Result<TrainState, RuntimeError> {
        Ok(TrainState {
            theta: to_vec_f32(&self.theta)?,
            m: to_vec_f32(&self.m)?,
            v: to_vec_f32(&self.v)?,
            step: self.step,
            next_batch: self.next_batch,
        })
    }
}

/// Owns the compiled executables and the data generator; advances a
/// [`TrainState`] one step per [`TrainSession::step`] call.
pub struct TrainSession {
    train_exe: Executable,
    eval_exe: Executable,
    data: DataGen,
    batch: usize,
    seq: usize,
}

impl TrainSession {
    pub fn new(rt: &Runtime, dir: &ArtifactDir, data_seed: u64) -> Result<Self, RuntimeError> {
        let train_exe = rt.load_hlo_text(&dir.hlo_path("train_step"))?;
        let eval_exe = rt.load_hlo_text(&dir.hlo_path("eval_loss"))?;
        let data = DataGen::new(dir.batch, dir.seq, dir.vocab, data_seed);
        Ok(TrainSession { train_exe, eval_exe, data, batch: dir.batch, seq: dir.seq })
    }

    pub fn data(&self) -> &DataGen {
        &self.data
    }

    /// Execute one training step, mutating `state` in place.
    /// Returns the step's loss.
    pub fn step(&self, state: &mut TrainState) -> Result<f32, RuntimeError> {
        let (x, y) = self.data.batch_at(state.next_batch);
        let out = self.train_exe.call(&[
            literal_f32(&state.theta),
            literal_f32(&state.m),
            literal_f32(&state.v),
            literal_scalar_f32(state.step),
            literal_i32_2d(&x, self.batch, self.seq)?,
            literal_i32_2d(&y, self.batch, self.seq)?,
        ])?;
        if out.len() != 5 {
            return Err(RuntimeError::Artifact(format!(
                "train_step returned {}-tuple, expected 5",
                out.len()
            )));
        }
        state.theta = to_vec_f32(&out[0])?;
        state.m = to_vec_f32(&out[1])?;
        state.v = to_vec_f32(&out[2])?;
        state.step = to_scalar_f32(&out[3])?;
        state.next_batch += 1;
        to_scalar_f32(&out[4])
    }

    /// One training step on literal-resident state — the optimised hot
    /// path (no host-vector round trip; see [`LitTrainState`]).
    pub fn step_lit(&self, state: &mut LitTrainState) -> Result<f32, RuntimeError> {
        let (x, y) = self.data.batch_at(state.next_batch);
        let step_scalar = literal_scalar_f32(state.step);
        let xl = literal_i32_2d(&x, self.batch, self.seq)?;
        let yl = literal_i32_2d(&y, self.batch, self.seq)?;
        let inputs: [&xla::Literal; 6] =
            [&state.theta, &state.m, &state.v, &step_scalar, &xl, &yl];
        let mut out = self.train_exe.call(&inputs)?;
        if out.len() != 5 {
            return Err(RuntimeError::Artifact(format!(
                "train_step returned {}-tuple, expected 5",
                out.len()
            )));
        }
        let loss = to_scalar_f32(&out[4])?;
        state.step = to_scalar_f32(&out[3])?;
        state.v = out.swap_remove(2);
        state.m = out.swap_remove(1);
        state.theta = out.swap_remove(0);
        state.next_batch += 1;
        Ok(loss)
    }

    /// Forward-only loss on literal-resident state.
    pub fn eval_lit(&self, state: &LitTrainState, index: u64) -> Result<f32, RuntimeError> {
        let (x, y) = self.data.batch_at(index);
        let xl = literal_i32_2d(&x, self.batch, self.seq)?;
        let yl = literal_i32_2d(&y, self.batch, self.seq)?;
        let inputs: [&xla::Literal; 3] = [&state.theta, &xl, &yl];
        let out = self.eval_exe.call(&inputs)?;
        to_scalar_f32(&out[0])
    }

    /// Forward-only loss on batch `index` (checkpoint verification,
    /// validation logging).
    pub fn eval(&self, state: &TrainState, index: u64) -> Result<f32, RuntimeError> {
        let (x, y) = self.data.batch_at(index);
        let out = self.eval_exe.call(&[
            literal_f32(&state.theta),
            literal_i32_2d(&x, self.batch, self.seq)?,
            literal_i32_2d(&y, self.batch, self.seq)?,
        ])?;
        to_scalar_f32(&out[0])
    }
}

// Execution tests live in rust/tests/runtime_integration.rs (they need
// the artifacts + a PJRT client). State-only tests:
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_bytes_accounting() {
        let s = TrainState {
            theta: vec![0.0; 100],
            m: vec![0.0; 100],
            v: vec![0.0; 100],
            step: 0.0,
            next_batch: 0,
        };
        assert_eq!(s.checkpoint_bytes(), 1212);
        assert_eq!(s.n_params(), 100);
    }
}
