//! Synthetic training corpus.
//!
//! The task mixes two structures a small causal transformer learns within
//! a few hundred steps (so the end-to-end example's loss curve visibly
//! drops):
//!
//! * an **affine byte map**: the target for token `x` is
//!   `(3x + 7) mod V` — a lookup table (same task the python unit tests
//!   train on);
//! * a **Markov background** on the inputs: tokens follow a sticky chain
//!   so the input distribution itself is non-uniform.
//!
//! Generation is deterministic per seed — a restored run re-produces the
//! exact same batch sequence, which the checkpoint/rollback tests rely
//! on (the coordinator replays post-rollback batches bit-identically).

use crate::util::rng::Pcg64;

/// Deterministic batch generator with the artifact's (batch, seq) shape.
#[derive(Debug, Clone)]
pub struct DataGen {
    batch: usize,
    seq: usize,
    vocab: i32,
    seed: u64,
}

impl DataGen {
    pub fn new(batch: usize, seq: usize, vocab: usize, seed: u64) -> Self {
        assert!(batch > 0 && seq > 0 && vocab > 1);
        DataGen { batch, seq, vocab: vocab as i32, seed }
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        (self.batch, self.seq)
    }

    /// Target map: `(3x + 7) mod V`.
    #[inline]
    pub fn target_of(&self, x: i32) -> i32 {
        (3 * x + 7) % self.vocab
    }

    /// Generate batch `index` (flat row-major `[batch, seq]` x and y).
    /// Batches are addressable by index, not by stream position: after a
    /// rollback the coordinator re-requests the same indices and gets the
    /// same bytes.
    pub fn batch_at(&self, index: u64) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Pcg64::new(self.seed, index.wrapping_add(1));
        let n = self.batch * self.seq;
        let mut x = Vec::with_capacity(n);
        // Sticky Markov chain: with p=0.6 stay near the previous token,
        // else jump uniformly.
        let mut prev = rng.below(self.vocab as u64) as i32;
        for _ in 0..n {
            let t = if rng.uniform() < 0.6 {
                (prev + rng.below(5) as i32 - 2).rem_euclid(self.vocab)
            } else {
                rng.below(self.vocab as u64) as i32
            };
            x.push(t);
            prev = t;
        }
        let y = x.iter().map(|&t| self.target_of(t)).collect();
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_index() {
        let g = DataGen::new(4, 16, 256, 9);
        assert_eq!(g.batch_at(3), g.batch_at(3));
        assert_ne!(g.batch_at(3), g.batch_at(4));
    }

    #[test]
    fn shapes_and_ranges() {
        let g = DataGen::new(8, 64, 256, 1);
        let (x, y) = g.batch_at(0);
        assert_eq!(x.len(), 8 * 64);
        assert_eq!(y.len(), 8 * 64);
        assert!(x.iter().all(|&t| (0..256).contains(&t)));
        assert!(y.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn targets_follow_affine_map() {
        let g = DataGen::new(2, 8, 256, 2);
        let (x, y) = g.batch_at(7);
        for (&xi, &yi) in x.iter().zip(&y) {
            assert_eq!(yi, (3 * xi + 7) % 256);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = DataGen::new(2, 8, 256, 1).batch_at(0);
        let b = DataGen::new(2, 8, 256, 2).batch_at(0);
        assert_ne!(a, b);
    }

    #[test]
    fn input_distribution_is_sticky() {
        // Adjacent tokens should often be within +-2 (the sticky moves).
        let g = DataGen::new(1, 4096, 256, 3);
        let (x, _) = g.batch_at(0);
        let near = x
            .windows(2)
            .filter(|w| {
                let d = (w[0] - w[1]).rem_euclid(256);
                d <= 2 || d >= 254
            })
            .count();
        let frac = near as f64 / (x.len() - 1) as f64;
        assert!(frac > 0.4, "frac={frac}");
    }
}
