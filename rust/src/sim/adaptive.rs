//! Adaptive-period discrete-event simulation: the online controller in
//! the loop, on stationary **or drifting** environments.
//!
//! [`super::engine`] simulates a *fixed* checkpointing period. This
//! module closes the loop the coordinator runs in production: an
//! [`AdaptiveController`] rides along the sample path, re-estimating
//! `C` and `R` from the (simulated) measured durations and `μ` from the
//! exposure estimator, and the period in force is re-read from its
//! [`PeriodPolicy`] after every completed checkpoint and every
//! recovery. With the frontier-aware policies (knee, ε-budgets) this is
//! the end-to-end test bed for "checkpoint at the Pareto knee online":
//! VELOC-style drifting parameters meet the paper's closed forms.
//!
//! # Drift
//!
//! [`AdaptiveSimConfig::drift`] binds the scenario to a
//! [`DriftProcess`]: the *true* environment then follows the
//! [`EnvTrajectory`] — checkpoint and recovery durations are read from
//! the scenario-at-time view at each phase start, the I/O power draw
//! integrates at its instantaneous value, and (with
//! [`AdaptiveSimConfig::paper_drifting`]) failures arrive from the
//! non-homogeneous thinned sampler. Two drift-tracking metrics ride
//! along every run:
//!
//! * **tracking lag** — at every period re-read point, the relative
//!   distance between the period in force and the policy's period on
//!   the *true instantaneous* scenario (the moving knee), averaged over
//!   the run ([`AdaptiveRunResult::tracking_lag_pct`]);
//! * **oracle regret** — [`AdaptiveSimConfig::oracle`] replaces the
//!   controller with a clairvoyant tracker that reads the true
//!   instantaneous policy period at the same decision points; the
//!   waste/energy gap between the paired runs (same seeds, same
//!   failure draws where μ is stationary) is the price of estimating
//!   instead of knowing ([`crate::sweep::DriftSummary`]).
//!
//! With [`DriftProcess::Stationary`] every code path below reduces to
//! the exact pre-drift behaviour **bit-for-bit**: `scenario_at` returns
//! the base scenario's bits, the failure stream falls back to the
//! homogeneous sampler with the same split tag, and the energy integral
//! is evaluated by the original end-of-run formula (the incremental
//! accumulation drift needs would reassociate the floating-point sums).
//! `tests/drift_tracking.rs` pins this zero-regression guarantee across
//! every trade-off preset and thread count.
//!
//! Semantics are exactly [`super::engine`]'s (same phase structure,
//! power states, and energy integration); the only addition is the
//! controller. The event loop deliberately mirrors the engine's rather
//! than threading callbacks through its hot path — any change to the
//! engine's phase or recovery semantics MUST be applied to both
//! (`deterministic_per_seed` + the engine's tests guard each side, and
//! `failure_free_run_stretches_the_period` ties the two together).
//! Measured durations equal the trajectory's true `C(t)`/`R(t)`
//! (the simulator has no measurement noise), so the estimates converge
//! from the controller's prior toward the truth and the applied period
//! converges — modulo the period-space hysteresis band — to the
//! policy's period on the true scenario.
//!
//! Runs are a pure function of `(config, seed)`: the controller is
//! deterministic (the frontier memo in [`crate::pareto::online`] caches
//! pure values keyed on quantised estimates), drift schedules are
//! deterministic, so Monte-Carlo estimates are byte-identical for every
//! thread count, exactly like [`super::runner::monte_carlo`].
//!
//! # Decision traces
//!
//! When a JSONL sink is installed ([`crate::telemetry::trace`], wired
//! to `simulate --adaptive --trace <path>`), every sample path emits
//! its decision log: `observe` events with the post-update estimates,
//! a `period` event at every re-read point (`current` vs `fresh`,
//! `changed`, and `suppressed` when the controller's pre-hysteresis
//! recompute is being held back by the band), plus `failure` /
//! `recovery` events. Oracle runs carry `"oracle": true`. Tracing is
//! observational: every emit site is guarded on [`trace::enabled`]
//! (one relaxed load when off), and results are bit-identical with
//! the sink installed or not (`tests/telemetry.rs`).

use super::engine::{settle_drains_with, Drain};
use super::failure::{Failure, FailureProcess, FailureSource};
use crate::coordinator::adaptive::AdaptiveController;
use crate::coordinator::policy::PeriodPolicy;
use crate::drift::{DriftProcess, EnvTrajectory};
use crate::model::params::{ModelError, Scenario};
use crate::model::time::young;
use crate::storage::{CopyRecord, TierHierarchy, TierStore};
use crate::telemetry::trace;
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use crate::util::rng::Pcg64;
use crate::util::stats::OnlineStats;

/// Configuration of an adaptive simulation.
#[derive(Debug, Clone)]
pub struct AdaptiveSimConfig {
    /// Ground truth at `t = 0`: the base scenario the drift schedule
    /// multiplies.
    pub scenario: Scenario,
    /// The policy the controller recomputes the period with.
    pub policy: PeriodPolicy,
    pub failure: FailureProcess,
    /// See [`super::engine::SimConfig::failures_during_recovery`].
    pub failures_during_recovery: bool,
    /// The controller's MTBF prior. The leader seeds it with the
    /// configured μ; pass something else to model a mis-calibrated
    /// prior the controller has to estimate its way out of.
    pub prior_mu: f64,
    /// Period-space hysteresis band handed to the controller.
    pub hysteresis: f64,
    /// C/R EWMA smoothing factor handed to the controller
    /// ([`AdaptiveController::with_ewma_alpha`]; default `0.3`).
    pub alpha: f64,
    /// How the true environment drifts over the run
    /// ([`DriftProcess::Stationary`] = the paper's world).
    pub drift: DriftProcess,
    /// Replace the controller with a clairvoyant tracker: the period is
    /// re-read from the policy on the *true instantaneous* scenario at
    /// the same decision points (after every completed checkpoint and
    /// recovery). The baseline the drift figure's regret is measured
    /// against.
    pub oracle: bool,
}

impl AdaptiveSimConfig {
    /// The paper's aggregate-exponential failure process, a correct
    /// prior, the controller's default smoothing/hysteresis, and a
    /// stationary environment.
    pub fn paper(scenario: Scenario, policy: PeriodPolicy) -> Self {
        AdaptiveSimConfig {
            scenario,
            policy,
            failure: FailureProcess::Exponential { mtbf: scenario.mu },
            failures_during_recovery: true,
            prior_mu: scenario.mu,
            hysteresis: crate::coordinator::adaptive::DEFAULT_HYSTERESIS,
            alpha: crate::coordinator::adaptive::DEFAULT_EWMA_ALPHA,
            drift: DriftProcess::Stationary,
            oracle: false,
        }
    }

    /// [`Self::paper`] on a drifting environment: the failure process
    /// becomes the non-homogeneous thinned sampler over the trajectory
    /// (bit-identical to the paper process when the schedule leaves μ
    /// alone). Errors when the schedule is invalid or drives the
    /// scenario out of the model's domain.
    pub fn paper_drifting(
        scenario: Scenario,
        policy: PeriodPolicy,
        drift: DriftProcess,
    ) -> Result<Self, ModelError> {
        let trajectory = EnvTrajectory::new(scenario, drift)?;
        let mut cfg = AdaptiveSimConfig::paper(scenario, policy);
        cfg.failure = FailureProcess::DriftingExponential { trajectory };
        cfg.drift = drift;
        Ok(cfg)
    }
}

/// Outcome of one adaptive sample path. The phase/energy fields mirror
/// [`super::engine::RunResult`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveRunResult {
    pub makespan: f64,
    pub energy: f64,
    pub n_failures: u64,
    pub n_checkpoints: u64,
    pub work_lost: f64,
    pub time_compute: f64,
    pub time_checkpoint: f64,
    pub time_recovery: f64,
    pub time_down: f64,
    /// How many times the applied period actually changed (hysteresis
    /// band crossings; the initial period does not count).
    pub n_period_updates: u64,
    /// The period in force when the run finished.
    pub final_period: f64,
    /// Mean over the run's period re-read points of
    /// `|applied − target|/target · 100`, where `target` is the
    /// policy's period on the true instantaneous scenario — how far
    /// the controller trails the moving knee. `0` when the run ended
    /// before the first re-read. Note this raw gap folds in the μ
    /// exposure-estimator's sampling noise, which no EWMA knob
    /// controls; [`Self::drift_lag_pct`] is the noise-cancelled
    /// component.
    pub tracking_lag_pct: f64,
    /// The component of the lag attributable to *tracking the drifting
    /// C/R*: the same mean, but measured against the period the
    /// controller would compute with exact C/R — its own scenario view
    /// (base powers, its μ estimate) with the true `C(t)`/`R(t)`
    /// substituted. Evaluating both periods at the controller's μ
    /// estimate cancels the μ-sampling noise, so this is the metric
    /// that decreases monotonically as the EWMA α grows (the drift
    /// figure's acceptance gate). `0` in oracle mode and for μ-only
    /// drift (the EWMA tracks C/R exactly there).
    pub drift_lag_pct: f64,
    /// Number of re-read points the lags were sampled at.
    pub tracking_samples: u64,
}

/// What ended a phase (mirrors the engine).
enum PhaseEnd {
    Ran,
    Finished(f64),
    Failed(f64),
}

/// Phase outcome for a phase of `len` wall time during which `need`
/// work remains and work accrues at `rate`.
fn phase_end(now: f64, len: f64, need: f64, rate: f64, fail_at: f64) -> PhaseEnd {
    let finish = if rate > 0.0 && need / rate <= len { Some(need / rate) } else { None };
    let fail = if fail_at < now + len { Some(fail_at - now) } else { None };
    match (finish, fail) {
        (Some(f), Some(x)) if f <= x => PhaseEnd::Finished(f),
        (_, Some(x)) => PhaseEnd::Failed(x),
        (Some(f), None) => PhaseEnd::Finished(f),
        (None, None) => PhaseEnd::Ran,
    }
}

/// The adaptive simulator. Construct once, run many seeds.
///
/// Fields are `pub(crate)` so the batched lockstep executor
/// ([`super::batch`]) can drive the same trajectory/controller state
/// without re-validating the drift schedule per block.
#[derive(Debug, Clone)]
pub struct AdaptiveSimulator {
    pub(crate) cfg: AdaptiveSimConfig,
    /// The scenario-at-time view of `cfg.scenario` under `cfg.drift`.
    pub(crate) traj: EnvTrajectory,
    /// Cached `!traj.is_stationary()`: gates every drift-only branch so
    /// the stationary path stays bit-identical to the pre-drift code.
    pub(crate) drifting: bool,
    /// The scenario's storage hierarchy, when it has one: gates every
    /// tiered branch (drain queues, node-loss restarts) the same way
    /// `drifting` gates the drift branches — scalar scenarios stay
    /// bit-identical to the pre-tier code.
    pub(crate) tiered: Option<TierHierarchy>,
}

impl AdaptiveSimulator {
    pub fn new(cfg: AdaptiveSimConfig) -> Self {
        assert!(
            cfg.scenario.clamp_period(cfg.scenario.min_period()).is_ok(),
            "scenario has no feasible period"
        );
        let traj = EnvTrajectory::new(cfg.scenario, cfg.drift)
            .expect("drift schedule leaves the model's domain");
        let drifting = !traj.is_stationary();
        let tiered = cfg.scenario.hierarchy().copied();
        // Drift schedules multiply the *scalar* environment; what a
        // drifting multi-level hierarchy means (which tier's C ramps?)
        // is not defined yet, so the combination is rejected rather
        // than silently mis-simulated.
        assert!(
            tiered.is_none() || !drifting,
            "tiered scenarios require a stationary drift schedule"
        );
        AdaptiveSimulator { cfg, traj, drifting, tiered }
    }

    pub fn config(&self) -> &AdaptiveSimConfig {
        &self.cfg
    }

    /// A clairvoyant twin of this simulator: same scenario, same
    /// (already-validated) trajectory, with
    /// [`AdaptiveSimConfig::oracle`] set. The drift grid cell pairs
    /// each estimating run with its oracle baseline off one trajectory
    /// build instead of re-validating the drift schedule twice.
    pub fn oracle_twin(&self) -> AdaptiveSimulator {
        let mut twin = self.clone();
        twin.cfg.oracle = true;
        twin
    }

    /// Execute one sample path.
    pub fn run(&self, seed: u64) -> AdaptiveRunResult {
        let s = &self.cfg.scenario;
        let c = s.ckpt.c;
        let d = s.ckpt.d;
        let omega = s.ckpt.omega;
        let pw = s.power;

        let mut ctl = AdaptiveController::new(
            self.cfg.policy,
            s.power,
            omega,
            d,
            self.cfg.prior_mu,
            s.t_base,
        )
        .with_ewma_alpha(self.cfg.alpha)
        .with_hysteresis(self.cfg.hysteresis);
        // Calibration, as the leader does before its run: one measured
        // checkpoint and restore seed the C/R estimators (at the
        // trajectory's t = 0 values).
        let s0 = self.traj.scenario_at(0.0);
        ctl.observe_checkpoint(s0.ckpt.c);
        ctl.observe_restore(s0.ckpt.r);
        if trace::enabled() {
            trace::emit(&trace::event(
                "observe",
                seed,
                0.0,
                vec![
                    ("c_est", Json::Num(ctl.c_estimate())),
                    ("r_est", Json::Num(ctl.r_estimate())),
                    ("mu_est", Json::Num(ctl.mu_estimate())),
                    ("oracle", Json::Bool(self.cfg.oracle)),
                ],
            ));
        }

        // When the controller's estimates leave the model's domain the
        // period in force stays what it was; before the first successful
        // recompute that is a clamped Young period (classical, policy-
        // agnostic, always feasible here).
        let fallback = s.clamp_period(young(s)).expect("feasible by construction");
        let mut period = if self.cfg.oracle {
            self.instantaneous_target(0.0).unwrap_or(fallback)
        } else {
            match ctl.period() {
                Some(p) => s.clamp_period(p).unwrap_or(fallback),
                None => fallback,
            }
        };
        if trace::enabled() {
            // The initial period: a decision point that never counts as
            // an update (`changed` is false by definition).
            trace::emit(&trace::event(
                "period",
                seed,
                0.0,
                vec![
                    ("current", Json::Null),
                    ("fresh", Json::Num(period)),
                    ("changed", Json::Bool(false)),
                    ("suppressed", Json::Bool(false)),
                    ("oracle", Json::Bool(self.cfg.oracle)),
                ],
            ));
        }

        let mut rng = Pcg64::seeded(seed);
        let mut stream = self.cfg.failure.stream(&mut rng);

        let mut res = AdaptiveRunResult {
            makespan: 0.0,
            energy: 0.0,
            n_failures: 0,
            n_checkpoints: 0,
            work_lost: 0.0,
            time_compute: 0.0,
            time_checkpoint: 0.0,
            time_recovery: 0.0,
            time_down: 0.0,
            n_period_updates: 0,
            final_period: period,
            tracking_lag_pct: 0.0,
            drift_lag_pct: 0.0,
            tracking_samples: 0,
        };

        let mut now = 0.0f64;
        // Work captured by the last completed checkpoint.
        let mut saved = 0.0f64;
        // Work done during that checkpoint (not yet covered).
        let mut overlap = 0.0f64;
        let mut next_fail = stream.next_after(0.0);

        // ---- tiered storage state (`None` ⇒ every block below is
        // skipped and the scalar path is untouched) ----
        let mut store = self.tiered.as_ref().map(TierStore::new);
        let mut inflight: Vec<Drain> = Vec::new();
        let mut drain_free_at = 0.0f64;
        let mut drain_energy = 0.0f64;
        let mut rec_io_energy = 0.0f64;
        // Pin-set scratch, reused across every settle (no per-event
        // allocation; values rebuilt in place).
        let mut pinned: Vec<f64> = Vec::new();
        // Cadence plan for the period currently in force; recomputed
        // lazily when the controller moves the period.
        let mut kappa = [1u32; crate::storage::MAX_TIERS];
        let mut kappa_period = f64::NAN;

        loop {
            // Under drift, the compute slice is planned against the
            // checkpoint cost in force at the period's start; a
            // stretched C(t) can exceed the period the controller still
            // has in force, so floor the slice (progress per period
            // stays positive — the trajectory's worst corner is
            // validated feasible, this only guards the transient).
            let compute_len = if self.drifting {
                (period - self.traj.scenario_at(now).ckpt.c).max(1e-3 * c)
            } else {
                period - c
            };

            // ---- compute phase (rate 1, power static+cal) ----
            let base_progress = saved + overlap;
            let need = s.t_base - base_progress;
            debug_assert!(need > 0.0);
            match phase_end(now, compute_len, need, 1.0, next_fail.at) {
                PhaseEnd::Finished(dt) => {
                    res.time_compute += dt;
                    if self.drifting {
                        res.energy += (pw.p_static + pw.p_cal) * dt;
                    }
                    now += dt;
                    break;
                }
                PhaseEnd::Failed(dt) => {
                    res.time_compute += dt;
                    if self.drifting {
                        res.energy += (pw.p_static + pw.p_cal) * dt;
                    }
                    now += dt;
                    ctl.observe_uptime(dt);
                    let tier_rec = if let (Some(h), Some(st)) =
                        (self.tiered.as_ref(), store.as_mut())
                    {
                        Some(tiered_node_loss(
                            h,
                            st,
                            &mut inflight,
                            &mut drain_free_at,
                            &mut drain_energy,
                            now,
                            base_progress + dt,
                            &mut saved,
                            &mut overlap,
                            &mut res.work_lost,
                            &mut pinned,
                        ))
                    } else {
                        res.work_lost += overlap + dt;
                        overlap = 0.0;
                        None
                    };
                    self.fail_and_recover(
                        &mut ctl,
                        &mut res,
                        &mut now,
                        &mut next_fail,
                        &mut stream,
                        seed,
                        tier_rec,
                        &mut rec_io_energy,
                    );
                    self.reread_period(&mut ctl, &mut res, &mut period, now, seed);
                    continue;
                }
                PhaseEnd::Ran => {
                    res.time_compute += compute_len;
                    if self.drifting {
                        res.energy += (pw.p_static + pw.p_cal) * compute_len;
                    }
                    now += compute_len;
                    ctl.observe_uptime(compute_len);
                }
            }

            // ---- checkpoint phase (rate ω, power static+ω·cal+io) ----
            // The write cost and the I/O draw are the trajectory's
            // values at the checkpoint's start.
            let (c_ckpt, p_io_ckpt) = if self.drifting {
                let s_ck = self.traj.scenario_at(now);
                (s_ck.ckpt.c, s_ck.power.p_io)
            } else {
                (c, pw.p_io)
            };
            let ckpt_rate = pw.p_static + omega * pw.p_cal + p_io_ckpt;
            let at_ckpt_start = base_progress + compute_len;
            let need = s.t_base - at_ckpt_start;
            match phase_end(now, c_ckpt, need, omega, next_fail.at) {
                PhaseEnd::Finished(dt) => {
                    res.time_checkpoint += dt;
                    if self.drifting {
                        res.energy += ckpt_rate * dt;
                    }
                    now += dt;
                    break;
                }
                PhaseEnd::Failed(dt) => {
                    res.time_checkpoint += dt;
                    if self.drifting {
                        res.energy += ckpt_rate * dt;
                    }
                    now += dt;
                    ctl.observe_uptime(dt);
                    let tier_rec = if let (Some(h), Some(st)) =
                        (self.tiered.as_ref(), store.as_mut())
                    {
                        Some(tiered_node_loss(
                            h,
                            st,
                            &mut inflight,
                            &mut drain_free_at,
                            &mut drain_energy,
                            now,
                            at_ckpt_start + omega * dt,
                            &mut saved,
                            &mut overlap,
                            &mut res.work_lost,
                            &mut pinned,
                        ))
                    } else {
                        res.work_lost += overlap + compute_len + omega * dt;
                        overlap = 0.0;
                        None
                    };
                    self.fail_and_recover(
                        &mut ctl,
                        &mut res,
                        &mut now,
                        &mut next_fail,
                        &mut stream,
                        seed,
                        tier_rec,
                        &mut rec_io_energy,
                    );
                    self.reread_period(&mut ctl, &mut res, &mut period, now, seed);
                    continue;
                }
                PhaseEnd::Ran => {
                    res.time_checkpoint += c_ckpt;
                    if self.drifting {
                        res.energy += ckpt_rate * c_ckpt;
                    }
                    now += c_ckpt;
                    ctl.observe_uptime(c_ckpt);
                    res.n_checkpoints += 1;
                    saved = at_ckpt_start;
                    overlap = omega * c_ckpt;
                    // The "measured" write duration is the true C(t).
                    ctl.observe_checkpoint(c_ckpt);
                    if trace::enabled() {
                        trace::emit(&trace::event(
                            "observe",
                            seed,
                            now,
                            vec![
                                ("c_est", Json::Num(ctl.c_estimate())),
                                ("r_est", Json::Num(ctl.r_estimate())),
                                ("mu_est", Json::Num(ctl.mu_estimate())),
                                ("oracle", Json::Bool(self.cfg.oracle)),
                            ],
                        ));
                    }
                    // Tiered: land completed drains, record the tier-0
                    // copy, and schedule the κ-aligned drains against
                    // the period currently in force (mirrors the
                    // engine's fixed-period loop).
                    if let (Some(h), Some(st)) = (self.tiered.as_ref(), store.as_mut()) {
                        settle_drains_with(
                            &mut inflight,
                            st,
                            &mut drain_energy,
                            h,
                            now,
                            false,
                            &mut pinned,
                        );
                        pinned.clear();
                        pinned.extend(inflight.iter().map(|dr| dr.work));
                        st.record(
                            0,
                            CopyRecord { work: at_ckpt_start, available_at: now },
                            &pinned,
                        );
                        if kappa_period != period {
                            kappa = crate::model::tiers::cadence_for(s, h, period);
                            kappa_period = period;
                        }
                        let idx = res.n_checkpoints;
                        let mut source_ready = now;
                        for tier in 1..h.len() {
                            if idx % kappa[tier] as u64 != 0 {
                                break;
                            }
                            let start = drain_free_at.max(source_ready);
                            let end = start + h.tier(tier).c;
                            drain_free_at = end;
                            source_ready = end;
                            inflight.push(Drain { tier, work: at_ckpt_start, start, end });
                        }
                    }
                    self.reread_period(&mut ctl, &mut res, &mut period, now, seed);
                }
            }
        }

        // End of run: completed drains land, in-flight ones abort with
        // pro-rated energy (no-op on the scalar path).
        if let (Some(h), Some(st)) = (self.tiered.as_ref(), store.as_mut()) {
            settle_drains_with(&mut inflight, st, &mut drain_energy, h, now, true, &mut pinned);
        }

        res.makespan = now;
        res.final_period = period;
        if res.tracking_samples > 0 {
            res.tracking_lag_pct /= res.tracking_samples as f64;
            res.drift_lag_pct /= res.tracking_samples as f64;
        }
        if self.tiered.is_some() {
            // Tiered (always stationary — the constructor rejects the
            // combination): tier-0 writes at the effective P_IO,
            // recovery reads priced per surviving tier, drains per
            // target tier (mirrors the engine's tiered integral).
            let p = &s.power;
            res.energy = p.p_static * res.makespan
                + p.p_cal * (res.time_compute + omega * res.time_checkpoint)
                + p.p_io * res.time_checkpoint
                + rec_io_energy
                + p.p_down * res.time_down
                + drain_energy;
        } else if !self.drifting {
            // Stationary: the original end-of-run integral, evaluated in
            // the original association order (bit-identical to the
            // pre-drift code; the incremental sums above would not be).
            let p = &s.power;
            res.energy = p.p_static * res.makespan
                + p.p_cal * (res.time_compute + omega * res.time_checkpoint)
                + p.p_io * (res.time_checkpoint + res.time_recovery)
                + p.p_down * res.time_down;
        }
        res
    }

    /// The policy's period on the true instantaneous scenario at `now`
    /// (clamped to that scenario's feasible range) — the moving target
    /// the tracking metrics measure against and the oracle applies.
    pub(crate) fn instantaneous_target(&self, now: f64) -> Option<f64> {
        let s_now = if self.drifting { self.traj.scenario_at(now) } else { self.cfg.scenario };
        let p = self.cfg.policy.period(&s_now).ok()?;
        s_now.clamp_period(p).ok()
    }

    /// The period the controller would compute with exact C/R: its own
    /// scenario view (base powers, its μ estimate) with the true
    /// `C(t)`/`R(t)` substituted — the μ-noise-cancelled reference
    /// behind [`AdaptiveRunResult::drift_lag_pct`].
    fn estimator_target(&self, ctl: &AdaptiveController, now: f64) -> Option<f64> {
        let s = &self.cfg.scenario;
        let s_now = if self.drifting { self.traj.scenario_at(now) } else { *s };
        let ckpt = crate::model::params::CheckpointParams::new(
            s_now.ckpt.c,
            s_now.ckpt.r,
            s.ckpt.d,
            s.ckpt.omega,
        )
        .ok()?;
        let view = Scenario::new(ckpt, s.power, ctl.mu_estimate(), s.t_base).ok()?;
        let p = self.cfg.policy.period(&view).ok()?;
        view.clamp_period(p).ok()
    }

    /// Re-read the period in force at a decision point (after a
    /// completed checkpoint or a recovery): from the controller —
    /// clamped to the *instantaneous* scenario's feasible range — or,
    /// in oracle mode, from the true instantaneous policy period. Also
    /// samples the tracking-lag metric against the instantaneous
    /// target.
    pub(crate) fn reread_period(
        &self,
        ctl: &mut AdaptiveController,
        res: &mut AdaptiveRunResult,
        period: &mut f64,
        now: f64,
        seed: u64,
    ) {
        let target = self.instantaneous_target(now);
        // The controller's raw (pre-clamp) answer, kept for the trace's
        // hysteresis-suppression diagnosis; `None` in oracle mode.
        let mut ctl_raw: Option<f64> = None;
        let fresh = if self.cfg.oracle {
            target.unwrap_or(*period)
        } else {
            let clamp_to =
                if self.drifting { self.traj.scenario_at(now) } else { self.cfg.scenario };
            ctl_raw = ctl.period();
            match ctl_raw {
                Some(p) => clamp_to.clamp_period(p).unwrap_or(*period),
                None => *period,
            }
        };
        let before = *period;
        let changed = fresh != *period;
        if changed {
            res.n_period_updates += 1;
            *period = fresh;
        }
        if trace::enabled() {
            // Suppressed: the controller's latest pre-hysteresis
            // recompute differs from the period it keeps in force —
            // the band is holding a move back.
            let suppressed =
                matches!((ctl_raw, ctl.fresh_period()), (Some(p), Some(f)) if f != p);
            let mut fields = vec![
                ("current", Json::Num(before)),
                ("fresh", Json::Num(fresh)),
                ("changed", Json::Bool(changed)),
                ("suppressed", Json::Bool(suppressed)),
                ("oracle", Json::Bool(self.cfg.oracle)),
            ];
            if let Some(t_star) = target {
                fields.push(("target", Json::Num(t_star)));
            }
            trace::emit(&trace::event("period", seed, now, fields));
        }
        if let Some(t_star) = target {
            res.tracking_lag_pct += ((*period - t_star) / t_star).abs() * 100.0;
            res.tracking_samples += 1;
            if !self.cfg.oracle {
                // An out-of-domain estimator view (collapsing μ
                // estimate) contributes zero gap rather than dropping
                // the sample.
                if let Some(t_est) = self.estimator_target(ctl, now) {
                    res.drift_lag_pct += ((*period - t_est) / t_est).abs() * 100.0;
                }
            }
        }
    }

    /// Downtime + recovery after a failure, mirroring the engine, with
    /// the controller observing every failure, the exposure time, and
    /// the restore duration. Under drift the recovery cost and the I/O
    /// draw are the trajectory's values at the recovery's start; on the
    /// tiered path `tier_rec` carries the surviving tier's `(R_j,
    /// P_IO_j)` (already resolved by [`tiered_node_loss`]) and the read
    /// energy accrues into `rec_io_energy` instead of the end-of-run
    /// blanket `P_IO` term. Generic over the failure source so the
    /// scalar reference loop and the batched executor monomorphise to
    /// the same body.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn fail_and_recover<S: FailureSource>(
        &self,
        ctl: &mut AdaptiveController,
        res: &mut AdaptiveRunResult,
        now: &mut f64,
        next_fail: &mut Failure,
        stream: &mut S,
        seed: u64,
        tier_rec: Option<(f64, f64)>,
        rec_io_energy: &mut f64,
    ) {
        let s = &self.cfg.scenario;
        let (d, r_base) = (s.ckpt.d, s.ckpt.r);
        let pw = s.power;
        res.n_failures += 1;
        ctl.observe_failure();
        if trace::enabled() {
            trace::emit(&trace::event(
                "failure",
                seed,
                *now,
                vec![
                    ("mu_est", Json::Num(ctl.mu_estimate())),
                    ("oracle", Json::Bool(self.cfg.oracle)),
                ],
            ));
        }
        *next_fail = stream.next_after(*now);
        loop {
            let d_end = *now + d;
            let (r_now, p_io_rec) = if let Some(t) = tier_rec {
                t
            } else if self.drifting {
                let s_rec = self.traj.scenario_at(d_end);
                (s_rec.ckpt.r, s_rec.power.p_io)
            } else {
                (r_base, pw.p_io)
            };
            let r_end = d_end + r_now;
            if self.cfg.failures_during_recovery && next_fail.at < r_end {
                // Failure mid-downtime or mid-recovery: account the
                // partial phases, then restart D + R.
                let fail_at = next_fail.at;
                if fail_at < d_end {
                    res.time_down += fail_at - *now;
                    if self.drifting {
                        res.energy += (pw.p_static + pw.p_down) * (fail_at - *now);
                    }
                } else {
                    res.time_down += d;
                    res.time_recovery += fail_at - d_end;
                    if tier_rec.is_some() {
                        *rec_io_energy += p_io_rec * (fail_at - d_end);
                    }
                    if self.drifting {
                        res.energy += (pw.p_static + pw.p_down) * d
                            + (pw.p_static + p_io_rec) * (fail_at - d_end);
                    }
                }
                ctl.observe_uptime(fail_at - *now);
                *now = fail_at;
                res.n_failures += 1;
                ctl.observe_failure();
                if trace::enabled() {
                    trace::emit(&trace::event(
                        "failure",
                        seed,
                        *now,
                        vec![
                            ("mu_est", Json::Num(ctl.mu_estimate())),
                            ("oracle", Json::Bool(self.cfg.oracle)),
                        ],
                    ));
                }
                *next_fail = stream.next_after(*now);
                continue;
            }
            res.time_down += d;
            res.time_recovery += r_now;
            if tier_rec.is_some() {
                *rec_io_energy += p_io_rec * r_now;
            }
            if self.drifting {
                res.energy += (pw.p_static + pw.p_down) * d + (pw.p_static + p_io_rec) * r_now;
            }
            if self.cfg.failures_during_recovery {
                // D + R is failure exposure only when failures can
                // actually strike there; with the clock suspended it
                // must not inflate the μ estimate.
                ctl.observe_uptime(r_end - *now);
            }
            *now = r_end;
            // Mirror the engine: a suspended failure process cannot fire
            // retroactively out of the D + R window.
            if !self.cfg.failures_during_recovery && next_fail.at < *now {
                *next_fail = stream.next_after(*now);
            }
            // The "measured" restore duration is the true R(t). A
            // tiered restart-from-scratch performs no read at all —
            // there is nothing to measure, so the estimator is left
            // alone rather than dragged toward zero.
            if tier_rec.is_none() || r_now > 0.0 {
                ctl.observe_restore(r_now);
            }
            if trace::enabled() {
                trace::emit(&trace::event(
                    "recovery",
                    seed,
                    *now,
                    vec![
                        ("r", Json::Num(r_now)),
                        ("c_est", Json::Num(ctl.c_estimate())),
                        ("r_est", Json::Num(ctl.r_estimate())),
                        ("mu_est", Json::Num(ctl.mu_estimate())),
                        ("oracle", Json::Bool(self.cfg.oracle)),
                    ],
                ));
            }
            return;
        }
    }
}

/// Node loss on the tiered path: abort in-flight drains (pro-rated
/// energy), purge the node-local tier, and restart from the freshest
/// surviving copy. Returns the recovery read `(R_j, P_IO_j)` of the
/// surviving tier — `(0, 0)` when nothing survives and the run restarts
/// from scratch with no read. Mirrors the engine's `tiered_failure`
/// bookkeeping. `pinned` is caller-owned pin-set scratch (see
/// [`settle_drains_with`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn tiered_node_loss(
    h: &TierHierarchy,
    store: &mut TierStore,
    inflight: &mut Vec<Drain>,
    drain_free_at: &mut f64,
    drain_energy: &mut f64,
    now: f64,
    progress_at_fail: f64,
    saved: &mut f64,
    overlap: &mut f64,
    work_lost: &mut f64,
    pinned: &mut Vec<f64>,
) -> (f64, f64) {
    settle_drains_with(inflight, store, drain_energy, h, now, true, pinned);
    *drain_free_at = now;
    store.purge_node_local();
    let (r, p_io, restart) = match store.freshest_surviving(now) {
        Some((tier, copy)) => (h.tier(tier).r, h.tier(tier).p_io, copy.work),
        None => (0.0, 0.0, 0.0),
    };
    *work_lost += progress_at_fail - restart;
    *saved = restart;
    *overlap = 0.0;
    (r, p_io)
}

/// Aggregated Monte-Carlo estimates of adaptive runs.
#[derive(Debug, Clone)]
pub struct AdaptiveMonteCarloResult {
    pub replicates: usize,
    pub makespan: OnlineStats,
    pub energy: OnlineStats,
    pub failures: OnlineStats,
    pub checkpoints: OnlineStats,
    pub work_lost: OnlineStats,
    pub period_updates: OnlineStats,
    pub final_period: OnlineStats,
    /// Per-run mean tracking lag (see
    /// [`AdaptiveRunResult::tracking_lag_pct`]).
    pub tracking_lag: OnlineStats,
    /// Per-run mean μ-noise-cancelled drift lag (see
    /// [`AdaptiveRunResult::drift_lag_pct`]).
    pub drift_lag: OnlineStats,
}

/// Fold per-replicate results into the Monte-Carlo aggregate, in
/// replicate-index order (the order is part of the thread-count
/// determinism contract — `OnlineStats` sums are order-sensitive).
fn collect_stats(replicates: usize, results: &[AdaptiveRunResult]) -> AdaptiveMonteCarloResult {
    let mut mc = AdaptiveMonteCarloResult {
        replicates,
        makespan: OnlineStats::new(),
        energy: OnlineStats::new(),
        failures: OnlineStats::new(),
        checkpoints: OnlineStats::new(),
        work_lost: OnlineStats::new(),
        period_updates: OnlineStats::new(),
        final_period: OnlineStats::new(),
        tracking_lag: OnlineStats::new(),
        drift_lag: OnlineStats::new(),
    };
    for r in results {
        mc.makespan.push(r.makespan);
        mc.energy.push(r.energy);
        mc.failures.push(r.n_failures as f64);
        mc.checkpoints.push(r.n_checkpoints as f64);
        mc.work_lost.push(r.work_lost);
        mc.period_updates.push(r.n_period_updates as f64);
        mc.final_period.push(r.final_period);
        mc.tracking_lag.push(r.tracking_lag_pct);
        mc.drift_lag.push(r.drift_lag_pct);
    }
    mc
}

/// Run `replicates` independent adaptive sample paths. Replicate `i`
/// simulates seed `base_seed + i`; results are byte-identical for every
/// `threads` value (same contract as [`super::runner::monte_carlo`]).
///
/// Dispatches to the batched lockstep executor ([`super::batch`]) —
/// bit-identical to the per-replica loop by construction, pinned by
/// `tests/batch_sim.rs` against [`adaptive_monte_carlo_reference`].
pub fn adaptive_monte_carlo(
    cfg: &AdaptiveSimConfig,
    replicates: usize,
    base_seed: u64,
    threads: usize,
) -> AdaptiveMonteCarloResult {
    let sim = AdaptiveSimulator::new(cfg.clone());
    adaptive_monte_carlo_with(&sim, replicates, base_seed, threads)
}

/// [`adaptive_monte_carlo`] on an already-constructed simulator: skips
/// re-validating the drift trajectory, so paired runs (an estimating
/// run and its [`AdaptiveSimulator::oracle_twin`]) share one build.
pub fn adaptive_monte_carlo_with(
    sim: &AdaptiveSimulator,
    replicates: usize,
    base_seed: u64,
    threads: usize,
) -> AdaptiveMonteCarloResult {
    assert!(replicates > 0);
    let results = super::batch::run_adaptive_batched(sim, replicates, base_seed, threads);
    collect_stats(replicates, &results)
}

/// The pre-batching per-replica driver, kept verbatim as the
/// bit-identity reference for the lockstep executor (the PR 9
/// `compute_reference` pattern). Not part of the public surface.
#[doc(hidden)]
pub fn adaptive_monte_carlo_reference(
    cfg: &AdaptiveSimConfig,
    replicates: usize,
    base_seed: u64,
    threads: usize,
) -> AdaptiveMonteCarloResult {
    assert!(replicates > 0);
    let threads = threads.clamp(1, replicates);
    let sim = AdaptiveSimulator::new(cfg.clone());
    let results: Vec<AdaptiveRunResult> = if threads == 1 || ThreadPool::in_worker() {
        (0..replicates).map(|i| sim.run(base_seed + i as u64)).collect()
    } else {
        ThreadPool::global().map(replicates, |i| sim.run(base_seed + i as u64))
    };
    collect_stats(replicates, &results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fig1_scenario;
    use crate::drift::DriftTargets;
    use crate::model::energy::t_energy_opt;
    use crate::model::time::t_time_opt;
    use crate::pareto::KneeMethod;
    use crate::sim::engine::{SimConfig, Simulator};
    use crate::util::stats::rel_err;

    #[test]
    fn deterministic_per_seed() {
        let s = fig1_scenario(300.0, 5.5);
        let sim = AdaptiveSimulator::new(AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT));
        let a = sim.run(42);
        let b = sim.run(42);
        assert_eq!(a, b);
        assert_ne!(a, sim.run(43));
    }

    #[test]
    fn correct_prior_tracks_the_static_policy() {
        // With the prior equal to the true μ and exact C/R measurements,
        // the adaptive run should land near the fixed-period simulation
        // at the policy's true period.
        let s = fig1_scenario(300.0, 5.5);
        let t = t_time_opt(&s).unwrap();
        let adaptive = adaptive_monte_carlo(
            &AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT),
            120,
            7,
            8,
        );
        let fixed = crate::sim::runner::monte_carlo(&SimConfig::paper(s, t), 120, 7, 8);
        assert!(
            rel_err(adaptive.makespan.mean(), fixed.makespan.mean()) < 0.03,
            "adaptive {} vs fixed {}",
            adaptive.makespan.mean(),
            fixed.makespan.mean()
        );
        assert!(
            rel_err(adaptive.energy.mean(), fixed.energy.mean()) < 0.03,
            "adaptive {} vs fixed {}",
            adaptive.energy.mean(),
            fixed.energy.mean()
        );
        // And the final period is near the true policy period.
        assert!(
            rel_err(adaptive.final_period.mean(), t) < 0.2,
            "final period {} vs T_Time_opt {t}",
            adaptive.final_period.mean()
        );
    }

    #[test]
    fn wrong_prior_is_estimated_away() {
        // Prior μ 5x too large: the controller must shrink the period
        // toward the true policy period as failures are observed.
        let s = fig1_scenario(300.0, 5.5);
        let mut cfg = AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT);
        cfg.prior_mu = s.mu * 5.0;
        let mc = adaptive_monte_carlo(&cfg, 80, 11, 8);
        let t = t_time_opt(&s).unwrap();
        assert!(
            rel_err(mc.final_period.mean(), t) < 0.25,
            "final period {} vs T_Time_opt {t}",
            mc.final_period.mean()
        );
        assert!(mc.period_updates.mean() >= 1.0, "period never adapted");
    }

    #[test]
    fn suspended_recovery_time_is_not_failure_exposure() {
        // μ comparable to D + R: counting the suspended D + R window as
        // exposure would inflate the μ estimate by ~(D+R)/μ = 20% and
        // the applied period by ~half that. The final period must track
        // the true policy period instead.
        let ckpt = crate::model::CheckpointParams::new(2.0, 2.0, 1.0, 0.5).unwrap();
        let power = crate::model::PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        let s = Scenario::new(ckpt, power, 15.0, 2000.0).unwrap();
        let mut cfg = AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT);
        cfg.failures_during_recovery = false;
        let mc = adaptive_monte_carlo(&cfg, 80, 13, 8);
        let t = t_time_opt(&s).unwrap();
        assert!(
            rel_err(mc.final_period.mean(), t) < 0.06,
            "final period {} vs T_Time_opt {t} (phantom D+R exposure would land ~10% high)",
            mc.final_period.mean()
        );
    }

    #[test]
    fn knee_policy_lands_between_the_endpoints() {
        let s = fig1_scenario(300.0, 5.5);
        let reps = 120;
        let seed = 5;
        let mc_of = |policy| {
            adaptive_monte_carlo(&AdaptiveSimConfig::paper(s, policy), reps, seed, 8)
        };
        let t = mc_of(PeriodPolicy::AlgoT);
        let e = mc_of(PeriodPolicy::AlgoE);
        let k = mc_of(PeriodPolicy::Knee {
            method: KneeMethod::MaxDistanceToChord,
            backend: crate::model::Backend::FirstOrder,
        });
        assert!(
            k.makespan.mean() < e.makespan.mean(),
            "knee makespan {} !< AlgoE {}",
            k.makespan.mean(),
            e.makespan.mean()
        );
        assert!(
            k.energy.mean() < t.energy.mean(),
            "knee energy {} !< AlgoT {}",
            k.energy.mean(),
            t.energy.mean()
        );
        // The knee's final period sits inside the optimal-period range.
        let tt = t_time_opt(&s).unwrap();
        let te = t_energy_opt(&s).unwrap();
        let kp = k.final_period.mean();
        assert!(kp > tt && kp < te, "knee period {kp} outside ({tt}, {te})");
    }

    #[test]
    fn energy_identity_holds_per_path() {
        let s = fig1_scenario(120.0, 7.0);
        let sim = AdaptiveSimulator::new(AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoE));
        for seed in 0..10 {
            let res = sim.run(seed);
            let p = &s.power;
            let manual = p.p_static * res.makespan
                + p.p_cal * (res.time_compute + s.ckpt.omega * res.time_checkpoint)
                + p.p_io * (res.time_checkpoint + res.time_recovery)
                + p.p_down * res.time_down;
            assert!(rel_err(res.energy, manual) < 1e-12, "seed={seed}");
            let total =
                res.time_compute + res.time_checkpoint + res.time_recovery + res.time_down;
            assert!(rel_err(res.makespan, total) < 1e-12, "seed={seed}");
        }
    }

    #[test]
    fn thread_count_does_not_change_estimates() {
        let s = fig1_scenario(300.0, 5.5);
        let cfg = AdaptiveSimConfig::paper(
            s,
            PeriodPolicy::Knee {
                method: KneeMethod::MaxDistanceToChord,
                backend: crate::model::Backend::FirstOrder,
            },
        );
        let a = adaptive_monte_carlo(&cfg, 48, 7, 1);
        let b = adaptive_monte_carlo(&cfg, 48, 7, 8);
        assert_eq!(a.makespan.mean().to_bits(), b.makespan.mean().to_bits());
        assert_eq!(a.energy.mean().to_bits(), b.energy.mean().to_bits());
        assert_eq!(a.final_period.mean().to_bits(), b.final_period.mean().to_bits());
    }

    #[test]
    fn failure_free_run_stretches_the_period() {
        // With no failures the exposure estimator's μ grows with the
        // observed uptime, so the controller checkpoints progressively
        // less often — and beats the fixed T_Time_opt schedule, which
        // keeps paying checkpoint overhead for failures that never come.
        let s = fig1_scenario(300.0, 5.5);
        let mut cfg = AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT);
        cfg.failure = FailureProcess::Exponential { mtbf: 1e18 };
        let sim = AdaptiveSimulator::new(cfg);
        let res = sim.run(1);
        assert_eq!(res.n_failures, 0);
        let t = t_time_opt(&s).unwrap();
        assert!(res.n_period_updates > 0, "period never adapted to the quiet platform");
        assert!(res.final_period > t, "final {} !> initial {t}", res.final_period);
        let fixed = Simulator::new(SimConfig {
            scenario: s,
            period: t,
            failure: FailureProcess::Exponential { mtbf: 1e18 },
            failures_during_recovery: true,
        })
        .run(1);
        assert!(res.makespan >= s.t_base);
        assert!(
            res.makespan < fixed.makespan,
            "adaptive {} !< fixed {} on a failure-free platform",
            res.makespan,
            fixed.makespan
        );
    }

    // ---- drift ----------------------------------------------------------

    const KNEE: PeriodPolicy = PeriodPolicy::Knee {
        method: KneeMethod::MaxDistanceToChord,
        backend: crate::model::Backend::FirstOrder,
    };

    fn io_ramp() -> DriftProcess {
        DriftProcess::Ramp {
            from_t: 0.0,
            to_t: 5000.0,
            to: DriftTargets { c: 2.0, r: 2.0, mu: 1.0, p_io: 2.0 },
        }
    }

    #[test]
    fn stationary_drift_config_is_bit_identical_to_paper() {
        // The zero-regression contract at the config level: an explicit
        // Stationary drift (or an identity-target shape) routes onto
        // the exact static code path.
        let s = fig1_scenario(300.0, 5.5);
        let base = AdaptiveSimulator::new(AdaptiveSimConfig::paper(s, KNEE));
        let via_drifting = AdaptiveSimulator::new(
            AdaptiveSimConfig::paper_drifting(s, KNEE, DriftProcess::Stationary).unwrap(),
        );
        let identity_ramp = AdaptiveSimulator::new(
            AdaptiveSimConfig::paper_drifting(
                s,
                KNEE,
                DriftProcess::Ramp { from_t: 0.0, to_t: 100.0, to: DriftTargets::ONE },
            )
            .unwrap(),
        );
        for seed in [1u64, 42, 2013] {
            let want = base.run(seed);
            assert_eq!(via_drifting.run(seed), want, "seed={seed}");
            assert_eq!(identity_ramp.run(seed), want, "seed={seed}");
        }
    }

    #[test]
    fn drifting_c_grows_the_applied_period() {
        // C ramps 10 → 20: the knee period scales ~sqrt(C), so the
        // final period must exceed the stationary one, and the measured
        // checkpoint time per checkpoint must reflect the stretch.
        let s = fig1_scenario(300.0, 5.5);
        let stationary = adaptive_monte_carlo(&AdaptiveSimConfig::paper(s, KNEE), 40, 3, 8);
        let cfg = AdaptiveSimConfig::paper_drifting(s, KNEE, io_ramp()).unwrap();
        let drifted = adaptive_monte_carlo(&cfg, 40, 3, 8);
        assert!(
            drifted.final_period.mean() > 1.2 * stationary.final_period.mean(),
            "drifted {} !> stationary {}",
            drifted.final_period.mean(),
            stationary.final_period.mean()
        );
        // Makespan and energy both pay for the contention.
        assert!(drifted.makespan.mean() > stationary.makespan.mean());
        assert!(drifted.energy.mean() > stationary.energy.mean());
    }

    #[test]
    fn drift_energy_integral_matches_phase_decomposition() {
        // Under a C/R-only drift (P_IO untouched) the incremental
        // energy integral must agree with the aggregate formula over
        // the recorded phase times (association differences only).
        let s = fig1_scenario(300.0, 5.5);
        let drift = DriftProcess::Ramp {
            from_t: 0.0,
            to_t: 5000.0,
            to: DriftTargets { c: 2.0, r: 2.0, mu: 1.0, p_io: 1.0 },
        };
        let cfg = AdaptiveSimConfig::paper_drifting(s, KNEE, drift).unwrap();
        let sim = AdaptiveSimulator::new(cfg);
        for seed in 0..8 {
            let res = sim.run(seed);
            let p = &s.power;
            let manual = p.p_static * res.makespan
                + p.p_cal * (res.time_compute + s.ckpt.omega * res.time_checkpoint)
                + p.p_io * (res.time_checkpoint + res.time_recovery)
                + p.p_down * res.time_down;
            assert!(
                rel_err(res.energy, manual) < 1e-9,
                "seed={seed}: {} vs {manual}",
                res.energy
            );
            let total =
                res.time_compute + res.time_checkpoint + res.time_recovery + res.time_down;
            assert!(rel_err(res.makespan, total) < 1e-9, "seed={seed}");
        }
    }

    #[test]
    fn mu_decay_raises_the_failure_count() {
        // μ ramps 300 → 120 over the run: more failures than the
        // stationary platform, and the controller shortens the period
        // relative to its own start (the target knee shrinks ~sqrt μ).
        let s = fig1_scenario(300.0, 5.5);
        let drift = DriftProcess::Ramp {
            from_t: 0.0,
            to_t: 5000.0,
            to: DriftTargets { c: 1.0, r: 1.0, mu: 0.4, p_io: 1.0 },
        };
        let cfg = AdaptiveSimConfig::paper_drifting(s, KNEE, drift).unwrap();
        let drifted = adaptive_monte_carlo(&cfg, 40, 9, 8);
        let stationary = adaptive_monte_carlo(&AdaptiveSimConfig::paper(s, KNEE), 40, 9, 8);
        assert!(
            drifted.failures.mean() > 1.5 * stationary.failures.mean(),
            "decaying μ must fail more: {} vs {}",
            drifted.failures.mean(),
            stationary.failures.mean()
        );
        assert!(drifted.final_period.mean() < stationary.final_period.mean());
    }

    #[test]
    fn oracle_tracks_tighter_than_the_controller_under_drift() {
        // The clairvoyant oracle reads the true instantaneous policy
        // period: its tracking lag collapses to (numerically) zero and
        // its waste is no worse than the estimating controller's, on
        // the same seeds.
        let s = fig1_scenario(300.0, 5.5);
        let cfg = AdaptiveSimConfig::paper_drifting(s, KNEE, io_ramp()).unwrap();
        let mut oracle_cfg = cfg.clone();
        oracle_cfg.oracle = true;
        let reps = 48;
        let adaptive = adaptive_monte_carlo(&cfg, reps, 17, 8);
        let oracle = adaptive_monte_carlo(&oracle_cfg, reps, 17, 8);
        assert!(
            oracle.tracking_lag.mean() < 1e-9,
            "oracle lag {} != 0",
            oracle.tracking_lag.mean()
        );
        assert!(
            adaptive.tracking_lag.mean() > 0.5,
            "controller lag {} suspiciously small under drift",
            adaptive.tracking_lag.mean()
        );
        // Near the knee the frontier objectives are flat to first
        // order, so single-axis regret is small (and can carry either
        // sign: a low-lagging period trades time against energy). The
        // paired runs must stay within a tight band of each other.
        let waste_gap =
            (adaptive.makespan.mean() - oracle.makespan.mean()) / s.t_base * 100.0;
        assert!(waste_gap.abs() < 2.0, "waste regret {waste_gap}% out of band");
    }

    #[test]
    fn drift_lag_shrinks_with_a_snappier_ewma() {
        // Higher α tracks the ramped C faster; with the hysteresis band
        // off and common random numbers (same seeds, μ-stationary drift
        // ⇒ identical failure draws) the μ-noise-cancelled drift lag
        // must decrease. (The *raw* tracking lag vs the true knee is
        // dominated by the exposure estimator's sampling noise, which
        // is α-independent — the drift figure documents the split.)
        let s = fig1_scenario(300.0, 5.5);
        let lag_at = |alpha: f64| {
            let mut cfg = AdaptiveSimConfig::paper_drifting(s, KNEE, io_ramp()).unwrap();
            cfg.alpha = alpha;
            cfg.hysteresis = 0.0;
            adaptive_monte_carlo(&cfg, 24, 29, 8).drift_lag.mean()
        };
        let slow = lag_at(0.05);
        let mid = lag_at(0.3);
        let fast = lag_at(0.9);
        assert!(slow > mid && mid > fast, "drift lag not monotone: {slow} {mid} {fast}");
        assert!(slow > 1.5 * fast, "α barely matters: {slow} vs {fast}");
    }

    #[test]
    fn mu_only_drift_has_zero_drift_lag() {
        // μ-only drift: C/R are stationary, the EWMA tracks them
        // exactly, so the noise-cancelled drift lag collapses to the
        // hysteresis floor (0 with the band off) while the raw lag
        // stays large (the exposure estimator trails the decay).
        let s = fig1_scenario(300.0, 5.5);
        let drift = DriftProcess::Ramp {
            from_t: 0.0,
            to_t: 10_000.0,
            to: DriftTargets { c: 1.0, r: 1.0, mu: 0.4, p_io: 1.0 },
        };
        let mut cfg = AdaptiveSimConfig::paper_drifting(s, KNEE, drift).unwrap();
        cfg.hysteresis = 0.0;
        let mc = adaptive_monte_carlo(&cfg, 24, 31, 8);
        assert!(
            mc.drift_lag.mean() < 1e-9,
            "μ-only drift lag {} != 0 with the band off",
            mc.drift_lag.mean()
        );
        assert!(
            mc.tracking_lag.mean() > 5.0,
            "raw lag {} should stay large under μ decay",
            mc.tracking_lag.mean()
        );
    }

    #[test]
    fn drift_runs_are_deterministic_and_thread_invariant() {
        let s = fig1_scenario(300.0, 5.5);
        let cfg = AdaptiveSimConfig::paper_drifting(s, KNEE, io_ramp()).unwrap();
        let sim = AdaptiveSimulator::new(cfg.clone());
        assert_eq!(sim.run(7), sim.run(7));
        let a = adaptive_monte_carlo(&cfg, 32, 7, 1);
        let b = adaptive_monte_carlo(&cfg, 32, 7, 8);
        assert_eq!(a.makespan.mean().to_bits(), b.makespan.mean().to_bits());
        assert_eq!(a.energy.mean().to_bits(), b.energy.mean().to_bits());
        assert_eq!(a.tracking_lag.mean().to_bits(), b.tracking_lag.mean().to_bits());
    }

    #[test]
    #[should_panic(expected = "domain")]
    fn domain_breaking_drift_panics_at_construction() {
        let s = fig1_scenario(300.0, 5.5);
        let mut cfg = AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT);
        cfg.drift = DriftProcess::Step {
            at: 100.0,
            to: DriftTargets { c: 1.0, r: 1.0, mu: 0.04, p_io: 1.0 },
        };
        let _ = AdaptiveSimulator::new(cfg);
    }

    // ---- tiered storage --------------------------------------------------

    fn tiered_scenario() -> Scenario {
        let ckpt = crate::model::CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = crate::model::PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        Scenario::with_tier_specs(
            ckpt,
            power,
            300.0,
            10_000.0,
            &[
                crate::storage::TierSpec::new(1.0, 1.0, 0.3),
                crate::storage::TierSpec::new(10.0, 10.0, 1.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn tiered_adaptive_is_deterministic_and_thread_invariant() {
        let cfg = AdaptiveSimConfig::paper(tiered_scenario(), KNEE);
        let sim = AdaptiveSimulator::new(cfg.clone());
        assert_eq!(sim.run(7), sim.run(7));
        let a = adaptive_monte_carlo(&cfg, 32, 7, 1);
        let b = adaptive_monte_carlo(&cfg, 32, 7, 8);
        assert_eq!(a.makespan.mean().to_bits(), b.makespan.mean().to_bits());
        assert_eq!(a.energy.mean().to_bits(), b.energy.mean().to_bits());
        assert_eq!(a.final_period.mean().to_bits(), b.final_period.mean().to_bits());
    }

    #[test]
    fn tiered_adaptive_pays_drain_energy() {
        // Same effective scalars, same seeds: the tiered run's energy
        // must exceed the scalar run's by the drain traffic (the
        // effective projection has identical C/R/P_IO on tier 0).
        let tiered = tiered_scenario();
        let flat = tiered.scalar_effective();
        let mc_t = adaptive_monte_carlo(&AdaptiveSimConfig::paper(tiered, KNEE), 24, 5, 8);
        let mc_f = adaptive_monte_carlo(&AdaptiveSimConfig::paper(flat, KNEE), 24, 5, 8);
        assert!(
            mc_t.energy.mean() > mc_f.energy.mean(),
            "tiered {} !> flat {}",
            mc_t.energy.mean(),
            mc_f.energy.mean()
        );
    }

    #[test]
    #[should_panic(expected = "stationary")]
    fn tiered_plus_drift_is_rejected() {
        let mut cfg = AdaptiveSimConfig::paper(tiered_scenario(), KNEE);
        cfg.drift = io_ramp();
        let _ = AdaptiveSimulator::new(cfg);
    }
}
