//! Adaptive-period discrete-event simulation: the online controller in
//! the loop.
//!
//! [`super::engine`] simulates a *fixed* checkpointing period. This
//! module closes the loop the coordinator runs in production: an
//! [`AdaptiveController`] rides along the sample path, re-estimating
//! `C` and `R` from the (simulated) measured durations and `μ` from the
//! exposure estimator, and the period in force is re-read from its
//! [`PeriodPolicy`] after every completed checkpoint and every
//! recovery. With the frontier-aware policies (knee, ε-budgets) this is
//! the end-to-end test bed for "checkpoint at the Pareto knee online":
//! VELOC-style drifting parameters meet the paper's closed forms.
//!
//! Semantics are exactly [`super::engine`]'s (same phase structure,
//! power states, and energy integration); the only addition is the
//! controller. The event loop deliberately mirrors the engine's rather
//! than threading callbacks through its hot path — any change to the
//! engine's phase or recovery semantics MUST be applied to both
//! (`deterministic_per_seed` + the engine's tests guard each side, and
//! `failure_free_run_stretches_the_period` ties the two together).
//! Measured durations equal the scenario's true `C`/`R`
//! (the simulator has no measurement noise), so the estimates converge
//! from the controller's prior toward the truth and the applied period
//! converges — modulo the period-space hysteresis band — to the
//! policy's period on the true scenario.
//!
//! Runs are a pure function of `(config, seed)`: the controller is
//! deterministic (the frontier memo in [`crate::pareto::online`] caches
//! pure values keyed on quantised estimates), so Monte-Carlo estimates
//! are byte-identical for every thread count, exactly like
//! [`super::runner::monte_carlo`].

use super::failure::{Failure, FailureProcess, FailureStream};
use crate::coordinator::adaptive::AdaptiveController;
use crate::coordinator::policy::PeriodPolicy;
use crate::model::params::Scenario;
use crate::model::time::young;
use crate::util::pool::ThreadPool;
use crate::util::rng::Pcg64;
use crate::util::stats::OnlineStats;

/// Configuration of an adaptive simulation.
#[derive(Debug, Clone)]
pub struct AdaptiveSimConfig {
    /// Ground truth: the platform the sample paths execute on.
    pub scenario: Scenario,
    /// The policy the controller recomputes the period with.
    pub policy: PeriodPolicy,
    pub failure: FailureProcess,
    /// See [`super::engine::SimConfig::failures_during_recovery`].
    pub failures_during_recovery: bool,
    /// The controller's MTBF prior. The leader seeds it with the
    /// configured μ; pass something else to model a mis-calibrated
    /// prior the controller has to estimate its way out of.
    pub prior_mu: f64,
    /// Period-space hysteresis band handed to the controller.
    pub hysteresis: f64,
}

impl AdaptiveSimConfig {
    /// The paper's aggregate-exponential failure process, a correct
    /// prior, and the controller's default hysteresis.
    pub fn paper(scenario: Scenario, policy: PeriodPolicy) -> Self {
        AdaptiveSimConfig {
            scenario,
            policy,
            failure: FailureProcess::Exponential { mtbf: scenario.mu },
            failures_during_recovery: true,
            prior_mu: scenario.mu,
            hysteresis: 0.05,
        }
    }
}

/// Outcome of one adaptive sample path. The phase/energy fields mirror
/// [`super::engine::RunResult`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveRunResult {
    pub makespan: f64,
    pub energy: f64,
    pub n_failures: u64,
    pub n_checkpoints: u64,
    pub work_lost: f64,
    pub time_compute: f64,
    pub time_checkpoint: f64,
    pub time_recovery: f64,
    pub time_down: f64,
    /// How many times the applied period actually changed (hysteresis
    /// band crossings; the initial period does not count).
    pub n_period_updates: u64,
    /// The period in force when the run finished.
    pub final_period: f64,
}

/// What ended a phase (mirrors the engine).
enum PhaseEnd {
    Ran,
    Finished(f64),
    Failed(f64),
}

/// Phase outcome for a phase of `len` wall time during which `need`
/// work remains and work accrues at `rate`.
fn phase_end(now: f64, len: f64, need: f64, rate: f64, fail_at: f64) -> PhaseEnd {
    let finish = if rate > 0.0 && need / rate <= len { Some(need / rate) } else { None };
    let fail = if fail_at < now + len { Some(fail_at - now) } else { None };
    match (finish, fail) {
        (Some(f), Some(x)) if f <= x => PhaseEnd::Finished(f),
        (_, Some(x)) => PhaseEnd::Failed(x),
        (Some(f), None) => PhaseEnd::Finished(f),
        (None, None) => PhaseEnd::Ran,
    }
}

/// The adaptive simulator. Construct once, run many seeds.
#[derive(Debug, Clone)]
pub struct AdaptiveSimulator {
    cfg: AdaptiveSimConfig,
}

impl AdaptiveSimulator {
    pub fn new(cfg: AdaptiveSimConfig) -> Self {
        assert!(
            cfg.scenario.clamp_period(cfg.scenario.min_period()).is_ok(),
            "scenario has no feasible period"
        );
        AdaptiveSimulator { cfg }
    }

    pub fn config(&self) -> &AdaptiveSimConfig {
        &self.cfg
    }

    /// Execute one sample path.
    pub fn run(&self, seed: u64) -> AdaptiveRunResult {
        let s = &self.cfg.scenario;
        let c = s.ckpt.c;
        let (d, r) = (s.ckpt.d, s.ckpt.r);
        let omega = s.ckpt.omega;

        let mut ctl = AdaptiveController::new(
            self.cfg.policy,
            s.power,
            omega,
            d,
            self.cfg.prior_mu,
            s.t_base,
        )
        .with_hysteresis(self.cfg.hysteresis);
        // Calibration, as the leader does before its run: one measured
        // checkpoint and restore seed the C/R estimators.
        ctl.observe_checkpoint(c);
        ctl.observe_restore(r);

        // When the controller's estimates leave the model's domain the
        // period in force stays what it was; before the first successful
        // recompute that is a clamped Young period (classical, policy-
        // agnostic, always feasible here).
        let fallback = s.clamp_period(young(s)).expect("feasible by construction");
        let mut period = match ctl.period() {
            Some(p) => s.clamp_period(p).unwrap_or(fallback),
            None => fallback,
        };

        let mut rng = Pcg64::seeded(seed);
        let mut stream = self.cfg.failure.stream(&mut rng);

        let mut res = AdaptiveRunResult {
            makespan: 0.0,
            energy: 0.0,
            n_failures: 0,
            n_checkpoints: 0,
            work_lost: 0.0,
            time_compute: 0.0,
            time_checkpoint: 0.0,
            time_recovery: 0.0,
            time_down: 0.0,
            n_period_updates: 0,
            final_period: period,
        };

        let mut now = 0.0f64;
        // Work captured by the last completed checkpoint.
        let mut saved = 0.0f64;
        // Work done during that checkpoint (not yet covered).
        let mut overlap = 0.0f64;
        let mut next_fail = stream.next_after(0.0);

        loop {
            let compute_len = period - c;

            // ---- compute phase (rate 1, power static+cal) ----
            let base_progress = saved + overlap;
            let need = s.t_base - base_progress;
            debug_assert!(need > 0.0);
            match phase_end(now, compute_len, need, 1.0, next_fail.at) {
                PhaseEnd::Finished(dt) => {
                    res.time_compute += dt;
                    now += dt;
                    break;
                }
                PhaseEnd::Failed(dt) => {
                    res.time_compute += dt;
                    now += dt;
                    ctl.observe_uptime(dt);
                    res.work_lost += overlap + dt;
                    overlap = 0.0;
                    self.fail_and_recover(
                        &mut ctl,
                        &mut res,
                        &mut now,
                        &mut next_fail,
                        &mut stream,
                    );
                    self.reread_period(&mut ctl, &mut res, &mut period);
                    continue;
                }
                PhaseEnd::Ran => {
                    res.time_compute += compute_len;
                    now += compute_len;
                    ctl.observe_uptime(compute_len);
                }
            }

            // ---- checkpoint phase (rate ω, power static+ω·cal+io) ----
            let at_ckpt_start = base_progress + compute_len;
            let need = s.t_base - at_ckpt_start;
            match phase_end(now, c, need, omega, next_fail.at) {
                PhaseEnd::Finished(dt) => {
                    res.time_checkpoint += dt;
                    now += dt;
                    break;
                }
                PhaseEnd::Failed(dt) => {
                    res.time_checkpoint += dt;
                    now += dt;
                    ctl.observe_uptime(dt);
                    res.work_lost += overlap + compute_len + omega * dt;
                    overlap = 0.0;
                    self.fail_and_recover(
                        &mut ctl,
                        &mut res,
                        &mut now,
                        &mut next_fail,
                        &mut stream,
                    );
                    self.reread_period(&mut ctl, &mut res, &mut period);
                    continue;
                }
                PhaseEnd::Ran => {
                    res.time_checkpoint += c;
                    now += c;
                    ctl.observe_uptime(c);
                    res.n_checkpoints += 1;
                    saved = at_ckpt_start;
                    overlap = omega * c;
                    // The "measured" write duration is the true C.
                    ctl.observe_checkpoint(c);
                    self.reread_period(&mut ctl, &mut res, &mut period);
                }
            }
        }

        res.makespan = now;
        res.final_period = period;
        let p = &s.power;
        res.energy = p.p_static * res.makespan
            + p.p_cal * (res.time_compute + omega * res.time_checkpoint)
            + p.p_io * (res.time_checkpoint + res.time_recovery)
            + p.p_down * res.time_down;
        res
    }

    /// Re-read the controller's period; adopt it (clamped to the true
    /// scenario's feasible range) when it changed.
    fn reread_period(
        &self,
        ctl: &mut AdaptiveController,
        res: &mut AdaptiveRunResult,
        period: &mut f64,
    ) {
        let fresh = match ctl.period() {
            Some(p) => self.cfg.scenario.clamp_period(p).unwrap_or(*period),
            None => *period,
        };
        if fresh != *period {
            res.n_period_updates += 1;
            *period = fresh;
        }
    }

    /// Downtime + recovery after a failure, mirroring the engine, with
    /// the controller observing every failure, the exposure time, and
    /// the restore duration.
    fn fail_and_recover(
        &self,
        ctl: &mut AdaptiveController,
        res: &mut AdaptiveRunResult,
        now: &mut f64,
        next_fail: &mut Failure,
        stream: &mut FailureStream,
    ) {
        let (d, r) = (self.cfg.scenario.ckpt.d, self.cfg.scenario.ckpt.r);
        res.n_failures += 1;
        ctl.observe_failure();
        *next_fail = stream.next_after(*now);
        loop {
            let d_end = *now + d;
            let r_end = d_end + r;
            if self.cfg.failures_during_recovery && next_fail.at < r_end {
                // Failure mid-downtime or mid-recovery: account the
                // partial phases, then restart D + R.
                let fail_at = next_fail.at;
                if fail_at < d_end {
                    res.time_down += fail_at - *now;
                } else {
                    res.time_down += d;
                    res.time_recovery += fail_at - d_end;
                }
                ctl.observe_uptime(fail_at - *now);
                *now = fail_at;
                res.n_failures += 1;
                ctl.observe_failure();
                *next_fail = stream.next_after(*now);
                continue;
            }
            res.time_down += d;
            res.time_recovery += r;
            if self.cfg.failures_during_recovery {
                // D + R is failure exposure only when failures can
                // actually strike there; with the clock suspended it
                // must not inflate the μ estimate.
                ctl.observe_uptime(r_end - *now);
            }
            *now = r_end;
            // Mirror the engine: a suspended failure process cannot fire
            // retroactively out of the D + R window.
            if !self.cfg.failures_during_recovery && next_fail.at < *now {
                *next_fail = stream.next_after(*now);
            }
            // The "measured" restore duration is the true R.
            ctl.observe_restore(r);
            return;
        }
    }
}

/// Aggregated Monte-Carlo estimates of adaptive runs.
#[derive(Debug, Clone)]
pub struct AdaptiveMonteCarloResult {
    pub replicates: usize,
    pub makespan: OnlineStats,
    pub energy: OnlineStats,
    pub failures: OnlineStats,
    pub checkpoints: OnlineStats,
    pub work_lost: OnlineStats,
    pub period_updates: OnlineStats,
    pub final_period: OnlineStats,
}

/// Run `replicates` independent adaptive sample paths. Replicate `i`
/// simulates seed `base_seed + i`; results are byte-identical for every
/// `threads` value (same contract as [`super::runner::monte_carlo`]).
pub fn adaptive_monte_carlo(
    cfg: &AdaptiveSimConfig,
    replicates: usize,
    base_seed: u64,
    threads: usize,
) -> AdaptiveMonteCarloResult {
    assert!(replicates > 0);
    let threads = threads.clamp(1, replicates);
    let sim = AdaptiveSimulator::new(cfg.clone());
    let results: Vec<AdaptiveRunResult> = if threads == 1 || ThreadPool::in_worker() {
        (0..replicates).map(|i| sim.run(base_seed + i as u64)).collect()
    } else {
        ThreadPool::global().map(replicates, |i| sim.run(base_seed + i as u64))
    };

    let mut mc = AdaptiveMonteCarloResult {
        replicates,
        makespan: OnlineStats::new(),
        energy: OnlineStats::new(),
        failures: OnlineStats::new(),
        checkpoints: OnlineStats::new(),
        work_lost: OnlineStats::new(),
        period_updates: OnlineStats::new(),
        final_period: OnlineStats::new(),
    };
    for r in &results {
        mc.makespan.push(r.makespan);
        mc.energy.push(r.energy);
        mc.failures.push(r.n_failures as f64);
        mc.checkpoints.push(r.n_checkpoints as f64);
        mc.work_lost.push(r.work_lost);
        mc.period_updates.push(r.n_period_updates as f64);
        mc.final_period.push(r.final_period);
    }
    mc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fig1_scenario;
    use crate::model::energy::t_energy_opt;
    use crate::model::time::t_time_opt;
    use crate::pareto::KneeMethod;
    use crate::sim::engine::{SimConfig, Simulator};
    use crate::util::stats::rel_err;

    #[test]
    fn deterministic_per_seed() {
        let s = fig1_scenario(300.0, 5.5);
        let sim = AdaptiveSimulator::new(AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT));
        let a = sim.run(42);
        let b = sim.run(42);
        assert_eq!(a, b);
        assert_ne!(a, sim.run(43));
    }

    #[test]
    fn correct_prior_tracks_the_static_policy() {
        // With the prior equal to the true μ and exact C/R measurements,
        // the adaptive run should land near the fixed-period simulation
        // at the policy's true period.
        let s = fig1_scenario(300.0, 5.5);
        let t = t_time_opt(&s).unwrap();
        let adaptive = adaptive_monte_carlo(
            &AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT),
            120,
            7,
            8,
        );
        let fixed = crate::sim::runner::monte_carlo(&SimConfig::paper(s, t), 120, 7, 8);
        assert!(
            rel_err(adaptive.makespan.mean(), fixed.makespan.mean()) < 0.03,
            "adaptive {} vs fixed {}",
            adaptive.makespan.mean(),
            fixed.makespan.mean()
        );
        assert!(
            rel_err(adaptive.energy.mean(), fixed.energy.mean()) < 0.03,
            "adaptive {} vs fixed {}",
            adaptive.energy.mean(),
            fixed.energy.mean()
        );
        // And the final period is near the true policy period.
        assert!(
            rel_err(adaptive.final_period.mean(), t) < 0.2,
            "final period {} vs T_Time_opt {t}",
            adaptive.final_period.mean()
        );
    }

    #[test]
    fn wrong_prior_is_estimated_away() {
        // Prior μ 5x too large: the controller must shrink the period
        // toward the true policy period as failures are observed.
        let s = fig1_scenario(300.0, 5.5);
        let mut cfg = AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT);
        cfg.prior_mu = s.mu * 5.0;
        let mc = adaptive_monte_carlo(&cfg, 80, 11, 8);
        let t = t_time_opt(&s).unwrap();
        assert!(
            rel_err(mc.final_period.mean(), t) < 0.25,
            "final period {} vs T_Time_opt {t}",
            mc.final_period.mean()
        );
        assert!(mc.period_updates.mean() >= 1.0, "period never adapted");
    }

    #[test]
    fn suspended_recovery_time_is_not_failure_exposure() {
        // μ comparable to D + R: counting the suspended D + R window as
        // exposure would inflate the μ estimate by ~(D+R)/μ = 20% and
        // the applied period by ~half that. The final period must track
        // the true policy period instead.
        let ckpt = crate::model::CheckpointParams::new(2.0, 2.0, 1.0, 0.5).unwrap();
        let power = crate::model::PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        let s = Scenario::new(ckpt, power, 15.0, 2000.0).unwrap();
        let mut cfg = AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT);
        cfg.failures_during_recovery = false;
        let mc = adaptive_monte_carlo(&cfg, 80, 13, 8);
        let t = t_time_opt(&s).unwrap();
        assert!(
            rel_err(mc.final_period.mean(), t) < 0.06,
            "final period {} vs T_Time_opt {t} (phantom D+R exposure would land ~10% high)",
            mc.final_period.mean()
        );
    }

    #[test]
    fn knee_policy_lands_between_the_endpoints() {
        let s = fig1_scenario(300.0, 5.5);
        let reps = 120;
        let seed = 5;
        let mc_of = |policy| {
            adaptive_monte_carlo(&AdaptiveSimConfig::paper(s, policy), reps, seed, 8)
        };
        let t = mc_of(PeriodPolicy::AlgoT);
        let e = mc_of(PeriodPolicy::AlgoE);
        let k = mc_of(PeriodPolicy::Knee {
            method: KneeMethod::MaxDistanceToChord,
            backend: crate::model::Backend::FirstOrder,
        });
        assert!(
            k.makespan.mean() < e.makespan.mean(),
            "knee makespan {} !< AlgoE {}",
            k.makespan.mean(),
            e.makespan.mean()
        );
        assert!(
            k.energy.mean() < t.energy.mean(),
            "knee energy {} !< AlgoT {}",
            k.energy.mean(),
            t.energy.mean()
        );
        // The knee's final period sits inside the optimal-period range.
        let tt = t_time_opt(&s).unwrap();
        let te = t_energy_opt(&s).unwrap();
        let kp = k.final_period.mean();
        assert!(kp > tt && kp < te, "knee period {kp} outside ({tt}, {te})");
    }

    #[test]
    fn energy_identity_holds_per_path() {
        let s = fig1_scenario(120.0, 7.0);
        let sim = AdaptiveSimulator::new(AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoE));
        for seed in 0..10 {
            let res = sim.run(seed);
            let p = &s.power;
            let manual = p.p_static * res.makespan
                + p.p_cal * (res.time_compute + s.ckpt.omega * res.time_checkpoint)
                + p.p_io * (res.time_checkpoint + res.time_recovery)
                + p.p_down * res.time_down;
            assert!(rel_err(res.energy, manual) < 1e-12, "seed={seed}");
            let total =
                res.time_compute + res.time_checkpoint + res.time_recovery + res.time_down;
            assert!(rel_err(res.makespan, total) < 1e-12, "seed={seed}");
        }
    }

    #[test]
    fn thread_count_does_not_change_estimates() {
        let s = fig1_scenario(300.0, 5.5);
        let cfg = AdaptiveSimConfig::paper(
            s,
            PeriodPolicy::Knee {
                method: KneeMethod::MaxDistanceToChord,
                backend: crate::model::Backend::FirstOrder,
            },
        );
        let a = adaptive_monte_carlo(&cfg, 48, 7, 1);
        let b = adaptive_monte_carlo(&cfg, 48, 7, 8);
        assert_eq!(a.makespan.mean().to_bits(), b.makespan.mean().to_bits());
        assert_eq!(a.energy.mean().to_bits(), b.energy.mean().to_bits());
        assert_eq!(a.final_period.mean().to_bits(), b.final_period.mean().to_bits());
    }

    #[test]
    fn failure_free_run_stretches_the_period() {
        // With no failures the exposure estimator's μ grows with the
        // observed uptime, so the controller checkpoints progressively
        // less often — and beats the fixed T_Time_opt schedule, which
        // keeps paying checkpoint overhead for failures that never come.
        let s = fig1_scenario(300.0, 5.5);
        let mut cfg = AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT);
        cfg.failure = FailureProcess::Exponential { mtbf: 1e18 };
        let sim = AdaptiveSimulator::new(cfg);
        let res = sim.run(1);
        assert_eq!(res.n_failures, 0);
        let t = t_time_opt(&s).unwrap();
        assert!(res.n_period_updates > 0, "period never adapted to the quiet platform");
        assert!(res.final_period > t, "final {} !> initial {t}", res.final_period);
        let fixed = Simulator::new(SimConfig {
            scenario: s,
            period: t,
            failure: FailureProcess::Exponential { mtbf: 1e18 },
            failures_during_recovery: true,
        })
        .run(1);
        assert!(res.makespan >= s.t_base);
        assert!(
            res.makespan < fixed.makespan,
            "adaptive {} !< fixed {} on a failure-free platform",
            res.makespan,
            fixed.makespan
        );
    }
}
