//! Monte-Carlo replication over seeds, multi-threaded with std threads
//! (no tokio/rayon in the offline vendor set — a scoped-thread fan-out is
//! all this needs).

use super::engine::{RunResult, SimConfig, Simulator};
use crate::util::stats::{ConfidenceLevel, OnlineStats};

/// Aggregated Monte-Carlo estimates.
#[derive(Debug, Clone)]
pub struct MonteCarloResult {
    pub replicates: usize,
    pub makespan: OnlineStats,
    pub energy: OnlineStats,
    pub failures: OnlineStats,
    pub checkpoints: OnlineStats,
    pub work_lost: OnlineStats,
}

impl MonteCarloResult {
    pub fn makespan_ci95(&self) -> (f64, f64) {
        self.makespan.ci(ConfidenceLevel::P95)
    }

    pub fn energy_ci95(&self) -> (f64, f64) {
        self.energy.ci(ConfidenceLevel::P95)
    }
}

/// Run `replicates` independent sample paths of `cfg`, fanned out over
/// `threads` OS threads (seeds `base_seed..base_seed+replicates` are
/// partitioned round-robin so results are independent of thread count).
pub fn monte_carlo(
    cfg: &SimConfig,
    replicates: usize,
    base_seed: u64,
    threads: usize,
) -> MonteCarloResult {
    assert!(replicates > 0);
    let mut threads = threads.clamp(1, replicates);
    let sim = Simulator::new(cfg.clone());
    // §Perf: thread spawn + join costs ~100 µs; a replicate of a typical
    // scenario costs ~2 µs. Calibrate on one run and only fan out when
    // the parallel half actually amortises the fork (see EXPERIMENTS.md
    // §Perf L3-1 for the before/after).
    let mut first: Option<RunResult> = None;
    if threads > 1 {
        let t0 = std::time::Instant::now();
        first = Some(sim.run(base_seed));
        let est_total = t0.elapsed().as_secs_f64() * (replicates - 1) as f64;
        if est_total < 1e-3 {
            threads = 1;
        }
    }
    let results: Vec<RunResult> = if threads == 1 {
        let skip = usize::from(first.is_some());
        let mut out: Vec<RunResult> = Vec::with_capacity(replicates);
        out.extend(first);
        out.extend((skip..replicates).map(|i| sim.run(base_seed + i as u64)));
        out
    } else {
        let mut out: Vec<Option<RunResult>> = vec![None; replicates];
        let chunks: Vec<Vec<usize>> = (0..threads)
            .map(|t| (t..replicates).step_by(threads).collect())
            .collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for idxs in &chunks {
                let sim = &sim;
                handles.push(scope.spawn(move || {
                    idxs.iter()
                        .map(|&i| (i, sim.run(base_seed + i as u64)))
                        .collect::<Vec<_>>()
                }));
            }
            for h in handles {
                for (i, r) in h.join().expect("sim thread panicked") {
                    out[i] = Some(r);
                }
            }
        });
        out.into_iter().map(|r| r.unwrap()).collect()
    };

    let mut mc = MonteCarloResult {
        replicates,
        makespan: OnlineStats::new(),
        energy: OnlineStats::new(),
        failures: OnlineStats::new(),
        checkpoints: OnlineStats::new(),
        work_lost: OnlineStats::new(),
    };
    for r in &results {
        mc.makespan.push(r.makespan);
        mc.energy.push(r.energy);
        mc.failures.push(r.n_failures as f64);
        mc.checkpoints.push(r.n_checkpoints as f64);
        mc.work_lost.push(r.work_lost);
    }
    mc
}

/// Empirically search the period minimising mean makespan or energy by
/// Monte Carlo over a grid — the simulator's answer to AlgoT/AlgoE, used
/// to validate the closed-form optima end to end.
pub fn empirical_optimal_period(
    cfg_at: impl Fn(f64) -> SimConfig,
    grid: &[f64],
    replicates: usize,
    base_seed: u64,
    threads: usize,
    objective_energy: bool,
) -> (f64, f64) {
    assert!(!grid.is_empty());
    let mut best = (f64::NAN, f64::INFINITY);
    for &t in grid {
        let mc = monte_carlo(&cfg_at(t), replicates, base_seed, threads);
        let v = if objective_energy { mc.energy.mean() } else { mc.makespan.mean() };
        if v < best.1 {
            best = (t, v);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{CheckpointParams, PowerParams, Scenario};
    use crate::model::{e_final, t_final};
    use crate::util::stats::rel_err;

    fn scenario(mu: f64) -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        Scenario::new(ckpt, power, mu, 20_000.0).unwrap()
    }

    #[test]
    fn thread_count_does_not_change_estimates() {
        let cfg = SimConfig::paper(scenario(300.0), 80.0);
        let a = monte_carlo(&cfg, 64, 7, 1);
        let b = monte_carlo(&cfg, 64, 7, 8);
        assert_eq!(a.makespan.mean(), b.makespan.mean());
        assert_eq!(a.energy.mean(), b.energy.mean());
    }

    #[test]
    fn sim_mean_matches_model_t_final() {
        // mu=300 >> C=10: first-order model should match MC within ~2%.
        let s = scenario(300.0);
        let t = 80.0;
        let cfg = SimConfig::paper(s, t);
        let mc = monte_carlo(&cfg, 400, 1, 8);
        let model = t_final(&s, t);
        let sim = mc.makespan.mean();
        assert!(rel_err(model, sim) < 0.02, "model={model} sim={sim}");
    }

    #[test]
    fn sim_mean_matches_model_e_final() {
        let s = scenario(300.0);
        let t = 80.0;
        let cfg = SimConfig::paper(s, t);
        let mc = monte_carlo(&cfg, 400, 2, 8);
        let model = e_final(&s, t);
        let sim = mc.energy.mean();
        assert!(rel_err(model, sim) < 0.02, "model={model} sim={sim}");
    }

    #[test]
    fn failure_count_matches_expectation() {
        let s = scenario(300.0);
        let t = 80.0;
        let mc = monte_carlo(&SimConfig::paper(s, t), 400, 3, 8);
        let expect = t_final(&s, t) / s.mu;
        assert!(
            rel_err(mc.failures.mean(), expect) < 0.05,
            "sim={} expect={expect}",
            mc.failures.mean()
        );
    }

    #[test]
    fn empirical_optimum_near_closed_form() {
        let s = scenario(300.0);
        let topt = crate::model::t_time_opt(&s).unwrap();
        let grid: Vec<f64> = (1..=12).map(|i| 20.0 * i as f64).collect();
        let (t_emp, _) = empirical_optimal_period(
            |t| SimConfig::paper(s, t),
            &grid,
            200,
            5,
            8,
            false,
        );
        // Grid resolution is 20 min; the empirical argmin should land in
        // the cell containing T_Time_opt (or an adjacent one: the
        // objective is very flat near the optimum).
        assert!(
            (t_emp - topt).abs() <= 40.0,
            "empirical={t_emp} closed-form={topt}"
        );
    }
}
