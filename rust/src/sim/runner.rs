//! Monte-Carlo replication over seeds, fanned out on the persistent
//! work-stealing pool ([`crate::util::pool::ThreadPool`]).
//!
//! Replicate `i` always simulates seed `base_seed + i` and estimates are
//! accumulated in index order, so the result is byte-identical for every
//! `threads` value. Earlier revisions spawned + joined scoped threads on
//! every call (~100 µs of churn that a per-call calibration hack tried to
//! amortise); the pool made both the churn and the hack unnecessary.
//! Inside a pool worker (e.g. when a [`crate::sweep::GridSpec`] cell runs
//! a simulation) the fan-out degrades to an inline loop — same seeds,
//! same results, no deadlock.

use super::engine::{RunResult, SimConfig, Simulator};
use crate::model::optimize::golden_section;
use crate::util::pool::ThreadPool;
use crate::util::stats::{ConfidenceLevel, OnlineStats};

/// Aggregated Monte-Carlo estimates.
#[derive(Debug, Clone)]
pub struct MonteCarloResult {
    pub replicates: usize,
    pub makespan: OnlineStats,
    pub energy: OnlineStats,
    pub failures: OnlineStats,
    pub checkpoints: OnlineStats,
    pub work_lost: OnlineStats,
}

impl MonteCarloResult {
    pub fn makespan_ci95(&self) -> (f64, f64) {
        self.makespan.ci(ConfidenceLevel::P95)
    }

    pub fn energy_ci95(&self) -> (f64, f64) {
        self.energy.ci(ConfidenceLevel::P95)
    }
}

/// Fold per-replicate results into the Monte-Carlo aggregate, in
/// replicate-index order (order is part of the thread-count determinism
/// contract — `OnlineStats` sums are order-sensitive).
fn collect_stats(replicates: usize, results: &[RunResult]) -> MonteCarloResult {
    let mut mc = MonteCarloResult {
        replicates,
        makespan: OnlineStats::new(),
        energy: OnlineStats::new(),
        failures: OnlineStats::new(),
        checkpoints: OnlineStats::new(),
        work_lost: OnlineStats::new(),
    };
    for r in results {
        mc.makespan.push(r.makespan);
        mc.energy.push(r.energy);
        mc.failures.push(r.n_failures as f64);
        mc.checkpoints.push(r.n_checkpoints as f64);
        mc.work_lost.push(r.work_lost);
    }
    mc
}

/// Run `replicates` independent sample paths of `cfg`. Replicate `i`
/// simulates seed `base_seed + i`; `threads > 1` fans the replicates out
/// on the persistent pool. Results are identical for every `threads`
/// value (the pool writes by index and aggregation is in index order).
///
/// Dispatches to the batched lockstep executor ([`super::batch`]) —
/// bit-identical to the per-replica loop by construction, pinned by
/// `tests/batch_sim.rs` against [`monte_carlo_reference`].
pub fn monte_carlo(
    cfg: &SimConfig,
    replicates: usize,
    base_seed: u64,
    threads: usize,
) -> MonteCarloResult {
    assert!(replicates > 0);
    let results = super::batch::run_batched(cfg, replicates, base_seed, threads);
    collect_stats(replicates, &results)
}

/// The pre-batching per-replica driver, kept verbatim as the
/// bit-identity reference for the lockstep executor (the PR 9
/// `compute_reference` pattern). Not part of the public surface.
#[doc(hidden)]
pub fn monte_carlo_reference(
    cfg: &SimConfig,
    replicates: usize,
    base_seed: u64,
    threads: usize,
) -> MonteCarloResult {
    assert!(replicates > 0);
    let threads = threads.clamp(1, replicates);
    let sim = Simulator::new(cfg.clone());
    let results: Vec<RunResult> = if threads == 1 || ThreadPool::in_worker() {
        (0..replicates).map(|i| sim.run(base_seed + i as u64)).collect()
    } else {
        ThreadPool::global().map(replicates, |i| sim.run(base_seed + i as u64))
    };
    collect_stats(replicates, &results)
}

/// Empirically search the period minimising mean makespan or energy by
/// Monte Carlo — the simulator's answer to AlgoT/AlgoE, used to
/// validate the closed-form optima end to end.
///
/// Every supplied `grid` period is evaluated (the grid may be
/// non-uniform, e.g. log-spaced), then the best bracket is refined
/// with the shared [`crate::model::optimize::golden_section`]
/// minimiser — the same scan-then-refine shape (and tolerance
/// convention) as `grid_then_golden`, rather than a bespoke argmin
/// loop. The Monte-Carlo objective is deterministic per period (fixed
/// `base_seed`), so the refinement is reproducible; it stays inside
/// the bracket around the best grid point, with residual Monte-Carlo
/// noise of the same order as the objective's flatness near its
/// optimum. `grid` must be sorted ascending.
pub fn empirical_optimal_period(
    cfg_at: impl Fn(f64) -> SimConfig,
    grid: &[f64],
    replicates: usize,
    base_seed: u64,
    threads: usize,
    objective_energy: bool,
) -> (f64, f64) {
    assert!(!grid.is_empty());
    debug_assert!(grid.windows(2).all(|w| w[0] <= w[1]), "grid must be sorted ascending");
    let mut eval = |t: f64| {
        let mc = monte_carlo(&cfg_at(t), replicates, base_seed, threads);
        if objective_energy {
            mc.energy.mean()
        } else {
            mc.makespan.mean()
        }
    };
    let mut best = (0usize, f64::INFINITY);
    for (i, &t) in grid.iter().enumerate() {
        let v = eval(t);
        if v < best.1 {
            best = (i, v);
        }
    }
    let (a, b) = (grid[best.0.saturating_sub(1)], grid[(best.0 + 1).min(grid.len() - 1)]);
    if b <= a {
        return (grid[best.0], best.1);
    }
    // Refining below a few percent of the bracket buys nothing: the MC
    // noise floor dominates long before that.
    golden_section(eval, a, b, (b - a) * 0.05)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{CheckpointParams, PowerParams, Scenario};
    use crate::model::{e_final, t_final};
    use crate::util::stats::rel_err;

    fn scenario(mu: f64) -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        Scenario::new(ckpt, power, mu, 20_000.0).unwrap()
    }

    #[test]
    fn thread_count_does_not_change_estimates() {
        let cfg = SimConfig::paper(scenario(300.0), 80.0);
        let a = monte_carlo(&cfg, 64, 7, 1);
        let b = monte_carlo(&cfg, 64, 7, 8);
        assert_eq!(a.makespan.mean(), b.makespan.mean());
        assert_eq!(a.energy.mean(), b.energy.mean());
    }

    #[test]
    fn sim_mean_matches_model_t_final() {
        // mu=300 >> C=10: first-order model should match MC within ~2%.
        let s = scenario(300.0);
        let t = 80.0;
        let cfg = SimConfig::paper(s, t);
        let mc = monte_carlo(&cfg, 400, 1, 8);
        let model = t_final(&s, t);
        let sim = mc.makespan.mean();
        assert!(rel_err(model, sim) < 0.02, "model={model} sim={sim}");
    }

    #[test]
    fn sim_mean_matches_model_e_final() {
        let s = scenario(300.0);
        let t = 80.0;
        let cfg = SimConfig::paper(s, t);
        let mc = monte_carlo(&cfg, 400, 2, 8);
        let model = e_final(&s, t);
        let sim = mc.energy.mean();
        assert!(rel_err(model, sim) < 0.02, "model={model} sim={sim}");
    }

    #[test]
    fn failure_count_matches_expectation() {
        let s = scenario(300.0);
        let t = 80.0;
        let mc = monte_carlo(&SimConfig::paper(s, t), 400, 3, 8);
        let expect = t_final(&s, t) / s.mu;
        assert!(
            rel_err(mc.failures.mean(), expect) < 0.05,
            "sim={} expect={expect}",
            mc.failures.mean()
        );
    }

    #[test]
    fn empirical_optimum_near_closed_form() {
        let s = scenario(300.0);
        let topt = crate::model::t_time_opt(&s).unwrap();
        let grid: Vec<f64> = (1..=12).map(|i| 20.0 * i as f64).collect();
        let (t_emp, _) = empirical_optimal_period(
            |t| SimConfig::paper(s, t),
            &grid,
            200,
            5,
            8,
            false,
        );
        // Grid resolution is 20 min; the refinement stays inside the
        // best coarse bracket, which contains T_Time_opt or a
        // neighbouring cell (the objective is very flat near the
        // optimum), so the argmin lands within two cells.
        assert!(
            (t_emp - topt).abs() <= 40.0,
            "empirical={t_emp} closed-form={topt}"
        );
    }
}
