//! Discrete-event simulator of coordinated checkpointing (the paper's
//! missing testbed — see DESIGN.md §6).
//!
//! The simulator executes the *stochastic process* that §3 of the paper
//! analyses in expectation: an application of `T_base` work units runs on
//! a platform whose failures arrive with MTBF `μ`; every period `T` it
//! takes a non-blocking checkpoint of length `C` during which computation
//! progresses at rate `ω`; each failure costs a downtime `D`, a recovery
//! `R`, and the loss of all work since the last *completed* checkpoint's
//! cut point. Wall-clock time and per-power-state energy are integrated
//! exactly along the sample path.
//!
//! Monte-Carlo replicates ([`runner`]) then estimate `E[T_final]` and
//! `E[E_final]`, which `rust/tests/sim_vs_model.rs` and
//! `examples/model_vs_sim` compare against the closed forms — the
//! validation the paper could not run.
//!
//! * [`failure`] — failure processes: platform-aggregate exponential (the
//!   paper's model), per-node exponential (superposition sanity check),
//!   per-node Weibull (robustness extension), and the non-homogeneous
//!   exponential over a drifting environment
//!   ([`crate::drift::EnvTrajectory`], thinned sampling).
//! * [`engine`] — the single-run event loop.
//! * [`runner`] — seeded Monte-Carlo replication on the persistent pool.
//! * [`batch`] — the batched lockstep executor behind [`monte_carlo`]
//!   and [`adaptive_monte_carlo`]: B replicas advance in lockstep per
//!   pool job over struct-of-arrays state, with block-drawn failure
//!   samples and no per-event allocation — bit-identical to the
//!   per-replica loops (replicas are independent; interleaving them
//!   changes no replica's operation sequence).
//! * [`adaptive`] — the engine with the online
//!   [`AdaptiveController`](crate::coordinator::AdaptiveController) in
//!   the loop: `C`/`R`/`μ` re-estimated along the sample path and the
//!   period re-read from the policy after every checkpoint/recovery;
//!   drives time-varying [`crate::drift`] trajectories and records
//!   tracking lag / clairvoyant-oracle regret.
//!
//! # Which failure process does the CLI simulate?
//!
//! Since the objective-model backend landed (PR 4), `simulate` (both
//! the fixed-period and the `--adaptive` path) matches its failure
//! process to the selected `--model` rather than defaulting to the
//! *realistic* process: failures strike during the D + R window only
//! under `--model exact` (= `exact:restarting`), while `first-order`
//! and `exact:ideal` suspend the failure clock there — the convention
//! `tests/sim_vs_model.rs` and [`crate::pareto::validate`] use, so the
//! printed model columns and the Monte-Carlo columns describe the same
//! stochastic process. Pass
//! [`SimConfig::failures_during_recovery`] `= true` directly for the
//! realistic process regardless of the model.
//!
//! # Seeding & determinism
//!
//! Replicate `i` of a [`monte_carlo`] call always simulates seed
//! `base_seed + i` and estimates accumulate in index order, so results
//! are byte-identical for every thread count. Grid-scale exploration
//! should go through [`crate::sweep::GridSpec`], which derives each
//! cell's `base_seed` by hashing the spec seed with the cell's parameter
//! bits and memoises cell outputs process-wide; `monte_carlo` remains
//! the single-scenario building block (and runs inline, same seeds, when
//! invoked from a grid cell on a pool worker).

pub mod adaptive;
pub mod batch;
pub mod engine;
pub mod failure;
pub mod runner;

pub use adaptive::{
    adaptive_monte_carlo, adaptive_monte_carlo_with, AdaptiveMonteCarloResult,
    AdaptiveRunResult, AdaptiveSimConfig, AdaptiveSimulator,
};
pub use engine::{RunResult, SimConfig, Simulator};
pub use failure::FailureProcess;
pub use runner::{monte_carlo, MonteCarloResult};
