//! Failure processes for the platform simulator.
//!
//! The paper models platform failures as a renewal process with MTBF `μ`
//! (exponential inter-arrivals — §2.1). We provide that process directly
//! (`Exponential`), the equivalent superposition of `N` per-node
//! exponential streams (`PerNodeExponential` — used to *test* the
//! `μ = μ_ind/N` aggregation the paper asserts), and per-node Weibull
//! renewals (`PerNodeWeibull` — a robustness extension: real HPC failure
//! logs show shape < 1, i.e. infant mortality).

use crate::util::rng::Pcg64;

/// Specification of a failure process.
#[derive(Debug, Clone, PartialEq)]
pub enum FailureProcess {
    /// Platform-aggregate exponential with the given MTBF (the paper's
    /// model; memoryless, so recovery/downtime need no special-casing).
    Exponential { mtbf: f64 },
    /// `n` nodes, each an independent exponential renewal with MTBF
    /// `mtbf_ind`. Equivalent in law to `Exponential { mtbf_ind / n }`.
    PerNodeExponential { n: usize, mtbf_ind: f64 },
    /// `n` nodes, each a Weibull renewal. `shape < 1` ⇒ decreasing hazard
    /// (bursty, infant-mortality-like); `shape = 1` ⇒ exponential.
    /// `scale_ind` is each node's Weibull scale parameter.
    PerNodeWeibull { n: usize, shape: f64, scale_ind: f64 },
}

impl FailureProcess {
    /// The process's long-run platform MTBF (used to parameterise model
    /// comparisons).
    pub fn platform_mtbf(&self) -> f64 {
        match self {
            FailureProcess::Exponential { mtbf } => *mtbf,
            FailureProcess::PerNodeExponential { n, mtbf_ind } => mtbf_ind / *n as f64,
            FailureProcess::PerNodeWeibull { n, shape, scale_ind } => {
                // Node mean = scale * Γ(1 + 1/shape); platform rate = n/node-mean.
                scale_ind * gamma(1.0 + 1.0 / shape) / *n as f64
            }
        }
    }

    /// Instantiate a sampling stream.
    pub fn stream(&self, rng: &mut Pcg64) -> FailureStream {
        match self {
            FailureProcess::Exponential { mtbf } => {
                FailureStream::Exponential { mtbf: *mtbf, rng: rng.split(0xFA11) }
            }
            FailureProcess::PerNodeExponential { n, mtbf_ind } => {
                // Superposition of exponentials is exponential: sample the
                // aggregate directly but keep per-node attribution by
                // picking a uniformly random node per event (exact for
                // i.i.d. exponential nodes).
                FailureStream::AggregateAttributed {
                    mtbf: mtbf_ind / *n as f64,
                    n: *n,
                    rng: rng.split(0xFA12),
                }
            }
            FailureProcess::PerNodeWeibull { n, shape, scale_ind } => {
                // True per-node renewal simulation via a next-event heap.
                let mut heap = std::collections::BinaryHeap::with_capacity(*n);
                let mut streams = Vec::with_capacity(*n);
                for node in 0..*n {
                    let mut node_rng = rng.split(0x7E1B + node as u64);
                    let first = node_rng.weibull(*shape, *scale_ind);
                    heap.push(NextEvent { at: first, node });
                    streams.push(node_rng);
                }
                FailureStream::PerNodeRenewal {
                    shape: *shape,
                    scale: *scale_ind,
                    heap,
                    streams,
                }
            }
        }
    }
}

/// A single failure event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Failure {
    /// Absolute platform time of the failure.
    pub at: f64,
    /// Which node failed (0 for aggregate processes).
    pub node: usize,
}

/// Min-heap entry (BinaryHeap is a max-heap; invert ordering on time).
/// Public only because it appears in [`FailureStream`]'s variant fields;
/// not constructible outside this module.
#[derive(Debug)]
pub struct NextEvent {
    at: f64,
    node: usize,
}

impl PartialEq for NextEvent {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at
    }
}
impl Eq for NextEvent {}
impl PartialOrd for NextEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for NextEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse: smallest time pops first.
        other.at.partial_cmp(&self.at).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// A stateful stream of failure events in increasing time order.
pub enum FailureStream {
    Exponential {
        mtbf: f64,
        rng: Pcg64,
    },
    AggregateAttributed {
        mtbf: f64,
        n: usize,
        rng: Pcg64,
    },
    PerNodeRenewal {
        shape: f64,
        scale: f64,
        heap: std::collections::BinaryHeap<NextEvent>,
        streams: Vec<Pcg64>,
    },
}

impl FailureStream {
    /// Next failure strictly after `now`. Streams are renewal processes in
    /// absolute time; the engine simply consumes them in order and skips
    /// events that land inside already-lost intervals is NOT needed —
    /// failures during downtime/recovery are real events the engine
    /// handles explicitly.
    pub fn next_after(&mut self, now: f64) -> Failure {
        match self {
            FailureStream::Exponential { mtbf, rng } => {
                Failure { at: now + rng.exponential(*mtbf), node: 0 }
            }
            FailureStream::AggregateAttributed { mtbf, n, rng } => {
                let at = now + rng.exponential(*mtbf);
                let node = rng.below(*n as u64) as usize;
                Failure { at, node }
            }
            FailureStream::PerNodeRenewal { shape, scale, heap, streams } => {
                loop {
                    let ev = heap.pop().expect("renewal heap never empties");
                    let node = ev.node;
                    let next = ev.at + streams[node].weibull(*shape, *scale);
                    heap.push(NextEvent { at: next, node });
                    if ev.at > now {
                        return Failure { at: ev.at, node };
                    }
                    // Event at or before `now` (can happen after the engine
                    // fast-forwards across downtime): drop it and keep the
                    // renewal ticking.
                }
            }
        }
    }
}

/// Lanczos approximation of Γ(x) for x > 0 (used for Weibull means).
pub fn gamma(x: f64) -> f64 {
    // g = 7, n = 9 Lanczos coefficients.
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        std::f64::consts::PI / ((std::f64::consts::PI * x).sin() * gamma(1.0 - x))
    } else {
        let x = x - 1.0;
        let mut a = COEF[0];
        let t = x + G + 0.5;
        for (i, &c) in COEF.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        (2.0 * std::f64::consts::PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::OnlineStats;

    #[test]
    fn gamma_known_values() {
        assert!((gamma(1.0) - 1.0).abs() < 1e-10);
        assert!((gamma(2.0) - 1.0).abs() < 1e-10);
        assert!((gamma(5.0) - 24.0).abs() < 1e-8);
        assert!((gamma(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-10);
        assert!((gamma(1.5) - std::f64::consts::PI.sqrt() / 2.0).abs() < 1e-10);
    }

    fn mean_interarrival(proc: &FailureProcess, events: usize, seed: u64) -> f64 {
        let mut rng = Pcg64::seeded(seed);
        let mut stream = proc.stream(&mut rng);
        let mut stats = OnlineStats::new();
        let mut now = 0.0;
        for _ in 0..events {
            let f = stream.next_after(now);
            stats.push(f.at - now);
            now = f.at;
        }
        stats.mean()
    }

    #[test]
    fn exponential_stream_mtbf() {
        let p = FailureProcess::Exponential { mtbf: 120.0 };
        let m = mean_interarrival(&p, 100_000, 1);
        assert!((m - 120.0).abs() / 120.0 < 0.02, "m={m}");
    }

    #[test]
    fn per_node_exponential_aggregates_to_mu_ind_over_n() {
        let p = FailureProcess::PerNodeExponential { n: 1000, mtbf_ind: 120_000.0 };
        assert!((p.platform_mtbf() - 120.0).abs() < 1e-9);
        let m = mean_interarrival(&p, 100_000, 2);
        assert!((m - 120.0).abs() / 120.0 < 0.02, "m={m}");
    }

    #[test]
    fn per_node_attribution_covers_nodes() {
        let p = FailureProcess::PerNodeExponential { n: 16, mtbf_ind: 1600.0 };
        let mut rng = Pcg64::seeded(3);
        let mut stream = p.stream(&mut rng);
        let mut seen = vec![false; 16];
        let mut now = 0.0;
        for _ in 0..2000 {
            let f = stream.next_after(now);
            seen[f.node] = true;
            now = f.at;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weibull_platform_mtbf_matches_simulation() {
        let p = FailureProcess::PerNodeWeibull { n: 50, shape: 0.7, scale_ind: 5000.0 };
        let predicted = p.platform_mtbf();
        // Long-run renewal rate: simulate plenty of events.
        let m = mean_interarrival(&p, 200_000, 4);
        assert!(
            (m - predicted).abs() / predicted < 0.05,
            "sim={m} predicted={predicted}"
        );
    }

    #[test]
    fn weibull_shape1_matches_exponential_mtbf() {
        let p = FailureProcess::PerNodeWeibull { n: 10, shape: 1.0, scale_ind: 1000.0 };
        assert!((p.platform_mtbf() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn events_strictly_increase() {
        for p in [
            FailureProcess::Exponential { mtbf: 10.0 },
            FailureProcess::PerNodeExponential { n: 4, mtbf_ind: 40.0 },
            FailureProcess::PerNodeWeibull { n: 4, shape: 0.8, scale_ind: 40.0 },
        ] {
            let mut rng = Pcg64::seeded(5);
            let mut stream = p.stream(&mut rng);
            let mut now = 0.0;
            for _ in 0..5000 {
                let f = stream.next_after(now);
                assert!(f.at > now, "{p:?}");
                now = f.at;
            }
        }
    }

    #[test]
    fn next_after_skips_stale_renewals() {
        // Jump far ahead: per-node renewal must discard old events.
        let p = FailureProcess::PerNodeWeibull { n: 8, shape: 1.0, scale_ind: 10.0 };
        let mut rng = Pcg64::seeded(6);
        let mut stream = p.stream(&mut rng);
        let f = stream.next_after(1000.0);
        assert!(f.at > 1000.0);
    }
}
