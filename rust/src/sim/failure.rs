//! Failure processes for the platform simulator.
//!
//! The paper models platform failures as a renewal process with MTBF `μ`
//! (exponential inter-arrivals — §2.1). We provide that process directly
//! (`Exponential`), the equivalent superposition of `N` per-node
//! exponential streams (`PerNodeExponential` — used to *test* the
//! `μ = μ_ind/N` aggregation the paper asserts), per-node Weibull
//! renewals (`PerNodeWeibull` — a robustness extension: real HPC failure
//! logs show shape < 1, i.e. infant mortality), and a **non-homogeneous
//! exponential** process driven by a drifting environment
//! (`DriftingExponential` — the rate `λ(t) = 1/μ(t)` follows an
//! [`EnvTrajectory`], sampled exactly by Lewis–Shedler thinning against
//! the trajectory's rate envelope; a μ-stationary trajectory falls back
//! to the homogeneous sampler **bit-for-bit**).

use crate::drift::EnvTrajectory;
use crate::util::rng::Pcg64;

/// Specification of a failure process.
#[derive(Debug, Clone, PartialEq)]
pub enum FailureProcess {
    /// Platform-aggregate exponential with the given MTBF (the paper's
    /// model; memoryless, so recovery/downtime need no special-casing).
    Exponential { mtbf: f64 },
    /// `n` nodes, each an independent exponential renewal with MTBF
    /// `mtbf_ind`. Equivalent in law to `Exponential { mtbf_ind / n }`.
    PerNodeExponential { n: usize, mtbf_ind: f64 },
    /// `n` nodes, each a Weibull renewal. `shape < 1` ⇒ decreasing hazard
    /// (bursty, infant-mortality-like); `shape = 1` ⇒ exponential.
    /// `scale_ind` is each node's Weibull scale parameter.
    PerNodeWeibull { n: usize, shape: f64, scale_ind: f64 },
    /// Platform-aggregate exponential whose MTBF follows the
    /// trajectory's `μ(t)` (wear-out decay, reconfiguration steps, …).
    /// Sampled exactly by thinning; when the trajectory's `μ` component
    /// is stationary this degenerates to `Exponential` at the base MTBF
    /// with **bit-identical** draws (no acceptance draws are consumed).
    DriftingExponential { trajectory: EnvTrajectory },
}

impl FailureProcess {
    /// The process's long-run platform MTBF (used to parameterise model
    /// comparisons).
    pub fn platform_mtbf(&self) -> f64 {
        match self {
            FailureProcess::Exponential { mtbf } => *mtbf,
            FailureProcess::PerNodeExponential { n, mtbf_ind } => mtbf_ind / *n as f64,
            FailureProcess::PerNodeWeibull { n, shape, scale_ind } => {
                // Node mean = scale * Γ(1 + 1/shape); platform rate = n/node-mean.
                scale_ind * gamma(1.0 + 1.0 / shape) / *n as f64
            }
            // The *base* (t = 0 schedule-identity) MTBF; the
            // instantaneous rate varies along the trajectory.
            FailureProcess::DriftingExponential { trajectory } => trajectory.base().mu,
        }
    }

    /// Instantiate a sampling stream.
    pub fn stream(&self, rng: &mut Pcg64) -> FailureStream {
        match self {
            FailureProcess::Exponential { mtbf } => {
                FailureStream::Exponential { mtbf: *mtbf, rng: rng.split(0xFA11) }
            }
            FailureProcess::PerNodeExponential { n, mtbf_ind } => {
                // Superposition of exponentials is exponential: sample the
                // aggregate directly but keep per-node attribution by
                // picking a uniformly random node per event (exact for
                // i.i.d. exponential nodes).
                FailureStream::AggregateAttributed {
                    mtbf: mtbf_ind / *n as f64,
                    n: *n,
                    rng: rng.split(0xFA12),
                }
            }
            FailureProcess::PerNodeWeibull { n, shape, scale_ind } => {
                // True per-node renewal simulation via a next-event heap.
                let mut heap = std::collections::BinaryHeap::with_capacity(*n);
                let mut streams = Vec::with_capacity(*n);
                for node in 0..*n {
                    let mut node_rng = rng.split(0x7E1B + node as u64);
                    let first = node_rng.weibull(*shape, *scale_ind);
                    heap.push(NextEvent { at: first, node });
                    streams.push(node_rng);
                }
                FailureStream::PerNodeRenewal {
                    shape: *shape,
                    scale: *scale_ind,
                    heap,
                    streams,
                }
            }
            FailureProcess::DriftingExponential { trajectory } => {
                if trajectory.mu_is_stationary() {
                    // Same stream tag, same draw sequence: a μ-stationary
                    // drift run consumes failure times bit-identical to
                    // the paper process (the common-random-numbers
                    // contract the drift acceptance tests lean on).
                    FailureStream::Exponential {
                        mtbf: trajectory.base().mu,
                        rng: rng.split(0xFA11),
                    }
                } else {
                    FailureStream::Thinned {
                        trajectory: *trajectory,
                        mu_floor: trajectory.min_mu(),
                        rng: rng.split(0xFA11),
                    }
                }
            }
        }
    }
}

/// A single failure event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Failure {
    /// Absolute platform time of the failure.
    pub at: f64,
    /// Which node failed (0 for aggregate processes).
    pub node: usize,
}

/// Min-heap entry (BinaryHeap is a max-heap; invert ordering on time).
/// Public only because it appears in [`FailureStream`]'s variant fields;
/// not constructible outside this module.
#[derive(Debug)]
pub struct NextEvent {
    at: f64,
    node: usize,
}

impl PartialEq for NextEvent {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at
    }
}
impl Eq for NextEvent {}
impl PartialOrd for NextEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for NextEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse: smallest time pops first.
        other.at.partial_cmp(&self.at).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// A stateful stream of failure events in increasing time order.
pub enum FailureStream {
    Exponential {
        mtbf: f64,
        rng: Pcg64,
    },
    AggregateAttributed {
        mtbf: f64,
        n: usize,
        rng: Pcg64,
    },
    PerNodeRenewal {
        shape: f64,
        scale: f64,
        heap: std::collections::BinaryHeap<NextEvent>,
        streams: Vec<Pcg64>,
    },
    /// Lewis–Shedler thinning for a non-homogeneous exponential with
    /// rate `λ(t) = 1/μ(t)`: propose at the envelope rate `1/mu_floor`
    /// (`mu_floor = inf_t μ(t)`, validated > 0 by [`EnvTrajectory`]),
    /// accept each proposal at `t` with probability
    /// `λ(t)/λ_max = mu_floor/μ(t) ∈ (0, 1]` — an exact sampler for
    /// the inhomogeneous process, not an approximation.
    Thinned {
        trajectory: EnvTrajectory,
        mu_floor: f64,
        rng: Pcg64,
    },
}

impl FailureStream {
    /// Next failure strictly after `now`. Streams are renewal processes in
    /// absolute time; the engine simply consumes them in order and skips
    /// events that land inside already-lost intervals is NOT needed —
    /// failures during downtime/recovery are real events the engine
    /// handles explicitly.
    pub fn next_after(&mut self, now: f64) -> Failure {
        match self {
            FailureStream::Exponential { mtbf, rng } => {
                Failure { at: now + rng.exponential(*mtbf), node: 0 }
            }
            FailureStream::AggregateAttributed { mtbf, n, rng } => {
                let at = now + rng.exponential(*mtbf);
                let node = rng.below(*n as u64) as usize;
                Failure { at, node }
            }
            FailureStream::PerNodeRenewal { shape, scale, heap, streams } => {
                loop {
                    let ev = heap.pop().expect("renewal heap never empties");
                    let node = ev.node;
                    let next = ev.at + streams[node].weibull(*shape, *scale);
                    heap.push(NextEvent { at: next, node });
                    if ev.at > now {
                        return Failure { at: ev.at, node };
                    }
                    // Event at or before `now` (can happen after the engine
                    // fast-forwards across downtime): drop it and keep the
                    // renewal ticking.
                }
            }
            FailureStream::Thinned { trajectory, mu_floor, rng } => {
                let mut t = now;
                loop {
                    t += rng.exponential(*mu_floor);
                    // Accept with λ(t)/λ_max = mu_floor/μ(t); uniform()
                    // ∈ [0, 1) so acceptance probability 1 never rejects.
                    if rng.uniform() < *mu_floor / trajectory.mu_at(t) {
                        return Failure { at: t, node: 0 };
                    }
                }
            }
        }
    }
}

/// Anything the event loops can pull ordered failure events from: the
/// plain [`FailureStream`] or the block-drawing [`BufferedFailures`]
/// wrapper. The recovery helpers in [`super::engine`]/[`super::adaptive`]
/// are generic over this, so the scalar reference loops and the batched
/// lockstep executor ([`super::batch`]) share one monomorphised body —
/// identical floating-point operation order either way.
pub(crate) trait FailureSource {
    fn next_after(&mut self, now: f64) -> Failure;
}

impl FailureSource for FailureStream {
    #[inline]
    fn next_after(&mut self, now: f64) -> Failure {
        FailureStream::next_after(self, now)
    }
}

/// Samples pre-drawn per refill of a blockable stream. Small enough
/// that a short run never draws far ahead of what it consumes, large
/// enough to amortise the per-call dispatch on failure-heavy paths.
const FAILURE_BLOCK: usize = 32;

/// Block-drawing wrapper over a [`FailureStream`].
///
/// The exponential samplers draw *gaps* that do not depend on `now`
/// (`at = now + rng.exponential(mtbf)`), so their samples can be drawn
/// in blocks ahead of consumption: the PCG draw **order is unchanged**
/// (samples are consumed in exactly the order they are drawn, and each
/// `(gap, node)` pair is drawn in the same within-event order as the
/// on-demand sampler), only the wall-clock moment of the draw moves.
/// `at = now + gap` is then the same f64 expression the stream
/// evaluates, so events are bit-identical — `buffered_failures_are_
/// bit_identical_to_on_demand` pins this per variant.
///
/// Now-dependent samplers (the Lewis–Shedler [`FailureStream::Thinned`]
/// envelope, whose acceptance draws depend on the proposal time, and
/// [`FailureStream::PerNodeRenewal`], whose heap consumption depends on
/// how far the engine fast-forwarded) pass through on demand, draw for
/// draw.
pub(crate) struct BufferedFailures {
    inner: FailureStream,
    /// Pre-drawn `(gap, node)` samples; refilled in place (the
    /// allocation happens once, at construction).
    buf: Vec<(f64, usize)>,
    pos: usize,
    blockable: bool,
}

impl BufferedFailures {
    pub(crate) fn new(inner: FailureStream) -> Self {
        let blockable = matches!(
            inner,
            FailureStream::Exponential { .. } | FailureStream::AggregateAttributed { .. }
        );
        BufferedFailures {
            inner,
            buf: Vec::with_capacity(if blockable { FAILURE_BLOCK } else { 0 }),
            pos: 0,
            blockable,
        }
    }

    fn refill(&mut self) {
        self.buf.clear();
        self.pos = 0;
        match &mut self.inner {
            FailureStream::Exponential { mtbf, rng } => {
                for _ in 0..FAILURE_BLOCK {
                    self.buf.push((rng.exponential(*mtbf), 0));
                }
            }
            FailureStream::AggregateAttributed { mtbf, n, rng } => {
                for _ in 0..FAILURE_BLOCK {
                    let gap = rng.exponential(*mtbf);
                    let node = rng.below(*n as u64) as usize;
                    self.buf.push((gap, node));
                }
            }
            _ => unreachable!("refill is only reachable for blockable streams"),
        }
    }
}

impl FailureSource for BufferedFailures {
    fn next_after(&mut self, now: f64) -> Failure {
        if !self.blockable {
            return self.inner.next_after(now);
        }
        if self.pos == self.buf.len() {
            self.refill();
        }
        let (gap, node) = self.buf[self.pos];
        self.pos += 1;
        Failure { at: now + gap, node }
    }
}

/// Lanczos approximation of Γ(x) for x > 0 (used for Weibull means).
pub fn gamma(x: f64) -> f64 {
    // g = 7, n = 9 Lanczos coefficients.
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        std::f64::consts::PI / ((std::f64::consts::PI * x).sin() * gamma(1.0 - x))
    } else {
        let x = x - 1.0;
        let mut a = COEF[0];
        let t = x + G + 0.5;
        for (i, &c) in COEF.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        (2.0 * std::f64::consts::PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::OnlineStats;

    #[test]
    fn gamma_known_values() {
        assert!((gamma(1.0) - 1.0).abs() < 1e-10);
        assert!((gamma(2.0) - 1.0).abs() < 1e-10);
        assert!((gamma(5.0) - 24.0).abs() < 1e-8);
        assert!((gamma(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-10);
        assert!((gamma(1.5) - std::f64::consts::PI.sqrt() / 2.0).abs() < 1e-10);
    }

    fn mean_interarrival(proc: &FailureProcess, events: usize, seed: u64) -> f64 {
        let mut rng = Pcg64::seeded(seed);
        let mut stream = proc.stream(&mut rng);
        let mut stats = OnlineStats::new();
        let mut now = 0.0;
        for _ in 0..events {
            let f = stream.next_after(now);
            stats.push(f.at - now);
            now = f.at;
        }
        stats.mean()
    }

    #[test]
    fn exponential_stream_mtbf() {
        let p = FailureProcess::Exponential { mtbf: 120.0 };
        let m = mean_interarrival(&p, 100_000, 1);
        assert!((m - 120.0).abs() / 120.0 < 0.02, "m={m}");
    }

    #[test]
    fn buffered_failures_are_bit_identical_to_on_demand() {
        // Every process family, including non-blockable ones (thinned,
        // per-node renewal) which must pass straight through. Arrival
        // times are advanced irregularly (by fractions of the gap) so
        // now-dependence would surface as a divergence.
        let procs = [
            FailureProcess::Exponential { mtbf: 120.0 },
            FailureProcess::PerNodeExponential { n: 100, mtbf_ind: 12_000.0 },
            FailureProcess::PerNodeWeibull { n: 8, shape: 0.7, scale_ind: 1200.0 },
        ];
        for p in procs {
            for seed in [1u64, 7, 42] {
                let mut rng_a = Pcg64::seeded(seed);
                let mut direct = p.stream(&mut rng_a);
                let mut rng_b = Pcg64::seeded(seed);
                let mut buffered = BufferedFailures::new(p.stream(&mut rng_b));
                let (mut now_a, mut now_b) = (0.0f64, 0.0f64);
                for step in 0..200 {
                    let a = direct.next_after(now_a);
                    let b = buffered.next_after(now_b);
                    assert_eq!(a.at.to_bits(), b.at.to_bits(), "{p:?} seed {seed} step {step}");
                    assert_eq!(a.node, b.node, "{p:?} seed {seed} step {step}");
                    let frac = 0.25 + 0.5 * ((step % 3) as f64 / 2.0);
                    now_a += (a.at - now_a) * frac;
                    now_b = now_a;
                }
            }
        }
    }

    #[test]
    fn per_node_exponential_aggregates_to_mu_ind_over_n() {
        let p = FailureProcess::PerNodeExponential { n: 1000, mtbf_ind: 120_000.0 };
        assert!((p.platform_mtbf() - 120.0).abs() < 1e-9);
        let m = mean_interarrival(&p, 100_000, 2);
        assert!((m - 120.0).abs() / 120.0 < 0.02, "m={m}");
    }

    #[test]
    fn per_node_attribution_covers_nodes() {
        let p = FailureProcess::PerNodeExponential { n: 16, mtbf_ind: 1600.0 };
        let mut rng = Pcg64::seeded(3);
        let mut stream = p.stream(&mut rng);
        let mut seen = vec![false; 16];
        let mut now = 0.0;
        for _ in 0..2000 {
            let f = stream.next_after(now);
            seen[f.node] = true;
            now = f.at;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weibull_platform_mtbf_matches_simulation() {
        let p = FailureProcess::PerNodeWeibull { n: 50, shape: 0.7, scale_ind: 5000.0 };
        let predicted = p.platform_mtbf();
        // Long-run renewal rate: simulate plenty of events.
        let m = mean_interarrival(&p, 200_000, 4);
        assert!(
            (m - predicted).abs() / predicted < 0.05,
            "sim={m} predicted={predicted}"
        );
    }

    #[test]
    fn weibull_shape1_matches_exponential_mtbf() {
        let p = FailureProcess::PerNodeWeibull { n: 10, shape: 1.0, scale_ind: 1000.0 };
        assert!((p.platform_mtbf() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn events_strictly_increase() {
        for p in [
            FailureProcess::Exponential { mtbf: 10.0 },
            FailureProcess::PerNodeExponential { n: 4, mtbf_ind: 40.0 },
            FailureProcess::PerNodeWeibull { n: 4, shape: 0.8, scale_ind: 40.0 },
        ] {
            let mut rng = Pcg64::seeded(5);
            let mut stream = p.stream(&mut rng);
            let mut now = 0.0;
            for _ in 0..5000 {
                let f = stream.next_after(now);
                assert!(f.at > now, "{p:?}");
                now = f.at;
            }
        }
    }

    #[test]
    fn drifting_process_with_stationary_mu_is_bit_identical_to_exponential() {
        use crate::config::presets::fig1_scenario;
        use crate::drift::{DriftProcess, DriftTargets, EnvTrajectory};
        let s = fig1_scenario(300.0, 5.5);
        // C drifts, μ does not: the sampler must fall back to the plain
        // homogeneous stream with the same split tag.
        let drift = DriftProcess::Ramp {
            from_t: 0.0,
            to_t: 5000.0,
            to: DriftTargets { c: 2.0, r: 2.0, mu: 1.0, p_io: 2.0 },
        };
        let traj = EnvTrajectory::new(s, drift).unwrap();
        let drifting = FailureProcess::DriftingExponential { trajectory: traj };
        let paper = FailureProcess::Exponential { mtbf: s.mu };
        let mut rng_a = Pcg64::seeded(9);
        let mut rng_b = Pcg64::seeded(9);
        let mut a = drifting.stream(&mut rng_a);
        let mut b = paper.stream(&mut rng_b);
        let mut now = 0.0;
        for _ in 0..200 {
            let fa = a.next_after(now);
            let fb = b.next_after(now);
            assert_eq!(fa.at.to_bits(), fb.at.to_bits());
            now = fa.at;
        }
        assert_eq!(drifting.platform_mtbf(), 300.0);
    }

    #[test]
    fn thinned_sampler_matches_piecewise_constant_rates() {
        use crate::config::presets::fig1_scenario;
        use crate::drift::{DriftProcess, DriftTargets, EnvTrajectory};
        // μ steps from 300 to 150 at t = 50_000: the empirical rate on
        // each side must match the local exponential rate.
        let s = fig1_scenario(300.0, 5.5);
        let drift = DriftProcess::Step {
            at: 50_000.0,
            to: DriftTargets { c: 1.0, r: 1.0, mu: 0.5, p_io: 1.0 },
        };
        let traj = EnvTrajectory::new(s, drift).unwrap();
        let p = FailureProcess::DriftingExponential { trajectory: traj };
        let mut rng = Pcg64::seeded(11);
        let mut stream = p.stream(&mut rng);
        let (mut before, mut after) = (0u64, 0u64);
        let mut now = 0.0;
        while now < 100_000.0 {
            let f = stream.next_after(now);
            assert!(f.at > now);
            now = f.at;
            if now < 50_000.0 {
                before += 1;
            } else if now < 100_000.0 {
                after += 1;
            }
        }
        // Expected ≈ 50_000/300 ≈ 167 and 50_000/150 ≈ 333.
        let (b, a) = (before as f64, after as f64);
        assert!((b - 166.7).abs() < 40.0, "before={before}");
        assert!((a - 333.3).abs() < 60.0, "after={after}");
        assert!(a > 1.5 * b, "rate did not double: {before} -> {after}");
    }

    #[test]
    fn thinned_sampler_tracks_a_ramp_in_law() {
        use crate::config::presets::fig1_scenario;
        use crate::drift::{DriftProcess, DriftTargets, EnvTrajectory};
        // μ ramps 300 → 120 over [0, 20_000], then holds: the total
        // count over [0, 40_000] must match ∫ λ(t) dt.
        let s = fig1_scenario(300.0, 5.5);
        let drift = DriftProcess::Ramp {
            from_t: 0.0,
            to_t: 20_000.0,
            to: DriftTargets { c: 1.0, r: 1.0, mu: 0.4, p_io: 1.0 },
        };
        let traj = EnvTrajectory::new(s, drift).unwrap();
        let p = FailureProcess::DriftingExponential { trajectory: traj };
        // ∫λ over the ramp: ∫ dt/μ(t), μ(t) = 300 − 9t/1000 for t in
        // [0, 20_000] → (1000/9)·ln(300/120) ≈ 101.8; plus 20_000/120.
        let expect = 1000.0 / 9.0 * (300.0f64 / 120.0).ln() + 20_000.0 / 120.0;
        let mut total = 0.0f64;
        let replicates = 40;
        for seed in 0..replicates {
            let mut rng = Pcg64::seeded(100 + seed);
            let mut stream = p.stream(&mut rng);
            let mut now = 0.0;
            loop {
                let f = stream.next_after(now);
                if f.at >= 40_000.0 {
                    break;
                }
                now = f.at;
                total += 1.0;
            }
        }
        let mean = total / replicates as f64;
        assert!(
            (mean - expect).abs() / expect < 0.05,
            "mean={mean} expect={expect}"
        );
    }

    #[test]
    fn next_after_skips_stale_renewals() {
        // Jump far ahead: per-node renewal must discard old events.
        let p = FailureProcess::PerNodeWeibull { n: 8, shape: 1.0, scale_ind: 10.0 };
        let mut rng = Pcg64::seeded(6);
        let mut stream = p.stream(&mut rng);
        let f = stream.next_after(1000.0);
        assert!(f.at > 1000.0);
    }
}
