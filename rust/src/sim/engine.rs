//! Single-run event loop: executes one sample path of the checkpointed
//! application and integrates wall-clock time and energy exactly.
//!
//! Semantics (matching §2–§3 of the paper):
//!
//! * A period of length `T` is `T−C` of pure compute (work rate 1)
//!   followed by a checkpoint of length `C` during which the work rate is
//!   `ω` and the I/O system is active.
//! * A completed checkpoint captures the progress at its *start*; the
//!   `ωC` work units executed while it was being written are only covered
//!   by the *next* checkpoint (this is why each failure additionally
//!   costs `ωC` re-execution in the paper's analysis).
//! * A failure interrupts the current phase, discards everything since
//!   the last completed checkpoint's cut point, then costs a downtime `D`
//!   (power `P_Static + P_Down`) and a recovery `R` (power
//!   `P_Static + P_IO`), after which a fresh period starts.
//! * Power states: compute ⇒ `P_Static + P_Cal`; checkpoint ⇒
//!   `P_Static + ω·P_Cal + P_IO` (CPU does `ω` work-units per unit time,
//!   I/O streams the checkpoint); recovery ⇒ `P_Static + P_IO`;
//!   downtime ⇒ `P_Static + P_Down`. These integrate to exactly the
//!   paper's `T_Cal`, `T_IO`, `T_Down` decomposition in expectation.
//! * The run ends the instant cumulative executed work reaches `T_base`
//!   (no checkpoint is taken after the final work unit).
//!
//! The engine is allocation-free after construction: one loop, a few
//! floats — ~50 ns per simulated period (see `benches/micro_simulator`).

use super::failure::{FailureProcess, FailureSource};
use crate::model::params::Scenario;
use crate::storage::{CopyRecord, TierHierarchy, TierStore};
use crate::util::rng::Pcg64;

/// Configuration of a simulation.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub scenario: Scenario,
    /// Checkpointing period `T` to simulate.
    pub period: f64,
    pub failure: FailureProcess,
    /// If `true` (default, realistic), failures can also strike during
    /// downtime/recovery, restarting them. The paper's first-order model
    /// ignores this; at `μ ≫ D+R` the difference is second-order.
    pub failures_during_recovery: bool,
}

impl SimConfig {
    /// Config with the paper's aggregate-exponential failure process.
    pub fn paper(scenario: Scenario, period: f64) -> Self {
        SimConfig {
            scenario,
            period,
            failure: FailureProcess::Exponential { mtbf: scenario.mu },
            failures_during_recovery: true,
        }
    }
}

/// Outcome of one simulated execution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RunResult {
    /// Total wall-clock time (the sample of `T_final`).
    pub makespan: f64,
    /// Total energy (the sample of `E_final`).
    pub energy: f64,
    pub n_failures: u64,
    pub n_checkpoints: u64,
    /// Work units discarded by failures.
    pub work_lost: f64,
    /// Wall-clock time per power state.
    pub time_compute: f64,
    pub time_checkpoint: f64,
    pub time_recovery: f64,
    pub time_down: f64,
}

impl RunResult {
    /// CPU-seconds at `P_Cal` (the paper's `T_Cal`): full-rate compute
    /// plus the `ω` fraction of checkpoint wall time.
    pub fn t_cal(&self, omega: f64) -> f64 {
        self.time_compute + omega * self.time_checkpoint
    }

    /// I/O-seconds at `P_IO` (the paper's `T_IO`).
    pub fn t_io(&self) -> f64 {
        self.time_checkpoint + self.time_recovery
    }
}

/// The simulator. Construct once, run many seeds.
#[derive(Debug, Clone)]
pub struct Simulator {
    cfg: SimConfig,
}

/// What ended a phase.
pub(crate) enum PhaseEnd {
    /// Phase ran its full planned length.
    Ran,
    /// The application's last work unit completed at the returned
    /// in-phase offset.
    Finished(f64),
    /// A failure struck at the returned in-phase offset.
    Failed(f64),
}

/// Phase outcome for a phase of `len` wall time during which `need`
/// work remains and work accrues at `rate`. Shared with the batched
/// lockstep executor ([`super::batch`]); the closures inside
/// [`Simulator::run`]/[`Simulator::run_tiered`] keep their own verbatim
/// copies so the scalar reference loops stay byte-for-byte untouched —
/// the math here is identical, expression for expression.
pub(crate) fn phase_end(now: f64, len: f64, need: f64, rate: f64, fail_at: f64) -> PhaseEnd {
    let finish = if rate > 0.0 && need / rate <= len {
        Some(need / rate)
    } else {
        None
    };
    let fail = if fail_at < now + len { Some(fail_at - now) } else { None };
    match (finish, fail) {
        (Some(f), Some(x)) if f <= x => PhaseEnd::Finished(f),
        (_, Some(x)) => PhaseEnd::Failed(x),
        (Some(f), None) => PhaseEnd::Finished(f),
        (None, None) => PhaseEnd::Ran,
    }
}

impl Simulator {
    pub fn new(cfg: SimConfig) -> Self {
        assert!(
            cfg.period >= cfg.scenario.min_period(),
            "period {} shorter than checkpoint {}",
            cfg.period,
            cfg.scenario.ckpt.c
        );
        Simulator { cfg }
    }

    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// Execute one sample path.
    ///
    /// Tiered scenarios take the drain-queue event loop
    /// ([`Self::run_tiered`]); scalar scenarios run the original loop
    /// below, untouched by the hierarchy refactor.
    pub fn run(&self, seed: u64) -> RunResult {
        if let Some(h) = self.cfg.scenario.hierarchy() {
            return self.run_tiered(seed, h);
        }
        let s = &self.cfg.scenario;
        let t_period = self.cfg.period;
        let c = s.ckpt.c;
        let (d, r) = (s.ckpt.d, s.ckpt.r);
        let omega = s.ckpt.omega;
        let compute_len = t_period - c;

        let mut rng = Pcg64::seeded(seed);
        let mut stream = self.cfg.failure.stream(&mut rng);

        let mut res = RunResult {
            makespan: 0.0,
            energy: 0.0,
            n_failures: 0,
            n_checkpoints: 0,
            work_lost: 0.0,
            time_compute: 0.0,
            time_checkpoint: 0.0,
            time_recovery: 0.0,
            time_down: 0.0,
        };

        let mut now = 0.0f64;
        // Work captured by the last completed checkpoint.
        let mut saved = 0.0f64;
        // Work done during that checkpoint (not yet covered by any ckpt).
        let mut overlap = 0.0f64;
        let mut next_fail = stream.next_after(0.0);

        // Returns the phase outcome for a phase of `len` wall time during
        // which `need` work remains and work accrues at `rate`.
        let phase_end = |now: f64, len: f64, need: f64, rate: f64, fail_at: f64| -> PhaseEnd {
            let finish = if rate > 0.0 && need / rate <= len {
                Some(need / rate)
            } else {
                None
            };
            let fail = if fail_at < now + len { Some(fail_at - now) } else { None };
            match (finish, fail) {
                (Some(f), Some(x)) if f <= x => PhaseEnd::Finished(f),
                (_, Some(x)) => PhaseEnd::Failed(x),
                (Some(f), None) => PhaseEnd::Finished(f),
                (None, None) => PhaseEnd::Ran,
            }
        };

        loop {
            // ---- compute phase (rate 1, power static+cal) ----
            let base_progress = saved + overlap;
            let need = s.t_base - base_progress;
            debug_assert!(need > 0.0);
            match phase_end(now, compute_len, need, 1.0, next_fail.at) {
                PhaseEnd::Finished(dt) => {
                    res.time_compute += dt;
                    now += dt;
                    break;
                }
                PhaseEnd::Failed(dt) => {
                    res.time_compute += dt;
                    now += dt;
                    res.work_lost += overlap + dt;
                    overlap = 0.0;
                    self.fail_and_recover(&mut res, &mut now, &mut next_fail, &mut stream, d, r);
                    continue;
                }
                PhaseEnd::Ran => {
                    res.time_compute += compute_len;
                    now += compute_len;
                }
            }

            // ---- checkpoint phase (rate ω, power static+ω·cal+io) ----
            let at_ckpt_start = base_progress + compute_len;
            let need = s.t_base - at_ckpt_start;
            match phase_end(now, c, need, omega, next_fail.at) {
                PhaseEnd::Finished(dt) => {
                    res.time_checkpoint += dt;
                    now += dt;
                    break;
                }
                PhaseEnd::Failed(dt) => {
                    res.time_checkpoint += dt;
                    now += dt;
                    res.work_lost += overlap + compute_len + omega * dt;
                    overlap = 0.0;
                    self.fail_and_recover(&mut res, &mut now, &mut next_fail, &mut stream, d, r);
                    continue;
                }
                PhaseEnd::Ran => {
                    res.time_checkpoint += c;
                    now += c;
                    res.n_checkpoints += 1;
                    saved = at_ckpt_start;
                    overlap = omega * c;
                }
            }
        }

        res.makespan = now;
        let p = &s.power;
        res.energy = p.p_static * res.makespan
            + p.p_cal * (res.time_compute + omega * res.time_checkpoint)
            + p.p_io * (res.time_checkpoint + res.time_recovery)
            + p.p_down * res.time_down;
        res
    }

    /// Handle the downtime + recovery after a failure, including failures
    /// that strike *during* recovery when configured. Generic over the
    /// failure source so the scalar reference loop (plain stream) and
    /// the batched executor (block-drawing wrapper) monomorphise to the
    /// same body.
    pub(crate) fn fail_and_recover<S: FailureSource>(
        &self,
        res: &mut RunResult,
        now: &mut f64,
        next_fail: &mut super::failure::Failure,
        stream: &mut S,
        d: f64,
        r: f64,
    ) {
        res.n_failures += 1;
        *next_fail = stream.next_after(*now);
        loop {
            let d_end = *now + d;
            let r_end = d_end + r;
            if self.cfg.failures_during_recovery && next_fail.at < r_end {
                // Failure mid-downtime or mid-recovery: account the
                // partial phases, then restart D + R.
                let fail_at = next_fail.at;
                if fail_at < d_end {
                    res.time_down += fail_at - *now;
                } else {
                    res.time_down += d;
                    res.time_recovery += fail_at - d_end;
                }
                *now = fail_at;
                res.n_failures += 1;
                *next_fail = stream.next_after(*now);
                continue;
            }
            res.time_down += d;
            res.time_recovery += r;
            *now = r_end;
            // With failures disabled during D + R, an event that landed
            // inside the window would otherwise fire *retroactively* in
            // the next phase (a negative in-phase offset: time ran
            // backwards and the failure struck anyway). The process is
            // suspended during recovery instead, so redraw past the
            // recovery end (exact for the memoryless exponential).
            if !self.cfg.failures_during_recovery && next_fail.at < *now {
                *next_fail = stream.next_after(*now);
            }
            return;
        }
    }

    /// One sample path over a storage hierarchy.
    ///
    /// Differences from the scalar loop:
    ///
    /// * Every completed checkpoint lands a tier-0 copy; every
    ///   `κ_i`-th checkpoint schedules an asynchronous **drain** to
    ///   tier `i` on a serialised drain device (one transfer at a
    ///   time; deeper drains chain off the shallower copy's landing).
    ///   The cadence vector is the energy-minimising plan at this
    ///   period ([`crate::model::tiers::cadence_for`]) — a pure
    ///   function of the config, so thread-count determinism holds.
    /// * Drains overlap compute: they cost energy
    ///   (`P_IO_i · C_i` when complete, pro-rated when a failure or
    ///   the end of the run aborts them) but no wall time.
    /// * A failure is a node loss: tier-0 copies are destroyed and
    ///   in-flight drains abort. Recovery restarts from the freshest
    ///   surviving copy (drain completed before the failure), reading
    ///   `R_j` minutes at `P_IO_j`; with no surviving copy the run
    ///   restarts from scratch after the downtime, with no recovery
    ///   read.
    /// * Per-tier retention/capacity evicts old copies, never the
    ///   freshest and never the source of an in-flight drain.
    fn run_tiered(&self, seed: u64, h: &TierHierarchy) -> RunResult {
        let s = &self.cfg.scenario;
        let t_period = self.cfg.period;
        let c = s.ckpt.c; // tier-0 write cost (effective projection)
        let d = s.ckpt.d;
        let omega = s.ckpt.omega;
        let compute_len = t_period - c;
        let kappa = crate::model::tiers::cadence_for(s, h, t_period);

        let mut rng = Pcg64::seeded(seed);
        let mut stream = self.cfg.failure.stream(&mut rng);

        let mut res = RunResult {
            makespan: 0.0,
            energy: 0.0,
            n_failures: 0,
            n_checkpoints: 0,
            work_lost: 0.0,
            time_compute: 0.0,
            time_checkpoint: 0.0,
            time_recovery: 0.0,
            time_down: 0.0,
        };

        let mut store = TierStore::new(h);
        let mut inflight: Vec<Drain> = Vec::new();
        let mut drain_free_at = 0.0f64;
        // I/O energy priced per tier (drains + recovery reads); the
        // blanket `p_io` at the end only covers tier-0 writes.
        let mut drain_energy = 0.0f64;
        let mut recovery_io_energy = 0.0f64;
        // Pin-set scratch, reused across every settle (values are
        // rebuilt in place — no per-event allocation).
        let mut pinned: Vec<f64> = Vec::new();

        let mut now = 0.0f64;
        let mut saved = 0.0f64;
        let mut overlap = 0.0f64;
        let mut next_fail = stream.next_after(0.0);

        let phase_end = |now: f64, len: f64, need: f64, rate: f64, fail_at: f64| -> PhaseEnd {
            let finish = if rate > 0.0 && need / rate <= len {
                Some(need / rate)
            } else {
                None
            };
            let fail = if fail_at < now + len { Some(fail_at - now) } else { None };
            match (finish, fail) {
                (Some(f), Some(x)) if f <= x => PhaseEnd::Finished(f),
                (_, Some(x)) => PhaseEnd::Failed(x),
                (Some(f), None) => PhaseEnd::Finished(f),
                (None, None) => PhaseEnd::Ran,
            }
        };

        loop {
            // ---- compute phase ----
            let base_progress = saved + overlap;
            let need = s.t_base - base_progress;
            debug_assert!(need > 0.0);
            match phase_end(now, compute_len, need, 1.0, next_fail.at) {
                PhaseEnd::Finished(dt) => {
                    res.time_compute += dt;
                    now += dt;
                    break;
                }
                PhaseEnd::Failed(dt) => {
                    res.time_compute += dt;
                    now += dt;
                    let progress = base_progress + dt;
                    self.tiered_failure(
                        &mut res,
                        &mut now,
                        &mut next_fail,
                        &mut stream,
                        h,
                        &mut store,
                        &mut inflight,
                        &mut drain_free_at,
                        &mut drain_energy,
                        &mut recovery_io_energy,
                        d,
                        progress,
                        &mut saved,
                        &mut overlap,
                        &mut pinned,
                    );
                    continue;
                }
                PhaseEnd::Ran => {
                    res.time_compute += compute_len;
                    now += compute_len;
                }
            }

            // ---- checkpoint phase (synchronous tier-0 write) ----
            let at_ckpt_start = base_progress + compute_len;
            let need = s.t_base - at_ckpt_start;
            match phase_end(now, c, need, omega, next_fail.at) {
                PhaseEnd::Finished(dt) => {
                    res.time_checkpoint += dt;
                    now += dt;
                    break;
                }
                PhaseEnd::Failed(dt) => {
                    res.time_checkpoint += dt;
                    now += dt;
                    let progress = at_ckpt_start + omega * dt;
                    self.tiered_failure(
                        &mut res,
                        &mut now,
                        &mut next_fail,
                        &mut stream,
                        h,
                        &mut store,
                        &mut inflight,
                        &mut drain_free_at,
                        &mut drain_energy,
                        &mut recovery_io_energy,
                        d,
                        progress,
                        &mut saved,
                        &mut overlap,
                        &mut pinned,
                    );
                    continue;
                }
                PhaseEnd::Ran => {
                    res.time_checkpoint += c;
                    now += c;
                    res.n_checkpoints += 1;
                    saved = at_ckpt_start;
                    overlap = omega * c;
                    // Completed drains land their copies before new
                    // pins are computed.
                    settle_drains_with(
                        &mut inflight,
                        &mut store,
                        &mut drain_energy,
                        h,
                        now,
                        false,
                        &mut pinned,
                    );
                    pinned.clear();
                    pinned.extend(inflight.iter().map(|dr| dr.work));
                    store.record(
                        0,
                        CopyRecord { work: at_ckpt_start, available_at: now },
                        &pinned,
                    );
                    // Chain drains: tier i sources the tier i-1 copy.
                    let idx = res.n_checkpoints;
                    let mut source_ready = now;
                    for tier in 1..h.len() {
                        if idx % kappa[tier] as u64 != 0 {
                            break; // nested divisibility: deeper drains align
                        }
                        let start = drain_free_at.max(source_ready);
                        let end = start + h.tier(tier).c;
                        drain_free_at = end;
                        source_ready = end;
                        inflight.push(Drain { tier, work: at_ckpt_start, start, end });
                    }
                }
            }
        }

        // End of run: completed drains land (energy), in-flight ones
        // abort with pro-rated energy.
        settle_drains_with(&mut inflight, &mut store, &mut drain_energy, h, now, true, &mut pinned);

        res.makespan = now;
        let p = &s.power;
        res.energy = p.p_static * res.makespan
            + p.p_cal * (res.time_compute + omega * res.time_checkpoint)
            + p.p_io * res.time_checkpoint
            + recovery_io_energy
            + p.p_down * res.time_down
            + drain_energy;
        res
    }

    /// Failure handling for the tiered loop: settle/abort drains, kill
    /// node-local copies, pick the restart tier, then run the
    /// downtime+recovery loop with that tier's read cost and power.
    /// `pinned` is caller-owned pin-set scratch (see
    /// [`settle_drains_with`]).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn tiered_failure<S: FailureSource>(
        &self,
        res: &mut RunResult,
        now: &mut f64,
        next_fail: &mut super::failure::Failure,
        stream: &mut S,
        h: &TierHierarchy,
        store: &mut TierStore,
        inflight: &mut Vec<Drain>,
        drain_free_at: &mut f64,
        drain_energy: &mut f64,
        recovery_io_energy: &mut f64,
        d: f64,
        progress_at_fail: f64,
        saved: &mut f64,
        overlap: &mut f64,
        pinned: &mut Vec<f64>,
    ) {
        let fail_at = *now;
        settle_drains_with(inflight, store, drain_energy, h, fail_at, true, pinned);
        *drain_free_at = fail_at;
        store.purge_node_local();
        let (r, p_io_r, restart_work) = match store.freshest_surviving(fail_at) {
            Some((tier, copy)) => (h.tier(tier).r, h.tier(tier).p_io, copy.work),
            // Nothing survives anywhere: restart from scratch after the
            // downtime, with no checkpoint to read.
            None => (0.0, 0.0, 0.0),
        };
        res.work_lost += progress_at_fail - restart_work;
        *saved = restart_work;
        *overlap = 0.0;

        res.n_failures += 1;
        *next_fail = stream.next_after(*now);
        loop {
            let d_end = *now + d;
            let r_end = d_end + r;
            if self.cfg.failures_during_recovery && next_fail.at < r_end {
                let fail_at = next_fail.at;
                if fail_at < d_end {
                    res.time_down += fail_at - *now;
                } else {
                    res.time_down += d;
                    let partial = fail_at - d_end;
                    res.time_recovery += partial;
                    *recovery_io_energy += p_io_r * partial;
                }
                *now = fail_at;
                res.n_failures += 1;
                *next_fail = stream.next_after(*now);
                continue;
            }
            res.time_down += d;
            res.time_recovery += r;
            *recovery_io_energy += p_io_r * r;
            *now = r_end;
            if !self.cfg.failures_during_recovery && next_fail.at < *now {
                *next_fail = stream.next_after(*now);
            }
            return;
        }
    }
}

/// An asynchronous tier-to-tier transfer in flight. Shared with the
/// adaptive simulator's tiered path ([`super::adaptive`]).
#[derive(Debug, Clone, Copy)]
pub(crate) struct Drain {
    /// Destination tier (sources the `tier - 1` copy of `work`).
    pub(crate) tier: usize,
    pub(crate) work: f64,
    pub(crate) start: f64,
    pub(crate) end: f64,
}

/// Land every drain that completed by `up_to` (full energy, copy
/// recorded). With `abort`, also charge pro-rated energy for drains the
/// cutoff interrupts and discard them (failure or end of run); without
/// it, later drains simply stay in flight.
///
/// `pinned` is a caller-owned pin-set scratch buffer: the simulators
/// and the batched executor reuse one allocation across every event
/// step. The buffer is cleared and rebuilt from the same expression an
/// allocating path would use, so the recorded values are identical.
#[allow(clippy::too_many_arguments)]
pub(crate) fn settle_drains_with(
    inflight: &mut Vec<Drain>,
    store: &mut TierStore,
    drain_energy: &mut f64,
    h: &TierHierarchy,
    up_to: f64,
    abort: bool,
    pinned: &mut Vec<f64>,
) {
    // Conservative pin set: any in-flight source work stays evictable
    // from no tier until the transfer settles.
    pinned.clear();
    pinned.extend(inflight.iter().map(|dr| dr.work));
    let mut i = 0;
    while i < inflight.len() {
        let dr = inflight[i];
        if dr.end <= up_to {
            *drain_energy += h.tier(dr.tier).p_io * (dr.end - dr.start);
            store.record(
                dr.tier,
                CopyRecord { work: dr.work, available_at: dr.end },
                &pinned,
            );
            inflight.remove(i);
        } else if abort {
            if dr.start < up_to {
                *drain_energy += h.tier(dr.tier).p_io * (up_to - dr.start);
            }
            inflight.remove(i);
        } else {
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{CheckpointParams, PowerParams, Scenario};
    use crate::util::stats::rel_err;

    fn scenario(mu: f64, omega: f64, t_base: f64) -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, omega).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        Scenario::new(ckpt, power, mu, t_base).unwrap()
    }

    /// A failure process that never fires (for failure-free checks).
    fn no_failures() -> FailureProcess {
        FailureProcess::Exponential { mtbf: 1e18 }
    }

    #[test]
    fn failure_free_matches_t_ff_blocking() {
        // omega=0, T=100, C=10: work per period 90; T_base=9000 => exactly
        // 100 periods; the last period needs no trailing checkpoint.
        let s = scenario(1e18, 0.0, 9000.0);
        let sim = Simulator::new(SimConfig {
            scenario: s,
            period: 100.0,
            failure: no_failures(),
            failures_during_recovery: true,
        });
        let res = sim.run(1);
        assert_eq!(res.n_failures, 0);
        // 99 full periods (with checkpoints) + 90 compute = 9990 — one C
        // less than T_ff's 100*T/(T-C) = 10000 (model checkpoints the
        // last period too).
        assert!((res.makespan - 9990.0).abs() < 1e-6, "makespan={}", res.makespan);
        assert_eq!(res.n_checkpoints, 99);
        assert!((res.time_compute - 9000.0).abs() < 1e-6);
        assert!((res.time_checkpoint - 990.0).abs() < 1e-6);
        assert_eq!(res.work_lost, 0.0);
    }

    #[test]
    fn failure_free_overlap_accounts_omega() {
        // omega=1/2, T=100, C=10: work per period = 95.
        let s = scenario(1e18, 0.5, 9500.0);
        let sim = Simulator::new(SimConfig {
            scenario: s,
            period: 100.0,
            failure: no_failures(),
            failures_during_recovery: true,
        });
        let res = sim.run(1);
        // 99 full periods = 99*95 = 9405 work, 9900 time; remaining 95
        // work = 90 compute + 5/0.5=10 ckpt time => finishes exactly at
        // the end of period 100's checkpoint.
        assert!((res.makespan - 10000.0).abs() < 1e-6, "makespan={}", res.makespan);
    }

    #[test]
    fn finishes_mid_compute_without_checkpoint() {
        let s = scenario(1e18, 0.5, 50.0);
        let sim = Simulator::new(SimConfig {
            scenario: s,
            period: 100.0,
            failure: no_failures(),
            failures_during_recovery: true,
        });
        let res = sim.run(1);
        assert_eq!(res.n_checkpoints, 0);
        assert!((res.makespan - 50.0).abs() < 1e-9);
        assert!((res.energy - 50.0 * (10.0 + 10.0)).abs() < 1e-6);
    }

    #[test]
    fn deterministic_per_seed() {
        let s = scenario(200.0, 0.5, 5000.0);
        let sim = Simulator::new(SimConfig::paper(s, 80.0));
        let a = sim.run(42);
        let b = sim.run(42);
        assert_eq!(a, b);
        let c = sim.run(43);
        assert_ne!(a, c);
    }

    #[test]
    fn energy_identity() {
        let s = scenario(150.0, 0.5, 5000.0);
        let sim = Simulator::new(SimConfig::paper(s, 70.0));
        for seed in 0..20 {
            let res = sim.run(seed);
            let p = &s.power;
            let manual = p.p_static * res.makespan
                + p.p_cal * res.t_cal(0.5)
                + p.p_io * res.t_io()
                + p.p_down * res.time_down;
            assert!(rel_err(res.energy, manual) < 1e-12);
            // Makespan is the sum of phase wall times.
            let total = res.time_compute
                + res.time_checkpoint
                + res.time_recovery
                + res.time_down;
            assert!(rel_err(res.makespan, total) < 1e-12, "seed={seed}");
        }
    }

    #[test]
    fn failures_cost_work_and_time() {
        let s = scenario(100.0, 0.5, 5000.0);
        let sim = Simulator::new(SimConfig::paper(s, 60.0));
        let res = sim.run(7);
        assert!(res.n_failures > 10, "n_failures={}", res.n_failures);
        assert!(res.work_lost > 0.0);
        assert!(res.makespan > 5000.0);
        assert!(res.time_down > 0.0 && res.time_recovery > 0.0);
    }

    #[test]
    fn more_failures_with_smaller_mtbf() {
        let mk = |mu: f64| {
            let s = scenario(mu, 0.5, 20_000.0);
            Simulator::new(SimConfig::paper(s, 80.0)).run(11)
        };
        assert!(mk(50.0).n_failures > mk(500.0).n_failures);
    }

    #[test]
    fn recovery_failures_toggle() {
        // With a tiny MTBF comparable to D+R, allowing failures during
        // recovery must increase the failure count.
        let s = scenario(40.0, 0.0, 2000.0);
        let mut cfg = SimConfig::paper(s, 50.0);
        cfg.failures_during_recovery = false;
        let without = Simulator::new(cfg.clone()).run(3);
        cfg.failures_during_recovery = true;
        let with = Simulator::new(cfg).run(3);
        assert!(with.n_failures >= without.n_failures);
    }

    #[test]
    fn suspended_recovery_failures_do_not_fire_retroactively() {
        // Regression: with failures_during_recovery = false, an event
        // landing inside the D + R window used to fire at a *negative*
        // in-phase offset in the next phase — time ran backwards and
        // the failure struck anyway, so the failure count tracked the
        // full makespan instead of the exposed (up) time. At μ = 40 and
        // D + R = 11 that inflates the count by ~25%.
        let s = scenario(40.0, 0.5, 2000.0);
        let mut cfg = SimConfig::paper(s, 50.0);
        cfg.failures_during_recovery = false;
        let sim = Simulator::new(cfg);
        let mut failures = 0.0;
        let mut exposed = 0.0;
        for seed in 0..20 {
            let res = sim.run(seed);
            failures += res.n_failures as f64;
            exposed += res.time_compute + res.time_checkpoint;
            // Work conservation still holds in this mode.
            let executed = res.time_compute + 0.5 * res.time_checkpoint;
            assert!(
                rel_err(executed, 2000.0 + res.work_lost) < 1e-9,
                "seed={seed}: executed={executed} vs {}",
                2000.0 + res.work_lost
            );
        }
        // Failures accrue only over exposed time: E[n] = exposed / μ.
        let expect = exposed / 40.0;
        assert!(
            rel_err(failures, expect) < 0.1,
            "failures={failures} expected≈{expect} (retroactive firing would give ~25% more)"
        );
    }

    #[test]
    #[should_panic(expected = "shorter than checkpoint")]
    fn rejects_period_below_c() {
        let s = scenario(200.0, 0.5, 1000.0);
        let _ = Simulator::new(SimConfig::paper(s, 5.0));
    }

    #[test]
    fn work_conservation() {
        // Executed work = t_base + work_lost (every executed unit is
        // either part of the final result or was lost to a failure).
        let s = scenario(120.0, 0.5, 8000.0);
        let sim = Simulator::new(SimConfig::paper(s, 70.0));
        for seed in 0..10 {
            let res = sim.run(seed);
            let executed = res.time_compute + 0.5 * res.time_checkpoint;
            assert!(
                rel_err(executed, 8000.0 + res.work_lost) < 1e-9,
                "seed={seed}: executed={executed} vs {}",
                8000.0 + res.work_lost
            );
        }
    }

    // ---- tiered storage paths ----

    use crate::storage::TierSpec;

    /// SSD (fast local) → PFS (slow, survives node loss).
    fn tiered_scenario(mu: f64, t_base: f64) -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        Scenario::with_tier_specs(
            ckpt,
            power,
            mu,
            t_base,
            &[TierSpec::new(1.0, 1.0, 30.0), TierSpec::new(10.0, 10.0, 100.0)],
        )
        .unwrap()
    }

    #[test]
    fn tiered_deterministic_per_seed() {
        let s = tiered_scenario(200.0, 5000.0);
        let sim = Simulator::new(SimConfig::paper(s, 80.0));
        let a = sim.run(42);
        let b = sim.run(42);
        assert_eq!(a, b);
        let c = sim.run(43);
        assert_ne!(a, c);
    }

    #[test]
    fn tiered_failure_free_drains_cost_energy_not_time() {
        // Without failures the tiered loop walks the same period
        // schedule as the scalar loop over the effective projection
        // (tier-0 write = C, same ω): identical makespan and phase
        // times, strictly more energy (the drains to deeper tiers).
        let s = tiered_scenario(1e18, 9_500.0);
        let flat = s.scalar_effective();
        let mk = |sc: Scenario| {
            Simulator::new(SimConfig {
                scenario: sc,
                period: 100.0,
                failure: no_failures(),
                failures_during_recovery: true,
            })
            .run(1)
        };
        let tiered = mk(s);
        let scalar = mk(flat);
        assert_eq!(tiered.n_failures, 0);
        assert!((tiered.makespan - scalar.makespan).abs() < 1e-9);
        assert!((tiered.time_compute - scalar.time_compute).abs() < 1e-9);
        assert!((tiered.time_checkpoint - scalar.time_checkpoint).abs() < 1e-9);
        assert!(
            tiered.energy > scalar.energy,
            "drain energy missing: tiered={} scalar={}",
            tiered.energy,
            scalar.energy
        );
    }

    #[test]
    fn tiered_work_conservation_under_failures() {
        let s = tiered_scenario(120.0, 8_000.0);
        let sim = Simulator::new(SimConfig::paper(s, 70.0));
        for seed in 0..10 {
            let res = sim.run(seed);
            let executed = res.time_compute + 0.5 * res.time_checkpoint;
            assert!(
                rel_err(executed, 8_000.0 + res.work_lost) < 1e-9,
                "seed={seed}: executed={executed} vs {}",
                8_000.0 + res.work_lost
            );
            // Makespan is still the sum of phase wall times (drains
            // overlap compute; they never add wall time).
            let total = res.time_compute
                + res.time_checkpoint
                + res.time_recovery
                + res.time_down;
            assert!(rel_err(res.makespan, total) < 1e-12, "seed={seed}");
        }
    }

    #[test]
    fn tiered_node_loss_restarts_from_drained_copy_or_zero() {
        // A drain so slow it can never complete before the next failure:
        // every node loss wipes tier 0 and finds nothing deeper, so each
        // failure restarts from scratch (no recovery read: R comes from
        // the *surviving* tier, and there is none).
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.0).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        let s = Scenario::with_tier_specs(
            ckpt,
            power,
            60.0,
            500.0,
            &[TierSpec::new(1.0, 1.0, 30.0), TierSpec::new(1e15, 10.0, 100.0)],
        )
        .unwrap();
        let sim = Simulator::new(SimConfig::paper(s, 50.0));
        let res = sim.run(5);
        assert!(res.n_failures > 0, "want at least one failure");
        assert_eq!(
            res.time_recovery, 0.0,
            "no surviving copy should mean no recovery read"
        );
        // Restart-from-zero loses *all* progress at each failure; with a
        // normal hierarchy (same seed, same failure process) the PFS
        // copies cap the losses.
        let normal = Simulator::new(SimConfig::paper(tiered_scenario(60.0, 500.0), 50.0)).run(5);
        assert!(
            res.work_lost >= normal.work_lost,
            "scratch restarts ({}) should lose at least as much as tiered recovery ({})",
            res.work_lost,
            normal.work_lost
        );
    }

    #[test]
    fn tiered_recovery_reads_survive_tier_pricing() {
        // With failures present and a working hierarchy, recovery reads
        // happen from the drained tier (R_1 = 10) even though the
        // effective tier-0 write is only C_0 = 1.
        let s = tiered_scenario(100.0, 4_000.0);
        let sim = Simulator::new(SimConfig::paper(s, 40.0));
        let mut saw_recovery = false;
        for seed in 0..20 {
            let res = sim.run(seed);
            if res.n_failures > 0 && res.time_recovery > 0.0 {
                saw_recovery = true;
                break;
            }
        }
        assert!(saw_recovery, "expected at least one recovery read from the PFS tier");
    }
}
