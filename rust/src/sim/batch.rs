//! Batched lockstep Monte-Carlo executor: the hot path behind
//! [`super::runner::monte_carlo`] and
//! [`super::adaptive::adaptive_monte_carlo`].
//!
//! # Why lockstep batching is bit-identical
//!
//! Replicates are fully independent: replicate `i` owns its RNG
//! (`Pcg64::seeded(base_seed + i)`), its failure stream, and its event
//! state, and nothing it computes feeds any other replicate. Advancing
//! B replicas in lockstep (one outer period-iteration per replica per
//! sweep) therefore *interleaves* their floating-point operations but
//! never changes any single replica's operation sequence — each
//! replica's result is bit-for-bit the result the per-replica loop
//! produces, and the index-ordered aggregation downstream is untouched.
//! `tests/batch_sim.rs` pins this against the `#[doc(hidden)]`
//! reference drivers across presets × backends × tier stacks × drift
//! families.
//!
//! What batching buys over the replica-at-a-time fan-out:
//!
//! * **Struct-of-arrays state.** The loop-carried scalars (clocks,
//!   saved/overlap work, next-failure events, per-replica accumulators)
//!   live in flat arrays indexed by slot, so a sweep over the block
//!   walks contiguous memory instead of chasing one replica's state
//!   through a full run before touching the next.
//! * **Block-drawn failure samples.** Gap-based streams pre-draw their
//!   exponential samples in blocks ([`BufferedFailures`]), amortising
//!   sampler dispatch; draw *order* per stream is unchanged, so the
//!   PR 5 seed contract (and the thinning envelope, which stays
//!   on-demand) is untouched.
//! * **Allocation-free event steps.** Per-slot drain queues retain
//!   their capacity and the pin-set scratch is one buffer per block
//!   ([`super::engine::settle_drains_with`]); steady-state stepping
//!   performs no heap traffic.
//! * **Coarser pool jobs.** One pool job runs a whole block, so the
//!   per-job scheduling overhead is paid once per B replicas.
//!
//! The per-replica scalar loops in [`super::engine`] / [`super::adaptive`]
//! remain the executable specification; the step functions here are
//! expression-for-expression transliterations of their loop bodies
//! (the recovery helpers are literally shared, monomorphised over
//! [`FailureSource`]).
//!
//! # Batch size
//!
//! The batch size is an execution-shape knob, never a result knob —
//! exactly like the thread count. [`set_batch_size`] installs a
//! process-wide override (the CLI's `--batch`); `auto` targets ~4 jobs
//! per pool participant, capped at [`MAX_AUTO_BATCH`] so a block's
//! working set stays cache-resident. The size in force is exported via
//! the `sim_batch_size` gauge.

use super::adaptive::{tiered_node_loss, AdaptiveRunResult, AdaptiveSimulator};
use super::engine::{
    phase_end, settle_drains_with, Drain, PhaseEnd, RunResult, SimConfig, Simulator,
};
use super::failure::{BufferedFailures, Failure, FailureSource};
use crate::coordinator::adaptive::AdaptiveController;
use crate::model::time::young;
use crate::storage::{CopyRecord, TierHierarchy, TierStore, MAX_TIERS};
use crate::telemetry::registry::metrics;
use crate::telemetry::trace;
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Upper bound on the *auto* batch size: beyond this the block's
/// struct-of-arrays working set stops fitting in cache and lockstep
/// sweeps lose their locality win. An explicit [`set_batch_size`]
/// override may exceed it.
pub const MAX_AUTO_BATCH: usize = 32;

/// Process-wide batch-size override; `0` means auto.
static BATCH_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Install (or clear, with `None`) the process-wide batch-size
/// override. Like `CKPT_POOL_THREADS`, this changes execution shape
/// only: replicas are independent and aggregated in replicate-index
/// order, so no value of the knob can change a result. `Some(0)` is
/// treated as auto.
pub fn set_batch_size(batch: Option<usize>) {
    BATCH_OVERRIDE.store(batch.unwrap_or(0), Ordering::Relaxed);
}

/// The batch size the executor will use for a `replicates`-sized call:
/// the override when set, otherwise ~4 jobs per pool participant capped
/// at [`MAX_AUTO_BATCH`]; never more than `replicates`.
pub fn effective_batch_size(replicates: usize) -> usize {
    let user = BATCH_OVERRIDE.load(Ordering::Relaxed);
    let b = if user > 0 {
        user
    } else {
        let participants = ThreadPool::global().n_workers() + 1;
        let per_job = replicates / (4 * participants);
        per_job.clamp(1, MAX_AUTO_BATCH)
    };
    b.min(replicates.max(1))
}

/// A block of replicas advancing in lockstep. `step` runs one outer
/// period-iteration of slot `i`'s event loop and reports whether the
/// replica finished.
trait Lockstep {
    fn slots(&self) -> usize;
    fn step(&mut self, i: usize) -> bool;
}

/// Sweep the block until every slot finishes. Slots are stepped in
/// slot (= replicate) order each sweep; finished slots drop out.
fn drive<M: Lockstep>(block: &mut M) {
    let mut live: Vec<usize> = (0..block.slots()).collect();
    while !live.is_empty() {
        live.retain(|&i| !block.step(i));
    }
}

const ZERO_RUN: RunResult = RunResult {
    makespan: 0.0,
    energy: 0.0,
    n_failures: 0,
    n_checkpoints: 0,
    work_lost: 0.0,
    time_compute: 0.0,
    time_checkpoint: 0.0,
    time_recovery: 0.0,
    time_down: 0.0,
};

/// Fan `replicates` out over `pool` in blocks of `batch`, preserving
/// replicate order (jobs are index-ordered and flattened in order).
fn fan_out<T: Send>(
    pool: &ThreadPool,
    replicates: usize,
    threads: usize,
    batch: usize,
    block_of: &(impl Fn(usize, usize) -> Vec<T> + Sync),
) -> Vec<T> {
    // Manual ceiling division: `usize::div_ceil` postdates the MSRV.
    let n_jobs = (replicates + batch - 1) / batch;
    let job = |j: usize| {
        let lo = j * batch;
        let hi = ((j + 1) * batch).min(replicates);
        block_of(lo, hi)
    };
    let threads = threads.clamp(1, replicates);
    let blocks: Vec<Vec<T>> = if threads == 1 || ThreadPool::in_worker() || n_jobs == 1 {
        (0..n_jobs).map(job).collect()
    } else {
        pool.map(n_jobs, job)
    };
    let mut out = Vec::with_capacity(replicates);
    for b in blocks {
        out.extend(b);
    }
    out
}

/// Run `replicates` fixed-period sample paths of `cfg` through the
/// lockstep executor. Replicate `i` simulates seed `base_seed + i`;
/// the returned vector is in replicate order and each element is
/// bit-identical to `Simulator::run(base_seed + i)`.
pub fn run_batched(
    cfg: &SimConfig,
    replicates: usize,
    base_seed: u64,
    threads: usize,
) -> Vec<RunResult> {
    run_batched_on(ThreadPool::global(), cfg, replicates, base_seed, threads)
}

/// [`run_batched`] on a caller-supplied pool. The serving bench's
/// replicas/sec legs use per-leg local pools so a "4 threads"
/// measurement means exactly four participants rather than however
/// many workers the global pool happens to own.
pub fn run_batched_on(
    pool: &ThreadPool,
    cfg: &SimConfig,
    replicates: usize,
    base_seed: u64,
    threads: usize,
) -> Vec<RunResult> {
    assert!(replicates > 0);
    let sim = Simulator::new(cfg.clone());
    let batch = effective_batch_size(replicates);
    metrics::SIM_BATCH_SIZE.set(batch as u64);
    metrics::SIM_BATCH_REPLICAS_TOTAL.add(replicates as u64);
    metrics::SIM_BATCH_JOBS_TOTAL.add(((replicates + batch - 1) / batch) as u64);
    match sim.config().scenario.hierarchy() {
        Some(_) => fan_out(pool, replicates, threads, batch, &|lo, hi| {
            let mut block = FixedTieredBlock::new(&sim, base_seed, lo, hi);
            drive(&mut block);
            block.finish()
        }),
        None => fan_out(pool, replicates, threads, batch, &|lo, hi| {
            let mut block = FixedScalarBlock::new(&sim, base_seed, lo, hi);
            drive(&mut block);
            block.finish()
        }),
    }
}

/// Run `replicates` adaptive sample paths through the lockstep
/// executor. Same ordering/bit-identity contract as [`run_batched`],
/// against `AdaptiveSimulator::run`.
pub fn run_adaptive_batched(
    sim: &AdaptiveSimulator,
    replicates: usize,
    base_seed: u64,
    threads: usize,
) -> Vec<AdaptiveRunResult> {
    assert!(replicates > 0);
    let batch = effective_batch_size(replicates);
    metrics::SIM_BATCH_SIZE.set(batch as u64);
    metrics::SIM_BATCH_REPLICAS_TOTAL.add(replicates as u64);
    metrics::SIM_BATCH_JOBS_TOTAL.add(((replicates + batch - 1) / batch) as u64);
    fan_out(ThreadPool::global(), replicates, threads, batch, &|lo, hi| {
        let mut block = AdaptiveBlock::new(sim, base_seed, lo, hi);
        drive(&mut block);
        block.finish()
    })
}

// ---------------------------------------------------------------------------
// Fixed-period, scalar scenario (transliterates `Simulator::run`).
// ---------------------------------------------------------------------------

struct FixedScalarBlock<'a> {
    sim: &'a Simulator,
    compute_len: f64,
    streams: Vec<BufferedFailures>,
    next_fail: Vec<Failure>,
    now: Vec<f64>,
    saved: Vec<f64>,
    overlap: Vec<f64>,
    res: Vec<RunResult>,
}

impl<'a> FixedScalarBlock<'a> {
    fn new(sim: &'a Simulator, base_seed: u64, lo: usize, hi: usize) -> Self {
        let n = hi - lo;
        let cfg = sim.config();
        let compute_len = cfg.period - cfg.scenario.ckpt.c;
        let mut block = FixedScalarBlock {
            sim,
            compute_len,
            streams: Vec::with_capacity(n),
            next_fail: Vec::with_capacity(n),
            now: vec![0.0; n],
            saved: vec![0.0; n],
            overlap: vec![0.0; n],
            res: vec![ZERO_RUN; n],
        };
        for i in lo..hi {
            let mut rng = Pcg64::seeded(base_seed + i as u64);
            let mut stream = BufferedFailures::new(cfg.failure.stream(&mut rng));
            block.next_fail.push(stream.next_after(0.0));
            block.streams.push(stream);
        }
        block
    }

    fn finish(mut self) -> Vec<RunResult> {
        let s = &self.sim.config().scenario;
        let omega = s.ckpt.omega;
        let p = &s.power;
        for i in 0..self.res.len() {
            let res = &mut self.res[i];
            res.makespan = self.now[i];
            res.energy = p.p_static * res.makespan
                + p.p_cal * (res.time_compute + omega * res.time_checkpoint)
                + p.p_io * (res.time_checkpoint + res.time_recovery)
                + p.p_down * res.time_down;
        }
        self.res
    }
}

impl Lockstep for FixedScalarBlock<'_> {
    fn slots(&self) -> usize {
        self.res.len()
    }

    fn step(&mut self, i: usize) -> bool {
        let sim = self.sim;
        let s = &sim.config().scenario;
        let c = s.ckpt.c;
        let (d, r) = (s.ckpt.d, s.ckpt.r);
        let omega = s.ckpt.omega;

        // ---- compute phase (rate 1) ----
        let base_progress = self.saved[i] + self.overlap[i];
        let need = s.t_base - base_progress;
        debug_assert!(need > 0.0);
        match phase_end(self.now[i], self.compute_len, need, 1.0, self.next_fail[i].at) {
            PhaseEnd::Finished(dt) => {
                self.res[i].time_compute += dt;
                self.now[i] += dt;
                return true;
            }
            PhaseEnd::Failed(dt) => {
                self.res[i].time_compute += dt;
                self.now[i] += dt;
                self.res[i].work_lost += self.overlap[i] + dt;
                self.overlap[i] = 0.0;
                sim.fail_and_recover(
                    &mut self.res[i],
                    &mut self.now[i],
                    &mut self.next_fail[i],
                    &mut self.streams[i],
                    d,
                    r,
                );
                return false;
            }
            PhaseEnd::Ran => {
                self.res[i].time_compute += self.compute_len;
                self.now[i] += self.compute_len;
            }
        }

        // ---- checkpoint phase (rate ω) ----
        let at_ckpt_start = base_progress + self.compute_len;
        let need = s.t_base - at_ckpt_start;
        match phase_end(self.now[i], c, need, omega, self.next_fail[i].at) {
            PhaseEnd::Finished(dt) => {
                self.res[i].time_checkpoint += dt;
                self.now[i] += dt;
                true
            }
            PhaseEnd::Failed(dt) => {
                self.res[i].time_checkpoint += dt;
                self.now[i] += dt;
                self.res[i].work_lost += self.overlap[i] + self.compute_len + omega * dt;
                self.overlap[i] = 0.0;
                sim.fail_and_recover(
                    &mut self.res[i],
                    &mut self.now[i],
                    &mut self.next_fail[i],
                    &mut self.streams[i],
                    d,
                    r,
                );
                false
            }
            PhaseEnd::Ran => {
                self.res[i].time_checkpoint += c;
                self.now[i] += c;
                self.res[i].n_checkpoints += 1;
                self.saved[i] = at_ckpt_start;
                self.overlap[i] = omega * c;
                false
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Fixed-period, tiered scenario (transliterates `Simulator::run_tiered`).
// ---------------------------------------------------------------------------

struct FixedTieredBlock<'a> {
    sim: &'a Simulator,
    h: &'a TierHierarchy,
    compute_len: f64,
    kappa: [u32; MAX_TIERS],
    streams: Vec<BufferedFailures>,
    next_fail: Vec<Failure>,
    now: Vec<f64>,
    saved: Vec<f64>,
    overlap: Vec<f64>,
    res: Vec<RunResult>,
    store: Vec<TierStore>,
    inflight: Vec<Vec<Drain>>,
    drain_free_at: Vec<f64>,
    drain_energy: Vec<f64>,
    recovery_io_energy: Vec<f64>,
    /// Shared pin-set scratch (one allocation per block).
    pinned: Vec<f64>,
}

impl<'a> FixedTieredBlock<'a> {
    fn new(sim: &'a Simulator, base_seed: u64, lo: usize, hi: usize) -> Self {
        let n = hi - lo;
        let cfg = sim.config();
        let s = &cfg.scenario;
        let h = s.hierarchy().expect("tiered block needs a hierarchy");
        let compute_len = cfg.period - s.ckpt.c;
        let kappa = crate::model::tiers::cadence_for(s, h, cfg.period);
        let mut block = FixedTieredBlock {
            sim,
            h,
            compute_len,
            kappa,
            streams: Vec::with_capacity(n),
            next_fail: Vec::with_capacity(n),
            now: vec![0.0; n],
            saved: vec![0.0; n],
            overlap: vec![0.0; n],
            res: vec![ZERO_RUN; n],
            store: (0..n).map(|_| TierStore::new(h)).collect(),
            inflight: (0..n).map(|_| Vec::new()).collect(),
            drain_free_at: vec![0.0; n],
            drain_energy: vec![0.0; n],
            recovery_io_energy: vec![0.0; n],
            pinned: Vec::new(),
        };
        for i in lo..hi {
            let mut rng = Pcg64::seeded(base_seed + i as u64);
            let mut stream = BufferedFailures::new(cfg.failure.stream(&mut rng));
            block.next_fail.push(stream.next_after(0.0));
            block.streams.push(stream);
        }
        block
    }

    fn finish(mut self) -> Vec<RunResult> {
        let s = &self.sim.config().scenario;
        let omega = s.ckpt.omega;
        let p = &s.power;
        for i in 0..self.res.len() {
            settle_drains_with(
                &mut self.inflight[i],
                &mut self.store[i],
                &mut self.drain_energy[i],
                self.h,
                self.now[i],
                true,
                &mut self.pinned,
            );
            let res = &mut self.res[i];
            res.makespan = self.now[i];
            res.energy = p.p_static * res.makespan
                + p.p_cal * (res.time_compute + omega * res.time_checkpoint)
                + p.p_io * res.time_checkpoint
                + self.recovery_io_energy[i]
                + p.p_down * res.time_down
                + self.drain_energy[i];
        }
        self.res
    }

    fn node_loss(&mut self, i: usize, d: f64, progress: f64) {
        let sim = self.sim;
        sim.tiered_failure(
            &mut self.res[i],
            &mut self.now[i],
            &mut self.next_fail[i],
            &mut self.streams[i],
            self.h,
            &mut self.store[i],
            &mut self.inflight[i],
            &mut self.drain_free_at[i],
            &mut self.drain_energy[i],
            &mut self.recovery_io_energy[i],
            d,
            progress,
            &mut self.saved[i],
            &mut self.overlap[i],
            &mut self.pinned,
        );
    }
}

impl Lockstep for FixedTieredBlock<'_> {
    fn slots(&self) -> usize {
        self.res.len()
    }

    fn step(&mut self, i: usize) -> bool {
        let s = &self.sim.config().scenario;
        let c = s.ckpt.c;
        let d = s.ckpt.d;
        let omega = s.ckpt.omega;

        // ---- compute phase ----
        let base_progress = self.saved[i] + self.overlap[i];
        let need = s.t_base - base_progress;
        debug_assert!(need > 0.0);
        match phase_end(self.now[i], self.compute_len, need, 1.0, self.next_fail[i].at) {
            PhaseEnd::Finished(dt) => {
                self.res[i].time_compute += dt;
                self.now[i] += dt;
                return true;
            }
            PhaseEnd::Failed(dt) => {
                self.res[i].time_compute += dt;
                self.now[i] += dt;
                let progress = base_progress + dt;
                self.node_loss(i, d, progress);
                return false;
            }
            PhaseEnd::Ran => {
                self.res[i].time_compute += self.compute_len;
                self.now[i] += self.compute_len;
            }
        }

        // ---- checkpoint phase (synchronous tier-0 write) ----
        let at_ckpt_start = base_progress + self.compute_len;
        let need = s.t_base - at_ckpt_start;
        match phase_end(self.now[i], c, need, omega, self.next_fail[i].at) {
            PhaseEnd::Finished(dt) => {
                self.res[i].time_checkpoint += dt;
                self.now[i] += dt;
                true
            }
            PhaseEnd::Failed(dt) => {
                self.res[i].time_checkpoint += dt;
                self.now[i] += dt;
                let progress = at_ckpt_start + omega * dt;
                self.node_loss(i, d, progress);
                false
            }
            PhaseEnd::Ran => {
                self.res[i].time_checkpoint += c;
                self.now[i] += c;
                self.res[i].n_checkpoints += 1;
                self.saved[i] = at_ckpt_start;
                self.overlap[i] = omega * c;
                settle_drains_with(
                    &mut self.inflight[i],
                    &mut self.store[i],
                    &mut self.drain_energy[i],
                    self.h,
                    self.now[i],
                    false,
                    &mut self.pinned,
                );
                self.pinned.clear();
                self.pinned.extend(self.inflight[i].iter().map(|dr| dr.work));
                self.store[i].record(
                    0,
                    CopyRecord { work: at_ckpt_start, available_at: self.now[i] },
                    &self.pinned,
                );
                let idx = self.res[i].n_checkpoints;
                let mut source_ready = self.now[i];
                for tier in 1..self.h.len() {
                    if idx % self.kappa[tier] as u64 != 0 {
                        break;
                    }
                    let start = self.drain_free_at[i].max(source_ready);
                    let end = start + self.h.tier(tier).c;
                    self.drain_free_at[i] = end;
                    source_ready = end;
                    self.inflight[i].push(Drain { tier, work: at_ckpt_start, start, end });
                }
                false
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Adaptive (transliterates `AdaptiveSimulator::run`).
// ---------------------------------------------------------------------------

struct AdaptiveBlock<'a> {
    sim: &'a AdaptiveSimulator,
    seeds: Vec<u64>,
    ctl: Vec<AdaptiveController>,
    period: Vec<f64>,
    streams: Vec<BufferedFailures>,
    next_fail: Vec<Failure>,
    now: Vec<f64>,
    saved: Vec<f64>,
    overlap: Vec<f64>,
    res: Vec<AdaptiveRunResult>,
    // Tiered state; untouched (empty queues, kappa never read) when the
    // scenario is scalar.
    store: Vec<Option<TierStore>>,
    inflight: Vec<Vec<Drain>>,
    drain_free_at: Vec<f64>,
    drain_energy: Vec<f64>,
    rec_io_energy: Vec<f64>,
    kappa: Vec<[u32; MAX_TIERS]>,
    kappa_period: Vec<f64>,
    /// Shared pin-set scratch (one allocation per block).
    pinned: Vec<f64>,
}

impl<'a> AdaptiveBlock<'a> {
    fn new(sim: &'a AdaptiveSimulator, base_seed: u64, lo: usize, hi: usize) -> Self {
        let n = hi - lo;
        let cfg = &sim.cfg;
        let s = &cfg.scenario;
        let omega = s.ckpt.omega;
        let d = s.ckpt.d;
        let fallback = s.clamp_period(young(s)).expect("feasible by construction");
        let mut block = AdaptiveBlock {
            sim,
            seeds: Vec::with_capacity(n),
            ctl: Vec::with_capacity(n),
            period: Vec::with_capacity(n),
            streams: Vec::with_capacity(n),
            next_fail: Vec::with_capacity(n),
            now: vec![0.0; n],
            saved: vec![0.0; n],
            overlap: vec![0.0; n],
            res: Vec::with_capacity(n),
            store: (0..n).map(|_| sim.tiered.as_ref().map(TierStore::new)).collect(),
            inflight: (0..n).map(|_| Vec::new()).collect(),
            drain_free_at: vec![0.0; n],
            drain_energy: vec![0.0; n],
            rec_io_energy: vec![0.0; n],
            kappa: vec![[1u32; MAX_TIERS]; n],
            kappa_period: vec![f64::NAN; n],
            pinned: Vec::new(),
        };
        for i in lo..hi {
            let seed = base_seed + i as u64;
            // Controller construction + calibration, verbatim from
            // `AdaptiveSimulator::run` (same observation order ⇒ same
            // estimator state bits).
            let mut ctl = AdaptiveController::new(
                cfg.policy,
                s.power,
                omega,
                d,
                cfg.prior_mu,
                s.t_base,
            )
            .with_ewma_alpha(cfg.alpha)
            .with_hysteresis(cfg.hysteresis);
            let s0 = sim.traj.scenario_at(0.0);
            ctl.observe_checkpoint(s0.ckpt.c);
            ctl.observe_restore(s0.ckpt.r);
            if trace::enabled() {
                trace::emit(&trace::event(
                    "observe",
                    seed,
                    0.0,
                    vec![
                        ("c_est", Json::Num(ctl.c_estimate())),
                        ("r_est", Json::Num(ctl.r_estimate())),
                        ("mu_est", Json::Num(ctl.mu_estimate())),
                        ("oracle", Json::Bool(cfg.oracle)),
                    ],
                ));
            }
            let period = if cfg.oracle {
                sim.instantaneous_target(0.0).unwrap_or(fallback)
            } else {
                match ctl.period() {
                    Some(p) => s.clamp_period(p).unwrap_or(fallback),
                    None => fallback,
                }
            };
            if trace::enabled() {
                trace::emit(&trace::event(
                    "period",
                    seed,
                    0.0,
                    vec![
                        ("current", Json::Null),
                        ("fresh", Json::Num(period)),
                        ("changed", Json::Bool(false)),
                        ("suppressed", Json::Bool(false)),
                        ("oracle", Json::Bool(cfg.oracle)),
                    ],
                ));
            }
            let mut rng = Pcg64::seeded(seed);
            let mut stream = BufferedFailures::new(cfg.failure.stream(&mut rng));
            block.next_fail.push(stream.next_after(0.0));
            block.streams.push(stream);
            block.seeds.push(seed);
            block.ctl.push(ctl);
            block.period.push(period);
            block.res.push(AdaptiveRunResult {
                makespan: 0.0,
                energy: 0.0,
                n_failures: 0,
                n_checkpoints: 0,
                work_lost: 0.0,
                time_compute: 0.0,
                time_checkpoint: 0.0,
                time_recovery: 0.0,
                time_down: 0.0,
                n_period_updates: 0,
                final_period: period,
                tracking_lag_pct: 0.0,
                drift_lag_pct: 0.0,
                tracking_samples: 0,
            });
        }
        block
    }

    fn finish(mut self) -> Vec<AdaptiveRunResult> {
        let sim = self.sim;
        let s = &sim.cfg.scenario;
        let omega = s.ckpt.omega;
        for i in 0..self.res.len() {
            if let (Some(h), Some(st)) = (sim.tiered.as_ref(), self.store[i].as_mut()) {
                settle_drains_with(
                    &mut self.inflight[i],
                    st,
                    &mut self.drain_energy[i],
                    h,
                    self.now[i],
                    true,
                    &mut self.pinned,
                );
            }
            let res = &mut self.res[i];
            res.makespan = self.now[i];
            res.final_period = self.period[i];
            if res.tracking_samples > 0 {
                res.tracking_lag_pct /= res.tracking_samples as f64;
                res.drift_lag_pct /= res.tracking_samples as f64;
            }
            if sim.tiered.is_some() {
                let p = &s.power;
                res.energy = p.p_static * res.makespan
                    + p.p_cal * (res.time_compute + omega * res.time_checkpoint)
                    + p.p_io * res.time_checkpoint
                    + self.rec_io_energy[i]
                    + p.p_down * res.time_down
                    + self.drain_energy[i];
            } else if !sim.drifting {
                let p = &s.power;
                res.energy = p.p_static * res.makespan
                    + p.p_cal * (res.time_compute + omega * res.time_checkpoint)
                    + p.p_io * (res.time_checkpoint + res.time_recovery)
                    + p.p_down * res.time_down;
            }
        }
        self.res
    }

    /// Node-loss + recovery + period re-read, shared by both phases'
    /// `Failed` arms (the per-phase `progress` expression differs).
    fn fail_path(&mut self, i: usize, dt: f64, progress: f64, overlap_loss: f64) {
        let sim = self.sim;
        let seed = self.seeds[i];
        self.ctl[i].observe_uptime(dt);
        let tier_rec = if let (Some(h), Some(st)) = (sim.tiered.as_ref(), self.store[i].as_mut())
        {
            Some(tiered_node_loss(
                h,
                st,
                &mut self.inflight[i],
                &mut self.drain_free_at[i],
                &mut self.drain_energy[i],
                self.now[i],
                progress,
                &mut self.saved[i],
                &mut self.overlap[i],
                &mut self.res[i].work_lost,
                &mut self.pinned,
            ))
        } else {
            self.res[i].work_lost += overlap_loss;
            self.overlap[i] = 0.0;
            None
        };
        sim.fail_and_recover(
            &mut self.ctl[i],
            &mut self.res[i],
            &mut self.now[i],
            &mut self.next_fail[i],
            &mut self.streams[i],
            seed,
            tier_rec,
            &mut self.rec_io_energy[i],
        );
        sim.reread_period(&mut self.ctl[i], &mut self.res[i], &mut self.period[i], self.now[i], seed);
    }
}

impl Lockstep for AdaptiveBlock<'_> {
    fn slots(&self) -> usize {
        self.res.len()
    }

    fn step(&mut self, i: usize) -> bool {
        let sim = self.sim;
        let s = &sim.cfg.scenario;
        let c = s.ckpt.c;
        let omega = s.ckpt.omega;
        let pw = s.power;
        let seed = self.seeds[i];

        let compute_len = if sim.drifting {
            (self.period[i] - sim.traj.scenario_at(self.now[i]).ckpt.c).max(1e-3 * c)
        } else {
            self.period[i] - c
        };

        // ---- compute phase (rate 1, power static+cal) ----
        let base_progress = self.saved[i] + self.overlap[i];
        let need = s.t_base - base_progress;
        debug_assert!(need > 0.0);
        match phase_end(self.now[i], compute_len, need, 1.0, self.next_fail[i].at) {
            PhaseEnd::Finished(dt) => {
                self.res[i].time_compute += dt;
                if sim.drifting {
                    self.res[i].energy += (pw.p_static + pw.p_cal) * dt;
                }
                self.now[i] += dt;
                return true;
            }
            PhaseEnd::Failed(dt) => {
                self.res[i].time_compute += dt;
                if sim.drifting {
                    self.res[i].energy += (pw.p_static + pw.p_cal) * dt;
                }
                self.now[i] += dt;
                let overlap_loss = self.overlap[i] + dt;
                self.fail_path(i, dt, base_progress + dt, overlap_loss);
                return false;
            }
            PhaseEnd::Ran => {
                self.res[i].time_compute += compute_len;
                if sim.drifting {
                    self.res[i].energy += (pw.p_static + pw.p_cal) * compute_len;
                }
                self.now[i] += compute_len;
                self.ctl[i].observe_uptime(compute_len);
            }
        }

        // ---- checkpoint phase (rate ω, power static+ω·cal+io) ----
        let (c_ckpt, p_io_ckpt) = if sim.drifting {
            let s_ck = sim.traj.scenario_at(self.now[i]);
            (s_ck.ckpt.c, s_ck.power.p_io)
        } else {
            (c, pw.p_io)
        };
        let ckpt_rate = pw.p_static + omega * pw.p_cal + p_io_ckpt;
        let at_ckpt_start = base_progress + compute_len;
        let need = s.t_base - at_ckpt_start;
        match phase_end(self.now[i], c_ckpt, need, omega, self.next_fail[i].at) {
            PhaseEnd::Finished(dt) => {
                self.res[i].time_checkpoint += dt;
                if sim.drifting {
                    self.res[i].energy += ckpt_rate * dt;
                }
                self.now[i] += dt;
                true
            }
            PhaseEnd::Failed(dt) => {
                self.res[i].time_checkpoint += dt;
                if sim.drifting {
                    self.res[i].energy += ckpt_rate * dt;
                }
                self.now[i] += dt;
                let overlap_loss = self.overlap[i] + compute_len + omega * dt;
                self.fail_path(i, dt, at_ckpt_start + omega * dt, overlap_loss);
                false
            }
            PhaseEnd::Ran => {
                self.res[i].time_checkpoint += c_ckpt;
                if sim.drifting {
                    self.res[i].energy += ckpt_rate * c_ckpt;
                }
                self.now[i] += c_ckpt;
                self.ctl[i].observe_uptime(c_ckpt);
                self.res[i].n_checkpoints += 1;
                self.saved[i] = at_ckpt_start;
                self.overlap[i] = omega * c_ckpt;
                self.ctl[i].observe_checkpoint(c_ckpt);
                if trace::enabled() {
                    trace::emit(&trace::event(
                        "observe",
                        seed,
                        self.now[i],
                        vec![
                            ("c_est", Json::Num(self.ctl[i].c_estimate())),
                            ("r_est", Json::Num(self.ctl[i].r_estimate())),
                            ("mu_est", Json::Num(self.ctl[i].mu_estimate())),
                            ("oracle", Json::Bool(sim.cfg.oracle)),
                        ],
                    ));
                }
                if let (Some(h), Some(st)) = (sim.tiered.as_ref(), self.store[i].as_mut()) {
                    settle_drains_with(
                        &mut self.inflight[i],
                        st,
                        &mut self.drain_energy[i],
                        h,
                        self.now[i],
                        false,
                        &mut self.pinned,
                    );
                    self.pinned.clear();
                    self.pinned.extend(self.inflight[i].iter().map(|dr| dr.work));
                    st.record(
                        0,
                        CopyRecord { work: at_ckpt_start, available_at: self.now[i] },
                        &self.pinned,
                    );
                    if self.kappa_period[i] != self.period[i] {
                        self.kappa[i] = crate::model::tiers::cadence_for(s, h, self.period[i]);
                        self.kappa_period[i] = self.period[i];
                    }
                    let idx = self.res[i].n_checkpoints;
                    let mut source_ready = self.now[i];
                    for tier in 1..h.len() {
                        if idx % self.kappa[i][tier] as u64 != 0 {
                            break;
                        }
                        let start = self.drain_free_at[i].max(source_ready);
                        let end = start + h.tier(tier).c;
                        self.drain_free_at[i] = end;
                        source_ready = end;
                        self.inflight[i].push(Drain { tier, work: at_ckpt_start, start, end });
                    }
                }
                sim.reread_period(
                    &mut self.ctl[i],
                    &mut self.res[i],
                    &mut self.period[i],
                    self.now[i],
                    seed,
                );
                false
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fig1_scenario;
    use crate::coordinator::policy::PeriodPolicy;
    use crate::model::params::{CheckpointParams, PowerParams, Scenario};
    use crate::sim::adaptive::AdaptiveSimConfig;
    use crate::sim::FailureProcess;
    use crate::storage::TierSpec;

    fn scenario(mu: f64) -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        Scenario::new(ckpt, power, mu, 20_000.0).unwrap()
    }

    #[test]
    fn batched_fixed_scalar_matches_per_replica_runs() {
        let cfg = SimConfig::paper(scenario(120.0), 80.0);
        let sim = Simulator::new(cfg.clone());
        for threads in [1, 4] {
            let batched = run_batched(&cfg, 24, 7, threads);
            for (i, got) in batched.iter().enumerate() {
                let want = sim.run(7 + i as u64);
                assert_eq!(*got, want, "replicate {i} at {threads} threads");
            }
        }
    }

    #[test]
    fn batched_fixed_tiered_matches_per_replica_runs() {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        let s = Scenario::with_tier_specs(
            ckpt,
            power,
            120.0,
            8_000.0,
            &[TierSpec::new(1.0, 1.0, 30.0), TierSpec::new(10.0, 10.0, 100.0)],
        )
        .unwrap();
        let cfg = SimConfig::paper(s, 70.0);
        let sim = Simulator::new(cfg.clone());
        let batched = run_batched(&cfg, 16, 3, 1);
        for (i, got) in batched.iter().enumerate() {
            let want = sim.run(3 + i as u64);
            assert_eq!(*got, want, "replicate {i}");
        }
    }

    #[test]
    fn batched_adaptive_matches_per_replica_runs() {
        let s = fig1_scenario(300.0, 5.5);
        let sim =
            AdaptiveSimulator::new(AdaptiveSimConfig::paper(s, PeriodPolicy::AlgoT));
        let batched = run_adaptive_batched(&sim, 12, 11, 1);
        for (i, got) in batched.iter().enumerate() {
            let want = sim.run(11 + i as u64);
            assert_eq!(*got, want, "replicate {i}");
        }
    }

    #[test]
    fn per_node_streams_pass_through_unblocked() {
        // PerNodeWeibull consumes a heap-ordered, now-dependent draw
        // count: the buffered wrapper must pass it through on demand.
        let mut cfg = SimConfig::paper(scenario(150.0), 80.0);
        cfg.failure = FailureProcess::PerNodeWeibull { n: 8, shape: 0.7, scale_ind: 1200.0 };
        let sim = Simulator::new(cfg.clone());
        let batched = run_batched(&cfg, 8, 5, 1);
        for (i, got) in batched.iter().enumerate() {
            assert_eq!(*got, sim.run(5 + i as u64), "replicate {i}");
        }
    }

    #[test]
    fn batch_size_override_is_result_neutral() {
        let cfg = SimConfig::paper(scenario(120.0), 80.0);
        let base = run_batched(&cfg, 20, 1, 1);
        for b in [1usize, 3, 7, 64] {
            set_batch_size(Some(b));
            let got = run_batched(&cfg, 20, 1, 1);
            set_batch_size(None);
            assert_eq!(got, base, "batch size {b} changed results");
        }
    }

    #[test]
    fn effective_batch_size_respects_override_and_bounds() {
        set_batch_size(Some(5));
        assert_eq!(effective_batch_size(100), 5);
        assert_eq!(effective_batch_size(3), 3, "never exceeds the replicate count");
        set_batch_size(None);
        let auto = effective_batch_size(10_000);
        assert!((1..=MAX_AUTO_BATCH).contains(&auto), "auto size {auto} out of bounds");
        assert_eq!(effective_batch_size(1), 1);
    }
}
