//! Declarative flag parser.

use std::collections::BTreeMap;

/// Errors produced while parsing a command line.
#[derive(Debug, PartialEq, Eq)]
pub enum CliError {
    UnknownFlag(String, String),
    MissingValue(String),
    InvalidValue(String, String, String),
    /// A value flag appeared more than once. Silently keeping the last
    /// occurrence hides typos in long invocations, so it is an error.
    DuplicateFlag(String),
    UnexpectedPositional(String),
    Help(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(flag, usage) => write!(f, "unknown flag `{flag}`\n{usage}"),
            CliError::MissingValue(flag) => write!(f, "flag `{flag}` requires a value"),
            CliError::InvalidValue(flag, value, why) => {
                write!(f, "invalid value `{value}` for flag `{flag}`: {why}")
            }
            CliError::DuplicateFlag(flag) => {
                write!(f, "flag `--{flag}` given more than once")
            }
            CliError::UnexpectedPositional(arg) => {
                write!(f, "unexpected positional argument `{arg}`")
            }
            CliError::Help(text) => write!(f, "{text}"),
        }
    }
}

impl std::error::Error for CliError {}

/// Specification of one flag.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `None` ⇒ boolean switch; `Some(default)` ⇒ value flag.
    pub default: Option<&'static str>,
}

impl ArgSpec {
    pub const fn flag(name: &'static str, default: &'static str, help: &'static str) -> Self {
        ArgSpec { name, help, default: Some(default) }
    }

    pub const fn switch(name: &'static str, help: &'static str) -> Self {
        ArgSpec { name, help, default: None }
    }
}

/// Parsed arguments for one subcommand.
#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
}

impl Args {
    /// Parse `argv` (without program/subcommand) against `specs`.
    pub fn parse(
        command: &str,
        about: &str,
        specs: &[ArgSpec],
        argv: &[String],
    ) -> Result<Args, CliError> {
        let mut values = BTreeMap::new();
        let mut switches = BTreeMap::new();
        for s in specs {
            match s.default {
                Some(d) => {
                    values.insert(s.name.to_string(), d.to_string());
                }
                None => {
                    switches.insert(s.name.to_string(), false);
                }
            }
        }
        let usage = render_usage(command, about, specs);
        let mut seen = std::collections::BTreeSet::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::Help(usage));
            }
            if let Some(name) = a.strip_prefix("--") {
                // Support --flag=value.
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                if switches.contains_key(name) {
                    if inline.is_some() {
                        return Err(CliError::InvalidValue(
                            name.into(),
                            inline.unwrap(),
                            "switch takes no value".into(),
                        ));
                    }
                    switches.insert(name.to_string(), true);
                } else if values.contains_key(name) {
                    // Repeated switches are idempotent, but a repeated
                    // value flag would silently drop the earlier value.
                    if !seen.insert(name.to_string()) {
                        return Err(CliError::DuplicateFlag(name.into()));
                    }
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.into()))?
                        }
                    };
                    values.insert(name.to_string(), v);
                } else {
                    return Err(CliError::UnknownFlag(a.clone(), usage));
                }
            } else {
                return Err(CliError::UnexpectedPositional(a.clone()));
            }
            i += 1;
        }
        Ok(Args { values, switches })
    }

    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or_else(|| {
            panic!("flag `{name}` was not declared in the ArgSpec list")
        })
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        let raw = self.get(name);
        raw.parse().map_err(|e: std::num::ParseFloatError| {
            CliError::InvalidValue(name.into(), raw.into(), e.to_string())
        })
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        let raw = self.get(name);
        raw.parse().map_err(|e: std::num::ParseIntError| {
            CliError::InvalidValue(name.into(), raw.into(), e.to_string())
        })
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        let raw = self.get(name);
        raw.parse().map_err(|e: std::num::ParseIntError| {
            CliError::InvalidValue(name.into(), raw.into(), e.to_string())
        })
    }

    pub fn switch(&self, name: &str) -> bool {
        *self.switches.get(name).unwrap_or_else(|| {
            panic!("switch `{name}` was not declared in the ArgSpec list")
        })
    }
}

fn render_usage(command: &str, about: &str, specs: &[ArgSpec]) -> String {
    let mut s = format!("{command} — {about}\n\nflags:\n");
    for spec in specs {
        match spec.default {
            Some(d) => {
                s.push_str(&format!("  --{:<24} {} (default: {})\n", spec.name, spec.help, d))
            }
            None => s.push_str(&format!("  --{:<24} {} (switch)\n", spec.name, spec.help)),
        }
    }
    s.push_str("  --help                     show this help\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPECS: &[ArgSpec] = &[
        ArgSpec::flag("mu", "300", "platform MTBF in minutes"),
        ArgSpec::flag("name", "default", "scenario name"),
        ArgSpec::switch("verbose", "print more"),
    ];

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse("t", "test", SPECS, &argv(&[])).unwrap();
        assert_eq!(a.get_f64("mu").unwrap(), 300.0);
        assert_eq!(a.get("name"), "default");
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn values_and_switches() {
        let a =
            Args::parse("t", "test", SPECS, &argv(&["--mu", "42.5", "--verbose"])).unwrap();
        assert_eq!(a.get_f64("mu").unwrap(), 42.5);
        assert!(a.switch("verbose"));
    }

    #[test]
    fn inline_equals_form() {
        let a = Args::parse("t", "test", SPECS, &argv(&["--mu=60"])).unwrap();
        assert_eq!(a.get_f64("mu").unwrap(), 60.0);
    }

    #[test]
    fn unknown_flag_rejected() {
        let e = Args::parse("t", "test", SPECS, &argv(&["--bogus", "1"])).unwrap_err();
        assert!(matches!(e, CliError::UnknownFlag(..)));
    }

    #[test]
    fn missing_value_rejected() {
        let e = Args::parse("t", "test", SPECS, &argv(&["--mu"])).unwrap_err();
        assert_eq!(e, CliError::MissingValue("mu".into()));
    }

    #[test]
    fn bad_number_reported() {
        let a = Args::parse("t", "test", SPECS, &argv(&["--mu", "abc"])).unwrap();
        assert!(matches!(a.get_f64("mu"), Err(CliError::InvalidValue(..))));
    }

    #[test]
    fn help_contains_flags() {
        let e = Args::parse("t", "test", SPECS, &argv(&["--help"])).unwrap_err();
        match e {
            CliError::Help(text) => {
                assert!(text.contains("--mu"));
                assert!(text.contains("--verbose"));
            }
            other => panic!("expected help, got {other:?}"),
        }
    }

    #[test]
    fn positional_rejected() {
        let e = Args::parse("t", "test", SPECS, &argv(&["oops"])).unwrap_err();
        assert_eq!(e, CliError::UnexpectedPositional("oops".into()));
    }

    #[test]
    fn switch_with_value_rejected() {
        let e = Args::parse("t", "test", SPECS, &argv(&["--verbose=yes"])).unwrap_err();
        assert!(matches!(e, CliError::InvalidValue(..)));
    }

    #[test]
    fn duplicate_value_flag_rejected() {
        let e = Args::parse("t", "test", SPECS, &argv(&["--mu", "1", "--mu", "2"]))
            .unwrap_err();
        assert_eq!(e, CliError::DuplicateFlag("mu".into()));
        assert!(e.to_string().contains("--mu"), "{e}");
        // The =-form and the space-form collide too.
        let e = Args::parse("t", "test", SPECS, &argv(&["--mu=1", "--mu", "2"]))
            .unwrap_err();
        assert_eq!(e, CliError::DuplicateFlag("mu".into()));
        // Distinct value flags are of course fine.
        let a =
            Args::parse("t", "test", SPECS, &argv(&["--mu", "1", "--name", "x"])).unwrap();
        assert_eq!(a.get_f64("mu").unwrap(), 1.0);
    }

    #[test]
    fn repeated_switch_stays_idempotent() {
        let a = Args::parse("t", "test", SPECS, &argv(&["--verbose", "--verbose"])).unwrap();
        assert!(a.switch("verbose"));
    }
}
