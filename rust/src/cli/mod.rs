//! Std-only command-line parsing (no `clap` in the offline vendor set).
//!
//! Grammar: `ckpt-period <subcommand> [--flag value]... [--switch]`.
//! Each subcommand declares its flags up front so `--help` is generated
//! and unknown flags are rejected with a useful message.

mod args;

pub use args::{ArgSpec, Args, CliError};
