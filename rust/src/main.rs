//! `ckpt-period` — CLI for the checkpoint-period library.
//!
//! Subcommands:
//!
//! * `optimize`  — optimal periods + time/energy trade-off for a scenario
//! * `sweep`     — CSV of `T_final`/`E_final` over a period grid
//! * `pareto`    — time–energy Pareto frontier: knees, ε-constraint
//!   solves, optional Monte-Carlo validation, JSON artifact
//! * `simulate`  — Monte-Carlo validation of the model on a scenario
//! * `figures`   — regenerate every paper figure as CSV + JSON
//! * `train`     — run the fault-tolerant training coordinator (PJRT)
//! * `batch`     — answer a JSON-lines stream of scenario queries
//!   (stdin, file, or Unix socket) through the batched serve engine
//! * `bench`     — standardised serving benchmark -> `BENCH_<n>.json`
//! * `info`      — artifact inventory

use std::path::{Path, PathBuf};

use ckpt_period::cli::{ArgSpec, Args, CliError};
use ckpt_period::config::presets::{
    drift_preset, drift_presets, fig1_scenario, power_ratio_sweep, tier_preset, tier_presets,
    tradeoff_presets,
};
use ckpt_period::config::ScenarioSpec;
use ckpt_period::coordinator::{Coordinator, CoordinatorConfig, OverlapMode, PeriodPolicy};
use ckpt_period::drift::DriftProcess;
use ckpt_period::figures;
use ckpt_period::model::energy::{e_final, t_energy_opt};
use ckpt_period::model::msk::compare_with_msk;
use ckpt_period::model::params::{CheckpointParams, PowerParams, Scenario};
use ckpt_period::model::ratios::compare;
use ckpt_period::model::time::{daly, t_final, t_time_opt, young};
use ckpt_period::model::{Backend, RecoveryModel};
use ckpt_period::pareto::{
    family_frontiers, min_energy_with_time_overhead, min_time_with_energy_overhead, validate,
    EpsSolution, Frontier, FrontierPoint, Knee, KneeMethod,
};
use ckpt_period::runtime::{write_binary_artifact, write_json_artifact, ArtifactDir, Runtime};
use ckpt_period::serve::{Answer, BatchEngine, ErrorRecord, Query};
use ckpt_period::sweep::{Cell, CellJob, CellOutput, GridSpec};
use ckpt_period::util::json::Json;
use ckpt_period::util::table::{fnum, Table};

const USAGE: &str =
    "ckpt-period <optimize|sweep|pareto|simulate|figures|train|batch|bench|info> [flags]
Reproduction of Aupy et al., 'Optimal Checkpointing Period: Time vs. Energy' (2013).

  optimize  optimal periods + time/energy trade-off for a scenario
            (--tiers <preset|grammar> evaluates it over a multi-level
            storage hierarchy; shared by sweep/pareto/simulate)
  sweep     CSV of T_final/E_final over a period grid
  pareto    time-energy Pareto frontier: knees, eps-constraint solves,
            optional Monte-Carlo validation, JSON artifact (--out);
            --family <presets|power-ratio> streams one artifact per scenario;
            --model first-order|exact[:ideal|:restarting] picks the
            objective backend (exact renewal vs the paper's closed forms)
  simulate  Monte-Carlo validation of the model on a scenario;
            --adaptive runs the online controller (any --policy,
            including knee and eps-time:<x>/eps-energy:<x> budgets,
            with --alpha/--hysteresis controller knobs, and
            --trace <path> writing a JSONL decision trace);
            --model retargets the frontier-aware policies and the
            model reference columns at the exact backend — note the
            simulated failure process is MODEL-MATCHED, not the
            realistic default: failures strike during D+R only under
            `--model exact` (= exact:restarting), so the table is an
            apples-to-apples validation of the selected objectives;
            --drift <spec|preset> runs the controller on a
            non-stationary environment (requires --adaptive)
  figures   regenerate every paper figure (incl. the frontier, the
            first-order-vs-exact knee drift, the adaptive policy
            comparison, the drift-tracking sweep, and the multi-level
            storage-tier comparison) as CSV
  train     fault-tolerant PJRT training run (--model as in simulate;
            --adaptive takes --alpha/--hysteresis, and --drift scales
            the failure injector's MTBF along the schedule)
  batch     answer a JSON-lines stream of scenario queries (stdin via
            --in -, a file, or --socket <path>): each line names a
            scenario (preset or inline params), a policy, a model
            backend, optional drift and a trajectory time `at`; answers
            stream to stdout in input order, malformed lines become
            {\"line\",\"error\"} records on stderr without killing the
            stream (see the serve module docs for the full protocol);
            a socket connection sending `GET /metrics` gets the
            Prometheus text exposition instead of a batch reply
  bench     standardised serving benchmark (cold/warm memo latency,
            queries/sec at 1/4/8 threads, grid-engine cell throughput)
            -> BENCH_<n>.json at the repo root (--quick for CI;
            --gate compares the two newest trajectory entries and fails
            on a >15% warm-path regression instead of benchmarking)
  info      artifact inventory + the unified cache/memo counter table
            (--metrics prints the full Prometheus text exposition)

Run a subcommand with --help for its flags.";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("optimize") => run(cmd_optimize(&argv[1..])),
        Some("sweep") => run(cmd_sweep(&argv[1..])),
        Some("pareto") => run(cmd_pareto(&argv[1..])),
        Some("simulate") => run(cmd_simulate(&argv[1..])),
        Some("figures") => run(cmd_figures(&argv[1..])),
        Some("train") => run(cmd_train(&argv[1..])),
        Some("batch") => run(cmd_batch(&argv[1..])),
        Some("bench") => run(cmd_bench(&argv[1..])),
        Some("info") => run(cmd_info(&argv[1..])),
        Some("--help") | Some("-h") | None => {
            println!("{USAGE}");
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand `{other}`\n{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn run(res: Result<(), String>) -> i32 {
    match res {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cli_err(e: CliError) -> String {
    match e {
        CliError::Help(text) => text,
        other => other.to_string(),
    }
}

/// Shared scenario flags.
const SCENARIO_SPECS: [ArgSpec; 9] = [
    ArgSpec::flag("c", "10", "checkpoint duration C (minutes)"),
    ArgSpec::flag("r", "10", "recovery duration R (minutes)"),
    ArgSpec::flag("d", "1", "downtime D (minutes)"),
    ArgSpec::flag("omega", "0.5", "checkpoint overlap factor in [0,1]"),
    ArgSpec::flag("mu", "300", "platform MTBF (minutes)"),
    ArgSpec::flag("t-base", "10000", "application duration T_base (minutes)"),
    ArgSpec::flag("rho", "5.5", "power ratio rho = (1+beta)/(1+alpha)"),
    ArgSpec::flag(
        "tiers",
        "",
        "storage hierarchy: a preset (tiers-1|tiers-2|tiers-3) or the raw tier \
         grammar; overrides C/R and the I/O draw with the hierarchy's projection",
    ),
    ArgSpec::flag("config", "", "JSON scenario file (overrides the flags above)"),
];

/// Map an unparseable `--tiers` value to a [`CliError`] with the full
/// grammar (and the preset names) in the message, mirroring `--drift`.
/// Raw grammar input is validated through [`TierHierarchy`] here so a
/// bad stack (too many levels, a non-positive cost) fails with the
/// same flag-scoped error as a syntax mistake.
fn parse_tiers_flag(raw: &str) -> Result<Vec<ckpt_period::storage::TierSpec>, String> {
    if let Some(preset) = tier_preset(raw) {
        return Ok(preset);
    }
    ckpt_period::storage::parse_tier_specs(raw)
        .and_then(|specs| {
            ckpt_period::storage::TierHierarchy::new(&specs)?;
            Ok(specs)
        })
        .map_err(|e| {
            let presets: Vec<&str> = tier_presets().iter().map(|(n, _)| *n).collect();
            cli_err(CliError::InvalidValue(
                "tiers".into(),
                raw.into(),
                format!(
                    "{e}; expected {} or a preset ({})",
                    ckpt_period::storage::TIER_GRAMMAR,
                    presets.join("|")
                ),
            ))
        })
}

fn scenario_from(args: &Args) -> Result<Scenario, String> {
    let cfg = args.get("config");
    if !cfg.is_empty() {
        let spec = ScenarioSpec::from_file(Path::new(cfg)).map_err(|e| e.to_string())?;
        return Ok(spec.scenario);
    }
    let ckpt = CheckpointParams::new(
        args.get_f64("c").map_err(cli_err)?,
        args.get_f64("r").map_err(cli_err)?,
        args.get_f64("d").map_err(cli_err)?,
        args.get_f64("omega").map_err(cli_err)?,
    )
    .map_err(|e| e.to_string())?;
    let power = PowerParams::from_rho(args.get_f64("rho").map_err(cli_err)?, 1.0, 0.0)
        .map_err(|e| e.to_string())?;
    let mu = args.get_f64("mu").map_err(cli_err)?;
    let t_base = args.get_f64("t-base").map_err(cli_err)?;
    let raw_tiers = args.get("tiers");
    if !raw_tiers.is_empty() {
        let tiers = parse_tiers_flag(raw_tiers)?;
        return Scenario::with_tier_specs(ckpt, power, mu, t_base, &tiers)
            .map_err(|e| e.to_string());
    }
    Scenario::new(ckpt, power, mu, t_base).map_err(|e| e.to_string())
}

fn cmd_optimize(argv: &[String]) -> Result<(), String> {
    let mut specs = SCENARIO_SPECS.to_vec();
    specs.push(ArgSpec::switch("msk", "also compare against the MSK baseline (omega=0)"));
    let args = Args::parse("optimize", "optimal periods for a scenario", &specs, argv)
        .map_err(cli_err)?;
    let s = scenario_from(&args)?;

    let tt = t_time_opt(&s).map_err(|e| e.to_string())?;
    let te = t_energy_opt(&s).map_err(|e| e.to_string())?;
    let cmp = compare(&s).map_err(|e| e.to_string())?;

    let mut t = Table::new(&["strategy", "period_min", "makespan_min", "energy_mW_min"]);
    for (name, period) in [
        ("AlgoT (Eq.1)", tt),
        ("AlgoE (quadratic)", te),
        ("Young", s.clamp_period(young(&s)).map_err(|e| e.to_string())?),
        ("Daly", s.clamp_period(daly(&s)).map_err(|e| e.to_string())?),
    ] {
        t.row(&[
            name.to_string(),
            fnum(period, 3),
            fnum(t_final(&s, period), 1),
            fnum(e_final(&s, period), 1),
        ]);
    }
    println!("{}", t.render());
    println!(
        "AlgoE vs AlgoT: energy gain {:.2}% for a time overhead of {:.2}%",
        cmp.energy_gain_pct(),
        cmp.time_overhead_pct()
    );
    if !s.first_order_ok() {
        println!("warning: C/D/R are not << mu; first-order approximations degrade");
    }
    if args.switch("msk") {
        if s.ckpt.omega == 0.0 {
            let m = compare_with_msk(&s).map_err(|e| e.to_string())?;
            println!(
                "MSK baseline: period {:.2} min (ours {:.2}); energy penalty at MSK's period: {:.2}%",
                m.t_msk, m.t_algo_e, m.penalty_pct
            );
        } else {
            println!("--msk requires --omega 0 (MSK models blocking checkpoints)");
        }
    }
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> Result<(), String> {
    let mut specs = SCENARIO_SPECS.to_vec();
    specs.push(ArgSpec::flag("points", "200", "grid points"));
    specs.push(ArgSpec::flag("out", "", "CSV output path (default: stdout table)"));
    specs.push(ArgSpec::switch("breakdown", "add waste-decomposition columns"));
    let args = Args::parse("sweep", "T_final/E_final over a period grid", &specs, argv)
        .map_err(cli_err)?;
    let s = scenario_from(&args)?;
    let n = args.get_usize("points").map_err(cli_err)?.max(2);
    let breakdown = args.switch("breakdown");
    let (lo, hi) = s.domain();
    let lo = s.min_period().max(lo * 1.01);
    let hi = hi * 0.99;

    let header: &[&str] = if breakdown {
        &[
            "period_min",
            "makespan_min",
            "energy_mW_min",
            "time_ckpt_min",
            "time_fail_min",
            "energy_ckpt",
            "energy_fail",
        ]
    } else {
        &["period_min", "makespan_min", "energy_mW_min"]
    };
    // One declarative grid: parallel on the persistent pool, memoised
    // across repeated invocations in the same process.
    let periods: Vec<f64> =
        (0..n).map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64).collect();
    let results = GridSpec::model_sweep(s, &periods, 1).evaluate();

    let mut t = Table::new(header);
    for (&period, r) in periods.iter().zip(&results) {
        let (tf, ef) = match r.output {
            CellOutput::Model { t_final, e_final } => (t_final, e_final),
            ref other => unreachable!("model sweep produced {other:?}"),
        };
        let mut row = vec![fnum(period, 3), fnum(tf, 2), fnum(ef, 2)];
        if breakdown {
            let w = ckpt_period::model::waste::waste_breakdown(&s, period);
            row.extend([
                fnum(w.time_checkpointing, 2),
                fnum(w.time_failures, 2),
                fnum(w.energy_checkpointing, 1),
                fnum(w.energy_failures, 1),
            ]);
        }
        t.row(&row);
    }
    let out = args.get("out");
    if out.is_empty() {
        println!("{}", t.render());
    } else {
        t.write_csv(Path::new(out)).map_err(|e| e.to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}

/// JSON shape shared by the single-scenario and family artifacts.
fn frontier_points_json(points: &[FrontierPoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("period_min", Json::Num(p.period)),
                    ("makespan_min", Json::Num(p.time)),
                    ("energy_mW_min", Json::Num(p.energy)),
                ])
            })
            .collect(),
    )
}

fn knee_json(k: &Option<Knee>) -> Json {
    match k {
        Some(k) => Json::obj(vec![
            ("period_min", Json::Num(k.point.period)),
            ("makespan_min", Json::Num(k.point.time)),
            ("energy_mW_min", Json::Num(k.point.energy)),
            ("score", Json::Num(k.score)),
        ]),
        None => Json::Null,
    }
}

fn cmd_pareto(argv: &[String]) -> Result<(), String> {
    let mut specs = SCENARIO_SPECS.to_vec();
    specs.push(ArgSpec::flag("points", "64", "frontier samples between the two optima"));
    specs.push(ArgSpec::flag(
        "family",
        "",
        "family mode: `presets` (the trade-off presets) or `power-ratio` \
         (an (alpha, beta, gamma) sweep at --mu); streams one JSON artifact \
         per scenario into --out-dir",
    ));
    specs.push(ArgSpec::flag("out-dir", "target/pareto", "artifact directory for --family"));
    specs.push(ArgSpec::flag(
        "eps-time",
        "",
        "time-overhead budget in % => minimise energy under it",
    ));
    specs.push(ArgSpec::flag(
        "eps-energy",
        "",
        "energy-overhead budget in % => minimise time under it",
    ));
    specs.push(ArgSpec::switch("simulate", "Monte-Carlo-validate the frontier"));
    specs.push(ArgSpec::flag("replicates", "200", "replicates per validated point"));
    specs.push(ArgSpec::flag("sim-points", "6", "frontier points to validate"));
    specs.push(ArgSpec::flag("seed", "1", "base seed for --simulate cells"));
    specs.push(ArgSpec::flag("out", "", "write the full frontier as a JSON artifact"));
    specs.push(ArgSpec::flag("table-rows", "12", "frontier rows printed to stdout"));
    specs.push(MODEL_SPEC);
    let args = Args::parse("pareto", "time-energy Pareto frontier of a scenario", &specs, argv)
        .map_err(cli_err)?;
    let backend = parse_model(args.get("model"))?;
    let family = args.get("family").to_string();
    if !family.is_empty() {
        return cmd_pareto_family(&args, &family, backend);
    }
    let s = scenario_from(&args)?;
    let points = args.get_usize("points").map_err(cli_err)?.max(2);
    let frontier = Frontier::compute(&s, points, backend).map_err(|e| e.to_string())?;

    let first = *frontier.time_opt_point();
    let last = *frontier.energy_opt_point();
    println!(
        "frontier: {} points (model {}), T in [{:.2}, {:.2}] min, hypervolume {:.4}",
        frontier.len(),
        backend.name(),
        frontier.t_time_opt,
        frontier.t_energy_opt,
        frontier.hypervolume()
    );
    println!(
        "endpoints: AlgoT ({:.1} min, {:.0} mW*min) -> AlgoE ({:.1} min, {:.0} mW*min): \
         {:.2}% energy gain for {:.2}% more time",
        first.time,
        first.energy,
        last.time,
        last.energy,
        (1.0 - last.energy / first.energy) * 100.0,
        (last.time / first.time - 1.0) * 100.0
    );

    let overhead_pct = |time: f64| (time / first.time - 1.0) * 100.0;
    let gain_pct = |energy: f64| (1.0 - energy / first.energy) * 100.0;

    let knees = [
        ("knee (max dist to chord)", frontier.knee(KneeMethod::MaxDistanceToChord)),
        ("knee (max curvature)", frontier.knee(KneeMethod::MaxCurvature)),
    ];
    for (label, knee) in &knees {
        match knee {
            Some(k) => println!(
                "{label}: T = {:.2} min -> {:.2}% energy gain for {:.2}% more time",
                k.point.period,
                gain_pct(k.point.energy),
                overhead_pct(k.point.time)
            ),
            None => println!("{label}: n/a (degenerate frontier)"),
        }
    }

    let max_rows = args.get_usize("table-rows").map_err(cli_err)?.max(2);
    let mut t = Table::new(&[
        "period_min",
        "makespan_min",
        "energy_mW_min",
        "time_overhead_pct",
        "energy_gain_pct",
    ]);
    let n = frontier.len();
    let shown = max_rows.min(n);
    for i in 0..shown {
        let idx = if shown == 1 { 0 } else { i * (n - 1) / (shown - 1) };
        let p = frontier.points()[idx];
        t.row(&[
            fnum(p.period, 3),
            fnum(p.time, 2),
            fnum(p.energy, 1),
            fnum(overhead_pct(p.time), 3),
            fnum(gain_pct(p.energy), 3),
        ]);
    }
    println!("{}", t.render());

    let eps_json = |sol: &EpsSolution| {
        Json::obj(vec![
            ("period_min", Json::Num(sol.period)),
            ("makespan_min", Json::Num(sol.time)),
            ("energy_mW_min", Json::Num(sol.energy)),
            ("bound", Json::Num(sol.bound)),
            ("binding", Json::Bool(sol.binding)),
        ])
    };
    let mut eps_entries: Vec<(&str, Json)> = Vec::new();
    if !args.get("eps-time").is_empty() {
        let eps = args.get_f64("eps-time").map_err(cli_err)?;
        if eps < 0.0 {
            return Err(format!("--eps-time must be >= 0, got {eps}"));
        }
        let sol = min_energy_with_time_overhead(&s, eps, backend).map_err(|e| e.to_string())?;
        println!(
            "eps-time {eps}%: min energy {:.1} mW*min at T = {:.2} min \
             ({:.2}% energy gain, {:.2}% time overhead, constraint {})",
            sol.energy,
            sol.period,
            gain_pct(sol.energy),
            overhead_pct(sol.time),
            if sol.binding { "binding" } else { "slack" }
        );
        eps_entries.push(("min_energy_given_time", eps_json(&sol)));
    }
    if !args.get("eps-energy").is_empty() {
        let eps = args.get_f64("eps-energy").map_err(cli_err)?;
        if eps < 0.0 {
            return Err(format!("--eps-energy must be >= 0, got {eps}"));
        }
        let sol = min_time_with_energy_overhead(&s, eps, backend).map_err(|e| e.to_string())?;
        println!(
            "eps-energy {eps}%: min makespan {:.1} min at T = {:.2} min \
             ({:.2}% energy gain, {:.2}% time overhead, constraint {})",
            sol.time,
            sol.period,
            gain_pct(sol.energy),
            overhead_pct(sol.time),
            if sol.binding { "binding" } else { "slack" }
        );
        eps_entries.push(("min_time_given_energy", eps_json(&sol)));
    }

    let mut sim_json = Json::Null;
    if args.switch("simulate") {
        let replicates = args.get_usize("replicates").map_err(cli_err)?.max(2);
        let sim_points = args.get_usize("sim-points").map_err(cli_err)?.max(2);
        let seed = args.get_u64("seed").map_err(cli_err)?;
        let v = validate(&frontier, sim_points, replicates, seed);
        let mut t = Table::new(&[
            "period_min",
            "model_makespan",
            "sim_makespan (95% CI half)",
            "model_energy",
            "sim_energy (95% CI half)",
            "agrees",
        ]);
        for p in &v.points {
            t.row(&[
                fnum(p.point.period, 2),
                fnum(p.point.time, 1),
                format!("{} ({})", fnum(p.sim.makespan_mean, 1), fnum(p.sim.makespan_ci95_half, 1)),
                fnum(p.point.energy, 1),
                format!("{} ({})", fnum(p.sim.energy_mean, 1), fnum(p.sim.energy_ci95_half, 1)),
                format!("{}", p.time_agrees && p.energy_agrees),
            ]);
        }
        println!("simulated frontier ({replicates} replicates per point):");
        println!("{}", t.render());
        println!(
            "analytic frontier {} the Monte-Carlo confidence bands",
            if v.all_agree() { "agrees with" } else { "DISAGREES with" }
        );
        // An array, like `frontier.points`: entries stay in frontier
        // order so consumers can zip the two by position.
        sim_json = Json::Arr(
            v.points
                .iter()
                .map(|p| {
                    Json::obj(vec![
                        ("period_min", Json::Num(p.point.period)),
                        ("sim_makespan_mean", Json::Num(p.sim.makespan_mean)),
                        ("sim_makespan_ci95_half", Json::Num(p.sim.makespan_ci95_half)),
                        ("sim_energy_mean", Json::Num(p.sim.energy_mean)),
                        ("sim_energy_ci95_half", Json::Num(p.sim.energy_ci95_half)),
                        // u64 seeds exceed f64's integer range;
                        // keep them exact as strings.
                        ("seed", Json::Str(p.seed.to_string())),
                        ("time_agrees", Json::Bool(p.time_agrees)),
                        ("energy_agrees", Json::Bool(p.energy_agrees)),
                    ])
                })
                .collect(),
        );
    }

    let out = args.get("out");
    if !out.is_empty() {
        let spec = ScenarioSpec { scenario: s, n_nodes: None };
        let points_json = frontier_points_json(frontier.points());
        let doc = Json::obj(vec![
            ("schema", Json::Str("ckpt-period/pareto-frontier/v1".into())),
            ("model", Json::Str(backend.name().into())),
            ("scenario", spec.to_json()),
            (
                "frontier",
                Json::obj(vec![
                    ("t_time_opt_min", Json::Num(frontier.t_time_opt)),
                    ("t_energy_opt_min", Json::Num(frontier.t_energy_opt)),
                    ("hypervolume", Json::Num(frontier.hypervolume())),
                    ("knee_chord", knee_json(&knees[0].1)),
                    ("knee_curvature", knee_json(&knees[1].1)),
                    ("points", points_json),
                ]),
            ),
            ("eps_constraints", Json::obj(eps_entries)),
            ("simulation", sim_json),
        ]);
        write_json_artifact(Path::new(out), &doc).map_err(|e| e.to_string())?;
        println!("frontier artifact written to {out}");
    }
    Ok(())
}

/// `pareto --family`: every scenario of a named family through
/// [`family_frontiers`] (parallel, memoised `CellJob::Frontier` cells),
/// one JSON artifact streamed out per scenario.
fn cmd_pareto_family(args: &Args, family: &str, backend: Backend) -> Result<(), String> {
    // The single-scenario extras have no meaning per family; silently
    // dropping them would hide that the user's solve never ran.
    for flag in ["eps-time", "eps-energy", "out"] {
        if !args.get(flag).is_empty() {
            return Err(format!(
                "--{flag} applies to single-scenario mode and is not supported with --family \
                 (run `pareto --config <scenario>` per scenario instead)"
            ));
        }
    }
    if args.switch("simulate") {
        return Err("--simulate is not supported with --family".into());
    }
    let points = args.get_usize("points").map_err(cli_err)?.max(2);
    let seed = args.get_u64("seed").map_err(cli_err)?;
    let out_dir = Path::new(args.get("out-dir")).to_path_buf();
    let scenarios: Vec<(String, Scenario)> = match family {
        "presets" => {
            tradeoff_presets().into_iter().map(|(l, s)| (l.to_string(), s)).collect()
        }
        "power-ratio" => {
            let mu = args.get_f64("mu").map_err(cli_err)?;
            power_ratio_sweep(mu, &[0.5, 1.0, 2.0], &[2.0, 6.0, 10.0, 15.0], &[0.0, 1.0])
        }
        other => {
            return Err(format!(
                "unknown family `{other}` (expected `presets` or `power-ratio`)"
            ))
        }
    };
    if scenarios.is_empty() {
        return Err("family has no in-domain scenarios at these parameters".into());
    }
    let frontiers = family_frontiers(scenarios, points, seed, backend);
    let mut written = 0usize;
    for f in &frontiers {
        let sum = match &f.summary {
            Ok(sum) => sum,
            // Surface the model error (out-of-domain reason) instead of
            // silently dropping the row.
            Err(e) => {
                println!("{}: skipped ({e})", f.label);
                continue;
            }
        };
        let path = out_dir.join(format!("{}.json", f.label));
        let doc = Json::obj(vec![
            ("schema", Json::Str("ckpt-period/pareto-frontier/v1".into())),
            ("model", Json::Str(backend.name().into())),
            ("family", Json::Str(family.to_string())),
            ("label", Json::Str(f.label.clone())),
            ("scenario", ScenarioSpec { scenario: f.scenario, n_nodes: None }.to_json()),
            (
                "frontier",
                Json::obj(vec![
                    ("t_time_opt_min", Json::Num(sum.t_time_opt)),
                    ("t_energy_opt_min", Json::Num(sum.t_energy_opt)),
                    ("hypervolume", Json::Num(sum.hypervolume)),
                    ("knee_chord", knee_json(&sum.knee_chord)),
                    ("knee_curvature", knee_json(&sum.knee_curvature)),
                    ("points", frontier_points_json(&sum.points)),
                ]),
            ),
        ]);
        write_json_artifact(&path, &doc).map_err(|e| e.to_string())?;
        written += 1;
        match sum.knee_chord.as_ref() {
            Some(k) => println!(
                "{}: {} points, hv {:.4}, knee T = {:.2} min \
                 ({:.2}% energy gain for {:.2}% more time) -> {}",
                f.label,
                sum.points.len(),
                sum.hypervolume,
                k.point.period,
                sum.energy_gain_pct(&k.point),
                sum.time_overhead_pct(&k.point),
                path.display()
            ),
            None => println!(
                "{}: {} points, hv {:.4}, degenerate frontier -> {}",
                f.label,
                sum.points.len(),
                sum.hypervolume,
                path.display()
            ),
        }
    }
    println!("{written} frontier artifacts written to {}", out_dir.display());
    Ok(())
}

fn cmd_simulate(argv: &[String]) -> Result<(), String> {
    let mut specs = SCENARIO_SPECS.to_vec();
    specs.push(ArgSpec::flag("period", "0", "period to simulate (0 = the policy's period)"));
    specs.push(ArgSpec::flag(
        "policy",
        "algo-t",
        "period policy: algo-t|algo-e|young|daly|fixed:<min>|knee|knee:curvature|\
         eps-time:<pct>|eps-energy:<pct>",
    ));
    specs.push(ArgSpec::switch(
        "adaptive",
        "simulate the online controller (re-estimates C/R/mu per sample path)",
    ));
    specs.push(ArgSpec::flag(
        "drift",
        "stationary",
        "environment drift schedule (adaptive only): a preset \
         (io-ramp|mu-decay|step-reconfig|contention-burst) or \
         step:...|ramp:...|contention:...|piecewise:...",
    ));
    specs.push(ArgSpec::flag(
        "alpha",
        ALPHA_FLAG_DEFAULT,
        "controller C/R EWMA smoothing in (0,1] (adaptive only)",
    ));
    specs.push(ArgSpec::flag(
        "hysteresis",
        HYSTERESIS_FLAG_DEFAULT,
        "controller period-space hysteresis band, >= 0 (adaptive only)",
    ));
    specs.push(ArgSpec::flag("replicates", "200", "Monte-Carlo replicates"));
    specs.push(ArgSpec::flag(
        "batch",
        "auto",
        "replicas per batched-executor pool job: auto|<n> with n >= 1 \
         (execution-shape knob; results are identical for every value)",
    ));
    specs.push(ArgSpec::flag("seed", "1", "base seed (cell seeds derive from it)"));
    specs.push(ArgSpec::flag(
        "trace",
        "",
        "write a JSONL decision trace (observe/period/failure/recovery \
         events per sample path) to this path (adaptive only; bypasses \
         the grid cell memo so every decision is re-emitted)",
    ));
    specs.push(MODEL_SPEC);
    let args = Args::parse("simulate", "Monte-Carlo validation of the model", &specs, argv)
        .map_err(cli_err)?;
    let s = scenario_from(&args)?;
    let backend = parse_model(args.get("model"))?;
    let policy = parse_policy(args.get("policy"))?.with_backend(backend);
    let reps = args.get_usize("replicates").map_err(cli_err)?;
    require_positive("replicates", reps as u64)?;
    apply_batch_flag(&args)?;
    let seed = args.get_u64("seed").map_err(cli_err)?;
    let knobs = ControllerKnobs::from_args(&args)?;
    // Mirrors the serve-layer rule (and the simulator's own assert):
    // the drain queue has no trajectory semantics yet.
    if s.hierarchy().is_some() && !knobs.drift.is_stationary() {
        return Err(cli_err(CliError::InvalidValue(
            "drift".into(),
            args.get("drift").into(),
            "tiered scenarios (--tiers) require a stationary drift schedule".into(),
        )));
    }
    let trace_path = args.get("trace");
    if args.switch("adaptive") {
        let tracing = !trace_path.is_empty();
        if tracing {
            ckpt_period::telemetry::trace::install(Path::new(trace_path))
                .map_err(|e| format!("installing trace {trace_path}: {e}"))?;
        }
        let out = cmd_simulate_adaptive(&s, policy, backend, reps, seed, knobs, tracing);
        if tracing {
            ckpt_period::telemetry::trace::finish();
            eprintln!("decision trace written to {trace_path}");
        }
        return out;
    }
    if !trace_path.is_empty() {
        return Err(
            "--trace records the online controller's decisions; pass --adaptive".into()
        );
    }
    if !knobs.is_default() {
        return Err(
            "--drift/--alpha/--hysteresis drive the online controller; pass --adaptive".into()
        );
    }
    let period = {
        let p = args.get_f64("period").map_err(cli_err)?;
        if p <= 0.0 {
            policy.period(&s).map_err(|e| e.to_string())?
        } else {
            p
        }
    };

    // A single Sim cell on the grid engine: replicates fan out on the
    // persistent pool, and re-running the same scenario in-process is a
    // cache hit. Simulate the failure process the selected model
    // actually assumes — the first-order forms and exact:ideal model
    // failure-free recovery, plain exact (restarting) models failures
    // striking during D + R — so the table is an apples-to-apples
    // validation (the convention `tests/sim_vs_model.rs` and
    // `pareto::validate` use).
    let failures_during_recovery = matches!(backend, Backend::Exact(RecoveryModel::Restarting));
    let mut spec = GridSpec::new(seed);
    spec.push(Cell {
        scenario: s,
        failure: None,
        job: CellJob::Sim { period, replicates: reps, failures_during_recovery },
    });
    let results = spec.evaluate();
    let mc = results[0].output.sim().expect("sim cell output");
    let (mk_lo, mk_hi) = mc.makespan_ci95();
    let (en_lo, en_hi) = mc.energy_ci95();
    let mut t = Table::new(&["quantity", "model", "simulated (95% CI)"]);
    t.row(&[
        "makespan_min".into(),
        fnum(backend.t_final(&s, period), 1),
        format!("{} [{}, {}]", fnum(mc.makespan_mean, 1), fnum(mk_lo, 1), fnum(mk_hi, 1)),
    ]);
    t.row(&[
        "energy_mW_min".into(),
        fnum(backend.e_final(&s, period), 1),
        format!("{} [{}, {}]", fnum(mc.energy_mean, 1), fnum(en_lo, 1), fnum(en_hi, 1)),
    ]);
    t.row(&[
        "failures".into(),
        fnum(backend.expected_failures(&s, period), 2),
        fnum(mc.failures_mean, 2),
    ]);
    println!("period = {period:.2} min, {reps} replicates, model {}", backend.name());
    println!("{}", t.render());
    Ok(())
}

/// Reject a zero count knob (`--replicates`, `--steps`) up front:
/// zero sample paths would make every downstream statistic undefined
/// and previously tripped an assert deep in the Monte-Carlo runner.
fn require_positive(flag: &str, n: u64) -> Result<(), String> {
    if n == 0 {
        return Err(cli_err(CliError::InvalidValue(
            flag.into(),
            "0".into(),
            "expected an integer >= 1".into(),
        )));
    }
    Ok(())
}

/// Parse `--batch auto|<n>` and install it process-wide for the
/// batched Monte-Carlo executor ([`ckpt_period::sim::batch`]).
fn apply_batch_flag(args: &Args) -> Result<(), String> {
    let raw = args.get("batch");
    if raw == "auto" {
        ckpt_period::sim::batch::set_batch_size(None);
        return Ok(());
    }
    match raw.parse::<usize>() {
        Ok(n) if n > 0 => {
            ckpt_period::sim::batch::set_batch_size(Some(n));
            Ok(())
        }
        _ => Err(cli_err(CliError::InvalidValue(
            "batch".into(),
            raw.into(),
            "expected 'auto' or an integer >= 1 (replicas per pool job)".into(),
        ))),
    }
}

/// Map an unparseable `--policy` value to a [`CliError`] with the full
/// grammar in the message.
fn parse_policy(raw: &str) -> Result<PeriodPolicy, String> {
    PeriodPolicy::parse(raw).ok_or_else(|| {
        cli_err(CliError::InvalidValue(
            "policy".into(),
            raw.into(),
            format!(
                "expected {} (fixed periods must be finite and > 0, \
                 eps budgets finite and >= 0)",
                PeriodPolicy::PARSE_HELP
            ),
        ))
    })
}

/// The shared `--model` flag: which objective backend evaluates
/// `T_final`/`E_final` and their optima.
const MODEL_SPEC: ArgSpec = ArgSpec::flag(
    "model",
    "first-order",
    "objective model: first-order (paper closed forms) | exact (renewal, \
     failures during recovery) | exact:ideal | exact:restarting",
);

/// Map an unparseable `--model` value to a [`CliError`] with the full
/// grammar in the message, mirroring the `--policy` error path.
fn parse_model(raw: &str) -> Result<Backend, String> {
    Backend::parse(raw).ok_or_else(|| {
        cli_err(CliError::InvalidValue(
            "model".into(),
            raw.into(),
            format!("expected {}", Backend::PARSE_HELP),
        ))
    })
}

/// The `--alpha`/`--hysteresis` flag defaults. These must render the
/// controller's `DEFAULT_EWMA_ALPHA`/`DEFAULT_HYSTERESIS` (a
/// `debug_assert` in [`ControllerKnobs::is_default`] ties the three
/// sources together in every test build); `is_default` parses these
/// same strings, so the default-detection — which routes between the
/// plain `AdaptiveRun` cell and the drift/oracle path — can never
/// drift from the declared flag defaults.
const ALPHA_FLAG_DEFAULT: &str = "0.3";
const HYSTERESIS_FLAG_DEFAULT: &str = "0.05";

/// The online controller's CLI knobs: the drift schedule and the
/// estimator tuning, validated once and passed as one unit (they only
/// mean something on the adaptive paths).
#[derive(Debug, Clone, Copy)]
struct ControllerKnobs {
    drift: DriftProcess,
    alpha: f64,
    hysteresis: f64,
}

impl ControllerKnobs {
    /// Parse the knobs for `simulate`: drift times in the scenario's
    /// minutes, named presets allowed.
    fn from_args(args: &Args) -> Result<Self, String> {
        Self::parse(args, true)
    }

    /// Parse the knobs for `train`: schedule times are wall-clock
    /// **seconds** there, so the minute-authored presets (timed against
    /// the simulation's `T_base` = 10 000 min) are rejected rather than
    /// silently running ~60x too fast — spell the schedule explicitly.
    fn from_args_seconds(args: &Args) -> Result<Self, String> {
        Self::parse(args, false)
    }

    fn parse(args: &Args, allow_presets: bool) -> Result<Self, String> {
        let raw_drift = args.get("drift");
        if !allow_presets && raw_drift != "stationary" && drift_preset(raw_drift).is_some() {
            return Err(cli_err(CliError::InvalidValue(
                "drift".into(),
                raw_drift.into(),
                "drift presets are authored in simulation minutes; `train` schedules \
                 run in wall-clock seconds — spell the schedule explicitly \
                 (e.g. ramp:0:600:mu=0.5)"
                    .into(),
            )));
        }
        let drift = parse_drift(raw_drift)?;
        let alpha = args.get_f64("alpha").map_err(cli_err)?;
        if !(alpha.is_finite() && alpha > 0.0 && alpha <= 1.0) {
            return Err(cli_err(CliError::InvalidValue(
                "alpha".into(),
                args.get("alpha").into(),
                "EWMA alpha must be finite and in (0, 1]".into(),
            )));
        }
        let hysteresis = args.get_f64("hysteresis").map_err(cli_err)?;
        if !(hysteresis.is_finite() && hysteresis >= 0.0) {
            return Err(cli_err(CliError::InvalidValue(
                "hysteresis".into(),
                args.get("hysteresis").into(),
                "hysteresis band must be finite and >= 0".into(),
            )));
        }
        Ok(ControllerKnobs { drift, alpha, hysteresis })
    }

    /// Whether every knob is at the `AdaptiveRun` default (stationary
    /// schedule, the controller's default α and band).
    fn is_default(&self) -> bool {
        let alpha_default: f64 = ALPHA_FLAG_DEFAULT.parse().expect("const parses");
        let hyst_default: f64 = HYSTERESIS_FLAG_DEFAULT.parse().expect("const parses");
        debug_assert_eq!(
            alpha_default,
            ckpt_period::coordinator::adaptive::DEFAULT_EWMA_ALPHA,
            "--alpha flag default diverged from the controller default"
        );
        debug_assert_eq!(
            hyst_default,
            ckpt_period::coordinator::adaptive::DEFAULT_HYSTERESIS,
            "--hysteresis flag default diverged from the controller default"
        );
        self.drift.is_stationary()
            && self.alpha == alpha_default
            && self.hysteresis == hyst_default
    }
}

/// Map an unparseable `--drift` value to a [`CliError`] with the full
/// grammar (and the preset names) in the message, mirroring
/// `--policy`/`--model`.
fn parse_drift(raw: &str) -> Result<DriftProcess, String> {
    if let Some(preset) = drift_preset(raw) {
        return Ok(preset);
    }
    DriftProcess::parse(raw).ok_or_else(|| {
        let presets: Vec<&str> = drift_presets().iter().map(|(n, _)| *n).collect();
        cli_err(CliError::InvalidValue(
            "drift".into(),
            raw.into(),
            format!("expected {} or a preset ({})", DriftProcess::PARSE_HELP, presets.join("|")),
        ))
    })
}

/// `simulate --adaptive`: one AdaptiveRun cell on the grid engine —
/// the online controller re-estimates (C, R, mu) along every sample
/// path and re-reads the policy period after each checkpoint/recovery.
/// With a drift schedule or non-default controller knobs the cell
/// becomes a DriftRun: the environment follows the trajectory and the
/// clairvoyant-oracle twin runs on the same seeds for the regret
/// columns.
fn cmd_simulate_adaptive(
    s: &Scenario,
    policy: PeriodPolicy,
    backend: Backend,
    reps: usize,
    seed: u64,
    knobs: ControllerKnobs,
    tracing: bool,
) -> Result<(), String> {
    // Match the failure process to the selected model's recovery
    // assumption, exactly like the non-adaptive path: the static-model
    // reference columns below come from `backend`, so the sample paths
    // must play by the same rules for the table to be comparable.
    let failures_during_recovery = matches!(backend, Backend::Exact(RecoveryModel::Restarting));
    if !knobs.is_default() {
        return cmd_simulate_drift(s, policy, backend, reps, seed, knobs, tracing);
    }
    let mut spec = GridSpec::new(seed);
    if tracing {
        // A memo-cached cell replays no decisions; tracing re-runs it.
        spec = spec.without_cache();
    }
    spec.push(Cell {
        scenario: *s,
        failure: None,
        job: CellJob::AdaptiveRun { policy, replicates: reps, failures_during_recovery },
    });
    let results = spec.evaluate();
    let mc = results[0]
        .output
        .adaptive()
        .ok_or("scenario has no feasible period (out of the model's domain)")?;

    // The static reference: the policy's period on the true scenario,
    // with the model columns evaluated by the selected backend.
    let static_period = policy.period(s).map_err(|e| e.to_string())?;
    let mut t = Table::new(&["quantity", "model @ static period", "adaptive sim (95% CI)"]);
    t.row(&[
        "period_min".into(),
        fnum(static_period, 2),
        format!("{} (final, mean)", fnum(mc.final_period_mean, 2)),
    ]);
    t.row(&[
        "makespan_min".into(),
        fnum(backend.t_final(s, static_period), 1),
        format!("{} ({})", fnum(mc.makespan_mean, 1), fnum(mc.makespan_ci95_half, 1)),
    ]);
    t.row(&[
        "energy_mW_min".into(),
        fnum(backend.e_final(s, static_period), 1),
        format!("{} ({})", fnum(mc.energy_mean, 1), fnum(mc.energy_ci95_half, 1)),
    ]);
    t.row(&[
        "failures".into(),
        fnum(backend.expected_failures(s, static_period), 2),
        fnum(mc.failures_mean, 2),
    ]);
    t.row(&["checkpoints".into(), String::new(), fnum(mc.checkpoints_mean, 1)]);
    t.row(&["period_updates".into(), String::new(), fnum(mc.period_updates_mean, 1)]);
    println!(
        "adaptive simulation: policy {}, model {}, {reps} replicates (prior mu = scenario mu)",
        policy.name(),
        backend.name()
    );
    println!("{}", t.render());
    Ok(())
}

/// `simulate --adaptive` with a drift schedule (or tuned controller
/// knobs): one DriftRun cell — the controller tracks the drifting
/// environment, the oracle twin pins the clairvoyant baseline.
fn cmd_simulate_drift(
    s: &Scenario,
    policy: PeriodPolicy,
    backend: Backend,
    reps: usize,
    seed: u64,
    knobs: ControllerKnobs,
    tracing: bool,
) -> Result<(), String> {
    // Drift tables simulate the *realistic* process (failures can
    // strike during D + R) regardless of --model — the same process
    // `figures drift` / drift.csv and its mirror-calibrated golden
    // bands use, so a CLI cell measures the same thing as a figure
    // cell. (--model still retargets the frontier-aware policy and the
    // indicative model reference column.)
    let failures_during_recovery = true;
    let mut spec = GridSpec::new(seed);
    if tracing {
        // A memo-cached cell replays no decisions; tracing re-runs it.
        spec = spec.without_cache();
    }
    spec.push(Cell {
        scenario: *s,
        failure: None,
        job: CellJob::DriftRun {
            policy,
            replicates: reps,
            failures_during_recovery,
            drift: knobs.drift,
            alpha: knobs.alpha,
            hysteresis: knobs.hysteresis,
        },
    });
    let results = spec.evaluate();
    let sum = results[0].output.drift().ok_or(
        "no feasible period: either the scenario is out of the model's domain \
         already, or the drift schedule's worst corner leaves it",
    )?;
    let mc = &sum.adaptive;

    // The static reference: the policy's period on the base (t = 0)
    // scenario, model columns from the selected backend.
    let static_period = policy.period(s).map_err(|e| e.to_string())?;
    let mut t = Table::new(&["quantity", "model @ base scenario", "adaptive sim (95% CI)"]);
    t.row(&[
        "period_min".into(),
        fnum(static_period, 2),
        format!("{} (final, mean)", fnum(mc.final_period_mean, 2)),
    ]);
    t.row(&[
        "makespan_min".into(),
        fnum(backend.t_final(s, static_period), 1),
        format!("{} ({})", fnum(mc.makespan_mean, 1), fnum(mc.makespan_ci95_half, 1)),
    ]);
    t.row(&[
        "energy_mW_min".into(),
        fnum(backend.e_final(s, static_period), 1),
        format!("{} ({})", fnum(mc.energy_mean, 1), fnum(mc.energy_ci95_half, 1)),
    ]);
    t.row(&["failures".into(), String::new(), fnum(mc.failures_mean, 2)]);
    t.row(&["checkpoints".into(), String::new(), fnum(mc.checkpoints_mean, 1)]);
    t.row(&["period_updates".into(), String::new(), fnum(mc.period_updates_mean, 1)]);
    t.row(&["tracking_lag_pct".into(), String::new(), fnum(mc.tracking_lag_pct_mean, 3)]);
    t.row(&["drift_lag_pct".into(), String::new(), fnum(mc.drift_lag_pct_mean, 3)]);
    t.row(&[
        "oracle_makespan_min".into(),
        String::new(),
        fnum(sum.oracle_makespan_mean, 1),
    ]);
    t.row(&["waste_regret_pct".into(), String::new(), fnum(sum.waste_regret_pct, 3)]);
    t.row(&["energy_regret_pct".into(), String::new(), fnum(sum.energy_regret_pct, 3)]);
    println!(
        "adaptive drift simulation: policy {}, model {}, drift {}, alpha {}, band {}, \
         {reps} replicates (oracle twin on the same seeds)",
        policy.name(),
        backend.name(),
        knobs.drift.render(),
        knobs.alpha,
        knobs.hysteresis
    );
    println!("{}", t.render());
    Ok(())
}

fn cmd_figures(argv: &[String]) -> Result<(), String> {
    let specs = [
        ArgSpec::flag("out-dir", "target/figures", "output directory"),
        ArgSpec::flag("points", "60", "points per axis"),
    ];
    let args = Args::parse("figures", "regenerate all paper figures", &specs, argv)
        .map_err(cli_err)?;
    let dir = Path::new(args.get("out-dir")).to_path_buf();
    let n = args.get_usize("points").map_err(cli_err)?.max(4);

    let f1 = figures::fig1::series(&figures::fig1::rho_grid(n));
    figures::persist(&figures::fig1::table(&f1), &dir, "fig1").map_err(|e| e.to_string())?;

    let f2 =
        figures::fig2::grid(&figures::fig2::mu_grid(n / 2), &figures::fig2::rho_grid(n / 2));
    figures::persist(&figures::fig2::table(&f2), &dir, "fig2").map_err(|e| e.to_string())?;

    for (rho, name) in [(5.5, "fig3a"), (7.0, "fig3b")] {
        let pts = figures::fig3::series(rho, &figures::fig3::node_grid(n));
        figures::persist(&figures::fig3::table(&pts), &dir, name)
            .map_err(|e| e.to_string())?;
        let (gain, at) = figures::fig3::peak_energy_gain(&pts);
        println!("{name}: peak energy gain {gain:.1}% at N = {at:.2e}");
    }

    let fr = figures::frontier::series(n);
    figures::persist(&figures::frontier::table(&fr), &dir, "frontier")
        .map_err(|e| e.to_string())?;
    figures::persist(&figures::frontier::knee_table(&fr), &dir, "frontier_knees")
        .map_err(|e| e.to_string())?;
    for (label, gain, overhead) in figures::frontier::knee_headlines(&fr) {
        println!("frontier knee [{label}]: {gain:.1}% energy gain for {overhead:.1}% more time");
    }

    let kd = figures::knee_drift::series();
    figures::persist(&figures::knee_drift::table(&kd), &dir, "knee_drift")
        .map_err(|e| e.to_string())?;
    for (label, drift) in figures::knee_drift::headlines(&kd, 5.0) {
        println!("knee drift [{label}]: exact knee {drift:+.1}% vs first-order");
    }

    let dr = figures::drift::series(24);
    figures::persist(&figures::drift::table(&dr), &dir, "drift").map_err(|e| e.to_string())?;
    for (family, lag, regret) in figures::drift::headlines(&dr) {
        println!(
            "drift tracking [{family}]: lag {lag:.2}% vs the moving knee, \
             waste regret {regret:+.3}% of T_base vs the oracle"
        );
    }

    let ti = figures::tiers::series(n);
    figures::persist(&figures::tiers::table(&ti), &dir, "tiers").map_err(|e| e.to_string())?;
    for (base, tname, dt, de) in figures::tiers::knee_shifts(&ti) {
        println!("tiers knee [{base}+{tname}]: time {dt:+.1}% / energy {de:+.1}% vs tiers-1");
    }

    let ad = figures::adaptive::series(64);
    figures::persist(&figures::adaptive::table(&ad), &dir, "adaptive")
        .map_err(|e| e.to_string())?;
    for (label, knee_waste, algoe_waste, knee_energy, algot_energy) in
        figures::adaptive::knee_headlines(&ad)
    {
        println!(
            "adaptive knee [{label}]: waste {knee_waste:.1}% (AlgoE {algoe_waste:.1}%), \
             energy overhead {knee_energy:.1}% (AlgoT {algot_energy:.1}%)"
        );
    }

    let h = figures::headline::compute();
    println!(
        "headline: mu=300 rho=5.5 -> {:.1}% energy gain / {:.1}% time overhead",
        h.energy_gain_mu300_rho55_pct, h.time_overhead_mu300_rho55_pct
    );
    // Counters are process-local, so this is where the drift grid's
    // memo churn is actually observable (a fresh `info` process would
    // report zeros).
    print_memo_stats();
    println!("figures written to {}", dir.display());
    Ok(())
}

/// The unified cache/memo counter table (process-local), registry-
/// driven: every cache surface — grid cells, the two pure-function
/// memos, the serve answer cache — reports the same columns through
/// [`ckpt_period::telemetry::cache_rows`]. Drift runs re-key the
/// online memo once per distinct quantised estimate, so the clears
/// column is the churn signal to watch.
fn print_memo_stats() {
    println!("memo caches (this process):");
    let mut t = Table::new(&["cache", "entries", "hits", "misses", "clears", "hit rate"]);
    for r in ckpt_period::telemetry::cache_rows() {
        t.row(&[
            r.name.into(),
            format!("{}", r.entries),
            format!("{}", r.hits),
            format!("{}", r.misses),
            format!("{}", r.clears),
            format!("{:.1}%", r.hit_rate() * 100.0),
        ]);
    }
    println!("{}", t.render());
}

fn cmd_train(argv: &[String]) -> Result<(), String> {
    let specs = [
        ArgSpec::flag("artifacts", "artifacts", "artifacts directory"),
        ArgSpec::flag("ckpt-dir", "target/ckpt", "checkpoint directory"),
        ArgSpec::flag(
            "policy",
            "algo-t",
            "algo-t|algo-e|young|daly|fixed:<s>|knee|knee:curvature|eps-time:<pct>|eps-energy:<pct>",
        ),
        ArgSpec::flag("steps", "200", "training steps"),
        ArgSpec::flag("mu", "30", "MTBF in wall-clock seconds"),
        ArgSpec::flag("downtime", "0.1", "downtime in seconds"),
        ArgSpec::flag("seed", "1", "data + failure seed"),
        ArgSpec::switch("blocking", "blocking checkpoints (omega = 0)"),
        ArgSpec::switch("no-failures", "disable failure injection"),
        ArgSpec::switch("adaptive", "re-estimate C/R/mu online and adapt the period"),
        ArgSpec::flag(
            "drift",
            "stationary",
            "failure-rate drift schedule (mu component only; times in \
             wall-clock SECONDS, so the minute-authored presets are \
             rejected): the --drift grammar, e.g. ramp:0:600:mu=0.5",
        ),
        ArgSpec::flag(
            "alpha",
            ALPHA_FLAG_DEFAULT,
            "controller C/R EWMA smoothing in (0,1] (adaptive)",
        ),
        ArgSpec::flag(
            "hysteresis",
            HYSTERESIS_FLAG_DEFAULT,
            "controller hysteresis band, >= 0 (adaptive)",
        ),
        ArgSpec::flag("report", "", "write the JSON run report here"),
        MODEL_SPEC,
    ];
    let args = Args::parse("train", "fault-tolerant PJRT training run", &specs, argv)
        .map_err(cli_err)?;

    let knobs = ControllerKnobs::from_args_seconds(&args)?;
    let mut cfg = CoordinatorConfig::new(args.get("artifacts"), args.get("ckpt-dir"));
    cfg.policy = parse_policy(args.get("policy"))?
        .with_backend(parse_model(args.get("model"))?);
    cfg.steps = args.get_u64("steps").map_err(cli_err)?;
    require_positive("steps", cfg.steps)?;
    cfg.mu_s = args.get_f64("mu").map_err(cli_err)?;
    cfg.downtime_s = args.get_f64("downtime").map_err(cli_err)?;
    cfg.data_seed = args.get_u64("seed").map_err(cli_err)?;
    cfg.failure_seed = cfg.data_seed + 1;
    if args.switch("blocking") {
        cfg.overlap = OverlapMode::Blocking;
    }
    cfg.inject_failures = !args.switch("no-failures");
    cfg.adaptive = args.switch("adaptive");
    cfg.drift = knobs.drift;
    cfg.ewma_alpha = knobs.alpha;
    cfg.hysteresis = knobs.hysteresis;

    let rt = Runtime::cpu().map_err(|e| e.to_string())?;
    let coord = Coordinator::new(&rt, cfg).map_err(|e| e.to_string())?;
    let report = coord.run().map_err(|e| e.to_string())?;

    let mut t = Table::new(&["metric", "value"]);
    t.row(&["policy".into(), report.policy.clone()]);
    t.row(&["period_s".into(), fnum(report.period_s, 3)]);
    t.row(&["measured C_s".into(), fnum(report.measured_c_s, 4)]);
    t.row(&["measured R_s".into(), fnum(report.measured_r_s, 4)]);
    t.row(&["step_s".into(), fnum(report.step_s, 4)]);
    t.row(&["makespan_s".into(), fnum(report.makespan_s, 2)]);
    t.row(&["energy".into(), fnum(report.energy.total, 1)]);
    t.row(&["failures".into(), format!("{}", report.n_failures)]);
    t.row(&["checkpoints".into(), format!("{}", report.n_checkpoints)]);
    t.row(&["steps_executed".into(), format!("{}", report.steps_executed)]);
    t.row(&["re_exec_fraction".into(), fnum(report.re_exec_fraction(), 4)]);
    t.row(&["omega_measured".into(), fnum(report.omega_measured, 3)]);
    t.row(&[
        "final_loss".into(),
        report.final_loss().map(|l| fnum(l as f64, 4)).unwrap_or_default(),
    ]);
    println!("{}", t.render());

    let out = args.get("report");
    if !out.is_empty() {
        std::fs::write(out, report.to_json().to_string_pretty()).map_err(|e| e.to_string())?;
        println!("report written to {out}");
    }
    Ok(())
}

/// One answered batch, ready for any transport: answer/error JSON lines
/// tagged with their input line numbers, plus the binary wire encoding.
struct BatchOutcome {
    answers: Vec<(usize, Json)>,
    errors: Vec<(usize, Json)>,
    unique: usize,
    wire: Vec<u8>,
}

/// Parse + dedup + solve one JSON-lines batch. Parse errors and solve
/// errors land in the same per-line record stream; answers keep input
/// order. Never fails: an unanswerable batch is all error records.
fn run_batch(input: &str) -> BatchOutcome {
    use ckpt_period::telemetry::registry::metrics::{
        SERVE_BATCHES_TOTAL, SERVE_PARSE_NS, SERVE_QUERIES_REJECTED_TOTAL,
    };
    SERVE_BATCHES_TOTAL.inc();
    let (tagged, parse_errors) = {
        let _span = ckpt_period::telemetry::Span::start(&SERVE_PARSE_NS);
        ckpt_period::serve::parse_lines(input)
    };
    SERVE_QUERIES_REJECTED_TOTAL.add(parse_errors.len() as u64);
    let queries: Vec<Query> = tagged.iter().map(|(_, q)| q.clone()).collect();
    let unique = BatchEngine::unique_count(&queries);
    let results = BatchEngine::new().answer_all(&queries);
    let wire = ckpt_period::serve::wire::encode(&results);
    let mut answers = Vec::with_capacity(queries.len());
    let mut errors: Vec<(usize, Json)> =
        parse_errors.iter().map(|r| (r.line, r.to_json())).collect();
    for ((line, q), res) in tagged.iter().zip(&results) {
        match res {
            Ok(a) => answers.push((*line, answer_json(*line, q, a))),
            Err(e) => {
                let rec = ErrorRecord { line: *line, error: e.to_string() };
                errors.push((*line, rec.to_json()));
            }
        }
    }
    errors.sort_by_key(|(l, _)| *l);
    BatchOutcome { answers, errors, unique, wire }
}

/// One answer line: correlation fields first (line, id, the echoed
/// query spellings), then the solved columns in the `optimize` table's
/// units.
fn answer_json(line: usize, q: &Query, a: &Answer) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![("line", Json::Num(line as f64))];
    if let Some(id) = &q.id {
        fields.push(("id", Json::Str(id.clone())));
    }
    if let Some(label) = &q.label {
        fields.push(("scenario", Json::Str(label.clone())));
    }
    fields.push(("policy", Json::Str(q.policy_spec())));
    fields.push(("model", Json::Str(q.backend.name().into())));
    if !q.drift.is_stationary() {
        fields.push(("drift", Json::Str(q.drift.render())));
        fields.push(("at", Json::Num(q.at)));
    }
    fields.push(("period_min", Json::Num(a.period)));
    fields.push(("makespan_min", Json::Num(a.t_final)));
    fields.push(("energy_mW_min", Json::Num(a.e_final)));
    fields.push(("t_time_opt_min", Json::Num(a.t_time_opt)));
    fields.push(("t_energy_opt_min", Json::Num(a.t_energy_opt)));
    fields.push(("time_overhead_pct", Json::Num(a.time_overhead_pct)));
    fields.push(("energy_gain_pct", Json::Num(a.energy_gain_pct)));
    Json::obj(fields)
}

fn cmd_batch(argv: &[String]) -> Result<(), String> {
    let specs = [
        ArgSpec::flag("in", "-", "query stream: '-' for stdin, else a file path"),
        ArgSpec::flag(
            "socket",
            "",
            "long-lived mode: serve batches from a Unix socket at this \
             path, one JSON-lines batch per connection (overrides --in); \
             a connection sending `GET /metrics` gets the Prometheus \
             text exposition",
        ),
        ArgSpec::flag("out", "", "also write answers + error records as a JSON artifact"),
        ArgSpec::flag(
            "bin-out",
            "",
            "also write the answers as a CKPTSRV1 fixed-offset binary artifact",
        ),
    ];
    let args = Args::parse("batch", "answer a JSON-lines query batch", &specs, argv)
        .map_err(cli_err)?;
    let socket = args.get("socket");
    if !socket.is_empty() {
        return serve_socket(socket);
    }
    let input = match args.get("in") {
        "-" => {
            use std::io::Read;
            let mut buf = String::new();
            std::io::stdin()
                .read_to_string(&mut buf)
                .map_err(|e| format!("reading stdin: {e}"))?;
            buf
        }
        path => std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?,
    };
    let outcome = run_batch(&input);
    // stdout carries only answer lines (input order); stderr only error
    // records plus the summary — the two streams consume independently.
    for (_, doc) in &outcome.answers {
        println!("{}", doc.to_string_compact());
    }
    for (_, rec) in &outcome.errors {
        eprintln!("{}", rec.to_string_compact());
    }
    eprintln!(
        "answered {} queries ({} unique solves), {} errors",
        outcome.answers.len(),
        outcome.unique,
        outcome.errors.len()
    );
    let out = args.get("out");
    if !out.is_empty() {
        let doc = Json::obj(vec![
            ("schema", Json::Str("ckpt-period/serve-batch/v1".into())),
            ("answered", Json::Num(outcome.answers.len() as f64)),
            ("unique_solves", Json::Num(outcome.unique as f64)),
            ("answers", Json::Arr(outcome.answers.iter().map(|(_, j)| j.clone()).collect())),
            ("errors", Json::Arr(outcome.errors.iter().map(|(_, j)| j.clone()).collect())),
        ]);
        write_json_artifact(Path::new(out), &doc).map_err(|e| e.to_string())?;
        eprintln!("batch artifact written to {out}");
    }
    let bin_out = args.get("bin-out");
    if !bin_out.is_empty() {
        write_binary_artifact(Path::new(bin_out), &outcome.wire).map_err(|e| e.to_string())?;
        eprintln!("binary answers written to {bin_out}");
    }
    Ok(())
}

/// The long-lived serving loop: one JSON-lines batch per connection,
/// answers and error records merged back by line number on the same
/// stream (error records are the objects carrying an `error` key).
/// Caches stay warm across connections — that is the point of the
/// long-lived process.
#[cfg(unix)]
fn serve_socket(path: &str) -> Result<(), String> {
    use std::io::{Read, Write};
    use std::os::unix::net::UnixListener;
    // A stale socket file from a previous run would make bind fail.
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path).map_err(|e| format!("bind {path}: {e}"))?;
    eprintln!(
        "serving on {path} (one JSON-lines batch per connection; \
         `GET /metrics` for the exposition; ctrl-c to stop)"
    );
    for conn in listener.incoming() {
        let mut stream = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("accept: {e}");
                continue;
            }
        };
        let mut input = String::new();
        if let Err(e) = stream.read_to_string(&mut input) {
            eprintln!("read: {e}");
            continue;
        }
        // A metrics scrape: an HTTP-style request line instead of a
        // batch. The reply is the bare text exposition (no HTTP
        // framing — the transport is a one-shot Unix socket, so the
        // scraper reads to EOF like every batch client).
        if input.trim_start().starts_with("GET /metrics") {
            let body = ckpt_period::telemetry::render::prometheus();
            if let Err(e) = stream.write_all(body.as_bytes()) {
                eprintln!("write: {e}");
            }
            eprintln!("served metrics exposition ({} bytes)", body.len());
            continue;
        }
        let outcome = run_batch(&input);
        let (answered, unique, n_errors) =
            (outcome.answers.len(), outcome.unique, outcome.errors.len());
        let mut lines = outcome.answers;
        lines.extend(outcome.errors);
        lines.sort_by_key(|(l, _)| *l);
        let mut reply = String::new();
        for (_, doc) in &lines {
            reply.push_str(&doc.to_string_compact());
            reply.push('\n');
        }
        if let Err(e) = stream.write_all(reply.as_bytes()) {
            eprintln!("write: {e}");
        }
        eprintln!("answered {answered} queries ({unique} unique solves), {n_errors} errors");
    }
    Ok(())
}

#[cfg(not(unix))]
fn serve_socket(_path: &str) -> Result<(), String> {
    Err("--socket requires a Unix platform (use --in on this one)".into())
}

/// The git work-tree root, so `bench` lands `BENCH_<n>.json` next to
/// the previous entries of the trajectory no matter the cwd; falls back
/// to `.` outside a work tree.
fn repo_root() -> PathBuf {
    std::process::Command::new("git")
        .args(["rev-parse", "--show-toplevel"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| PathBuf::from(s.trim()))
        .filter(|p| p.is_dir())
        .unwrap_or_else(|| PathBuf::from("."))
}

fn cmd_bench(argv: &[String]) -> Result<(), String> {
    let specs = [
        ArgSpec::switch("quick", "shrink every workload (sets CKPT_BENCH_QUICK; CI mode)"),
        ArgSpec::switch(
            "gate",
            "compare the two newest BENCH_<n>.json instead of benchmarking: fail on a \
             >15% warm-path regression, skip cleanly across schema changes (CI gate)",
        ),
        ArgSpec::flag(
            "out-dir",
            "",
            "directory for BENCH_<n>.json (default: the git work-tree root, else `.`)",
        ),
    ];
    let args =
        Args::parse("bench", "standardised serving benchmark -> BENCH_<n>.json", &specs, argv)
            .map_err(cli_err)?;
    if args.switch("quick") {
        std::env::set_var("CKPT_BENCH_QUICK", "1");
    }
    let dir = match args.get("out-dir") {
        "" => repo_root(),
        d => PathBuf::from(d),
    };
    if args.switch("gate") {
        for line in ckpt_period::serve::bench::gate_trajectory(&dir)? {
            println!("{line}");
        }
        return Ok(());
    }
    let doc = ckpt_period::serve::bench::run_bench();
    // First unused index: the perf trajectory appends, never overwrites.
    let mut n = 0u32;
    let path = loop {
        let p = dir.join(format!("BENCH_{n}.json"));
        if !p.exists() {
            break p;
        }
        n += 1;
    };
    write_json_artifact(&path, &doc).map_err(|e| e.to_string())?;
    print_memo_stats();
    println!("bench results written to {}", path.display());
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<(), String> {
    let specs = [
        ArgSpec::flag("artifacts", "artifacts", "artifacts directory"),
        ArgSpec::switch(
            "metrics",
            "print the full Prometheus text exposition of the telemetry \
             registry instead of the summary view",
        ),
    ];
    let args = Args::parse("info", "artifact inventory", &specs, argv).map_err(cli_err)?;
    if args.switch("metrics") {
        print!("{}", ckpt_period::telemetry::render::prometheus());
        return Ok(());
    }
    match ArtifactDir::open(args.get("artifacts")) {
        Ok(dir) => {
            println!("artifacts at {}", dir.root().display());
            println!(
                "  model: {} params, batch {} x seq {}, vocab {}, lr {}",
                dir.n_params, dir.batch, dir.seq, dir.vocab, dir.lr
            );
            println!("  sweep grid: {} periods", dir.sweep_grid_n);
            println!("  parameter manifest: {} tensors", dir.manifest.len());
        }
        Err(e) => {
            // Missing artifacts are not an error for `info`: the model /
            // simulator / figures side of the binary is fully usable
            // without them.
            println!("artifacts: unavailable ({e})");
            println!("  model: params unavailable — run `make artifacts`");
            println!("  sweep grid: unavailable");
        }
    }
    // The reference scenario, for orientation.
    let cmp = compare(&fig1_scenario(300.0, 5.5)).map_err(|e| e.to_string())?;
    println!(
        "reference scenario (mu=300, rho=5.5): AlgoT {:.1} min, AlgoE {:.1} min",
        cmp.t_time, cmp.t_energy
    );
    print_memo_stats();
    Ok(())
}
