//! Layer-3 coordinator: a fault-tolerant training runtime that applies
//! the paper's checkpoint-period policies to a real PJRT workload.
//!
//! Topology (std threads + mpsc; no tokio in the offline vendor set):
//!
//! ```text
//!  leader (control loop, real wall-clock)
//!    ├── trainer           one PJRT train_step call per step (in-loop)
//!    ├── checkpoint writer thread — serializes snapshots to disk;
//!    │                     non-blocking mode lets training continue
//!    │                     while the write is in flight (this IS the
//!    │                     paper's ω-overlap, measured not assumed)
//!    └── failure injector  pre-drawn exponential schedule; on firing,
//!                          the leader discards live state, pays a
//!                          downtime D, restores the last durable
//!                          checkpoint (recovery R) and replays
//! ```
//!
//! Energy is accounted per phase with the paper's power model
//! ([`crate::energy`]); the run report carries everything EXPERIMENTS.md
//! needs (makespan, energy breakdown, loss curve, measured C/R/ω).
//!
//! * [`checkpoint`] — durable checkpoint store (CRC-protected binary
//!   format, atomic rename, async writer thread).
//! * [`policy`] — period policies: AlgoT (Eq. 1), AlgoE (quadratic),
//!   Young, Daly, fixed, the Pareto knee, and the ε-constraint budgets
//!   (`eps-time` / `eps-energy`, via [`crate::pareto`]).
//! * [`injector`] — reproducible failure schedules in wall-clock seconds.
//! * [`leader`] — the control loop.
//! * [`report`] — structured run results (+ JSON).

pub mod adaptive;
pub mod checkpoint;
pub mod injector;
pub mod leader;
pub mod policy;
pub mod report;

pub use adaptive::AdaptiveController;
pub use checkpoint::{AsyncCheckpointWriter, CheckpointStore};
pub use injector::FailureSchedule;
pub use leader::{Coordinator, CoordinatorConfig, OverlapMode};
pub use policy::PeriodPolicy;
pub use report::RunReport;
