//! Structured results of a coordinator run.

use crate::energy::EnergyBreakdown;
use crate::util::json::Json;

/// One logged event (failure, checkpoint, restore…).
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Seconds from run start.
    pub at: f64,
    pub kind: EventKind,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    CheckpointBegun { step: f32 },
    CheckpointDone { step: f32, seconds: f64 },
    Failure,
    Restored { step: f32, seconds: f64 },
    RestartedFromScratch,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::CheckpointBegun { .. } => "checkpoint_begun",
            EventKind::CheckpointDone { .. } => "checkpoint_done",
            EventKind::Failure => "failure",
            EventKind::Restored { .. } => "restored",
            EventKind::RestartedFromScratch => "restarted_from_scratch",
        }
    }
}

/// Everything a run produces; EXPERIMENTS.md tables are printed from
/// this, and `to_json` feeds machine-readable logs.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub policy: String,
    /// Chosen checkpoint period (seconds).
    pub period_s: f64,
    /// Calibration measurements (seconds).
    pub measured_c_s: f64,
    pub measured_r_s: f64,
    pub step_s: f64,
    /// ω used for the period computation and the ω actually measured
    /// (steps completed inside checkpoint windows / window capacity).
    pub omega_assumed: f64,
    pub omega_measured: f64,
    /// Wall-clock makespan (seconds).
    pub makespan_s: f64,
    /// Phase durations (seconds).
    pub compute_s: f64,
    pub checkpoint_s: f64,
    pub recovery_s: f64,
    pub down_s: f64,
    pub energy: EnergyBreakdown,
    pub n_failures: u64,
    pub n_checkpoints: u64,
    /// Steps executed including re-execution after rollbacks.
    pub steps_executed: u64,
    /// Target steps (the workload's `T_base` in step units).
    pub steps_target: u64,
    /// (step, loss) samples.
    pub losses: Vec<(f32, f32)>,
    pub events: Vec<Event>,
    /// Model predictions for this run's scenario (for side-by-side).
    pub predicted_makespan_s: f64,
    pub predicted_energy: f64,
}

impl RunReport {
    /// Fraction of executed steps that were re-execution.
    pub fn re_exec_fraction(&self) -> f64 {
        if self.steps_executed == 0 {
            return 0.0;
        }
        1.0 - self.steps_target as f64 / self.steps_executed as f64
    }

    pub fn final_loss(&self) -> Option<f32> {
        self.losses.last().map(|&(_, l)| l)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::Str(self.policy.clone())),
            ("period_s", Json::Num(self.period_s)),
            ("measured_c_s", Json::Num(self.measured_c_s)),
            ("measured_r_s", Json::Num(self.measured_r_s)),
            ("step_s", Json::Num(self.step_s)),
            ("omega_assumed", Json::Num(self.omega_assumed)),
            ("omega_measured", Json::Num(self.omega_measured)),
            ("makespan_s", Json::Num(self.makespan_s)),
            ("compute_s", Json::Num(self.compute_s)),
            ("checkpoint_s", Json::Num(self.checkpoint_s)),
            ("recovery_s", Json::Num(self.recovery_s)),
            ("down_s", Json::Num(self.down_s)),
            ("energy_total", Json::Num(self.energy.total)),
            ("energy_static", Json::Num(self.energy.static_e)),
            ("energy_cal", Json::Num(self.energy.cal_e)),
            ("energy_io", Json::Num(self.energy.io_e)),
            ("energy_down", Json::Num(self.energy.down_e)),
            ("n_failures", Json::Num(self.n_failures as f64)),
            ("n_checkpoints", Json::Num(self.n_checkpoints as f64)),
            ("steps_executed", Json::Num(self.steps_executed as f64)),
            ("steps_target", Json::Num(self.steps_target as f64)),
            ("re_exec_fraction", Json::Num(self.re_exec_fraction())),
            ("predicted_makespan_s", Json::Num(self.predicted_makespan_s)),
            ("predicted_energy", Json::Num(self.predicted_energy)),
            (
                "losses",
                Json::Arr(
                    self.losses
                        .iter()
                        .map(|&(s, l)| Json::arr_f64(&[s as f64, l as f64]))
                        .collect(),
                ),
            ),
            (
                "events",
                Json::Arr(
                    self.events
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("at", Json::Num(e.at)),
                                ("kind", Json::Str(e.kind.name().into())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> RunReport {
        RunReport {
            policy: "algo-t".into(),
            period_s: 5.0,
            measured_c_s: 0.1,
            measured_r_s: 0.05,
            step_s: 0.02,
            omega_assumed: 0.9,
            omega_measured: 0.85,
            makespan_s: 100.0,
            compute_s: 90.0,
            checkpoint_s: 8.0,
            recovery_s: 1.0,
            down_s: 1.0,
            energy: EnergyBreakdown {
                static_e: 1000.0,
                cal_e: 900.0,
                io_e: 900.0,
                down_e: 0.0,
                total: 2800.0,
            },
            n_failures: 2,
            n_checkpoints: 18,
            steps_executed: 220,
            steps_target: 200,
            losses: vec![(1.0, 5.5), (200.0, 0.3)],
            events: vec![Event { at: 10.0, kind: EventKind::Failure }],
            predicted_makespan_s: 98.0,
            predicted_energy: 2700.0,
        }
    }

    #[test]
    fn re_exec_fraction_math() {
        let r = report();
        assert!((r.re_exec_fraction() - (1.0 - 200.0 / 220.0)).abs() < 1e-12);
        assert_eq!(r.final_loss(), Some(0.3));
    }

    #[test]
    fn json_roundtrips_and_has_fields() {
        let r = report();
        let j = r.to_json();
        let parsed = crate::util::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.req_f64("makespan_s").unwrap(), 100.0);
        assert_eq!(parsed.req_str("policy").unwrap(), "algo-t");
        assert_eq!(parsed.get("losses").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            parsed.get("events").unwrap().as_arr().unwrap()[0]
                .req_str("kind")
                .unwrap(),
            "failure"
        );
    }
}
