//! The leader control loop: calibrate → pick the period → train with
//! periodic (optionally non-blocking) checkpoints under injected
//! failures → report time/energy.
//!
//! Wall-clock semantics: the run executes in real time. The scenario
//! handed to the period policy uses *measured* quantities — checkpoint
//! write time `C`, restore time `R`, per-step time — plus the configured
//! MTBF `μ` and downtime `D` (both in seconds). Energy applies the
//! paper's power model to the measured phase durations
//! ([`crate::energy`]).

use std::path::PathBuf;
use std::time::Instant;

use super::adaptive::AdaptiveController;
use super::checkpoint::{AsyncCheckpointWriter, CheckpointStore};
use super::injector::FailureSchedule;
use super::policy::PeriodPolicy;
use super::report::{Event, EventKind, RunReport};
use crate::energy::{energy_of, Phase, PhaseTracker};
use crate::model::params::{CheckpointParams, PowerParams, Scenario};
use crate::model::{e_final, t_final};
use crate::runtime::{ArtifactDir, Runtime};
use crate::sim::failure::FailureProcess;
use crate::workload::{LitTrainState, TrainSession, TrainState};

/// Blocking vs non-blocking checkpoints.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OverlapMode {
    /// Training pauses while the checkpoint is written (ω = 0).
    Blocking,
    /// A writer thread persists a snapshot while training continues;
    /// `assumed_omega` seeds the period computation and the measured ω
    /// is reported afterwards.
    Overlapped { assumed_omega: f64 },
}

impl OverlapMode {
    pub fn assumed_omega(&self) -> f64 {
        match self {
            OverlapMode::Blocking => 0.0,
            OverlapMode::Overlapped { assumed_omega } => *assumed_omega,
        }
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: PathBuf,
    pub checkpoint_dir: PathBuf,
    pub power: PowerParams,
    /// Platform MTBF in wall-clock seconds.
    pub mu_s: f64,
    /// Downtime (simulated by sleeping) in seconds.
    pub downtime_s: f64,
    pub policy: PeriodPolicy,
    pub overlap: OverlapMode,
    /// Target training steps (the workload size).
    pub steps: u64,
    pub data_seed: u64,
    pub failure_seed: u64,
    /// Calibration steps used to measure per-step time.
    pub calibration_steps: u64,
    /// Verify restored checkpoints with a forward-pass eval.
    pub verify_on_restore: bool,
    /// Disable failure injection (baseline runs).
    pub inject_failures: bool,
    /// Adapt the period online: re-estimate C/R (EWMA of measured
    /// durations) and μ (exposure estimator seeded with `mu_s` as the
    /// prior) and recompute the policy period after every event
    /// ([`super::adaptive::AdaptiveController`]).
    pub adaptive: bool,
    /// C/R EWMA smoothing factor for the adaptive controller
    /// (α ∈ (0, 1]; `0.3` = the historical default).
    pub ewma_alpha: f64,
    /// Period-space hysteresis band for the adaptive controller.
    pub hysteresis: f64,
    /// Environment drift schedule, in wall-clock **seconds** (the
    /// coordinator's units). Only the `μ` component applies here: the
    /// failure injector's rate follows the trajectory via the thinned
    /// sampler, while `C`/`R` are *measured* wall-clock durations that
    /// cannot be scripted. C/R/IO components are ignored with the
    /// schedule's μ left intact.
    pub drift: crate::drift::DriftProcess,
}

impl CoordinatorConfig {
    /// Reasonable defaults for the end-to-end example: Exascale power
    /// ratios, MTBF scaled down to seconds.
    pub fn new(artifacts_dir: impl Into<PathBuf>, checkpoint_dir: impl Into<PathBuf>) -> Self {
        CoordinatorConfig {
            artifacts_dir: artifacts_dir.into(),
            checkpoint_dir: checkpoint_dir.into(),
            power: PowerParams::new(10.0, 10.0, 100.0, 0.0).expect("valid powers"),
            mu_s: 30.0,
            downtime_s: 0.1,
            policy: PeriodPolicy::AlgoT,
            overlap: OverlapMode::Overlapped { assumed_omega: 0.9 },
            steps: 200,
            data_seed: 1,
            failure_seed: 2,
            calibration_steps: 5,
            verify_on_restore: true,
            inject_failures: true,
            adaptive: false,
            ewma_alpha: super::adaptive::DEFAULT_EWMA_ALPHA,
            hysteresis: super::adaptive::DEFAULT_HYSTERESIS,
            drift: crate::drift::DriftProcess::Stationary,
        }
    }
}

/// Errors the coordinator can surface.
#[derive(Debug)]
pub enum CoordinatorError {
    Runtime(crate::runtime::RuntimeError),
    Checkpoint(super::checkpoint::CheckpointError),
    Model(crate::model::ModelError),
    Other(String),
}

impl std::fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CoordinatorError::Runtime(e) => write!(f, "{e}"),
            CoordinatorError::Checkpoint(e) => write!(f, "{e}"),
            CoordinatorError::Model(e) => write!(f, "{e}"),
            CoordinatorError::Other(m) => write!(f, "coordinator error: {m}"),
        }
    }
}

impl std::error::Error for CoordinatorError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CoordinatorError::Runtime(e) => Some(e),
            CoordinatorError::Checkpoint(e) => Some(e),
            CoordinatorError::Model(e) => Some(e),
            CoordinatorError::Other(_) => None,
        }
    }
}

impl From<crate::runtime::RuntimeError> for CoordinatorError {
    fn from(e: crate::runtime::RuntimeError) -> Self {
        CoordinatorError::Runtime(e)
    }
}

impl From<super::checkpoint::CheckpointError> for CoordinatorError {
    fn from(e: super::checkpoint::CheckpointError) -> Self {
        CoordinatorError::Checkpoint(e)
    }
}

impl From<crate::model::ModelError> for CoordinatorError {
    fn from(e: crate::model::ModelError) -> Self {
        CoordinatorError::Model(e)
    }
}

/// The leader. Owns the PJRT session, the checkpoint store and the
/// failure schedule for one run.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    session: TrainSession,
    dir: ArtifactDir,
}

impl Coordinator {
    pub fn new(rt: &Runtime, cfg: CoordinatorConfig) -> Result<Self, CoordinatorError> {
        let dir = ArtifactDir::open(&cfg.artifacts_dir)?;
        let session = TrainSession::new(rt, &dir, cfg.data_seed)?;
        Ok(Coordinator { cfg, session, dir })
    }

    /// Calibrate, choose the period, and execute the full run.
    pub fn run(&self) -> Result<RunReport, CoordinatorError> {
        let cfg = &self.cfg;
        let store = CheckpointStore::new(&cfg.checkpoint_dir)?;
        store.clear()?;

        // ---- calibration -------------------------------------------------
        let mut cal_state = LitTrainState::from_state(&TrainState::initial(&self.dir)?);
        // One untimed warmup step: the first PJRT execution after
        // compilation pays lazy-initialisation costs that would bias the
        // estimate high.
        self.session.step_lit(&mut cal_state)?;
        let mut step_times = Vec::new();
        for _ in 0..cfg.calibration_steps.max(1) {
            let t0 = Instant::now();
            self.session.step_lit(&mut cal_state)?;
            step_times.push(t0.elapsed().as_secs_f64());
        }
        let step_s = crate::util::stats::median(&step_times);
        // C includes the snapshot materialisation (Literal -> host
        // vectors), exactly what the runtime pays per checkpoint. The
        // first save also creates the file and warms the fsync path —
        // do one untimed, then take the median of three.
        let snap = cal_state.to_state()?;
        store.save(&snap)?;
        let mut c_times = Vec::new();
        for _ in 0..3 {
            let t0 = Instant::now();
            let snap = cal_state.to_state()?;
            store.save(&snap)?;
            c_times.push(t0.elapsed().as_secs_f64());
        }
        let c_s = crate::util::stats::median(&c_times);
        let (_, r_dur) = store.load()?;
        // Restore verification cost is part of R when enabled.
        let mut r_s = r_dur.as_secs_f64();
        if cfg.verify_on_restore {
            let t0 = Instant::now();
            let _ = self.session.eval_lit(&cal_state, 0)?;
            r_s += t0.elapsed().as_secs_f64();
        }
        store.clear()?;

        // ---- scenario + period -------------------------------------------
        let omega = cfg.overlap.assumed_omega();
        let t_base_s = cfg.steps as f64 * step_s;
        let ckpt = CheckpointParams::new(c_s.max(1e-6), r_s.max(1e-6), cfg.downtime_s, omega)?;
        let scenario = Scenario::new(ckpt, cfg.power, cfg.mu_s, t_base_s)?;
        let period_s = cfg.policy.period(&scenario)?;
        // A period must fit at least one step beyond the checkpoint.
        let mut period_s = period_s.max(c_s + step_s);

        // Optional online adaptation, seeded with the calibration
        // measurements and the configured MTBF as prior.
        let mut controller = if cfg.adaptive {
            let mut ctl = AdaptiveController::new(
                cfg.policy,
                cfg.power,
                omega,
                cfg.downtime_s,
                cfg.mu_s,
                t_base_s,
            )
            .with_ewma_alpha(cfg.ewma_alpha)
            .with_hysteresis(cfg.hysteresis);
            ctl.observe_checkpoint(c_s);
            ctl.observe_restore(r_s);
            Some(ctl)
        } else {
            None
        };

        let predicted_makespan = t_final(&scenario, period_s);
        let predicted_energy = e_final(&scenario, period_s);

        // ---- failure schedule --------------------------------------------
        let horizon = (predicted_makespan.max(t_base_s) * 4.0).max(60.0);
        let mut schedule = if cfg.inject_failures {
            // Only the schedule's μ component is injectable on a real
            // run (see `CoordinatorConfig::drift`); a μ-stationary
            // schedule keeps the historical homogeneous process
            // bit-for-bit.
            let drift = cfg.drift.mu_only();
            let process = if drift.is_stationary() {
                FailureProcess::Exponential { mtbf: cfg.mu_s }
            } else {
                let trajectory = crate::drift::EnvTrajectory::new(scenario, drift)?;
                FailureProcess::DriftingExponential { trajectory }
            };
            FailureSchedule::generate(&process, horizon, cfg.failure_seed)
        } else {
            FailureSchedule::none()
        };

        // ---- main loop -----------------------------------------------------
        let mut writer = AsyncCheckpointWriter::new(store.clone());
        let mut phases = PhaseTracker::new();
        let mut events: Vec<Event> = Vec::new();
        let mut losses: Vec<(f32, f32)> = Vec::new();
        let mut state = LitTrainState::from_state(&TrainState::initial(&self.dir)?);
        let mut n_failures = 0u64;
        let mut n_checkpoints = 0u64;
        let mut steps_executed = 0u64;
        // ω measurement: wall time spent in checkpoint windows and the
        // step-work completed inside them.
        let mut ckpt_window_s = 0.0f64;
        let mut ckpt_window_work_s = 0.0f64;

        let run_start = Instant::now();
        let now = |start: &Instant| start.elapsed().as_secs_f64();
        let mut last_ckpt_at = 0.0f64;

        while state.step < cfg.steps as f32 {
            let t_now = now(&run_start);

            // -- failure? --
            if let Some(_fired) = schedule.due(t_now) {
                n_failures += 1;
                events.push(Event { at: t_now, kind: EventKind::Failure });
                if let Some(ctl) = controller.as_mut() {
                    ctl.observe_failure();
                }
                // Let an in-flight (pre-failure, still valid) write drain;
                // its tail is checkpoint time.
                if writer.in_flight() {
                    let t0 = Instant::now();
                    if let Some(done) = writer.wait() {
                        let d = done.map_err(CoordinatorError::Other)?;
                        n_checkpoints += 1;
                        events.push(Event {
                            at: now(&run_start),
                            kind: EventKind::CheckpointDone {
                                step: d.step,
                                seconds: d.duration.as_secs_f64(),
                            },
                        });
                    }
                    let drain = t0.elapsed().as_secs_f64();
                    phases.add(Phase::Checkpoint, drain);
                    ckpt_window_s += drain;
                }
                // Downtime.
                std::thread::sleep(std::time::Duration::from_secs_f64(cfg.downtime_s));
                phases.add(Phase::Down, cfg.downtime_s);
                // Recovery: restore last durable checkpoint (or restart).
                let t0 = Instant::now();
                match store.load() {
                    Ok((restored, _)) => {
                        state = LitTrainState::from_state(&restored);
                        if cfg.verify_on_restore {
                            let loss = self.session.eval_lit(&state, state.next_batch)?;
                            if !loss.is_finite() {
                                return Err(CoordinatorError::Other(
                                    "restored checkpoint produced non-finite loss".into(),
                                ));
                            }
                        }
                        events.push(Event {
                            at: now(&run_start),
                            kind: EventKind::Restored {
                                step: state.step,
                                seconds: t0.elapsed().as_secs_f64(),
                            },
                        });
                    }
                    Err(super::checkpoint::CheckpointError::Missing(_)) => {
                        state = LitTrainState::from_state(&TrainState::initial(&self.dir)?);
                        events.push(Event {
                            at: now(&run_start),
                            kind: EventKind::RestartedFromScratch,
                        });
                    }
                    Err(e) => return Err(e.into()),
                }
                let recovery_secs = t0.elapsed().as_secs_f64();
                phases.add(Phase::Recovery, recovery_secs);
                if let Some(ctl) = controller.as_mut() {
                    ctl.observe_restore(recovery_secs);
                    if let Some(p) = ctl.period() {
                        period_s = p.max(ctl.c_estimate() + step_s);
                    }
                }
                // The period restarts after recovery.
                last_ckpt_at = now(&run_start);
                continue;
            }

            // -- checkpoint due? --
            if !writer.in_flight() && t_now - last_ckpt_at >= period_s {
                events.push(Event {
                    at: t_now,
                    kind: EventKind::CheckpointBegun { step: state.step },
                });
                match cfg.overlap {
                    OverlapMode::Blocking => {
                        let t0 = Instant::now();
                        let snap = state.to_state()?;
                        store.save(&snap)?;
                        let secs = t0.elapsed().as_secs_f64();
                        phases.add(Phase::Checkpoint, secs);
                        ckpt_window_s += secs;
                        n_checkpoints += 1;
                        events.push(Event {
                            at: now(&run_start),
                            kind: EventKind::CheckpointDone { step: state.step, seconds: secs },
                        });
                        if let Some(ctl) = controller.as_mut() {
                            ctl.observe_checkpoint(secs);
                            if let Some(p) = ctl.period() {
                                period_s = p.max(ctl.c_estimate() + step_s);
                            }
                        }
                    }
                    OverlapMode::Overlapped { .. } => {
                        // Snapshot materialisation is the synchronous part
                        // of a non-blocking checkpoint (the "copy to local
                        // memory" of §2.1); the disk write then overlaps.
                        let t0 = Instant::now();
                        writer.begin(state.to_state()?);
                        let snap_secs = t0.elapsed().as_secs_f64();
                        phases.add(Phase::Checkpoint, snap_secs);
                        ckpt_window_s += snap_secs;
                    }
                }
                last_ckpt_at = now(&run_start);
            }

            // -- one training step --
            let in_ckpt_window = writer.in_flight();
            let t0 = Instant::now();
            let loss = self.session.step_lit(&mut state)?;
            let dt = t0.elapsed().as_secs_f64();
            steps_executed += 1;
            losses.push((state.step, loss));
            if let Some(ctl) = controller.as_mut() {
                ctl.observe_uptime(dt);
            }
            if in_ckpt_window {
                phases.add(Phase::Checkpoint, dt);
                ckpt_window_s += dt;
                ckpt_window_work_s += step_s;
            } else {
                phases.add(Phase::Compute, dt);
            }

            // -- writer completion? --
            if let Some(done) = writer.poll() {
                let d = done.map_err(CoordinatorError::Other)?;
                n_checkpoints += 1;
                events.push(Event {
                    at: now(&run_start),
                    kind: EventKind::CheckpointDone {
                        step: d.step,
                        seconds: d.duration.as_secs_f64(),
                    },
                });
                if let Some(ctl) = controller.as_mut() {
                    ctl.observe_checkpoint(d.duration.as_secs_f64());
                    if let Some(p) = ctl.period() {
                        period_s = p.max(ctl.c_estimate() + step_s);
                    }
                }
            }
        }

        // Drain a trailing write so the store is consistent.
        if writer.in_flight() {
            let t0 = Instant::now();
            if let Some(done) = writer.wait() {
                let d = done.map_err(CoordinatorError::Other)?;
                n_checkpoints += 1;
                events.push(Event {
                    at: now(&run_start),
                    kind: EventKind::CheckpointDone {
                        step: d.step,
                        seconds: d.duration.as_secs_f64(),
                    },
                });
            }
            let drain = t0.elapsed().as_secs_f64();
            phases.add(Phase::Checkpoint, drain);
            ckpt_window_s += drain;
        }

        let makespan_s = now(&run_start);
        let omega_measured = if ckpt_window_s > 0.0 {
            (ckpt_window_work_s / ckpt_window_s).min(1.0)
        } else {
            0.0
        };
        let energy = energy_of(
            &phases,
            &cfg.power,
            match cfg.overlap {
                OverlapMode::Blocking => 0.0,
                OverlapMode::Overlapped { .. } => omega_measured,
            },
        );

        Ok(RunReport {
            policy: cfg.policy.name().to_string(),
            period_s,
            measured_c_s: c_s,
            measured_r_s: r_s,
            step_s,
            omega_assumed: omega,
            omega_measured,
            makespan_s,
            compute_s: phases.get(Phase::Compute),
            checkpoint_s: phases.get(Phase::Checkpoint),
            recovery_s: phases.get(Phase::Recovery),
            down_s: phases.get(Phase::Down),
            energy,
            n_failures,
            n_checkpoints,
            steps_executed,
            steps_target: cfg.steps,
            losses,
            events,
            predicted_makespan_s: predicted_makespan,
            predicted_energy,
        })
    }
}

// Integration tests (need artifacts + PJRT) live in
// rust/tests/coordinator_e2e.rs.
