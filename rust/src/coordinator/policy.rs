//! Checkpoint-period policies.
//!
//! The coordinator treats the period as a pluggable policy so AlgoT and
//! AlgoE (the paper's two strategies) can be compared on identical runs,
//! with Young/Daly as classical baselines and `Fixed` for ablations.
//! The frontier-aware policies close the loop with [`crate::pareto`]:
//! `Knee` checkpoints at the Pareto knee (the budget-free "most of the
//! energy gain for part of the time price" operating point), while
//! `EnergyBudget`/`TimeBudget` solve the ε-constraint problems of
//! Aupy et al. (arXiv:1302.3720) — an operator-supplied overhead budget
//! instead of either endpoint. All three recompute the frontier from
//! whatever scenario they are handed, so the adaptive controller can
//! track a drifting `(C, R, μ)` through them (the heavy lifting is
//! memoised in [`crate::pareto::online`]).

use crate::model::energy::t_energy_opt;
use crate::model::params::{ModelError, Scenario};
use crate::model::time::{daly, t_time_opt, young};
use crate::pareto::online;
use crate::pareto::KneeMethod;

/// Which period to checkpoint with.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PeriodPolicy {
    /// Time-optimal (Eq. 1) — the paper's AlgoT.
    AlgoT,
    /// Energy-optimal (quadratic root) — the paper's AlgoE.
    AlgoE,
    /// Young's `sqrt(2Cμ) + C`.
    Young,
    /// Daly's `sqrt(2C(μ+D+R)) + C`.
    Daly,
    /// A fixed period (same units as the scenario).
    Fixed(f64),
    /// The knee of the time–energy Pareto frontier under the given
    /// detector — between AlgoT and AlgoE wherever the trade-off is
    /// non-degenerate.
    Knee { method: KneeMethod },
    /// Minimise energy subject to a time overhead of at most
    /// `max_time_overhead` percent of AlgoT's makespan (ε-constraint).
    EnergyBudget { max_time_overhead: f64 },
    /// Minimise time subject to an energy overhead of at most
    /// `max_energy_overhead` percent of AlgoE's consumption
    /// (the transposed ε-constraint).
    TimeBudget { max_energy_overhead: f64 },
}

impl PeriodPolicy {
    /// The accepted `--policy` spellings, for CLI help and error
    /// messages.
    pub const PARSE_HELP: &'static str =
        "algo-t|algo-e|young|daly|fixed:<period>|knee|knee:curvature|eps-time:<pct>|eps-energy:<pct>";

    pub fn name(&self) -> &'static str {
        match self {
            PeriodPolicy::AlgoT => "algo-t",
            PeriodPolicy::AlgoE => "algo-e",
            PeriodPolicy::Young => "young",
            PeriodPolicy::Daly => "daly",
            PeriodPolicy::Fixed(_) => "fixed",
            PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord } => "knee",
            PeriodPolicy::Knee { method: KneeMethod::MaxCurvature } => "knee-curvature",
            PeriodPolicy::EnergyBudget { .. } => "eps-time",
            PeriodPolicy::TimeBudget { .. } => "eps-energy",
        }
    }

    /// Parse a CLI-style name (`fixed:<value>` for fixed periods,
    /// `knee[:curvature]` for the frontier knee, `eps-time:<pct>` /
    /// `eps-energy:<pct>` for the budgeted trade-offs). Numeric
    /// parameters must be finite — and positive for `fixed:`,
    /// non-negative for the budgets — or parsing fails.
    pub fn parse(s: &str) -> Option<PeriodPolicy> {
        match s {
            "algo-t" | "algot" | "time" => Some(PeriodPolicy::AlgoT),
            "algo-e" | "algoe" | "energy" => Some(PeriodPolicy::AlgoE),
            "young" => Some(PeriodPolicy::Young),
            "daly" => Some(PeriodPolicy::Daly),
            "knee" | "knee:chord" => {
                Some(PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord })
            }
            "knee:curvature" => Some(PeriodPolicy::Knee { method: KneeMethod::MaxCurvature }),
            other => {
                if let Some(v) = other.strip_prefix("fixed:") {
                    // `parse::<f64>` happily accepts "NaN", "inf" and
                    // negatives; none of them is a checkpointing period.
                    let t = v.parse::<f64>().ok()?;
                    return (t.is_finite() && t > 0.0).then_some(PeriodPolicy::Fixed(t));
                }
                if let Some(v) = other.strip_prefix("eps-time:") {
                    let x = v.parse::<f64>().ok()?;
                    return (x.is_finite() && x >= 0.0)
                        .then_some(PeriodPolicy::EnergyBudget { max_time_overhead: x });
                }
                if let Some(v) = other.strip_prefix("eps-energy:") {
                    let x = v.parse::<f64>().ok()?;
                    return (x.is_finite() && x >= 0.0)
                        .then_some(PeriodPolicy::TimeBudget { max_energy_overhead: x });
                }
                None
            }
        }
    }

    /// The period this policy checkpoints with, clamped to the
    /// scenario's feasible range.
    pub fn period(&self, s: &Scenario) -> Result<f64, ModelError> {
        match self {
            PeriodPolicy::AlgoT => t_time_opt(s),
            PeriodPolicy::AlgoE => t_energy_opt(s),
            PeriodPolicy::Young => s.clamp_period(young(s)),
            PeriodPolicy::Daly => s.clamp_period(daly(s)),
            PeriodPolicy::Fixed(t) => s.clamp_period(*t),
            PeriodPolicy::Knee { method } => online::knee_period(s, *method),
            PeriodPolicy::EnergyBudget { max_time_overhead } => {
                online::min_energy_period(s, *max_time_overhead)
            }
            PeriodPolicy::TimeBudget { max_energy_overhead } => {
                online::min_time_period(s, *max_energy_overhead)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{CheckpointParams, PowerParams};
    use crate::pareto::{min_energy_with_time_overhead, min_time_with_energy_overhead};

    fn scenario() -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        Scenario::new(ckpt, power, 300.0, 10_000.0).unwrap()
    }

    #[test]
    fn parse_roundtrip() {
        for (s, p) in [
            ("algo-t", PeriodPolicy::AlgoT),
            ("algo-e", PeriodPolicy::AlgoE),
            ("young", PeriodPolicy::Young),
            ("daly", PeriodPolicy::Daly),
            ("fixed:42.5", PeriodPolicy::Fixed(42.5)),
            ("knee", PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord }),
            ("knee:chord", PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord }),
            ("knee:curvature", PeriodPolicy::Knee { method: KneeMethod::MaxCurvature }),
            ("eps-time:5", PeriodPolicy::EnergyBudget { max_time_overhead: 5.0 }),
            ("eps-energy:2.5", PeriodPolicy::TimeBudget { max_energy_overhead: 2.5 }),
        ] {
            assert_eq!(PeriodPolicy::parse(s), Some(p));
        }
        assert_eq!(PeriodPolicy::parse("nope"), None);
        assert_eq!(PeriodPolicy::parse("fixed:abc"), None);
    }

    #[test]
    fn parse_rejects_non_finite_and_non_positive_fixed_periods() {
        for bad in ["fixed:NaN", "fixed:nan", "fixed:inf", "fixed:-inf", "fixed:-5", "fixed:0"] {
            assert_eq!(PeriodPolicy::parse(bad), None, "{bad}");
        }
        // Budgets: zero is a valid (tight) budget, negatives and
        // non-finite values are not.
        assert!(PeriodPolicy::parse("eps-time:0").is_some());
        for bad in ["eps-time:-1", "eps-time:NaN", "eps-energy:inf", "eps-energy:-0.5"] {
            assert_eq!(PeriodPolicy::parse(bad), None, "{bad}");
        }
    }

    #[test]
    fn periods_ordered_as_expected() {
        let s = scenario();
        let t = PeriodPolicy::AlgoT.period(&s).unwrap();
        let e = PeriodPolicy::AlgoE.period(&s).unwrap();
        let y = PeriodPolicy::Young.period(&s).unwrap();
        let d = PeriodPolicy::Daly.period(&s).unwrap();
        // rho = 5.5 > 1 so AlgoE stretches the period.
        assert!(e > t, "e={e} t={t}");
        assert!(d >= y, "d={d} y={y}");
        // All feasible.
        for p in [t, e, y, d] {
            assert!(p >= s.min_period());
        }
    }

    #[test]
    fn knee_period_sits_between_the_endpoints() {
        let s = scenario();
        let t = PeriodPolicy::AlgoT.period(&s).unwrap();
        let e = PeriodPolicy::AlgoE.period(&s).unwrap();
        for method in [KneeMethod::MaxDistanceToChord, KneeMethod::MaxCurvature] {
            let k = PeriodPolicy::Knee { method }.period(&s).unwrap();
            assert!(k > t && k < e, "{method:?}: {k} outside ({t}, {e})");
        }
    }

    #[test]
    fn budget_policies_match_the_epsilon_solves() {
        let s = scenario();
        let sol = min_energy_with_time_overhead(&s, 5.0).unwrap();
        let p = PeriodPolicy::EnergyBudget { max_time_overhead: 5.0 }.period(&s).unwrap();
        assert_eq!(p.to_bits(), sol.period.to_bits());
        let sol = min_time_with_energy_overhead(&s, 5.0).unwrap();
        let p = PeriodPolicy::TimeBudget { max_energy_overhead: 5.0 }.period(&s).unwrap();
        assert_eq!(p.to_bits(), sol.period.to_bits());
        // Invalid budgets surface as errors, not panics.
        assert!(PeriodPolicy::EnergyBudget { max_time_overhead: -1.0 }.period(&s).is_err());
    }

    #[test]
    fn fixed_clamps() {
        let s = scenario();
        assert_eq!(PeriodPolicy::Fixed(1.0).period(&s).unwrap(), s.min_period());
        let big = PeriodPolicy::Fixed(1e9).period(&s).unwrap();
        assert!(big < s.domain().1);
    }

    #[test]
    fn names_stable() {
        assert_eq!(PeriodPolicy::AlgoT.name(), "algo-t");
        assert_eq!(PeriodPolicy::Fixed(1.0).name(), "fixed");
        assert_eq!(
            PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord }.name(),
            "knee"
        );
        assert_eq!(
            PeriodPolicy::Knee { method: KneeMethod::MaxCurvature }.name(),
            "knee-curvature"
        );
        assert_eq!(PeriodPolicy::EnergyBudget { max_time_overhead: 5.0 }.name(), "eps-time");
        assert_eq!(PeriodPolicy::TimeBudget { max_energy_overhead: 5.0 }.name(), "eps-energy");
    }
}
