//! Checkpoint-period policies.
//!
//! The coordinator treats the period as a pluggable policy so AlgoT and
//! AlgoE (the paper's two strategies) can be compared on identical runs,
//! with Young/Daly as classical baselines and `Fixed` for ablations.
//! The frontier-aware policies close the loop with [`crate::pareto`]:
//! `Knee` checkpoints at the Pareto knee (the budget-free "most of the
//! energy gain for part of the time price" operating point), while
//! `EnergyBudget`/`TimeBudget` solve the ε-constraint problems of
//! Aupy et al. (arXiv:1302.3720) — an operator-supplied overhead budget
//! instead of either endpoint. All three recompute the frontier from
//! whatever scenario they are handed, so the adaptive controller can
//! track a drifting `(C, R, μ)` through them (the heavy lifting is
//! memoised in [`crate::pareto::online`]).
//!
//! The frontier-aware policies additionally carry an objective-model
//! [`Backend`]: with `Backend::Exact(..)` (CLI `--model exact`) the
//! knee/budget periods come from the exact renewal objectives instead
//! of the paper's first-order forms — the difference is 5–40% of the
//! period at small μ (see `figures::knee_drift`). AlgoT/AlgoE/Young/
//! Daly are *defined* by their closed forms, so
//! [`PeriodPolicy::with_backend`] leaves them untouched.

use crate::model::backend::Backend;
use crate::model::params::{ModelError, Scenario};
use crate::model::time::{daly, young};
use crate::model::{t_energy_opt, t_time_opt};
use crate::pareto::online;
use crate::pareto::KneeMethod;

/// Which period to checkpoint with.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PeriodPolicy {
    /// Time-optimal (Eq. 1) — the paper's AlgoT.
    AlgoT,
    /// Energy-optimal (quadratic root) — the paper's AlgoE.
    AlgoE,
    /// Young's `sqrt(2Cμ) + C`.
    Young,
    /// Daly's `sqrt(2C(μ+D+R)) + C`.
    Daly,
    /// A fixed period (same units as the scenario).
    Fixed(f64),
    /// The knee of the time–energy Pareto frontier under the given
    /// detector and objective backend — between the backend's AlgoT and
    /// AlgoE endpoints wherever the trade-off is non-degenerate.
    Knee { method: KneeMethod, backend: Backend },
    /// Minimise energy subject to a time overhead of at most
    /// `max_time_overhead` percent of AlgoT's makespan (ε-constraint),
    /// under the given objective backend.
    EnergyBudget { max_time_overhead: f64, backend: Backend },
    /// Minimise time subject to an energy overhead of at most
    /// `max_energy_overhead` percent of AlgoE's consumption
    /// (the transposed ε-constraint), under the given objective backend.
    TimeBudget { max_energy_overhead: f64, backend: Backend },
}

impl PeriodPolicy {
    /// The accepted `--policy` spellings, for CLI help and error
    /// messages. The objective backend is orthogonal (the `--model`
    /// flag, [`Backend::PARSE_HELP`]); parsing always yields
    /// `Backend::FirstOrder`, which [`Self::with_backend`] overrides.
    pub const PARSE_HELP: &'static str =
        "algo-t|algo-e|young|daly|fixed:<period>|knee|knee:curvature|eps-time:<pct>|eps-energy:<pct>";

    pub fn name(&self) -> &'static str {
        match self {
            PeriodPolicy::AlgoT => "algo-t",
            PeriodPolicy::AlgoE => "algo-e",
            PeriodPolicy::Young => "young",
            PeriodPolicy::Daly => "daly",
            PeriodPolicy::Fixed(_) => "fixed",
            PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord, .. } => "knee",
            PeriodPolicy::Knee { method: KneeMethod::MaxCurvature, .. } => "knee-curvature",
            PeriodPolicy::EnergyBudget { .. } => "eps-time",
            PeriodPolicy::TimeBudget { .. } => "eps-energy",
        }
    }

    /// The objective backend this policy evaluates through, when it has
    /// one (the frontier-aware policies; the closed-form policies are
    /// backend-less by definition).
    pub fn backend(&self) -> Option<Backend> {
        match self {
            PeriodPolicy::Knee { backend, .. }
            | PeriodPolicy::EnergyBudget { backend, .. }
            | PeriodPolicy::TimeBudget { backend, .. } => Some(*backend),
            _ => None,
        }
    }

    /// Re-target the frontier-aware policies at `backend`
    /// (no-op for the closed-form policies, which have no backend to
    /// swap — see the module docs).
    pub fn with_backend(self, backend: Backend) -> PeriodPolicy {
        match self {
            PeriodPolicy::Knee { method, .. } => PeriodPolicy::Knee { method, backend },
            PeriodPolicy::EnergyBudget { max_time_overhead, .. } => {
                PeriodPolicy::EnergyBudget { max_time_overhead, backend }
            }
            PeriodPolicy::TimeBudget { max_energy_overhead, .. } => {
                PeriodPolicy::TimeBudget { max_energy_overhead, backend }
            }
            other => other,
        }
    }

    /// Parse a CLI-style name (`fixed:<value>` for fixed periods,
    /// `knee[:curvature]` for the frontier knee, `eps-time:<pct>` /
    /// `eps-energy:<pct>` for the budgeted trade-offs). Numeric
    /// parameters must be finite — and positive for `fixed:`,
    /// non-negative for the budgets — or parsing fails. Frontier-aware
    /// policies parse with the first-order backend; apply
    /// [`Self::with_backend`] for the exact one.
    pub fn parse(s: &str) -> Option<PeriodPolicy> {
        let backend = Backend::FirstOrder;
        match s {
            "algo-t" | "algot" | "time" => Some(PeriodPolicy::AlgoT),
            "algo-e" | "algoe" | "energy" => Some(PeriodPolicy::AlgoE),
            "young" => Some(PeriodPolicy::Young),
            "daly" => Some(PeriodPolicy::Daly),
            "knee" | "knee:chord" => {
                Some(PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord, backend })
            }
            "knee:curvature" => {
                Some(PeriodPolicy::Knee { method: KneeMethod::MaxCurvature, backend })
            }
            other => {
                if let Some(v) = other.strip_prefix("fixed:") {
                    // `parse::<f64>` happily accepts "NaN", "inf" and
                    // negatives; none of them is a checkpointing period.
                    let t = v.parse::<f64>().ok()?;
                    return (t.is_finite() && t > 0.0).then_some(PeriodPolicy::Fixed(t));
                }
                if let Some(v) = other.strip_prefix("eps-time:") {
                    let x = v.parse::<f64>().ok()?;
                    return (x.is_finite() && x >= 0.0).then_some(PeriodPolicy::EnergyBudget {
                        max_time_overhead: x,
                        backend,
                    });
                }
                if let Some(v) = other.strip_prefix("eps-energy:") {
                    let x = v.parse::<f64>().ok()?;
                    return (x.is_finite() && x >= 0.0).then_some(PeriodPolicy::TimeBudget {
                        max_energy_overhead: x,
                        backend,
                    });
                }
                None
            }
        }
    }

    /// The period this policy checkpoints with, clamped to the
    /// scenario's feasible range.
    pub fn period(&self, s: &Scenario) -> Result<f64, ModelError> {
        match self {
            PeriodPolicy::AlgoT => t_time_opt(s),
            PeriodPolicy::AlgoE => t_energy_opt(s),
            PeriodPolicy::Young => s.clamp_period(young(s)),
            PeriodPolicy::Daly => s.clamp_period(daly(s)),
            PeriodPolicy::Fixed(t) => s.clamp_period(*t),
            PeriodPolicy::Knee { method, backend } => online::knee_period(s, *method, *backend),
            PeriodPolicy::EnergyBudget { max_time_overhead, backend } => {
                online::min_energy_period(s, *max_time_overhead, *backend)
            }
            PeriodPolicy::TimeBudget { max_energy_overhead, backend } => {
                online::min_time_period(s, *max_energy_overhead, *backend)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::exact::RecoveryModel;
    use crate::model::params::{CheckpointParams, PowerParams};
    use crate::pareto::{min_energy_with_time_overhead, min_time_with_energy_overhead};

    const FO: Backend = Backend::FirstOrder;
    const EXACT: Backend = Backend::Exact(RecoveryModel::Ideal);

    fn scenario() -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        Scenario::new(ckpt, power, 300.0, 10_000.0).unwrap()
    }

    #[test]
    fn parse_roundtrip() {
        for (s, p) in [
            ("algo-t", PeriodPolicy::AlgoT),
            ("algo-e", PeriodPolicy::AlgoE),
            ("young", PeriodPolicy::Young),
            ("daly", PeriodPolicy::Daly),
            ("fixed:42.5", PeriodPolicy::Fixed(42.5)),
            (
                "knee",
                PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord, backend: FO },
            ),
            (
                "knee:chord",
                PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord, backend: FO },
            ),
            (
                "knee:curvature",
                PeriodPolicy::Knee { method: KneeMethod::MaxCurvature, backend: FO },
            ),
            (
                "eps-time:5",
                PeriodPolicy::EnergyBudget { max_time_overhead: 5.0, backend: FO },
            ),
            (
                "eps-energy:2.5",
                PeriodPolicy::TimeBudget { max_energy_overhead: 2.5, backend: FO },
            ),
        ] {
            assert_eq!(PeriodPolicy::parse(s), Some(p));
        }
        assert_eq!(PeriodPolicy::parse("nope"), None);
        assert_eq!(PeriodPolicy::parse("fixed:abc"), None);
    }

    #[test]
    fn parse_rejects_non_finite_and_non_positive_fixed_periods() {
        for bad in ["fixed:NaN", "fixed:nan", "fixed:inf", "fixed:-inf", "fixed:-5", "fixed:0"] {
            assert_eq!(PeriodPolicy::parse(bad), None, "{bad}");
        }
        // Budgets: zero is a valid (tight) budget, negatives and
        // non-finite values are not.
        assert!(PeriodPolicy::parse("eps-time:0").is_some());
        for bad in ["eps-time:-1", "eps-time:NaN", "eps-energy:inf", "eps-energy:-0.5"] {
            assert_eq!(PeriodPolicy::parse(bad), None, "{bad}");
        }
    }

    #[test]
    fn with_backend_retargets_only_the_frontier_policies() {
        let knee = PeriodPolicy::parse("knee").unwrap().with_backend(EXACT);
        assert_eq!(
            knee,
            PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord, backend: EXACT }
        );
        assert_eq!(knee.backend(), Some(EXACT));
        let eps = PeriodPolicy::parse("eps-time:5").unwrap().with_backend(EXACT);
        assert_eq!(
            eps,
            PeriodPolicy::EnergyBudget { max_time_overhead: 5.0, backend: EXACT }
        );
        let eps = PeriodPolicy::parse("eps-energy:5").unwrap().with_backend(EXACT);
        assert_eq!(
            eps,
            PeriodPolicy::TimeBudget { max_energy_overhead: 5.0, backend: EXACT }
        );
        // Closed-form policies are untouched and report no backend.
        for p in [
            PeriodPolicy::AlgoT,
            PeriodPolicy::AlgoE,
            PeriodPolicy::Young,
            PeriodPolicy::Daly,
            PeriodPolicy::Fixed(7.0),
        ] {
            assert_eq!(p.with_backend(EXACT), p);
            assert_eq!(p.backend(), None);
        }
    }

    #[test]
    fn periods_ordered_as_expected() {
        let s = scenario();
        let t = PeriodPolicy::AlgoT.period(&s).unwrap();
        let e = PeriodPolicy::AlgoE.period(&s).unwrap();
        let y = PeriodPolicy::Young.period(&s).unwrap();
        let d = PeriodPolicy::Daly.period(&s).unwrap();
        // rho = 5.5 > 1 so AlgoE stretches the period.
        assert!(e > t, "e={e} t={t}");
        assert!(d >= y, "d={d} y={y}");
        // All feasible.
        for p in [t, e, y, d] {
            assert!(p >= s.min_period());
        }
    }

    #[test]
    fn knee_period_sits_between_the_endpoints() {
        let s = scenario();
        let t = PeriodPolicy::AlgoT.period(&s).unwrap();
        let e = PeriodPolicy::AlgoE.period(&s).unwrap();
        for method in [KneeMethod::MaxDistanceToChord, KneeMethod::MaxCurvature] {
            let k = PeriodPolicy::Knee { method, backend: FO }.period(&s).unwrap();
            assert!(k > t && k < e, "{method:?}: {k} outside ({t}, {e})");
        }
    }

    #[test]
    fn exact_knee_sits_between_the_exact_optima_and_above_the_first_order_knee() {
        let s = scenario();
        let fo_knee = PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord, backend: FO }
            .period(&s)
            .unwrap();
        let ex_knee =
            PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord, backend: EXACT }
                .period(&s)
                .unwrap();
        let tt = EXACT.t_time_opt(&s).unwrap();
        let te = EXACT.t_energy_opt(&s).unwrap();
        assert!(ex_knee > tt && ex_knee < te, "{ex_knee} outside ({tt}, {te})");
        // At mu=300 the exact knee runs ~10% longer than the first-order
        // one (the knee-drift headline).
        assert!(ex_knee > fo_knee * 1.05, "exact {ex_knee} !> first-order {fo_knee}");
    }

    #[test]
    fn budget_policies_match_the_epsilon_solves() {
        let s = scenario();
        for backend in [FO, EXACT] {
            let sol = min_energy_with_time_overhead(&s, 5.0, backend).unwrap();
            let p = PeriodPolicy::EnergyBudget { max_time_overhead: 5.0, backend }
                .period(&s)
                .unwrap();
            assert_eq!(p.to_bits(), sol.period.to_bits(), "{}", backend.name());
            let sol = min_time_with_energy_overhead(&s, 5.0, backend).unwrap();
            let p = PeriodPolicy::TimeBudget { max_energy_overhead: 5.0, backend }
                .period(&s)
                .unwrap();
            assert_eq!(p.to_bits(), sol.period.to_bits(), "{}", backend.name());
        }
        // Invalid budgets surface as errors, not panics.
        assert!(PeriodPolicy::EnergyBudget { max_time_overhead: -1.0, backend: FO }
            .period(&s)
            .is_err());
    }

    #[test]
    fn fixed_clamps() {
        let s = scenario();
        assert_eq!(PeriodPolicy::Fixed(1.0).period(&s).unwrap(), s.min_period());
        let big = PeriodPolicy::Fixed(1e9).period(&s).unwrap();
        assert!(big < s.domain().1);
    }

    #[test]
    fn names_stable() {
        assert_eq!(PeriodPolicy::AlgoT.name(), "algo-t");
        assert_eq!(PeriodPolicy::Fixed(1.0).name(), "fixed");
        assert_eq!(
            PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord, backend: FO }.name(),
            "knee"
        );
        assert_eq!(
            PeriodPolicy::Knee { method: KneeMethod::MaxCurvature, backend: EXACT }.name(),
            "knee-curvature"
        );
        assert_eq!(
            PeriodPolicy::EnergyBudget { max_time_overhead: 5.0, backend: FO }.name(),
            "eps-time"
        );
        assert_eq!(
            PeriodPolicy::TimeBudget { max_energy_overhead: 5.0, backend: EXACT }.name(),
            "eps-energy"
        );
        // The name is backend-independent (CSV/figure join keys); the
        // backend is reported separately.
        assert_eq!(
            PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord, backend: EXACT }.name(),
            "knee"
        );
    }
}
