//! Checkpoint-period policies.
//!
//! The coordinator treats the period as a pluggable policy so AlgoT and
//! AlgoE (the paper's two strategies) can be compared on identical runs,
//! with Young/Daly as classical baselines and `Fixed` for ablations.

use crate::model::energy::t_energy_opt;
use crate::model::params::{ModelError, Scenario};
use crate::model::time::{daly, t_time_opt, young};

/// Which period to checkpoint with.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PeriodPolicy {
    /// Time-optimal (Eq. 1) — the paper's AlgoT.
    AlgoT,
    /// Energy-optimal (quadratic root) — the paper's AlgoE.
    AlgoE,
    /// Young's `sqrt(2Cμ) + C`.
    Young,
    /// Daly's `sqrt(2C(μ+D+R)) + C`.
    Daly,
    /// A fixed period (same units as the scenario).
    Fixed(f64),
}

impl PeriodPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            PeriodPolicy::AlgoT => "algo-t",
            PeriodPolicy::AlgoE => "algo-e",
            PeriodPolicy::Young => "young",
            PeriodPolicy::Daly => "daly",
            PeriodPolicy::Fixed(_) => "fixed",
        }
    }

    /// Parse a CLI-style name (`fixed:<value>` for fixed periods).
    pub fn parse(s: &str) -> Option<PeriodPolicy> {
        match s {
            "algo-t" | "algot" | "time" => Some(PeriodPolicy::AlgoT),
            "algo-e" | "algoe" | "energy" => Some(PeriodPolicy::AlgoE),
            "young" => Some(PeriodPolicy::Young),
            "daly" => Some(PeriodPolicy::Daly),
            other => other
                .strip_prefix("fixed:")
                .and_then(|v| v.parse::<f64>().ok())
                .map(PeriodPolicy::Fixed),
        }
    }

    /// The period this policy checkpoints with, clamped to the
    /// scenario's feasible range.
    pub fn period(&self, s: &Scenario) -> Result<f64, ModelError> {
        match self {
            PeriodPolicy::AlgoT => t_time_opt(s),
            PeriodPolicy::AlgoE => t_energy_opt(s),
            PeriodPolicy::Young => s.clamp_period(young(s)),
            PeriodPolicy::Daly => s.clamp_period(daly(s)),
            PeriodPolicy::Fixed(t) => s.clamp_period(*t),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{CheckpointParams, PowerParams};

    fn scenario() -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        Scenario::new(ckpt, power, 300.0, 10_000.0).unwrap()
    }

    #[test]
    fn parse_roundtrip() {
        for (s, p) in [
            ("algo-t", PeriodPolicy::AlgoT),
            ("algo-e", PeriodPolicy::AlgoE),
            ("young", PeriodPolicy::Young),
            ("daly", PeriodPolicy::Daly),
            ("fixed:42.5", PeriodPolicy::Fixed(42.5)),
        ] {
            assert_eq!(PeriodPolicy::parse(s), Some(p));
        }
        assert_eq!(PeriodPolicy::parse("nope"), None);
        assert_eq!(PeriodPolicy::parse("fixed:abc"), None);
    }

    #[test]
    fn periods_ordered_as_expected() {
        let s = scenario();
        let t = PeriodPolicy::AlgoT.period(&s).unwrap();
        let e = PeriodPolicy::AlgoE.period(&s).unwrap();
        let y = PeriodPolicy::Young.period(&s).unwrap();
        let d = PeriodPolicy::Daly.period(&s).unwrap();
        // rho = 5.5 > 1 so AlgoE stretches the period.
        assert!(e > t, "e={e} t={t}");
        assert!(d >= y, "d={d} y={y}");
        // All feasible.
        for p in [t, e, y, d] {
            assert!(p >= s.min_period());
        }
    }

    #[test]
    fn fixed_clamps() {
        let s = scenario();
        assert_eq!(PeriodPolicy::Fixed(1.0).period(&s).unwrap(), s.min_period());
        let big = PeriodPolicy::Fixed(1e9).period(&s).unwrap();
        assert!(big < s.domain().1);
    }

    #[test]
    fn names_stable() {
        assert_eq!(PeriodPolicy::AlgoT.name(), "algo-t");
        assert_eq!(PeriodPolicy::Fixed(1.0).name(), "fixed");
    }
}
