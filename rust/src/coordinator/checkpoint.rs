//! Durable checkpoint store for [`crate::workload::TrainState`].
//!
//! Format (little-endian):
//!
//! ```text
//! magic  u32 = 0xC4E2_2013      version u32 = 1
//! n_params u64   step f32   next_batch u64
//! theta f32[n]   m f32[n]   v f32[n]
//! crc32  u32 (IEEE, over everything above)
//! ```
//!
//! Writes go to `<dir>/ckpt.tmp` then atomically rename onto
//! `<dir>/ckpt.bin`, so a failure mid-write never corrupts the last
//! durable checkpoint — exactly the "stable storage" assumption of
//! coordinated checkpointing (§2.1).
//!
//! [`AsyncCheckpointWriter`] runs the serialization + write on its own
//! thread: in non-blocking mode the trainer keeps stepping while the
//! write is in flight, which is the behavioural definition of the
//! paper's ω.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::workload::trainer::TrainState;

const MAGIC: u32 = 0xC4E2_2013;
const VERSION: u32 = 1;

/// Errors from checkpoint persistence.
#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    Corrupt(String),
    Missing(PathBuf),
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io error: {e}"),
            CheckpointError::Corrupt(m) => write!(f, "corrupt checkpoint: {m}"),
            CheckpointError::Missing(p) => write!(f, "no checkpoint present at {}", p.display()),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

/// IEEE CRC-32 (table-driven).
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut table = [0u32; 256];
        for (i, entry) in table.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *entry = c;
        }
        table
    });
    let mut c = !0u32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Serialize a [`TrainState`] into the on-disk format.
pub fn encode(state: &TrainState) -> Vec<u8> {
    let n = state.theta.len();
    let mut buf = Vec::with_capacity(28 + 12 * n + 4);
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(n as u64).to_le_bytes());
    buf.extend_from_slice(&state.step.to_le_bytes());
    buf.extend_from_slice(&state.next_batch.to_le_bytes());
    for vec in [&state.theta, &state.m, &state.v] {
        for x in vec.iter() {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf
}

/// Parse the on-disk format back into a [`TrainState`].
pub fn decode(data: &[u8]) -> Result<TrainState, CheckpointError> {
    let fail = |m: &str| Err(CheckpointError::Corrupt(m.to_string()));
    if data.len() < 32 {
        return fail("truncated header");
    }
    let (body, crc_bytes) = data.split_at(data.len() - 4);
    let stored_crc = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    if crc32(body) != stored_crc {
        return fail("crc mismatch");
    }
    let rd_u32 = |off: usize| u32::from_le_bytes(data[off..off + 4].try_into().unwrap());
    let rd_u64 = |off: usize| u64::from_le_bytes(data[off..off + 8].try_into().unwrap());
    if rd_u32(0) != MAGIC {
        return fail("bad magic");
    }
    if rd_u32(4) != VERSION {
        return fail("unsupported version");
    }
    let n = rd_u64(8) as usize;
    let step = f32::from_le_bytes(data[16..20].try_into().unwrap());
    let next_batch = rd_u64(20);
    let expect = 28 + 12 * n + 4;
    if data.len() != expect {
        return fail(&format!("length {} != expected {expect}", data.len()));
    }
    let read_vec = |start: usize| -> Vec<f32> {
        data[start..start + 4 * n]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    };
    let theta = read_vec(28);
    let m = read_vec(28 + 4 * n);
    let v = read_vec(28 + 8 * n);
    Ok(TrainState { theta, m, v, step, next_batch })
}

/// Synchronous checkpoint store rooted at a directory.
#[derive(Debug, Clone)]
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self, CheckpointError> {
        std::fs::create_dir_all(dir.as_ref())?;
        Ok(CheckpointStore { dir: dir.as_ref().to_path_buf() })
    }

    pub fn path(&self) -> PathBuf {
        self.dir.join("ckpt.bin")
    }

    fn tmp_path(&self) -> PathBuf {
        self.dir.join("ckpt.tmp")
    }

    /// Durably save (write tmp + fsync + atomic rename).
    /// Returns the wall time taken — the measured `C`.
    pub fn save(&self, state: &TrainState) -> Result<Duration, CheckpointError> {
        let t0 = Instant::now();
        let buf = encode(state);
        let tmp = self.tmp_path();
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&buf)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, self.path())?;
        Ok(t0.elapsed())
    }

    /// Load + verify the last durable checkpoint.
    /// Returns the state and the wall time taken — the measured `R`.
    pub fn load(&self) -> Result<(TrainState, Duration), CheckpointError> {
        let t0 = Instant::now();
        let path = self.path();
        if !path.exists() {
            return Err(CheckpointError::Missing(path));
        }
        let data = std::fs::read(&path)?;
        let state = decode(&data)?;
        Ok((state, t0.elapsed()))
    }

    pub fn exists(&self) -> bool {
        self.path().exists()
    }

    /// Remove any stored checkpoint (test hygiene).
    pub fn clear(&self) -> Result<(), CheckpointError> {
        for p in [self.path(), self.tmp_path()] {
            if p.exists() {
                std::fs::remove_file(p)?;
            }
        }
        Ok(())
    }
}

enum WriterMsg {
    Save(TrainState),
    Shutdown,
}

/// Completed-write notification.
#[derive(Debug, Clone, Copy)]
pub struct WriteDone {
    pub duration: Duration,
    /// The step counter the written checkpoint captured.
    pub step: f32,
}

/// Background checkpoint writer (the non-blocking half of the protocol).
pub struct AsyncCheckpointWriter {
    tx: mpsc::Sender<WriterMsg>,
    done_rx: mpsc::Receiver<Result<WriteDone, String>>,
    handle: Option<std::thread::JoinHandle<()>>,
    in_flight: bool,
}

impl AsyncCheckpointWriter {
    pub fn new(store: CheckpointStore) -> Self {
        let (tx, rx) = mpsc::channel::<WriterMsg>();
        let (done_tx, done_rx) = mpsc::channel();
        let handle = std::thread::Builder::new()
            .name("ckpt-writer".into())
            .spawn(move || {
                while let Ok(msg) = rx.recv() {
                    match msg {
                        WriterMsg::Save(state) => {
                            let step = state.step;
                            let res = store
                                .save(&state)
                                .map(|duration| WriteDone { duration, step })
                                .map_err(|e| e.to_string());
                            if done_tx.send(res).is_err() {
                                return;
                            }
                        }
                        WriterMsg::Shutdown => return,
                    }
                }
            })
            .expect("spawn ckpt-writer");
        AsyncCheckpointWriter { tx, done_rx, handle: Some(handle), in_flight: false }
    }

    /// Begin a non-blocking save of a state snapshot. Panics if a write
    /// is already in flight (the leader enforces one-at-a-time — a
    /// period shorter than the write time means the scenario is
    //  infeasible and is caught by period validation).
    pub fn begin(&mut self, snapshot: TrainState) {
        assert!(!self.in_flight, "checkpoint writer already busy");
        self.in_flight = true;
        self.tx.send(WriterMsg::Save(snapshot)).expect("ckpt-writer alive");
    }

    pub fn in_flight(&self) -> bool {
        self.in_flight
    }

    /// Non-blocking poll for a completed write.
    pub fn poll(&mut self) -> Option<Result<WriteDone, String>> {
        match self.done_rx.try_recv() {
            Ok(res) => {
                self.in_flight = false;
                Some(res)
            }
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.in_flight = false;
                Some(Err("checkpoint writer thread died".into()))
            }
        }
    }

    /// Block until the in-flight write (if any) completes.
    pub fn wait(&mut self) -> Option<Result<WriteDone, String>> {
        if !self.in_flight {
            return None;
        }
        let res = self.done_rx.recv().map_err(|e| e.to_string()).and_then(|r| r);
        self.in_flight = false;
        Some(res)
    }
}

impl Drop for AsyncCheckpointWriter {
    fn drop(&mut self) {
        let _ = self.tx.send(WriterMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(n: usize) -> TrainState {
        TrainState {
            theta: (0..n).map(|i| i as f32 * 0.25).collect(),
            m: (0..n).map(|i| -(i as f32)).collect(),
            v: (0..n).map(|i| i as f32 * i as f32).collect(),
            step: 42.0,
            next_batch: 17,
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ckpt_store_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn crc32_known_vector() {
        // Standard test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = state(100);
        let buf = encode(&s);
        let back = decode(&buf).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn decode_rejects_corruption() {
        let s = state(10);
        let mut buf = encode(&s);
        // Flip a byte in theta.
        buf[40] ^= 0xFF;
        match decode(&buf) {
            Err(CheckpointError::Corrupt(msg)) => assert!(msg.contains("crc")),
            other => panic!("expected corrupt, got {other:?}"),
        }
        // Truncation.
        assert!(decode(&encode(&s)[..20]).is_err());
        // Bad magic.
        let mut buf = encode(&s);
        buf[0] ^= 1;
        assert!(decode(&buf).is_err());
    }

    #[test]
    fn store_roundtrip_and_timing() {
        let store = CheckpointStore::new(tmp_dir("rt")).unwrap();
        let s = state(1000);
        let c = store.save(&s).unwrap();
        assert!(c.as_nanos() > 0);
        let (back, r) = store.load().unwrap();
        assert_eq!(s, back);
        assert!(r.as_nanos() > 0);
        store.clear().unwrap();
        assert!(!store.exists());
    }

    #[test]
    fn load_missing_is_typed() {
        let store = CheckpointStore::new(tmp_dir("missing")).unwrap();
        assert!(matches!(store.load(), Err(CheckpointError::Missing(_))));
    }

    #[test]
    fn save_overwrites_atomically() {
        let store = CheckpointStore::new(tmp_dir("atomic")).unwrap();
        let s1 = state(50);
        let mut s2 = state(50);
        s2.step = 99.0;
        store.save(&s1).unwrap();
        store.save(&s2).unwrap();
        let (back, _) = store.load().unwrap();
        assert_eq!(back.step, 99.0);
        // No tmp file left behind.
        assert!(!store.tmp_path().exists());
    }

    #[test]
    fn async_writer_completes_and_reports() {
        let store = CheckpointStore::new(tmp_dir("async")).unwrap();
        let mut w = AsyncCheckpointWriter::new(store.clone());
        assert!(!w.in_flight());
        w.begin(state(5000));
        assert!(w.in_flight());
        let done = w.wait().unwrap().unwrap();
        assert_eq!(done.step, 42.0);
        assert!(!w.in_flight());
        let (back, _) = store.load().unwrap();
        assert_eq!(back.next_batch, 17);
    }

    #[test]
    #[should_panic(expected = "already busy")]
    fn async_writer_rejects_concurrent_begin() {
        let store = CheckpointStore::new(tmp_dir("busy")).unwrap();
        let mut w = AsyncCheckpointWriter::new(store);
        w.begin(state(10));
        w.begin(state(10));
    }

    #[test]
    fn async_writer_poll_eventually_sees_completion() {
        let store = CheckpointStore::new(tmp_dir("poll")).unwrap();
        let mut w = AsyncCheckpointWriter::new(store);
        w.begin(state(10));
        let mut tries = 0;
        loop {
            if let Some(res) = w.poll() {
                res.unwrap();
                break;
            }
            tries += 1;
            assert!(tries < 10_000, "writer never completed");
            std::thread::sleep(Duration::from_micros(100));
        }
    }
}
