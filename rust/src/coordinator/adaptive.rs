//! Online period adaptation.
//!
//! The paper assumes `C`, `R` and `μ` are known a priori. In production
//! none of them is: checkpoint cost drifts with model size and filesystem
//! load, and the platform MTBF is only revealed by observed failures.
//! [`AdaptiveController`] estimates all three online, recomputes the
//! policy period as the estimates move, and applies a *period-space*
//! hysteresis band so re-estimation noise cannot thrash the checkpoint
//! interval:
//!
//! * `C`, `R` — exponentially weighted moving averages of measured
//!   save/restore durations (EWMA, α = 0.3: reactive but not jumpy);
//! * `μ` — the classical exposure estimator `total uptime / failures`,
//!   with a Bayesian-flavoured prior (`prior_mu`, weight one pseudo-
//!   failure) so the controller behaves before the first failure.
//!
//! The controller is policy-agnostic: it owns a [`PeriodPolicy`] and a
//! power model and exposes [`AdaptiveController::period`], which the
//! leader re-reads after every checkpoint/failure event.

use super::policy::PeriodPolicy;
use crate::model::params::{CheckpointParams, PowerParams, Scenario};

/// The controller's default C/R EWMA smoothing factor — the single
/// source every constructor (and the CLI's default-detection) reads.
pub const DEFAULT_EWMA_ALPHA: f64 = 0.3;

/// The controller's default period-space hysteresis band.
pub const DEFAULT_HYSTERESIS: f64 = 0.05;

/// EWMA with configurable smoothing.
#[derive(Debug, Clone, Copy)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` must lie in `(0, 1]`: `alpha = 0` would silently freeze
    /// the estimate at its first sample forever (every later `push`
    /// becomes a no-op), which is never what a drift tracker wants.
    pub fn new(alpha: f64) -> Self {
        assert!(
            alpha > 0.0 && alpha <= 1.0,
            "EWMA alpha must be in (0, 1], got {alpha}"
        );
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Online estimates + period recomputation.
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    policy: PeriodPolicy,
    power: PowerParams,
    omega: f64,
    downtime: f64,
    t_base_hint: f64,
    /// Prior platform MTBF (used until failures are observed, and blended
    /// afterwards with one pseudo-failure of weight).
    prior_mu: f64,
    c_est: Ewma,
    r_est: Ewma,
    uptime: f64,
    failures: u64,
    /// Current period (recomputed lazily).
    cached_period: Option<f64>,
    /// Period-space hysteresis band: a freshly computed period within
    /// this relative distance of the current one does not replace it.
    hysteresis: f64,
    cached_inputs: (f64, f64, f64),
    /// The most recent *pre-hysteresis* policy period — what the last
    /// [`period`](Self::period) recompute produced before the band was
    /// applied. Decision traces read this to tell a recomputed change
    /// from a hysteresis-suppressed one.
    cached_fresh: Option<f64>,
}

impl AdaptiveController {
    pub fn new(
        policy: PeriodPolicy,
        power: PowerParams,
        omega: f64,
        downtime: f64,
        prior_mu: f64,
        t_base_hint: f64,
    ) -> Self {
        AdaptiveController {
            policy,
            power,
            omega,
            downtime,
            t_base_hint,
            prior_mu,
            c_est: Ewma::new(DEFAULT_EWMA_ALPHA),
            r_est: Ewma::new(DEFAULT_EWMA_ALPHA),
            uptime: 0.0,
            failures: 0,
            cached_period: None,
            hysteresis: DEFAULT_HYSTERESIS,
            cached_inputs: (0.0, 0.0, 0.0),
            cached_fresh: None,
        }
    }

    /// Override the C/R EWMA smoothing factor (default `0.3`), the
    /// knob that trades reactivity against noise-chasing when the
    /// environment drifts (see `figures::drift`). Must be called
    /// before any observation — swapping the smoothing mid-stream
    /// would silently discard the accumulated estimate. `alpha` must
    /// satisfy [`Ewma::new`]'s α ∈ (0, 1] contract.
    pub fn with_ewma_alpha(mut self, alpha: f64) -> Self {
        assert!(
            self.c_est.get().is_none() && self.r_est.get().is_none(),
            "set the EWMA alpha before the first observation"
        );
        self.c_est = Ewma::new(alpha);
        self.r_est = Ewma::new(alpha);
        self
    }

    /// Override the period-space hysteresis band (default 5%).
    pub fn with_hysteresis(mut self, hysteresis: f64) -> Self {
        assert!(
            hysteresis >= 0.0 && hysteresis.is_finite(),
            "hysteresis must be finite and >= 0, got {hysteresis}"
        );
        self.hysteresis = hysteresis;
        self
    }

    /// Record a measured checkpoint write duration.
    pub fn observe_checkpoint(&mut self, seconds: f64) {
        self.c_est.push(seconds);
    }

    /// Record a measured restore duration.
    pub fn observe_restore(&mut self, seconds: f64) {
        self.r_est.push(seconds);
    }

    /// Record uptime accrued since the last call (any phase where a
    /// failure could have struck).
    pub fn observe_uptime(&mut self, seconds: f64) {
        debug_assert!(seconds >= 0.0);
        self.uptime += seconds;
    }

    /// Record an observed failure.
    pub fn observe_failure(&mut self) {
        self.failures += 1;
    }

    /// Current MTBF estimate: exposure estimator blended with the prior
    /// (one pseudo-failure at `prior_mu`).
    pub fn mu_estimate(&self) -> f64 {
        (self.uptime + self.prior_mu) / (self.failures + 1) as f64
    }

    /// Current C estimate (falls back to a conservative guess until the
    /// first observation).
    pub fn c_estimate(&self) -> f64 {
        self.c_est.get().unwrap_or(self.prior_mu / 100.0)
    }

    pub fn r_estimate(&self) -> f64 {
        self.r_est.get().unwrap_or_else(|| self.c_estimate())
    }

    pub fn observed_failures(&self) -> u64 {
        self.failures
    }

    /// The scenario implied by current estimates.
    pub fn scenario(&self) -> Option<Scenario> {
        let ckpt = CheckpointParams::new(
            self.c_estimate().max(1e-9),
            self.r_estimate().max(1e-9),
            self.downtime,
            self.omega,
        )
        .ok()?;
        Scenario::new(ckpt, self.power, self.mu_estimate(), self.t_base_hint).ok()
    }

    /// Current period, with hysteresis **in period space**: the policy
    /// period is recomputed whenever an estimate moved, but it only
    /// *replaces* the period in force when it differs by more than the
    /// hysteresis band. An earlier revision banded the estimates
    /// instead, which gets the geometry backwards — near-flat regions
    /// of the objective let large period jumps through while steep
    /// regions suppressed needed updates. The leader can call this
    /// every iteration without thrashing the period; unchanged
    /// estimates short-circuit before any model evaluation.
    pub fn period(&mut self) -> Option<f64> {
        let inputs = (self.c_estimate(), self.r_estimate(), self.mu_estimate());
        if let Some(p) = self.cached_period {
            if inputs == self.cached_inputs {
                return Some(p);
            }
        }
        let s = self.scenario()?;
        let fresh = self.policy.period(&s).ok()?;
        let p = match self.cached_period {
            Some(current) if (fresh - current).abs() <= self.hysteresis * current => current,
            _ => fresh,
        };
        self.cached_period = Some(p);
        self.cached_inputs = inputs;
        self.cached_fresh = Some(fresh);
        Some(p)
    }

    /// The pre-hysteresis period from the most recent recompute inside
    /// [`period`](Self::period), or `None` before the first one.
    /// Observational only (decision traces): comparing it with the
    /// period in force shows whether the last recompute was adopted or
    /// suppressed by the hysteresis band.
    pub fn fresh_period(&self) -> Option<f64> {
        self.cached_fresh
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller() -> AdaptiveController {
        AdaptiveController::new(
            PeriodPolicy::AlgoT,
            PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap(),
            0.5,
            0.1,
            30.0, // prior mu: 30 s
            1000.0,
        )
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.3);
        assert_eq!(e.get(), None);
        e.push(10.0);
        assert_eq!(e.get(), Some(10.0));
        for _ in 0..50 {
            e.push(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn mu_estimator_blends_prior_and_observations() {
        let mut c = controller();
        // No failures yet: estimate equals the prior.
        assert_eq!(c.mu_estimate(), 30.0);
        // 90 s uptime, 2 failures: (90 + 30) / 3 = 40.
        c.observe_uptime(90.0);
        c.observe_failure();
        c.observe_failure();
        assert_eq!(c.mu_estimate(), 40.0);
    }

    #[test]
    fn period_tracks_c_changes() {
        let mut c = controller();
        c.observe_checkpoint(0.1);
        let p1 = c.period().unwrap();
        // Checkpoints suddenly get 16x slower: Eq.1 ~ sqrt(C) => the
        // period should grow by ~4x (modulo the (D+R+wC) correction).
        for _ in 0..30 {
            c.observe_checkpoint(1.6);
        }
        let p2 = c.period().unwrap();
        assert!(p2 > 2.5 * p1, "p1={p1} p2={p2}");
    }

    #[test]
    fn hysteresis_avoids_thrash() {
        let mut c = controller();
        c.observe_checkpoint(0.1);
        let p1 = c.period().unwrap();
        // A 1% wobble in C must not change the cached period.
        c.observe_checkpoint(0.101);
        let p2 = c.period().unwrap();
        assert_eq!(p1, p2);
    }

    #[test]
    fn hysteresis_band_lives_in_period_space() {
        // Drive the C estimate ~8% up — past the old 5% *estimate* band
        // — but since the period scales ~sqrt(C), the fresh period moves
        // only ~4%, inside the 5% *period* band: the period in force
        // must not change.
        let mut c = controller();
        c.observe_checkpoint(0.1);
        let p1 = c.period().unwrap();
        for _ in 0..60 {
            c.observe_checkpoint(0.108);
        }
        assert!((c.c_estimate() - 0.108).abs() < 1e-6, "EWMA converged");
        let p2 = c.period().unwrap();
        assert_eq!(p1, p2, "4% period move crossed the 5% band");
        // A genuinely large move still goes through (covered again by
        // `period_tracks_c_changes`).
        for _ in 0..60 {
            c.observe_checkpoint(0.2);
        }
        assert!(c.period().unwrap() > p1);
    }

    #[test]
    fn zero_hysteresis_tracks_every_recompute() {
        let mut c = controller().with_hysteresis(0.0);
        c.observe_checkpoint(0.1);
        let p1 = c.period().unwrap();
        for _ in 0..60 {
            c.observe_checkpoint(0.101);
        }
        let p2 = c.period().unwrap();
        assert!(p2 > p1, "with no band the 1% C move must shift the period");
    }

    #[test]
    fn ewma_accepts_the_full_half_open_interval() {
        let mut e = Ewma::new(1.0);
        e.push(3.0);
        e.push(5.0);
        assert_eq!(e.get(), Some(5.0));
    }

    #[test]
    #[should_panic(expected = "EWMA alpha")]
    fn ewma_rejects_alpha_zero() {
        // Regression: alpha = 0 froze C/R estimates at their first
        // sample forever.
        let _ = Ewma::new(0.0);
    }

    #[test]
    #[should_panic(expected = "EWMA alpha")]
    fn ewma_rejects_alpha_above_one() {
        let _ = Ewma::new(1.5);
    }

    #[test]
    fn ewma_alpha_is_configurable_before_observations() {
        // alpha = 1: the estimate snaps to the latest sample, so a C
        // jump moves the period immediately (no smoothing lag).
        let mut snappy = controller().with_ewma_alpha(1.0);
        snappy.observe_checkpoint(0.1);
        let p1 = snappy.period().unwrap();
        snappy.observe_checkpoint(1.6);
        let p2 = snappy.period().unwrap();
        assert!(p2 > 2.5 * p1, "alpha=1 must track instantly: {p1} -> {p2}");
        // The default (0.3) needs several samples for the same move.
        let mut smooth = controller();
        smooth.observe_checkpoint(0.1);
        let q1 = smooth.period().unwrap();
        smooth.observe_checkpoint(1.6);
        let q2 = smooth.period().unwrap();
        assert!(q2 < p2, "default alpha moved as fast as alpha=1: {q2} vs {p2}");
        assert!(q2 >= q1);
    }

    #[test]
    #[should_panic(expected = "EWMA alpha")]
    fn with_ewma_alpha_rejects_out_of_contract_values() {
        let _ = controller().with_ewma_alpha(0.0);
    }

    #[test]
    #[should_panic(expected = "before the first observation")]
    fn with_ewma_alpha_rejects_late_reconfiguration() {
        let mut c = controller();
        c.observe_checkpoint(0.1);
        let _ = c.with_ewma_alpha(0.5);
    }

    #[test]
    fn knee_policy_period_sits_between_the_endpoint_policies() {
        let mk = |policy| {
            let mut c = AdaptiveController::new(
                policy,
                PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap(),
                0.5,
                0.1,
                30.0,
                1000.0,
            );
            c.observe_checkpoint(0.1);
            c.observe_restore(0.1);
            c.period().unwrap()
        };
        let t = mk(PeriodPolicy::AlgoT);
        let e = mk(PeriodPolicy::AlgoE);
        let k = mk(PeriodPolicy::Knee {
            method: crate::pareto::KneeMethod::MaxDistanceToChord,
            backend: crate::model::Backend::FirstOrder,
        });
        assert!(t < k && k < e, "knee {k} outside ({t}, {e})");
    }

    #[test]
    fn more_failures_shrink_the_period() {
        let mut quiet = controller();
        quiet.observe_checkpoint(0.1);
        quiet.observe_uptime(300.0);
        let p_quiet = quiet.period().unwrap();

        let mut noisy = controller();
        noisy.observe_checkpoint(0.1);
        noisy.observe_uptime(300.0);
        for _ in 0..20 {
            noisy.observe_failure();
        }
        let p_noisy = noisy.period().unwrap();
        assert!(p_noisy < p_quiet, "noisy {p_noisy} !< quiet {p_quiet}");
    }

    #[test]
    fn algo_e_policy_supported() {
        let mut c = AdaptiveController::new(
            PeriodPolicy::AlgoE,
            PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap(),
            0.5,
            0.1,
            30.0,
            1000.0,
        );
        c.observe_checkpoint(0.1);
        c.observe_restore(0.05);
        let mut t = AdaptiveController::new(
            PeriodPolicy::AlgoT,
            PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap(),
            0.5,
            0.1,
            30.0,
            1000.0,
        );
        t.observe_checkpoint(0.1);
        t.observe_restore(0.05);
        // rho = 5.5 > 1: energy period longer.
        assert!(c.period().unwrap() > t.period().unwrap());
    }

    #[test]
    fn degenerate_estimates_return_none() {
        let mut c = controller();
        // Make mu collapse far below C: no feasible period.
        c.observe_checkpoint(100.0);
        for _ in 0..1000 {
            c.observe_failure();
        }
        assert!(c.period().is_none());
    }
}
