//! Failure injection for real runs.
//!
//! The schedule is pre-drawn (reproducible per seed) from any
//! [`FailureProcess`], in wall-clock seconds. The leader polls
//! [`FailureSchedule::due`] against its monotonic clock; firing discards
//! the live training state, exactly like a node loss under coordinated
//! checkpointing (all processes roll back together — §2.1).

use crate::sim::failure::FailureProcess;
use crate::util::rng::Pcg64;

/// A reproducible sequence of failure instants (seconds from run start).
#[derive(Debug, Clone)]
pub struct FailureSchedule {
    times: Vec<f64>,
    next: usize,
}

impl FailureSchedule {
    /// Draw all failures up to `horizon` seconds.
    pub fn generate(process: &FailureProcess, horizon: f64, seed: u64) -> Self {
        let mut rng = Pcg64::seeded(seed);
        let mut stream = process.stream(&mut rng);
        let mut times = Vec::new();
        let mut now = 0.0;
        loop {
            let f = stream.next_after(now);
            if f.at > horizon {
                break;
            }
            times.push(f.at);
            now = f.at;
        }
        FailureSchedule { times, next: 0 }
    }

    /// A schedule with no failures (baseline runs).
    pub fn none() -> Self {
        FailureSchedule { times: Vec::new(), next: 0 }
    }

    /// Explicit failure instants (tests, deterministic demos).
    pub fn at(times: Vec<f64>) -> Self {
        let mut times = times;
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        FailureSchedule { times, next: 0 }
    }

    /// Total failures in the schedule.
    pub fn len(&self) -> usize {
        self.times.len()
    }

    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// Time of the next pending failure, if any.
    pub fn peek(&self) -> Option<f64> {
        self.times.get(self.next).copied()
    }

    /// If a failure is due at/before `now`, consume and return it.
    /// Multiple overdue failures collapse into the earliest (the machine
    /// is already down; coordinated rollback handles them identically) —
    /// the rest are consumed too.
    pub fn due(&mut self, now: f64) -> Option<f64> {
        let first = self.peek().filter(|&t| t <= now)?;
        while self.peek().is_some_and(|t| t <= now) {
            self.next += 1;
        }
        Some(first)
    }

    /// Remaining failure count.
    pub fn remaining(&self) -> usize {
        self.times.len() - self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_reproducible() {
        let p = FailureProcess::Exponential { mtbf: 10.0 };
        let a = FailureSchedule::generate(&p, 1000.0, 7);
        let b = FailureSchedule::generate(&p, 1000.0, 7);
        assert_eq!(a.times, b.times);
        assert!(a.len() > 50, "len={}", a.len());
    }

    #[test]
    fn generate_respects_horizon_and_rate() {
        let p = FailureProcess::Exponential { mtbf: 5.0 };
        let s = FailureSchedule::generate(&p, 10_000.0, 1);
        assert!(s.times.iter().all(|&t| t <= 10_000.0));
        let rate = s.len() as f64 / 10_000.0;
        assert!((rate - 0.2).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn due_consumes_in_order() {
        let mut s = FailureSchedule::at(vec![5.0, 1.0, 3.0]);
        assert_eq!(s.peek(), Some(1.0));
        assert_eq!(s.due(0.5), None);
        assert_eq!(s.due(1.0), Some(1.0));
        assert_eq!(s.remaining(), 2);
        // Two overdue collapse to the earliest.
        assert_eq!(s.due(10.0), Some(3.0));
        assert_eq!(s.remaining(), 0);
        assert_eq!(s.due(100.0), None);
    }

    #[test]
    fn none_never_fires() {
        let mut s = FailureSchedule::none();
        assert!(s.is_empty());
        assert_eq!(s.due(f64::INFINITY), None);
    }
}
