//! Opt-in JSONL decision traces.
//!
//! A trace is a line-per-event JSON artifact recording *why* the
//! adaptive controller did what it did along each sample path: EWMA
//! estimate updates, recomputed vs hysteresis-suppressed period
//! changes, failures and recoveries, and the clairvoyant oracle's
//! concurrent decisions. `simulate --adaptive ... --trace <path>`
//! installs the sink; nothing is written (and nothing is allocated)
//! unless one is installed — the hot-path guard is a single relaxed
//! load, so the simulator's bit-identical determinism contract holds
//! with tracing on or off (`tests/telemetry.rs`).
//!
//! Event schema: every line is a JSON object with at least
//! `{"kind": ..., "seed": ..., "t": ...}` (`t` in simulated minutes).
//! Kinds: `observe` (an estimator update, with the post-update
//! estimates), `period` (a decision point: `fresh` vs `current`,
//! `changed`, and `suppressed` when hysteresis held a recomputed
//! move back), `failure`, `recovery`. Oracle-twin events carry
//! `"oracle": true`. Replicates may interleave when the Monte-Carlo
//! driver runs on the pool; lines are written atomically and each
//! carries its seed, so per-path traces are a filter away.

use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;

static ACTIVE: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<BufWriter<std::fs::File>>> = Mutex::new(None);

/// Install a JSONL sink at `path` (truncating; parent directories
/// created). Replaces any previous sink after flushing it.
pub fn install(path: &Path) -> std::io::Result<()> {
    let file = crate::runtime::artifacts::create_artifact_file(path)?;
    let mut sink = SINK.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(mut old) = sink.take() {
        let _ = old.flush();
    }
    *sink = Some(BufWriter::new(file));
    ACTIVE.store(true, Ordering::Release);
    Ok(())
}

/// Whether a sink is installed. Callers must guard event construction
/// on this so a disabled trace costs one relaxed load and nothing else.
#[inline]
pub fn enabled() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Write one event as a compact JSON line. Silently a no-op when no
/// sink is installed (the guard belongs at the call site; this is the
/// backstop).
pub fn emit(event: &Json) {
    if !enabled() {
        return;
    }
    let line = event.to_string_compact();
    let mut sink = SINK.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(w) = sink.as_mut() {
        let _ = writeln!(w, "{line}");
    }
}

/// Flush and uninstall the sink (the writer is a process-lifetime
/// static, so `Drop` never runs — callers must finish explicitly).
/// Returns whether a sink was installed.
pub fn finish() -> bool {
    ACTIVE.store(false, Ordering::Release);
    let mut sink = SINK.lock().unwrap_or_else(|e| e.into_inner());
    match sink.take() {
        Some(mut w) => {
            let _ = w.flush();
            true
        }
        None => false,
    }
}

/// Convenience constructor for the common event envelope.
pub fn event(kind: &str, seed: u64, t: f64, fields: Vec<(&str, Json)>) -> Json {
    let mut all: Vec<(&str, Json)> = vec![
        ("kind", Json::Str(kind.to_string())),
        ("seed", Json::Num(seed as f64)),
        ("t", Json::Num(t)),
    ];
    all.extend(fields);
    Json::obj(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_emit_finish_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ckpt_trace_{}", std::process::id()));
        let path = dir.join("t.jsonl");
        install(&path).unwrap();
        assert!(enabled());
        emit(&event("period", 7, 1.5, vec![("changed", Json::Bool(true))]));
        emit(&event("failure", 7, 2.0, vec![]));
        assert!(finish());
        assert!(!enabled());
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(first.req_str("kind").unwrap(), "period");
        assert_eq!(first.req_f64("seed").unwrap(), 7.0);
        assert_eq!(first.get("changed").and_then(|j| j.as_bool()), Some(true));
        // With no sink, emit is a no-op and finish reports it.
        emit(&event("failure", 1, 0.0, vec![]));
        assert!(!finish());
        assert_eq!(std::fs::read_to_string(&path).unwrap(), text);
        let _ = std::fs::remove_dir_all(dir);
    }
}
