//! The process-wide metric registry: named counters, gauges and
//! histograms as `static`s, plus the unified cache-statistics view.
//!
//! Everything here is a relaxed atomic — observation never takes a
//! lock and never feeds back into computation (see the determinism
//! contract in the [module docs](crate::telemetry)). The families are
//! declared centrally in [`metrics`] so the Prometheus rendering
//! ([`crate::telemetry::render`]) and the `info --metrics` view always
//! agree on the full inventory; hot paths hold `&'static` handles, so
//! recording is a single `fetch_add`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use super::histogram::Histogram;

/// A monotone counter (`_total` families).
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Reset to zero. Diagnostic/test use only (Prometheus counters
    /// are nominally monotone; scrapers treat a drop as a restart).
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

impl Default for Counter {
    fn default() -> Self {
        Counter::new()
    }
}

/// A last-write-wins gauge (u64 values).
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Gauge::new()
    }
}

/// Span timing on/off (default on). `CKPT_TELEMETRY=0` (or `off`)
/// disables the `Instant::now` pairs on the per-job/per-cell hot
/// paths; counters stay on — they are single relaxed adds and the
/// cache/memo stat surfaces depend on them.
pub fn timing_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| {
        !matches!(
            std::env::var("CKPT_TELEMETRY").as_deref(),
            Ok("0") | Ok("off") | Ok("false")
        )
    })
}

/// Upper bound on per-worker busy-time slots ([`metrics::POOL_WORKER_BUSY_NS`]).
/// The pool sizes itself to the machine (or `CKPT_POOL_THREADS`);
/// workers beyond the last slot fold into it.
pub const MAX_WORKER_SLOTS: usize = 64;

/// Central declaration of every metric family in the process.
pub mod metrics {
    use super::{Counter, Gauge, MAX_WORKER_SLOTS};
    use crate::telemetry::histogram::Histogram;

    // --- serve: the batched query engine -------------------------------
    /// Queries answered by `BatchEngine` (after dedup scatter: one per
    /// input query, not per unique solve).
    pub static SERVE_QUERIES_TOTAL: Counter = Counter::new();
    /// JSON-lines inputs rejected at parse/validate time (the per-line
    /// `{"line","error"}` records, now countable without scraping stderr).
    pub static SERVE_QUERIES_REJECTED_TOTAL: Counter = Counter::new();
    /// Batches run end-to-end (`run_batch`: stdin, file or one socket
    /// connection each).
    pub static SERVE_BATCHES_TOTAL: Counter = Counter::new();
    /// Per-stage batch latency (whole stage per batch, ns):
    /// parse / dedup / solve / scatter.
    pub static SERVE_PARSE_NS: Histogram = Histogram::new();
    pub static SERVE_DEDUP_NS: Histogram = Histogram::new();
    pub static SERVE_SOLVE_NS: Histogram = Histogram::new();
    pub static SERVE_SCATTER_NS: Histogram = Histogram::new();

    // --- grid engine ----------------------------------------------------
    /// Per-cell evaluation latency (cache misses only — actual evals).
    /// (Cache hit/miss counters live per-shard in the caches themselves
    /// since the sharded-map migration; `cache_rows` aggregates them.)
    pub static GRID_CELL_NS: Histogram = Histogram::new();

    // --- sharded caches -------------------------------------------------
    /// Time spent blocked on a contended cache-shard lock, across every
    /// sharded cache in the process. Recorded only when the uncontended
    /// `try_lock` fast path fails (and span timing is enabled), so a
    /// near-empty histogram is the healthy signal.
    pub static SHARD_LOCK_WAIT_NS: Histogram = Histogram::new();

    // --- pareto ---------------------------------------------------------
    /// Dense frontier solves (`Frontier::compute`: figures, the pareto
    /// CLI, and every online-policy memo miss).
    pub static FRONTIER_SOLVE_NS: Histogram = Histogram::new();

    // --- tier-plan envelope ---------------------------------------------
    /// Cadence vectors whose objective was actually evaluated during
    /// tier-plan envelope scans (`model::tiers`).
    pub static TIER_ENVELOPE_EVALUATED_TOTAL: Counter = Counter::new();
    /// Cadence vectors skipped by the drain-cost lower bound before
    /// their objective was evaluated (same scans; evaluated + skipped =
    /// the full divisibility-constrained envelope).
    pub static TIER_ENVELOPE_SKIPPED_TOTAL: Counter = Counter::new();

    // --- batched Monte-Carlo executor -----------------------------------
    /// Lockstep batch size in force for the most recent
    /// `sim::batch` dispatch (override or auto — execution shape only,
    /// never part of any result or cache key).
    pub static SIM_BATCH_SIZE: Gauge = Gauge::new();
    /// Replicates dispatched through the batched executor.
    pub static SIM_BATCH_REPLICAS_TOTAL: Counter = Counter::new();
    /// Lockstep blocks (pool jobs) dispatched by the batched executor.
    pub static SIM_BATCH_JOBS_TOTAL: Counter = Counter::new();

    // --- warm-start frontier re-solves ----------------------------------
    /// Warm-started optimiser solves whose seeded bracket validated (the
    /// golden refinement ran on the cold-identical bracket directly,
    /// skipping the grid scan).
    pub static OPT_WARM_HITS_TOTAL: Counter = Counter::new();
    /// Warm-start attempts whose bracket check failed, falling back to
    /// the cold grid-then-golden path bit-identically.
    pub static OPT_WARM_FALLBACKS_TOTAL: Counter = Counter::new();

    // --- thread pool ----------------------------------------------------
    /// Successful steals from another participant's queue.
    pub static POOL_STEALS_TOTAL: Counter = Counter::new();
    /// Jobs executed (counted even with span timing disabled).
    pub static POOL_JOBS_TOTAL: Counter = Counter::new();
    /// Batches submitted to the pool.
    pub static POOL_BATCHES_TOTAL: Counter = Counter::new();
    /// Tasks enqueued by the most recent batch (set at submit time —
    /// the depth the queues start the batch at).
    pub static POOL_QUEUE_DEPTH: Gauge = Gauge::new();
    /// Per-job latency (ns).
    pub static POOL_JOB_NS: Histogram = Histogram::new();
    /// Busy nanoseconds per participant (worker index; the submitting
    /// thread records under its participation index `n_workers`).
    pub static POOL_WORKER_BUSY_NS: [Counter; MAX_WORKER_SLOTS] = {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: Counter = Counter::new();
        [ZERO; MAX_WORKER_SLOTS]
    };
}

/// One row of the unified cache/memo statistics table: the five
/// process-wide caches, one schema (`info` renders this; the
/// Prometheus exposition emits the same numbers as labelled families).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheRow {
    /// Stable row label (`grid cell cache`, `online policy memo`, ...).
    pub name: &'static str,
    pub entries: usize,
    pub hits: u64,
    pub misses: u64,
    /// Wholesale clears (memos, answer cache) or FIFO eviction events
    /// (grid cache) — either way the churn signal.
    pub clears: u64,
}

impl CacheRow {
    /// Hit fraction in `[0, 1]` (`0` before any lookup).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Snapshot of every cache/memo stat surface in the process, in stable
/// order. This is the single source for `info`'s table, the
/// `ckpt_cache_*` Prometheus families, and the bench telemetry block.
pub fn cache_rows() -> Vec<CacheRow> {
    let (grid_hits, grid_misses) = crate::sweep::cache::stats();
    let (online, online_len) = crate::pareto::online::memo_stats();
    let (opt, opt_len) = crate::model::backend::opt_memo_stats();
    let (tier, tier_len) = crate::model::tiers::tier_plan_memo_stats();
    let (serve_hits, serve_misses) = crate::serve::answer_cache_stats();
    vec![
        CacheRow {
            name: "grid cell cache",
            entries: crate::sweep::cache::len(),
            hits: grid_hits,
            misses: grid_misses,
            clears: crate::sweep::cache::evictions(),
        },
        CacheRow {
            name: "online policy memo",
            entries: online_len,
            hits: online.hits,
            misses: online.misses,
            clears: online.clears,
        },
        CacheRow {
            name: "exact optima memo",
            entries: opt_len,
            hits: opt.hits,
            misses: opt.misses,
            clears: opt.clears,
        },
        CacheRow {
            name: "tier plan memo",
            entries: tier_len,
            hits: tier.hits,
            misses: tier.misses,
            clears: tier.clears,
        },
        CacheRow {
            name: "serve answer cache",
            entries: crate::serve::answer_cache_len(),
            hits: serve_hits,
            misses: serve_misses,
            clears: crate::serve::answer_cache_clears(),
        },
    ]
}

/// Per-shard occupancy of every sharded cache, in [`cache_rows`] order
/// — the `ckpt_cache_shard_entries` exposition family (occupied shards
/// only are rendered; the vectors here are always full length).
pub fn shard_rows() -> Vec<(&'static str, Vec<usize>)> {
    vec![
        ("grid cell cache", crate::sweep::cache::shard_entries()),
        ("online policy memo", crate::pareto::online::memo_shard_entries()),
        ("exact optima memo", crate::model::backend::opt_memo_shard_entries()),
        ("tier plan memo", crate::model::tiers::tier_plan_memo_shard_entries()),
        ("serve answer cache", crate::serve::answer_cache_shard_entries()),
    ]
}

/// The histogram families by (family name, optional `stage` label),
/// for rendering and the bench snapshot. Order is stable.
pub fn histogram_families() -> Vec<(&'static str, Option<&'static str>, &'static Histogram)> {
    vec![
        ("ckpt_serve_stage_ns", Some("parse"), &metrics::SERVE_PARSE_NS),
        ("ckpt_serve_stage_ns", Some("dedup"), &metrics::SERVE_DEDUP_NS),
        ("ckpt_serve_stage_ns", Some("solve"), &metrics::SERVE_SOLVE_NS),
        ("ckpt_serve_stage_ns", Some("scatter"), &metrics::SERVE_SCATTER_NS),
        ("ckpt_pool_job_ns", None, &metrics::POOL_JOB_NS),
        ("ckpt_grid_cell_ns", None, &metrics::GRID_CELL_NS),
        ("ckpt_frontier_solve_ns", None, &metrics::FRONTIER_SOLVE_NS),
        ("ckpt_shard_lock_wait_ns", None, &metrics::SHARD_LOCK_WAIT_NS),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        static C: Counter = Counter::new();
        static G: Gauge = Gauge::new();
        C.inc();
        C.add(4);
        assert_eq!(C.get(), 5);
        C.reset();
        assert_eq!(C.get(), 0);
        G.set(17);
        assert_eq!(G.get(), 17);
    }

    #[test]
    fn cache_rows_schema_is_stable() {
        let rows = cache_rows();
        let names: Vec<&str> = rows.iter().map(|r| r.name).collect();
        assert_eq!(
            names,
            [
                "grid cell cache",
                "online policy memo",
                "exact optima memo",
                "tier plan memo",
                "serve answer cache"
            ]
        );
        let empty = CacheRow { name: "x", entries: 0, hits: 0, misses: 0, clears: 0 };
        assert_eq!(empty.hit_rate(), 0.0);
        let half = CacheRow { hits: 1, misses: 1, ..empty };
        assert!((half.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_families_cover_every_stage() {
        let fams = histogram_families();
        let stages: Vec<_> =
            fams.iter().filter(|(n, _, _)| *n == "ckpt_serve_stage_ns").collect();
        assert_eq!(stages.len(), 4);
    }
}
