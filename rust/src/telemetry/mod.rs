//! Unified telemetry: metrics registry, scoped span timers, and
//! opt-in decision traces.
//!
//! The serving and simulation stack is a long-lived process (the
//! `batch --socket` server, drift sweeps, the bench harness); this
//! module is its one observability surface, with three pillars:
//!
//! * **Metrics registry** ([`registry`]) — process-wide named
//!   counters, gauges and log2-bucket histograms ([`histogram`]),
//!   all plain relaxed atomics: lock-free on the hot path, cheap
//!   enough to leave on, and *observational only*. Every pre-existing
//!   ad-hoc stat surface (the [`PureMemo`](crate::util::memo)
//!   hit/miss/clear counters, the grid-cell cache, the serve answer
//!   cache) reports through the same snapshot ([`registry::cache_rows`]).
//! * **Spans** ([`span`]) — RAII timers recording elapsed nanoseconds
//!   into a histogram on drop. They instrument the serve engine's
//!   parse/dedup/solve/scatter stages, per-job pool latency, grid-cell
//!   evaluation, and frontier solves, so the bench trajectory carries
//!   p50/p95/p99 tails instead of single means.
//! * **Decision traces** ([`trace`]) — an opt-in JSONL sink recording
//!   the adaptive controller's estimate updates, recomputed vs
//!   hysteresis-suppressed period changes, and failure/recovery
//!   events (`simulate --adaptive ... --trace <path>`). Disabled it
//!   costs one relaxed load per would-be event.
//!
//! Rendering: [`render::prometheus`] emits the Prometheus text
//! exposition (served on the `batch --socket` path for a
//! `GET /metrics` request line, and printed by `info --metrics`);
//! [`render::snapshot_json`] embeds the same data in `bench` output.
//!
//! # Naming conventions
//!
//! Families are prefixed `ckpt_`; counters end in `_total`, duration
//! histograms in `_ns`. Labelled families (`{cache=...}`,
//! `{memo=...}`, `{stage=...}`, `{worker=...}`) keep one family per
//! concept rather than one per instance.
//!
//! # Determinism contract
//!
//! Telemetry values never feed a cache key, a memo key, or a seed
//! derivation — `Scenario::key_bits`, `sweep::grid` cell keys/seeds
//! and `serve::Query::solve_key` are all computed from model
//! parameters alone. Adding a metric must preserve that: observe,
//! never steer. `tests/telemetry.rs` pins instrumented runs
//! bit-identical to uninstrumented expectations at 1 and 8 threads.

pub mod histogram;
pub mod registry;
pub mod render;
pub mod span;
pub mod trace;

pub use histogram::{Histogram, HistogramSnapshot};
pub use registry::{cache_rows, timing_enabled, CacheRow, Counter, Gauge};
pub use span::Span;
