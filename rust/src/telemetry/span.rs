//! Scoped span timers: elapsed nanoseconds into a histogram on drop.

use std::time::Instant;

use super::histogram::Histogram;
use super::registry::timing_enabled;

/// An RAII timer. [`Span::start`] captures `Instant::now()`; dropping
/// the span records the elapsed nanoseconds into the histogram. When
/// span timing is disabled (`CKPT_TELEMETRY=0`) starting is one
/// branch and dropping is free — safe to leave in the hottest loops.
///
/// ```
/// use ckpt_period::telemetry::{Histogram, Span};
/// static H: Histogram = Histogram::new();
/// {
///     let _span = Span::start(&H);
///     // ... timed work ...
/// } // drop records into H
/// ```
pub struct Span<'h> {
    hist: &'h Histogram,
    start: Option<Instant>,
}

impl<'h> Span<'h> {
    pub fn start(hist: &'h Histogram) -> Span<'h> {
        let start = if timing_enabled() { Some(Instant::now()) } else { None };
        Span { hist, start }
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let ns = t0.elapsed().as_nanos();
            self.hist.observe(u64::try_from(ns).unwrap_or(u64::MAX));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_records_on_drop() {
        static H: Histogram = Histogram::new();
        let before = H.snapshot().count();
        {
            let _s = Span::start(&H);
            std::hint::black_box(3u64 + 4);
        }
        // Timing may be disabled via the environment; when enabled the
        // drop must have recorded exactly one observation.
        if timing_enabled() {
            assert_eq!(H.snapshot().count(), before + 1);
        } else {
            assert_eq!(H.snapshot().count(), before);
        }
    }
}
