//! Rendering the registry: Prometheus text exposition and a JSON
//! snapshot for bench artifacts.

use crate::util::json::Json;

use super::histogram::{HistogramSnapshot, BUCKETS};
use super::registry::{self, metrics};

/// The full Prometheus text-format exposition of every family in the
/// registry — served for a `GET /metrics` request line on the
/// `batch --socket` path and printed by `info --metrics`. All families
/// are always present (zero-valued before traffic) so scrapers and
/// the CI greps see a stable inventory.
pub fn prometheus() -> String {
    let mut out = String::new();

    let counters: &[(&str, &str, u64)] = &[
        (
            "ckpt_serve_queries_total",
            "Queries answered by the batch engine",
            metrics::SERVE_QUERIES_TOTAL.get(),
        ),
        (
            "ckpt_serve_queries_rejected_total",
            "Input lines rejected at parse/validate time",
            metrics::SERVE_QUERIES_REJECTED_TOTAL.get(),
        ),
        (
            "ckpt_serve_batches_total",
            "Batches run end-to-end (stdin, file or socket connection)",
            metrics::SERVE_BATCHES_TOTAL.get(),
        ),
        (
            "ckpt_pool_steals_total",
            "Successful work-steals between pool participants",
            metrics::POOL_STEALS_TOTAL.get(),
        ),
        (
            "ckpt_pool_jobs_total",
            "Jobs executed on the thread pool",
            metrics::POOL_JOBS_TOTAL.get(),
        ),
        (
            "ckpt_pool_batches_total",
            "Batches submitted to the thread pool",
            metrics::POOL_BATCHES_TOTAL.get(),
        ),
        (
            "ckpt_tier_envelope_evaluated_total",
            "Cadence vectors evaluated by tier-plan envelope scans",
            metrics::TIER_ENVELOPE_EVALUATED_TOTAL.get(),
        ),
        (
            "ckpt_tier_envelope_skipped_total",
            "Cadence vectors pruned by the drain-cost lower bound",
            metrics::TIER_ENVELOPE_SKIPPED_TOTAL.get(),
        ),
        (
            "ckpt_sim_batch_replicas_total",
            "Replicates dispatched through the batched Monte-Carlo executor",
            metrics::SIM_BATCH_REPLICAS_TOTAL.get(),
        ),
        (
            "ckpt_sim_batch_jobs_total",
            "Lockstep blocks dispatched by the batched Monte-Carlo executor",
            metrics::SIM_BATCH_JOBS_TOTAL.get(),
        ),
        (
            "ckpt_opt_warm_hits_total",
            "Warm-started optimiser solves whose seeded bracket validated",
            metrics::OPT_WARM_HITS_TOTAL.get(),
        ),
        (
            "ckpt_opt_warm_fallbacks_total",
            "Warm-start attempts that fell back to the cold grid scan",
            metrics::OPT_WARM_FALLBACKS_TOTAL.get(),
        ),
    ];
    for (name, help, v) in counters {
        header(&mut out, name, help, "counter");
        out.push_str(&format!("{name} {v}\n"));
    }

    header(
        &mut out,
        "ckpt_pool_queue_depth",
        "Tasks enqueued by the most recent pool batch",
        "gauge",
    );
    out.push_str(&format!("ckpt_pool_queue_depth {}\n", metrics::POOL_QUEUE_DEPTH.get()));

    header(
        &mut out,
        "ckpt_sim_batch_size",
        "Lockstep batch size in force for the most recent sim dispatch",
        "gauge",
    );
    out.push_str(&format!("ckpt_sim_batch_size {}\n", metrics::SIM_BATCH_SIZE.get()));

    // Per-worker busy time: one family, worker-labelled; only slots
    // that have recorded anything (the inventory line stays via HELP).
    header(
        &mut out,
        "ckpt_pool_worker_busy_ns_total",
        "Busy nanoseconds per pool participant",
        "counter",
    );
    for (w, c) in metrics::POOL_WORKER_BUSY_NS.iter().enumerate() {
        let v = c.get();
        if v > 0 {
            out.push_str(&format!("ckpt_pool_worker_busy_ns_total{{worker=\"{w}\"}} {v}\n"));
        }
    }

    // The unified cache view, as labelled families.
    header(&mut out, "ckpt_cache_entries", "Live entries per cache/memo", "gauge");
    let rows = registry::cache_rows();
    for r in &rows {
        out.push_str(&format!(
            "ckpt_cache_entries{{cache=\"{}\"}} {}\n",
            slug(r.name),
            r.entries
        ));
    }
    for (name, help, pick) in [
        ("ckpt_cache_hits_total", "Cache/memo hits", 0usize),
        ("ckpt_cache_misses_total", "Cache/memo misses", 1),
        ("ckpt_cache_clears_total", "Cache/memo wholesale clears or evictions", 2),
    ] {
        header(&mut out, name, help, "counter");
        for r in &rows {
            let v = match pick {
                0 => r.hits,
                1 => r.misses,
                _ => r.clears,
            };
            out.push_str(&format!("{name}{{cache=\"{}\"}} {v}\n", slug(r.name)));
        }
    }

    // Per-shard occupancy of the sharded caches: occupied shards only
    // (64 mostly-zero lines per cache would drown the exposition; the
    // HELP/TYPE header keeps the family in the inventory regardless).
    header(
        &mut out,
        "ckpt_cache_shard_entries",
        "Live entries per cache shard (occupied shards only)",
        "gauge",
    );
    for (name, shards) in registry::shard_rows() {
        for (i, n) in shards.iter().enumerate() {
            if *n > 0 {
                out.push_str(&format!(
                    "ckpt_cache_shard_entries{{cache=\"{}\",shard=\"{i}\"}} {n}\n",
                    slug(name)
                ));
            }
        }
    }

    // Histograms: cumulative buckets, +Inf, _sum and _count per the
    // text-format convention. Consecutive same-name families share one
    // header.
    let mut last_family = "";
    for (family, stage, hist) in registry::histogram_families() {
        if family != last_family {
            header(&mut out, family, "Span latency histogram (ns)", "histogram");
            last_family = family;
        }
        let snap = hist.snapshot();
        write_histogram(&mut out, family, stage, &snap);
    }
    out
}

fn header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// Prometheus label values for cache rows (`grid cell cache` →
/// `grid-cell-cache`).
fn slug(name: &str) -> String {
    name.replace(' ', "-")
}

fn write_histogram(out: &mut String, family: &str, stage: Option<&str>, snap: &HistogramSnapshot) {
    let label = |extra: &str| match stage {
        Some(s) if extra.is_empty() => format!("{{stage=\"{s}\"}}"),
        Some(s) => format!("{{stage=\"{s}\",{extra}}}"),
        None if extra.is_empty() => String::new(),
        None => format!("{{{extra}}}"),
    };
    // Trim trailing empty buckets but keep the full cumulative ramp up
    // to the last observation; +Inf always closes the series.
    let last = snap
        .buckets
        .iter()
        .rposition(|&c| c > 0)
        .map(|i| i + 1)
        .unwrap_or(0)
        .min(BUCKETS);
    let mut cum = 0u64;
    for i in 0..last {
        cum += snap.buckets[i];
        out.push_str(&format!(
            "{family}_bucket{} {cum}\n",
            label(&format!("le=\"{}\"", HistogramSnapshot::upper_bound(i)))
        ));
    }
    out.push_str(&format!("{family}_bucket{} {cum}\n", label("le=\"+Inf\"")));
    out.push_str(&format!("{family}_sum{} {}\n", label(""), snap.sum));
    out.push_str(&format!("{family}_count{} {}\n", label(""), snap.count()));
}

/// Percentile block for one histogram snapshot — the shape embedded
/// per-stage in `bench` v2 artifacts.
pub fn hist_stats_json(snap: &HistogramSnapshot) -> Json {
    Json::obj(vec![
        ("count", Json::Num(snap.count() as f64)),
        ("sum_ns", Json::Num(snap.sum as f64)),
        ("mean_ns", Json::Num(snap.mean())),
        ("p50_ns", Json::Num(snap.quantile(0.50))),
        ("p95_ns", Json::Num(snap.quantile(0.95))),
        ("p99_ns", Json::Num(snap.quantile(0.99))),
    ])
}

/// JSON snapshot of the whole registry (counters + cache rows +
/// histogram percentiles) — the `telemetry` block of `bench` v2
/// output, and anything else that wants machine-readable metrics.
pub fn snapshot_json() -> Json {
    let counters = Json::obj(vec![
        ("serve_queries_total", Json::Num(metrics::SERVE_QUERIES_TOTAL.get() as f64)),
        (
            "serve_queries_rejected_total",
            Json::Num(metrics::SERVE_QUERIES_REJECTED_TOTAL.get() as f64),
        ),
        ("serve_batches_total", Json::Num(metrics::SERVE_BATCHES_TOTAL.get() as f64)),
        ("pool_steals_total", Json::Num(metrics::POOL_STEALS_TOTAL.get() as f64)),
        ("pool_jobs_total", Json::Num(metrics::POOL_JOBS_TOTAL.get() as f64)),
        ("pool_batches_total", Json::Num(metrics::POOL_BATCHES_TOTAL.get() as f64)),
        (
            "tier_envelope_evaluated_total",
            Json::Num(metrics::TIER_ENVELOPE_EVALUATED_TOTAL.get() as f64),
        ),
        (
            "tier_envelope_skipped_total",
            Json::Num(metrics::TIER_ENVELOPE_SKIPPED_TOTAL.get() as f64),
        ),
        (
            "sim_batch_replicas_total",
            Json::Num(metrics::SIM_BATCH_REPLICAS_TOTAL.get() as f64),
        ),
        ("sim_batch_jobs_total", Json::Num(metrics::SIM_BATCH_JOBS_TOTAL.get() as f64)),
        ("sim_batch_size", Json::Num(metrics::SIM_BATCH_SIZE.get() as f64)),
        ("opt_warm_hits_total", Json::Num(metrics::OPT_WARM_HITS_TOTAL.get() as f64)),
        (
            "opt_warm_fallbacks_total",
            Json::Num(metrics::OPT_WARM_FALLBACKS_TOTAL.get() as f64),
        ),
    ]);
    let caches = Json::Obj(
        registry::cache_rows()
            .into_iter()
            .map(|r| {
                (
                    slug(r.name),
                    Json::obj(vec![
                        ("entries", Json::Num(r.entries as f64)),
                        ("hits", Json::Num(r.hits as f64)),
                        ("misses", Json::Num(r.misses as f64)),
                        ("clears", Json::Num(r.clears as f64)),
                    ]),
                )
            })
            .collect(),
    );
    let hists = Json::Obj(
        registry::histogram_families()
            .into_iter()
            .map(|(family, stage, hist)| {
                let key = match stage {
                    Some(s) => format!("{family}/{s}"),
                    None => family.to_string(),
                };
                (key, hist_stats_json(&hist.snapshot()))
            })
            .collect(),
    );
    Json::obj(vec![("counters", counters), ("caches", caches), ("histograms", hists)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::histogram::Histogram;

    #[test]
    fn exposition_lists_every_family() {
        let text = prometheus();
        for family in [
            "ckpt_serve_queries_total",
            "ckpt_serve_queries_rejected_total",
            "ckpt_serve_batches_total",
            "ckpt_pool_steals_total",
            "ckpt_pool_jobs_total",
            "ckpt_pool_queue_depth",
            "ckpt_pool_worker_busy_ns_total",
            "ckpt_cache_entries",
            "ckpt_cache_hits_total",
            "ckpt_cache_shard_entries",
            "ckpt_tier_envelope_evaluated_total",
            "ckpt_tier_envelope_skipped_total",
            "ckpt_sim_batch_size",
            "ckpt_sim_batch_replicas_total",
            "ckpt_sim_batch_jobs_total",
            "ckpt_opt_warm_hits_total",
            "ckpt_opt_warm_fallbacks_total",
            "ckpt_serve_stage_ns",
            "ckpt_pool_job_ns",
            "ckpt_grid_cell_ns",
            "ckpt_frontier_solve_ns",
            "ckpt_shard_lock_wait_ns",
        ] {
            assert!(text.contains(&format!("# TYPE {family}")), "missing {family}\n{text}");
        }
        // Every stage label appears on the serve histogram.
        for stage in ["parse", "dedup", "solve", "scatter"] {
            assert!(text.contains(&format!("stage=\"{stage}\"")), "missing {stage}");
        }
    }

    #[test]
    fn histogram_rendering_is_cumulative_with_inf() {
        let h = Histogram::new();
        h.observe(3);
        h.observe(3);
        h.observe(1000);
        let mut out = String::new();
        write_histogram(&mut out, "x_ns", None, &h.snapshot());
        assert!(out.contains("x_ns_bucket{le=\"4\"} 2\n"), "{out}");
        assert!(out.contains("x_ns_bucket{le=\"1024\"} 3\n"), "{out}");
        assert!(out.contains("x_ns_bucket{le=\"+Inf\"} 3\n"), "{out}");
        assert!(out.contains("x_ns_sum 1006\n"), "{out}");
        assert!(out.contains("x_ns_count 3\n"), "{out}");
    }

    #[test]
    fn snapshot_json_has_the_three_sections() {
        let doc = snapshot_json();
        assert!(doc.get("counters").is_some());
        assert!(doc.get("caches").is_some());
        let hists = doc.get("histograms").unwrap();
        let solve = hists.get("ckpt_serve_stage_ns/solve").unwrap();
        assert!(solve.req_f64("count").unwrap() >= 0.0);
        assert!(solve.get("p99_ns").is_some());
    }
}
