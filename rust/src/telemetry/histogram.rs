//! Fixed-bucket log2 histograms on relaxed atomics.
//!
//! A [`Histogram`] is a static-friendly array of power-of-two buckets
//! (`le = 2^i` nanoseconds) plus a running sum. Observation is two
//! relaxed `fetch_add`s and a `leading_zeros` — no locks, no
//! allocation — so the hot paths (per pool job, per grid cell) can
//! record unconditionally. Reads go through [`Histogram::snapshot`];
//! snapshots subtract ([`HistogramSnapshot::since`]) so callers can
//! attribute traffic to one measurement window, and merge by plain
//! bucket-wise addition — the layout makes merging associative and
//! commutative, which is why totals cannot depend on how many worker
//! threads recorded them (`tests/telemetry.rs` pins this).

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets: `le = 2^0 .. 2^(BUCKETS-1)` ns, with the last
/// bucket absorbing everything larger (2^42 ns ≈ 73 min — far beyond
/// any span this crate times).
pub const BUCKETS: usize = 43;

/// Bucket index for an observed value: the smallest `i` with
/// `v <= 2^i`, clamped to the top catch-all bucket.
fn bucket_index(v: u64) -> usize {
    if v <= 1 {
        0
    } else {
        ((64 - (v - 1).leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// A lock-free log2-bucket histogram (values in nanoseconds).
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    sum: AtomicU64,
}

impl Histogram {
    /// Const-constructible so histograms can live in `static`s.
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram { buckets: [ZERO; BUCKETS], sum: AtomicU64::new(0) }
    }

    /// Record one observation (nanoseconds).
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Point-in-time copy of the bucket counts and sum.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (b, a) in buckets.iter_mut().zip(&self.buckets) {
            *b = a.load(Ordering::Relaxed);
        }
        HistogramSnapshot { buckets, sum: self.sum.load(Ordering::Relaxed) }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// An owned copy of a [`Histogram`]'s state; all readout lives here.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: [u64; BUCKETS],
    /// Sum of all observed values (ns).
    pub sum: u64,
}

impl HistogramSnapshot {
    pub fn empty() -> Self {
        HistogramSnapshot { buckets: [0; BUCKETS], sum: 0 }
    }

    /// Total number of observations.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Mean observed value (ns); 0 with no observations.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum as f64 / n as f64
        }
    }

    /// The traffic recorded since `earlier` (bucket-wise difference).
    /// Counters are monotone, so on the same histogram this is always
    /// well-defined; saturates defensively anyway.
    pub fn since(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for i in 0..BUCKETS {
            buckets[i] = self.buckets[i].saturating_sub(earlier.buckets[i]);
        }
        HistogramSnapshot { buckets, sum: self.sum.saturating_sub(earlier.sum) }
    }

    /// Bucket-wise merge (associative + commutative).
    pub fn merge(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for i in 0..BUCKETS {
            buckets[i] = self.buckets[i] + other.buckets[i];
        }
        HistogramSnapshot { buckets, sum: self.sum + other.sum }
    }

    /// Estimated `q`-quantile (`q` in `[0, 1]`), linearly interpolated
    /// inside the bucket that crosses the target rank (the standard
    /// Prometheus `histogram_quantile` estimate). 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = q * total as f64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c;
            if next as f64 >= rank {
                let lower = if i == 0 { 0.0 } else { (1u64 << (i - 1)) as f64 };
                let upper = (1u64 << i) as f64;
                let frac = (rank - cum as f64) / c as f64;
                return lower + (upper - lower) * frac.clamp(0.0, 1.0);
            }
            cum = next;
        }
        (1u64 << (BUCKETS - 1)) as f64
    }

    /// Upper bound (`le`, ns) of bucket `i`.
    pub fn upper_bound(i: usize) -> u64 {
        1u64 << i
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_smallest_covering_power() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(1024), 10);
        assert_eq!(bucket_index(1025), 11);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn observe_and_snapshot_roundtrip() {
        let h = Histogram::new();
        for v in [1, 2, 3, 1000, 100_000] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 5);
        assert_eq!(s.sum, 1 + 2 + 3 + 1000 + 100_000);
        assert!((s.mean() - s.sum as f64 / 5.0).abs() < 1e-9);
    }

    #[test]
    fn since_isolates_a_window() {
        let h = Histogram::new();
        h.observe(10);
        let before = h.snapshot();
        h.observe(20);
        h.observe(30);
        let d = h.snapshot().since(&before);
        assert_eq!(d.count(), 2);
        assert_eq!(d.sum, 50);
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mk = |vals: &[u64]| {
            let h = Histogram::new();
            for &v in vals {
                h.observe(v);
            }
            h.snapshot()
        };
        let a = mk(&[1, 5, 9]);
        let b = mk(&[100, 200]);
        let c = mk(&[3_000_000]);
        assert_eq!(a.merge(&b), b.merge(&a));
        assert_eq!(a.merge(&b).merge(&c), a.merge(&b.merge(&c)));
        assert_eq!(a.merge(&b).merge(&c).count(), 6);
    }

    #[test]
    fn quantiles_interpolate_within_buckets() {
        let h = Histogram::new();
        // 100 observations all in the (512, 1024] bucket.
        for _ in 0..100 {
            h.observe(800);
        }
        let s = h.snapshot();
        let p50 = s.quantile(0.5);
        assert!((512.0..=1024.0).contains(&p50), "{p50}");
        // Median of a single bucket lands mid-bucket.
        assert!((p50 - 768.0).abs() < 16.0, "{p50}");
        assert!(s.quantile(0.99) > p50);
        assert_eq!(HistogramSnapshot::empty().quantile(0.5), 0.0);
    }

    #[test]
    fn quantile_orders_across_buckets() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.observe(100);
        }
        for _ in 0..10 {
            h.observe(1_000_000);
        }
        let s = h.snapshot();
        assert!(s.quantile(0.5) <= 128.0);
        assert!(s.quantile(0.99) > 500_000.0);
    }
}
