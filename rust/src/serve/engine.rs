//! The batched solve path: dedup → pooled solve → scatter.
//!
//! [`solve`] answers one [`Query`] — policy period plus both objective
//! columns and the backend's per-objective optima — entirely through
//! pure functions of the query's [`Query::solve_key`], so an answer is
//! bit-identical no matter which thread, batch, or process computes it.
//! On top of that purity:
//!
//! * a process-wide **answer cache** (the serve-path sibling of the
//!   online-policy [`PureMemo`](crate::util::memo::PureMemo), but
//!   holding whole [`Answer`] records rather than one scalar) serves
//!   repeat queries without re-entering the solver at all;
//! * [`BatchEngine`] answers a query *vector*: it deduplicates by solve
//!   key first, fans the unique solves out on the [`ThreadPool`] (the
//!   same work-stealing pool the grid engine uses, so exact-backend
//!   bracketing amortises across the batch), then scatters results back
//!   into input order. Results are written by unique-index, so the
//!   output is byte-identical for every thread count — the same
//!   determinism contract as [`ThreadPool::map`].

use std::collections::HashMap;

use super::query::Query;
use crate::model::params::ModelError;
use crate::telemetry::registry::metrics::{
    SERVE_DEDUP_NS, SERVE_QUERIES_TOTAL, SERVE_SCATTER_NS, SERVE_SOLVE_NS,
};
use crate::telemetry::Span;
use crate::util::pool::ThreadPool;
use crate::util::shard::ShardedMap;

/// One solved query: the policy's period and where it lands on both
/// objectives, plus the backend's per-objective optima for context.
/// All fields are minutes except the two percentages.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Answer {
    /// The period the policy chose (minutes).
    pub period: f64,
    /// Expected makespan at that period, under the query's backend.
    pub t_final: f64,
    /// Expected energy at that period (mW·min), same backend.
    pub e_final: f64,
    /// The backend's time-optimal period (minutes).
    pub t_time_opt: f64,
    /// The backend's energy-optimal period (minutes).
    pub t_energy_opt: f64,
    /// Makespan overhead vs running at `t_time_opt`, in percent — the
    /// knee metadata: how much time the chosen period gives up.
    pub time_overhead_pct: f64,
    /// Energy saved vs running at `t_time_opt`, in percent — what that
    /// time buys.
    pub energy_gain_pct: f64,
}

/// Answer one query. Pure function of [`Query::solve_key`]: the
/// effective scenario is read off the drift trajectory at `at`, the
/// policy picks its period through the online memo, and both objective
/// columns (plus the two optima anchoring the overhead/gain
/// percentages) evaluate through the query's backend.
pub fn solve(q: &Query) -> Result<Answer, ModelError> {
    let s = q.effective_scenario()?;
    let period = q.policy.period(&s)?;
    let (t_final, e_final) = q.backend.objectives(&s, period);
    let t_time_opt = q.backend.t_time_opt(&s)?;
    let t_energy_opt = q.backend.t_energy_opt(&s)?;
    let (t_at_topt, e_at_topt) = q.backend.objectives(&s, t_time_opt);
    Ok(Answer {
        period,
        t_final,
        e_final,
        t_time_opt,
        t_energy_opt,
        time_overhead_pct: (t_final / t_at_topt - 1.0) * 100.0,
        energy_gain_pct: (1.0 - e_final / e_at_topt) * 100.0,
    })
}

/// Capacity bound of the process-wide answer cache; overflow clears
/// wholesale, like [`PureMemo`](crate::util::memo::PureMemo) (entries
/// are pure functions of their key, so losing them only costs
/// recomputation).
const ANSWER_CACHE_CAPACITY: usize = 1 << 16;

static ANSWER_CACHE: ShardedMap<Vec<u64>, Answer> = ShardedMap::clearing(ANSWER_CACHE_CAPACITY);

/// Cached [`solve`]: repeats of a key are served without re-entering
/// the solver. Only `Ok` answers are cached — errors pass through
/// uncached and uncounted, the [`PureMemo`] convention
/// (counters track cache behaviour, not domain validity).
pub fn solve_cached(q: &Query) -> Result<Answer, ModelError> {
    let key = q.solve_key();
    if let Some(a) = ANSWER_CACHE.get(&key) {
        return Ok(a);
    }
    // Compute outside the lock: a concurrent miss on the same key just
    // recomputes the same pure value. Insert-if-absent keeps the first
    // writer's answer (identical bits either way — answers are pure
    // functions of the key) so stats stay coherent.
    let a = solve(q)?;
    ANSWER_CACHE.count_miss(&key);
    Ok(ANSWER_CACHE.insert_if_absent(key, a))
}

/// Hit/miss counters of the serve answer cache since process start
/// (the `info` subcommand's serve-path line, mirroring
/// `sweep::cache::stats`).
pub fn answer_cache_stats() -> (u64, u64) {
    ANSWER_CACHE.stats()
}

/// Wholesale capacity clears of the serve answer cache.
pub fn answer_cache_clears() -> u64 {
    ANSWER_CACHE.clears()
}

/// Live entry count of the serve answer cache.
pub fn answer_cache_len() -> usize {
    ANSWER_CACHE.len()
}

/// Live entries per shard (`ckpt_cache_shard_entries` exposition).
pub fn answer_cache_shard_entries() -> Vec<usize> {
    ANSWER_CACHE.shard_entries()
}

/// Batch query engine: dedup by solve key, solve each unique query once
/// on a thread pool, scatter answers back into input order.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchEngine {
    use_cache: bool,
}

impl BatchEngine {
    /// Engine backed by the process-wide answer cache (the serving
    /// default: repeats across batches are hits).
    pub fn new() -> BatchEngine {
        BatchEngine { use_cache: true }
    }

    /// Engine that bypasses the answer cache — every unique key solves
    /// fresh. Benchmarks use this for cold-path numbers; the underlying
    /// policy/optima memos still apply.
    pub fn without_cache() -> BatchEngine {
        BatchEngine { use_cache: false }
    }

    /// Answer a batch on the process-wide pool.
    pub fn answer_all(&self, queries: &[Query]) -> Vec<Result<Answer, ModelError>> {
        self.answer_all_on(ThreadPool::global(), queries)
    }

    /// Answer a batch on a caller-supplied pool. Answers come back in
    /// input order, one per query, bit-identical to calling [`solve`]
    /// on each query sequentially — at any worker count.
    pub fn answer_all_on(
        &self,
        pool: &ThreadPool,
        queries: &[Query],
    ) -> Vec<Result<Answer, ModelError>> {
        SERVE_QUERIES_TOTAL.add(queries.len() as u64);
        // Dedup pass: first occurrence of each solve key claims a slot.
        let (unique, slot) = {
            let _span = Span::start(&SERVE_DEDUP_NS);
            let keys: Vec<Vec<u64>> = queries.iter().map(Query::solve_key).collect();
            let mut first: HashMap<&[u64], usize> = HashMap::with_capacity(queries.len());
            let mut unique: Vec<usize> = Vec::new(); // query index of each unique key
            let mut slot: Vec<usize> = Vec::with_capacity(queries.len());
            for (i, key) in keys.iter().enumerate() {
                let u = *first.entry(key.as_slice()).or_insert_with(|| {
                    unique.push(i);
                    unique.len() - 1
                });
                slot.push(u);
            }
            (unique, slot)
        };
        // Pooled solve of the unique queries; results land by index, so
        // the scatter below is schedule-independent.
        let use_cache = self.use_cache;
        let solved: Vec<Result<Answer, ModelError>> = {
            let _span = Span::start(&SERVE_SOLVE_NS);
            pool.map(unique.len(), |u| {
                let q = &queries[unique[u]];
                if use_cache {
                    solve_cached(q)
                } else {
                    solve(q)
                }
            })
        };
        let _span = Span::start(&SERVE_SCATTER_NS);
        slot.into_iter().map(|u| solved[u].clone()).collect()
    }

    /// Number of unique solve keys in a batch (diagnostics: the batch
    /// summary line reports `answered N (U unique solves)`).
    pub fn unique_count(queries: &[Query]) -> usize {
        let keys: std::collections::HashSet<Vec<u64>> =
            queries.iter().map(Query::solve_key).collect();
        keys.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::tradeoff_presets;
    use crate::coordinator::PeriodPolicy;
    use crate::model::Backend;

    fn preset_query(label: &str) -> Query {
        let line = format!("{{\"scenario\": \"{label}\"}}");
        Query::parse_line(&line).unwrap()
    }

    #[test]
    fn solve_matches_the_sequential_policy_call() {
        for (label, s) in tradeoff_presets() {
            let q = preset_query(label);
            let a = solve(&q).unwrap();
            assert_eq!(
                a.period.to_bits(),
                q.policy.period(&s).unwrap().to_bits(),
                "{label}"
            );
            let (t, e) = q.backend.objectives(&s, a.period);
            assert_eq!(a.t_final.to_bits(), t.to_bits(), "{label}");
            assert_eq!(a.e_final.to_bits(), e.to_bits(), "{label}");
            // The knee trades a small time overhead for an energy gain.
            assert!(a.time_overhead_pct >= 0.0, "{label}: {}", a.time_overhead_pct);
            assert!(a.energy_gain_pct > 0.0, "{label}: {}", a.energy_gain_pct);
            assert!(a.t_time_opt > 0.0 && a.t_energy_opt > 0.0, "{label}");
        }
    }

    #[test]
    fn solve_errors_on_out_of_domain_scenarios_without_caching() {
        // C >= 2*mu*b: infeasible under every backend.
        let mut q = preset_query("fig1-rho5.5");
        q.scenario.mu = 6.0;
        let (_, misses_before) = answer_cache_stats();
        assert!(solve(&q).is_err());
        assert!(solve_cached(&q).is_err());
        let (_, misses_after) = answer_cache_stats();
        assert_eq!(misses_before, misses_after);
    }

    #[test]
    fn cached_solve_is_bit_identical_and_counts_hits() {
        let q = preset_query("alpha-heavy");
        let fresh = solve(&q).unwrap();
        let (h0, _) = answer_cache_stats();
        let first = solve_cached(&q).unwrap();
        let second = solve_cached(&q).unwrap();
        assert_eq!(first, fresh);
        assert_eq!(second, fresh);
        let (h1, _) = answer_cache_stats();
        assert!(h1 > h0, "repeat lookup must count a hit");
        assert!(answer_cache_len() >= 1);
    }

    #[test]
    fn batch_deduplicates_and_preserves_input_order() {
        let a = preset_query("fig1-rho5.5");
        let b = preset_query("fig1-rho7");
        let mut c = preset_query("fig1-rho5.5");
        c.policy = PeriodPolicy::AlgoT;
        let batch = vec![a.clone(), b.clone(), a.clone(), c.clone(), b.clone(), a.clone()];
        assert_eq!(BatchEngine::unique_count(&batch), 3);
        let answers = BatchEngine::without_cache().answer_all_on(&ThreadPool::new(0), &batch);
        assert_eq!(answers.len(), batch.len());
        // Duplicates answer identically; distinct queries differ.
        let get = |i: usize| answers[i].clone().unwrap();
        assert_eq!(get(0), get(2));
        assert_eq!(get(0), get(5));
        assert_eq!(get(1), get(4));
        assert_ne!(get(0).period.to_bits(), get(3).period.to_bits());
        // And each slot matches the direct sequential solve.
        for (i, q) in batch.iter().enumerate() {
            assert_eq!(get(i), solve(q).unwrap(), "slot {i}");
        }
    }

    #[test]
    fn batch_errors_scatter_to_every_duplicate() {
        let good = preset_query("fig1-rho5.5");
        let mut bad = preset_query("fig1-rho5.5");
        bad.scenario.mu = 6.0; // infeasible
        let batch = vec![good.clone(), bad.clone(), bad.clone(), good.clone()];
        let answers = BatchEngine::without_cache().answer_all_on(&ThreadPool::new(0), &batch);
        assert!(answers[0].is_ok() && answers[3].is_ok());
        assert!(answers[1].is_err() && answers[2].is_err());
        assert_eq!(answers[1], answers[2]);
    }

    #[test]
    fn exact_backend_batches_answer_like_sequential_solves() {
        let line = r#"{"scenario": "fig1-rho5.5", "model": "exact", "policy": "knee"}"#;
        let q = Query::parse_line(line).unwrap();
        assert_ne!(q.backend, Backend::FirstOrder);
        let direct = solve(&q).unwrap();
        let pooled =
            BatchEngine::without_cache().answer_all_on(&ThreadPool::new(3), &[q.clone(), q]);
        for a in pooled {
            assert_eq!(a.unwrap(), direct);
        }
    }
}
