//! The standardised serving benchmark behind `ckpt-period bench`.
//!
//! One workload, four numbers, every PR (the repo-root `BENCH_<n>.json`
//! trajectory):
//!
//! * **cold vs warm memo latency** — nanoseconds per knee solve on
//!   never-seen scenarios vs memo-resident repeats, measured directly
//!   on [`knee_period`] (the serving hot path);
//! * **queries/sec at 1, 4 and 8 threads** — [`BatchEngine`] end to
//!   end, cold (fresh scenarios, answer cache bypassed) and warm
//!   (answer-cache hits), on a per-thread-count local pool;
//! * **grid-engine cell throughput** — closed-form model cells per
//!   second through `GridSpec::evaluate` with the cell cache off, via
//!   the shared [`Bench`] harness (so quick mode and the
//!   `target/bench-results` dump behave like the `benches/` suites).
//!
//! Freshness is load-bearing: the online-policy memo quantises `(C, R,
//! μ)` to 3 significant digits, so "fresh" scenarios must differ by
//! more than 0.1% relative to miss. The generator walks μ
//! *multiplicatively* (0.45% per step — always a new quantum) off a
//! process-wide counter, so no two benchmark phases, reps, or calls
//! ever re-touch a quantised key by accident.
//!
//! Since schema v2 the document also carries tail latency: cold memo
//! p50/p95/p99 (per-solve timing), per-thread-count serve-stage
//! percentiles (windowed [`telemetry`](crate::telemetry) histogram
//! snapshots around each queries/sec leg, so each window holds exactly
//! that leg's batches), the pool thread count each measurement
//! actually used, and a full registry snapshot under `"telemetry"`.

use std::sync::atomic::{AtomicI32, Ordering};
use std::time::Instant;

use super::engine::BatchEngine;
use super::query::Query;
use crate::config::presets::fig1_scenario;
use crate::coordinator::PeriodPolicy;
use crate::model::params::Scenario;
use crate::model::Backend;
use crate::pareto::online::knee_period;
use crate::pareto::KneeMethod;
use crate::sweep::GridSpec;
use crate::telemetry::histogram::HistogramSnapshot;
use crate::telemetry::registry::metrics::{SERVE_DEDUP_NS, SERVE_SCATTER_NS, SERVE_SOLVE_NS};
use crate::telemetry::render;
use crate::util::bench::{black_box, Bench};
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use crate::util::stats::percentile;

const KNEE: PeriodPolicy = PeriodPolicy::Knee {
    method: KneeMethod::MaxDistanceToChord,
    backend: Backend::FirstOrder,
};

/// 0.45% per step: always more than the online memo's 0.1% quantum,
/// small enough that tens of thousands of steps stay in domain.
const MU_GROWTH: f64 = 1.0045;

static FRESH: AtomicI32 = AtomicI32::new(0);

/// `k` scenarios no prior phase of this process has solved: the μ walk
/// advances a process-wide counter, and consecutive μ values differ by
/// 0.45% relative — a fresh online-memo quantum each.
fn fresh_scenarios(k: usize) -> Vec<Scenario> {
    let start = FRESH.fetch_add(k as i32, Ordering::Relaxed);
    (0..k as i32).map(|i| fig1_scenario(120.0 * MU_GROWTH.powi(start + i), 5.5)).collect()
}

/// Per-knee-solve latency over `k` fresh scenarios: cold mean +
/// percentiles, warm bulk mean.
struct MemoLatency {
    cold_ns: f64,
    cold_p50_ns: f64,
    cold_p95_ns: f64,
    cold_p99_ns: f64,
    warm_ns: f64,
}

fn memo_latency(k: usize) -> MemoLatency {
    let scenarios = fresh_scenarios(k);
    let solve = |s: &Scenario| {
        black_box(
            knee_period(s, KneeMethod::MaxDistanceToChord, Backend::FirstOrder)
                .expect("bench scenarios stay in domain"),
        )
    };
    // Cold: per-solve timing so the trajectory records the tail, not
    // just the mean (the per-call `Instant` cost is tens of ns against
    // a ~tens-of-µs solve).
    let mut cold_each = Vec::with_capacity(k);
    for s in &scenarios {
        let t0 = Instant::now();
        solve(s);
        cold_each.push(t0.elapsed().as_secs_f64() * 1e9);
    }
    // Warm hits are ~100 ns — comparable to the timer itself — so the
    // warm figure stays a bulk mean over many passes.
    const PASSES: usize = 10;
    let t1 = Instant::now();
    for _ in 0..PASSES {
        for s in &scenarios {
            solve(s);
        }
    }
    let warm = t1.elapsed().as_secs_f64();
    MemoLatency {
        cold_ns: cold_each.iter().sum::<f64>() / k as f64,
        cold_p50_ns: percentile(&cold_each, 0.50),
        cold_p95_ns: percentile(&cold_each, 0.95),
        cold_p99_ns: percentile(&cold_each, 0.99),
        warm_ns: warm / (k * PASSES) as f64 * 1e9,
    }
}

/// (cold, warm) queries/sec through the batch engine on a pool with
/// `threads` participants (the submitter plus `threads - 1` workers).
/// Median over `reps` disjoint fresh batches of `batch` queries.
fn queries_per_sec(threads: usize, batch: usize, reps: usize) -> (f64, f64, usize) {
    let pool = ThreadPool::new(threads - 1);
    let pool_threads = pool.n_workers() + 1;
    let mut cold_s = Vec::with_capacity(reps);
    let mut warm_s = Vec::with_capacity(reps);
    for _ in 0..reps {
        let queries: Vec<Query> = fresh_scenarios(batch)
            .into_iter()
            .map(|s| Query::new(s, KNEE, Backend::FirstOrder))
            .collect();
        let t0 = Instant::now();
        black_box(BatchEngine::without_cache().answer_all_on(&pool, &queries));
        cold_s.push(t0.elapsed().as_secs_f64());
        // Fill the answer cache untimed, then time the pure-hit pass.
        let engine = BatchEngine::new();
        black_box(engine.answer_all_on(&pool, &queries));
        let t1 = Instant::now();
        black_box(engine.answer_all_on(&pool, &queries));
        warm_s.push(t1.elapsed().as_secs_f64());
    }
    let b = batch as f64;
    (b / percentile(&cold_s, 0.5), b / percentile(&warm_s, 0.5), pool_threads)
}

/// The serve-stage percentile block for one queries/sec leg: the
/// windowed histogram deltas (`after.since(before)`) for the engine's
/// dedup/solve/scatter spans, so each leg reports exactly its own
/// batches. (Parse never runs here — the bench constructs queries
/// directly.)
fn stage_stats_json(before: &[HistogramSnapshot; 3], after: &[HistogramSnapshot; 3]) -> Json {
    let stages = ["dedup", "solve", "scatter"];
    Json::obj(
        stages
            .iter()
            .enumerate()
            .map(|(i, name)| (*name, render::hist_stats_json(&after[i].since(&before[i]))))
            .collect(),
    )
}

/// The three serve-stage histograms the bench windows, snapshotted now.
fn stage_snapshots() -> [HistogramSnapshot; 3] {
    [SERVE_DEDUP_NS.snapshot(), SERVE_SOLVE_NS.snapshot(), SERVE_SCATTER_NS.snapshot()]
}

/// `git describe --always --dirty`, or `"unknown"` outside a work tree
/// (the bench must run anywhere the binary does).
pub fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Run the standardised workload and return the `BENCH_<n>.json`
/// document. Quick mode (the `--quick` flag sets `CKPT_BENCH_QUICK`)
/// shrinks every batch so CI finishes in seconds; the schema is
/// identical either way — `tests/bench_schema.rs` holds it fixed.
pub fn run_bench() -> Json {
    let quick = std::env::var("CKPT_BENCH_QUICK").is_ok();
    let memo_scenarios = if quick { 128 } else { 512 };
    let batch = if quick { 256 } else { 1024 };
    let reps = if quick { 3 } else { 5 };
    let cells = if quick { 2048usize } else { 8192 };

    println!("serve bench ({}): memo latency …", if quick { "quick" } else { "full" });
    let memo = memo_latency(memo_scenarios);
    println!(
        "  cold {:.0} ns/solve (p99 {:.0}), warm {:.0} ns/solve",
        memo.cold_ns, memo.cold_p99_ns, memo.warm_ns
    );

    let mut qps = Vec::new();
    for threads in [1usize, 4, 8] {
        let before = stage_snapshots();
        let (cold, warm, pool_threads) = queries_per_sec(threads, batch, reps);
        let stages = stage_stats_json(&before, &stage_snapshots());
        println!("  {threads} thread(s): {cold:.0} cold q/s, {warm:.0} warm q/s");
        qps.push((
            threads.to_string(),
            Json::obj(vec![
                ("cold", Json::Num(cold)),
                ("warm", Json::Num(warm)),
                ("pool_threads", Json::Num(pool_threads as f64)),
                ("stages", stages),
            ]),
        ));
    }

    // Grid-engine cell throughput through the shared harness (prints
    // its own report line and lands in target/bench-results/serve.json).
    let s = fig1_scenario(300.0, 5.5);
    let periods: Vec<f64> = (0..cells).map(|i| 15.0 + 0.02 * i as f64).collect();
    let spec = GridSpec::model_sweep(s, &periods, 1).without_cache();
    let mut bench = Bench::new("serve");
    let cell_throughput = {
        let m = bench.run_units("grid_model_cells", cells as f64, || spec.evaluate());
        cells as f64 / m.median()
    };
    bench.finish();

    Json::obj(vec![
        ("schema", Json::Str("ckpt-period/bench/v2".into())),
        ("suite", Json::Str("serve".into())),
        ("quick", Json::Bool(quick)),
        ("git_describe", Json::Str(git_describe())),
        ("pool_threads", Json::Num((ThreadPool::global().n_workers() + 1) as f64)),
        ("memo_scenarios", Json::Num(memo_scenarios as f64)),
        ("batch", Json::Num(batch as f64)),
        ("cold_memo_ns", Json::Num(memo.cold_ns)),
        ("cold_memo_p50_ns", Json::Num(memo.cold_p50_ns)),
        ("cold_memo_p95_ns", Json::Num(memo.cold_p95_ns)),
        ("cold_memo_p99_ns", Json::Num(memo.cold_p99_ns)),
        ("warm_memo_ns", Json::Num(memo.warm_ns)),
        ("queries_per_sec", Json::Obj(qps.into_iter().collect())),
        ("cells", Json::Num(cells as f64)),
        ("cell_throughput_per_sec", Json::Num(cell_throughput)),
        // The whole-registry snapshot: counters, cache rows, histogram
        // percentiles — everything the run touched, not just the legs.
        ("telemetry", render::snapshot_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_scenarios_never_collide_even_across_calls() {
        let a = fresh_scenarios(16);
        let b = fresh_scenarios(16);
        let mut keys: Vec<[u64; 10]> = Vec::new();
        for s in a.iter().chain(&b) {
            keys.push(s.key_bits());
        }
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), 32, "duplicate scenario bits");
        // Consecutive μ steps exceed the online memo's 0.1% quantum.
        for w in a.windows(2) {
            let rel = (w[1].mu - w[0].mu) / w[0].mu;
            assert!(rel > 0.002, "step {rel} too small for the quantiser");
        }
        // And the scenarios are solvable.
        assert!(knee_period(&a[0], KneeMethod::MaxDistanceToChord, Backend::FirstOrder).is_ok());
    }

    #[test]
    fn git_describe_always_yields_a_label() {
        assert!(!git_describe().is_empty());
    }
}
