//! The standardised serving benchmark behind `ckpt-period bench`.
//!
//! One workload, four numbers, every PR (the repo-root `BENCH_<n>.json`
//! trajectory):
//!
//! * **cold vs warm memo latency** — nanoseconds per knee solve on
//!   never-seen scenarios vs memo-resident repeats, measured directly
//!   on [`knee_period`] (the serving hot path);
//! * **queries/sec at 1, 4 and 8 threads** — [`BatchEngine`] end to
//!   end, cold (fresh scenarios, answer cache bypassed) and warm
//!   (answer-cache hits), on a per-thread-count local pool;
//! * **grid-engine cell throughput** — closed-form model cells per
//!   second through `GridSpec::evaluate` with the cell cache off, via
//!   the shared [`Bench`] harness (so quick mode and the
//!   `target/bench-results` dump behave like the `benches/` suites).
//!
//! Freshness is load-bearing: the online-policy memo quantises `(C, R,
//! μ)` to 3 significant digits, so "fresh" scenarios must differ by
//! more than 0.1% relative to miss. The generator walks μ
//! *multiplicatively* (0.45% per step — always a new quantum) off a
//! process-wide counter, so no two benchmark phases, reps, or calls
//! ever re-touch a quantised key by accident.

use std::sync::atomic::{AtomicI32, Ordering};
use std::time::Instant;

use super::engine::BatchEngine;
use super::query::Query;
use crate::config::presets::fig1_scenario;
use crate::coordinator::PeriodPolicy;
use crate::model::params::Scenario;
use crate::model::Backend;
use crate::pareto::online::knee_period;
use crate::pareto::KneeMethod;
use crate::sweep::GridSpec;
use crate::util::bench::{black_box, Bench};
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use crate::util::stats::percentile;

const KNEE: PeriodPolicy = PeriodPolicy::Knee {
    method: KneeMethod::MaxDistanceToChord,
    backend: Backend::FirstOrder,
};

/// 0.45% per step: always more than the online memo's 0.1% quantum,
/// small enough that tens of thousands of steps stay in domain.
const MU_GROWTH: f64 = 1.0045;

static FRESH: AtomicI32 = AtomicI32::new(0);

/// `k` scenarios no prior phase of this process has solved: the μ walk
/// advances a process-wide counter, and consecutive μ values differ by
/// 0.45% relative — a fresh online-memo quantum each.
fn fresh_scenarios(k: usize) -> Vec<Scenario> {
    let start = FRESH.fetch_add(k as i32, Ordering::Relaxed);
    (0..k as i32).map(|i| fig1_scenario(120.0 * MU_GROWTH.powi(start + i), 5.5)).collect()
}

/// (cold_ns, warm_ns) per knee solve over `k` fresh scenarios.
fn memo_latency(k: usize) -> (f64, f64) {
    let scenarios = fresh_scenarios(k);
    let solve = |s: &Scenario| {
        black_box(
            knee_period(s, KneeMethod::MaxDistanceToChord, Backend::FirstOrder)
                .expect("bench scenarios stay in domain"),
        )
    };
    let t0 = Instant::now();
    for s in &scenarios {
        solve(s);
    }
    let cold = t0.elapsed().as_secs_f64();
    const PASSES: usize = 10;
    let t1 = Instant::now();
    for _ in 0..PASSES {
        for s in &scenarios {
            solve(s);
        }
    }
    let warm = t1.elapsed().as_secs_f64();
    (cold / k as f64 * 1e9, warm / (k * PASSES) as f64 * 1e9)
}

/// (cold, warm) queries/sec through the batch engine on a pool with
/// `threads` participants (the submitter plus `threads - 1` workers).
/// Median over `reps` disjoint fresh batches of `batch` queries.
fn queries_per_sec(threads: usize, batch: usize, reps: usize) -> (f64, f64) {
    let pool = ThreadPool::new(threads - 1);
    let mut cold_s = Vec::with_capacity(reps);
    let mut warm_s = Vec::with_capacity(reps);
    for _ in 0..reps {
        let queries: Vec<Query> = fresh_scenarios(batch)
            .into_iter()
            .map(|s| Query::new(s, KNEE, Backend::FirstOrder))
            .collect();
        let t0 = Instant::now();
        black_box(BatchEngine::without_cache().answer_all_on(&pool, &queries));
        cold_s.push(t0.elapsed().as_secs_f64());
        // Fill the answer cache untimed, then time the pure-hit pass.
        let engine = BatchEngine::new();
        black_box(engine.answer_all_on(&pool, &queries));
        let t1 = Instant::now();
        black_box(engine.answer_all_on(&pool, &queries));
        warm_s.push(t1.elapsed().as_secs_f64());
    }
    let b = batch as f64;
    (b / percentile(&cold_s, 0.5), b / percentile(&warm_s, 0.5))
}

/// `git describe --always --dirty`, or `"unknown"` outside a work tree
/// (the bench must run anywhere the binary does).
pub fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Run the standardised workload and return the `BENCH_<n>.json`
/// document. Quick mode (the `--quick` flag sets `CKPT_BENCH_QUICK`)
/// shrinks every batch so CI finishes in seconds; the schema is
/// identical either way — `tests/bench_schema.rs` holds it fixed.
pub fn run_bench() -> Json {
    let quick = std::env::var("CKPT_BENCH_QUICK").is_ok();
    let memo_scenarios = if quick { 128 } else { 512 };
    let batch = if quick { 256 } else { 1024 };
    let reps = if quick { 3 } else { 5 };
    let cells = if quick { 2048usize } else { 8192 };

    println!("serve bench ({}): memo latency …", if quick { "quick" } else { "full" });
    let (cold_ns, warm_ns) = memo_latency(memo_scenarios);
    println!("  cold {cold_ns:.0} ns/solve, warm {warm_ns:.0} ns/solve");

    let mut qps = Vec::new();
    for threads in [1usize, 4, 8] {
        let (cold, warm) = queries_per_sec(threads, batch, reps);
        println!("  {threads} thread(s): {cold:.0} cold q/s, {warm:.0} warm q/s");
        qps.push((
            threads.to_string(),
            Json::obj(vec![("cold", Json::Num(cold)), ("warm", Json::Num(warm))]),
        ));
    }

    // Grid-engine cell throughput through the shared harness (prints
    // its own report line and lands in target/bench-results/serve.json).
    let s = fig1_scenario(300.0, 5.5);
    let periods: Vec<f64> = (0..cells).map(|i| 15.0 + 0.02 * i as f64).collect();
    let spec = GridSpec::model_sweep(s, &periods, 1).without_cache();
    let mut bench = Bench::new("serve");
    let cell_throughput = {
        let m = bench.run_units("grid_model_cells", cells as f64, || spec.evaluate());
        cells as f64 / m.median()
    };
    bench.finish();

    Json::obj(vec![
        ("schema", Json::Str("ckpt-period/bench/v1".into())),
        ("suite", Json::Str("serve".into())),
        ("quick", Json::Bool(quick)),
        ("git_describe", Json::Str(git_describe())),
        ("pool_threads", Json::Num((ThreadPool::global().n_workers() + 1) as f64)),
        ("memo_scenarios", Json::Num(memo_scenarios as f64)),
        ("batch", Json::Num(batch as f64)),
        ("cold_memo_ns", Json::Num(cold_ns)),
        ("warm_memo_ns", Json::Num(warm_ns)),
        ("queries_per_sec", Json::Obj(qps.into_iter().collect())),
        ("cells", Json::Num(cells as f64)),
        ("cell_throughput_per_sec", Json::Num(cell_throughput)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_scenarios_never_collide_even_across_calls() {
        let a = fresh_scenarios(16);
        let b = fresh_scenarios(16);
        let mut keys: Vec<[u64; 10]> = Vec::new();
        for s in a.iter().chain(&b) {
            keys.push(s.key_bits());
        }
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), 32, "duplicate scenario bits");
        // Consecutive μ steps exceed the online memo's 0.1% quantum.
        for w in a.windows(2) {
            let rel = (w[1].mu - w[0].mu) / w[0].mu;
            assert!(rel > 0.002, "step {rel} too small for the quantiser");
        }
        // And the scenarios are solvable.
        assert!(knee_period(&a[0], KneeMethod::MaxDistanceToChord, Backend::FirstOrder).is_ok());
    }

    #[test]
    fn git_describe_always_yields_a_label() {
        assert!(!git_describe().is_empty());
    }
}
