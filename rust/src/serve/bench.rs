//! The standardised serving benchmark behind `ckpt-period bench`.
//!
//! One workload, four numbers, every PR (the repo-root `BENCH_<n>.json`
//! trajectory):
//!
//! * **cold vs warm memo latency** — nanoseconds per knee solve on
//!   never-seen scenarios vs memo-resident repeats, measured directly
//!   on [`knee_period`] (the serving hot path);
//! * **queries/sec at 1, 4 and 8 threads** — [`BatchEngine`] end to
//!   end, cold (fresh scenarios, answer cache bypassed) and warm
//!   (answer-cache hits), on a per-thread-count local pool;
//! * **grid-engine cell throughput** — closed-form model cells per
//!   second through `GridSpec::evaluate` with the cell cache off, via
//!   the shared [`Bench`] harness (so quick mode and the
//!   `target/bench-results` dump behave like the `benches/` suites).
//!
//! Freshness is load-bearing: the online-policy memo quantises `(C, R,
//! μ)` to 3 significant digits, so "fresh" scenarios must differ by
//! more than 0.1% relative to miss. The generator walks μ
//! *multiplicatively* (0.45% per step — always a new quantum) off a
//! process-wide counter, so no two benchmark phases, reps, or calls
//! ever re-touch a quantised key by accident.
//!
//! Since schema v2 the document also carries tail latency: cold memo
//! p50/p95/p99 (per-solve timing), per-thread-count serve-stage
//! percentiles (windowed [`telemetry`](crate::telemetry) histogram
//! snapshots around each queries/sec leg, so each window holds exactly
//! that leg's batches), the pool thread count each measurement
//! actually used, and a full registry snapshot under `"telemetry"`.
//!
//! Schema v3 adds the two solve-hot-path legs behind the sharded-cache
//! and pruned-envelope work: **frontier points/sec at 1, 4 and 8
//! threads** (dense exact-backend sampling over
//! [`Frontier::compute_on`] on a per-leg local pool; cold = never-seen
//! tiered scenario including the optima-memo misses, warm =
//! memo-resident re-sample) and **tier-plan solves/sec** (cold
//! bound-pruned envelope optimisation vs memoised repeats, with the
//! `ckpt_tier_envelope_*` counter deltas of the cold pass recording
//! the pruning rate). The gate compares cold legs as well as warm ones
//! since v3.
//!
//! Schema v4 adds the two legs behind the batched-executor and
//! warm-start work: **Monte-Carlo replicas/sec at 1, 4 and 8 threads**
//! (the retained per-replica scalar reference loop vs the batched
//! lockstep executor on per-leg local pools — identical seeds,
//! bit-identical results, the lockstep batch size in force reported
//! per leg) and **warm-started exact endpoint re-solves/sec** (a μ
//! walk down one warm-hint family, the drift re-solve shape, vs
//! family-cold solves that each run the full endpoint grid scan, with
//! the `ckpt_opt_warm_*` counter deltas of the drifting pass).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicI32, Ordering};
use std::time::Instant;

use super::engine::BatchEngine;
use super::query::Query;
use crate::config::presets::fig1_scenario;
use crate::coordinator::PeriodPolicy;
use crate::model::params::Scenario;
use crate::model::{tiers, Backend, CheckpointParams, PowerParams, RecoveryModel};
use crate::pareto::online::knee_period;
use crate::pareto::{Frontier, KneeMethod};
use crate::sim::batch::{effective_batch_size, run_batched_on};
use crate::sim::{FailureProcess, SimConfig, Simulator};
use crate::storage::TierSpec;
use crate::sweep::GridSpec;
use crate::telemetry::histogram::HistogramSnapshot;
use crate::telemetry::registry::metrics::{
    OPT_WARM_FALLBACKS_TOTAL, OPT_WARM_HITS_TOTAL, SERVE_DEDUP_NS, SERVE_SCATTER_NS,
    SERVE_SOLVE_NS, TIER_ENVELOPE_EVALUATED_TOTAL, TIER_ENVELOPE_SKIPPED_TOTAL,
};
use crate::telemetry::render;
use crate::util::bench::{black_box, Bench};
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use crate::util::stats::percentile;

const KNEE: PeriodPolicy = PeriodPolicy::Knee {
    method: KneeMethod::MaxDistanceToChord,
    backend: Backend::FirstOrder,
};

/// 0.45% per step: always more than the online memo's 0.1% quantum,
/// small enough that tens of thousands of steps stay in domain.
const MU_GROWTH: f64 = 1.0045;

static FRESH: AtomicI32 = AtomicI32::new(0);

/// `k` scenarios no prior phase of this process has solved: the μ walk
/// advances a process-wide counter, and consecutive μ values differ by
/// 0.45% relative — a fresh online-memo quantum each.
fn fresh_scenarios(k: usize) -> Vec<Scenario> {
    let start = FRESH.fetch_add(k as i32, Ordering::Relaxed);
    (0..k as i32).map(|i| fig1_scenario(120.0 * MU_GROWTH.powi(start + i), 5.5)).collect()
}

/// Per-knee-solve latency over `k` fresh scenarios: cold mean +
/// percentiles, warm bulk mean.
struct MemoLatency {
    cold_ns: f64,
    cold_p50_ns: f64,
    cold_p95_ns: f64,
    cold_p99_ns: f64,
    warm_ns: f64,
}

fn memo_latency(k: usize) -> MemoLatency {
    let scenarios = fresh_scenarios(k);
    let solve = |s: &Scenario| {
        black_box(
            knee_period(s, KneeMethod::MaxDistanceToChord, Backend::FirstOrder)
                .expect("bench scenarios stay in domain"),
        )
    };
    // Cold: per-solve timing so the trajectory records the tail, not
    // just the mean (the per-call `Instant` cost is tens of ns against
    // a ~tens-of-µs solve).
    let mut cold_each = Vec::with_capacity(k);
    for s in &scenarios {
        let t0 = Instant::now();
        solve(s);
        cold_each.push(t0.elapsed().as_secs_f64() * 1e9);
    }
    // Warm hits are ~100 ns — comparable to the timer itself — so the
    // warm figure stays a bulk mean over many passes.
    const PASSES: usize = 10;
    let t1 = Instant::now();
    for _ in 0..PASSES {
        for s in &scenarios {
            solve(s);
        }
    }
    let warm = t1.elapsed().as_secs_f64();
    MemoLatency {
        cold_ns: cold_each.iter().sum::<f64>() / k as f64,
        cold_p50_ns: percentile(&cold_each, 0.50),
        cold_p95_ns: percentile(&cold_each, 0.95),
        cold_p99_ns: percentile(&cold_each, 0.99),
        warm_ns: warm / (k * PASSES) as f64 * 1e9,
    }
}

/// (cold, warm) queries/sec through the batch engine on a pool with
/// `threads` participants (the submitter plus `threads - 1` workers).
/// Median over `reps` disjoint fresh batches of `batch` queries.
fn queries_per_sec(threads: usize, batch: usize, reps: usize) -> (f64, f64, usize) {
    let pool = ThreadPool::new(threads - 1);
    let pool_threads = pool.n_workers() + 1;
    let mut cold_s = Vec::with_capacity(reps);
    let mut warm_s = Vec::with_capacity(reps);
    for _ in 0..reps {
        let queries: Vec<Query> = fresh_scenarios(batch)
            .into_iter()
            .map(|s| Query::new(s, KNEE, Backend::FirstOrder))
            .collect();
        let t0 = Instant::now();
        black_box(BatchEngine::without_cache().answer_all_on(&pool, &queries));
        cold_s.push(t0.elapsed().as_secs_f64());
        // Fill the answer cache untimed, then time the pure-hit pass.
        let engine = BatchEngine::new();
        black_box(engine.answer_all_on(&pool, &queries));
        let t1 = Instant::now();
        black_box(engine.answer_all_on(&pool, &queries));
        warm_s.push(t1.elapsed().as_secs_f64());
    }
    let b = batch as f64;
    (b / percentile(&cold_s, 0.5), b / percentile(&warm_s, 0.5), pool_threads)
}

/// `k` three-tier scenarios off the same μ walk as [`fresh_scenarios`]
/// — exact-bits tier-plan/optima memo keys no prior phase has seen.
/// The SSD + burst-buffer + PFS shape matches the tiers-3 preset, the
/// configuration the envelope pruning is sized against.
fn fresh_tiered(k: usize) -> Vec<Scenario> {
    let start = FRESH.fetch_add(k as i32, Ordering::Relaxed);
    let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).expect("static params");
    let power = PowerParams::new(1.0, 1.0, 10.0, 0.0).expect("static params");
    (0..k as i32)
        .map(|i| {
            Scenario::with_tier_specs(
                ckpt,
                power,
                300.0,
                10_000.0 * MU_GROWTH.powi(start + i),
                &[
                    TierSpec::new(1.0, 1.0, 3.0),
                    TierSpec::new(2.0, 3.0, 6.0),
                    TierSpec::new(10.0, 10.0, 10.0),
                ],
            )
            .expect("bench scenarios stay in domain")
        })
        .collect()
}

/// (cold, warm) frontier points/sec on a pool with `threads`
/// participants: dense exact-backend sampling of a tiered scenario's
/// trade-off through [`Frontier::compute_on`]. Cold solves a
/// never-seen scenario (the optima-memo misses — two numeric
/// optimisations — included); warm re-samples the same scenario with
/// memo-resident optima, so it measures the pooled per-point sampling
/// itself. Median over `reps` fresh scenarios.
fn frontier_points_per_sec(threads: usize, points: usize, reps: usize) -> (f64, f64, usize) {
    let pool = ThreadPool::new(threads - 1);
    let pool_threads = pool.n_workers() + 1;
    let backend = Backend::Exact(RecoveryModel::Ideal);
    let mut cold_s = Vec::with_capacity(reps);
    let mut warm_s = Vec::with_capacity(reps);
    for s in fresh_tiered(reps) {
        let t0 = Instant::now();
        black_box(Frontier::compute_on(&pool, &s, points, backend).expect("in domain"));
        cold_s.push(t0.elapsed().as_secs_f64());
        let t1 = Instant::now();
        black_box(Frontier::compute_on(&pool, &s, points, backend).expect("in domain"));
        warm_s.push(t1.elapsed().as_secs_f64());
    }
    let p = points as f64;
    (p / percentile(&cold_s, 0.5), p / percentile(&warm_s, 0.5), pool_threads)
}

/// Tier-plan solves/sec over `k` fresh three-tier scenarios (a time
/// plan and an energy plan each): cold runs the bound-pruned envelope
/// optimisation end to end, warm repeats the memo-resident plans. Also
/// returns the `ckpt_tier_envelope_*` counter deltas over the cold
/// pass — the recorded pruning rate.
fn tier_plan_solves_per_sec(k: usize) -> (f64, f64, u64, u64) {
    let scenarios = fresh_tiered(k);
    let solve = |s: &Scenario| {
        let h = *s.hierarchy().expect("tiered scenario");
        black_box(tiers::time_plan(s, &h).expect("in domain"));
        black_box(tiers::energy_plan(s, &h).expect("in domain"));
    };
    let evaluated0 = TIER_ENVELOPE_EVALUATED_TOTAL.get();
    let skipped0 = TIER_ENVELOPE_SKIPPED_TOTAL.get();
    let t0 = Instant::now();
    for s in &scenarios {
        solve(s);
    }
    let cold = t0.elapsed().as_secs_f64();
    let evaluated = TIER_ENVELOPE_EVALUATED_TOTAL.get() - evaluated0;
    let skipped = TIER_ENVELOPE_SKIPPED_TOTAL.get() - skipped0;
    const PASSES: usize = 10;
    let t1 = Instant::now();
    for _ in 0..PASSES {
        for s in &scenarios {
            solve(s);
        }
    }
    let warm = t1.elapsed().as_secs_f64();
    ((2 * k) as f64 / cold, (2 * k * PASSES) as f64 / warm, evaluated, skipped)
}

/// (scalar, batched) Monte-Carlo replicas/sec on a pool with `threads`
/// participants: the retained per-replica reference loop (one
/// `Simulator::run` per pool task) vs the batched lockstep executor
/// ([`run_batched_on`], whole blocks per pool job over
/// struct-of-arrays state). Identical seeds, bit-identical results —
/// the leg measures execution shape only. Median over `reps` runs;
/// also returns the lockstep batch size in force and the pool's
/// participant count.
fn sim_replicas_per_sec(
    threads: usize,
    replicates: usize,
    reps: usize,
) -> (f64, f64, usize, usize) {
    let pool = ThreadPool::new(threads - 1);
    let pool_threads = pool.n_workers() + 1;
    let s = fig1_scenario(300.0, 5.5);
    // Young's period: deterministic, in domain, close enough to the
    // optimum that the event mix is representative.
    let period = s.min_period().max((2.0 * s.ckpt.c * s.mu).sqrt());
    let cfg = SimConfig {
        scenario: s,
        period,
        failure: FailureProcess::Exponential { mtbf: 300.0 },
        failures_during_recovery: true,
    };
    let sim = Simulator::new(cfg.clone());
    // A fixed base seed: both executors simulate the same sample paths,
    // so the two timings cover identical work.
    const SEED: u64 = 41_000_000;
    let mut scalar_s = Vec::with_capacity(reps);
    let mut batched_s = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        if threads == 1 {
            for i in 0..replicates {
                black_box(sim.run(SEED + i as u64));
            }
        } else {
            black_box(pool.map(replicates, |i| sim.run(SEED + i as u64)));
        }
        scalar_s.push(t0.elapsed().as_secs_f64());
        let t1 = Instant::now();
        black_box(run_batched_on(&pool, &cfg, replicates, SEED, threads));
        batched_s.push(t1.elapsed().as_secs_f64());
    }
    let r = replicates as f64;
    (
        r / percentile(&scalar_s, 0.5),
        r / percentile(&batched_s, 0.5),
        effective_batch_size(replicates),
        pool_threads,
    )
}

/// ω decay for the family-cold re-solve scenarios: multiplicative off
/// the shared [`FRESH`] counter, so every step is a never-seen
/// warm-hint family key and the decayed value stays in `(0, 0.5]` for
/// any process-lifetime counter value.
const OMEGA_DECAY: f64 = 0.9995;

/// (cold, warm) exact-backend endpoint solves/sec. The *warm* pass
/// walks μ multiplicatively down one warm-hint family — the shape of a
/// drifting frontier re-solve — so after the family's first solve
/// every optimisation seeds a 3-probe bracket from the previous
/// optimum. The *cold* pass gives every scenario a fresh ω (ω is part
/// of the drift-invariant family key), so the hint store never has an
/// offer and every solve runs the full endpoint grid scan. Also
/// returns the `ckpt_opt_warm_*` counter deltas of the warm pass — the
/// recorded hit/fallback split.
fn warm_resolve_per_sec(k: usize) -> (f64, f64, u64, u64) {
    let backend = Backend::Exact(RecoveryModel::Ideal);
    let base = fig1_scenario(140.0, 5.5);
    let start = FRESH.fetch_add(2 * k as i32, Ordering::Relaxed);
    let solve = |s: &Scenario| {
        black_box(backend.t_time_opt(s).expect("bench scenarios stay in domain"));
        black_box(backend.t_energy_opt(s).expect("bench scenarios stay in domain"));
    };
    let cold_scens: Vec<Scenario> = (0..k as i32)
        .map(|i| {
            let ckpt = CheckpointParams::new(
                base.ckpt.c,
                base.ckpt.r,
                base.ckpt.d,
                0.5 * OMEGA_DECAY.powi(start + i),
            )
            .expect("bench scenarios stay in domain");
            Scenario::new(ckpt, base.power, base.mu, base.t_base)
                .expect("bench scenarios stay in domain")
        })
        .collect();
    let warm_scens: Vec<Scenario> = (0..k as i32)
        .map(|i| {
            Scenario::new(
                base.ckpt,
                base.power,
                140.0 * MU_GROWTH.powi(start + k as i32 + i),
                base.t_base,
            )
            .expect("bench scenarios stay in domain")
        })
        .collect();
    let t0 = Instant::now();
    for s in &cold_scens {
        solve(s);
    }
    let cold = t0.elapsed().as_secs_f64();
    let hits0 = OPT_WARM_HITS_TOTAL.get();
    let falls0 = OPT_WARM_FALLBACKS_TOTAL.get();
    let t1 = Instant::now();
    for s in &warm_scens {
        solve(s);
    }
    let warm = t1.elapsed().as_secs_f64();
    (
        (2 * k) as f64 / cold,
        (2 * k) as f64 / warm,
        OPT_WARM_HITS_TOTAL.get() - hits0,
        OPT_WARM_FALLBACKS_TOTAL.get() - falls0,
    )
}

/// The serve-stage percentile block for one queries/sec leg: the
/// windowed histogram deltas (`after.since(before)`) for the engine's
/// dedup/solve/scatter spans, so each leg reports exactly its own
/// batches. (Parse never runs here — the bench constructs queries
/// directly.)
fn stage_stats_json(before: &[HistogramSnapshot; 3], after: &[HistogramSnapshot; 3]) -> Json {
    let stages = ["dedup", "solve", "scatter"];
    Json::obj(
        stages
            .iter()
            .enumerate()
            .map(|(i, name)| (*name, render::hist_stats_json(&after[i].since(&before[i]))))
            .collect(),
    )
}

/// The three serve-stage histograms the bench windows, snapshotted now.
fn stage_snapshots() -> [HistogramSnapshot; 3] {
    [SERVE_DEDUP_NS.snapshot(), SERVE_SOLVE_NS.snapshot(), SERVE_SCATTER_NS.snapshot()]
}

/// `git describe --always --dirty`, or `"unknown"` outside a work tree
/// (the bench must run anywhere the binary does).
pub fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Run the standardised workload and return the `BENCH_<n>.json`
/// document. Quick mode (the `--quick` flag sets `CKPT_BENCH_QUICK`)
/// shrinks every batch so CI finishes in seconds; the schema is
/// identical either way — `tests/bench_schema.rs` holds it fixed.
pub fn run_bench() -> Json {
    let quick = std::env::var("CKPT_BENCH_QUICK").is_ok();
    let memo_scenarios = if quick { 128 } else { 512 };
    let batch = if quick { 256 } else { 1024 };
    let reps = if quick { 3 } else { 5 };
    let cells = if quick { 2048usize } else { 8192 };
    let frontier_points = if quick { 64usize } else { 256 };
    let tier_scenarios = if quick { 32usize } else { 128 };
    let sim_replicates = if quick { 512usize } else { 4096 };
    let warm_scenarios = if quick { 32usize } else { 128 };

    println!("serve bench ({}): memo latency …", if quick { "quick" } else { "full" });
    let memo = memo_latency(memo_scenarios);
    println!(
        "  cold {:.0} ns/solve (p99 {:.0}), warm {:.0} ns/solve",
        memo.cold_ns, memo.cold_p99_ns, memo.warm_ns
    );

    let mut qps = Vec::new();
    for threads in [1usize, 4, 8] {
        let before = stage_snapshots();
        let (cold, warm, pool_threads) = queries_per_sec(threads, batch, reps);
        let stages = stage_stats_json(&before, &stage_snapshots());
        println!("  {threads} thread(s): {cold:.0} cold q/s, {warm:.0} warm q/s");
        qps.push((
            threads.to_string(),
            Json::obj(vec![
                ("cold", Json::Num(cold)),
                ("warm", Json::Num(warm)),
                ("pool_threads", Json::Num(pool_threads as f64)),
                ("stages", stages),
            ]),
        ));
    }

    let mut frontier = Vec::new();
    for threads in [1usize, 4, 8] {
        let (cold, warm, pool_threads) =
            frontier_points_per_sec(threads, frontier_points, reps);
        println!("  frontier @{threads} thread(s): {cold:.0} cold pts/s, {warm:.0} warm pts/s");
        frontier.push((
            threads.to_string(),
            Json::obj(vec![
                ("cold", Json::Num(cold)),
                ("warm", Json::Num(warm)),
                ("pool_threads", Json::Num(pool_threads as f64)),
            ]),
        ));
    }

    let mut sim = Vec::new();
    for threads in [1usize, 4, 8] {
        let (scalar, batched, batch_size, pool_threads) =
            sim_replicas_per_sec(threads, sim_replicates, reps);
        println!(
            "  sim @{threads} thread(s): {scalar:.0} scalar replicas/s, \
             {batched:.0} batched replicas/s (batch {batch_size})"
        );
        sim.push((
            threads.to_string(),
            Json::obj(vec![
                ("scalar", Json::Num(scalar)),
                ("batched", Json::Num(batched)),
                ("batch_size", Json::Num(batch_size as f64)),
                ("pool_threads", Json::Num(pool_threads as f64)),
            ]),
        ));
    }

    let (resolve_cold, resolve_warm, warm_hits, warm_fallbacks) =
        warm_resolve_per_sec(warm_scenarios);
    println!(
        "  warm re-solves: {resolve_cold:.0} cold solves/s, {resolve_warm:.0} warm solves/s \
         ({warm_hits} warm hits, {warm_fallbacks} fallbacks)"
    );

    let (tier_cold, tier_warm, envelope_evaluated, envelope_skipped) =
        tier_plan_solves_per_sec(tier_scenarios);
    println!(
        "  tier plans: {tier_cold:.0} cold solves/s, {tier_warm:.0} warm solves/s \
         ({envelope_skipped} of {} envelope vectors pruned)",
        envelope_evaluated + envelope_skipped
    );

    // Grid-engine cell throughput through the shared harness (prints
    // its own report line and lands in target/bench-results/serve.json).
    let s = fig1_scenario(300.0, 5.5);
    let periods: Vec<f64> = (0..cells).map(|i| 15.0 + 0.02 * i as f64).collect();
    let spec = GridSpec::model_sweep(s, &periods, 1).without_cache();
    let mut bench = Bench::new("serve");
    let cell_throughput = {
        let m = bench.run_units("grid_model_cells", cells as f64, || spec.evaluate());
        cells as f64 / m.median()
    };
    bench.finish();

    Json::obj(vec![
        ("schema", Json::Str("ckpt-period/bench/v4".into())),
        ("suite", Json::Str("serve".into())),
        ("quick", Json::Bool(quick)),
        ("git_describe", Json::Str(git_describe())),
        ("pool_threads", Json::Num((ThreadPool::global().n_workers() + 1) as f64)),
        ("memo_scenarios", Json::Num(memo_scenarios as f64)),
        ("batch", Json::Num(batch as f64)),
        ("cold_memo_ns", Json::Num(memo.cold_ns)),
        ("cold_memo_p50_ns", Json::Num(memo.cold_p50_ns)),
        ("cold_memo_p95_ns", Json::Num(memo.cold_p95_ns)),
        ("cold_memo_p99_ns", Json::Num(memo.cold_p99_ns)),
        ("warm_memo_ns", Json::Num(memo.warm_ns)),
        ("queries_per_sec", Json::Obj(qps.into_iter().collect())),
        ("frontier_points", Json::Num(frontier_points as f64)),
        ("frontier_per_sec", Json::Obj(frontier.into_iter().collect())),
        ("tier_plan_scenarios", Json::Num(tier_scenarios as f64)),
        (
            "tier_plan_per_sec",
            Json::obj(vec![
                ("cold", Json::Num(tier_cold)),
                ("warm", Json::Num(tier_warm)),
                ("envelope_evaluated", Json::Num(envelope_evaluated as f64)),
                ("envelope_skipped", Json::Num(envelope_skipped as f64)),
            ]),
        ),
        ("sim_replicates", Json::Num(sim_replicates as f64)),
        ("sim_replicas_per_sec", Json::Obj(sim.into_iter().collect())),
        ("warm_resolve_scenarios", Json::Num(warm_scenarios as f64)),
        (
            "warm_resolve_per_sec",
            Json::obj(vec![
                ("cold", Json::Num(resolve_cold)),
                ("warm", Json::Num(resolve_warm)),
                ("warm_hits", Json::Num(warm_hits as f64)),
                ("warm_fallbacks", Json::Num(warm_fallbacks as f64)),
            ]),
        ),
        ("cells", Json::Num(cells as f64)),
        ("cell_throughput_per_sec", Json::Num(cell_throughput)),
        // The whole-registry snapshot: counters, cache rows, histogram
        // percentiles — everything the run touched, not just the legs.
        ("telemetry", render::snapshot_json()),
    ])
}

/// Warm-path regression the trajectory gate tolerates before failing:
/// warm numbers are memo/cache hits, far above run-to-run noise, so a
/// 15% drop is a real regression, not a flaky runner.
pub const GATE_TOLERANCE_PCT: f64 = 15.0;

/// The `BENCH_<n>.json` trajectory entries under `dir`, index-sorted.
fn trajectory_entries(dir: &Path) -> Vec<(u32, PathBuf)> {
    let mut out = Vec::new();
    let Ok(rd) = std::fs::read_dir(dir) else { return out };
    for e in rd.flatten() {
        let name = e.file_name();
        let name = name.to_string_lossy();
        if let Some(n) =
            name.strip_prefix("BENCH_").and_then(|s| s.strip_suffix(".json"))
        {
            if let Ok(n) = n.parse::<u32>() {
                out.push((n, e.path()));
            }
        }
    }
    out.sort_by_key(|(n, _)| *n);
    out
}

/// The gated metrics, as `(label, previous, current, higher_is_better)`
/// rows. Fields missing from either document are skipped (schema
/// growth must not break the gate), and only thread counts present in
/// both per-thread blocks are compared. Warm legs measure the
/// cache/memo machinery; cold legs (gated since v3) measure the
/// solvers themselves — sharded lookups, pool scatter, envelope
/// pruning — under the same tolerance.
fn gate_metrics(prev: &Json, curr: &Json) -> Vec<(String, f64, f64, bool)> {
    let mut rows = Vec::new();
    let both = |key: &str| Some((prev.get(key)?.as_f64()?, curr.get(key)?.as_f64()?));
    if let Some((p, c)) = both("cold_memo_ns") {
        rows.push(("cold memo ns/solve".to_string(), p, c, false));
    }
    if let Some((p, c)) = both("warm_memo_ns") {
        rows.push(("warm memo ns/solve".to_string(), p, c, false));
    }
    if let Some((p, c)) = both("cell_throughput_per_sec") {
        rows.push(("grid cells/sec".to_string(), p, c, true));
    }
    // Per-thread-count legs: queries/sec and frontier points/sec (cold
    // and warm sides), and since v4 the Monte-Carlo replicas/sec leg
    // (scalar and batched sides).
    let per_thread: [(&str, &str, [&str; 2]); 3] = [
        ("queries_per_sec", "q/s", ["cold", "warm"]),
        ("frontier_per_sec", "frontier pts/s", ["cold", "warm"]),
        ("sim_replicas_per_sec", "sim replicas/s", ["scalar", "batched"]),
    ];
    for (block, what, sides) in per_thread {
        if let (Some(Json::Obj(pq)), Some(Json::Obj(cq))) = (prev.get(block), curr.get(block)) {
            for (threads, pv) in pq {
                for side in sides {
                    let leg = |v: &Json| v.get(side).and_then(Json::as_f64);
                    if let (Some(p), Some(c)) = (leg(pv), cq.get(threads).and_then(|v| leg(v))) {
                        rows.push((format!("{side} {what} @{threads} thread(s)"), p, c, true));
                    }
                }
            }
        }
    }
    // Single-block cold/warm legs: tier-plan solves (v3) and the
    // warm-started endpoint re-solves (v4).
    for (block, what) in [
        ("tier_plan_per_sec", "tier plans/s"),
        ("warm_resolve_per_sec", "endpoint re-solves/s"),
    ] {
        if let (Some(pt), Some(ct)) = (prev.get(block), curr.get(block)) {
            for side in ["cold", "warm"] {
                let leg = |v: &Json| v.get(side).and_then(Json::as_f64);
                if let (Some(p), Some(c)) = (leg(pt), leg(ct)) {
                    rows.push((format!("{side} {what}"), p, c, true));
                }
            }
        }
    }
    rows
}

/// Compare the two most recent `BENCH_<n>.json` trajectory entries
/// under `dir` — the CI perf-regression gate behind `bench --gate`.
///
/// Benign situations return `Ok` with an explanation (fewer than two
/// entries, a schema-version or quick-mode change making the documents
/// incomparable); a gated metric regressing by more than
/// [`GATE_TOLERANCE_PCT`] returns `Err` with the full report, failing
/// the CI step. Warm legs cover the cache/memo machinery this repo's
/// perf story is built on; since v3 the cold legs are gated too — the
/// sharded-cache and envelope-pruning work moved the solvers
/// themselves, and the 15% tolerance still clears allocator/turbo
/// noise on cold medians. Since v4 the gate also covers the batched
/// Monte-Carlo replicas/sec legs (scalar and batched sides per thread
/// count) and the warm-started endpoint re-solve leg.
pub fn gate_trajectory(dir: &Path) -> Result<Vec<String>, String> {
    let entries = trajectory_entries(dir);
    if entries.len() < 2 {
        return Ok(vec![format!(
            "bench gate: {} trajectory entries under {} — need two to compare, skipping",
            entries.len(),
            dir.display()
        )]);
    }
    let (_, prev_path) = &entries[entries.len() - 2];
    let (_, curr_path) = &entries[entries.len() - 1];
    let load = |p: &Path| -> Result<Json, String> {
        let text = std::fs::read_to_string(p).map_err(|e| format!("{}: {e}", p.display()))?;
        crate::util::json::parse(&text).map_err(|e| format!("{}: {e}", p.display()))
    };
    let prev = load(prev_path)?;
    let curr = load(curr_path)?;
    let name = |p: &Path| p.file_name().unwrap_or_default().to_string_lossy().into_owned();
    let mut lines = vec![format!("bench gate: {} -> {}", name(prev_path), name(curr_path))];

    let prev_schema = prev.req_str("schema").map_err(|e| e.to_string())?.to_string();
    let curr_schema = curr.req_str("schema").map_err(|e| e.to_string())?.to_string();
    if prev_schema != curr_schema {
        lines.push(format!(
            "  schema changed ({prev_schema} -> {curr_schema}): not comparable, skipping"
        ));
        return Ok(lines);
    }
    if prev.get("quick").and_then(Json::as_bool) != curr.get("quick").and_then(Json::as_bool) {
        lines.push("  quick-mode flag changed: workloads not comparable, skipping".to_string());
        return Ok(lines);
    }

    let rows = gate_metrics(&prev, &curr);
    if rows.is_empty() {
        lines.push("  no shared warm-path metrics: nothing to compare, skipping".to_string());
        return Ok(lines);
    }
    let mut regressions = 0usize;
    for (label, p, c, higher_is_better) in rows {
        if !(p.is_finite() && c.is_finite() && p > 0.0) {
            continue;
        }
        let delta_pct = (c / p - 1.0) * 100.0;
        let regressed = if higher_is_better {
            delta_pct < -GATE_TOLERANCE_PCT
        } else {
            delta_pct > GATE_TOLERANCE_PCT
        };
        lines.push(format!(
            "  {label}: {p:.0} -> {c:.0} ({delta_pct:+.1}%){}",
            if regressed { "  REGRESSION" } else { "" }
        ));
        regressions += regressed as usize;
    }
    if regressions > 0 {
        return Err(format!(
            "{}\nbench gate FAILED: {regressions} metric(s) regressed more than \
             {GATE_TOLERANCE_PCT}%",
            lines.join("\n")
        ));
    }
    lines.push(format!("bench gate passed (tolerance {GATE_TOLERANCE_PCT}%)"));
    Ok(lines)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_scenarios_never_collide_even_across_calls() {
        let a = fresh_scenarios(16);
        let b = fresh_scenarios(16);
        let mut keys: Vec<Vec<u64>> = Vec::new();
        for s in a.iter().chain(&b) {
            keys.push(s.key_words());
        }
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), 32, "duplicate scenario bits");
        // Consecutive μ steps exceed the online memo's 0.1% quantum.
        for w in a.windows(2) {
            let rel = (w[1].mu - w[0].mu) / w[0].mu;
            assert!(rel > 0.002, "step {rel} too small for the quantiser");
        }
        // And the scenarios are solvable.
        assert!(knee_period(&a[0], KneeMethod::MaxDistanceToChord, Backend::FirstOrder).is_ok());
    }

    #[test]
    fn git_describe_always_yields_a_label() {
        assert!(!git_describe().is_empty());
    }

    /// Fresh scratch directory for one gate test.
    fn gate_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ckpt-gate-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// A minimal trajectory document with the gate's warm-path fields.
    fn write_doc(dir: &Path, n: u32, schema: &str, warm_memo: f64, qps_warm: f64, cells: f64) {
        let doc = Json::obj(vec![
            ("schema", Json::Str(schema.into())),
            ("quick", Json::Bool(true)),
            ("warm_memo_ns", Json::Num(warm_memo)),
            ("cell_throughput_per_sec", Json::Num(cells)),
            (
                "queries_per_sec",
                Json::obj(vec![(
                    "4",
                    Json::obj(vec![
                        ("cold", Json::Num(qps_warm / 2.0)),
                        ("warm", Json::Num(qps_warm)),
                    ]),
                )]),
            ),
        ]);
        std::fs::write(dir.join(format!("BENCH_{n}.json")), doc.to_string_pretty()).unwrap();
    }

    #[test]
    fn gate_skips_without_two_entries() {
        let d = gate_dir("empty");
        let lines = gate_trajectory(&d).unwrap();
        assert!(lines[0].contains("skipping"), "{lines:?}");
        write_doc(&d, 0, "ckpt-period/bench/v2", 90.0, 5e6, 2e6);
        let lines = gate_trajectory(&d).unwrap();
        assert!(lines[0].contains("skipping"), "{lines:?}");
    }

    #[test]
    fn gate_skips_on_schema_change() {
        let d = gate_dir("schema");
        write_doc(&d, 0, "ckpt-period/bench/v1", 90.0, 5e6, 2e6);
        // Even a catastrophic slowdown is not comparable across schemas.
        write_doc(&d, 1, "ckpt-period/bench/v2", 900.0, 5e5, 2e5);
        let lines = gate_trajectory(&d).unwrap();
        assert!(lines.iter().any(|l| l.contains("schema changed")), "{lines:?}");

        // The v3 -> v4 transition point skips cleanly the same way: the
        // v4 doc grows legs the v3 one lacks, so they never compare.
        let d = gate_dir("schema34");
        write_doc(&d, 0, "ckpt-period/bench/v3", 90.0, 5e6, 2e6);
        write_doc(&d, 1, "ckpt-period/bench/v4", 900.0, 5e5, 2e5);
        let lines = gate_trajectory(&d).unwrap();
        assert!(lines.iter().any(|l| l.contains("schema changed")), "{lines:?}");
        assert!(lines.last().unwrap().contains("skipping"), "{lines:?}");
    }

    #[test]
    fn gate_passes_within_tolerance_and_compares_the_two_newest() {
        let d = gate_dir("pass");
        // An ancient terrible entry must be ignored: only 7 vs 9 count.
        write_doc(&d, 2, "ckpt-period/bench/v2", 9000.0, 5e3, 2e3);
        write_doc(&d, 7, "ckpt-period/bench/v2", 90.0, 5e6, 2e6);
        write_doc(&d, 9, "ckpt-period/bench/v2", 99.0, 4.6e6, 1.9e6);
        let lines = gate_trajectory(&d).unwrap();
        let pair = lines[0].contains("BENCH_7.json") && lines[0].contains("BENCH_9.json");
        assert!(pair, "{lines:?}");
        assert!(lines.last().unwrap().contains("passed"), "{lines:?}");
    }

    #[test]
    fn gate_fails_on_warm_path_regressions() {
        // >15% warm-q/s drop.
        let d = gate_dir("qps");
        write_doc(&d, 0, "ckpt-period/bench/v2", 90.0, 5e6, 2e6);
        write_doc(&d, 1, "ckpt-period/bench/v2", 90.0, 3.5e6, 2e6);
        let err = gate_trajectory(&d).unwrap_err();
        assert!(err.contains("REGRESSION") && err.contains("FAILED"), "{err}");
        assert!(err.contains("warm q/s @4"), "{err}");

        // >15% warm-memo latency increase (lower is better there).
        let d = gate_dir("memo");
        write_doc(&d, 0, "ckpt-period/bench/v2", 90.0, 5e6, 2e6);
        write_doc(&d, 1, "ckpt-period/bench/v2", 120.0, 5e6, 2e6);
        let err = gate_trajectory(&d).unwrap_err();
        assert!(err.contains("warm memo ns/solve") && err.contains("REGRESSION"), "{err}");

        // An improvement on the lower-is-better axis must NOT fail.
        let d = gate_dir("better");
        write_doc(&d, 0, "ckpt-period/bench/v2", 120.0, 5e6, 2e6);
        write_doc(&d, 1, "ckpt-period/bench/v2", 60.0, 6e6, 3e6);
        assert!(gate_trajectory(&d).is_ok());
    }

    #[test]
    fn gate_covers_the_v3_cold_and_solver_legs() {
        let d = gate_dir("v3");
        let doc = |frontier_warm: f64, tier_cold: f64, cold_memo: f64| {
            Json::obj(vec![
                ("schema", Json::Str("ckpt-period/bench/v3".into())),
                ("quick", Json::Bool(true)),
                ("cold_memo_ns", Json::Num(cold_memo)),
                ("warm_memo_ns", Json::Num(90.0)),
                (
                    "frontier_per_sec",
                    Json::obj(vec![(
                        "8",
                        Json::obj(vec![
                            ("cold", Json::Num(2e5)),
                            ("warm", Json::Num(frontier_warm)),
                        ]),
                    )]),
                ),
                (
                    "tier_plan_per_sec",
                    Json::obj(vec![("cold", Json::Num(tier_cold)), ("warm", Json::Num(5e4))]),
                ),
            ])
        };
        let write = |n: u32, d_json: Json| {
            std::fs::write(d.join(format!("BENCH_{n}.json")), d_json.to_string_pretty()).unwrap();
        };
        write(0, doc(4e5, 1e3, 100.0));
        write(1, doc(4e5, 1e3, 100.0));
        assert!(gate_trajectory(&d).is_ok());
        // A cold solver-leg regression now fails the gate.
        write(2, doc(4e5, 7e2, 100.0));
        let err = gate_trajectory(&d).unwrap_err();
        assert!(err.contains("cold tier plans/s") && err.contains("REGRESSION"), "{err}");
        // So does a pooled-frontier warm regression.
        write(3, doc(2e5, 7e2, 100.0));
        let err = gate_trajectory(&d).unwrap_err();
        assert!(err.contains("warm frontier pts/s @8"), "{err}");
        // And a cold-memo latency increase (lower is better there).
        write(4, doc(2e5, 7e2, 130.0));
        let err = gate_trajectory(&d).unwrap_err();
        assert!(err.contains("cold memo ns/solve"), "{err}");
    }

    #[test]
    fn gate_covers_the_v4_sim_and_warm_resolve_legs() {
        let d = gate_dir("v4");
        let doc = |batched: f64, resolve_warm: f64| {
            Json::obj(vec![
                ("schema", Json::Str("ckpt-period/bench/v4".into())),
                ("quick", Json::Bool(true)),
                ("warm_memo_ns", Json::Num(90.0)),
                (
                    "sim_replicas_per_sec",
                    Json::obj(vec![(
                        "8",
                        Json::obj(vec![
                            ("scalar", Json::Num(8e5)),
                            ("batched", Json::Num(batched)),
                        ]),
                    )]),
                ),
                (
                    "warm_resolve_per_sec",
                    Json::obj(vec![
                        ("cold", Json::Num(2e4)),
                        ("warm", Json::Num(resolve_warm)),
                    ]),
                ),
            ])
        };
        let write = |n: u32, d_json: Json| {
            std::fs::write(d.join(format!("BENCH_{n}.json")), d_json.to_string_pretty()).unwrap();
        };
        write(0, doc(3e6, 1.8e5));
        write(1, doc(3e6, 1.8e5));
        assert!(gate_trajectory(&d).is_ok());
        // A batched-executor throughput regression fails the gate.
        write(2, doc(2e6, 1.8e5));
        let err = gate_trajectory(&d).unwrap_err();
        assert!(err.contains("batched sim replicas/s @8") && err.contains("REGRESSION"), "{err}");
        // So does a warm-started re-solve slowdown.
        write(3, doc(2e6, 1.2e5));
        let err = gate_trajectory(&d).unwrap_err();
        assert!(err.contains("warm endpoint re-solves/s"), "{err}");
    }
}
