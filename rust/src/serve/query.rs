//! Query parsing: one JSON object per line in, [`Query`] or a
//! structured [`ErrorRecord`] out.
//!
//! The accepted fields (see the [module docs](crate::serve) for the
//! full protocol):
//!
//! * `scenario` — **required**: a trade-off preset name
//!   (`config::presets::tradeoff_presets`) or an inline scenario object
//!   in the [`ScenarioSpec`] grammar;
//! * `policy` — a [`PeriodPolicy::parse`] spelling (default `knee`);
//! * `model` — a [`Backend::parse`] spelling (default `first-order`);
//! * `drift` — a drift preset name or [`DriftProcess::parse`] grammar
//!   (default `stationary`);
//! * `at` — trajectory time in minutes the answer is read at (finite,
//!   `>= 0`, default `0`);
//! * `id` — opaque client correlation string, echoed into the answer.
//!
//! Unknown fields are rejected (a typo'd `polcy` must not silently fall
//! back to the default). The scenario × drift pair is validated at
//! parse time ([`EnvTrajectory::new`] checks the domain-worst corner),
//! so a malformed *or* out-of-domain line becomes a per-line
//! [`ErrorRecord`] and never a mid-batch solve failure.

use crate::config::presets::{drift_preset, drift_presets, tradeoff_presets};
use crate::config::ScenarioSpec;
use crate::coordinator::PeriodPolicy;
use crate::drift::{DriftProcess, EnvTrajectory};
use crate::model::params::{ModelError, Scenario};
use crate::model::Backend;
use crate::pareto::KneeMethod;
use crate::sweep::grid::policy_key;
use crate::util::json::{self, Json};

/// One parsed, validated scenario query.
#[derive(Debug, Clone, PartialEq)]
pub struct Query {
    /// Opaque client correlation id, echoed into the answer record.
    pub id: Option<String>,
    /// Preset label, when the scenario came from a preset (reporting).
    pub label: Option<String>,
    /// The base (`t = 0`) scenario.
    pub scenario: Scenario,
    /// Period policy, already retargeted at [`Self::backend`].
    pub policy: PeriodPolicy,
    /// Objective backend the answer's `T`/`E` columns evaluate through.
    pub backend: Backend,
    /// Environment drift schedule (default stationary).
    pub drift: DriftProcess,
    /// Trajectory time (minutes) the answer is read at.
    pub at: f64,
}

impl Query {
    /// A plain stationary query (the programmatic construction path;
    /// the JSON path is [`Self::parse_line`]).
    pub fn new(scenario: Scenario, policy: PeriodPolicy, backend: Backend) -> Query {
        Query {
            id: None,
            label: None,
            scenario,
            policy: policy.with_backend(backend),
            backend,
            drift: DriftProcess::Stationary,
            at: 0.0,
        }
    }

    /// Parse one JSON line. Errors are human-readable strings destined
    /// for an [`ErrorRecord`].
    pub fn parse_line(line: &str) -> Result<Query, String> {
        let doc = json::parse(line).map_err(|e| e.to_string())?;
        Query::from_json(&doc)
    }

    /// Parse a query from an already-parsed JSON document.
    pub fn from_json(doc: &Json) -> Result<Query, String> {
        let obj = match doc {
            Json::Obj(m) => m,
            _ => return Err("query must be a JSON object".into()),
        };
        for key in obj.keys() {
            if !matches!(key.as_str(), "id" | "scenario" | "policy" | "model" | "drift" | "at") {
                return Err(format!(
                    "unknown query field `{key}` (expected id|scenario|policy|model|drift|at)"
                ));
            }
        }
        let id = match doc.get("id") {
            None => None,
            Some(Json::Str(s)) => Some(s.clone()),
            Some(_) => return Err("`id` must be a string".into()),
        };
        let (label, scenario) = match doc.get("scenario") {
            None => {
                return Err(
                    "missing `scenario` (a preset name or an inline scenario object)".into()
                )
            }
            Some(Json::Str(name)) => match scenario_preset(name) {
                Some(s) => (Some(name.clone()), s),
                None => {
                    let names: Vec<&str> =
                        tradeoff_presets().iter().map(|(n, _)| *n).collect();
                    return Err(format!(
                        "unknown scenario preset `{name}` (expected {})",
                        names.join("|")
                    ));
                }
            },
            Some(node @ Json::Obj(_)) => {
                let spec = ScenarioSpec::from_str(&node.to_string_compact())
                    .map_err(|e| format!("scenario: {e}"))?;
                (None, spec.scenario)
            }
            Some(_) => {
                return Err("`scenario` must be a preset name or a scenario object".into())
            }
        };
        let backend = match doc.get("model") {
            None => Backend::FirstOrder,
            Some(Json::Str(s)) => Backend::parse(s).ok_or_else(|| {
                format!("invalid model `{s}` (expected {})", Backend::PARSE_HELP)
            })?,
            Some(_) => return Err("`model` must be a string".into()),
        };
        let policy = match doc.get("policy") {
            None => PeriodPolicy::Knee {
                method: KneeMethod::MaxDistanceToChord,
                backend: Backend::FirstOrder,
            },
            Some(Json::Str(s)) => PeriodPolicy::parse(s).ok_or_else(|| {
                format!("invalid policy `{s}` (expected {})", PeriodPolicy::PARSE_HELP)
            })?,
            Some(_) => return Err("`policy` must be a string".into()),
        }
        .with_backend(backend);
        let drift = match doc.get("drift") {
            None => DriftProcess::Stationary,
            Some(Json::Str(s)) => match drift_preset(s) {
                Some(d) => d,
                None => DriftProcess::parse(s).ok_or_else(|| {
                    let presets: Vec<&str> =
                        drift_presets().iter().map(|(n, _)| *n).collect();
                    format!(
                        "invalid drift `{s}` (expected {} or a preset: {})",
                        DriftProcess::PARSE_HELP,
                        presets.join("|")
                    )
                })?,
            },
            Some(_) => return Err("`drift` must be a string".into()),
        };
        let at = match doc.get("at") {
            None => 0.0,
            Some(Json::Num(t)) if t.is_finite() && *t >= 0.0 => *t,
            Some(other) => {
                return Err(format!("`at` must be a finite number >= 0, got {other}"))
            }
        };
        // Drift schedules multiply the scalar environment; what they
        // mean for a multi-level hierarchy is undefined, so the
        // combination is a per-line error (mirrors the simulator).
        if scenario.hierarchy().is_some() && !drift.is_stationary() {
            return Err("tiered scenarios do not accept a drift schedule".into());
        }
        // Validate the whole trajectory up front: a query that cannot be
        // answered is a per-line error record, never a mid-batch panic.
        EnvTrajectory::new(scenario, drift).map_err(|e| format!("scenario/drift: {e}"))?;
        Ok(Query { id, label, scenario, policy, backend, drift, at })
    }

    /// Serialise back to the wire grammar: parsing the compact form of
    /// this value yields a query that solves to bit-identical answers
    /// (`f64`s round-trip exactly through [`Json`]).
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        if let Some(id) = &self.id {
            fields.push(("id", Json::Str(id.clone())));
        }
        let scenario = match &self.label {
            Some(l) => Json::Str(l.clone()),
            None => ScenarioSpec { scenario: self.scenario, n_nodes: None }.to_json(),
        };
        fields.push(("scenario", scenario));
        fields.push(("policy", Json::Str(policy_spec(self.policy))));
        fields.push(("model", Json::Str(self.backend.name().into())));
        if !self.drift.is_stationary() {
            fields.push(("drift", Json::Str(self.drift.render())));
        }
        if self.at != 0.0 {
            fields.push(("at", Json::Num(self.at)));
        }
        Json::obj(fields)
    }

    /// The instantaneous scenario the answer is computed from: the base
    /// scenario pushed through the drift schedule to time [`Self::at`]
    /// (the base itself, bit-for-bit, when stationary).
    pub fn effective_scenario(&self) -> Result<Scenario, ModelError> {
        Ok(EnvTrajectory::new(self.scenario, self.drift)?.scenario_at(self.at))
    }

    /// Exact-bits dedup/cache key: scenario words (tier-aware) + the
    /// grid engine's policy encoding + backend word + drift schedule
    /// words + `at` bits. Two queries with equal keys have
    /// bit-identical answers.
    pub fn solve_key(&self) -> Vec<u64> {
        let mut k = Vec::with_capacity(20);
        k.extend(self.scenario.key_words());
        k.extend_from_slice(&policy_key(self.policy));
        k.push(self.backend.key_word());
        k.extend(self.drift.key_words());
        k.push(self.at.to_bits());
        k
    }

    /// The canonical `--policy` spelling of this query's policy.
    pub fn policy_spec(&self) -> String {
        policy_spec(self.policy)
    }
}

/// Look up a scenario preset by its trade-off label.
pub fn scenario_preset(name: &str) -> Option<Scenario> {
    tradeoff_presets().into_iter().find(|(l, _)| *l == name).map(|(_, s)| s)
}

/// The canonical `--policy` spelling of `p` — parses back to the same
/// policy via [`PeriodPolicy::parse`] + a backend retarget (numeric
/// parameters print in shortest-round-trip form, so `fixed:`/`eps-*:`
/// budgets survive bit-exactly).
pub fn policy_spec(p: PeriodPolicy) -> String {
    match p {
        PeriodPolicy::AlgoT => "algo-t".into(),
        PeriodPolicy::AlgoE => "algo-e".into(),
        PeriodPolicy::Young => "young".into(),
        PeriodPolicy::Daly => "daly".into(),
        PeriodPolicy::Fixed(t) => format!("fixed:{t}"),
        PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord, .. } => "knee".into(),
        PeriodPolicy::Knee { method: KneeMethod::MaxCurvature, .. } => "knee:curvature".into(),
        PeriodPolicy::EnergyBudget { max_time_overhead, .. } => {
            format!("eps-time:{max_time_overhead}")
        }
        PeriodPolicy::TimeBudget { max_energy_overhead, .. } => {
            format!("eps-energy:{max_energy_overhead}")
        }
    }
}

/// One malformed (or unanswerable) input line: the 1-based line number
/// and the reason, serialised as a JSON error record on the error
/// stream. The stream itself continues — parse errors are per-line
/// data, not process failures.
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorRecord {
    pub line: usize,
    pub error: String,
}

impl ErrorRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("line", Json::Num(self.line as f64)),
            ("error", Json::Str(self.error.clone())),
        ])
    }
}

/// Split a JSON-lines batch into parsed queries (tagged with their
/// 1-based line numbers) and per-line error records. Blank lines are
/// skipped but still counted, so line numbers always match the input —
/// a malformed line never shifts the positions of the lines after it.
pub fn parse_lines(input: &str) -> (Vec<(usize, Query)>, Vec<ErrorRecord>) {
    let mut queries = Vec::new();
    let mut errors = Vec::new();
    for (i, line) in input.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        match Query::parse_line(line) {
            Ok(q) => queries.push((i + 1, q)),
            Err(e) => errors.push(ErrorRecord { line: i + 1, error: e }),
        }
    }
    (queries, errors)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_query_parses_with_defaults() {
        let q = Query::parse_line(r#"{"scenario": "fig1-rho5.5"}"#).unwrap();
        assert_eq!(q.label.as_deref(), Some("fig1-rho5.5"));
        assert_eq!(q.backend, Backend::FirstOrder);
        assert_eq!(
            q.policy,
            PeriodPolicy::Knee {
                method: KneeMethod::MaxDistanceToChord,
                backend: Backend::FirstOrder
            }
        );
        assert!(q.drift.is_stationary());
        assert_eq!(q.at, 0.0);
        assert_eq!(q.id, None);
        // The effective scenario of a stationary query is the base,
        // bit-for-bit.
        assert_eq!(q.effective_scenario().unwrap(), q.scenario);
    }

    #[test]
    fn inline_scenario_and_exact_model_parse() {
        let line = r#"{
            "id": "q-7",
            "scenario": {
                "checkpoint": {"c": 10.0, "r": 10.0, "d": 1.0, "omega": 0.5},
                "power": {"p_static": 10, "p_cal": 10, "p_io": 100, "p_down": 0},
                "mu_minutes": 300.0, "t_base_minutes": 10000.0
            },
            "policy": "eps-time:5", "model": "exact"
        }"#
        .replace('\n', " ");
        let q = Query::parse_line(&line).unwrap();
        assert_eq!(q.id.as_deref(), Some("q-7"));
        assert_eq!(q.label, None);
        assert_eq!(q.scenario.mu, 300.0);
        // The backend is threaded into the frontier-aware policy.
        assert_eq!(q.policy.backend(), Some(q.backend));
        assert_ne!(q.backend, Backend::FirstOrder);
    }

    #[test]
    fn drift_presets_and_grammar_both_parse() {
        let a =
            Query::parse_line(r#"{"scenario": "fig1-rho5.5", "drift": "io-ramp", "at": 2500}"#)
                .unwrap();
        assert!(!a.drift.is_stationary());
        assert_eq!(a.at, 2500.0);
        // Halfway up the ramp the effective C sits above the base C.
        assert!(a.effective_scenario().unwrap().ckpt.c > a.scenario.ckpt.c);
        let b = Query::parse_line(
            r#"{"scenario": "fig1-rho5.5", "drift": "ramp:0:5000:c=2,r=2,io=2", "at": 2500}"#,
        )
        .unwrap();
        assert_eq!(
            a.effective_scenario().unwrap().key_bits(),
            b.effective_scenario().unwrap().key_bits()
        );
    }

    #[test]
    fn malformed_queries_are_structured_errors() {
        for (line, needle) in [
            ("{", "json parse error"),
            ("[1, 2]", "must be a JSON object"),
            (r#"{"policy": "knee"}"#, "missing `scenario`"),
            (r#"{"scenario": "bogus-preset"}"#, "unknown scenario preset"),
            (r#"{"scenario": "fig1-rho5.5", "polcy": "knee"}"#, "unknown query field"),
            (r#"{"scenario": "fig1-rho5.5", "policy": "bogus"}"#, "invalid policy"),
            (r#"{"scenario": "fig1-rho5.5", "model": "second-order"}"#, "invalid model"),
            (r#"{"scenario": "fig1-rho5.5", "drift": "nope"}"#, "invalid drift"),
            (r#"{"scenario": "fig1-rho5.5", "at": -1}"#, "`at` must be"),
            (r#"{"scenario": "fig1-rho5.5", "id": 5}"#, "`id` must be a string"),
        ] {
            let err = Query::parse_line(line).unwrap_err();
            assert!(err.contains(needle), "{line}: {err}");
        }
    }

    #[test]
    fn out_of_domain_drift_is_a_parse_time_error() {
        // mu scaled down 1000x drives the worst corner out of the
        // feasible domain; the error surfaces at parse time.
        let err = Query::parse_line(
            r#"{"scenario": "fig1-rho5.5", "drift": "step:100:mu=0.001"}"#,
        )
        .unwrap_err();
        assert!(err.contains("scenario/drift"), "{err}");
    }

    #[test]
    fn parse_lines_preserves_positions_and_continues_past_errors() {
        let input = "\n{\"scenario\": \"fig1-rho5.5\"}\nnot json\n\n{\"scenario\": \"fig1-rho7\"}\n";
        let (queries, errors) = parse_lines(input);
        assert_eq!(queries.len(), 2);
        assert_eq!(queries[0].0, 2);
        assert_eq!(queries[1].0, 5);
        assert_eq!(errors.len(), 1);
        assert_eq!(errors[0].line, 3);
        let rec = errors[0].to_json().to_string_compact();
        assert!(rec.contains("\"line\":3"), "{rec}");
    }

    #[test]
    fn to_json_roundtrips_presets_and_inline_scenarios() {
        for line in [
            r#"{"scenario": "fig1-rho5.5"}"#.to_string(),
            r#"{"scenario": "beta-heavy", "policy": "fixed:42.5", "model": "exact:ideal"}"#
                .to_string(),
            r#"{"id": "x", "scenario": "fig1-rho7", "drift": "io-ramp", "at": 1234.5}"#
                .to_string(),
        ] {
            let q = Query::parse_line(&line).unwrap();
            let back = Query::parse_line(&q.to_json().to_string_compact()).unwrap();
            // Labels survive for presets; drift renders in grammar form,
            // so compare through the parts that define the answer.
            assert_eq!(back.scenario, q.scenario);
            assert_eq!(back.policy, q.policy);
            assert_eq!(back.backend, q.backend);
            assert_eq!(back.at.to_bits(), q.at.to_bits());
            assert_eq!(back.solve_key(), q.solve_key());
        }
    }

    #[test]
    fn solve_keys_separate_every_axis() {
        let base = Query::parse_line(r#"{"scenario": "fig1-rho5.5"}"#).unwrap();
        for other in [
            r#"{"scenario": "fig1-rho7"}"#,
            r#"{"scenario": "fig1-rho5.5", "policy": "algo-t"}"#,
            r#"{"scenario": "fig1-rho5.5", "model": "exact"}"#,
            r#"{"scenario": "fig1-rho5.5", "drift": "io-ramp"}"#,
            r#"{"scenario": "fig1-rho5.5", "drift": "io-ramp", "at": 10}"#,
        ] {
            let q = Query::parse_line(other).unwrap();
            assert_ne!(q.solve_key(), base.solve_key(), "{other}");
        }
        // The id is correlation metadata, not solve input.
        let tagged = Query::parse_line(r#"{"id": "z", "scenario": "fig1-rho5.5"}"#).unwrap();
        assert_eq!(tagged.solve_key(), base.solve_key());
    }
}
