//! Compact binary answer encoding (zero-copy friendly).
//!
//! A batch of `Result<Answer, _>` serialises to a fixed-layout
//! little-endian buffer:
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"CKPTSRV1"
//! 8       8     record count, u64 LE
//! 16      64*n  records (8 u64 words each, LE)
//! ```
//!
//! Each record is eight 8-byte words: word 0 is the status (`0` = ok,
//! `1` = error), words 1–7 are the `f64` bit patterns of the
//! [`Answer`] fields in declaration order (`period`, `t_final`,
//! `e_final`, `t_time_opt`, `t_energy_opt`, `time_overhead_pct`,
//! `energy_gain_pct`), zeroed for error records. Fixed offsets mean a
//! consumer can `mmap` the artifact and read any record without
//! parsing — the encoding never needs a scan, and every `f64` survives
//! bit-exactly (unlike any decimal text form with less care than
//! [`crate::util::json`] takes).

use super::engine::Answer;
use crate::model::params::ModelError;

/// File magic: protocol name + version in 8 bytes.
pub const MAGIC: &[u8; 8] = b"CKPTSRV1";
/// Header: magic + record count.
pub const HEADER_BYTES: usize = 16;
/// Words per record (status + 7 answer fields).
pub const RECORD_WORDS: usize = 8;
/// Bytes per record.
pub const RECORD_BYTES: usize = RECORD_WORDS * 8;

fn answer_words(a: &Answer) -> [u64; 7] {
    [
        a.period.to_bits(),
        a.t_final.to_bits(),
        a.e_final.to_bits(),
        a.t_time_opt.to_bits(),
        a.t_energy_opt.to_bits(),
        a.time_overhead_pct.to_bits(),
        a.energy_gain_pct.to_bits(),
    ]
}

/// Encode a batch of results. Error records carry status 1 and zeroed
/// payload words (the textual reason travels on the JSON error stream,
/// not the binary artifact).
pub fn encode(results: &[Result<Answer, ModelError>]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(HEADER_BYTES + results.len() * RECORD_BYTES);
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(results.len() as u64).to_le_bytes());
    for r in results {
        match r {
            Ok(a) => {
                buf.extend_from_slice(&0u64.to_le_bytes());
                for w in answer_words(a) {
                    buf.extend_from_slice(&w.to_le_bytes());
                }
            }
            Err(_) => {
                buf.extend_from_slice(&1u64.to_le_bytes());
                buf.extend_from_slice(&[0u8; (RECORD_WORDS - 1) * 8]);
            }
        }
    }
    buf
}

fn word_at(buf: &[u8], offset: usize) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&buf[offset..offset + 8]);
    u64::from_le_bytes(b)
}

/// Validate the header and return the record count.
pub fn record_count(buf: &[u8]) -> Result<usize, String> {
    if buf.len() < HEADER_BYTES {
        return Err(format!("buffer too short for header: {} bytes", buf.len()));
    }
    if &buf[..8] != MAGIC {
        return Err("bad magic: not a CKPTSRV1 buffer".into());
    }
    let n = word_at(buf, 8) as usize;
    let want = HEADER_BYTES + n * RECORD_BYTES;
    if buf.len() != want {
        return Err(format!("length mismatch: {} bytes for {n} records (want {want})", buf.len()));
    }
    Ok(n)
}

/// Decode record `i` (0-based) without touching the others: `Ok(None)`
/// is an error record, `Ok(Some(a))` a bit-exact [`Answer`].
pub fn decode_record(buf: &[u8], i: usize) -> Result<Option<Answer>, String> {
    let n = record_count(buf)?;
    if i >= n {
        return Err(format!("record {i} out of range (count {n})"));
    }
    let base = HEADER_BYTES + i * RECORD_BYTES;
    match word_at(buf, base) {
        0 => Ok(Some(Answer {
            period: f64::from_bits(word_at(buf, base + 8)),
            t_final: f64::from_bits(word_at(buf, base + 16)),
            e_final: f64::from_bits(word_at(buf, base + 24)),
            t_time_opt: f64::from_bits(word_at(buf, base + 32)),
            t_energy_opt: f64::from_bits(word_at(buf, base + 40)),
            time_overhead_pct: f64::from_bits(word_at(buf, base + 48)),
            energy_gain_pct: f64::from_bits(word_at(buf, base + 56)),
        })),
        1 => Ok(None),
        s => Err(format!("record {i}: unknown status {s}")),
    }
}

/// Decode a whole buffer (`None` slots are error records).
pub fn decode(buf: &[u8]) -> Result<Vec<Option<Answer>>, String> {
    (0..record_count(buf)?).map(|i| decode_record(buf, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn answer(seed: f64) -> Answer {
        Answer {
            period: 53.0 + seed,
            t_final: 11_000.0 * (1.0 + seed / 97.0),
            e_final: 1.0e8 / (1.0 + seed),
            t_time_opt: 48.25 + seed,
            t_energy_opt: 91.0 - seed,
            time_overhead_pct: 0.1 * seed,
            energy_gain_pct: 7.5 + 0.3 * seed,
        }
    }

    #[test]
    fn roundtrip_is_bit_exact_including_errors() {
        let results: Vec<Result<Answer, ModelError>> = vec![
            Ok(answer(0.0)),
            Err(ModelError::Invalid("x".into())),
            Ok(answer(1.0 / 3.0)), // non-terminating decimal: bits must survive
        ];
        let buf = encode(&results);
        assert_eq!(buf.len(), HEADER_BYTES + 3 * RECORD_BYTES);
        assert_eq!(record_count(&buf).unwrap(), 3);
        let back = decode(&buf).unwrap();
        assert_eq!(back.len(), 3);
        let a0 = back[0].unwrap();
        let a2 = back[2].unwrap();
        let want0 = answer(0.0);
        let want2 = answer(1.0 / 3.0);
        for (got, want) in [(a0, want0), (a2, want2)] {
            assert_eq!(got.period.to_bits(), want.period.to_bits());
            assert_eq!(got.t_final.to_bits(), want.t_final.to_bits());
            assert_eq!(got.e_final.to_bits(), want.e_final.to_bits());
            assert_eq!(got.t_time_opt.to_bits(), want.t_time_opt.to_bits());
            assert_eq!(got.t_energy_opt.to_bits(), want.t_energy_opt.to_bits());
            assert_eq!(got.time_overhead_pct.to_bits(), want.time_overhead_pct.to_bits());
            assert_eq!(got.energy_gain_pct.to_bits(), want.energy_gain_pct.to_bits());
        }
        assert!(back[1].is_none());
        // Random access without a scan.
        assert_eq!(decode_record(&buf, 2).unwrap(), Some(want2));
    }

    #[test]
    fn empty_batch_is_a_valid_header() {
        let buf = encode(&[]);
        assert_eq!(buf.len(), HEADER_BYTES);
        assert_eq!(record_count(&buf).unwrap(), 0);
        assert_eq!(decode(&buf).unwrap(), Vec::new());
    }

    #[test]
    fn corrupt_buffers_are_rejected_with_reasons() {
        let good = encode(&[Ok(answer(2.0))]);
        // Truncated header.
        assert!(record_count(&good[..10]).unwrap_err().contains("too short"));
        // Wrong magic.
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert!(record_count(&bad_magic).unwrap_err().contains("bad magic"));
        // Truncated payload.
        assert!(record_count(&good[..good.len() - 1]).unwrap_err().contains("length mismatch"));
        // Unknown status word.
        let mut bad_status = good.clone();
        bad_status[HEADER_BYTES] = 7;
        assert!(decode_record(&bad_status, 0).unwrap_err().contains("unknown status"));
        // Out-of-range index.
        assert!(decode_record(&good, 1).unwrap_err().contains("out of range"));
    }
}
