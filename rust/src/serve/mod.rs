//! Policy-as-a-service: the batched scenario-query engine behind
//! `ckpt-period batch` and `ckpt-period bench`.
//!
//! The rest of the crate answers *one* scenario per CLI invocation.
//! This module turns the solver into a long-lived service: a stream of
//! JSON-lines queries in, a stream of answers out, with exact-bits
//! deduplication and process-wide caching between them.
//!
//! # Query protocol (JSON lines)
//!
//! One JSON object per line; blank lines are ignored. Fields:
//!
//! ```json
//! {"id": "q1", "scenario": "fig1-rho5.5", "policy": "knee",
//!  "model": "exact", "drift": "io-ramp", "at": 2500}
//! ```
//!
//! * `scenario` (**required**) — a trade-off preset name
//!   (`fig1-rho5.5`, `exascale-io-heavy`, …) or an inline object in the
//!   [`ScenarioSpec`](crate::config::ScenarioSpec) grammar
//!   (`checkpoint{c,r,d,omega}`, `power{…}`, `mu_minutes`,
//!   `t_base_minutes`);
//! * `policy` — `algo-t|algo-e|young|daly|fixed:<T>|knee|knee:curvature|
//!   eps-time:<pct>|eps-energy:<pct>` (default `knee`);
//! * `model` — `first-order|exact|exact:ideal|exact:restarting`
//!   (default `first-order`); frontier-aware policies are retargeted at
//!   this backend;
//! * `drift` — a drift preset (`io-ramp`, `mu-decay`, …) or the
//!   [`DriftProcess`](crate::drift::DriftProcess) grammar (default
//!   stationary);
//! * `at` — the trajectory time (minutes) the answer is read at
//!   (default `0`);
//! * `id` — opaque correlation string, echoed back.
//!
//! Unknown fields are rejected. Each answer is one JSON line on stdout,
//! in **input order**, carrying the line number, the echoed `id`, the
//! canonical policy/model spellings, the chosen period, both objective
//! columns, the backend's per-objective optima and the knee metadata
//! (time overhead vs `t_time_opt`, energy gain).
//!
//! # Error records
//!
//! A malformed or unanswerable line never kills the stream: it becomes
//! a structured record `{"line": <n>, "error": "<reason>"}` on stderr,
//! and the stream position is preserved — line numbers of subsequent
//! answers are unaffected (see [`parse_lines`]). Exit status stays `0`;
//! a non-zero exit means the *stream itself* could not be read.
//!
//! # Backpressure
//!
//! `batch` mode reads the whole stream (stdin/file/one socket
//! connection) before answering: dedup and the pooled solve want the
//! full vector, and answers must come back in input order. Backpressure
//! is therefore at stream granularity — a client pipelining batches
//! over the Unix socket gets one connection per batch, served
//! sequentially from the accept loop, while the answer caches stay warm
//! across connections (that is the point of the long-lived process).
//! Within a batch, stdout carries only answer lines and stderr only
//! error records plus a final `answered N queries (U unique solves), E
//! errors` summary, so the two streams can be consumed independently.
//!
//! # Engine
//!
//! [`BatchEngine`] deduplicates queries by [`Query::solve_key`]
//! (scenario [`key_words`](crate::model::params::Scenario::key_words) +
//! the grid engine's policy encoding + backend + drift + `at`), solves
//! each unique key once on the [`ThreadPool`](crate::util::pool::ThreadPool)
//! work-stealing pool, and scatters answers back — bit-identical to
//! sequential [`solve`] calls at every thread count
//! (`tests/serve_equivalence.rs` gates this). Repeats across batches
//! are served from a process-wide answer cache
//! ([`answer_cache_stats`]; surfaced by `ckpt-period info`). Batches
//! can additionally be written as a compact fixed-offset binary
//! artifact ([`wire`]) via `runtime::artifacts` for zero-copy
//! consumers.
//!
//! [`bench`] packages the standardised serving workload behind
//! `ckpt-period bench`, emitting the repo-root `BENCH_<n>.json` perf
//! trajectory.

pub mod bench;
pub mod engine;
pub mod query;
pub mod wire;

pub use engine::{
    answer_cache_clears, answer_cache_len, answer_cache_shard_entries, answer_cache_stats, solve,
    solve_cached, Answer, BatchEngine,
};
pub use query::{parse_lines, policy_spec, ErrorRecord, Query};
