//! Monte-Carlo validation of the analytic frontier.
//!
//! Every validated frontier point becomes a [`CellJob::Sim`] cell on
//! the grid engine: replicates fan out on the persistent pool, per-cell
//! seeds derive from the spec's base seed and the cell's parameter bits
//! (so the validated frontier is byte-identical for every thread
//! count), and repeated validations of overlapping frontiers hit the
//! process-wide memo cache.
//!
//! The simulation and the agreement band follow the frontier's
//! [`Backend`]:
//!
//! * **first-order** — the closed forms assume failure-free recovery,
//!   so the cells simulate with `failures_during_recovery = false`, and
//!   the analytic value must fall within the 95% confidence band of the
//!   Monte-Carlo mean widened by the model's own truncation error —
//!   the neglected multi-failure-per-period terms scale like `(T/μ)²`,
//!   the same allowance `rust/tests/sim_vs_model.rs` has validated
//!   across every preset family.
//! * **exact** — the renewal model carries no truncation error, so the
//!   band stays at a flat 2% sampling allowance (what
//!   `sim_vs_model::exact_model_matches_simulation_at_small_mu`
//!   established); `RecoveryModel::Ideal` simulates with suspended
//!   recovery, `RecoveryModel::Restarting` with failures striking
//!   during D + R — each exact variant validates against the process it
//!   models.

use crate::model::backend::Backend;
use crate::model::exact::RecoveryModel;
use crate::model::params::Scenario;
use crate::sweep::{Cell, CellJob, GridSpec, SimSummary};

use super::frontier::{Frontier, FrontierPoint};

/// One frontier point with its Monte-Carlo estimate and verdicts.
#[derive(Debug, Clone, PartialEq)]
pub struct ValidatedPoint {
    pub point: FrontierPoint,
    /// The derived per-cell seed (reproduce with
    /// `monte_carlo(cfg, replicates, seed, 1)`).
    pub seed: u64,
    pub sim: SimSummary,
    pub time_agrees: bool,
    pub energy_agrees: bool,
}

/// The validated frontier.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierValidation {
    pub replicates: usize,
    pub points: Vec<ValidatedPoint>,
}

impl FrontierValidation {
    /// True when every validated point agrees in both objectives.
    pub fn all_agree(&self) -> bool {
        self.points.iter().all(|p| p.time_agrees && p.energy_agrees)
    }
}

/// Subsample up to `max_points` frontier points (endpoints always
/// included), simulate each as one grid cell under the failure process
/// matching the frontier's backend, and compare the analytic objectives
/// against the Monte-Carlo confidence bands.
pub fn validate(
    frontier: &Frontier,
    max_points: usize,
    replicates: usize,
    base_seed: u64,
) -> FrontierValidation {
    assert!(max_points >= 2 && replicates >= 2);
    let s = frontier.scenario;
    let backend = frontier.backend;
    let picked = subsample(frontier.points(), max_points);

    let failures_during_recovery = match backend {
        // The first-order forms assume failure-free recovery; so does
        // the exact Ideal variant.
        Backend::FirstOrder | Backend::Exact(RecoveryModel::Ideal) => false,
        Backend::Exact(RecoveryModel::Restarting) => true,
    };
    let mut spec = GridSpec::new(base_seed);
    for p in &picked {
        spec.push(Cell {
            scenario: s,
            failure: None,
            job: CellJob::Sim { period: p.period, replicates, failures_during_recovery },
        });
    }
    let results = spec.evaluate();

    let points = picked
        .into_iter()
        .zip(results)
        .map(|(point, r)| {
            let sim = *r.output.sim().expect("sim cell output");
            let tol = model_tol(&s, point.period, backend);
            let time_agrees = within_band(
                point.time,
                sim.makespan_mean,
                sim.makespan_ci95_half,
                tol,
            );
            let energy_agrees =
                within_band(point.energy, sim.energy_mean, sim.energy_ci95_half, tol);
            ValidatedPoint { point, seed: r.seed, sim, time_agrees, energy_agrees }
        })
        .collect();
    FrontierValidation { replicates, points }
}

/// Relative truncation allowance of the first-order model at period
/// `t`: `2% + (T/μ)²/2` (see `rust/tests/sim_vs_model.rs`).
pub fn truncation_tol(s: &Scenario, t: f64) -> f64 {
    0.02 + 0.5 * (t / s.mu).powi(2)
}

/// The agreement allowance for `backend` at period `t`: the first-order
/// truncation band, or a flat 2% for the truncation-free exact model.
pub fn model_tol(s: &Scenario, t: f64, backend: Backend) -> f64 {
    match backend {
        Backend::FirstOrder => truncation_tol(s, t),
        Backend::Exact(_) => 0.02,
    }
}

fn within_band(model: f64, mean: f64, ci95_half: f64, rel_tol: f64) -> bool {
    (model - mean).abs() <= 3.0 * ci95_half + rel_tol * model
}

/// Evenly spaced indices over `points` including both endpoints.
fn subsample(points: &[FrontierPoint], max_points: usize) -> Vec<FrontierPoint> {
    if points.len() <= max_points {
        return points.to_vec();
    }
    let n = points.len();
    let mut out = Vec::with_capacity(max_points);
    let mut last = usize::MAX;
    for i in 0..max_points {
        let idx = (i as f64 * (n - 1) as f64 / (max_points - 1) as f64).round() as usize;
        let idx = idx.min(n - 1);
        if idx != last {
            out.push(points[idx]);
            last = idx;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fig1_scenario;
    use crate::sim::{monte_carlo, SimConfig};

    #[test]
    fn reference_frontier_validates() {
        let s = fig1_scenario(300.0, 5.5);
        let f = Frontier::compute(&s, 33, Backend::FirstOrder).unwrap();
        let v = validate(&f, 4, 120, 2013);
        assert_eq!(v.points.len(), 4);
        assert!(v.all_agree(), "{:?}", v.points.iter().map(|p| (p.time_agrees, p.energy_agrees)).collect::<Vec<_>>());
        // Endpoints survived subsampling.
        assert_eq!(v.points[0].point.period.to_bits(), f.t_time_opt.to_bits());
        assert_eq!(
            v.points.last().unwrap().point.period.to_bits(),
            f.t_energy_opt.to_bits()
        );
    }

    #[test]
    fn exact_frontier_validates_where_first_order_would_need_the_wide_band() {
        // mu=120: AlgoE periods reach ~0.5*mu, where the first-order
        // forms need their (T/mu)^2 allowance. The exact backend's
        // frontier must agree within the flat 2% band, in both recovery
        // modes.
        let s = fig1_scenario(120.0, 5.5);
        for m in [RecoveryModel::Ideal, RecoveryModel::Restarting] {
            let f = Frontier::compute(&s, 17, Backend::Exact(m)).unwrap();
            let v = validate(&f, 3, 200, 2013);
            assert!(
                v.all_agree(),
                "{m:?}: {:?}",
                v.points
                    .iter()
                    .map(|p| (p.point.period, p.time_agrees, p.energy_agrees))
                    .collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn validation_is_deterministic_and_seed_reproducible() {
        let s = fig1_scenario(300.0, 5.5);
        let f = Frontier::compute(&s, 17, Backend::FirstOrder).unwrap();
        let a = validate(&f, 3, 64, 7);
        let b = validate(&f, 3, 64, 7);
        assert_eq!(a, b);
        // Each point's estimate is exactly serial monte_carlo at the
        // derived seed (the grid engine's determinism contract).
        for p in &a.points {
            let mut cfg = SimConfig::paper(s, p.point.period);
            cfg.failures_during_recovery = false;
            let mc = monte_carlo(&cfg, 64, p.seed, 1);
            assert_eq!(p.sim.makespan_mean.to_bits(), mc.makespan.mean().to_bits());
            assert_eq!(p.sim.energy_mean.to_bits(), mc.energy.mean().to_bits());
        }
    }

    #[test]
    fn subsample_keeps_endpoints_and_order() {
        let pts: Vec<FrontierPoint> = (0..100)
            .map(|i| FrontierPoint { period: i as f64, time: i as f64, energy: -(i as f64) })
            .collect();
        let out = subsample(&pts, 7);
        assert_eq!(out.len(), 7);
        assert_eq!(out[0].period, 0.0);
        assert_eq!(out[6].period, 99.0);
        for w in out.windows(2) {
            assert!(w[1].period > w[0].period);
        }
        // No subsampling needed when the frontier is small enough.
        assert_eq!(subsample(&pts[..5], 7).len(), 5);
    }
}
