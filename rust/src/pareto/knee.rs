//! Knee-point detection on a normalised frontier.
//!
//! The knee is where the trade-off stops paying: to its left a small
//! time concession buys a lot of energy, to its right the returns
//! flatten. Two standard detectors, both operating on the frontier's
//! normalised `[0, 1]²` coordinates so the choice of units cannot move
//! the knee:
//!
//! * **max distance to chord** — the point farthest below the straight
//!   line joining the AlgoT and AlgoE endpoints (the classic
//!   "kneedle" geometry). Robust to sampling density.
//! * **max curvature** — the point of largest discrete (Menger)
//!   curvature over consecutive point triples. More local; agrees with
//!   the chord detector on cleanly convex frontiers and flags genuinely
//!   sharp bends on irregular ones.

use super::frontier::{Frontier, FrontierPoint};

/// Which detector produced a [`Knee`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KneeMethod {
    MaxDistanceToChord,
    MaxCurvature,
}

/// A detected knee point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Knee {
    /// Index into [`Frontier::points`].
    pub index: usize,
    pub point: FrontierPoint,
    /// The detector's score at the knee (chord distance in normalised
    /// units, or Menger curvature).
    pub score: f64,
    pub method: KneeMethod,
}

/// Detect the knee of `frontier` with `method`. `None` when the
/// frontier has no interior point (fewer than three samples).
pub fn knee(frontier: &Frontier, method: KneeMethod) -> Option<Knee> {
    let norm = frontier.normalized();
    if norm.len() < 3 {
        return None;
    }
    let scores: Vec<f64> = match method {
        KneeMethod::MaxDistanceToChord => chord_distances(&norm),
        KneeMethod::MaxCurvature => menger_curvatures(&norm),
    };
    // Interior argmax, deterministic first-wins tie-break.
    let mut best: Option<(usize, f64)> = None;
    for (i, &score) in scores.iter().enumerate() {
        if i == 0 || i == norm.len() - 1 {
            continue;
        }
        if best.map(|(_, b)| score > b).unwrap_or(true) {
            best = Some((i, score));
        }
    }
    best.map(|(index, score)| Knee {
        index,
        point: frontier.points()[index],
        score,
        method,
    })
}

/// Perpendicular distance of each point to the endpoint chord.
fn chord_distances(norm: &[(f64, f64)]) -> Vec<f64> {
    let (x0, y0) = norm[0];
    let (x1, y1) = *norm.last().expect("non-empty");
    let (dx, dy) = (x1 - x0, y1 - y0);
    let len = (dx * dx + dy * dy).sqrt();
    if len == 0.0 {
        return vec![0.0; norm.len()];
    }
    norm.iter()
        .map(|&(x, y)| ((x - x0) * dy - (y - y0) * dx).abs() / len)
        .collect()
}

/// Discrete Menger curvature per point (endpoints get 0): four times
/// the triangle area over the product of the side lengths of each
/// consecutive triple.
fn menger_curvatures(norm: &[(f64, f64)]) -> Vec<f64> {
    let mut out = vec![0.0; norm.len()];
    for i in 1..norm.len() - 1 {
        let (ax, ay) = norm[i - 1];
        let (bx, by) = norm[i];
        let (cx, cy) = norm[i + 1];
        let area2 = ((bx - ax) * (cy - ay) - (by - ay) * (cx - ax)).abs();
        let ab = ((bx - ax).powi(2) + (by - ay).powi(2)).sqrt();
        let bc = ((cx - bx).powi(2) + (cy - by).powi(2)).sqrt();
        let ca = ((cx - ax).powi(2) + (cy - ay).powi(2)).sqrt();
        let denom = ab * bc * ca;
        out[i] = if denom > 0.0 { 2.0 * area2 / denom } else { 0.0 };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fig1_scenario;
    use crate::model::backend::Backend;
    use crate::pareto::frontier::Frontier;

    #[test]
    fn both_methods_find_an_interior_knee() {
        let s = fig1_scenario(300.0, 5.5);
        let f = Frontier::compute(&s, 65, Backend::FirstOrder).unwrap();
        for method in [KneeMethod::MaxDistanceToChord, KneeMethod::MaxCurvature] {
            let k = f.knee(method).expect("interior knee");
            assert!(k.index > 0 && k.index < f.len() - 1, "{method:?} at {}", k.index);
            assert!(k.score > 0.0, "{method:?} score {}", k.score);
            assert_eq!(k.method, method);
            // The knee is a real frontier point.
            assert_eq!(k.point, f.points()[k.index]);
        }
    }

    #[test]
    fn knee_buys_most_of_the_gain_for_part_of_the_price() {
        // The knee's raison d'être: at the chord knee the energy gain
        // fraction (of the full AlgoT→AlgoE gain) exceeds the time cost
        // fraction (of the full overhead).
        let s = fig1_scenario(300.0, 5.5);
        let f = Frontier::compute(&s, 129, Backend::FirstOrder).unwrap();
        let k = f.knee(KneeMethod::MaxDistanceToChord).unwrap();
        let norm = f.normalized();
        let (x, y) = norm[k.index];
        // Below the chord x + y = 1 means gain fraction (1 - y) > time
        // fraction x.
        assert!(1.0 - y > x, "knee at ({x}, {y}) not below the chord");
    }

    #[test]
    fn chord_knee_stable_under_refinement() {
        let s = fig1_scenario(300.0, 7.0);
        let coarse = Frontier::compute(&s, 33, Backend::FirstOrder).unwrap();
        let fine = Frontier::compute(&s, 257, Backend::FirstOrder).unwrap();
        let kc = coarse.knee(KneeMethod::MaxDistanceToChord).unwrap();
        let kf = fine.knee(KneeMethod::MaxDistanceToChord).unwrap();
        // Same knee location within one coarse step.
        let step = (coarse.t_energy_opt - coarse.t_time_opt).abs() / 32.0;
        assert!(
            (kc.point.period - kf.point.period).abs() <= 1.5 * step,
            "coarse {} vs fine {}",
            kc.point.period,
            kf.point.period
        );
        // Scores converge too.
        assert!((kc.score - kf.score).abs() < 0.05);
    }

    #[test]
    fn too_few_points_yield_no_knee() {
        let s = fig1_scenario(300.0, 5.5);
        let f = Frontier::compute(&s, 2, Backend::FirstOrder).unwrap();
        assert!(f.knee(KneeMethod::MaxDistanceToChord).is_none());
        assert!(f.knee(KneeMethod::MaxCurvature).is_none());
    }
}
